//! Equality harness for every decode path.
//!
//! CORP's serving claims only mean something if pruned+compensated decode
//! provably computes the same function as the reference forward. This
//! suite pins the KV-cached incremental path (`dec_*` artifacts through
//! `exec::DecodePlan`) against the full-prefill `run_gpt` forward,
//! token-for-token, on dense, pruned, and compensated gpt_s — across
//! prompt lengths (1, mid, `n_ctx − 1`), batch sizes (1 and batched, with
//! mixed prefill + continuation dispatches), decode modes (kv vs
//! prefill-per-step), engine worker counts, and dispatch policies — and
//! across the paged-KV features: chunked prefill, prefix-block adoption,
//! and fork/copy-on-write must leave every output bit-identical. It also
//! carries the causal-mask regression probe: poisoned future tokens and
//! poisoned cache padding must never leak into a position's logits.
//!
//! Everything runs on the native runtime (no artifacts directory); the
//! engine pieces are compiled out under `--cfg pjrt_backend` like
//! `serve_engine.rs`.
#![cfg(not(pjrt_backend))]

use corp::data::{Split, TextGen};
use corp::exec::{argmax, DecodeMode, Executor, ForwardPlan, KvPoolOpts};
use corp::model::{ModelConfig, Scope, Sparsity, WeightStore};
use corp::prune::{calibrate, prune, Method, PruneOpts};
use corp::runtime::{Input, Runtime};
use corp::serve::{run_engine, DispatchPolicy, EngineOpts, GenWorkload, Workload};
use corp::tensor::Tensor;

fn native_runtime() -> Runtime {
    Runtime::new(std::env::temp_dir().join("corp_decode_equality_no_artifacts")).unwrap()
}

fn gpt_s() -> &'static ModelConfig {
    ModelConfig::by_name("gpt_s").unwrap()
}

/// Prune at 50% joint sparsity from a tiny calibration pass, with
/// (`Method::Corp`) or without (`Method::Naive`) compensation.
fn pruned_store(exec: &Executor<'_>, dense: &WeightStore, method: Method) -> WeightStore {
    let opts = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 5),
        method,
        calib_batches: 2,
        attn_max_samples: 32,
        ..PruneOpts::default()
    };
    let stats = calibrate(exec, dense, &opts).unwrap();
    prune(exec, dense, &stats, &opts).unwrap().weights
}

/// Reference greedy decode through the fused full-prefill forward: every
/// step re-runs the whole (zero-padded) sequence and reads the logits at
/// the current last position.
fn greedy_full(
    plan: &ForwardPlan<'_, '_>,
    cfg: &ModelConfig,
    prompt: &[i32],
    steps: usize,
) -> (Vec<i32>, Vec<Vec<f32>>) {
    let mut seq = prompt.to_vec();
    let mut preds = Vec::with_capacity(steps);
    let mut rows = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut padded = seq.clone();
        padded.resize(cfg.n_ctx, 0);
        let logits = plan.run_gpt(&padded, 1).unwrap();
        let row = logits.data()[(seq.len() - 1) * cfg.vocab..seq.len() * cfg.vocab].to_vec();
        let p = argmax(&row);
        preds.push(p);
        rows.push(row);
        if seq.len() < cfg.n_ctx {
            seq.push(p);
        }
    }
    (preds, rows)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn kv_decode_matches_full_prefill_token_for_token() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let pruned = pruned_store(&exec, &dense, Method::Naive);
    let comp = pruned_store(&exec, &dense, Method::Corp);
    let gen = TextGen::new(corp::data::DATA_SEED);
    let n = cfg.n_ctx;
    for (label, w) in [("dense", &dense), ("pruned", &pruned), ("compensated", &comp)] {
        let dec = exec.decode_plan(w).unwrap();
        assert_eq!(dec.mode, DecodeMode::KvCache);
        let fwd = exec.forward_plan(w).unwrap();
        for plen in [1usize, n / 2, n - 1] {
            let (ids, _) = gen.batch(Split::Eval, plen as u64, 1, n);
            let prompt = &ids[..plen];
            let steps = (n - plen + 1).min(4);
            let (pk, rk) = dec.greedy(prompt, steps).unwrap();
            let (pf, rf) = greedy_full(&fwd, cfg, prompt, steps);
            assert_eq!(pk, pf, "{label} plen={plen}: greedy token streams diverged");
            for (i, (a, b)) in rk.iter().zip(&rf).enumerate() {
                let d = max_abs_diff(a, b);
                assert!(d < 1e-5, "{label} plen={plen} step {i}: kv vs prefill logits |Δ|={d}");
            }
        }
    }
}

#[test]
fn batched_mixed_length_extend_matches_full_forward_rows() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let pruned = pruned_store(&exec, &dense, Method::Naive);
    let gen = TextGen::new(corp::data::DATA_SEED);
    let n = cfg.n_ctx;
    let plens = [1usize, n / 2, n - 1];
    for (label, w) in [("dense", &dense), ("pruned", &pruned)] {
        let dec = exec.decode_plan(w).unwrap();
        let fwd = exec.forward_plan(w).unwrap();
        // Three sequences with different prompt lengths prefill together in
        // one padded dispatch (batch 3 dispatched at 4).
        let prompts: Vec<Vec<i32>> =
            plens.iter().map(|&p| gen.batch(Split::Eval, p as u64, 1, n).0[..p].to_vec()).collect();
        let mut s0 = dec.begin();
        let mut s1 = dec.begin();
        let mut s2 = dec.begin();
        let rows = {
            let mut states = [&mut s0, &mut s1, &mut s2];
            let new: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
            dec.extend_at(&mut states, &new, 4).unwrap()
        };
        // Every prompt position's logits must match the fused full forward.
        for (e, prompt) in prompts.iter().enumerate() {
            let mut padded = prompt.clone();
            padded.resize(n, 0);
            let full = fwd.run_gpt(&padded, 1).unwrap();
            let want = &full.data()[..prompt.len() * cfg.vocab];
            let d = max_abs_diff(&rows[e], want);
            assert!(d < 1e-5, "{label} seq {e}: batched prefill rows |Δ|={d}");
        }
        // A mixed dispatch: two single-token continuations + one fresh
        // prefill batch together; per-sequence lengths ride the dispatch.
        let cont0 = vec![argmax(&rows[0][rows[0].len() - cfg.vocab..])];
        let cont1 = vec![argmax(&rows[1][rows[1].len() - cfg.vocab..])];
        let fresh = gen.batch(Split::Eval, 99, 1, n).0[..5].to_vec();
        let mut s3 = dec.begin();
        let rows2 = {
            let mut states = [&mut s0, &mut s1, &mut s3];
            let new: Vec<&[i32]> = vec![&cont0, &cont1, &fresh];
            dec.extend(&mut states, &new).unwrap()
        };
        let cases: [(&corp::exec::DecodeState, usize); 3] = [(&s0, 1), (&s1, 1), (&s3, 5)];
        for (e, (st, m)) in cases.iter().enumerate() {
            let mut padded = st.ids().to_vec();
            padded.resize(n, 0);
            let full = fwd.run_gpt(&padded, 1).unwrap();
            let want =
                &full.data()[(st.len() - m) * cfg.vocab..st.len() * cfg.vocab];
            let d = max_abs_diff(&rows2[e], want);
            assert!(d < 1e-5, "{label} mixed seq {e}: |Δ|={d}");
        }
    }
}

#[test]
fn prefill_fallback_mode_matches_kv_cache() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let pruned = pruned_store(&exec, &dense, Method::Naive);
    let gen = TextGen::new(corp::data::DATA_SEED);
    for (label, w) in [("dense", &dense), ("pruned", &pruned)] {
        let kv = exec.decode_plan_with(w, DecodeMode::KvCache).unwrap();
        let pf = exec.decode_plan_with(w, DecodeMode::Prefill).unwrap();
        let (ids, plen) = gen.prompt(3, cfg.n_ctx, 4);
        let plen = plen.min(cfg.n_ctx - 5);
        let (pk, rk) = kv.greedy(&ids[..plen], 6).unwrap();
        let (pp, rp) = pf.greedy(&ids[..plen], 6).unwrap();
        assert_eq!(pk, pp, "{label}: kv vs prefill-per-step token streams diverged");
        for (i, (a, b)) in rk.iter().zip(&rp).enumerate() {
            let d = max_abs_diff(a, b);
            assert!(d < 1e-5, "{label} step {i}: |Δ|={d}");
        }
        // The two modes dispatch different artifact families.
        assert!(kv.artifact(1).starts_with("dec_"));
        assert!(pf.artifact(1).starts_with("fwd_"));
    }
}

#[test]
fn decode_plan_artifact_cache_reuses_handles() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 6);
    let plan = exec.decode_plan(&w).unwrap();
    assert_eq!(plan.cached_batch_sizes(), 0);
    let a1 = plan.artifact(2);
    let a2 = plan.artifact(2);
    assert!(std::sync::Arc::ptr_eq(&a1, &a2));
    assert_eq!(&*a1, format!("dec_gpt_s_q{}_o{}_b2", plan.dqk, plan.o).as_str());
    assert_eq!(plan.cached_batch_sizes(), 1);
    // Degenerate extends are rejected with clear errors.
    let mut st = plan.begin();
    assert!(plan.extend(&mut [], &[]).is_err());
    let too_long = vec![0i32; cfg.n_ctx + 1];
    assert!(plan.extend(&mut [&mut st], &[&too_long]).is_err());
    let empty: &[i32] = &[];
    assert!(plan.extend(&mut [&mut st], &[empty]).is_err());
}

#[test]
fn gen_workload_invariant_across_workers_and_dispatch() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 11);
    let comp = pruned_store(&exec, &dense, Method::Corp);
    for (label, w) in [("dense", &dense), ("compensated", &comp)] {
        let workload = GenWorkload::new(cfg, corp::data::DATA_SEED).unwrap().with_max_new(4);
        let mk = |workers, dispatch| EngineOpts {
            workers,
            rate: 1e12,
            requests: 12,
            max_batch: 4,
            max_wait: 0.002,
            queue_cap: 256,
            dispatch,
            ..Default::default()
        };
        let key = |s: &corp::serve::EngineStats| -> Vec<(usize, i32, usize, usize)> {
            s.records.iter().map(|r| (r.id, r.pred, r.tokens, r.steps)).collect()
        };
        let mut baseline: Option<Vec<(usize, i32, usize, usize)>> = None;
        for workers in [1usize, 2, 4] {
            for dispatch in
                [DispatchPolicy::Padded, DispatchPolicy::Exact, DispatchPolicy::Auto]
            {
                let s = run_engine(&exec, w, &workload, &mk(workers, dispatch)).unwrap();
                assert_eq!(s.served, 12, "{label} w={workers} {dispatch:?}");
                assert_eq!(s.shed, 0);
                // Multi-step accounting is self-consistent.
                for r in &s.records {
                    assert!(r.steps >= 1);
                    assert!(r.first_ms <= r.total_ms + 1e-9);
                    if r.steps == 1 {
                        assert_eq!(r.itl_ms, 0.0);
                    } else {
                        assert!(r.itl_ms >= 0.0);
                    }
                }
                assert!(s.steps_mean >= 1.0);
                let k = key(&s);
                match &baseline {
                    None => baseline = Some(k),
                    Some(b) => assert_eq!(
                        &k, b,
                        "{label}: outputs changed at workers={workers} dispatch={dispatch:?}"
                    ),
                }
            }
        }
        // Every engine record equals a direct greedy decode of the same
        // request: same final token, token charge, and step count.
        let dec = exec.decode_plan(w).unwrap();
        let base = baseline.unwrap();
        for &(id, pred, tokens, steps) in &base {
            let req = workload.synth(id);
            assert_eq!(steps, req.target_new, "{label} request {id}");
            assert_eq!(tokens, req.prompt_len + req.target_new, "{label} request {id}");
            let (preds, _) = dec.greedy(&req.prompt, req.target_new).unwrap();
            assert_eq!(pred, *preds.last().unwrap(), "{label} request {id}");
        }
    }
}

/// Assemble the `dec_*` input list by hand: ids, past, fresh, caches, then
/// the full dense parameter list in spec order.
fn dec_inputs<'a>(
    cfg: &ModelConfig,
    w: &'a WeightStore,
    ids: &'a [i32],
    past: &'a [i32],
    fresh: &'a [i32],
    kc: &'a Tensor,
    vc: &'a Tensor,
) -> Vec<Input<'a>> {
    let b = past.len();
    let m = ids.len() / b;
    let mut inputs: Vec<Input<'a>> = vec![
        Input::I32(ids, vec![b, m]),
        Input::I32(past, vec![b]),
        Input::I32(fresh, vec![b]),
        Input::F32(kc),
        Input::F32(vc),
    ];
    for (name, _) in cfg.param_spec_at(cfg.dh(), cfg.mlp) {
        inputs.push(Input::F32(w.expect(&name).unwrap()));
    }
    inputs
}

#[test]
fn incremental_mask_ignores_future_tokens_and_cache_padding() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 6);
    let (n, h, l, dh, vocab) = (cfg.n_ctx, cfg.heads, cfg.layers, cfg.dh(), cfg.vocab);
    let art = cfg.dec_artifact(dh, cfg.mlp, 1);
    let gen = TextGen::new(corp::data::DATA_SEED);
    let (full_ids, _) = gen.batch(Split::Eval, 0, 1, n);
    let plen = 8usize;
    let prompt = &full_ids[..plen];

    let zero_k = Tensor::from_vec(&[1, l, h, n, dh], vec![0.0; l * h * n * dh]);
    let zero_v = Tensor::from_vec(&[1, l, h, n, dh], vec![0.0; l * h * n * dh]);

    // A: one-shot prefill of the prompt through the incremental artifact.
    let past0 = [0i32];
    let fresh_a = [plen as i32];
    let out_a = rt
        .execute(&art, &dec_inputs(cfg, &w, prompt, &past0, &fresh_a, &zero_k, &zero_v))
        .unwrap();
    let logits_a = &out_a[0];
    assert_eq!(logits_a.shape(), &[1, plen, vocab]);

    // The incremental prefill equals the layered full forward row-for-row.
    let mut padded = prompt.to_vec();
    padded.resize(n, 0);
    let full = exec.forward_gpt(&w, &padded, 1).unwrap();
    let d = max_abs_diff(logits_a.data(), &full.data()[..plen * vocab]);
    assert!(d < 1e-5, "incremental prefill vs full forward |Δ|={d}");

    // B: poison every token after position 3 with different (valid) ids —
    // rows 0..=3 must not move: the causal mask never attends past the
    // current position.
    let mut poisoned = prompt.to_vec();
    for t in poisoned.iter_mut().skip(4) {
        *t = (*t + 17) % vocab as i32;
    }
    let out_b = rt
        .execute(&art, &dec_inputs(cfg, &w, &poisoned, &past0, &fresh_a, &zero_k, &zero_v))
        .unwrap();
    let d = max_abs_diff(
        &logits_a.data()[..4 * vocab],
        &out_b[0].data()[..4 * vocab],
    );
    assert!(d < 1e-6, "future-token poison leaked into past logits |Δ|={d}");
    // ...and rows past the poison point must move (the probe is live).
    let d_after = max_abs_diff(
        &logits_a.data()[4 * vocab..],
        &out_b[0].data()[4 * vocab..],
    );
    assert!(d_after > 1e-6, "poison probe inert — future rows did not change");

    // C: split prefill at position 3 and poison the cache *padding* (rows
    // ≥ past) with huge values — masked attention must never read them.
    let knew = &out_a[1]; // [1, l, h, plen, dh]
    let vnew = &out_a[2];
    let split = 3usize;
    let mut kbuf = vec![1e9f32; l * h * n * dh];
    let mut vbuf = vec![1e9f32; l * h * n * dh];
    for lh in 0..l * h {
        for r in 0..split {
            let src = (lh * plen + r) * dh;
            let dst = (lh * n + r) * dh;
            kbuf[dst..dst + dh].copy_from_slice(&knew.data()[src..src + dh]);
            vbuf[dst..dst + dh].copy_from_slice(&vnew.data()[src..src + dh]);
        }
    }
    let kc = Tensor::from_vec(&[1, l, h, n, dh], kbuf);
    let vc = Tensor::from_vec(&[1, l, h, n, dh], vbuf);
    let past3 = [split as i32];
    let fresh_c = [(plen - split) as i32];
    let out_c = rt
        .execute(&art, &dec_inputs(cfg, &w, &prompt[split..], &past3, &fresh_c, &kc, &vc))
        .unwrap();
    let d = max_abs_diff(out_c[0].data(), &logits_a.data()[split * vocab..]);
    assert!(d < 1e-5, "poisoned cache padding leaked into decode logits |Δ|={d}");
}

#[test]
fn greedy_rejects_zero_steps_and_empty_prompt() {
    // Regression: `steps == 0` used to reach the `steps - 1` capacity
    // arithmetic; it must be a clear error, not an underflow panic.
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 6);
    for mode in [DecodeMode::KvCache, DecodeMode::Prefill] {
        let dec = exec.decode_plan_with(&w, mode).unwrap();
        let err = dec.greedy(&[1, 2, 3], 0).unwrap_err().to_string();
        assert!(err.contains("steps"), "{mode:?}: unhelpful zero-steps error: {err}");
        let err = dec.greedy(&[], 4).unwrap_err().to_string();
        assert!(err.contains("prompt"), "{mode:?}: unhelpful empty-prompt error: {err}");
        assert!(dec.greedy_chunked(&[1, 2, 3], 0, 2).is_err());
    }
}

#[test]
fn chunked_prefill_matches_one_shot_exactly() {
    // Per-row K/V and logits arithmetic is independent of how prompt
    // positions are grouped into dispatches, so chunked prefill is not
    // merely close — it is bitwise identical to the one-shot prefill.
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let comp = pruned_store(&exec, &dense, Method::Corp);
    let gen = TextGen::new(corp::data::DATA_SEED);
    let (ids, _) = gen.batch(Split::Eval, 7, 1, cfg.n_ctx);
    let (plen, steps) = (24usize, 5usize);
    let prompt = &ids[..plen];
    for (label, w) in [("dense", &dense), ("compensated", &comp)] {
        let (p0, r0) = exec.decode_plan(w).unwrap().greedy(prompt, steps).unwrap();
        for chunk in [1usize, 3, 8, 100] {
            let dec = exec.decode_plan(w).unwrap();
            let (p, r) = dec.greedy_chunked(prompt, steps, chunk).unwrap();
            assert_eq!(p, p0, "{label} chunk={chunk}: token streams diverged");
            assert_eq!(r, r0, "{label} chunk={chunk}: logits not bitwise identical");
        }
    }
}

#[test]
fn prefix_sharing_adopts_blocks_and_preserves_outputs() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 6);
    let gen = TextGen::new(corp::data::DATA_SEED);
    let (ids, _) = gen.batch(Split::Eval, 13, 1, cfg.n_ctx);
    // Default block size is 16: a 24-token prompt publishes one full
    // block, which the second sequence adopts (24 - 1 ≥ 16).
    let (plen, steps) = (24usize, 4usize);
    let prompt = &ids[..plen];
    let dec = exec.decode_plan(&w).unwrap();
    let (p1, r1) = dec.greedy(prompt, steps).unwrap();
    let s0 = dec.pool_stats().unwrap();
    assert!(s0.registered_prefixes >= 1, "greedy did not publish its prompt prefix");
    assert_eq!(s0.shared_hits, 0);
    let (p2, r2) = dec.greedy(prompt, steps).unwrap();
    let s1 = dec.pool_stats().unwrap();
    assert!(s1.shared_hits > 0, "second identical prompt adopted no blocks");
    assert!(s1.allocs < 2 * s0.allocs, "adoption did not save allocations");
    assert_eq!(p1, p2, "prefix adoption changed the token stream");
    assert_eq!(r1, r2, "prefix adoption changed the logits");
    // A sharing-disabled pool computes the same function from scratch.
    let iso = exec
        .decode_plan_opts(&w, DecodeMode::KvCache, KvPoolOpts { share_prefixes: false, ..KvPoolOpts::default() })
        .unwrap();
    let (p3, r3) = iso.greedy(prompt, steps).unwrap();
    assert_eq!(iso.pool_stats().unwrap().shared_hits, 0);
    assert_eq!(p1, p3);
    assert_eq!(r1, r3);
}

#[test]
fn fork_copy_on_write_keeps_branches_independent() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 6);
    let gen = TextGen::new(corp::data::DATA_SEED);
    let (ids, _) = gen.batch(Split::Eval, 21, 1, cfg.n_ctx);
    // 10 tokens: a single *partial* block, so the fork shares a tail block
    // that the first append must copy-on-write.
    let prompt = &ids[..10];
    let dec = exec.decode_plan(&w).unwrap();
    let (mut st, skip) = dec.begin_prompt(prompt).unwrap();
    assert_eq!(skip, 0, "empty registry must adopt nothing");
    let rows = dec.extend(&mut [&mut st], &[prompt]).unwrap();
    let p = argmax(&rows[0][rows[0].len() - cfg.vocab..]);
    let mut br = st.fork();
    assert_eq!(br.ids(), st.ids());
    assert_eq!(br.kv_blocks(), st.kv_blocks());
    let cow0 = dec.pool_stats().unwrap().cow_copies;
    // Trunk and branch continue with different tokens.
    let alt = (p + 1) % cfg.vocab as i32;
    let r_trunk = dec.extend(&mut [&mut st], &[&[p]]).unwrap();
    let r_branch = dec.extend(&mut [&mut br], &[&[alt]]).unwrap();
    assert!(
        dec.pool_stats().unwrap().cow_copies > cow0,
        "append into a forked tail block did not copy-on-write"
    );
    // Each branch's logits equal the full forward over its own sequence.
    let fwd = exec.forward_plan(&w).unwrap();
    for (label, state, row) in [("trunk", &st, &r_trunk), ("branch", &br, &r_branch)] {
        let mut padded = state.ids().to_vec();
        padded.resize(cfg.n_ctx, 0);
        let full = fwd.run_gpt(&padded, 1).unwrap();
        let want = &full.data()[(state.len() - 1) * cfg.vocab..state.len() * cfg.vocab];
        let d = max_abs_diff(&row[0], want);
        assert!(d < 1e-5, "{label}: post-fork logits |Δ|={d}");
    }
    assert_ne!(st.ids().last(), br.ids().last());
}

#[test]
fn kv_bytes_scale_with_appended_rows_not_context_capacity() {
    // The acceptance property behind the bench's `kv_bytes_per_step`
    // column: cache traffic is exactly the appended rows times the
    // per-row K/V footprint — there is no `n_ctx` term, unlike the old
    // slab design which copied the full [n_ctx] cache every step.
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let pruned = pruned_store(&exec, &dense, Method::Naive);
    let gen = TextGen::new(corp::data::DATA_SEED);
    let (ids, _) = gen.batch(Split::Eval, 5, 1, cfg.n_ctx);
    let (plen, steps) = (10usize, 5usize);
    for (label, w) in [("dense", &dense), ("pruned", &pruned)] {
        let dec = exec.decode_plan(w).unwrap();
        assert_eq!(dec.kv_counters(), (0, 0));
        dec.greedy(&ids[..plen], steps).unwrap();
        let (dispatches, bytes) = dec.kv_counters();
        assert_eq!(dispatches, steps as u64, "{label}");
        let row = cfg.layers * cfg.heads * (dec.dqk + cfg.dh()) * std::mem::size_of::<f32>();
        let appended = plen + steps - 1; // prompt rows + one row per later step
        assert_eq!(bytes, (appended * row) as u64, "{label}");
        // Pool accounting agrees with the counter-level story.
        let s = dec.pool_stats().unwrap();
        assert!(s.peak_bytes() >= bytes, "{label}: peak below appended bytes");
        assert_eq!(s.block_bytes, s.block_positions * row, "{label}");
    }
}

#[test]
fn engine_outputs_invariant_under_chunked_prefill_and_prefix_sharing() {
    // The serving-side acceptance check: splitting prefills into bounded
    // chunks and adopting shared-opening blocks are scheduling/memory
    // optimizations — request outputs must be bit-identical across chunk
    // sizes, and the pool must actually report adopted blocks.
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 11);
    let eopts = EngineOpts {
        workers: 2,
        rate: 1e12,
        requests: 12,
        max_batch: 4,
        max_wait: 0.002,
        queue_cap: 256,
        dispatch: DispatchPolicy::Exact,
        ..Default::default()
    };
    // min_prompt 20 > shared opening 16 = one default block, so every
    // request both registers and (after the first) adopts the opening.
    let mk_wl = |chunk: usize| {
        GenWorkload::new(cfg, corp::data::DATA_SEED)
            .unwrap()
            .with_max_new(4)
            .with_min_prompt(20)
            .with_shared_prefix(16)
            .with_prefill_chunk(chunk)
    };
    let key = |s: &corp::serve::EngineStats| -> Vec<(usize, i32, usize)> {
        let mut k: Vec<_> = s.records.iter().map(|r| (r.id, r.pred, r.tokens)).collect();
        k.sort_unstable();
        k
    };
    let mut baseline: Option<Vec<(usize, i32, usize)>> = None;
    for chunk in [0usize, 1, 4, 7] {
        let s = run_engine(&exec, &w, &mk_wl(chunk), &eopts).unwrap();
        assert_eq!(s.served, 12, "chunk={chunk}");
        assert!(s.kv_shared_hits > 0, "chunk={chunk}: no prefix blocks adopted");
        assert!(s.kv_bytes_per_step > 0.0, "chunk={chunk}");
        assert!(s.kv_peak_bytes > 0, "chunk={chunk}");
        let k = key(&s);
        match &baseline {
            None => baseline = Some(k),
            Some(b) => assert_eq!(&k, b, "outputs changed at prefill chunk {chunk}"),
        }
    }
    // Prefill-per-step plans hold no pool: the kv columns stay zero.
    let wl = GenWorkload::new(cfg, corp::data::DATA_SEED).unwrap().with_max_new(4);
    let s = run_engine(&exec, &w, &wl.with_decode(DecodeMode::Prefill), &eopts).unwrap();
    assert_eq!(s.kv_peak_bytes, 0);
    assert_eq!(s.kv_bytes_per_step, 0.0);
}
