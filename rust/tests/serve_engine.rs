//! Serving-engine + batch-polymorphic fast-path integration tests.
//!
//! Everything here runs on the native runtime (no artifacts directory), so
//! the suite exercises the real serving dispatch path offline. The engine's
//! *timing* is load-dependent by design; what these tests pin down is that
//! batching, padding vs exact-size dispatch, the engine worker count, and
//! the pool-width override never change *what* is computed — for both the
//! vision and the text workload, on dense, pruned, and compensated weights.
//!
//! The whole file is compiled out under `--cfg pjrt_backend`, where
//! `run_engine` is a deliberate fail-fast stub (see `serve::engine`).
#![cfg(not(pjrt_backend))]

use std::sync::Arc;

use corp::data::{Split, VisionGen};
use corp::exec::Executor;
use corp::model::{keep_count, ModelConfig, Scope, Sparsity, WeightStore};
use corp::prune::{calibrate, prune, Method, PruneOpts};
use corp::runtime::Runtime;
use corp::serve::{
    run_engine, run_fleet, DispatchPolicy, EngineOpts, FleetMember, GenWorkload, GptWorkload,
    VisionWorkload, Workload,
};
use corp::tensor::Tensor;

fn native_runtime() -> Runtime {
    // A directory without manifest.json → the native interpreter serves
    // every artifact name.
    Runtime::new(std::env::temp_dir().join("corp_serve_engine_no_artifacts")).unwrap()
}

fn vit_t() -> &'static ModelConfig {
    ModelConfig::by_name("vit_t").unwrap()
}

/// Prune at 50% joint sparsity from a tiny calibration pass, with
/// (`Method::Corp`) or without (`Method::Naive`) compensation.
fn pruned_store(exec: &Executor<'_>, dense: &WeightStore, method: Method) -> WeightStore {
    let opts = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 5),
        method,
        calib_batches: 2,
        attn_max_samples: 32,
        ..PruneOpts::default()
    };
    let stats = calibrate(exec, dense, &opts).unwrap();
    prune(exec, dense, &stats, &opts).unwrap().weights
}

fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best as i32
}

#[test]
fn plan_forward_matches_layered_executor_at_any_batch() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 5);
    let pruned = pruned_store(&exec, &dense, Method::Naive);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    for w in [&dense, &pruned] {
        // One plan per variant serves every batch size.
        let plan = exec.forward_plan(w).unwrap();
        for b in [1usize, 3, 4] {
            let (tokens, _) = gen.batch(Split::Eval, 0, b);
            let fused = plan.run_vit(&tokens).unwrap();
            let layered = exec.forward_vit(w, &tokens, b).unwrap();
            assert_eq!(fused.shape(), &[b, cfg.classes]);
            assert!(
                fused.max_abs_diff(&layered) < 1e-5,
                "b={b}: fused vs layered diverged by {}",
                fused.max_abs_diff(&layered)
            );
        }
    }
    // The fast path derives its dims from the stored weight shapes.
    let p = exec.forward_plan(&pruned).unwrap();
    assert_eq!(p.dqk, keep_count(cfg.dh(), 5));
    assert_eq!(p.o, keep_count(cfg.mlp, 5));
    assert_eq!(&*p.artifact(2), format!("fwd_vit_t_q{}_o{}_b2", p.dqk, p.o));
}

#[test]
fn plan_artifact_cache_reuses_handles_per_batch_size() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 5);
    let plan = exec.forward_plan(&w).unwrap();
    assert_eq!(plan.cached_batch_sizes(), 0);
    // Same batch size → the *same* cached handle (pointer-identical), not a
    // re-formatted name.
    let a1 = plan.artifact(4);
    let a2 = plan.artifact(4);
    assert!(Arc::ptr_eq(&a1, &a2));
    assert_eq!(plan.cached_batch_sizes(), 1);
    // Distinct sizes get distinct entries; running through the plan
    // populates the same cache.
    let a3 = plan.artifact(7);
    assert!(!Arc::ptr_eq(&a1, &a3));
    assert_ne!(&*a1, &*a3);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let (tokens, _) = gen.batch(Split::Eval, 0, 2);
    plan.run_vit(&tokens).unwrap();
    assert_eq!(plan.cached_batch_sizes(), 3);
    assert!(Arc::ptr_eq(&plan.artifact(4), &a1));
}

#[test]
fn plan_forward_matches_layered_gpt() {
    let rt = native_runtime();
    let cfg = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 6);
    let gen = corp::data::TextGen::new(corp::data::DATA_SEED);
    let plan = exec.forward_plan(&w).unwrap();
    for b in [1usize, 2] {
        let (ids, _) = gen.batch(Split::Eval, 0, b, cfg.n_ctx);
        let fused = plan.run_gpt(&ids, b).unwrap();
        let layered = exec.forward_gpt(&w, &ids, b).unwrap();
        assert_eq!(fused.shape(), &[b, cfg.n_ctx, cfg.vocab]);
        assert!(fused.max_abs_diff(&layered) < 1e-5);
    }
    // Mismatched id count / batch is rejected.
    let short = vec![0i32; cfg.n_ctx];
    assert!(plan.run_gpt(&short, 2).is_err());
}

#[test]
fn engine_predictions_invariant_across_worker_counts() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 7);
    let workload = VisionWorkload::new(cfg, corp::data::DATA_SEED).unwrap();
    let mk = |workers| EngineOpts {
        workers,
        rate: 1e12, // saturated: batch composition differs per run/worker count
        requests: 24,
        max_batch: 8,
        max_wait: 0.002,
        queue_cap: 1024,
        ..Default::default()
    };
    let s1 = run_engine(&exec, &w, &workload, &mk(1)).unwrap();
    let s2 = run_engine(&exec, &w, &workload, &mk(2)).unwrap();
    // A CORP_THREADS-style pool-width override must not change results
    // either (engine workers serialize their nested pool regions).
    let s3 = corp::util::threads::with_threads(3, || run_engine(&exec, &w, &workload, &mk(2)))
        .unwrap();
    for s in [&s1, &s2, &s3] {
        assert_eq!(s.served, 24);
        assert_eq!(s.shed, 0);
        assert_eq!(s.records.len(), 24);
        // Records are sorted by id and cover every request exactly once.
        assert!(s.records.windows(2).all(|p| p[0].id < p[1].id));
        assert!(s.throughput_fps > 0.0);
        assert!(s.p95_ms >= s.p50_ms);
        // Vision accounting: one token (image) per request.
        assert!(s.records.iter().all(|r| r.tokens == 1));
    }
    let preds1: Vec<i32> = s1.records.iter().map(|r| r.pred).collect();
    let preds2: Vec<i32> = s2.records.iter().map(|r| r.pred).collect();
    let preds3: Vec<i32> = s3.records.iter().map(|r| r.pred).collect();
    assert_eq!(preds1, preds2);
    assert_eq!(preds1, preds3);
    // And each prediction equals the unbatched layered executor's.
    let gen = VisionGen::new(corp::data::DATA_SEED);
    for r in &s1.records {
        let (t, _) = gen.batch(Split::Eval, r.id as u64, 1);
        let logits = exec.forward_vit(&w, &t, 1).unwrap();
        assert_eq!(r.pred, argmax(logits.data()), "request {}", r.id);
    }
}

#[test]
fn dispatch_policies_agree_on_predictions_for_every_variant() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 5);
    let pruned = pruned_store(&exec, &dense, Method::Naive);
    let comp = pruned_store(&exec, &dense, Method::Corp);
    let workload = VisionWorkload::new(cfg, corp::data::DATA_SEED).unwrap();
    let mk = |dispatch| EngineOpts {
        workers: 2,
        rate: 1e12,
        requests: 21, // not a multiple of max_batch → partial batches occur
        max_batch: 8,
        max_wait: 0.002,
        queue_cap: 1024,
        dispatch,
        ..Default::default()
    };
    for (label, w) in [("dense", &dense), ("pruned", &pruned), ("compensated", &comp)] {
        let sp = run_engine(&exec, w, &workload, &mk(DispatchPolicy::Padded)).unwrap();
        let se = run_engine(&exec, w, &workload, &mk(DispatchPolicy::Exact)).unwrap();
        let sa = run_engine(&exec, w, &workload, &mk(DispatchPolicy::Auto)).unwrap();
        for s in [&sp, &se, &sa] {
            assert_eq!(s.served, 21, "{label}");
        }
        let pp: Vec<i32> = sp.records.iter().map(|r| r.pred).collect();
        let pe: Vec<i32> = se.records.iter().map(|r| r.pred).collect();
        let pa: Vec<i32> = sa.records.iter().map(|r| r.pred).collect();
        assert_eq!(pp, pe, "{label}: padded vs exact predictions diverged");
        assert_eq!(pp, pa, "{label}: padded vs auto predictions diverged");
        // Padded always dispatches the artifact batch; exact never exceeds
        // the formed batch.
        assert!((sp.mean_dispatch - 8.0).abs() < 1e-9, "{label}: {}", sp.mean_dispatch);
        assert!(
            se.mean_dispatch <= se.mean_batch + 1e-9,
            "{label}: exact dispatched {} for mean batch {}",
            se.mean_dispatch,
            se.mean_batch
        );
    }
}

#[test]
fn gpt_workload_deterministic_across_workers_and_dispatch() {
    let rt = native_runtime();
    let cfg = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 11);
    // The bench grid serves pruned text variants too — cover the pruned
    // gpt fused path, not just dense init.
    let pruned = pruned_store(&exec, &dense, Method::Naive);
    let workload = GptWorkload::new(cfg, corp::data::DATA_SEED).unwrap();
    let mk = |workers, dispatch| EngineOpts {
        workers,
        rate: 1e12,
        requests: 10,
        max_batch: 4,
        max_wait: 0.002,
        queue_cap: 64,
        dispatch,
        ..Default::default()
    };
    for (label, w) in [("dense", &dense), ("pruned", &pruned)] {
        let s1 = run_engine(&exec, w, &workload, &mk(1, DispatchPolicy::Padded)).unwrap();
        let s2 = run_engine(&exec, w, &workload, &mk(2, DispatchPolicy::Padded)).unwrap();
        let s3 = run_engine(&exec, w, &workload, &mk(2, DispatchPolicy::Exact)).unwrap();
        for s in [&s1, &s2, &s3] {
            assert_eq!(s.served, 10, "{label}");
            // Per-token accounting: prompts are shorter than or equal to
            // n_ctx and the token throughput reflects their sum.
            assert!(s.records.iter().all(|r| r.tokens >= 1 && r.tokens <= cfg.n_ctx));
            assert!(s.throughput_tps >= s.throughput_fps);
        }
        let key = |s: &corp::serve::EngineStats| -> Vec<(i32, usize)> {
            s.records.iter().map(|r| (r.pred, r.tokens)).collect()
        };
        assert_eq!(key(&s1), key(&s2), "{label}: worker count changed gpt outputs");
        assert_eq!(key(&s1), key(&s3), "{label}: dispatch policy changed gpt outputs");
        // Each prediction equals a batch-1 forward of the same prompt at
        // the prompt's final position.
        let plan = exec.forward_plan(w).unwrap();
        for r in &s1.records {
            let req = workload.synth(r.id);
            assert_eq!(r.tokens, req.prompt_len);
            let logits = plan.run_gpt(&req.ids, 1).unwrap();
            let row =
                &logits.data()[(req.prompt_len - 1) * cfg.vocab..req.prompt_len * cfg.vocab];
            assert_eq!(r.pred, argmax(row), "{label}: request {}", r.id);
        }
    }
}

#[test]
fn partial_batch_padding_matches_unbatched() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 8);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let workload = VisionWorkload::new(cfg, corp::data::DATA_SEED).unwrap();
    // Fewer requests than a batch: every executed batch is partial, and the
    // padded policy pads each to the fixed artifact batch.
    let opts = EngineOpts {
        workers: 1,
        rate: 1e12,
        requests: 3,
        max_batch: 8,
        max_wait: 0.0,
        queue_cap: 16,
        dispatch: DispatchPolicy::Padded,
        ..Default::default()
    };
    let s = run_engine(&exec, &w, &workload, &opts).unwrap();
    assert_eq!(s.served, 3);
    assert!(s.mean_batch <= 3.0 + 1e-9);
    assert!((s.mean_dispatch - 8.0).abs() < 1e-9);
    for r in &s.records {
        let (t, _) = gen.batch(Split::Eval, r.id as u64, 1);
        let logits = exec.forward_vit(&w, &t, 1).unwrap();
        assert_eq!(r.pred, argmax(logits.data()), "request {}", r.id);
    }
    // Direct fused check: a zero-padded batch reproduces the unbatched rows.
    let per = cfg.patches * cfg.patch_dim;
    let (t3, _) = gen.batch(Split::Eval, 0, 3);
    let mut padded = t3.data().to_vec();
    padded.resize(8 * per, 0.0);
    let plan = exec.forward_plan(&w).unwrap();
    let logits8 = plan
        .run_vit(&Tensor::from_vec(&[8, cfg.patches, cfg.patch_dim], padded))
        .unwrap();
    let logits3 = exec.forward_vit(&w, &t3, 3).unwrap();
    for i in 0..3 {
        let a = &logits8.data()[i * cfg.classes..(i + 1) * cfg.classes];
        let b = &logits3.data()[i * cfg.classes..(i + 1) * cfg.classes];
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5, "row {i}: {x} vs {y}");
        }
    }
}

#[test]
fn mixed_fleet_matches_single_workload_runs() {
    // Vision + text + generation requests through ONE engine run (one
    // queue, one worker pool, three units over two models) must produce
    // exactly the per-request outputs of three single-workload runs with
    // the same seeds: workers form single-unit batches and per-example
    // math is composition-invariant.
    let rt = native_runtime();
    let vit = vit_t();
    let gpt = ModelConfig::by_name("gpt_s").unwrap();
    let ev = Executor::new(&rt, vit);
    let eg = Executor::new(&rt, gpt);
    let wv = WeightStore::init(vit, 5);
    let wg = WeightStore::init(gpt, 6);
    let vwl = VisionWorkload::new(vit, corp::data::DATA_SEED).unwrap();
    let twl = GptWorkload::new(gpt, corp::data::DATA_SEED).unwrap();
    let gwl = GenWorkload::new(gpt, corp::data::DATA_SEED).unwrap().with_max_new(3);
    let (nv, nt, ng) = (12usize, 6usize, 8usize);
    let opts = EngineOpts {
        workers: 2,
        rate: 1e12,
        requests: 1, // ignored by run_fleet (per-member counts used)
        max_batch: 4,
        max_wait: 0.002,
        queue_cap: 1024,
        ..Default::default()
    };
    let fleet = run_fleet(
        vec![
            FleetMember::new(&ev, &wv, &vwl, nv).erased(),
            FleetMember::new(&eg, &wg, &twl, nt).erased(),
            FleetMember::new(&eg, &wg, &gwl, ng).erased(),
        ],
        &opts,
    )
    .unwrap();
    assert_eq!(fleet.len(), 3);
    let [fv, ft, fg] = [&fleet[0], &fleet[1], &fleet[2]];
    let sv = run_engine(&ev, &wv, &vwl, &EngineOpts { requests: nv, ..opts.clone() }).unwrap();
    let st = run_engine(&eg, &wg, &twl, &EngineOpts { requests: nt, ..opts.clone() }).unwrap();
    let sg = run_engine(&eg, &wg, &gwl, &EngineOpts { requests: ng, ..opts.clone() }).unwrap();
    let key = |s: &corp::serve::EngineStats| -> Vec<(usize, i32, usize, usize)> {
        s.records.iter().map(|r| (r.id, r.pred, r.tokens, r.steps)).collect()
    };
    assert_eq!(fv.served, nv);
    assert_eq!(ft.served, nt);
    assert_eq!(fg.served, ng);
    assert_eq!(fv.shed + ft.shed + fg.shed, 0);
    assert_eq!(key(fv), key(&sv), "fleet vision outputs diverged from the solo run");
    assert_eq!(key(ft), key(&st), "fleet text outputs diverged from the solo run");
    assert_eq!(key(fg), key(&sg), "fleet gen outputs diverged from the solo run");
    // Generation is multi-step; vision and single-shot text are not —
    // visible in the per-unit step accounting of the same fleet run.
    assert!(fv.records.iter().all(|r| r.steps == 1));
    assert!(ft.records.iter().all(|r| r.steps == 1));
    assert!(fg.records.iter().any(|r| r.steps > 1));
    assert!((fv.steps_mean - 1.0).abs() < 1e-9);
    // Without a controller every request is served on the dense rung.
    assert_eq!(fv.served_by_variant, vec![nv]);
    assert!(fv.transitions.is_empty());
    // Degenerate fleets are rejected up front: no members at all, and a
    // member that offers zero requests.
    let err = run_fleet(vec![], &opts).unwrap_err().to_string();
    assert!(err.contains("at least one member"), "{err}");
    let err = run_fleet(
        vec![
            FleetMember::new(&ev, &wv, &vwl, 0).erased(),
            FleetMember::new(&eg, &wg, &gwl, ng).erased(),
        ],
        &opts,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("at least one request"), "{err}");
}

#[test]
fn bounded_queue_sheds_overload() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 9);
    let workload = VisionWorkload::new(cfg, corp::data::DATA_SEED).unwrap();
    // Saturated arrivals into a 2-deep queue with a slow (floored) executor:
    // most of the load must be shed, and accounting must still balance.
    let opts = EngineOpts {
        workers: 1,
        rate: 1e12,
        requests: 64,
        max_batch: 4,
        max_wait: 0.0,
        queue_cap: 2,
        exec_floor: 0.01,
        seed: 3,
        ..Default::default()
    };
    let s = run_engine(&exec, &w, &workload, &opts).unwrap();
    assert_eq!(s.served + s.shed, 64, "every request is served or shed");
    assert!(s.shed > 0, "expected shedding under overload");
    assert!(s.served >= 1);
    // The floor is visible in the per-batch execution accounting.
    assert!(s.exec_mean_ms >= 10.0 - 1.0);
}

#[test]
fn degenerate_engine_configs_error_and_mismatched_workload_rejected() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 10);
    let workload = VisionWorkload::new(cfg, corp::data::DATA_SEED).unwrap();
    for (opts, needle) in [
        (EngineOpts { queue_cap: 0, ..Default::default() }, "queue_cap"),
        (EngineOpts { max_batch: 0, ..Default::default() }, "max_batch"),
        (EngineOpts { workers: 0, ..Default::default() }, "workers"),
        (EngineOpts { requests: 0, ..Default::default() }, "requests"),
        // Regression: a negative or non-finite floor used to trip a debug
        // assert instead of surfacing a named-flag error.
        (EngineOpts { exec_floor: -1.0, ..Default::default() }, "--exec-floor"),
        (EngineOpts { exec_floor: f64::NAN, ..Default::default() }, "--exec-floor"),
        (EngineOpts { spike: 0.0, ..Default::default() }, "--spike"),
    ] {
        let err = run_engine(&exec, &w, &workload, &opts).unwrap_err().to_string();
        assert!(err.contains(needle), "{err}");
    }
    // Driving a vit executor with a gpt-bound workload is a config error,
    // not a shape panic deep in the runtime.
    let gpt = ModelConfig::by_name("gpt_s").unwrap();
    let gw = GptWorkload::new(gpt, corp::data::DATA_SEED).unwrap();
    let err = run_engine(&exec, &w, &gw, &EngineOpts::default()).unwrap_err().to_string();
    assert!(err.contains("gpt_s") && err.contains("vit_t"), "{err}");
}
