//! Serving-engine + fused-fast-path integration tests.
//!
//! Everything here runs on the native runtime (no artifacts directory), so
//! the suite exercises the real serving dispatch path offline. The engine's
//! *timing* is load-dependent by design; what these tests pin down is that
//! batching, padding, the engine worker count, and the pool-width override
//! never change *what* is computed.
//!
//! The whole file is compiled out under `--cfg pjrt_backend`, where
//! `run_engine` is a deliberate fail-fast stub (see `serve::engine`).
#![cfg(not(pjrt_backend))]

use corp::data::{Split, VisionGen};
use corp::exec::Executor;
use corp::model::{keep_count, ModelConfig, Scope, Sparsity, WeightStore};
use corp::prune::{calibrate, prune, Method, PruneOpts};
use corp::runtime::Runtime;
use corp::serve::{run_engine, EngineOpts};
use corp::tensor::Tensor;

fn native_runtime() -> Runtime {
    // A directory without manifest.json → the native interpreter serves
    // every artifact name.
    Runtime::new(std::env::temp_dir().join("corp_serve_engine_no_artifacts")).unwrap()
}

fn vit_t() -> &'static ModelConfig {
    ModelConfig::by_name("vit_t").unwrap()
}

/// Prune (no compensation — shapes are what matter here) at 50% joint
/// sparsity from a tiny calibration pass.
fn pruned_store(exec: &Executor<'_>, dense: &WeightStore) -> WeightStore {
    let opts = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 5),
        method: Method::Naive,
        calib_batches: 2,
        attn_max_samples: 32,
        ..PruneOpts::default()
    };
    let stats = calibrate(exec, dense, &opts).unwrap();
    prune(exec, dense, &stats, &opts).unwrap().weights
}

fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best as i32
}

#[test]
fn fused_forward_matches_layered_executor() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 5);
    let pruned = pruned_store(&exec, &dense);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let b = 4;
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    for w in [&dense, &pruned] {
        let prepared = exec.prepare_forward(w, b).unwrap();
        let fused = prepared.run_vit(&tokens).unwrap();
        let layered = exec.forward_vit(w, &tokens, b).unwrap();
        assert_eq!(fused.shape(), &[b, cfg.classes]);
        assert!(
            fused.max_abs_diff(&layered) < 1e-5,
            "fused vs layered diverged: {}",
            fused.max_abs_diff(&layered)
        );
    }
    // The fast path derives its dims from the stored weight shapes.
    let p = exec.prepare_forward(&pruned, 2).unwrap();
    assert_eq!(p.dqk, keep_count(cfg.dh(), 5));
    assert_eq!(p.o, keep_count(cfg.mlp, 5));
    assert_eq!(p.artifact(), format!("fwd_vit_t_q{}_o{}_b2", p.dqk, p.o));
}

#[test]
fn fused_forward_matches_layered_gpt() {
    let rt = native_runtime();
    let cfg = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 6);
    let gen = corp::data::TextGen::new(corp::data::DATA_SEED);
    let b = 2;
    let (ids, _) = gen.batch(Split::Eval, 0, b, cfg.n_ctx);
    let prepared = exec.prepare_forward(&w, b).unwrap();
    let fused = prepared.run_gpt(&ids).unwrap();
    let layered = exec.forward_gpt(&w, &ids, b).unwrap();
    assert_eq!(fused.shape(), &[b, cfg.n_ctx, cfg.vocab]);
    assert!(fused.max_abs_diff(&layered) < 1e-5);
}

#[test]
fn engine_predictions_invariant_across_worker_counts() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 7);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let mk = |workers| EngineOpts {
        workers,
        rate: 1e12, // saturated: batch composition differs per run/worker count
        requests: 24,
        max_batch: 8,
        max_wait: 0.002,
        queue_cap: 1024,
        ..Default::default()
    };
    let s1 = run_engine(&exec, &w, &gen, &mk(1)).unwrap();
    let s2 = run_engine(&exec, &w, &gen, &mk(2)).unwrap();
    // A CORP_THREADS-style pool-width override must not change results
    // either (engine workers serialize their nested pool regions).
    let s3 = corp::util::threads::with_threads(3, || run_engine(&exec, &w, &gen, &mk(2)))
        .unwrap();
    for s in [&s1, &s2, &s3] {
        assert_eq!(s.served, 24);
        assert_eq!(s.shed, 0);
        assert_eq!(s.records.len(), 24);
        // Records are sorted by id and cover every request exactly once.
        assert!(s.records.windows(2).all(|p| p[0].id < p[1].id));
        assert!(s.throughput_fps > 0.0);
        assert!(s.p95_ms >= s.p50_ms);
    }
    let preds1: Vec<i32> = s1.records.iter().map(|r| r.pred).collect();
    let preds2: Vec<i32> = s2.records.iter().map(|r| r.pred).collect();
    let preds3: Vec<i32> = s3.records.iter().map(|r| r.pred).collect();
    assert_eq!(preds1, preds2);
    assert_eq!(preds1, preds3);
    // And each prediction equals the unbatched layered executor's.
    for r in &s1.records {
        let (t, _) = gen.batch(Split::Eval, r.id as u64, 1);
        let logits = exec.forward_vit(&w, &t, 1).unwrap();
        assert_eq!(r.pred, argmax(logits.data()), "request {}", r.id);
    }
}

#[test]
fn partial_batch_padding_matches_unbatched() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 8);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    // Fewer requests than a batch: every executed batch is partial + padded.
    let opts = EngineOpts {
        workers: 1,
        rate: 1e12,
        requests: 3,
        max_batch: 8,
        max_wait: 0.0,
        queue_cap: 16,
        ..Default::default()
    };
    let s = run_engine(&exec, &w, &gen, &opts).unwrap();
    assert_eq!(s.served, 3);
    assert!(s.mean_batch <= 3.0 + 1e-9);
    for r in &s.records {
        let (t, _) = gen.batch(Split::Eval, r.id as u64, 1);
        let logits = exec.forward_vit(&w, &t, 1).unwrap();
        assert_eq!(r.pred, argmax(logits.data()), "request {}", r.id);
    }
    // Direct fused check: a zero-padded batch reproduces the unbatched rows.
    let per = cfg.patches * cfg.patch_dim;
    let (t3, _) = gen.batch(Split::Eval, 0, 3);
    let mut padded = t3.data().to_vec();
    padded.resize(8 * per, 0.0);
    let prepared = exec.prepare_forward(&w, 8).unwrap();
    let logits8 = prepared.run_vit(&Tensor::from_vec(
        &[8, cfg.patches, cfg.patch_dim],
        padded,
    ))
    .unwrap();
    let logits3 = exec.forward_vit(&w, &t3, 3).unwrap();
    for i in 0..3 {
        let a = &logits8.data()[i * cfg.classes..(i + 1) * cfg.classes];
        let b = &logits3.data()[i * cfg.classes..(i + 1) * cfg.classes];
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5, "row {i}: {x} vs {y}");
        }
    }
}

#[test]
fn bounded_queue_sheds_overload() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 9);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    // Saturated arrivals into a 2-deep queue with a slow (floored) executor:
    // most of the load must be shed, and accounting must still balance.
    let opts = EngineOpts {
        workers: 1,
        rate: 1e12,
        requests: 64,
        max_batch: 4,
        max_wait: 0.0,
        queue_cap: 2,
        exec_floor: 0.01,
        seed: 3,
        ..Default::default()
    };
    let s = run_engine(&exec, &w, &gen, &opts).unwrap();
    assert_eq!(s.served + s.shed, 64, "every request is served or shed");
    assert!(s.shed > 0, "expected shedding under overload");
    assert!(s.served >= 1);
    // The floor is visible in the per-batch execution accounting.
    assert!(s.exec_mean_ms >= 10.0 - 1.0);
}
