//! Integration: the native (pure-Rust) runtime serves the full artifact
//! interface without any `artifacts/` directory — embed/block/head shapes,
//! capture consistency, pruned-shape execution, and loss sanity at init.
//!
//! These mirror `runtime_roundtrip.rs` (which needs PJRT artifacts and skips
//! without them) but always run, so the stitched-forward path is covered by
//! tier-1 on a fresh checkout.

use corp::data::{Split, TextGen, VisionGen};
use corp::exec::Executor;
use corp::model::{keep_count, ModelConfig, WeightStore};
use corp::runtime::Runtime;

fn native_runtime() -> Runtime {
    // A directory with no manifest.json forces the native backend.
    let dir = std::env::temp_dir().join("corp_native_rt_tests");
    Runtime::new(dir).expect("native runtime")
}

#[test]
fn embed_block_head_shapes() {
    let rt = native_runtime();
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 1);
    let b = cfg.eval_batch();
    let gen = VisionGen::new(0);
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    let x = exec.embed(&w, &tokens, b).unwrap();
    assert_eq!(x.shape(), &[b, cfg.n_ctx, cfg.d]);
    let y = exec.block(&w, 0, &x, b).unwrap();
    assert_eq!(y.shape(), &[b, cfg.n_ctx, cfg.d]);
    let logits = exec.head(&w, &y, b).unwrap();
    assert_eq!(logits.shape(), &[b, cfg.classes]);
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

#[test]
fn capture_matches_plain_block() {
    let rt = native_runtime();
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 2);
    let b = cfg.eval_batch();
    let gen = VisionGen::new(1);
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    let x = exec.embed(&w, &tokens, b).unwrap();
    let plain = exec.block(&w, 0, &x, b).unwrap();
    let (cap_y, cap) = exec.block_capture(&w, 0, &x).unwrap();
    assert!(plain.max_abs_diff(&cap_y) < 1e-5, "capture must not perturb output");
    assert_eq!(cap.hidden.shape(), &[b, cfg.n_ctx, cfg.mlp]);
    assert_eq!(cap.q.shape(), &[b, cfg.heads, cfg.n_ctx, cfg.dh()]);
    assert_eq!(cap.k.shape(), &[b, cfg.heads, cfg.n_ctx, cfg.dh()]);
}

#[test]
fn pruned_block_shapes_execute() {
    let rt = native_runtime();
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    // Manually shrink weights to the 50%-joint shape and run end-to-end.
    let mut w = WeightStore::init(cfg, 3);
    let dqk = keep_count(cfg.dh(), 5);
    let o = keep_count(cfg.mlp, 5);
    for l in 0..cfg.layers {
        for (name, shape) in cfg.block_param_spec(dqk, o) {
            let n: usize = shape.iter().product();
            let t = corp::tensor::Tensor::from_vec(&shape, vec![0.01; n]);
            w.insert(format!("blocks.{l}.{name}"), t);
        }
        // restore norm gains to 1
        w.insert(
            format!("blocks.{l}.ln1.g"),
            corp::tensor::Tensor::from_vec(&[cfg.d], vec![1.0; cfg.d]),
        );
        w.insert(
            format!("blocks.{l}.ln2.g"),
            corp::tensor::Tensor::from_vec(&[cfg.d], vec![1.0; cfg.d]),
        );
    }
    let b = cfg.eval_batch();
    let gen = VisionGen::new(2);
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    let logits = exec.forward_vit(&w, &tokens, b).unwrap();
    assert_eq!(logits.shape(), &[b, cfg.classes]);
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

#[test]
fn untrained_losses_sit_at_entropy() {
    // At deterministic init the head weights are ~0, so the loss must sit
    // near ln(num classes) — a strong end-to-end check of embed/block/head
    // plus the cross-entropy path (masking or bias bugs skew it).
    let rt = native_runtime();

    let gpt = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, gpt);
    let w = WeightStore::init(gpt, 4);
    let b = gpt.eval_batch();
    let gen = TextGen::new(3);
    let (ids, targets) = gen.batch(Split::Eval, 0, b, gpt.n_ctx);
    let logits = exec.forward_gpt(&w, &ids, b).unwrap();
    assert_eq!(logits.shape(), &[b, gpt.n_ctx, gpt.vocab]);
    let loss = exec.eval_loss(&w, None, Some(&ids), &targets).unwrap();
    assert!((loss - (gpt.vocab as f32).ln()).abs() < 0.5, "gpt loss={loss}");

    let vit = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, vit);
    let w = WeightStore::init(vit, 5);
    let vgen = VisionGen::new(corp::data::DATA_SEED);
    let bv = vit.eval_batch();
    let (tokens, labels) = vgen.batch(Split::Eval, 0, bv);
    let loss = exec.eval_loss(&w, Some(&tokens), None, &labels).unwrap();
    assert!((loss - (vit.classes as f32).ln()).abs() < 0.5, "vit loss={loss}");
}

#[test]
fn stitched_forward_matches_evloss_graph() {
    // The per-block stitched path and the monolithic loss computation must
    // agree on the same batch.
    let rt = native_runtime();
    let cfg = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 6);
    let gen = TextGen::new(9);
    let direct = corp::eval::ppl_dense(&exec, &w, &gen, 2).unwrap();
    let stitched = corp::eval::ppl_stitched(&exec, &w, &gen, 2).unwrap();
    let rel = (direct - stitched).abs() / direct;
    assert!(rel < 1e-3, "ppl mismatch: {direct} vs {stitched}");
}

#[test]
fn native_pipeline_calibrates_and_prunes() {
    use corp::model::{Scope, Sparsity};
    use corp::prune::{calibrate, prune, Method, PruneOpts};
    let rt = native_runtime();
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 10);
    let opts = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 5),
        method: Method::Corp,
        calib_batches: 2,
        attn_max_samples: 32,
        ..PruneOpts::default()
    };
    let stats = calibrate(&exec, &dense, &opts).unwrap();
    assert_eq!(stats.layers.len(), cfg.layers);
    let result = prune(&exec, &dense, &stats, &opts).unwrap();
    let dqk = keep_count(cfg.dh(), 5);
    let o = keep_count(cfg.mlp, 5);
    let w = &result.weights;
    assert_eq!(w.get("blocks.0.attn.wq").unwrap().shape(), &[cfg.d, cfg.heads * dqk]);
    assert_eq!(w.get("blocks.0.mlp.w1").unwrap().shape(), &[cfg.d, o]);
    assert_eq!(w.get("blocks.0.mlp.w2").unwrap().shape(), &[o, cfg.d]);
    // The pruned model runs end-to-end on the native backend.
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let b = cfg.eval_batch();
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    let logits = exec.forward_vit(w, &tokens, b).unwrap();
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

#[test]
fn prune_results_thread_count_invariant() {
    use corp::model::{Scope, Sparsity};
    use corp::prune::{calibrate, prune, Method, PruneOpts};
    use corp::util::threads::with_threads;
    let rt = native_runtime();
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 11);
    let opts = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 5),
        method: Method::Corp,
        calib_batches: 1,
        attn_max_samples: 16,
        ..PruneOpts::default()
    };
    let run = |workers: usize| {
        with_threads(workers, || {
            let stats = calibrate(&exec, &dense, &opts).unwrap();
            prune(&exec, &dense, &stats, &opts).unwrap().weights
        })
    };
    let w1 = run(1);
    let w4 = run(4);
    for (name, t1) in w1.iter() {
        let t4 = w4.get(name).unwrap();
        assert_eq!(t1.shape(), t4.shape(), "{name}");
        assert!(t1.max_abs_diff(t4) < 1e-4, "{name} differs across worker counts");
    }
}
