//! Equality harness for the int8 weight-quantized serving path.
//!
//! The `pruned+compensated+int8` rung only earns its place on the degrade
//! ladder if it provably computes (almost) the same function as the f32
//! store it quantizes. This suite pins, on gpt_s: the KV-cached int8
//! decode against the fused int8 full-prefill forward token-for-token
//! (both run the same per-row dynamically-quantized GEMMs, so they agree
//! to f32 round-off like the f32 harness in `decode_equality.rs`); the
//! int8 fused logits against the f32 compensated logits within a stated
//! relative tolerance; and `run_engine_q8` invariance across worker
//! counts and dispatch policies. On vit_t it asserts the closed-form
//! dequant correction's no-harm guarantee — the fitted residual MSE never
//! exceeds the identity (uncorrected) MSE — and that corrected-int8 top-1
//! does not trail plain-int8 top-1 beyond eval-window noise.
//!
//! Everything runs on the native runtime (no artifacts directory); the
//! engine pieces are compiled out under `--cfg pjrt_backend` like
//! `serve_engine.rs`.
#![cfg(not(pjrt_backend))]

use corp::compensate::{mlp_kept_indices, quantize_weights, quantize_weights_corrected, QuantReport};
use corp::data::{Split, TextGen, VisionGen};
use corp::exec::{argmax, DecodeMode, Executor, ForwardPlan, KvPoolOpts};
use corp::model::{ModelConfig, QuantStore, Scope, Sparsity, WeightStore};
use corp::prune::{calibrate, prune, Method, PruneOpts};
use corp::runtime::Runtime;
use corp::serve::{run_engine_q8, run_fleet, DispatchPolicy, EngineOpts, FleetMember, GenWorkload};

fn native_runtime() -> Runtime {
    Runtime::new(std::env::temp_dir().join("corp_quant_equality_no_artifacts")).unwrap()
}

fn gpt_s() -> &'static ModelConfig {
    ModelConfig::by_name("gpt_s").unwrap()
}

fn vit_t() -> &'static ModelConfig {
    ModelConfig::by_name("vit_t").unwrap()
}

fn popts() -> PruneOpts {
    PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 5),
        method: Method::Corp,
        calib_batches: 2,
        attn_max_samples: 32,
        ..PruneOpts::default()
    }
}

/// Prune with compensation at 50% joint sparsity, then quantize with the
/// compensation-folded dequant correction — the full `pruned+compensated+
/// int8` rung as the CLI's `--quantize` builds it.
fn corrected_q8(
    exec: &Executor<'_>,
    cfg: &ModelConfig,
    dense: &WeightStore,
) -> (WeightStore, QuantStore, QuantReport) {
    let opts = popts();
    let stats = calibrate(exec, dense, &opts).unwrap();
    let comp = prune(exec, dense, &stats, &opts).unwrap().weights;
    let kept = mlp_kept_indices(cfg, dense, &stats, &opts).unwrap();
    let (qs, report) = quantize_weights_corrected(cfg, &comp, &stats, &kept, opts.lambda).unwrap();
    (comp, qs, report)
}

/// Reference greedy decode through a fused full-prefill forward plan:
/// every step re-runs the whole (zero-padded) sequence and reads the
/// logits at the current last position.
fn greedy_full(
    plan: &ForwardPlan<'_, '_>,
    cfg: &ModelConfig,
    prompt: &[i32],
    steps: usize,
) -> (Vec<i32>, Vec<Vec<f32>>) {
    let mut seq = prompt.to_vec();
    let mut preds = Vec::with_capacity(steps);
    let mut rows = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut padded = seq.clone();
        padded.resize(cfg.n_ctx, 0);
        let logits = plan.run_gpt(&padded, 1).unwrap();
        let row = logits.data()[(seq.len() - 1) * cfg.vocab..seq.len() * cfg.vocab].to_vec();
        let p = argmax(&row);
        preds.push(p);
        rows.push(row);
        if seq.len() < cfg.n_ctx {
            seq.push(p);
        }
    }
    (preds, rows)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn spread(row: &[f32]) -> f32 {
    let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
    hi - lo
}

/// Top-1 accuracy of an int8 store over eval batches `start..start+n`,
/// through the quantized fused forward (mirrors `eval::top1_from`).
fn top1_q8(
    exec: &Executor<'_>,
    qs: &QuantStore,
    gen: &VisionGen,
    n_batches: usize,
    start: u64,
) -> f64 {
    let plan = exec.forward_plan_q8(qs).unwrap();
    let b = exec.cfg.eval_batch();
    let c = exec.cfg.classes;
    let (mut correct, mut total) = (0usize, 0usize);
    for i in 0..n_batches {
        let (tokens, labels) = gen.batch(Split::Eval, start + i as u64, b);
        let logits = plan.run_vit(&tokens).unwrap();
        for (j, &label) in labels.iter().enumerate() {
            if argmax(&logits.data()[j * c..(j + 1) * c]) == label {
                correct += 1;
            }
            total += 1;
        }
    }
    100.0 * correct as f64 / total as f64
}

/// The int8 KV-cached decode and the int8 fused full-prefill forward run
/// the same per-row quantized GEMMs, so — exactly like the f32 harness —
/// their greedy token streams must match and their logits agree to f32
/// round-off, across prompt lengths.
#[test]
fn int8_kv_decode_matches_int8_fused_prefill_token_for_token() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let (_comp, qs, _report) = corrected_q8(&exec, cfg, &dense);

    let fwd = exec.forward_plan_q8(&qs).unwrap();
    let dec = exec.decode_plan_opts_q8(&qs, DecodeMode::KvCache, KvPoolOpts::default()).unwrap();
    assert!(fwd.is_quantized() && dec.is_quantized());
    assert!(fwd.artifact(1).ends_with("_w8"), "fused int8 artifact: {}", fwd.artifact(1));
    assert!(dec.artifact(1).ends_with("_w8"), "decode int8 artifact: {}", dec.artifact(1));

    let gen = TextGen::new(corp::data::DATA_SEED);
    let n = cfg.n_ctx;
    for plen in [1usize, n / 2, n - 1] {
        let (ids, _) = gen.batch(Split::Eval, plen as u64, 1, n);
        let prompt = &ids[..plen];
        let steps = (n - plen + 1).min(4);
        let (pk, rk) = dec.greedy(prompt, steps).unwrap();
        let (pf, rf) = greedy_full(&fwd, cfg, prompt, steps);
        assert_eq!(pk, pf, "int8 plen={plen}: greedy token streams diverged");
        for (i, (a, b)) in rk.iter().zip(&rf).enumerate() {
            let d = max_abs_diff(a, b);
            assert!(d < 1e-5, "int8 plen={plen} step {i}: kv vs prefill logits |Δ|={d}");
        }
    }
}

/// Stated tolerance for the quantization itself: int8 fused logits must
/// track the f32 compensated logits within 20% of the f32 logit spread at
/// every position probed (in practice the error is a few percent; the
/// bound is loose enough to be seed-stable, tight enough to catch a
/// mis-scaled channel). The paths must also *differ* — a bitwise-equal
/// result would mean the quantized GEMM never ran.
#[test]
fn int8_fused_logits_track_f32_within_stated_tolerance() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let (comp, qs, _report) = corrected_q8(&exec, cfg, &dense);

    let fwd_f32 = exec.forward_plan(&comp).unwrap();
    let fwd_q8 = exec.forward_plan_q8(&qs).unwrap();
    let gen = TextGen::new(corp::data::DATA_SEED);
    let (n, v) = (cfg.n_ctx, cfg.vocab);
    let mut saw_diff = false;
    for plen in [1usize, n / 2, n - 1] {
        let (ids, _) = gen.batch(Split::Eval, plen as u64, 1, n);
        let mut padded = ids[..plen].to_vec();
        padded.resize(n, 0);
        let lf = fwd_f32.run_gpt(&padded, 1).unwrap();
        let lq = fwd_q8.run_gpt(&padded, 1).unwrap();
        let row_f = &lf.data()[(plen - 1) * v..plen * v];
        let row_q = &lq.data()[(plen - 1) * v..plen * v];
        let d = max_abs_diff(row_f, row_q);
        let tol = 0.2 * spread(row_f) + 1e-6;
        assert!(d <= tol, "plen={plen}: int8 vs f32 logits |Δ|={d} exceeds tolerance {tol}");
        saw_diff |= d > 0.0;
    }
    assert!(saw_diff, "int8 logits bitwise-equal to f32 — quantized path did not run");
}

/// The int8 rung behaves like any other under the engine: the full
/// per-request record stream (id, prediction, tokens, steps) is invariant
/// across worker counts and dispatch policies.
#[test]
fn int8_engine_invariant_across_workers_and_dispatch() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let (_comp, qs, _report) = corrected_q8(&exec, cfg, &dense);
    let workload = GenWorkload::new(cfg, corp::data::DATA_SEED).unwrap().with_max_new(4);

    let mk = |workers: usize, dispatch: DispatchPolicy| EngineOpts {
        workers,
        rate: 1e12,
        requests: 12,
        max_batch: 4,
        max_wait: 0.002,
        queue_cap: 256,
        dispatch,
        ..Default::default()
    };
    let key = |s: &corp::serve::EngineStats| {
        s.records.iter().map(|r| (r.id, r.pred, r.tokens, r.steps)).collect::<Vec<_>>()
    };

    let base = run_engine_q8(&exec, &qs, &workload, &mk(1, DispatchPolicy::Padded)).unwrap();
    assert_eq!(base.served, 12);
    let base_key = key(&base);
    for workers in [1usize, 2, 4] {
        for dispatch in [DispatchPolicy::Padded, DispatchPolicy::Exact, DispatchPolicy::Auto] {
            let s = run_engine_q8(&exec, &qs, &workload, &mk(workers, dispatch)).unwrap();
            assert_eq!(s.served, 12, "workers={workers} dispatch={dispatch:?}");
            assert_eq!(
                key(&s),
                base_key,
                "int8 engine records diverged at workers={workers} dispatch={dispatch:?}"
            );
        }
    }
}

/// A fleet member carrying the full degrade ladder — dense, then
/// pruned+compensated, then int8 — builds plans for every rung (the int8
/// rung goes through `forward_plan_q8`/`decode_plan_opts_q8` inside the
/// engine) and serves every request.
#[test]
fn fleet_with_int8_rung_serves_all_requests() {
    let rt = native_runtime();
    let cfg = gpt_s();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let (comp, qs, _report) = corrected_q8(&exec, cfg, &dense);
    let workload = GenWorkload::new(cfg, corp::data::DATA_SEED).unwrap().with_max_new(4);

    let member = FleetMember::new(&exec, &dense, &workload, 8)
        .with_fallback(&comp)
        .with_quant_fallback(&qs);
    let opts = EngineOpts {
        workers: 2,
        rate: 1e12,
        requests: 8,
        max_batch: 4,
        max_wait: 0.002,
        queue_cap: 256,
        ..Default::default()
    };
    let stats = run_fleet(vec![member.erased()], &opts).unwrap();
    assert_eq!(stats.len(), 1);
    assert_eq!(stats[0].served + stats[0].shed, 8);
    assert!(stats[0].served > 0, "fleet with int8 rung served nothing");
}

/// The closed-form dequant correction's no-harm guarantee, plus the
/// satellite top-1 gap: on the synthetic eval window, corrected int8 must
/// not trail plain (uncorrected) int8 beyond eval noise, and must stay
/// close to the f32 compensated store it quantizes.
#[test]
fn dequant_correction_no_harm_and_top1_gap() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 6);
    let (comp, qs_corr, report) = corrected_q8(&exec, cfg, &dense);

    // Closed-form no-harm: the per-column guard keeps the fitted residual
    // MSE from ever exceeding the identity (g=1, c=0) residual.
    assert!(report.layers_corrected > 0, "dequant correction touched no layers");
    assert!(
        report.mse_fitted <= report.mse_identity * 1.001 + 1e-9,
        "dequant correction raised residual mse: {} -> {}",
        report.mse_identity,
        report.mse_fitted,
    );

    let qs_plain = quantize_weights(cfg, &comp).unwrap();
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let start = corp::eval::eval_window(0);
    let t_corr = top1_q8(&exec, &qs_corr, &gen, 4, start);
    let t_plain = top1_q8(&exec, &qs_plain, &gen, 4, start);
    let t_f32 = corp::eval::top1_from(&exec, &comp, &gen, 4, start).unwrap();

    // Same eval window for every variant; generous slack — the assertion
    // guards against the correction actively hurting, not for a win on an
    // untrained model where all variants sit near each other.
    assert!(
        t_corr + 15.0 >= t_plain,
        "corrected int8 top-1 {t_corr:.1} trails plain int8 {t_plain:.1} beyond eval noise"
    );
    assert!(
        (t_corr - t_f32).abs() <= 20.0,
        "int8 top-1 {t_corr:.1} far from f32 compensated top-1 {t_f32:.1}"
    );
}
