//! SLO-controller integration tests on the deterministic simulator.
//!
//! The threaded engine cannot promise bit-reproducible controller
//! trajectories (condvar wakeups are OS-scheduled), so everything here
//! drives `serve::run_fleet_sim`: the same queueing semantics replayed as
//! a discrete-event loop on the virtual clock, with per-batch service
//! times drawn from a seeded `SimCost` model. That makes the load-spike
//! scenario a pure function of its inputs — the tests assert the exact
//! degrade → recover transition sequence, byte-identical repeat runs, and
//! strictly less shedding than the controller-off baseline, at every
//! worker count in {1, 2, 4}.
//!
//! Compiled out under `--cfg pjrt_backend` (no threaded engine, no sim).
#![cfg(not(pjrt_backend))]

use anyhow::{bail, Result};

use corp::exec::Executor;
use corp::model::{ModelConfig, WeightStore};
use corp::runtime::Runtime;
use corp::serve::{
    run_fleet_sim, Action, Controller, ControllerOpts, CostEstimator, EngineOpts, EngineStats,
    FleetMember, MemberCfg, Obs, Plans, RequestOutput, SimCost, StepOutcome, Workload,
};
use corp::util::Pcg64;

fn native_runtime() -> Runtime {
    Runtime::new(std::env::temp_dir().join("corp_serve_controller_no_artifacts")).unwrap()
}

fn vit_t() -> &'static ModelConfig {
    ModelConfig::by_name("vit_t").unwrap()
}

/// A trivial single-shot workload whose outputs are a pure function of the
/// request id: the spike tests exercise *queueing and control* dynamics,
/// so model execution is reduced to a deterministic echo — time comes from
/// the `SimCost` model either way.
struct EchoWorkload {
    cfg: &'static ModelConfig,
}

impl Workload for EchoWorkload {
    type Req = usize;

    fn cfg(&self) -> &'static ModelConfig {
        self.cfg
    }

    fn label(&self) -> &'static str {
        "echo"
    }

    fn synth(&self, id: usize) -> usize {
        id
    }

    fn run_step(
        &self,
        _plans: &Plans<'_, '_>,
        reqs: &[&usize],
        dispatch: usize,
    ) -> Result<Vec<StepOutcome>> {
        if reqs.is_empty() || dispatch < reqs.len() {
            bail!("echo run_step: {} requests into dispatch {dispatch}", reqs.len());
        }
        Ok(reqs
            .iter()
            .map(|&&id| {
                StepOutcome::Done(RequestOutput { pred: ((id as i32) * 31) % 97, tokens: 1 })
            })
            .collect())
    }
}

// ---------------------------------------------------------------------------
// Cost-curve estimator properties
// ---------------------------------------------------------------------------

#[test]
fn estimator_monotone_and_converges_to_oracle_across_seeds() {
    // True cost strongly increasing in dispatch size, observed under ±5%
    // multiplicative noise: the learned curve must stay monotone (it is a
    // running max by construction) and the exact-vs-padded decision must
    // converge to the oracle's ("exact is always cheaper here").
    let truth = |b: usize| 1e-3 * (1.0 + b as f64);
    for seed in [1u64, 7, 23, 99, 1234] {
        let mut rng = Pcg64::new(seed);
        let mut est = CostEstimator::new(12);
        for _ in 0..600 {
            let b = 1 + rng.below(12);
            let noise = 1.0 + 0.05 * (2.0 * rng.uniform() - 1.0);
            est.observe(b, truth(b) * noise);
        }
        let costs: Vec<f64> = (1..=12).map(|b| est.cost(b).expect("observed")).collect();
        for w in costs.windows(2) {
            assert!(w[1] >= w[0], "seed {seed}: learned curve not monotone: {costs:?}");
        }
        for take in 1..12 {
            assert_eq!(
                est.dispatch_size(take, 12),
                take,
                "seed {seed}: exact dispatch is cheaper at every partial size"
            );
        }
        assert_eq!(est.dispatch_size(12, 12), 12);
        // With exact always winning, the learned fill threshold says "never
        // pad a partial batch".
        assert!(est.fill_threshold(12) > 0.9, "seed {seed}: {}", est.fill_threshold(12));
    }
}

#[test]
fn estimator_ignores_garbage_observations() {
    let mut est = CostEstimator::new(8);
    est.observe(0, 1.0);
    est.observe(3, f64::NAN);
    est.observe(3, -1.0);
    assert!(est.cost(8).is_none(), "garbage must not create cost data");
    // Out-of-range dispatches clamp into the top bucket instead of
    // panicking.
    est.observe(64, 0.5);
    assert!(est.cost(8).is_some());
}

#[test]
fn controller_never_flaps_within_dwell_under_adversarial_load() {
    // Random (seeded) observation streams alternating pressure and calm:
    // however hostile the load, two variant switches of one member must be
    // at least `min_dwell_ticks` controller ticks apart.
    let dwell = 5u64;
    for seed in [3u64, 17, 41, 77] {
        let mut rng = Pcg64::new(seed);
        let opts = ControllerOpts {
            degrade: true,
            degrade_after: 1,
            recover_after: 1,
            min_dwell_ticks: dwell as u32,
            ..Default::default()
        };
        let mut c =
            Controller::new(opts, 0.01, 8, &[MemberCfg { slo_p99_ms: 100.0, variants: 3 }]);
        let est = CostEstimator::new(8);
        let mut last_switch: Option<u64> = None;
        for tick in 0..400u64 {
            let queue_frac = rng.uniform();
            let p99 = [Some(20.0 + 300.0 * rng.uniform())];
            let acts = c.tick(
                &Obs {
                    t: tick as f64 * 0.01,
                    queue_frac,
                    arrival_rate: 100.0 + 900.0 * rng.uniform(),
                    fault_rate: 0.0,
                    p99_ms: &p99,
                },
                &est,
            );
            if acts.iter().any(|a| matches!(a, Action::Variant { .. })) {
                if let Some(prev) = last_switch {
                    assert!(
                        tick - prev >= dwell,
                        "seed {seed}: switches at ticks {prev} and {tick} violate dwell {dwell}"
                    );
                }
                last_switch = Some(tick);
            }
        }
        assert!(last_switch.is_some(), "seed {seed}: adversarial load never switched at all");
    }
}

// ---------------------------------------------------------------------------
// Load-spike regression on the virtual clock
// ---------------------------------------------------------------------------

/// Dense per-batch cost model: 8 ms + 0.5 ms/row; the degraded rung runs
/// at 0.4× (CORP's pruned+compensated GEMMs are cheaper).
const BASE_S: f64 = 0.008;
const PER_ROW_S: f64 = 0.0005;
const MAX_BATCH: usize = 8;
const SLO_P99_MS: f64 = 250.0;

fn dense_capacity(workers: usize) -> f64 {
    workers as f64 * MAX_BATCH as f64 / (BASE_S + PER_ROW_S * MAX_BATCH as f64)
}

/// Run the two-member echo fleet through the simulator: offered load at
/// half the dense fleet capacity, 3× spike over the middle third (so the
/// spike offers 1.5× dense capacity — overload — but only 0.6× of the
/// degraded rung's capacity).
fn spike_run(workers: usize, with_controller: bool) -> Vec<EngineStats> {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 5);
    let degraded = WeightStore::init(cfg, 6);
    let wl = EchoWorkload { cfg };
    // Per-member counts scale with workers so the spike lasts the same
    // virtual duration (~8 controller ticks) at every worker count.
    let per_member = 120 * workers;
    let eopts = EngineOpts {
        workers,
        rate: 0.5 * dense_capacity(workers),
        requests: 1, // ignored by run_fleet_sim (per-member counts used)
        max_batch: MAX_BATCH,
        max_wait: 0.004,
        queue_cap: 16,
        seed: 11,
        spike: 3.0,
        slo_p99_ms: SLO_P99_MS,
        controller: with_controller.then(|| ControllerOpts {
            tick_s: 0.01,
            slo_p99_ms: SLO_P99_MS,
            degrade: true,
            degrade_after: 3,
            recover_after: 3,
            min_dwell_ticks: 10,
            ..Default::default()
        }),
        ..Default::default()
    };
    let members = vec![
        // Member 0 carries an explicit per-member SLO override; member 1
        // defers to the fleet default — both resolve to the same budget.
        FleetMember::new(&exec, &dense, &wl, per_member)
            .with_slo_p99_ms(SLO_P99_MS)
            .with_fallback(&degraded)
            .erased(),
        FleetMember::new(&exec, &dense, &wl, per_member).with_fallback(&degraded).erased(),
    ];
    let cost = SimCost::affine(MAX_BATCH, BASE_S, PER_ROW_S, &[1.0, 0.4]).with_jitter(0.02);
    run_fleet_sim(members, &[cost.clone(), cost], &eopts).unwrap()
}

/// Bit-level digest of everything a trajectory determines: per-request
/// records, shedding, percentiles, and the transition log.
fn digest(stats: &[EngineStats]) -> Vec<u64> {
    let mut d = Vec::new();
    for s in stats {
        d.push(s.served as u64);
        d.push(s.shed as u64);
        d.push(s.p50_ms.to_bits());
        d.push(s.p99_ms.to_bits());
        for r in &s.records {
            d.push(r.id as u64);
            d.push(r.pred as u64);
            d.push(r.steps as u64);
            d.push(r.variant as u64);
            d.push(r.total_ms.to_bits());
            d.push(r.queue_ms.to_bits());
        }
        for t in &s.transitions {
            d.push(t.t.to_bits());
            d.push(t.member as u64);
            d.push(t.from as u64);
            d.push(t.to as u64);
        }
    }
    d
}

#[test]
fn load_spike_controller_holds_slo_and_sheds_less_at_every_worker_count() {
    for workers in [1usize, 2, 4] {
        let base = spike_run(workers, false);
        let ctl = spike_run(workers, true);
        let base_shed: usize = base.iter().map(|s| s.shed).sum();
        let ctl_shed: usize = ctl.iter().map(|s| s.shed).sum();
        assert!(
            base_shed > 0,
            "workers {workers}: the spike must overload the uncontrolled engine"
        );
        assert!(
            ctl_shed < base_shed,
            "workers {workers}: controller shed {ctl_shed}, baseline shed {base_shed}"
        );
        for (m, s) in ctl.iter().enumerate() {
            assert_eq!(s.slo_p99_ms, SLO_P99_MS, "workers {workers} member {m}");
            assert!(
                s.p99_ms <= SLO_P99_MS,
                "workers {workers} member {m}: p99 {:.2}ms over the {SLO_P99_MS}ms budget",
                s.p99_ms
            );
            // The exact hysteresis trajectory: one degrade into the spike,
            // one recovery after it — never a flap.
            let seq: Vec<(usize, usize)> =
                s.transitions.iter().map(|t| (t.from, t.to)).collect();
            assert_eq!(
                seq,
                vec![(0, 1), (1, 0)],
                "workers {workers} member {m}: transition sequence {seq:?}"
            );
            assert!(s.transitions.iter().all(|t| t.member == m));
            assert!(
                s.transitions[0].t < s.transitions[1].t,
                "workers {workers} member {m}: transitions out of order"
            );
            // Some — but not all — requests rode the degraded rung.
            let degraded: usize = s.served_by_variant.iter().skip(1).sum();
            assert!(degraded > 0, "workers {workers} member {m}: nothing served degraded");
            assert!(
                degraded < s.served,
                "workers {workers} member {m}: everything served degraded"
            );
            assert!(s.time_in_variant_s[1] > 0.0, "workers {workers} member {m}");
            // Everything offered is accounted for.
            assert_eq!(s.served + s.shed, 120 * workers, "workers {workers} member {m}");
        }
        // The baseline never switches variants and serves dense only.
        for s in &base {
            assert!(s.transitions.is_empty());
            assert!(s.served_by_variant.iter().skip(1).all(|&n| n == 0));
        }
        // Bit-reproducible: the same inputs give byte-identical
        // trajectories, including the transition log.
        assert_eq!(
            digest(&ctl),
            digest(&spike_run(workers, true)),
            "workers {workers}: controller trajectory not reproducible"
        );
        assert_eq!(
            digest(&base),
            digest(&spike_run(workers, false)),
            "workers {workers}: baseline trajectory not reproducible"
        );
    }
}

#[test]
fn sim_rejects_degenerate_fleets() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 5);
    let wl = EchoWorkload { cfg };
    let cost = SimCost::affine(4, 0.001, 0.0001, &[]);
    let opts = EngineOpts::default();
    let err = run_fleet_sim(vec![], &[cost.clone()], &opts).unwrap_err().to_string();
    assert!(err.contains("at least one member"), "{err}");
    let err = run_fleet_sim(
        vec![FleetMember::new(&exec, &dense, &wl, 0).erased()],
        &[cost.clone()],
        &opts,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("at least one request"), "{err}");
    let err = run_fleet_sim(vec![FleetMember::new(&exec, &dense, &wl, 4).erased()], &[], &opts)
        .unwrap_err()
        .to_string();
    assert!(err.contains("SimCost"), "{err}");
}
