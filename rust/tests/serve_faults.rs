//! Fault-tolerance integration tests: the deterministic chaos layer
//! ([`FaultPlan`]) driven through both the threaded engine and the
//! discrete-event simulator.
//!
//! The contract under test: injected worker kills, dispatch faults, and
//! deadline expiries change *when* requests run (retries, respawns,
//! failures) but never *what* surviving requests compute — every
//! non-failed request's prediction is bitwise-equal to the fault-free
//! run's, accounting balances (`served + shed + failures == offered`),
//! and aborted generations return their paged KV blocks
//! (`kv_blocks_in_use == kv_registered_blocks` on every exit path).
//!
//! Compiled out under `--cfg pjrt_backend` (no threaded engine, no sim).
#![cfg(not(pjrt_backend))]

use anyhow::{bail, Result};

use corp::data::DATA_SEED;
use corp::exec::Executor;
use corp::model::{ModelConfig, WeightStore};
use corp::runtime::Runtime;
use corp::serve::{
    run_engine, run_fleet_sim, EngineOpts, EngineStats, FaultPlan, FleetMember, GenWorkload,
    Plans, RequestOutput, SimCost, StepOutcome, VisionWorkload, Workload,
};

fn native_runtime() -> Runtime {
    Runtime::new(std::env::temp_dir().join("corp_serve_faults_no_artifacts")).unwrap()
}

fn vit_t() -> &'static ModelConfig {
    ModelConfig::by_name("vit_t").unwrap()
}

/// `(id, pred)` per served request — records are sorted by id, so two
/// runs agree iff they served the same requests with identical outputs.
fn preds(s: &EngineStats) -> Vec<(usize, i32)> {
    s.records.iter().map(|r| (r.id, r.pred)).collect()
}

// ---------------------------------------------------------------------------
// FaultPlan grammar
// ---------------------------------------------------------------------------

#[test]
fn fault_plan_parse_accepts_and_rejects() {
    let p = FaultPlan::parse(" kill=0@1 , fail=3, fail=5@2 ,delay=7:20.5,, ").unwrap();
    assert_eq!(p.kills, vec![(0, 1)]);
    assert_eq!(p.fails, vec![(3, 0), (5, 2)]);
    assert_eq!(p.delays.len(), 1);
    assert_eq!(p.delays[0].0, 7);
    assert!((p.delays[0].1 - 0.0205).abs() < 1e-12, "ms spec parses into seconds");
    assert!(FaultPlan::parse("").unwrap().is_empty());
    assert!(!p.is_empty());
    for (spec, needle) in [
        ("kill=0", "W@B"),
        ("kill=zero@1", "not a non-negative integer"),
        ("fail=x", "not a non-negative integer"),
        ("delay=3", "ID:MS"),
        ("delay=3:abc", "not a number"),
        ("delay=3:-5", ">= 0"),
        ("oops=1", "unknown fault kind"),
        ("fail3", "kind=value"),
    ] {
        let err = FaultPlan::parse(spec).unwrap_err().to_string();
        assert!(err.contains(needle), "{spec}: {err}");
    }
}

// ---------------------------------------------------------------------------
// Threaded engine: chaos changes timing, never results
// ---------------------------------------------------------------------------

#[test]
fn chaos_engine_matches_fault_free_predictions_bitwise() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 7);
    let workload = VisionWorkload::new(cfg, DATA_SEED).unwrap();
    let mk = |chaos: Option<FaultPlan>| EngineOpts {
        workers: 1, // every batch is worker 0's → the kill ordinal is exact
        rate: 1e12,
        requests: 24,
        max_batch: 8,
        max_wait: 0.002,
        queue_cap: 1024,
        max_retries: 2,
        chaos,
        ..Default::default()
    };
    let base = run_engine(&exec, &w, &workload, &mk(None)).unwrap();
    let plan = FaultPlan::parse("kill=0@1,fail=3,fail=7@0,delay=5:5").unwrap();
    let chaos = run_engine(&exec, &w, &workload, &mk(Some(plan))).unwrap();
    // The kill is absorbed (no process abort, no run error), the killed
    // batch and both faulted dispatches retry, and everything is served.
    assert_eq!(chaos.served, 24);
    assert_eq!(chaos.shed, 0);
    assert_eq!(chaos.failures, 0);
    assert_eq!(chaos.timeouts, 0);
    assert_eq!(chaos.worker_respawns, 1);
    // ≥ 1 request rode the killed batch + the two injected dispatch faults.
    assert!(chaos.retries >= 3, "retries {}", chaos.retries);
    assert_eq!(base.worker_respawns, 0);
    assert_eq!(base.retries, 0);
    // The headline guarantee: per-request outputs are bitwise-unchanged.
    assert_eq!(preds(&base), preds(&chaos), "chaos changed served predictions");
}

#[test]
fn retry_budget_exhaustion_counts_failures() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 9);
    let workload = VisionWorkload::new(cfg, DATA_SEED).unwrap();
    let opts = EngineOpts {
        workers: 2,
        rate: 1e12,
        requests: 12,
        max_batch: 4,
        max_wait: 0.002,
        queue_cap: 1024,
        max_retries: 0, // no budget: the injected fault is terminal
        chaos: Some(FaultPlan::parse("fail=5").unwrap()),
        ..Default::default()
    };
    let s = run_engine(&exec, &w, &workload, &opts).unwrap();
    assert_eq!(s.failures, 1);
    assert_eq!(s.served, 11);
    assert_eq!(s.served + s.shed + s.failures, 12, "accounting must balance");
    assert_eq!(s.retries, 0);
    assert!(s.records.iter().all(|r| r.id != 5), "failed requests leave no record");
    // Vision requests hold no KV state — nothing to reclaim.
    assert_eq!(s.kv_reclaimed_blocks, 0);
}

#[test]
fn timeouts_retry_then_fail_with_balanced_accounting() {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 11);
    let workload = VisionWorkload::new(cfg, DATA_SEED).unwrap();
    // Saturated arrivals into a floored (20 ms/batch) single worker with a
    // 1 ms deadline: most requests expire at dispatch, burn their one
    // retry, and fail — the wall-clock timings vary but the accounting
    // identity and the counter directions are invariant.
    let opts = EngineOpts {
        workers: 1,
        rate: 1e12,
        requests: 32,
        max_batch: 4,
        max_wait: 0.0,
        queue_cap: 1024,
        exec_floor: 0.02,
        request_timeout: 0.001,
        max_retries: 1,
        retry_backoff: 0.0005,
        ..Default::default()
    };
    let s = run_engine(&exec, &w, &workload, &opts).unwrap();
    assert_eq!(s.served + s.shed + s.failures, 32, "accounting must balance");
    assert!(s.timeouts > 0, "the deadline must fire under a 20 ms floor");
    assert!(s.failures > 0, "double-expired requests must fail");
    assert!(s.retries > 0, "first expiries must retry");
    assert!(s.timeouts >= s.failures, "every failure here expired at least twice");
    assert_eq!(s.worker_respawns, 0);
}

// ---------------------------------------------------------------------------
// Generation workloads: aborts return their paged KV blocks
// ---------------------------------------------------------------------------

#[test]
fn gen_fault_reclaims_kv_blocks_mid_generation() {
    let rt = native_runtime();
    let gpt = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, gpt);
    let w = WeightStore::init(gpt, 6);
    // Chunked prefill guarantees every request reaches a step 1 holding
    // live KV blocks from its first chunk, so the injected fault below
    // always lands mid-sequence with state to reclaim (prompts are ≥ 4
    // tokens — `default_min_prompt` — hence ≥ 2 chunks of 2).
    let wl =
        GenWorkload::new(gpt, DATA_SEED).unwrap().with_max_new(4).with_prefill_chunk(2);
    let victim = 2usize;
    let mk = |chaos: Option<FaultPlan>| EngineOpts {
        workers: 2,
        rate: 1e12,
        requests: 6,
        max_batch: 4,
        max_wait: 0.002,
        queue_cap: 1024,
        max_retries: 0,
        chaos,
        ..Default::default()
    };
    let base = run_engine(&exec, &w, &wl, &mk(None)).unwrap();
    let plan = FaultPlan { fails: vec![(victim, 1)], ..Default::default() };
    let s = run_engine(&exec, &w, &wl, &mk(Some(plan))).unwrap();
    assert_eq!(s.failures, 1);
    assert_eq!(s.served, 5);
    assert!(s.records.iter().all(|r| r.id != victim));
    // The aborted sequence's prefill blocks went back to the pool …
    assert!(s.kv_reclaimed_blocks > 0, "mid-generation abort must return KV blocks");
    // … and nothing leaked: only registry-pinned blocks may remain.
    assert_eq!(s.kv_blocks_in_use, s.kv_registered_blocks, "leaked KV blocks");
    assert_eq!(base.kv_blocks_in_use, base.kv_registered_blocks);
    // Survivors are bitwise-unchanged relative to the fault-free run.
    let survivors: Vec<(usize, i32)> =
        preds(&base).into_iter().filter(|&(id, _)| id != victim).collect();
    assert_eq!(survivors, preds(&s), "fault changed surviving generations");
}

#[test]
fn gen_shedding_under_overload_leaks_no_kv_blocks() {
    let rt = native_runtime();
    let gpt = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, gpt);
    let w = WeightStore::init(gpt, 6);
    // Chunked prefill forces every request through ≥ 2 steps, so admitted
    // generations always re-enqueue continuations into the full queue.
    let wl =
        GenWorkload::new(gpt, DATA_SEED).unwrap().with_max_new(3).with_prefill_chunk(2);
    // Saturated arrivals into a 2-deep queue: fresh arrivals are shed, but
    // admitted generations' continuations bypass the bound — a shed
    // continuation would strand its KV blocks (the regression this pins).
    let opts = EngineOpts {
        workers: 1,
        rate: 1e12,
        requests: 16,
        max_batch: 2,
        max_wait: 0.0,
        queue_cap: 2,
        exec_floor: 0.005,
        ..Default::default()
    };
    let s = run_engine(&exec, &w, &wl, &opts).unwrap();
    assert!(s.shed > 0, "a 2-deep queue must shed under saturation");
    assert_eq!(s.served + s.shed + s.failures, 16, "accounting must balance");
    assert!(s.records.iter().any(|r| r.steps > 1), "some generation decoded");
    assert_eq!(
        s.kv_blocks_in_use, s.kv_registered_blocks,
        "shed/served churn leaked KV blocks"
    );
}

// ---------------------------------------------------------------------------
// Simulator: chaos trajectories are bit-reproducible
// ---------------------------------------------------------------------------

/// Single-shot echo (prediction = pure function of the id): fault tests
/// exercise *routing*, so model math is reduced to arithmetic.
struct EchoWorkload {
    cfg: &'static ModelConfig,
}

impl Workload for EchoWorkload {
    type Req = usize;

    fn cfg(&self) -> &'static ModelConfig {
        self.cfg
    }

    fn label(&self) -> &'static str {
        "echo"
    }

    fn synth(&self, id: usize) -> usize {
        id
    }

    fn run_step(
        &self,
        _plans: &Plans<'_, '_>,
        reqs: &[&usize],
        dispatch: usize,
    ) -> Result<Vec<StepOutcome>> {
        if reqs.is_empty() || dispatch < reqs.len() {
            bail!("echo run_step: {} requests into dispatch {dispatch}", reqs.len());
        }
        Ok(reqs
            .iter()
            .map(|&&id| {
                StepOutcome::Done(RequestOutput { pred: ((id as i32) * 31) % 97, tokens: 1 })
            })
            .collect())
    }
}

/// Bit-level digest of a simulated trajectory, fault accounting included.
fn digest(stats: &[EngineStats]) -> Vec<u64> {
    let mut d = Vec::new();
    for s in stats {
        for n in [s.served, s.shed, s.failures, s.retries, s.timeouts] {
            d.push(n as u64);
        }
        d.push(s.worker_respawns as u64);
        d.push(s.kv_reclaimed_blocks as u64);
        d.push(s.p50_ms.to_bits());
        d.push(s.p99_ms.to_bits());
        for r in &s.records {
            d.push(r.id as u64);
            d.push(r.pred as u64);
            d.push(r.steps as u64);
            d.push(r.total_ms.to_bits());
            d.push(r.queue_ms.to_bits());
        }
    }
    d
}

fn chaos_sim(workers: usize) -> Vec<EngineStats> {
    let rt = native_runtime();
    let cfg = vit_t();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 5);
    let wl = EchoWorkload { cfg };
    let opts = EngineOpts {
        workers,
        rate: 500.0 * workers as f64, // 0.5× fleet capacity: no shedding
        requests: 1,                  // ignored (per-member count below)
        max_batch: 8,
        max_wait: 0.004,
        queue_cap: 64,
        seed: 11,
        max_retries: 3,
        chaos: Some(FaultPlan::parse("kill=0@1,fail=3,fail=9@0,delay=5:20").unwrap()),
        ..Default::default()
    };
    let members = vec![FleetMember::new(&exec, &dense, &wl, 60).erased()];
    let cost = SimCost::affine(8, 0.004, 0.0005, &[1.0]);
    run_fleet_sim(members, &[cost], &opts).unwrap()
}

#[test]
fn sim_chaos_deterministic_and_served_outputs_worker_invariant() {
    let mut all_preds: Vec<Vec<(usize, i32)>> = Vec::new();
    for workers in [1usize, 2, 4] {
        let s = chaos_sim(workers);
        assert_eq!(s.len(), 1);
        let s0 = &s[0];
        // The retry budget absorbs every injected fault: nothing fails.
        assert_eq!(s0.served, 60, "workers {workers}");
        assert_eq!(s0.shed, 0, "workers {workers}");
        assert_eq!(s0.failures, 0, "workers {workers}");
        assert_eq!(s0.worker_respawns, 1, "workers {workers}: kill=0@1 must fire");
        assert!(s0.retries >= 3, "workers {workers}: retries {}", s0.retries);
        for r in &s0.records {
            assert_eq!(r.pred, ((r.id as i32) * 31) % 97, "workers {workers} id {}", r.id);
        }
        // Same inputs → byte-identical trajectory, fault tallies included.
        assert_eq!(
            digest(&s),
            digest(&chaos_sim(workers)),
            "workers {workers}: chaos trajectory not reproducible"
        );
        all_preds.push(preds(s0));
    }
    // Faults key on request ids and per-server ordinals — never on global
    // schedule order — so served outputs are invariant across fleet sizes.
    assert_eq!(all_preds[0], all_preds[1], "1 vs 2 workers diverged");
    assert_eq!(all_preds[0], all_preds[2], "1 vs 4 workers diverged");
}

#[test]
fn sim_timeout_accounting_balances_deterministically() {
    let run = || {
        let rt = native_runtime();
        let cfg = vit_t();
        let exec = Executor::new(&rt, cfg);
        let dense = WeightStore::init(cfg, 5);
        let wl = EchoWorkload { cfg };
        let opts = EngineOpts {
            workers: 1,
            rate: 1e12, // everything due at t = 0 behind a 50 ms/batch server
            requests: 1,
            max_batch: 4,
            max_wait: 0.0,
            queue_cap: 64,
            seed: 3,
            request_timeout: 0.06,
            max_retries: 1,
            ..Default::default()
        };
        let members = vec![FleetMember::new(&exec, &dense, &wl, 40).erased()];
        let cost = SimCost::affine(4, 0.05, 0.0, &[1.0]);
        run_fleet_sim(members, &[cost], &opts).unwrap()
    };
    let s = run();
    let s0 = &s[0];
    assert_eq!(s0.served + s0.shed + s0.failures, 40, "accounting must balance");
    assert!(s0.served > 0, "the head of the queue beats its deadline");
    assert!(s0.timeouts > 0);
    assert!(s0.retries > 0);
    assert!(s0.failures > 0, "double-expired requests must fail");
    assert!(s0.timeouts >= s0.failures);
    // The virtual clock makes even the failure pattern bit-reproducible.
    assert_eq!(digest(&s), digest(&run()), "timeout trajectory not reproducible");
}
