//! End-to-end pipeline integration tests (need built artifacts; each test
//! skips gracefully when artifacts/ is absent).
//!
//! Uses untrained (deterministic-init) weights where possible so the suite
//! stays fast; behavioral accuracy claims live in the benches.

use corp::data::{Split, VisionGen};
use corp::exec::Executor;
use corp::model::{keep_count, ModelConfig, Scope, Sparsity, WeightStore};
use corp::prune::{calibrate, prune, Method, PruneOpts};
use corp::runtime::Runtime;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = corp::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

fn small_opts(sp: Sparsity, method: Method) -> PruneOpts {
    PruneOpts { sparsity: sp, method, calib_batches: 2, attn_max_samples: 32, ..PruneOpts::default() }
}

#[test]
fn corp_pipeline_produces_runnable_pruned_model() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 10);
    let opts = small_opts(Sparsity::of(Scope::Both, 5), Method::Corp);
    let stats = calibrate(&exec, &dense, &opts).unwrap();
    let result = prune(&exec, &dense, &stats, &opts).unwrap();
    // Shapes: wq/wk reduced, w1/w2 reduced, v/o untouched.
    let dqk = keep_count(cfg.dh(), 5);
    let o = keep_count(cfg.mlp, 5);
    let w = &result.weights;
    assert_eq!(w.get("blocks.0.attn.wq").unwrap().shape(), &[cfg.d, cfg.heads * dqk]);
    assert_eq!(w.get("blocks.0.attn.wk").unwrap().shape(), &[cfg.d, cfg.heads * dqk]);
    assert_eq!(w.get("blocks.0.attn.wv").unwrap().shape(), &[cfg.d, cfg.d]);
    assert_eq!(w.get("blocks.0.mlp.w1").unwrap().shape(), &[cfg.d, o]);
    assert_eq!(w.get("blocks.0.mlp.w2").unwrap().shape(), &[o, cfg.d]);
    // Pruned model runs end-to-end.
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let b = cfg.eval_batch();
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    let logits = exec.forward_vit(w, &tokens, b).unwrap();
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

#[test]
fn all_methods_produce_valid_models() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 11);
    let opts0 = small_opts(Sparsity::of(Scope::Mlp, 5), Method::Corp);
    let stats = calibrate(&exec, &dense, &opts0).unwrap();
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let b = cfg.eval_batch();
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    for method in [Method::Corp, Method::Naive, Method::Grail, Method::Vbp] {
        let opts = small_opts(Sparsity::of(Scope::Mlp, 5), method);
        let result = prune(&exec, &dense, &stats, &opts).unwrap();
        let logits = exec.forward_vit(&result.weights, &tokens, b).unwrap();
        assert!(
            logits.data().iter().all(|v| v.is_finite()),
            "{} produced non-finite logits",
            method.label()
        );
    }
}

#[test]
fn compensated_model_closer_to_dense_than_naive() {
    // On *calibration-distribution* data, CORP logits must be closer to the
    // dense model's logits than naive pruning's (representation recovery).
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    // Use a trained checkpoint if available (realistic activations);
    // deterministic-init otherwise.
    let opts_t = corp::train::TrainOpts::default();
    let ck = corp::train::ckpt_path(cfg, &opts_t);
    let dense = if ck.exists() { WeightStore::load(&ck).unwrap() } else { WeightStore::init(cfg, 12) };
    let opts = small_opts(Sparsity::of(Scope::Both, 4), Method::Corp);
    let stats = calibrate(&exec, &dense, &opts).unwrap();
    let corp_w = prune(&exec, &dense, &stats, &opts).unwrap().weights;
    let naive_w = prune(&exec, &dense, &stats, &small_opts(Sparsity::of(Scope::Both, 4), Method::Naive))
        .unwrap()
        .weights;
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let b = cfg.eval_batch();
    let mut d_corp = 0.0;
    let mut d_naive = 0.0;
    for i in 0..3 {
        let (tokens, _) = gen.batch(Split::Calib, 100 + i, b);
        let full = exec.forward_vit(&dense, &tokens, b).unwrap();
        let c = exec.forward_vit(&corp_w, &tokens, b).unwrap();
        let n = exec.forward_vit(&naive_w, &tokens, b).unwrap();
        d_corp += full.sq_dist(&c);
        d_naive += full.sq_dist(&n);
    }
    assert!(
        d_corp < d_naive,
        "CORP logit distance {d_corp} not below naive {d_naive}"
    );
}

#[test]
fn sparsity_zero_scopes_are_noops() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 13);
    // MLP-only pruning must leave attention weights bit-identical.
    let opts = small_opts(Sparsity::of(Scope::Mlp, 5), Method::Corp);
    let stats = calibrate(&exec, &dense, &opts).unwrap();
    let out = prune(&exec, &dense, &stats, &opts).unwrap().weights;
    for l in 0..cfg.layers {
        for name in ["attn.wq", "attn.bq", "attn.wk", "attn.bk", "attn.wv", "attn.wo"] {
            let key = format!("blocks.{l}.{name}");
            assert_eq!(out.get(&key).unwrap().data(), dense.get(&key).unwrap().data(), "{key}");
        }
    }
}

#[test]
fn gpt_pipeline_prunes_and_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 14);
    let opts = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 3),
        calib_batches: 2,
        attn_max_samples: 16,
        ..PruneOpts::default()
    };
    let stats = calibrate(&exec, &dense, &opts).unwrap();
    let result = prune(&exec, &dense, &stats, &opts).unwrap();
    let gen = corp::data::TextGen::new(corp::data::DATA_SEED);
    let ppl = corp::eval::ppl_stitched(&exec, &result.weights, &gen, 2).unwrap();
    assert!(ppl.is_finite() && ppl > 1.0);
}

#[test]
fn serve_measure_reports_sane_numbers() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 15);
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let stats = corp::serve::measure(&exec, &w, &gen, 3, 3).unwrap();
    assert!(stats.p50_ms > 0.0);
    assert!(stats.p95_ms >= stats.p50_ms);
    assert!(stats.throughput_fps > 0.0);
}

// (The engine is a fail-fast stub in the pjrt_backend build; see serve::engine.)
#[cfg(not(pjrt_backend))]
#[test]
fn serving_engine_serves_all_requests() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 16);
    let workload = corp::serve::VisionWorkload::new(cfg, corp::data::DATA_SEED).unwrap();
    let opts = corp::serve::EngineOpts { rate: 500.0, requests: 48, ..Default::default() };
    let stats = corp::serve::run_engine(&exec, &w, &workload, &opts).unwrap();
    assert_eq!(stats.served, 48);
    assert_eq!(stats.shed, 0);
    assert!(stats.mean_batch >= 1.0);
    assert!(stats.mean_dispatch >= stats.mean_batch - 1e-9);
    assert!(stats.p50_ms > 0.0);
}
