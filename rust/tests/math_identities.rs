//! Cross-module property tests of the paper's mathematical identities,
//! composing stats → rank → compensate exactly as the pipeline does.

use corp::linalg::Mat;
use corp::rank::partition;
use corp::stats::{cov_blocks, MomentAccumulator};
use corp::tensor::Tensor;
use corp::util::prop::{gen, run_prop};
use corp::util::Pcg64;

/// Generate correlated activations: x = zB + mean + noise (low-rank + bias).
fn correlated_acts(rng: &mut Pcg64, rows: usize, o: usize, rank: usize) -> Vec<f32> {
    let basis = gen::matrix(rng, rank, o, 1.0);
    let mean: Vec<f32> = (0..o).map(|_| rng.normal_f32(0.4, 0.5)).collect();
    let mut x = vec![0.0f32; rows * o];
    for r in 0..rows {
        let z: Vec<f32> = (0..rank).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for c in 0..o {
            let mut v = mean[c];
            for k in 0..rank {
                v += z[k] * basis[k * o + c];
            }
            x[r * o + c] = v + rng.normal_f32(0.0, 0.05);
        }
    }
    x
}

/// Eq. 12 consequence: compensated error ≤ uncompensated error, measured
/// empirically through the full stats → compensate path; on low-rank +
/// biased activations the gain must be substantial.
#[test]
fn compensation_never_hurts_on_calibration() {
    run_prop("e2e.comp <= naive error", 8, |rng| {
        let o = 8 + rng.below(8);
        let d = 2 + rng.below(4);
        let rows = 400;
        let x = correlated_acts(rng, rows, o, 3);
        let mut acc = MomentAccumulator::new(o);
        acc.add_batch(&x, rows);
        let w2 = Tensor::from_vec(&[o, d], gen::matrix(rng, o, d, 1.0));
        let b2 = Tensor::from_vec(&[d], vec![0.1; d]);
        let scores = acc.energy();
        let (kept, pruned) = partition(&scores, 5);
        let blocks = cov_blocks(&acc.covariance(), &acc.mean(), &kept, &pruned);
        let comp = corp::compensate::compensate_mlp(&w2, &b2, &kept, &pruned, &blocks, 1e-6);

        let (mut err_comp, mut err_naive) = (0.0f64, 0.0f64);
        for r in 0..rows {
            let xr = &x[r * o..(r + 1) * o];
            for col in 0..d {
                let full: f64 = (0..o).map(|i| (xr[i] * w2.at2(i, col)) as f64).sum::<f64>()
                    + b2.data()[col] as f64;
                let naive: f64 = kept.iter().map(|&i| (xr[i] * w2.at2(i, col)) as f64).sum::<f64>()
                    + b2.data()[col] as f64;
                let compd: f64 = (0..kept.len())
                    .map(|k| (xr[kept[k]] * comp.w2_hat.at2(k, col)) as f64)
                    .sum::<f64>()
                    + comp.b2_hat.data()[col] as f64;
                err_comp += (full - compd) * (full - compd);
                err_naive += (full - naive) * (full - naive);
            }
        }
        assert!(err_comp <= err_naive * 1.001 + 1e-9, "comp {err_comp} > naive {err_naive}");
        assert!(err_comp < err_naive * 0.8, "gain too small: {err_comp} vs {err_naive}");
    });
}

/// The fold identity (Eq. 20): Ŵ_S x_S + b̂ == W_S x_S + W_P (B x_S + c) + b.
#[test]
fn fold_equals_explicit_affine_prediction() {
    run_prop("e2e.fold identity", 8, |rng| {
        let o = 6 + rng.below(6);
        let d = 3;
        let rows = 200;
        let x = correlated_acts(rng, rows, o, 2);
        let mut acc = MomentAccumulator::new(o);
        acc.add_batch(&x, rows);
        let (kept, pruned) = partition(&acc.energy(), 5);
        let blocks = cov_blocks(&acc.covariance(), &acc.mean(), &kept, &pruned);
        let w2 = Tensor::from_vec(&[o, d], gen::matrix(rng, o, d, 1.0));
        let b2 = Tensor::from_vec(&[d], vec![0.3; d]);
        let comp = corp::compensate::compensate_mlp(&w2, &b2, &kept, &pruned, &blocks, 1e-4);

        let b_mat = corp::linalg::ridge::ridge_right(&blocks.ps, &blocks.ss, 1e-4);
        let xs: Vec<f64> = kept.iter().map(|&i| x[i] as f64).collect();
        let xp_hat: Vec<f64> = (0..pruned.len())
            .map(|i| {
                blocks.mu_p[i]
                    + (0..kept.len())
                        .map(|j| b_mat.at(i, j) * (xs[j] - blocks.mu_s[j]))
                        .sum::<f64>()
            })
            .collect();
        for col in 0..d {
            let explicit: f64 = kept
                .iter()
                .enumerate()
                .map(|(k, &i)| xs[k] * w2.at2(i, col) as f64)
                .sum::<f64>()
                + pruned
                    .iter()
                    .enumerate()
                    .map(|(pi, &i)| xp_hat[pi] * w2.at2(i, col) as f64)
                    .sum::<f64>()
                + b2.data()[col] as f64;
            let folded: f64 = (0..kept.len())
                .map(|k| xs[k] * comp.w2_hat.at2(k, col) as f64)
                .sum::<f64>()
                + comp.b2_hat.data()[col] as f64;
            assert!((explicit - folded).abs() < 1e-3, "col {col}: {explicit} vs {folded}");
        }
    });
}

/// The parallel kernels must be worker-count invariant through the full
/// stats → rank → compensate composition at pipeline-realistic sizes: the
/// same calibration data folded and solved under 1 vs N workers must yield
/// the same compensated weights within f32 tolerance (row-ownership in the
/// packed kernels actually makes this bitwise, but only tolerance equality
/// is asserted).
#[test]
fn pipeline_composition_thread_count_invariant() {
    use corp::util::threads::with_threads;
    run_prop("e2e.thread invariance", 3, |rng| {
        let o = 96 + rng.below(64); // larger than the seed's ~30-dim caps
        let d = 24;
        let rows = 600;
        let x = correlated_acts(rng, rows, o, 6);
        let w2 = Tensor::from_vec(&[o, d], gen::matrix(rng, o, d, 1.0));
        let b2 = Tensor::from_vec(&[d], vec![0.2; d]);
        let compensate = |workers: usize| {
            with_threads(workers, || {
                let mut acc = MomentAccumulator::new(o);
                acc.add_batch(&x, rows);
                let (kept, pruned) = partition(&acc.energy(), 5);
                let blocks = cov_blocks(&acc.covariance(), &acc.mean(), &kept, &pruned);
                corp::compensate::compensate_mlp(&w2, &b2, &kept, &pruned, &blocks, 1e-4)
            })
        };
        let base = compensate(1);
        for workers in [2usize, 4] {
            let got = compensate(workers);
            assert!(
                got.w2_hat.max_abs_diff(&base.w2_hat) < 1e-4,
                "w2_hat differs at {workers} workers"
            );
            assert!(
                got.b2_hat.max_abs_diff(&base.b2_hat) < 1e-4,
                "b2_hat differs at {workers} workers"
            );
        }
    });
}

/// Attention: compensated logit error ≤ naive logit error on calibration
/// (Prop. C.2.2 through the full per-head rank → compensate → fold path).
#[test]
fn attn_compensation_never_hurts() {
    run_prop("e2e.attn comp <= naive", 6, |rng| {
        let (d, dh, n, bsz) = (8, 6, 9, 16);
        let wq = Mat::from_f32(d, dh, &gen::matrix(rng, d, dh, 0.6));
        let wk = Mat::from_f32(d, dh, &gen::matrix(rng, d, dh, 0.6));
        let bq = vec![0.05; dh];
        let bk = vec![-0.02; dh];
        let basis = Mat::from_f32(3, d, &gen::matrix(rng, 3, d, 1.0));
        let mut qdata = vec![0.0f32; bsz * n * dh];
        let mut kdata = vec![0.0f32; bsz * n * dh];
        let mut xs = Vec::new();
        for b in 0..bsz {
            let mut x = Mat::zeros(n, d);
            for t in 0..n {
                let z: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
                for c in 0..d {
                    let mut v = 0.0;
                    for (k, zk) in z.iter().enumerate() {
                        v += zk * basis.at(k, c);
                    }
                    x.set(t, c, v + 0.05 * rng.normal());
                }
            }
            for t in 0..n {
                for j in 0..dh {
                    let mut qv = bq[j];
                    let mut kv = bk[j];
                    for c in 0..d {
                        qv += x.at(t, c) * wq.at(c, j);
                        kv += x.at(t, c) * wk.at(c, j);
                    }
                    qdata[(b * n + t) * dh + j] = qv as f32;
                    kdata[(b * n + t) * dh + j] = kv as f32;
                }
            }
            xs.push(x);
        }
        let q = Tensor::from_vec(&[bsz, n, dh], qdata);
        let k = Tensor::from_vec(&[bsz, n, dh], kdata);
        let scores = corp::rank::score_attn_logit_energy(&q, &k);
        let (kept, pruned) = partition(&scores, 5);
        let comp = corp::compensate::compensate_attn_head(
            &q, &k, &kept, &pruned, &wq, &bq, &wk, &bk, 1e-4, bsz,
        );
        let bias_row = |n: usize, b: &[f64]| {
            let mut m = Mat::zeros(n, b.len());
            for t in 0..n {
                for j in 0..b.len() {
                    m.set(t, j, b[j]);
                }
            }
            m
        };
        let all_rows: Vec<usize> = (0..n).collect();
        let (mut err_comp, mut err_naive) = (0.0, 0.0);
        for x in &xs {
            let qf = x.mul(&wq).add(&bias_row(n, &bq));
            let kf = x.mul(&wk).add(&bias_row(n, &bk));
            let full = qf.mul(&kf.t());
            let qs = qf.submatrix(&all_rows, &kept);
            let ks = kf.submatrix(&all_rows, &kept);
            let naive = qs.mul(&ks.t());
            let qc = x.mul(&comp.wq).add(&bias_row(n, &comp.bq));
            let kc = x.mul(&comp.wk).add(&bias_row(n, &comp.bk));
            let compd = qc.mul(&kc.t());
            err_comp += full.sub(&compd).frob().powi(2);
            err_naive += full.sub(&naive).frob().powi(2);
        }
        assert!(err_comp <= err_naive * 1.01 + 1e-9, "comp {err_comp} vs naive {err_naive}");
    });
}
