//! Criterion-zoo and FLOPs-allocator integration tests (need built
//! artifacts; each test skips gracefully when artifacts/ is absent).
//!
//! Shape/invariant contracts: every baseline produces artifact-compatible
//! pruned shapes (kept counts match `keep_count` / the allocation), CORP
//! compensation composes with every criterion in the zoo, and the greedy
//! allocator lands within ±2% of the requested global FLOPs budget measured
//! on the *actual* pruned per-layer shapes.

use corp::data::{Split, VisionGen};
use corp::exec::Executor;
use corp::model::{keep_count, ModelConfig, Scope, Sparsity, WeightStore};
use corp::prune::{allocate_flops, baselines, calibrate, prune, Method, PruneOpts};
use corp::rank::Criterion;
use corp::runtime::Runtime;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = corp::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

fn small_opts(sp: Sparsity, method: Method) -> PruneOpts {
    PruneOpts { sparsity: sp, method, calib_batches: 2, attn_max_samples: 32, ..PruneOpts::default() }
}

/// Per-row argmax of a [b, classes] logits tensor.
fn argmax_rows(logits: &corp::tensor::Tensor, b: usize, classes: usize) -> Vec<usize> {
    (0..b)
        .map(|j| {
            let row = &logits.data()[j * classes..(j + 1) * classes];
            let mut best = 0usize;
            for k in 1..classes {
                if row[k] > row[best] {
                    best = k;
                }
            }
            best
        })
        .collect()
}

#[test]
fn baselines_produce_artifact_compatible_shapes() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 21);
    let opts = small_opts(Sparsity::of(Scope::Both, 5), Method::Grail);
    let stats = calibrate(&exec, &dense, &opts).unwrap();
    let o_keep = keep_count(cfg.mlp, 5);
    let dqk = keep_count(cfg.dh(), 5);
    // GRAIL-like and VBP-like: same kept counts as the uniform grid, so the
    // pruned stores must match the `block_*` artifact shapes exactly.
    for method in [Method::Grail, Method::Vbp] {
        let w = prune(&exec, &dense, &stats, &small_opts(Sparsity::of(Scope::Both, 5), method))
            .unwrap()
            .weights;
        for l in 0..cfg.layers {
            assert_eq!(
                w.get(&format!("blocks.{l}.mlp.w1")).unwrap().shape(),
                &[cfg.d, o_keep],
                "{} layer {l} w1",
                method.label()
            );
            assert_eq!(
                w.get(&format!("blocks.{l}.mlp.w2")).unwrap().shape(),
                &[o_keep, cfg.d],
                "{} layer {l} w2",
                method.label()
            );
            assert_eq!(
                w.get(&format!("blocks.{l}.attn.wq")).unwrap().shape(),
                &[cfg.d, cfg.heads * dqk],
                "{} layer {l} wq",
                method.label()
            );
        }
    }
    // DC-ViT-like: MLP pruned to the same kept count, attention left dense
    // (whole modules are removed via the layer list instead).
    let (result, removed) =
        baselines::prune_dcvit(&exec, &dense, &stats, &small_opts(Sparsity::of(Scope::Both, 5), Method::Corp), 2)
            .unwrap();
    assert_eq!(removed.len(), 2);
    assert!(removed.iter().all(|&l| l < cfg.layers));
    let w = &result.weights;
    for l in 0..cfg.layers {
        assert_eq!(w.get(&format!("blocks.{l}.mlp.w1")).unwrap().shape(), &[cfg.d, o_keep]);
        assert_eq!(
            w.get(&format!("blocks.{l}.attn.wq")).unwrap().shape(),
            &[cfg.d, cfg.d],
            "dcvit leaves attention dense (layer {l})"
        );
    }
}

#[test]
fn compensation_composes_with_every_zoo_criterion() {
    // For each criterion: the compensated model's logits must be closer to
    // dense than the uncompensated ones *and* agree with the dense model's
    // top-1 predictions at least as often on the seeded eval window —
    // CORP's representation-preserving claim, per criterion.
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let opts_t = corp::train::TrainOpts::default();
    let ck = corp::train::ckpt_path(cfg, &opts_t);
    let dense = if ck.exists() { WeightStore::load(&ck).unwrap() } else { WeightStore::init(cfg, 22) };
    let opts0 = small_opts(Sparsity::of(Scope::Both, 4), Method::Corp);
    let stats = calibrate(&exec, &dense, &opts0).unwrap();
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let b = cfg.eval_batch();
    let start = corp::eval::eval_window(opts0.seed);
    for crit in Criterion::zoo() {
        let corp_w = {
            let o = PruneOpts { criterion: crit, ..opts0.clone() };
            prune(&exec, &dense, &stats, &o).unwrap().weights
        };
        let naive_w = {
            let o = PruneOpts {
                criterion: crit,
                ..small_opts(Sparsity::of(Scope::Both, 4), Method::Naive)
            };
            prune(&exec, &dense, &stats, &o).unwrap().weights
        };
        let (mut d_corp, mut d_naive) = (0.0, 0.0);
        let (mut agree_corp, mut agree_naive) = (0usize, 0usize);
        for i in 0..4 {
            let (tokens, _) = gen.batch(Split::Eval, start + i, b);
            let full = exec.forward_vit(&dense, &tokens, b).unwrap();
            let c = exec.forward_vit(&corp_w, &tokens, b).unwrap();
            let n = exec.forward_vit(&naive_w, &tokens, b).unwrap();
            d_corp += full.sq_dist(&c);
            d_naive += full.sq_dist(&n);
            let want = argmax_rows(&full, b, cfg.classes);
            let gc = argmax_rows(&c, b, cfg.classes);
            let gn = argmax_rows(&n, b, cfg.classes);
            agree_corp += want.iter().zip(&gc).filter(|(a, g)| a == g).count();
            agree_naive += want.iter().zip(&gn).filter(|(a, g)| a == g).count();
        }
        assert!(
            d_corp < d_naive,
            "{}: compensated logit distance {d_corp} not below naive {d_naive}",
            crit.label()
        );
        assert!(
            agree_corp >= agree_naive,
            "{}: compensated top-1 agreement {agree_corp} below naive {agree_naive}",
            crit.label()
        );
    }
}

#[test]
fn allocator_budget_holds_on_actual_pruned_shapes() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let dense = WeightStore::init(cfg, 23);
    let opts0 = small_opts(Sparsity::of(Scope::Both, 5), Method::Corp);
    let stats = calibrate(&exec, &dense, &opts0).unwrap();
    let budget = 60.0;
    let alloc = allocate_flops(cfg, &dense, &stats, Criterion::Energy, opts0.lambda, budget).unwrap();
    let opts = PruneOpts { alloc: Some(alloc.clone()), ..opts0 };
    let result = prune(&exec, &dense, &stats, &opts).unwrap();
    // The store's real shapes must be exactly the allocation's dims...
    let dims = exec.stored_layer_dims(&result.weights).unwrap();
    assert_eq!(dims, alloc.layer_dims());
    // ...and the achieved FLOPs measured on those shapes within ±2%.
    let f = corp::flops::flops_layered(cfg, &dims) as f64;
    let fd = corp::flops::flops(cfg, Sparsity::dense()) as f64;
    let achieved = 100.0 * f / fd;
    assert!((achieved - budget).abs() <= 2.0, "achieved {achieved:.2}% vs budget {budget}%");
    // The non-uniform store still evaluates end-to-end on the stitched path.
    let gen = VisionGen::new(corp::data::DATA_SEED);
    let b = cfg.eval_batch();
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    let logits = exec.forward_vit(&result.weights, &tokens, b).unwrap();
    assert!(logits.data().iter().all(|v| v.is_finite()));
}
