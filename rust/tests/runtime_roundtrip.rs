//! Integration: PJRT runtime round-trips the AOT artifacts.
//!
//! Requires `make artifacts` to have produced artifacts/ (skipped otherwise).

use corp::data::{Split, VisionGen};
use corp::exec::Executor;
use corp::model::{ModelConfig, WeightStore};
use corp::runtime::Runtime;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = corp::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

#[test]
fn embed_block_head_shapes() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 1);
    let b = cfg.eval_batch();
    let gen = VisionGen::new(0);
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    let x = exec.embed(&w, &tokens, b).unwrap();
    assert_eq!(x.shape(), &[b, cfg.n_ctx, cfg.d]);
    let y = exec.block(&w, 0, &x, b).unwrap();
    assert_eq!(y.shape(), &[b, cfg.n_ctx, cfg.d]);
    let logits = exec.head(&w, &y, b).unwrap();
    assert_eq!(logits.shape(), &[b, cfg.classes]);
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

#[test]
fn capture_matches_plain_block() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 2);
    let b = cfg.eval_batch();
    let gen = VisionGen::new(1);
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    let x = exec.embed(&w, &tokens, b).unwrap();
    let plain = exec.block(&w, 0, &x, b).unwrap();
    let (cap_y, cap) = exec.block_capture(&w, 0, &x).unwrap();
    assert!(plain.max_abs_diff(&cap_y) < 1e-4, "capture must not perturb output");
    assert_eq!(cap.hidden.shape(), &[b, cfg.n_ctx, cfg.mlp]);
    assert_eq!(cap.q.shape(), &[b, cfg.heads, cfg.n_ctx, cfg.dh()]);
    assert_eq!(cap.k.shape(), &[b, cfg.heads, cfg.n_ctx, cfg.dh()]);
}

#[test]
fn pruned_block_artifacts_execute() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let exec = Executor::new(&rt, cfg);
    // Manually shrink weights to the 50%-joint shape and run the block.
    let mut w = WeightStore::init(cfg, 3);
    let dqk = corp::model::keep_count(cfg.dh(), 5);
    let o = corp::model::keep_count(cfg.mlp, 5);
    for l in 0..cfg.layers {
        for (name, shape) in cfg.block_param_spec(dqk, o) {
            let n: usize = shape.iter().product();
            let t = corp::tensor::Tensor::from_vec(&shape, vec![0.01; n]);
            w.insert(format!("blocks.{l}.{name}"), t);
        }
        // restore norm gains to 1
        w.insert(format!("blocks.{l}.ln1.g"), corp::tensor::Tensor::from_vec(&[cfg.d], vec![1.0; cfg.d]));
        w.insert(format!("blocks.{l}.ln2.g"), corp::tensor::Tensor::from_vec(&[cfg.d], vec![1.0; cfg.d]));
    }
    let b = cfg.eval_batch();
    let gen = VisionGen::new(2);
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    let logits = exec.forward_vit(&w, &tokens, b).unwrap();
    assert_eq!(logits.shape(), &[b, cfg.classes]);
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

#[test]
fn gpt_forward_and_loss() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 4);
    let b = cfg.eval_batch();
    let gen = corp::data::TextGen::new(3);
    let (ids, targets) = gen.batch(Split::Eval, 0, b, cfg.n_ctx);
    let logits = exec.forward_gpt(&w, &ids, b).unwrap();
    assert_eq!(logits.shape(), &[b, cfg.n_ctx, cfg.vocab]);
    let loss = exec.eval_loss(&w, None, Some(&ids), &targets).unwrap();
    // Untrained loss ≈ ln(vocab) = ln 96 ≈ 4.56.
    assert!((loss - (cfg.vocab as f32).ln()).abs() < 0.5, "loss={loss}");
}

#[test]
fn train_step_reduces_loss_vit_t() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("vit_t").unwrap();
    let opts = corp::train::TrainOpts {
        steps: 60,
        lr: 1e-3,
        warmup: 10,
        log_every: 1000,
        ..Default::default()
    };
    let init = WeightStore::init(cfg, 5);
    let (_, log) = corp::train::train(&rt, cfg, init, &opts).unwrap();
    let first = log.losses[0];
    let last = *log.losses.last().unwrap();
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(log.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn stitched_forward_matches_evloss_graph() {
    // The per-block stitched path and the monolithic loss graph must agree:
    // cross-check CE computed from stitched logits vs the evloss artifact.
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::by_name("gpt_s").unwrap();
    let exec = Executor::new(&rt, cfg);
    let w = WeightStore::init(cfg, 6);
    let gen = corp::data::TextGen::new(9);
    let direct = corp::eval::ppl_dense(&exec, &w, &gen, 2).unwrap();
    let stitched = corp::eval::ppl_stitched(&exec, &w, &gen, 2).unwrap();
    let rel = (direct - stitched).abs() / direct;
    assert!(rel < 1e-3, "ppl mismatch: {direct} vs {stitched}");
}
