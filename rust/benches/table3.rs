//! Bench: regenerates the paper's table3 (see DESIGN.md §6).
//! Scale with CORP_BENCH_MODE={smoke,fast,full}; CSV lands in results/.

fn main() {
    let mut coord = corp::coordinator::Coordinator::new().expect("runtime (run `make artifacts` first)");
    corp::bench_tables::tables::table3(&mut coord).expect("table3");
}
