//! Microbenchmarks of the L3 hot paths (the §Perf targets): packed parallel
//! GEMM / Gram accumulation vs the seed scalar kernels, the Kronecker-ridge
//! assembly+solve, Cholesky, and the per-block execute round-trip overhead.
//!
//! The richer harness (JSON output, thread sweep, e2e pipeline timing) lives
//! in `corp bench linalg --json`; this bench keeps the historical CSV rows.

use corp::linalg::gemm::{matmul_f32, reference, syrk_upper_f32};
use corp::linalg::kron::KronRidge;
use corp::linalg::{Cholesky, Mat};
use corp::util::bench::{bench, CsvWriter};
use corp::util::prop::gen;
use corp::util::threads::{threads, with_threads};
use corp::util::Pcg64;

fn main() {
    let mut csv = CsvWriter::new("microbench", "name,mean_s,p50_s,flops,gflops_per_s");
    let mut rng = Pcg64::new(1);
    println!("worker pool: {} thread(s)", threads());

    // GEMM 256x256x256 (the calibration workhorse shape class), packed vs
    // the seed's scalar kernel.
    {
        let n = 256;
        let a = gen::matrix(&mut rng, n, n, 1.0);
        let b = gen::matrix(&mut rng, n, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let flops = 2.0 * (n * n * n) as f64;
        for (name, seed) in [("gemm_256", false), ("gemm_256_seedref", true)] {
            let s = bench(name, 2, 10, || {
                c.iter_mut().for_each(|v| *v = 0.0);
                if seed {
                    reference::matmul_f32_seed(&a, &b, &mut c, n, n, n);
                } else {
                    matmul_f32(&a, &b, &mut c, n, n, n);
                }
            });
            println!("{:24} {:9.4} ms  {:6.2} GFLOP/s", s.name, s.mean_s * 1e3, flops / s.mean_s / 1e9);
            csv.row(&[s.name.clone(), format!("{}", s.mean_s), format!("{}", s.p50_s), format!("{flops}"), format!("{:.3}", flops / s.mean_s / 1e9)]);
        }
    }

    // Gram accumulation: 2048 rows x 768 channels (vit_b hidden slab),
    // packed vs seed, plus a worker sweep.
    {
        let (rows, n) = (2048, 768);
        let x = gen::matrix(&mut rng, rows, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let flops = (rows * n * n) as f64; // ~half of full gemm
        for (name, seed) in [("syrk_2048x768", false), ("syrk_2048x768_seedref", true)] {
            let s = bench(name, 1, 5, || {
                c.iter_mut().for_each(|v| *v = 0.0);
                if seed {
                    reference::syrk_upper_f32_seed(&x, &mut c, rows, n);
                } else {
                    syrk_upper_f32(&x, &mut c, rows, n);
                }
            });
            println!("{:24} {:9.4} ms  {:6.2} GFLOP/s", s.name, s.mean_s * 1e3, flops / s.mean_s / 1e9);
            csv.row(&[s.name.clone(), format!("{}", s.mean_s), format!("{}", s.p50_s), format!("{flops}"), format!("{:.3}", flops / s.mean_s / 1e9)]);
        }
        for w in [1usize, 2, 4] {
            if w > threads() && w != 1 {
                continue;
            }
            let s = with_threads(w, || {
                bench(&format!("syrk_2048x768_w{w}"), 1, 3, || {
                    c.iter_mut().for_each(|v| *v = 0.0);
                    syrk_upper_f32(&x, &mut c, rows, n);
                })
            });
            println!("{:24} {:9.4} ms  {:6.2} GFLOP/s", s.name, s.mean_s * 1e3, flops / s.mean_s / 1e9);
            csv.row(&[s.name.clone(), format!("{}", s.mean_s), format!("{}", s.p50_s), format!("{flops}"), format!("{:.3}", flops / s.mean_s / 1e9)]);
        }
    }

    // Kronecker accumulate+solve at the 50%-pruned head size (d' = 16).
    {
        let d = 16;
        let n_tok = 17;
        let samples = 64;
        let mats: Vec<(Mat, Mat, Mat)> = (0..samples)
            .map(|_| {
                let qs = Mat::from_f32(n_tok, d, &gen::matrix(&mut rng, n_tok, d, 1.0));
                let ks = Mat::from_f32(n_tok, d, &gen::matrix(&mut rng, n_tok, d, 1.0));
                let r = Mat::from_f32(d, d, &gen::matrix(&mut rng, d, d, 1.0));
                (qs.t().mul(&qs), ks.t().mul(&ks), r)
            })
            .collect();
        let s = bench("kron_accum_solve_d16", 1, 5, || {
            let mut acc = KronRidge::new(d);
            for (qq, kk, r) in &mats {
                acc.accumulate(kk, qq, r, 1.0);
            }
            acc.solve(1e-2)
        });
        println!("{:24} {:9.4} ms  ({} samples)", s.name, s.mean_s * 1e3, samples);
        csv.row(&[s.name.clone(), format!("{}", s.mean_s), format!("{}", s.p50_s), "0".into(), "0".into()]);
    }

    // Cholesky solve at MLP-compensation size (768 kept of 1280).
    {
        let n = 640;
        let a = Mat::from_f32(n, n, &gen::spd(&mut rng, n, 0.5));
        let s = bench("cholesky_640", 1, 3, || Cholesky::new(&a).unwrap());
        let flops = (n * n * n) as f64 / 3.0;
        println!("{:24} {:9.4} ms  {:6.2} GFLOP/s", s.name, s.mean_s * 1e3, flops / s.mean_s / 1e9);
        csv.row(&[s.name.clone(), format!("{}", s.mean_s), format!("{}", s.p50_s), format!("{flops}"), format!("{:.3}", flops / s.mean_s / 1e9)]);
    }

    // Per-block execute round trip: PJRT when artifacts are built, the
    // native interpreter otherwise.
    if let Ok(coord) = corp::coordinator::Coordinator::new() {
        let cfg = corp::model::ModelConfig::by_name("vit_t").unwrap();
        let exec = coord.executor(cfg);
        let w = corp::model::WeightStore::init(cfg, 1);
        let gen_v = corp::data::VisionGen::new(0);
        let (tokens, _) = gen_v.batch(corp::data::Split::Eval, 0, 1);
        let x = exec.embed(&w, &tokens, 1).unwrap();
        let s = bench("block_vit_t_b1", 3, 30, || exec.block(&w, 0, &x, 1).unwrap());
        println!("{:24} {:9.4} ms  (per-block execute round trip)", s.name, s.mean_s * 1e3);
        csv.row(&[s.name.clone(), format!("{}", s.mean_s), format!("{}", s.p50_s), "0".into(), "0".into()]);
    } else {
        eprintln!("block round-trip microbench skipped: runtime unavailable");
    }

    csv.flush().unwrap();
}
