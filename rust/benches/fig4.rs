//! Bench: regenerates the paper's fig4 (see DESIGN.md §6).
//! Scale with CORP_BENCH_MODE={smoke,fast,full}; CSV lands in results/.

fn main() {
    let mut coord = corp::coordinator::Coordinator::new().expect("runtime (run `make artifacts` first)");
    corp::bench_tables::tables::fig4(&mut coord).expect("fig4");
}
