//! Bench: regenerates Tables 4a (vs SNOWS/GRAIL) and 4b (vs DC-ViT).

fn main() {
    let mut coord = corp::coordinator::Coordinator::new().expect("runtime (run `make artifacts` first)");
    corp::bench_tables::tables::table4a(&mut coord).expect("table4a");
    corp::bench_tables::tables::table4b(&mut coord).expect("table4b");
}
