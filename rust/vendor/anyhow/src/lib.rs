//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build environment has no registry access, so the subset of
//! `anyhow` this workspace actually uses is implemented here with the same
//! names and semantics:
//!
//! * [`Error`] — an opaque error carrying a context chain (outermost first);
//! * [`Result`] — `Result<T, Error>` alias;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` (for both
//!   std errors and [`Error`] itself) and on `Option`;
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] macros;
//! * `From<E: std::error::Error>` so `?` converts any std error.
//!
//! `{e}` prints the outermost message, `{e:#}` the full chain joined with
//! `": "`, and `{e:?}` an anyhow-style "Caused by" listing.

use std::error::Error as StdError;
use std::fmt;

/// Result alias defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Opaque error value: a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in self.chain.iter().skip(1) {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, exactly
// like the real anyhow — that is what keeps this blanket conversion coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

// Private extension trait unifying "things convertible into Error" so the
// Context impl below covers both `Result<T, E: StdError>` and
// `Result<T, Error>`. Coherent because `Error` does not implement the
// foreign `std::error::Error` trait (the same device the real anyhow uses).
mod ext {
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => { $crate::Error::msg(format!($($t)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*).into()) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err()).context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn context_on_already_converted_error() {
        let base: Result<()> = Err(anyhow!("inner"));
        let e = base.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("absent").unwrap_err();
        assert_eq!(format!("{e}"), "absent");
        let some: Option<u32> = Some(3);
        assert_eq!(some.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with code {}", 7);
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "failed with code 7");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.root_cause(), "x = 3");
    }
}
