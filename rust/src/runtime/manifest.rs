//! Artifact manifest parsing (`artifacts/manifest.json`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One graph input/output description.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
}

/// Parsed manifest, indexed by artifact name.
#[derive(Debug, Default)]
pub struct Manifest {
    by_name: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).context("parsing manifest json")?;
        let arts = root
            .get("artifacts")
            .as_arr()
            .context("manifest missing 'artifacts' array")?;
        let mut by_name = HashMap::new();
        for a in arts {
            let name = a.get("name").as_str().context("artifact missing name")?.to_string();
            let file = a.get("file").as_str().context("artifact missing file")?.to_string();
            let mut inputs = Vec::new();
            for i in a.get("inputs").as_arr().context("artifact missing inputs")? {
                let shape = i
                    .get("shape")
                    .as_arr()
                    .context("input missing shape")?
                    .iter()
                    .map(|v| v.as_usize().context("bad dim"))
                    .collect::<Result<Vec<_>>>()?;
                inputs.push(IoSpec {
                    name: i.get("name").as_str().context("input missing name")?.to_string(),
                    shape,
                    dtype: i.get("dtype").as_str().unwrap_or("f32").to_string(),
                });
            }
            let outputs = a
                .get("outputs")
                .as_arr()
                .context("artifact missing outputs")?
                .iter()
                .map(|v| v.as_str().map(String::from).context("bad output name"))
                .collect::<Result<Vec<_>>>()?;
            if by_name.insert(name.clone(), ArtifactSpec { name: name.clone(), file, inputs, outputs }).is_some() {
                bail!("duplicate artifact '{name}' in manifest");
            }
        }
        Ok(Self { by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.by_name.get(name)
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"artifacts": [
        {"name": "block_a", "file": "block_a.hlo.txt",
         "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"},
                    {"name": "ids", "shape": [2], "dtype": "i32"}],
         "outputs": ["y"]}
    ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("block_a").unwrap();
        assert_eq!(a.file, "block_a.hlo.txt");
        assert_eq!(a.inputs[0], IoSpec { name: "x".into(), shape: vec![2, 3], dtype: "f32".into() });
        assert_eq!(a.inputs[1].dtype, "i32");
        assert_eq!(a.outputs, vec!["y"]);
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let dup = r#"{"artifacts": [
            {"name": "a", "file": "f", "inputs": [], "outputs": []},
            {"name": "a", "file": "g", "inputs": [], "outputs": []}]}"#;
        assert!(Manifest::parse(dup).is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert!(m.len() > 100, "expected full artifact set, got {}", m.len());
            assert!(m.get("train_vit_t").is_some());
        }
    }
}
