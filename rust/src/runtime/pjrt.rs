//! PJRT backend: compiles and executes the AOT HLO-text artifacts through
//! the `xla` crate. Only compiled under `--cfg pjrt_backend` (set via
//! RUSTFLAGS), which additionally requires the vendored `xla` dependency to
//! be declared in Cargo.toml — the crate exists only in the vendored build
//! environment, which is why this is a rustc cfg and not a cargo feature
//! (`--all-features` must stay buildable offline).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::{ArtifactSpec, Input, IoSpec};
use crate::tensor::Tensor;

/// A live PJRT client plus the per-process executable cache.
///
/// The cache is behind a `Mutex` (not `RefCell`) so that sharing a
/// `Runtime` across the serving engine's worker threads is not blocked by
/// this type — whether the backend is actually `Sync` then hinges on the
/// vendored `xla` crate's client/executable types (see the ROADMAP's PJRT
/// gating follow-ups; the engine itself is exercised on the native
/// backend).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn executable(
        &self,
        dir: &Path,
        spec: &ArtifactSpec,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(&spec.name) {
            return Ok(e.clone());
        }
        let path = dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(to_anyhow)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(to_anyhow)?;
        let rc = Arc::new(exe);
        self.cache.lock().unwrap().insert(spec.name.clone(), rc.clone());
        Ok(rc)
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute an artifact. `inputs` must match the manifest spec in order,
    /// shape, and dtype. Returns the output tuple elements as f32 tensors.
    pub fn execute(
        &self,
        dir: &Path,
        spec: &ArtifactSpec,
        inputs: &[Input<'_>],
    ) -> Result<Vec<Tensor>> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{}': got {} inputs, manifest expects {}",
                spec.name,
                inputs.len(),
                spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (inp, ispec) in inputs.iter().zip(&spec.inputs) {
            literals.push(to_literal(inp, ispec, &spec.name)?);
        }
        let exe = self.executable(dir, spec)?;
        let result = exe.execute::<xla::Literal>(&literals).map_err(to_anyhow)?;
        // Graphs are lowered with return_tuple=True.
        let mut tuple = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        let elems = tuple.decompose_tuple().map_err(to_anyhow)?;
        let mut out = Vec::with_capacity(elems.len());
        for lit in elems {
            out.push(literal_to_tensor(&lit)?);
        }
        Ok(out)
    }
}

fn to_literal(input: &Input<'_>, spec: &IoSpec, artifact: &str) -> Result<xla::Literal> {
    match input {
        Input::F32(t) => {
            if spec.dtype != "f32" {
                bail!("{artifact}/{}: expected dtype {}, got f32", spec.name, spec.dtype);
            }
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{artifact}/{}: shape {:?} != manifest {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
            let lit = xla::Literal::vec1(t.data());
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            lit.reshape(&dims).map_err(to_anyhow)
        }
        Input::I32(v, shape) => {
            if spec.dtype != "i32" {
                bail!("{artifact}/{}: expected dtype {}, got i32", spec.name, spec.dtype);
            }
            if shape != &spec.shape {
                bail!(
                    "{artifact}/{}: shape {:?} != manifest {:?}",
                    spec.name,
                    shape,
                    spec.shape
                );
            }
            let lit = xla::Literal::vec1(*v);
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            lit.reshape(&dims).map_err(to_anyhow)
        }
        Input::Scalar(v) => {
            if !spec.shape.is_empty() {
                bail!("{artifact}/{}: scalar provided for non-scalar input", spec.name);
            }
            Ok(xla::Literal::from(*v))
        }
        Input::Q8 { .. } => {
            // `_w8` artifact names never appear in an AOT manifest; int8
            // weights are a native-interpreter feature.
            bail!("{artifact}/{}: int8 weights are not supported by the PJRT backend", spec.name)
        }
    }
}

fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(to_anyhow)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = lit.to_vec().map_err(to_anyhow)?;
    Ok(Tensor::from_vec(&dims, data))
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}
