//! Execution runtime: native pure-Rust interpreter + optional PJRT backend.
//!
//! Two backends serve the same artifact-name interface:
//!
//! * [`native`] (always available) — a pure-Rust interpreter for the whole
//!   artifact family (`embed_* / block_* / blockcap_* / mlponly_* / fwd_* /
//!   dec_* / head_* / lnf_* / evloss_* / train_*`), built on the packed
//!   parallel linalg kernels. Needs no `artifacts/` directory and no
//!   external crates, so `cargo build && cargo test` work offline.
//! * `pjrt` (behind `--cfg pjrt_backend`, vendored environments only) — the
//!   original path that loads the AOT HLO-text artifacts written by
//!   `python/compile/aot.py` and executes them through the `xla` crate.
//!   Selected automatically when the cfg is on and `artifacts/manifest.json`
//!   exists; the manifest then also validates input shapes/dtypes per
//!   artifact.
//!
//! Python is never touched here — this *is* the request path.

pub mod manifest;
pub mod native;
#[cfg(pjrt_backend)]
pub mod pjrt;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::tensor::Tensor;
// (Input conversion and executable caching for the PJRT path live in
// `pjrt.rs`; the enum itself is shared.)
pub use manifest::{ArtifactSpec, IoSpec, Manifest};

/// Locate the artifacts directory: `CORP_ARTIFACTS` env var or
/// `<repo>/artifacts` relative to the crate root.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CORP_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A loaded runtime bound to one artifacts directory (which may be absent —
/// the native backend synthesizes everything it needs from artifact names).
///
/// The default (native) runtime is `Sync`: the serving engine shares one
/// `Runtime` across its worker threads, so the execution counter is an
/// atomic rather than a cell.
pub struct Runtime {
    dir: PathBuf,
    manifest: Manifest,
    /// Cumulative number of executions (telemetry for the serve engine).
    exec_count: AtomicU64,
    #[cfg(pjrt_backend)]
    pjrt: Option<pjrt::PjrtBackend>,
}

impl Runtime {
    /// Bind to `dir`, parsing `manifest.json` when present. With the PJRT
    /// backend compiled in (`--cfg pjrt_backend`) and a manifest, artifact
    /// execution goes through PJRT; otherwise the native interpreter serves
    /// every request.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let manifest = if mpath.exists() {
            Manifest::load(&mpath)
                .with_context(|| format!("loading manifest from {}", dir.display()))?
        } else {
            Manifest::default()
        };
        #[cfg(pjrt_backend)]
        let pjrt = if manifest.is_empty() { None } else { Some(pjrt::PjrtBackend::new()?) };
        Ok(Self {
            dir,
            manifest,
            exec_count: AtomicU64::new(0),
            #[cfg(pjrt_backend)]
            pjrt,
        })
    }

    /// Runtime over the default artifacts directory (see `make artifacts`).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Whether `name` can be executed — present in the manifest, or
    /// interpretable by the native backend.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.manifest.get(name).is_some() || native::supports(name)
    }

    pub fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }

    /// Whether this runtime prefers the fixed shapes its AOT artifacts were
    /// lowered at — true only in a `--cfg pjrt_backend` build bound to a
    /// loaded manifest. The native interpreter synthesizes any batch size
    /// from the artifact name, so exact-size dispatch is free there; a
    /// PJRT-backed runtime would silently fall back to the interpreter for
    /// shapes missing from the manifest, so serving policies (dispatch
    /// selection in `serve`, the fused path in `serve::measure`) consult
    /// this and keep the padded fixed-shape path instead.
    pub fn prefers_fixed_shapes(&self) -> bool {
        cfg!(pjrt_backend) && !self.manifest.is_empty()
    }

    /// Paged-KV decode dispatch of a `dec_*` artifact — native backend
    /// only: each live example's cache rides a block-table view and the new
    /// K/V rows are appended into pool blocks in place, so no cache slabs
    /// cross the call. Fixed-shape backends never reach here —
    /// `DecodeMode::resolve` collapses them to prefill-per-step before a
    /// KV-cache plan exists. Counts as one execution.
    pub(crate) fn execute_decode_paged(
        &self,
        name: &str,
        ids: &[i32],
        past: &[i32],
        fresh: &[i32],
        seqs: &[native::forward::PagedKv],
        params: &[Input<'_>],
    ) -> Result<Tensor> {
        let out = native::execute_decode_paged(name, ids, past, fresh, seqs, params)
            .with_context(|| format!("native paged decode of artifact '{name}'"))?;
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }

    /// Execute `name` on the selected backend. `inputs` follow the canonical
    /// parameter order of the artifact (data inputs first, then parameters
    /// in `param_spec` order). Returns the output tuple elements as f32
    /// tensors.
    pub fn execute(&self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<Tensor>> {
        #[cfg(pjrt_backend)]
        if let (Some(backend), Some(spec)) = (&self.pjrt, self.manifest.get(name)) {
            let out = backend.execute(&self.dir, spec, inputs)?;
            self.exec_count.fetch_add(1, Ordering::Relaxed);
            return Ok(out);
        }
        let out = native::execute(name, inputs)
            .with_context(|| format!("native execute of artifact '{name}'"))?;
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }
}

/// An input value for [`Runtime::execute`].
pub enum Input<'a> {
    F32(&'a Tensor),
    I32(&'a [i32], Vec<usize>),
    Scalar(f32),
    /// An int8 weight-quantized matrix (per-output-channel scales), consumed
    /// only by the `_w8` fused forward/decode artifacts in parameter slots
    /// whose `param_spec` name is a block GEMM projection. Native backend
    /// only — the PJRT path never sees `_w8` names.
    Q8 { data: &'a [i8], scales: &'a [f32], din: usize, dout: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_without_artifacts_uses_native() {
        let dir = std::env::temp_dir().join("corp_no_artifacts_here");
        let rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.manifest().len(), 0);
        assert!(rt.has_artifact("embed_vit_t_b16"));
        assert!(rt.has_artifact("train_gpt_s"));
        assert!(rt.has_artifact("dec_gpt_s_q32_o512_b2"));
        // Int8 weight-quantized serving variants of the fused paths.
        assert!(rt.has_artifact("fwd_gpt_s_q32_o512_b4_w8"));
        assert!(rt.has_artifact("dec_gpt_s_q32_o512_b2_w8"));
        assert!(!rt.has_artifact("definitely_not_an_artifact"));
        assert_eq!(rt.exec_count(), 0);
        // No manifest → shapes are synthesized per request; exact-size
        // dispatch is always available.
        assert!(!rt.prefers_fixed_shapes());
    }
}
