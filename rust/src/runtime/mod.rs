//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! Manifest-driven: `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) records every artifact's input names/shapes and
//! output names; the [`Runtime`] validates tensors against that spec,
//! compiles executables lazily, and caches them for the life of the process.
//! Python is never touched here — this *is* the request path.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
pub use manifest::{ArtifactSpec, IoSpec, Manifest};

/// Locate the artifacts directory: `CORP_ARTIFACTS` env var or
/// `<repo>/artifacts` relative to the crate root.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CORP_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A loaded PJRT runtime bound to one artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative number of executions (telemetry for the serve engine).
    exec_count: RefCell<u64>,
}

impl Runtime {
    /// Create a CPU PJRT client and parse the manifest in `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    /// Runtime over the default artifacts directory (see `make artifacts`).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.manifest.get(name).is_some()
    }

    pub fn exec_count(&self) -> u64 {
        *self.exec_count.borrow()
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(to_anyhow)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(to_anyhow)?;
        let rc = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute `name`. `inputs` must match the manifest spec in order,
    /// shape, and dtype. Returns the output tuple elements as f32 tensors.
    pub fn execute(&self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}': got {} inputs, manifest expects {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (inp, ispec) in inputs.iter().zip(&spec.inputs) {
            literals.push(inp.to_literal(ispec, name)?);
        }
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&literals).map_err(to_anyhow)?;
        *self.exec_count.borrow_mut() += 1;
        let mut tuple = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        // Graphs are lowered with return_tuple=True.
        let elems = tuple.decompose_tuple().map_err(to_anyhow)?;
        let mut out = Vec::with_capacity(elems.len());
        for lit in elems {
            out.push(literal_to_tensor(&lit)?);
        }
        Ok(out)
    }
}

/// An input value for [`Runtime::execute`].
pub enum Input<'a> {
    F32(&'a Tensor),
    I32(&'a [i32], Vec<usize>),
    Scalar(f32),
}

impl<'a> Input<'a> {
    fn to_literal(&self, spec: &IoSpec, artifact: &str) -> Result<xla::Literal> {
        match self {
            Input::F32(t) => {
                if spec.dtype != "f32" {
                    bail!("{artifact}/{}: expected dtype {}, got f32", spec.name, spec.dtype);
                }
                if t.shape() != spec.shape.as_slice() {
                    bail!(
                        "{artifact}/{}: shape {:?} != manifest {:?}",
                        spec.name,
                        t.shape(),
                        spec.shape
                    );
                }
                let lit = xla::Literal::vec1(t.data());
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(to_anyhow)
            }
            Input::I32(v, shape) => {
                if spec.dtype != "i32" {
                    bail!("{artifact}/{}: expected dtype {}, got i32", spec.name, spec.dtype);
                }
                if shape != &spec.shape {
                    bail!(
                        "{artifact}/{}: shape {:?} != manifest {:?}",
                        spec.name,
                        shape,
                        spec.shape
                    );
                }
                let lit = xla::Literal::vec1(*v);
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(to_anyhow)
            }
            Input::Scalar(v) => {
                if !spec.shape.is_empty() {
                    bail!("{artifact}/{}: scalar provided for non-scalar input", spec.name);
                }
                Ok(xla::Literal::from(*v))
            }
        }
    }
}

fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(to_anyhow)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = lit.to_vec().map_err(to_anyhow)?;
    Ok(Tensor::from_vec(&dims, data))
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}
