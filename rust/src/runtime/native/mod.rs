//! Pure-Rust interpreter for the AOT artifact family.
//!
//! Artifact names encode everything the interpreter needs
//! (`block_vit_b_q16_o384_b16` → model config, pruned dims, batch), and the
//! input convention is shared with the PJRT path: data tensors first, then
//! parameters in canonical `param_spec` order. The math mirrors
//! `python/compile/model.py` / `kernels/ref.py` exactly (tanh-GELU,
//! layernorm ε = 1e-6, dense-head 1/√dh logit scale, causal masking for
//! GPT), so weights trained or pruned under either backend are
//! interchangeable.
//!
//! Heavy lifting runs on the packed parallel linalg kernels; batches fan
//! out per example over the worker pool. The `train_*` artifacts are served
//! by a hand-written reverse-mode pass (see `train`) driving the same
//! Adam update as the JAX graph.

pub(crate) mod forward;
pub(crate) mod train;

use anyhow::{bail, Context, Result};

use crate::model::ModelConfig;
use crate::runtime::Input;
use crate::tensor::Tensor;

/// A parsed artifact name. (Not `Copy`: the layered fused-forward variant
/// carries per-layer dim vectors.)
#[derive(Clone, Debug)]
pub(crate) enum Op {
    Embed { cfg: &'static ModelConfig, b: usize },
    Head { cfg: &'static ModelConfig, b: usize },
    Lnf { cfg: &'static ModelConfig, b: usize },
    Block { cfg: &'static ModelConfig, dqk: usize, o: usize, b: usize },
    BlockCap { cfg: &'static ModelConfig, b: usize },
    /// Fused full forward at pruned dims (the serving fast path). `w8`
    /// (name suffix `_w8`) selects the int8 weight-quantized variant: the
    /// six block GEMM projections arrive as [`Input::Q8`] instead of f32.
    Forward { cfg: &'static ModelConfig, dqk: usize, o: usize, b: usize, w8: bool },
    /// Fused full forward at *per-layer* pruned dims
    /// (`fwd_vit_t_qv16-16-12_ov192-200-88_b8`) — the allocator's
    /// non-uniform stores. Native-only; `w8` as in [`Op::Forward`].
    ForwardLayered { cfg: &'static ModelConfig, dqk: Vec<usize>, o: Vec<usize>, b: usize, w8: bool },
    /// Incremental KV-cached decode at pruned dims (autoregressive serving);
    /// `w8` as in [`Op::Forward`].
    Decode { cfg: &'static ModelConfig, dqk: usize, o: usize, b: usize, w8: bool },
    MlpOnly { cfg: &'static ModelConfig, o: usize, b: usize },
    EvLoss { cfg: &'static ModelConfig },
    Train { cfg: &'static ModelConfig },
}

fn tail_num<'s>(s: &'s str, sep: &str) -> Option<(&'s str, usize)> {
    let (head, num) = s.rsplit_once(sep)?;
    num.parse().ok().map(|n| (head, n))
}

/// Like [`tail_num`] but for a dash-joined per-layer dim list
/// (`..._qv16-16-12` → `[16, 16, 12]`). Empty lists fail the parse.
fn tail_dims<'s>(s: &'s str, sep: &str) -> Option<(&'s str, Vec<usize>)> {
    let (head, list) = s.rsplit_once(sep)?;
    let dims: Option<Vec<usize>> = list.split('-').map(|t| t.parse().ok()).collect();
    match dims {
        Some(d) if !d.is_empty() => Some((head, d)),
        _ => None,
    }
}

pub(crate) fn parse(name: &str) -> Option<Op> {
    // Longest prefixes first: "block_" is a prefix of "blockcap_".
    if let Some(rest) = name.strip_prefix("blockcap_") {
        let (m, b) = tail_num(rest, "_b")?;
        return ModelConfig::by_name(m).map(|cfg| Op::BlockCap { cfg, b });
    }
    if let Some(rest) = name.strip_prefix("block_") {
        let (rest, b) = tail_num(rest, "_b")?;
        let (rest, o) = tail_num(rest, "_o")?;
        let (m, dqk) = tail_num(rest, "_q")?;
        return ModelConfig::by_name(m).map(|cfg| Op::Block { cfg, dqk, o, b });
    }
    if let Some(rest) = name.strip_prefix("fwd_") {
        let (rest, w8) = match rest.strip_suffix("_w8") {
            Some(r) => (r, true),
            None => (rest, false),
        };
        let (rest, b) = tail_num(rest, "_b")?;
        // Layered form first: `_qv`/`_ov` carry dash-joined per-layer dims.
        // (Unambiguous with the uniform `_q`/`_o` form — a `_o` rsplit on a
        // layered name would leave a leading `v`, which fails the numeric
        // parse.)
        if rest.contains("_ov") {
            let (rest, o) = tail_dims(rest, "_ov")?;
            let (m, dqk) = tail_dims(rest, "_qv")?;
            return ModelConfig::by_name(m).and_then(|cfg| {
                (dqk.len() == cfg.layers && o.len() == cfg.layers)
                    .then_some(Op::ForwardLayered { cfg, dqk, o, b, w8 })
            });
        }
        let (rest, o) = tail_num(rest, "_o")?;
        let (m, dqk) = tail_num(rest, "_q")?;
        return ModelConfig::by_name(m).map(|cfg| Op::Forward { cfg, dqk, o, b, w8 });
    }
    if let Some(rest) = name.strip_prefix("dec_") {
        let (rest, w8) = match rest.strip_suffix("_w8") {
            Some(r) => (r, true),
            None => (rest, false),
        };
        let (rest, b) = tail_num(rest, "_b")?;
        let (rest, o) = tail_num(rest, "_o")?;
        let (m, dqk) = tail_num(rest, "_q")?;
        return ModelConfig::by_name(m).map(|cfg| Op::Decode { cfg, dqk, o, b, w8 });
    }
    if let Some(rest) = name.strip_prefix("mlponly_") {
        let (rest, b) = tail_num(rest, "_b")?;
        let (m, o) = tail_num(rest, "_o")?;
        return ModelConfig::by_name(m).map(|cfg| Op::MlpOnly { cfg, o, b });
    }
    if let Some(rest) = name.strip_prefix("embed_") {
        let (m, b) = tail_num(rest, "_b")?;
        return ModelConfig::by_name(m).map(|cfg| Op::Embed { cfg, b });
    }
    if let Some(rest) = name.strip_prefix("head_") {
        let (m, b) = tail_num(rest, "_b")?;
        return ModelConfig::by_name(m).map(|cfg| Op::Head { cfg, b });
    }
    if let Some(rest) = name.strip_prefix("lnf_") {
        let (m, b) = tail_num(rest, "_b")?;
        return ModelConfig::by_name(m).map(|cfg| Op::Lnf { cfg, b });
    }
    if let Some(rest) = name.strip_prefix("evloss_") {
        return ModelConfig::by_name(rest).map(|cfg| Op::EvLoss { cfg });
    }
    if let Some(rest) = name.strip_prefix("train_") {
        return ModelConfig::by_name(rest).map(|cfg| Op::Train { cfg });
    }
    None
}

/// Whether the native backend can interpret `name`.
pub fn supports(name: &str) -> bool {
    parse(name).is_some()
}

/// Execute the paged-cache variant of a `dec_*` artifact: same math as the
/// slab interpreter ([`forward::run_decode`]) but each live example's K/V
/// lives in pool blocks addressed through a block-table view
/// ([`forward::PagedKv`]) and the new rows are appended in place — no cache
/// slabs enter or leave the call. `params` carries only the parameter list
/// (`param_spec_at` order); returns the logits `[b, m, vocab]`.
pub(crate) fn execute_decode_paged(
    name: &str,
    ids: &[i32],
    past: &[i32],
    fresh: &[i32],
    seqs: &[forward::PagedKv],
    params: &[Input<'_>],
) -> Result<Tensor> {
    match parse(name) {
        Some(Op::Decode { cfg, dqk, o, b, w8 }) => {
            let mut inp = In::new(params);
            let mut out =
                forward::run_decode_paged(cfg, dqk, o, b, w8, ids, past, fresh, seqs, &mut inp)
                    .with_context(|| format!("interpreting '{name}' (paged)"))?;
            Ok(out.remove(0))
        }
        _ => bail!("'{name}' is not a dec_* artifact (paged decode)"),
    }
}

/// Execute an artifact natively.
pub fn execute(name: &str, inputs: &[Input<'_>]) -> Result<Vec<Tensor>> {
    let op = match parse(name) {
        Some(op) => op,
        None => bail!("unknown artifact '{name}' (no manifest entry, not native-interpretable)"),
    };
    let mut inp = In::new(inputs);
    match op {
        Op::Embed { cfg, b } => forward::run_embed(cfg, b, &mut inp),
        Op::Head { cfg, b } => forward::run_head(cfg, b, &mut inp),
        Op::Lnf { cfg, b } => forward::run_lnf(cfg, b, &mut inp),
        Op::Block { cfg, dqk, o, b } => forward::run_block(cfg, dqk, o, b, false, &mut inp),
        Op::BlockCap { cfg, b } => {
            forward::run_block(cfg, cfg.dh(), cfg.mlp, b, true, &mut inp)
        }
        Op::Forward { cfg, dqk, o, b, w8 } => forward::run_forward(cfg, dqk, o, b, w8, &mut inp),
        Op::ForwardLayered { cfg, dqk, o, b, w8 } => {
            forward::run_forward_layered(cfg, &dqk, &o, b, w8, &mut inp)
        }
        Op::Decode { cfg, dqk, o, b, w8 } => forward::run_decode(cfg, dqk, o, b, w8, &mut inp),
        Op::MlpOnly { cfg, o, b } => forward::run_mlponly(cfg, o, b, &mut inp),
        Op::EvLoss { cfg } => forward::run_evloss(cfg, &mut inp),
        Op::Train { cfg } => train::run_train(cfg, &mut inp),
    }
    .with_context(|| format!("interpreting '{name}'"))
}

/// Sequential input cursor: artifacts consume data inputs first, then
/// parameters in canonical spec order.
pub(crate) struct In<'i, 'a> {
    items: &'i [Input<'a>],
    pos: usize,
}

impl<'i, 'a> In<'i, 'a> {
    pub(crate) fn new(items: &'i [Input<'a>]) -> Self {
        Self { items, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.items.len() - self.pos
    }

    pub(crate) fn tensor(&mut self) -> Result<&'a Tensor> {
        let i = self.pos;
        self.pos += 1;
        match self.items.get(i) {
            Some(Input::F32(t)) => Ok(*t),
            Some(_) => bail!("input {i}: expected an f32 tensor"),
            None => bail!("input {i}: missing (have {})", self.items.len()),
        }
    }

    /// Next f32 tensor's raw data, validated against an expected length.
    pub(crate) fn slice(&mut self, expect_len: usize, what: &str) -> Result<&'a [f32]> {
        let t = self.tensor().with_context(|| format!("parameter '{what}'"))?;
        if t.len() != expect_len {
            bail!("parameter '{what}': {} values, expected {expect_len}", t.len());
        }
        Ok(t.data())
    }

    /// Next int8 weight-quantized matrix, validated against the expected
    /// `[din, dout]` shape of the named projection.
    pub(crate) fn q8(
        &mut self,
        din: usize,
        dout: usize,
        what: &str,
    ) -> Result<(&'a [i8], &'a [f32])> {
        let i = self.pos;
        self.pos += 1;
        match self.items.get(i) {
            Some(Input::Q8 { data, scales, din: d, dout: n }) => {
                if (*d, *n) != (din, dout) {
                    bail!("parameter '{what}': q8 shape [{d}, {n}], expected [{din}, {dout}]");
                }
                if data.len() != din * dout || scales.len() != dout {
                    bail!(
                        "parameter '{what}': {} codes / {} scales for [{din}, {dout}]",
                        data.len(),
                        scales.len()
                    );
                }
                Ok((*data, *scales))
            }
            Some(_) => bail!("input {i}: expected an int8 quantized matrix ('{what}')"),
            None => bail!("input {i}: missing (have {})", self.items.len()),
        }
    }

    pub(crate) fn ints(&mut self) -> Result<&'a [i32]> {
        let i = self.pos;
        self.pos += 1;
        match self.items.get(i) {
            Some(Input::I32(v, _)) => Ok(*v),
            Some(_) => bail!("input {i}: expected an i32 tensor"),
            None => bail!("input {i}: missing (have {})", self.items.len()),
        }
    }

    pub(crate) fn scalar(&mut self) -> Result<f32> {
        let i = self.pos;
        self.pos += 1;
        match self.items.get(i) {
            Some(Input::Scalar(v)) => Ok(*v),
            Some(_) => bail!("input {i}: expected a scalar"),
            None => bail!("input {i}: missing (have {})", self.items.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_artifact_family() {
        assert!(matches!(parse("embed_vit_t_b16"), Some(Op::Embed { b: 16, .. })));
        assert!(matches!(parse("embed_vit_t_b1"), Some(Op::Embed { b: 1, .. })));
        match parse("block_vit_b_q16_o384_b16") {
            Some(Op::Block { cfg, dqk, o, b }) => {
                assert_eq!(cfg.name, "vit_b");
                assert_eq!((dqk, o, b), (16, 384, 16));
            }
            other => panic!("bad parse: {other:?}"),
        }
        // `_b` inside the model name must not confuse the suffix parser.
        match parse("blockcap_vit_b_b16") {
            Some(Op::BlockCap { cfg, b }) => {
                assert_eq!(cfg.name, "vit_b");
                assert_eq!(b, 16);
            }
            other => panic!("bad parse: {other:?}"),
        }
        assert!(matches!(parse("mlponly_vit_t_o384_b16"), Some(Op::MlpOnly { o: 384, b: 16, .. })));
        match parse("fwd_vit_b_q16_o384_b8") {
            Some(Op::Forward { cfg, dqk, o, b, w8 }) => {
                assert_eq!(cfg.name, "vit_b");
                assert_eq!((dqk, o, b, w8), (16, 384, 8, false));
            }
            other => panic!("bad parse: {other:?}"),
        }
        match parse("dec_gpt_s_q16_o256_b4") {
            Some(Op::Decode { cfg, dqk, o, b, w8 }) => {
                assert_eq!(cfg.name, "gpt_s");
                assert_eq!((dqk, o, b, w8), (16, 256, 4, false));
            }
            other => panic!("bad parse: {other:?}"),
        }
        // `_w8` marks the int8 weight-quantized fused variants.
        match parse("fwd_gpt_s_q32_o512_b4_w8") {
            Some(Op::Forward { cfg, dqk, o, b, w8 }) => {
                assert_eq!(cfg.name, "gpt_s");
                assert_eq!((dqk, o, b, w8), (32, 512, 4, true));
            }
            other => panic!("bad parse: {other:?}"),
        }
        match parse("dec_gpt_s_q16_o256_b2_w8") {
            Some(Op::Decode { cfg, dqk, o, b, w8 }) => {
                assert_eq!(cfg.name, "gpt_s");
                assert_eq!((dqk, o, b, w8), (16, 256, 2, true));
            }
            other => panic!("bad parse: {other:?}"),
        }
        // Layered fused forward: per-layer dims, dash-joined.
        match parse("fwd_vit_t_qv16-16-12-16-16-16_ov192-200-88-192-192-192_b8") {
            Some(Op::ForwardLayered { cfg, dqk, o, b, w8 }) => {
                assert_eq!(cfg.name, "vit_t");
                assert_eq!(dqk, vec![16, 16, 12, 16, 16, 16]);
                assert_eq!(o, vec![192, 200, 88, 192, 192, 192]);
                assert_eq!((b, w8), (8, false));
            }
            other => panic!("bad parse: {other:?}"),
        }
        match parse("fwd_vit_t_qv32-32-32-32-32-32_ov384-384-384-384-384-384_b16_w8") {
            Some(Op::ForwardLayered { cfg, w8, .. }) => {
                assert_eq!(cfg.name, "vit_t");
                assert!(w8);
            }
            other => panic!("bad parse: {other:?}"),
        }
        assert!(matches!(parse("head_gpt_s_b8"), Some(Op::Head { b: 8, .. })));
        assert!(matches!(parse("lnf_vit_t_b16"), Some(Op::Lnf { .. })));
        assert!(matches!(parse("evloss_gpt_s"), Some(Op::EvLoss { .. })));
        assert!(matches!(parse("train_vit_t"), Some(Op::Train { .. })));
    }

    #[test]
    fn rejects_unknown_names() {
        assert!(parse("block_vit_t_q32_o384").is_none()); // missing batch
        assert!(parse("embed_unknown_b16").is_none());
        assert!(parse("bogus").is_none());
        assert!(!supports(""));
        // `_w8` is only meaningful on fwd_/dec_; elsewhere it breaks parse.
        assert!(parse("block_vit_t_q32_o384_b16_w8").is_none());
        assert!(parse("fwd_gpt_s_q32_o512_b4_w16").is_none());
        // Layered dim lists must match the model's layer count exactly.
        assert!(parse("fwd_vit_t_qv16-16_ov192-192_b8").is_none());
        assert!(parse("fwd_vit_t_qv16-16-12-16-16-x_ov192-192-192-192-192-192_b8").is_none());
    }
}
