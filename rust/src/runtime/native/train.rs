//! Native `train_*` artifact: hand-written reverse-mode differentiation of
//! the dense transformer plus the exact Adam update of
//! `python/compile/model.py::train_step` (β₁ = 0.9, β₂ = 0.999, ε = 1e-8,
//! bias correction at the 1-based step counter carried through the chunk).
//!
//! Input/output convention matches the AOT train graph: per-step data slabs
//! stacked on a leading K axis, then `lrs [K]`, the scalar Adam `t0`, and
//! the parameter/m/v lists in canonical spec order; outputs are
//! `params' … m' … v' … losses [K]`.
//!
//! Examples inside a step are differentiated independently (fanned out over
//! the worker pool in bounded chunks so peak memory stays at
//! `workers × |params|`) and their gradients are reduced in example order.

use anyhow::{bail, Result};

use super::forward::{
    attention_one, gather_cols, gelu, gelu_grad, layernorm, linear, scatter_cols, BlockParams,
    EmbedParams, ExampleInput, ModelParams, LN_EPS,
};
use super::In;
use crate::linalg::gemm::{dot_f32, matmul_f32, matmul_tn_f32};
use crate::model::{ModelConfig, ModelKind};
use crate::tensor::Tensor;
use crate::util::threads;

// Block parameter offsets within a layer's 16-slot spec group.
const LN1G: usize = 0;
const LN1B: usize = 1;
const WQ: usize = 2;
const BQ: usize = 3;
const WK: usize = 4;
const BK: usize = 5;
const WV: usize = 6;
const BV: usize = 7;
const WO: usize = 8;
const BO: usize = 9;
const LN2G: usize = 10;
const LN2B: usize = 11;
const W1: usize = 12;
const B1: usize = 13;
const W2: usize = 14;
const B2: usize = 15;

/// Flat slot indexing into the canonical spec order.
#[derive(Clone, Copy)]
struct SpecIdx {
    /// Number of embedding parameters (4 vit / 2 gpt).
    ne: usize,
    layers: usize,
}

impl SpecIdx {
    fn new(cfg: &ModelConfig) -> Self {
        let ne = match cfg.kind {
            ModelKind::Vit => 4,
            ModelKind::Gpt => 2,
        };
        Self { ne, layers: cfg.layers }
    }

    fn block(&self, l: usize, j: usize) -> usize {
        self.ne + l * 16 + j
    }

    fn head(&self, j: usize) -> usize {
        self.ne + self.layers * 16 + j
    }
}

/// Per-block forward tape (everything the backward pass re-reads).
struct BlockTape {
    x: Vec<f32>,
    xn: Vec<f32>,
    qf: Vec<f32>,
    kf: Vec<f32>,
    vf: Vec<f32>,
    /// Softmax probabilities, [h, n, n].
    probs: Vec<f32>,
    merged: Vec<f32>,
    y: Vec<f32>,
    yn: Vec<f32>,
    hpre: Vec<f32>,
    hidden: Vec<f32>,
}

/// Dense-block forward retaining the tape. `x` is consumed into the tape.
fn block_forward_tape(
    cfg: &ModelConfig,
    p: &BlockParams<'_>,
    x: Vec<f32>,
    causal: bool,
) -> (Vec<f32>, BlockTape) {
    let (n, d, h, dh) = (cfg.n_ctx, cfg.d, cfg.heads, cfg.dh());
    let o = cfg.mlp;
    let scale = 1.0 / (dh as f32).sqrt();

    let xn = layernorm(&x, n, d, p.ln1g, p.ln1b);
    let qf = linear(&xn, n, d, p.wq.f32(), h * dh, Some(p.bq));
    let kf = linear(&xn, n, d, p.wk.f32(), h * dh, Some(p.bk));
    let vf = linear(&xn, n, d, p.wv.f32(), h * dh, Some(p.bv));
    let mut merged = vec![0.0f32; n * h * dh];
    let mut probs_all = vec![0.0f32; h * n * n];
    for head in 0..h {
        let qh = gather_cols(&qf, n, h * dh, head * dh, dh);
        let kh = gather_cols(&kf, n, h * dh, head * dh, dh);
        let vh = gather_cols(&vf, n, h * dh, head * dh, dh);
        let (att, probs) = attention_one(&qh, &kh, &vh, n, dh, dh, scale, causal);
        scatter_cols(&mut merged, &att, n, h * dh, head * dh, dh);
        probs_all[head * n * n..(head + 1) * n * n].copy_from_slice(&probs);
    }
    let attn_out = linear(&merged, n, h * dh, p.wo.f32(), d, Some(p.bo));
    let y: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

    let yn = layernorm(&y, n, d, p.ln2g, p.ln2b);
    let hpre = linear(&yn, n, d, p.w1.f32(), o, Some(p.b1));
    let hidden: Vec<f32> = hpre.iter().map(|&v| gelu(v)).collect();
    let mlp_out = linear(&hidden, n, o, p.w2.f32(), d, Some(p.b2));
    let z: Vec<f32> = y.iter().zip(&mlp_out).map(|(a, b)| a + b).collect();
    let tape =
        BlockTape { x, xn, qf, kf, vf, probs: probs_all, merged, y, yn, hpre, hidden };
    (z, tape)
}

/// C[m,n] += A[m,k] · B[n,k]ᵀ (the `dy·Wᵀ` / `dA·Vᵀ` shape).
fn matmul_nt_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let cr = &mut c[i * n..(i + 1) * n];
        for (j, cv) in cr.iter_mut().enumerate() {
            *cv += dot_f32(ar, &b[j * k..(j + 1) * k]);
        }
    }
}

/// `db[j] += Σ_rows dy[r, j]`.
fn colsum_add(dy: &[f32], rows: usize, d: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), rows * d);
    debug_assert_eq!(db.len(), d);
    for r in 0..rows {
        let row = &dy[r * d..(r + 1) * d];
        for (b, &v) in db.iter_mut().zip(row) {
            *b += v;
        }
    }
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// LayerNorm backward; returns (dx, dγ, dβ). Statistics are recomputed from
/// the saved input (cheaper than caching them per row).
fn ln_backward(x: &[f32], g: &[f32], dy: &[f32], rows: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * d];
    let mut dg = vec![0.0f32; d];
    let mut db = vec![0.0f32; d];
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() * inv_d;
        let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() * inv_d;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let mut m1 = 0.0f32; // mean of dx̂
        let mut m2 = 0.0f32; // mean of dx̂ ⊙ x̂
        for j in 0..d {
            let xhat = (xr[j] - mu) * inv;
            let dxhat = dyr[j] * g[j];
            db[j] += dyr[j];
            dg[j] += dyr[j] * xhat;
            m1 += dxhat;
            m2 += dxhat * xhat;
        }
        m1 *= inv_d;
        m2 *= inv_d;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            let xhat = (xr[j] - mu) * inv;
            let dxhat = dyr[j] * g[j];
            dxr[j] = (dxhat - m1 - xhat * m2) * inv;
        }
    }
    (dx, dg, db)
}

/// Softmax-attention backward for one head. Returns (dq, dk, dv).
fn attn_backward_head(
    qh: &[f32],
    kh: &[f32],
    vh: &[f32],
    probs: &[f32],
    datt: &[f32],
    n: usize,
    dh: usize,
    scale: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    // dV = Pᵀ·dA
    let mut dvh = vec![0.0f32; n * dh];
    matmul_tn_f32(probs, datt, &mut dvh, n, n, dh);
    // dP = dA·Vᵀ
    let mut dp = vec![0.0f32; n * n];
    matmul_nt_acc(datt, vh, &mut dp, n, dh, n);
    // dlogits = P ⊙ (dP − rowsum(dP ⊙ P)), scaled by the logit scale.
    // Masked positions have P = 0, so their dlogits vanish automatically.
    let mut dlog = vec![0.0f32; n * n];
    for t in 0..n {
        let pr = &probs[t * n..(t + 1) * n];
        let dpr = &dp[t * n..(t + 1) * n];
        let rd: f32 = pr.iter().zip(dpr).map(|(p, v)| p * v).sum();
        let out = &mut dlog[t * n..(t + 1) * n];
        for s in 0..n {
            out[s] = pr[s] * (dpr[s] - rd) * scale;
        }
    }
    // dQ = dlogits·K ; dK = dlogitsᵀ·Q
    let mut dqh = vec![0.0f32; n * dh];
    matmul_f32(&dlog, kh, &mut dqh, n, n, dh);
    let mut dkh = vec![0.0f32; n * dh];
    matmul_tn_f32(&dlog, qh, &mut dkh, n, n, dh);
    (dqh, dkh, dvh)
}

/// Backward through one dense block; accumulates parameter gradients into
/// `grads` (flat spec slots) and returns dx for the previous block.
fn block_backward(
    cfg: &ModelConfig,
    p: &BlockParams<'_>,
    tape: &BlockTape,
    dz: &[f32],
    idx: SpecIdx,
    l: usize,
    grads: &mut [Vec<f32>],
) -> Vec<f32> {
    let (n, d, h, dh) = (cfg.n_ctx, cfg.d, cfg.heads, cfg.dh());
    let o = cfg.mlp;
    let scale = 1.0 / (dh as f32).sqrt();

    // ---- MLP: z = y + gelu(yn·W1 + b1)·W2 + b2 ----
    let mut d_hidden = vec![0.0f32; n * o];
    matmul_nt_acc(dz, p.w2.f32(), &mut d_hidden, n, d, o);
    matmul_tn_f32(&tape.hidden, dz, &mut grads[idx.block(l, W2)], n, o, d);
    colsum_add(dz, n, d, &mut grads[idx.block(l, B2)]);
    let d_hpre: Vec<f32> =
        d_hidden.iter().zip(&tape.hpre).map(|(g, &x)| g * gelu_grad(x)).collect();
    let mut d_yn = vec![0.0f32; n * d];
    matmul_nt_acc(&d_hpre, p.w1.f32(), &mut d_yn, n, o, d);
    matmul_tn_f32(&tape.yn, &d_hpre, &mut grads[idx.block(l, W1)], n, d, o);
    colsum_add(&d_hpre, n, o, &mut grads[idx.block(l, B1)]);
    let (d_y_ln, dg2, db2) = ln_backward(&tape.y, p.ln2g, &d_yn, n, d);
    add_into(&mut grads[idx.block(l, LN2G)], &dg2);
    add_into(&mut grads[idx.block(l, LN2B)], &db2);
    let mut dy = dz.to_vec(); // residual
    add_into(&mut dy, &d_y_ln);

    // ---- attention: y = x + merged·Wo + bo ----
    let mut d_merged = vec![0.0f32; n * h * dh];
    matmul_nt_acc(&dy, p.wo.f32(), &mut d_merged, n, d, h * dh);
    matmul_tn_f32(&tape.merged, &dy, &mut grads[idx.block(l, WO)], n, h * dh, d);
    colsum_add(&dy, n, d, &mut grads[idx.block(l, BO)]);

    let mut dqf = vec![0.0f32; n * h * dh];
    let mut dkf = vec![0.0f32; n * h * dh];
    let mut dvf = vec![0.0f32; n * h * dh];
    for head in 0..h {
        let qh = gather_cols(&tape.qf, n, h * dh, head * dh, dh);
        let kh = gather_cols(&tape.kf, n, h * dh, head * dh, dh);
        let vh = gather_cols(&tape.vf, n, h * dh, head * dh, dh);
        let datt = gather_cols(&d_merged, n, h * dh, head * dh, dh);
        let probs = &tape.probs[head * n * n..(head + 1) * n * n];
        let (dqh, dkh, dvh) = attn_backward_head(&qh, &kh, &vh, probs, &datt, n, dh, scale);
        scatter_cols(&mut dqf, &dqh, n, h * dh, head * dh, dh);
        scatter_cols(&mut dkf, &dkh, n, h * dh, head * dh, dh);
        scatter_cols(&mut dvf, &dvh, n, h * dh, head * dh, dh);
    }

    let mut dxn = vec![0.0f32; n * d];
    matmul_nt_acc(&dqf, p.wq.f32(), &mut dxn, n, h * dh, d);
    matmul_tn_f32(&tape.xn, &dqf, &mut grads[idx.block(l, WQ)], n, d, h * dh);
    colsum_add(&dqf, n, h * dh, &mut grads[idx.block(l, BQ)]);
    matmul_nt_acc(&dkf, p.wk.f32(), &mut dxn, n, h * dh, d);
    matmul_tn_f32(&tape.xn, &dkf, &mut grads[idx.block(l, WK)], n, d, h * dh);
    colsum_add(&dkf, n, h * dh, &mut grads[idx.block(l, BK)]);
    matmul_nt_acc(&dvf, p.wv.f32(), &mut dxn, n, h * dh, d);
    matmul_tn_f32(&tape.xn, &dvf, &mut grads[idx.block(l, WV)], n, d, h * dh);
    colsum_add(&dvf, n, h * dh, &mut grads[idx.block(l, BV)]);

    let (d_x_ln, dg1, db1) = ln_backward(&tape.x, p.ln1g, &dxn, n, d);
    add_into(&mut grads[idx.block(l, LN1G)], &dg1);
    add_into(&mut grads[idx.block(l, LN1B)], &db1);
    let mut dx = dy; // residual
    add_into(&mut dx, &d_x_ln);
    dx
}

/// Labels for one example.
enum ExampleLabel<'a> {
    Vit(i32),
    Gpt(&'a [i32]),
}

/// Forward + backward for one example. Returns (unscaled loss, gradient
/// slots). `grad_scale` folds the batch-mean factor into dlogits.
#[allow(clippy::too_many_arguments)]
fn example_grad(
    cfg: &ModelConfig,
    mp: &ModelParams<'_>,
    sizes: &[usize],
    idx: SpecIdx,
    ex: ExampleInput<'_>,
    label: ExampleLabel<'_>,
    grad_scale: f32,
) -> Result<(f32, Vec<Vec<f32>>)> {
    let (n, d) = (cfg.n_ctx, cfg.d);
    let causal = cfg.kind == ModelKind::Gpt;
    let mut grads: Vec<Vec<f32>> = sizes.iter().map(|&s| vec![0.0f32; s]).collect();

    // ---- forward with tape ----
    let x0 = match &ex {
        ExampleInput::Vit(tokens) => super::forward::vit_embed_one(cfg, &mp.embed, tokens),
        ExampleInput::Gpt(ids) => super::forward::gpt_embed_one(cfg, &mp.embed, ids)?,
    };
    let mut tapes: Vec<BlockTape> = Vec::with_capacity(cfg.layers);
    let mut x = x0;
    for bp in &mp.blocks {
        let (z, tape) = block_forward_tape(cfg, bp, x, causal);
        tapes.push(tape);
        x = z;
    }
    let xfinal = x;
    let hln = layernorm(&xfinal, n, d, mp.head_ln_g, mp.head_ln_b);
    let out_dim = match cfg.kind {
        ModelKind::Vit => cfg.classes,
        ModelKind::Gpt => cfg.vocab,
    };

    // ---- loss + head backward ----
    let mut d_hln = vec![0.0f32; n * d];
    let loss = match (&cfg.kind, &label) {
        (ModelKind::Vit, ExampleLabel::Vit(y)) => {
            let y = *y;
            if y < 0 || y as usize >= out_dim {
                bail!("label {y} out of range 0..{out_dim}");
            }
            let logits = {
                let mut lg = mp.head_b.to_vec();
                for (c, &xv) in hln[..d].iter().enumerate() {
                    let wrow = &mp.head_w[c * out_dim..(c + 1) * out_dim];
                    for (j, lv) in lg.iter_mut().enumerate() {
                        *lv += xv * wrow[j];
                    }
                }
                lg
            };
            let loss = super::forward::cross_entropy(&logits, y as usize);
            // dlogits = (softmax − onehot)·grad_scale
            let mut dl = logits;
            let m = dl.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let mut sum = 0.0f32;
            for v in dl.iter_mut() {
                *v = (*v - m).exp();
                sum += *v;
            }
            for v in dl.iter_mut() {
                *v /= sum;
            }
            dl[y as usize] -= 1.0;
            for v in dl.iter_mut() {
                *v *= grad_scale;
            }
            // head params + d(hln row 0)
            let wg = &mut grads[idx.head(2)];
            for (c, &xv) in hln[..d].iter().enumerate() {
                let wrow = &mut wg[c * out_dim..(c + 1) * out_dim];
                for (j, wv) in wrow.iter_mut().enumerate() {
                    *wv += xv * dl[j];
                }
            }
            add_into(&mut grads[idx.head(3)], &dl);
            let row0 = &mut d_hln[..d];
            for (c, rv) in row0.iter_mut().enumerate() {
                *rv = dot_f32(&mp.head_w[c * out_dim..(c + 1) * out_dim], &dl);
            }
            loss
        }
        (ModelKind::Gpt, ExampleLabel::Gpt(ys)) => {
            let logits = linear(&hln, n, d, mp.head_w, out_dim, Some(mp.head_b));
            let mut loss = 0.0f32;
            let mut dl = logits;
            for t in 0..n {
                let y = ys[t];
                if y < 0 || y as usize >= out_dim {
                    bail!("target {y} out of range 0..{out_dim}");
                }
                let row = &mut dl[t * out_dim..(t + 1) * out_dim];
                loss += super::forward::cross_entropy(row, y as usize);
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
                let mut sum = 0.0f32;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    sum += *v;
                }
                for v in row.iter_mut() {
                    *v /= sum;
                }
                row[y as usize] -= 1.0;
                for v in row.iter_mut() {
                    *v *= grad_scale;
                }
            }
            loss /= n as f32;
            matmul_tn_f32(&hln, &dl, &mut grads[idx.head(2)], n, d, out_dim);
            colsum_add(&dl, n, out_dim, &mut grads[idx.head(3)]);
            matmul_nt_acc(&dl, mp.head_w, &mut d_hln, n, out_dim, d);
            loss
        }
        _ => bail!("label kind does not match model kind"),
    };

    // ---- head layernorm backward ----
    let (mut dxf, dhg, dhb) = ln_backward(&xfinal, mp.head_ln_g, &d_hln, n, d);
    add_into(&mut grads[idx.head(0)], &dhg);
    add_into(&mut grads[idx.head(1)], &dhb);

    // ---- blocks in reverse ----
    for l in (0..cfg.layers).rev() {
        dxf = block_backward(cfg, &mp.blocks[l], &tapes[l], &dxf, idx, l, &mut grads);
    }

    // ---- embedding backward ----
    match (&mp.embed, &ex) {
        (EmbedParams::Vit { we: _, be: _, cls: _, pos: _ }, ExampleInput::Vit(tokens)) => {
            let (pn, pd) = (cfg.patches, cfg.patch_dim);
            // x0 = [cls; tokens·We + be] + pos
            add_into(&mut grads[idx_embed_pos(idx)], &dxf); // dpos += dx0
            add_into(&mut grads[2], &dxf[..d]); // dcls += row 0
            let dtok = &dxf[d..]; // rows 1..P+1, [pn, d]
            matmul_tn_f32(tokens, dtok, &mut grads[0], pn, pd, d); // dWe += tokᵀ·dx
            colsum_add(dtok, pn, d, &mut grads[1]); // dbe
        }
        (EmbedParams::Gpt { .. }, ExampleInput::Gpt(ids)) => {
            add_into(&mut grads[1], &dxf); // dpos
            let wg = &mut grads[0];
            for (t, &id) in ids.iter().enumerate() {
                let row = &mut wg[id as usize * d..(id as usize + 1) * d];
                add_into(row, &dxf[t * d..(t + 1) * d]);
            }
        }
        _ => bail!("embed params do not match input kind"),
    }

    Ok((loss, grads))
}

/// Position of `embed.pos` in the flat spec (vit: slot 3, gpt: slot 1).
fn idx_embed_pos(idx: SpecIdx) -> usize {
    idx.ne - 1
}

/// One Adam step in f32, mirroring the JAX graph bit-for-bit in structure.
fn adam_update(
    params: &mut [Vec<f32>],
    m_state: &mut [Vec<f32>],
    v_state: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    lr: f32,
    t: f32,
) {
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let bc1 = 1.0 - b1.powf(t);
    let bc2 = 1.0 - b2.powf(t);
    for i in 0..params.len() {
        let (p, mm, vv, g) = (&mut params[i], &mut m_state[i], &mut v_state[i], &grads[i]);
        for j in 0..p.len() {
            mm[j] = b1 * mm[j] + (1.0 - b1) * g[j];
            vv[j] = b2 * vv[j] + (1.0 - b2) * g[j] * g[j];
            p[j] -= lr * (mm[j] / bc1) / ((vv[j] / bc2).sqrt() + eps);
        }
    }
}

/// Execute the `train_{model}` artifact natively.
pub(crate) fn run_train(cfg: &'static ModelConfig, inp: &mut In<'_, '_>) -> Result<Vec<Tensor>> {
    let b = cfg.eval_batch();
    let n = cfg.n_ctx;
    let spec = cfg.param_spec();
    let np = spec.len();
    let sizes: Vec<usize> = spec.iter().map(|(_, s)| s.iter().product()).collect();
    let idx = SpecIdx::new(cfg);

    // ---- data inputs ----
    enum Data<'a> {
        Vit { tokens: &'a [f32], labels: &'a [i32] },
        Gpt { ids: &'a [i32], labels: &'a [i32] },
    }
    let data = match cfg.kind {
        ModelKind::Vit => {
            let tokens = inp.tensor()?;
            let labels = inp.ints()?;
            Data::Vit { tokens: tokens.data(), labels }
        }
        ModelKind::Gpt => {
            let ids = inp.ints()?;
            let labels = inp.ints()?;
            Data::Gpt { ids, labels }
        }
    };
    let lrs = inp.tensor()?;
    let k_steps = lrs.len();
    if k_steps == 0 {
        bail!("train chunk with zero steps");
    }
    let t0 = inp.scalar()?;
    // Validate slab sizes against K.
    match &data {
        Data::Vit { tokens, labels } => {
            let per = b * cfg.patches * cfg.patch_dim;
            if tokens.len() != k_steps * per || labels.len() != k_steps * b {
                bail!(
                    "train data sizes (tokens {}, labels {}) do not match K={k_steps} B={b}",
                    tokens.len(),
                    labels.len()
                );
            }
        }
        Data::Gpt { ids, labels } => {
            if ids.len() != k_steps * b * n || labels.len() != k_steps * b * n {
                bail!(
                    "train data sizes (ids {}, labels {}) do not match K={k_steps} B={b} n={n}",
                    ids.len(),
                    labels.len()
                );
            }
        }
    }

    // ---- parameter / optimizer state (owned, updated in place) ----
    let mut params: Vec<Vec<f32>> = Vec::with_capacity(np);
    for ((name, _), &len) in spec.iter().zip(&sizes) {
        params.push(inp.slice(len, name)?.to_vec());
    }
    let mut m_state: Vec<Vec<f32>> = Vec::with_capacity(np);
    for ((name, _), &len) in spec.iter().zip(&sizes) {
        m_state.push(inp.slice(len, &format!("adam_m.{name}"))?.to_vec());
    }
    let mut v_state: Vec<Vec<f32>> = Vec::with_capacity(np);
    for ((name, _), &len) in spec.iter().zip(&sizes) {
        v_state.push(inp.slice(len, &format!("adam_v.{name}"))?.to_vec());
    }
    if inp.remaining() != 0 {
        bail!("train artifact: {} unconsumed inputs", inp.remaining());
    }

    // ---- the chunk loop ----
    let mut losses = Vec::with_capacity(k_steps);
    for i in 0..k_steps {
        let views: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        let mp = ModelParams::from_slices(cfg, &views);
        let grad_scale = match cfg.kind {
            ModelKind::Vit => 1.0 / b as f32,
            ModelKind::Gpt => 1.0 / (b * n) as f32,
        };

        let mut grads: Vec<Vec<f32>> = sizes.iter().map(|&s| vec![0.0f32; s]).collect();
        let mut step_loss = 0.0f32;
        // Bounded-memory fan-out: at most `workers` example gradients alive.
        let chunk = threads::threads().clamp(1, 8).min(b);
        let mut e0 = 0;
        while e0 < b {
            let e1 = (e0 + chunk).min(b);
            let results: Vec<Result<(f32, Vec<Vec<f32>>)>> =
                threads::parallel_map(e1 - e0, |j| {
                    let e = e0 + j;
                    let (ex, label) = match &data {
                        Data::Vit { tokens, labels } => {
                            let per = cfg.patches * cfg.patch_dim;
                            let base = (i * b + e) * per;
                            (
                                ExampleInput::Vit(&tokens[base..base + per]),
                                ExampleLabel::Vit(labels[i * b + e]),
                            )
                        }
                        Data::Gpt { ids, labels } => {
                            let base = (i * b + e) * n;
                            (
                                ExampleInput::Gpt(&ids[base..base + n]),
                                ExampleLabel::Gpt(&labels[base..base + n]),
                            )
                        }
                    };
                    example_grad(cfg, &mp, &sizes, idx, ex, label, grad_scale)
                });
            for r in results {
                let (l, g) = r?;
                step_loss += l;
                for (acc, gi) in grads.iter_mut().zip(&g) {
                    add_into(acc, gi);
                }
            }
            e0 = e1;
        }
        step_loss /= b as f32;
        losses.push(step_loss);
        adam_update(&mut params, &mut m_state, &mut v_state, &grads, lrs.data()[i], t0 + i as f32);
    }

    // ---- outputs: params', m', v', losses ----
    let mut out = Vec::with_capacity(3 * np + 1);
    for ((_, shape), p) in spec.iter().zip(params) {
        out.push(Tensor::from_vec(shape, p));
    }
    for ((_, shape), p) in spec.iter().zip(m_state) {
        out.push(Tensor::from_vec(shape, p));
    }
    for ((_, shape), p) in spec.iter().zip(v_state) {
        out.push(Tensor::from_vec(shape, p));
    }
    out.push(Tensor::from_vec(&[k_steps], losses));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::prop::gen;
    use crate::util::Pcg64;

    #[test]
    fn ln_backward_matches_finite_difference() {
        let mut rng = Pcg64::new(5);
        let (rows, d) = (2, 6);
        let x = gen::matrix(&mut rng, rows, d, 1.0);
        let g = gen::matrix(&mut rng, 1, d, 0.5);
        let dy = gen::matrix(&mut rng, rows, d, 1.0);
        let (dx, dg, db) = ln_backward(&x, &g, &dy, rows, d);
        // Scalar objective L = Σ dy ⊙ ln(x); check ∂L/∂x numerically.
        let beta = vec![0.0f32; d];
        let f = |xv: &[f32], gv: &[f32]| -> f32 {
            let out = layernorm(xv, rows, d, gv, &beta);
            out.iter().zip(&dy).map(|(o, y)| o * y).sum()
        };
        let eps = 1e-2f32;
        for i in 0..rows * d {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (f(&xp, &g) - f(&xm, &g)) / (2.0 * eps);
            assert!((dx[i] - fd).abs() < 2e-2 * (1.0 + fd.abs()), "dx[{i}]: {} vs {fd}", dx[i]);
        }
        for j in 0..d {
            let mut gp = g.clone();
            gp[j] += eps;
            let mut gm = g.clone();
            gm[j] -= eps;
            let fd = (f(&x, &gp) - f(&x, &gm)) / (2.0 * eps);
            assert!((dg[j] - fd).abs() < 2e-2 * (1.0 + fd.abs()), "dg[{j}]");
        }
        // dβ is just Σ dy rows.
        for j in 0..d {
            let want: f32 = (0..rows).map(|r| dy[r * d + j]).sum();
            assert!((db[j] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_attention_backward_matches_finite_difference() {
        let mut rng = Pcg64::new(9);
        let (n, dh) = (4, 3);
        let q = gen::matrix(&mut rng, n, dh, 0.8);
        let k = gen::matrix(&mut rng, n, dh, 0.8);
        let v = gen::matrix(&mut rng, n, dh, 0.8);
        let dy = gen::matrix(&mut rng, n, dh, 1.0);
        let scale = 0.7f32;
        let f = |qv: &[f32], kv: &[f32], vv: &[f32]| -> f32 {
            let (att, _) = attention_one(qv, kv, vv, n, dh, dh, scale, false);
            att.iter().zip(&dy).map(|(a, y)| a * y).sum()
        };
        let (_, probs) = attention_one(&q, &k, &v, n, dh, dh, scale, false);
        let (dq, dk, dv) = attn_backward_head(&q, &k, &v, &probs, &dy, n, dh, scale);
        let eps = 1e-2f32;
        let check = |name: &str, base: &[f32], grad: &[f32], which: usize| {
            for i in 0..n * dh {
                let mut p = base.to_vec();
                p[i] += eps;
                let mut m = base.to_vec();
                m[i] -= eps;
                let (fp, fm) = match which {
                    0 => (f(&p, &k, &v), f(&m, &k, &v)),
                    1 => (f(&q, &p, &v), f(&q, &m, &v)),
                    _ => (f(&q, &k, &p), f(&q, &k, &m)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                assert!(
                    (grad[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()),
                    "{name}[{i}]: {} vs {fd}",
                    grad[i]
                );
            }
        };
        check("dq", &q, &dq, 0);
        check("dk", &k, &dk, 1);
        check("dv", &v, &dv, 2);
    }

    /// Full-model gradient check. Expensive relative to the rest of the
    /// suite and redundant with the layer-level checks above, so it is
    /// ignored by default; run with `cargo test -- --ignored` when touching
    /// the backward pass.
    #[test]
    #[ignore]
    fn full_gradient_matches_finite_difference_vit_t() {
        use crate::model::WeightStore;
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let store = WeightStore::init(cfg, 3);
        let spec = cfg.param_spec();
        let sizes: Vec<usize> = spec.iter().map(|(_, s)| s.iter().product()).collect();
        let idx = SpecIdx::new(cfg);
        let flats: Vec<Vec<f32>> =
            spec.iter().map(|(name, _)| store.get(name).unwrap().data().to_vec()).collect();
        let mut rng = Pcg64::new(7);
        let tokens = gen::matrix(&mut rng, cfg.patches, cfg.patch_dim, 1.0);
        let label = 3i32;
        let loss_of = |flats: &[Vec<f32>]| -> f32 {
            let views: Vec<&[f32]> = flats.iter().map(|p| p.as_slice()).collect();
            let mp = ModelParams::from_slices(cfg, &views);
            let logits =
                super::super::forward::forward_example(
                    cfg,
                    cfg.dh(),
                    cfg.mlp,
                    &mp,
                    ExampleInput::Vit(&tokens),
                )
                    .unwrap();
            super::super::forward::cross_entropy(&logits, label as usize)
        };
        let views: Vec<&[f32]> = flats.iter().map(|p| p.as_slice()).collect();
        let mp = ModelParams::from_slices(cfg, &views);
        let (_, grads) = example_grad(
            cfg,
            &mp,
            &sizes,
            idx,
            ExampleInput::Vit(&tokens),
            ExampleLabel::Vit(label),
            1.0,
        )
        .unwrap();
        // Spot-check a few parameters from different groups.
        let eps = 1e-2f32;
        for &(slot, elem) in &[(0usize, 5usize), (idx.block(0, WQ), 17), (idx.block(2, W2), 3), (idx.head(2), 11)] {
            let mut fp = flats.clone();
            fp[slot][elem] += eps;
            let mut fm = flats.clone();
            fm[slot][elem] -= eps;
            let fd = (loss_of(&fp) - loss_of(&fm)) / (2.0 * eps);
            let got = grads[slot][elem];
            assert!((got - fd).abs() < 5e-2 * (1.0 + fd.abs()), "slot {slot}[{elem}]: {got} vs {fd}");
        }
    }
}
