//! Forward-pass math for the native backend.
//!
//! Single-example bodies mirror `python/compile/model.py` (`vit_embed_one`,
//! `block_one`, `head_one`) with the `kernels/ref.py` definitions: layernorm
//! over the trailing dim with ε = 1e-6, tanh-approximate GELU, softmax
//! attention at the dense-head scale 1/√dh (kept after pruning, §3.4), and
//! causal masking for GPT. Batch slabs fan out per example over the worker
//! pool; per-example arithmetic is identical regardless of worker count.

use anyhow::{bail, Result};

use super::In;
use crate::linalg::gemm::{dot_f32, matmul_f32};
use crate::linalg::qgemm::matmul_q8_raw;
use crate::model::is_q8_param;
use crate::model::{ModelConfig, ModelKind};
use crate::tensor::Tensor;
use crate::util::threads;

pub(crate) const LN_EPS: f32 = 1e-6;

/// LayerNorm over the trailing dimension of a [rows, d] slab.
pub(crate) fn layernorm(x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * d);
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let or = &mut out[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for j in 0..d {
            or[j] = (xr[j] - mu) * inv * g[j] + b[j];
        }
    }
    out
}

/// Tanh-approximate GELU (matches `kernels/ref.py::gelu`).
#[inline]
pub(crate) fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    const A: f32 = 0.044_715;
    0.5 * x * (1.0 + (C * (x + A * x * x * x)).tanh())
}

/// Derivative of the tanh-approximate GELU.
#[inline]
pub(crate) fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_56;
    const A: f32 = 0.044_715;
    let u = C * (x + A * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * A * x * x)
}

/// y[rows, dout] = x[rows, din] · w[din, dout] (+ bias broadcast).
pub(crate) fn linear(
    x: &[f32],
    rows: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * din);
    debug_assert_eq!(w.len(), din * dout);
    let mut out = vec![0.0f32; rows * dout];
    if let Some(b) = bias {
        debug_assert_eq!(b.len(), dout);
        for r in 0..rows {
            out[r * dout..(r + 1) * dout].copy_from_slice(b);
        }
    }
    matmul_f32(x, w, &mut out, rows, din, dout);
    out
}

/// A block GEMM projection weight view: full-precision f32, or the int8
/// weight-quantized form (per-output-channel scales, channel-major codes)
/// the `_w8` fused artifacts carry. Everything outside the six per-block
/// projections stays f32 — see `model::quant`.
#[derive(Clone, Copy)]
pub(crate) enum WMat<'a> {
    F32(&'a [f32]),
    Q8 { data: &'a [i8], scales: &'a [f32], din: usize, dout: usize },
}

impl<'a> WMat<'a> {
    /// The f32 view. Panics on a quantized weight — callers that require
    /// f32 (the train path, the capture/calibration artifacts) never see
    /// `_w8` inputs.
    pub(crate) fn f32(&self) -> &'a [f32] {
        match self {
            WMat::F32(w) => w,
            WMat::Q8 { .. } => panic!("f32 view of an int8-quantized weight"),
        }
    }
}

/// [`linear`] over a [`WMat`]: the f32 GEMM, or the int8 kernel with its
/// f32 dequant epilogue — same `y = x · W (+ b)` contract either way.
pub(crate) fn linear_w(
    x: &[f32],
    rows: usize,
    din: usize,
    w: &WMat<'_>,
    dout: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    match w {
        WMat::F32(wf) => linear(x, rows, din, wf, dout, bias),
        WMat::Q8 { data, scales, din: d, dout: n } => {
            debug_assert_eq!((*d, *n), (din, dout));
            let mut out = vec![0.0f32; rows * dout];
            if let Some(b) = bias {
                debug_assert_eq!(b.len(), dout);
                for r in 0..rows {
                    out[r * dout..(r + 1) * dout].copy_from_slice(b);
                }
            }
            matmul_q8_raw(x, data, scales, din, dout, &mut out, rows);
            out
        }
    }
}

/// Row-wise softmax in place.
pub(crate) fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Gather a per-head column block: src [n, stride] → [n, width] starting at
/// column `at`.
pub(crate) fn gather_cols(src: &[f32], n: usize, stride: usize, at: usize, width: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * width];
    for t in 0..n {
        out[t * width..(t + 1) * width]
            .copy_from_slice(&src[t * stride + at..t * stride + at + width]);
    }
    out
}

/// Scatter a per-head block back: dst [n, stride], block [n, width].
pub(crate) fn scatter_cols(dst: &mut [f32], block: &[f32], n: usize, stride: usize, at: usize, width: usize) {
    for t in 0..n {
        dst[t * stride + at..t * stride + at + width]
            .copy_from_slice(&block[t * width..(t + 1) * width]);
    }
}

/// Raw attention logits q·kᵀ·scale [n, n] with optional causal mask.
pub(crate) fn attn_logits(
    q: &[f32],
    k: &[f32],
    n: usize,
    dqk: usize,
    scale: f32,
    causal: bool,
) -> Vec<f32> {
    let mut logits = vec![0.0f32; n * n];
    for t in 0..n {
        let qt = &q[t * dqk..(t + 1) * dqk];
        let row = &mut logits[t * n..(t + 1) * n];
        for (s, rv) in row.iter_mut().enumerate() {
            *rv = dot_f32(qt, &k[s * dqk..(s + 1) * dqk]) * scale;
        }
        if causal {
            for rv in row.iter_mut().skip(t + 1) {
                *rv = f32::NEG_INFINITY;
            }
        }
    }
    logits
}

/// Softmax attention for one head: probs · v. Returns (att [n, dv],
/// probs [n, n]).
pub(crate) fn attention_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    dqk: usize,
    dv: usize,
    scale: f32,
    causal: bool,
) -> (Vec<f32>, Vec<f32>) {
    let mut probs = attn_logits(q, k, n, dqk, scale, causal);
    softmax_rows(&mut probs, n, n);
    let mut att = vec![0.0f32; n * dv];
    matmul_f32(&probs, v, &mut att, n, n, dv);
    (att, probs)
}

/// Incremental (KV-cached) softmax attention for one head: `m` new queries
/// at absolute positions `past..past+m` attend over the `past` cached keys
/// plus the new keys up to and including their own position (causal).
/// Returns att `[m, dv]`.
///
/// Per-row arithmetic is ordered exactly like the full-sequence path
/// ([`attn_logits`] + [`softmax_rows`]): logit `s` is the same `dot_f32`
/// in the same key order, masked-out positions contribute exact zeros, so
/// the cached and the full computation agree to within the GEMM's
/// accumulation-order noise (asserted ≤ 1e-5 by `tests/decode_equality`).
pub(crate) fn attention_cached(
    q_new: &[f32],
    k_cache: &[f32],
    k_new: &[f32],
    v_cache: &[f32],
    v_new: &[f32],
    past: usize,
    m: usize,
    dqk: usize,
    dv: usize,
    scale: f32,
) -> Vec<f32> {
    debug_assert_eq!(q_new.len(), m * dqk);
    debug_assert_eq!(k_cache.len(), past * dqk);
    debug_assert_eq!(v_cache.len(), past * dv);
    let mut att = vec![0.0f32; m * dv];
    let mut logits: Vec<f32> = Vec::with_capacity(past + m);
    for j in 0..m {
        let span = past + j + 1; // keys visible to absolute position past + j
        let qj = &q_new[j * dqk..(j + 1) * dqk];
        logits.clear();
        for s in 0..past {
            logits.push(dot_f32(qj, &k_cache[s * dqk..(s + 1) * dqk]) * scale);
        }
        for s in 0..=j {
            logits.push(dot_f32(qj, &k_new[s * dqk..(s + 1) * dqk]) * scale);
        }
        softmax_rows(&mut logits, 1, span);
        let out = &mut att[j * dv..(j + 1) * dv];
        for (s, &p) in logits.iter().enumerate() {
            let vrow = if s < past {
                &v_cache[s * dv..(s + 1) * dv]
            } else {
                &v_new[(s - past) * dv..(s - past + 1) * dv]
            };
            for (o, &vv) in out.iter_mut().zip(vrow) {
                *o += p * vv;
            }
        }
    }
    att
}

/// Per-block parameter views in `block_param_spec` order. The six GEMM
/// projections are [`WMat`]s — f32 everywhere except the `_w8` fused
/// serving artifacts, where they arrive int8-quantized.
pub(crate) struct BlockParams<'a> {
    pub ln1g: &'a [f32],
    pub ln1b: &'a [f32],
    pub wq: WMat<'a>,
    pub bq: &'a [f32],
    pub wk: WMat<'a>,
    pub bk: &'a [f32],
    pub wv: WMat<'a>,
    pub bv: &'a [f32],
    pub wo: WMat<'a>,
    pub bo: &'a [f32],
    pub ln2g: &'a [f32],
    pub ln2b: &'a [f32],
    pub w1: WMat<'a>,
    pub b1: &'a [f32],
    pub w2: WMat<'a>,
    pub b2: &'a [f32],
}

impl<'a> BlockParams<'a> {
    /// Build from 16 slices in spec order (shapes already validated).
    pub(crate) fn from_slices(s: &[&'a [f32]]) -> Self {
        assert_eq!(s.len(), 16);
        BlockParams {
            ln1g: s[0],
            ln1b: s[1],
            wq: WMat::F32(s[2]),
            bq: s[3],
            wk: WMat::F32(s[4]),
            bk: s[5],
            wv: WMat::F32(s[6]),
            bv: s[7],
            wo: WMat::F32(s[8]),
            bo: s[9],
            ln2g: s[10],
            ln2b: s[11],
            w1: WMat::F32(s[12]),
            b1: s[13],
            w2: WMat::F32(s[14]),
            b2: s[15],
        }
    }

    pub(crate) fn read(cfg: &ModelConfig, dqk: usize, o: usize, inp: &mut In<'_, 'a>) -> Result<Self> {
        Self::read_w(cfg, dqk, o, false, inp)
    }

    /// [`BlockParams::read`] with an int8 flag: when `w8` is set the six
    /// GEMM projections are consumed as [`crate::runtime::Input::Q8`]
    /// matrices (shape-checked against the spec); everything else stays f32.
    pub(crate) fn read_w(
        cfg: &ModelConfig,
        dqk: usize,
        o: usize,
        w8: bool,
        inp: &mut In<'_, 'a>,
    ) -> Result<Self> {
        let spec = cfg.block_param_spec(dqk, o);
        let mut mats: Vec<WMat<'a>> = Vec::with_capacity(16);
        for (name, shape) in &spec {
            if w8 && is_q8_param(name) {
                let (data, scales) = inp.q8(shape[0], shape[1], name)?;
                mats.push(WMat::Q8 { data, scales, din: shape[0], dout: shape[1] });
            } else {
                mats.push(WMat::F32(inp.slice(shape.iter().product(), name)?));
            }
        }
        Ok(BlockParams {
            ln1g: mats[0].f32(),
            ln1b: mats[1].f32(),
            wq: mats[2],
            bq: mats[3].f32(),
            wk: mats[4],
            bk: mats[5].f32(),
            wv: mats[6],
            bv: mats[7].f32(),
            wo: mats[8],
            bo: mats[9].f32(),
            ln2g: mats[10].f32(),
            ln2b: mats[11].f32(),
            w1: mats[12],
            b1: mats[13].f32(),
            w2: mats[14],
            b2: mats[15].f32(),
        })
    }
}

/// Output of one transformer block on one example.
pub(crate) struct BlockOut {
    pub y: Vec<f32>,
    /// Post-GELU hidden [n, o] (capture mode).
    pub hidden: Option<Vec<f32>>,
    /// Per-head queries [h, n, dqk] (capture mode).
    pub q: Option<Vec<f32>>,
    /// Per-head keys [h, n, dqk] (capture mode).
    pub k: Option<Vec<f32>>,
}

/// One transformer block on a single example x [n, d]
/// (`model.py::block_one`).
pub(crate) fn block_one(
    cfg: &ModelConfig,
    dqk: usize,
    o: usize,
    p: &BlockParams<'_>,
    x: &[f32],
    causal: bool,
    capture: bool,
) -> BlockOut {
    let (n, d, h, dh) = (cfg.n_ctx, cfg.d, cfg.heads, cfg.dh());
    debug_assert_eq!(x.len(), n * d);
    // Dense-head scale even when dqk < dh (§3.4).
    let scale = 1.0 / (dh as f32).sqrt();

    let xn = layernorm(x, n, d, p.ln1g, p.ln1b);
    let qf = linear_w(&xn, n, d, &p.wq, h * dqk, Some(p.bq));
    let kf = linear_w(&xn, n, d, &p.wk, h * dqk, Some(p.bk));
    let vf = linear_w(&xn, n, d, &p.wv, h * dh, Some(p.bv));

    let mut merged = vec![0.0f32; n * h * dh];
    let mut qcap = if capture { Some(vec![0.0f32; h * n * dqk]) } else { None };
    let mut kcap = if capture { Some(vec![0.0f32; h * n * dqk]) } else { None };
    for head in 0..h {
        let qh = gather_cols(&qf, n, h * dqk, head * dqk, dqk);
        let kh = gather_cols(&kf, n, h * dqk, head * dqk, dqk);
        let vh = gather_cols(&vf, n, h * dh, head * dh, dh);
        let (att, _probs) = attention_one(&qh, &kh, &vh, n, dqk, dh, scale, causal);
        scatter_cols(&mut merged, &att, n, h * dh, head * dh, dh);
        if let Some(qc) = &mut qcap {
            qc[head * n * dqk..(head + 1) * n * dqk].copy_from_slice(&qh);
        }
        if let Some(kc) = &mut kcap {
            kc[head * n * dqk..(head + 1) * n * dqk].copy_from_slice(&kh);
        }
    }
    let attn_out = linear_w(&merged, n, h * dh, &p.wo, d, Some(p.bo));
    let y: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

    let yn = layernorm(&y, n, d, p.ln2g, p.ln2b);
    let mut hidden = linear_w(&yn, n, d, &p.w1, o, Some(p.b1));
    for v in hidden.iter_mut() {
        *v = gelu(*v);
    }
    let mlp_out = linear_w(&hidden, n, o, &p.w2, d, Some(p.b2));
    let z: Vec<f32> = y.iter().zip(&mlp_out).map(|(a, b)| a + b).collect();
    BlockOut { y: z, hidden: capture.then_some(hidden), q: qcap, k: kcap }
}

fn check_slab(t: &Tensor, shape: &[usize], what: &str) -> Result<()> {
    if t.shape() != shape {
        bail!("{what}: shape {:?}, expected {shape:?}", t.shape());
    }
    Ok(())
}

/// `block_*` / `blockcap_*`: x [b, n, d] + 16 block params → y [b, n, d]
/// (+ hidden [b, n, o], q/k [b, h, n, dqk] in capture mode).
pub(crate) fn run_block(
    cfg: &'static ModelConfig,
    dqk: usize,
    o: usize,
    b: usize,
    capture: bool,
    inp: &mut In<'_, '_>,
) -> Result<Vec<Tensor>> {
    let (n, d, h) = (cfg.n_ctx, cfg.d, cfg.heads);
    let x = inp.tensor()?;
    check_slab(x, &[b, n, d], "block input")?;
    let p = BlockParams::read(cfg, dqk, o, inp)?;
    let causal = cfg.kind == ModelKind::Gpt;
    let outs: Vec<BlockOut> = threads::parallel_map(b, |e| {
        block_one(cfg, dqk, o, &p, &x.data()[e * n * d..(e + 1) * n * d], causal, capture)
    });
    let mut y = Vec::with_capacity(b * n * d);
    for out in &outs {
        y.extend_from_slice(&out.y);
    }
    let y = Tensor::from_vec(&[b, n, d], y);
    if !capture {
        return Ok(vec![y]);
    }
    let mut hidden = Vec::with_capacity(b * n * o);
    let mut q = Vec::with_capacity(b * h * n * dqk);
    let mut k = Vec::with_capacity(b * h * n * dqk);
    for out in &outs {
        hidden.extend_from_slice(out.hidden.as_ref().expect("capture hidden"));
        q.extend_from_slice(out.q.as_ref().expect("capture q"));
        k.extend_from_slice(out.k.as_ref().expect("capture k"));
    }
    Ok(vec![
        y,
        Tensor::from_vec(&[b, n, o], hidden),
        Tensor::from_vec(&[b, h, n, dqk], q),
        Tensor::from_vec(&[b, h, n, dqk], k),
    ])
}

/// `mlponly_*`: attention-free block (`model.py::mlponly_block_one`).
pub(crate) fn run_mlponly(
    cfg: &'static ModelConfig,
    o: usize,
    b: usize,
    inp: &mut In<'_, '_>,
) -> Result<Vec<Tensor>> {
    let (n, d) = (cfg.n_ctx, cfg.d);
    let x = inp.tensor()?;
    check_slab(x, &[b, n, d], "mlponly input")?;
    let ln2g = inp.slice(d, "ln2.g")?;
    let ln2b = inp.slice(d, "ln2.b")?;
    let w1 = inp.slice(d * o, "mlp.w1")?;
    let b1 = inp.slice(o, "mlp.b1")?;
    let w2 = inp.slice(o * d, "mlp.w2")?;
    let b2 = inp.slice(d, "mlp.b2")?;
    let rows = b * n;
    let yn = layernorm(x.data(), rows, d, ln2g, ln2b);
    let mut hidden = linear(&yn, rows, d, w1, o, Some(b1));
    for v in hidden.iter_mut() {
        *v = gelu(*v);
    }
    let mlp_out = linear(&hidden, rows, o, w2, d, Some(b2));
    let y: Vec<f32> = x.data().iter().zip(&mlp_out).map(|(a, m)| a + m).collect();
    Ok(vec![Tensor::from_vec(&[b, n, d], y)])
}

/// Embedding parameter views.
pub(crate) enum EmbedParams<'a> {
    Vit { we: &'a [f32], be: &'a [f32], cls: &'a [f32], pos: &'a [f32] },
    Gpt { wemb: &'a [f32], pos: &'a [f32] },
}

impl<'a> EmbedParams<'a> {
    pub(crate) fn read(cfg: &ModelConfig, inp: &mut In<'_, 'a>) -> Result<Self> {
        match cfg.kind {
            ModelKind::Vit => Ok(EmbedParams::Vit {
                we: inp.slice(cfg.patch_dim * cfg.d, "embed.w")?,
                be: inp.slice(cfg.d, "embed.b")?,
                cls: inp.slice(cfg.d, "embed.cls")?,
                pos: inp.slice(cfg.n_ctx * cfg.d, "embed.pos")?,
            }),
            ModelKind::Gpt => Ok(EmbedParams::Gpt {
                wemb: inp.slice(cfg.vocab * cfg.d, "embed.w")?,
                pos: inp.slice(cfg.n_ctx * cfg.d, "embed.pos")?,
            }),
        }
    }

    pub(crate) fn from_slices(cfg: &ModelConfig, s: &[&'a [f32]]) -> Self {
        match cfg.kind {
            ModelKind::Vit => EmbedParams::Vit { we: s[0], be: s[1], cls: s[2], pos: s[3] },
            ModelKind::Gpt => EmbedParams::Gpt { wemb: s[0], pos: s[1] },
        }
    }
}

/// ViT patch embedding for one example: tokens [P, pd] → x [P+1, d].
pub(crate) fn vit_embed_one(cfg: &ModelConfig, ep: &EmbedParams<'_>, tokens: &[f32]) -> Vec<f32> {
    let (pn, pd, d, n) = (cfg.patches, cfg.patch_dim, cfg.d, cfg.n_ctx);
    let (we, be, cls, pos) = match ep {
        EmbedParams::Vit { we, be, cls, pos } => (*we, *be, *cls, *pos),
        EmbedParams::Gpt { .. } => panic!("vit embed with gpt params"),
    };
    debug_assert_eq!(tokens.len(), pn * pd);
    let xe = linear(tokens, pn, pd, we, d, Some(be));
    let mut x = vec![0.0f32; n * d];
    for j in 0..d {
        x[j] = cls[j] + pos[j];
    }
    for t in 0..pn {
        let dst = &mut x[(t + 1) * d..(t + 2) * d];
        let src = &xe[t * d..(t + 1) * d];
        let ps = &pos[(t + 1) * d..(t + 2) * d];
        for j in 0..d {
            dst[j] = src[j] + ps[j];
        }
    }
    x
}

/// GPT token embedding for one example: ids `[n]` → x `[n, d]`.
pub(crate) fn gpt_embed_one(cfg: &ModelConfig, ep: &EmbedParams<'_>, ids: &[i32]) -> Result<Vec<f32>> {
    let (d, n, vocab) = (cfg.d, cfg.n_ctx, cfg.vocab);
    let (wemb, pos) = match ep {
        EmbedParams::Gpt { wemb, pos } => (*wemb, *pos),
        EmbedParams::Vit { .. } => panic!("gpt embed with vit params"),
    };
    debug_assert_eq!(ids.len(), n);
    let mut x = vec![0.0f32; n * d];
    for t in 0..n {
        let id = ids[t];
        if id < 0 || id as usize >= vocab {
            bail!("token id {id} out of vocab range 0..{vocab}");
        }
        let row = &wemb[id as usize * d..(id as usize + 1) * d];
        let ps = &pos[t * d..(t + 1) * d];
        let dst = &mut x[t * d..(t + 1) * d];
        for j in 0..d {
            dst[j] = row[j] + ps[j];
        }
    }
    Ok(x)
}

/// `embed_*`: batch embedding.
pub(crate) fn run_embed(cfg: &'static ModelConfig, b: usize, inp: &mut In<'_, '_>) -> Result<Vec<Tensor>> {
    let (n, d) = (cfg.n_ctx, cfg.d);
    match cfg.kind {
        ModelKind::Vit => {
            let tokens = inp.tensor()?;
            check_slab(tokens, &[b, cfg.patches, cfg.patch_dim], "embed tokens")?;
            let ep = EmbedParams::read(cfg, inp)?;
            let per = cfg.patches * cfg.patch_dim;
            let rows: Vec<Vec<f32>> = threads::parallel_map(b, |e| {
                vit_embed_one(cfg, &ep, &tokens.data()[e * per..(e + 1) * per])
            });
            let mut out = Vec::with_capacity(b * n * d);
            for r in rows {
                out.extend_from_slice(&r);
            }
            Ok(vec![Tensor::from_vec(&[b, n, d], out)])
        }
        ModelKind::Gpt => {
            let ids = inp.ints()?;
            if ids.len() != b * n {
                bail!("embed ids: {} values, expected {}", ids.len(), b * n);
            }
            let ep = EmbedParams::read(cfg, inp)?;
            let mut out = Vec::with_capacity(b * n * d);
            for e in 0..b {
                out.extend_from_slice(&gpt_embed_one(cfg, &ep, &ids[e * n..(e + 1) * n])?);
            }
            Ok(vec![Tensor::from_vec(&[b, n, d], out)])
        }
    }
}

/// `head_*`: classification / LM head (`model.py::head_one`).
pub(crate) fn run_head(cfg: &'static ModelConfig, b: usize, inp: &mut In<'_, '_>) -> Result<Vec<Tensor>> {
    let (n, d) = (cfg.n_ctx, cfg.d);
    let x = inp.tensor()?;
    check_slab(x, &[b, n, d], "head input")?;
    let g = inp.slice(d, "head.ln.g")?;
    let bb = inp.slice(d, "head.ln.b")?;
    let out_dim = match cfg.kind {
        ModelKind::Vit => cfg.classes,
        ModelKind::Gpt => cfg.vocab,
    };
    let w = inp.slice(d * out_dim, "head.w")?;
    let bias = inp.slice(out_dim, "head.b")?;
    let xn = layernorm(x.data(), b * n, d, g, bb);
    match cfg.kind {
        ModelKind::Vit => {
            // CLS-token logits per example.
            let mut logits = vec![0.0f32; b * out_dim];
            for e in 0..b {
                let row = &xn[e * n * d..e * n * d + d];
                let lr = &mut logits[e * out_dim..(e + 1) * out_dim];
                lr.copy_from_slice(bias);
                for (c, &xv) in row.iter().enumerate() {
                    let wrow = &w[c * out_dim..(c + 1) * out_dim];
                    for (j, lv) in lr.iter_mut().enumerate() {
                        *lv += xv * wrow[j];
                    }
                }
            }
            Ok(vec![Tensor::from_vec(&[b, out_dim], logits)])
        }
        ModelKind::Gpt => {
            let logits = linear(&xn, b * n, d, w, out_dim, Some(bias));
            Ok(vec![Tensor::from_vec(&[b, n, out_dim], logits)])
        }
    }
}

/// `lnf_*`: final layernorm features.
pub(crate) fn run_lnf(cfg: &'static ModelConfig, b: usize, inp: &mut In<'_, '_>) -> Result<Vec<Tensor>> {
    let (n, d) = (cfg.n_ctx, cfg.d);
    let x = inp.tensor()?;
    check_slab(x, &[b, n, d], "lnf input")?;
    let g = inp.slice(d, "ln.g")?;
    let bb = inp.slice(d, "ln.b")?;
    let out = layernorm(x.data(), b * n, d, g, bb);
    Ok(vec![Tensor::from_vec(&[b, n, d], out)])
}

/// Full model parameter views (dense shapes, canonical spec order).
pub(crate) struct ModelParams<'a> {
    pub embed: EmbedParams<'a>,
    pub blocks: Vec<BlockParams<'a>>,
    pub head_ln_g: &'a [f32],
    pub head_ln_b: &'a [f32],
    pub head_w: &'a [f32],
    pub head_b: &'a [f32],
}

impl<'a> ModelParams<'a> {
    pub(crate) fn read(cfg: &ModelConfig, inp: &mut In<'_, 'a>) -> Result<Self> {
        Self::read_at(cfg, cfg.dh(), cfg.mlp, inp)
    }

    /// Read the full parameter list at explicit pruned dims `(dqk, o)` —
    /// the input convention of the fused `fwd_*` artifacts.
    pub(crate) fn read_at(
        cfg: &ModelConfig,
        dqk: usize,
        o: usize,
        inp: &mut In<'_, 'a>,
    ) -> Result<Self> {
        Self::read_at_w(cfg, dqk, o, false, inp)
    }

    /// [`ModelParams::read_at`] with the int8 flag of the `_w8` artifacts:
    /// block GEMM projections arrive quantized, everything else f32.
    pub(crate) fn read_at_w(
        cfg: &ModelConfig,
        dqk: usize,
        o: usize,
        w8: bool,
        inp: &mut In<'_, 'a>,
    ) -> Result<Self> {
        let embed = EmbedParams::read(cfg, inp)?;
        let mut blocks = Vec::with_capacity(cfg.layers);
        for _ in 0..cfg.layers {
            blocks.push(BlockParams::read_w(cfg, dqk, o, w8, inp)?);
        }
        let out_dim = match cfg.kind {
            ModelKind::Vit => cfg.classes,
            ModelKind::Gpt => cfg.vocab,
        };
        Ok(ModelParams {
            embed,
            blocks,
            head_ln_g: inp.slice(cfg.d, "head.ln.g")?,
            head_ln_b: inp.slice(cfg.d, "head.ln.b")?,
            head_w: inp.slice(cfg.d * out_dim, "head.w")?,
            head_b: inp.slice(out_dim, "head.b")?,
        })
    }

    /// [`ModelParams::read_at_w`] with *per-layer* pruned dims — the input
    /// convention of the layered `fwd_*` artifacts produced by the global
    /// FLOPs-budget allocator. Each block's 16 parameters are validated
    /// against that layer's own `(dqk, o)`.
    pub(crate) fn read_layered_w(
        cfg: &ModelConfig,
        dqk: &[usize],
        o: &[usize],
        w8: bool,
        inp: &mut In<'_, 'a>,
    ) -> Result<Self> {
        if dqk.len() != cfg.layers || o.len() != cfg.layers {
            bail!(
                "layered dims: {} qk / {} mlp entries for {} layers",
                dqk.len(),
                o.len(),
                cfg.layers
            );
        }
        let embed = EmbedParams::read(cfg, inp)?;
        let mut blocks = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            blocks.push(BlockParams::read_w(cfg, dqk[l], o[l], w8, inp)?);
        }
        let out_dim = match cfg.kind {
            ModelKind::Vit => cfg.classes,
            ModelKind::Gpt => cfg.vocab,
        };
        Ok(ModelParams {
            embed,
            blocks,
            head_ln_g: inp.slice(cfg.d, "head.ln.g")?,
            head_ln_b: inp.slice(cfg.d, "head.ln.b")?,
            head_w: inp.slice(cfg.d * out_dim, "head.w")?,
            head_b: inp.slice(out_dim, "head.b")?,
        })
    }

    /// Build from a flat slice list in spec order (the train path, where
    /// parameters live in mutable buffers rather than `Input`s).
    pub(crate) fn from_slices(cfg: &ModelConfig, flat: &[&'a [f32]]) -> Self {
        let ne = match cfg.kind {
            ModelKind::Vit => 4,
            ModelKind::Gpt => 2,
        };
        let embed = EmbedParams::from_slices(cfg, &flat[..ne]);
        let mut blocks = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            blocks.push(BlockParams::from_slices(&flat[ne + l * 16..ne + (l + 1) * 16]));
        }
        let hb = ne + cfg.layers * 16;
        ModelParams {
            embed,
            blocks,
            head_ln_g: flat[hb],
            head_ln_b: flat[hb + 1],
            head_w: flat[hb + 2],
            head_b: flat[hb + 3],
        }
    }
}

/// Per-example input for a full forward.
pub(crate) enum ExampleInput<'a> {
    Vit(&'a [f32]),
    Gpt(&'a [i32]),
}

/// Full forward for one example at pruned dims `(dqk, o)` → logits
/// (vit: `[classes]`; gpt: `[n, vocab]`). Dense callers pass
/// `(cfg.dh(), cfg.mlp)`; the fused `fwd_*` serving path passes the dims
/// derived from the stored weight shapes, so every GEMM runs at the
/// retained width directly.
pub(crate) fn forward_example(
    cfg: &ModelConfig,
    dqk: usize,
    o: usize,
    p: &ModelParams<'_>,
    inp: ExampleInput<'_>,
) -> Result<Vec<f32>> {
    let (n, d) = (cfg.n_ctx, cfg.d);
    let causal = cfg.kind == ModelKind::Gpt;
    let mut x = match inp {
        ExampleInput::Vit(tokens) => vit_embed_one(cfg, &p.embed, tokens),
        ExampleInput::Gpt(ids) => gpt_embed_one(cfg, &p.embed, ids)?,
    };
    for bp in &p.blocks {
        x = block_one(cfg, dqk, o, bp, &x, causal, false).y;
    }
    let xn = layernorm(&x, n, d, p.head_ln_g, p.head_ln_b);
    let out_dim = match cfg.kind {
        ModelKind::Vit => cfg.classes,
        ModelKind::Gpt => cfg.vocab,
    };
    match cfg.kind {
        ModelKind::Vit => {
            let mut logits = p.head_b.to_vec();
            for (c, &xv) in xn[..d].iter().enumerate() {
                let wrow = &p.head_w[c * out_dim..(c + 1) * out_dim];
                for (j, lv) in logits.iter_mut().enumerate() {
                    *lv += xv * wrow[j];
                }
            }
            Ok(logits)
        }
        ModelKind::Gpt => Ok(linear(&xn, n, d, p.head_w, out_dim, Some(p.head_b))),
    }
}

/// [`forward_example`] at per-layer pruned dims: block `l` runs at
/// `(dqk[l], o[l])`. The uniform path is the special case where every layer
/// shares one shape.
pub(crate) fn forward_example_layered(
    cfg: &ModelConfig,
    dqk: &[usize],
    o: &[usize],
    p: &ModelParams<'_>,
    inp: ExampleInput<'_>,
) -> Result<Vec<f32>> {
    let (n, d) = (cfg.n_ctx, cfg.d);
    let causal = cfg.kind == ModelKind::Gpt;
    let mut x = match inp {
        ExampleInput::Vit(tokens) => vit_embed_one(cfg, &p.embed, tokens),
        ExampleInput::Gpt(ids) => gpt_embed_one(cfg, &p.embed, ids)?,
    };
    for (l, bp) in p.blocks.iter().enumerate() {
        x = block_one(cfg, dqk[l], o[l], bp, &x, causal, false).y;
    }
    let xn = layernorm(&x, n, d, p.head_ln_g, p.head_ln_b);
    let out_dim = match cfg.kind {
        ModelKind::Vit => cfg.classes,
        ModelKind::Gpt => cfg.vocab,
    };
    match cfg.kind {
        ModelKind::Vit => {
            let mut logits = p.head_b.to_vec();
            for (c, &xv) in xn[..d].iter().enumerate() {
                let wrow = &p.head_w[c * out_dim..(c + 1) * out_dim];
                for (j, lv) in logits.iter_mut().enumerate() {
                    *lv += xv * wrow[j];
                }
            }
            Ok(logits)
        }
        ModelKind::Gpt => Ok(linear(&xn, n, d, p.head_w, out_dim, Some(p.head_b))),
    }
}

/// −log `softmax(row)[target]`.
pub(crate) fn cross_entropy(row: &[f32], target: usize) -> f32 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let lse: f32 = row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
    lse - row[target]
}

/// `evloss_*`: mean cross-entropy over one eval batch (dense weights).
pub(crate) fn run_evloss(cfg: &'static ModelConfig, inp: &mut In<'_, '_>) -> Result<Vec<Tensor>> {
    let b = cfg.eval_batch();
    let n = cfg.n_ctx;
    match cfg.kind {
        ModelKind::Vit => {
            let tokens = inp.tensor()?;
            check_slab(tokens, &[b, cfg.patches, cfg.patch_dim], "evloss tokens")?;
            let labels = inp.ints()?;
            if labels.len() != b {
                bail!("evloss labels: {} values, expected {b}", labels.len());
            }
            let p = ModelParams::read(cfg, inp)?;
            let per = cfg.patches * cfg.patch_dim;
            let losses: Vec<Result<f32>> = threads::parallel_map(b, |e| {
                let logits = forward_example(
                    cfg,
                    cfg.dh(),
                    cfg.mlp,
                    &p,
                    ExampleInput::Vit(&tokens.data()[e * per..(e + 1) * per]),
                )?;
                let t = labels[e];
                if t < 0 || t as usize >= cfg.classes {
                    bail!("label {t} out of range");
                }
                Ok(cross_entropy(&logits, t as usize))
            });
            let mut total = 0.0f32;
            for l in losses {
                total += l?;
            }
            Ok(vec![Tensor::scalar(total / b as f32)])
        }
        ModelKind::Gpt => {
            let ids = inp.ints()?;
            if ids.len() != b * n {
                bail!("evloss ids: {} values, expected {}", ids.len(), b * n);
            }
            let labels = inp.ints()?;
            if labels.len() != b * n {
                bail!("evloss labels: {} values, expected {}", labels.len(), b * n);
            }
            let p = ModelParams::read(cfg, inp)?;
            let losses: Vec<Result<f32>> = threads::parallel_map(b, |e| {
                let logits = forward_example(
                    cfg,
                    cfg.dh(),
                    cfg.mlp,
                    &p,
                    ExampleInput::Gpt(&ids[e * n..(e + 1) * n]),
                )?;
                let mut s = 0.0f32;
                for t in 0..n {
                    let y = labels[e * n + t];
                    if y < 0 || y as usize >= cfg.vocab {
                        bail!("target {y} out of range");
                    }
                    s += cross_entropy(&logits[t * cfg.vocab..(t + 1) * cfg.vocab], y as usize);
                }
                Ok(s / n as f32)
            });
            let mut total = 0.0f32;
            for l in losses {
                total += l?;
            }
            Ok(vec![Tensor::scalar(total / b as f32)])
        }
    }
}

/// `fwd_*`: fused full forward (embed + all blocks + head) at pruned dims
/// `(dqk, o)` — one native dispatch per batch instead of `layers + 2`, with
/// a single per-example fan-out over the worker pool. This is the serving
/// fast path: every projection GEMM runs at the retained width read off the
/// weight shapes, so dense, pruned, and compensated variants are timed on
/// the arithmetic they actually keep. The batch size `b` is decoded from
/// the artifact name like every other dim, so the interpreter serves any
/// batch a [`crate::exec::ForwardPlan`] dispatches — exact-size partial
/// batches do proportionally less work, which is what the serving engine's
/// `exact` dispatch policy exploits.
pub(crate) fn run_forward(
    cfg: &'static ModelConfig,
    dqk: usize,
    o: usize,
    b: usize,
    w8: bool,
    inp: &mut In<'_, '_>,
) -> Result<Vec<Tensor>> {
    let n = cfg.n_ctx;
    match cfg.kind {
        ModelKind::Vit => {
            let tokens = inp.tensor()?;
            check_slab(tokens, &[b, cfg.patches, cfg.patch_dim], "fwd tokens")?;
            let p = ModelParams::read_at_w(cfg, dqk, o, w8, inp)?;
            let per = cfg.patches * cfg.patch_dim;
            let rows: Vec<Result<Vec<f32>>> = threads::parallel_map(b, |e| {
                forward_example(
                    cfg,
                    dqk,
                    o,
                    &p,
                    ExampleInput::Vit(&tokens.data()[e * per..(e + 1) * per]),
                )
            });
            let mut logits = Vec::with_capacity(b * cfg.classes);
            for r in rows {
                logits.extend_from_slice(&r?);
            }
            Ok(vec![Tensor::from_vec(&[b, cfg.classes], logits)])
        }
        ModelKind::Gpt => {
            let ids = inp.ints()?;
            if ids.len() != b * n {
                bail!("fwd ids: {} values, expected {}", ids.len(), b * n);
            }
            let p = ModelParams::read_at_w(cfg, dqk, o, w8, inp)?;
            let rows: Vec<Result<Vec<f32>>> = threads::parallel_map(b, |e| {
                forward_example(cfg, dqk, o, &p, ExampleInput::Gpt(&ids[e * n..(e + 1) * n]))
            });
            let mut logits = Vec::with_capacity(b * n * cfg.vocab);
            for r in rows {
                logits.extend_from_slice(&r?);
            }
            Ok(vec![Tensor::from_vec(&[b, n, cfg.vocab], logits)])
        }
    }
}

/// `fwd_*` with `_qv`/`_ov` per-layer dim lists: the layered analogue of
/// [`run_forward`], serving the allocator's non-uniform stores. Same input
/// convention (data first, then `param_spec_layered` order), same parallel
/// per-example fan-out — each block's GEMMs just run at that layer's own
/// retained widths.
pub(crate) fn run_forward_layered(
    cfg: &'static ModelConfig,
    dqk: &[usize],
    o: &[usize],
    b: usize,
    w8: bool,
    inp: &mut In<'_, '_>,
) -> Result<Vec<Tensor>> {
    let n = cfg.n_ctx;
    match cfg.kind {
        ModelKind::Vit => {
            let tokens = inp.tensor()?;
            check_slab(tokens, &[b, cfg.patches, cfg.patch_dim], "fwd tokens")?;
            let p = ModelParams::read_layered_w(cfg, dqk, o, w8, inp)?;
            let per = cfg.patches * cfg.patch_dim;
            let rows: Vec<Result<Vec<f32>>> = threads::parallel_map(b, |e| {
                forward_example_layered(
                    cfg,
                    dqk,
                    o,
                    &p,
                    ExampleInput::Vit(&tokens.data()[e * per..(e + 1) * per]),
                )
            });
            let mut logits = Vec::with_capacity(b * cfg.classes);
            for r in rows {
                logits.extend_from_slice(&r?);
            }
            Ok(vec![Tensor::from_vec(&[b, cfg.classes], logits)])
        }
        ModelKind::Gpt => {
            let ids = inp.ints()?;
            if ids.len() != b * n {
                bail!("fwd ids: {} values, expected {}", ids.len(), b * n);
            }
            let p = ModelParams::read_layered_w(cfg, dqk, o, w8, inp)?;
            let rows: Vec<Result<Vec<f32>>> = threads::parallel_map(b, |e| {
                forward_example_layered(
                    cfg,
                    dqk,
                    o,
                    &p,
                    ExampleInput::Gpt(&ids[e * n..(e + 1) * n]),
                )
            });
            let mut logits = Vec::with_capacity(b * n * cfg.vocab);
            for r in rows {
                logits.extend_from_slice(&r?);
            }
            Ok(vec![Tensor::from_vec(&[b, n, cfg.vocab], logits)])
        }
    }
}

/// Incremental forward for one gpt example: `fresh = ids_new.len()` new
/// tokens at absolute positions `past..past+fresh`, attending over the
/// per-layer K/V cache of the first `past` positions (layout
/// `[layers, h, n_ctx, dqk|dh]`; rows ≥ `past` are never read). Returns
/// (logits `[fresh, vocab]`, knew `[layers, h, fresh, dqk]`,
/// vnew `[layers, h, fresh, dh]`) — the caller appends the new rows to its
/// cache. With `past == 0` and `fresh == n_ctx` this is exactly
/// [`forward_example`] (asserted by `tests/decode_equality`); with
/// `fresh == 1` it is one autoregressive decode step.
pub(crate) fn decode_example(
    cfg: &ModelConfig,
    dqk: usize,
    o: usize,
    p: &ModelParams<'_>,
    ids_new: &[i32],
    past: usize,
    kcache: &[f32],
    vcache: &[f32],
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    let (n, d, h, dh, vocab) = (cfg.n_ctx, cfg.d, cfg.heads, cfg.dh(), cfg.vocab);
    let m = ids_new.len();
    if m == 0 {
        bail!("decode: no new tokens");
    }
    if past + m > n {
        bail!("decode: {past} cached + {m} new positions exceed n_ctx {n}");
    }
    debug_assert_eq!(kcache.len(), cfg.layers * h * n * dqk);
    debug_assert_eq!(vcache.len(), cfg.layers * h * n * dh);
    // Dense-head scale even when dqk < dh (§3.4), as in the full forward.
    let scale = 1.0 / (dh as f32).sqrt();

    let (wemb, pos) = match &p.embed {
        EmbedParams::Gpt { wemb, pos } => (*wemb, *pos),
        EmbedParams::Vit { .. } => bail!("decode on vit params"),
    };
    let mut x = vec![0.0f32; m * d];
    for (j, &id) in ids_new.iter().enumerate() {
        if id < 0 || id as usize >= vocab {
            bail!("token id {id} out of vocab range 0..{vocab}");
        }
        let row = &wemb[id as usize * d..(id as usize + 1) * d];
        let ps = &pos[(past + j) * d..(past + j + 1) * d];
        let dst = &mut x[j * d..(j + 1) * d];
        for c in 0..d {
            dst[c] = row[c] + ps[c];
        }
    }

    let mut knew = vec![0.0f32; cfg.layers * h * m * dqk];
    let mut vnew = vec![0.0f32; cfg.layers * h * m * dh];
    for (l, bp) in p.blocks.iter().enumerate() {
        let xn = layernorm(&x, m, d, bp.ln1g, bp.ln1b);
        let qf = linear_w(&xn, m, d, &bp.wq, h * dqk, Some(bp.bq));
        let kf = linear_w(&xn, m, d, &bp.wk, h * dqk, Some(bp.bk));
        let vf = linear_w(&xn, m, d, &bp.wv, h * dh, Some(bp.bv));
        let mut merged = vec![0.0f32; m * h * dh];
        for head in 0..h {
            let qh = gather_cols(&qf, m, h * dqk, head * dqk, dqk);
            let kh = gather_cols(&kf, m, h * dqk, head * dqk, dqk);
            let vh = gather_cols(&vf, m, h * dh, head * dh, dh);
            let kc = &kcache[(l * h + head) * n * dqk..][..past * dqk];
            let vc = &vcache[(l * h + head) * n * dh..][..past * dh];
            let att = attention_cached(&qh, kc, &kh, vc, &vh, past, m, dqk, dh, scale);
            scatter_cols(&mut merged, &att, m, h * dh, head * dh, dh);
            knew[(l * h + head) * m * dqk..(l * h + head + 1) * m * dqk].copy_from_slice(&kh);
            vnew[(l * h + head) * m * dh..(l * h + head + 1) * m * dh].copy_from_slice(&vh);
        }
        let attn_out = linear_w(&merged, m, h * dh, &bp.wo, d, Some(bp.bo));
        let y: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();
        let yn = layernorm(&y, m, d, bp.ln2g, bp.ln2b);
        let mut hidden = linear_w(&yn, m, d, &bp.w1, o, Some(bp.b1));
        for v in hidden.iter_mut() {
            *v = gelu(*v);
        }
        let mlp_out = linear_w(&hidden, m, o, &bp.w2, d, Some(bp.b2));
        x = y.iter().zip(&mlp_out).map(|(a, b)| a + b).collect();
    }
    let xn = layernorm(&x, m, d, p.head_ln_g, p.head_ln_b);
    let logits = linear(&xn, m, d, p.head_w, vocab, Some(p.head_b));
    Ok((logits, knew, vnew))
}

/// `dec_*`: batched incremental (KV-cached) decode at pruned dims
/// `(dqk, o)` — the autoregressive serving fast path (gpt only).
///
/// Inputs: new ids `[b, m]` (`m` decoded from the id count), cached lengths
/// `past [b]`, new counts `fresh [b]` (`1..=m`; id columns ≥ `fresh[e]` are
/// padding), per-layer caches `[b, layers, h, n_ctx, dqk|dh]` (rows ≥
/// `past[e]` are never read — padding can batch sequences with different
/// cache lengths into one dispatch), then the full parameter list in
/// `param_spec_at(dqk, o)` order. Outputs: logits `[b, m, vocab]` at the
/// new positions (rows ≥ `fresh[e]` zero) plus the new K/V rows
/// `[b, layers, h, m, dqk|dh]` for the caller to append to its caches.
pub(crate) fn run_decode(
    cfg: &'static ModelConfig,
    dqk: usize,
    o: usize,
    b: usize,
    w8: bool,
    inp: &mut In<'_, '_>,
) -> Result<Vec<Tensor>> {
    if cfg.kind != ModelKind::Gpt {
        bail!("dec artifact on non-gpt config '{}'", cfg.name);
    }
    let (n, h, dh, vocab, layers) = (cfg.n_ctx, cfg.heads, cfg.dh(), cfg.vocab, cfg.layers);
    let ids = inp.ints()?;
    if b == 0 || ids.is_empty() || ids.len() % b != 0 {
        bail!("dec ids: {} values do not tile batch {b}", ids.len());
    }
    let m = ids.len() / b;
    let past = inp.ints()?;
    let fresh = inp.ints()?;
    if past.len() != b || fresh.len() != b {
        bail!("dec lens: {} past / {} fresh values, expected {b}", past.len(), fresh.len());
    }
    let kc = inp.tensor()?;
    check_slab(kc, &[b, layers, h, n, dqk], "dec kcache")?;
    let vc = inp.tensor()?;
    check_slab(vc, &[b, layers, h, n, dh], "dec vcache")?;
    let p = ModelParams::read_at_w(cfg, dqk, o, w8, inp)?;
    let clen_k = layers * h * n * dqk;
    let clen_v = layers * h * n * dh;
    let outs: Vec<Result<(Vec<f32>, Vec<f32>, Vec<f32>)>> = threads::parallel_map(b, |e| {
        let (pe, fe) = (past[e], fresh[e]);
        if pe < 0 || fe < 1 || fe as usize > m {
            bail!("dec lens: example {e} has past {pe} / fresh {fe} for m {m}");
        }
        decode_example(
            cfg,
            dqk,
            o,
            &p,
            &ids[e * m..e * m + fe as usize],
            pe as usize,
            &kc.data()[e * clen_k..(e + 1) * clen_k],
            &vc.data()[e * clen_v..(e + 1) * clen_v],
        )
    });
    let mut logits = vec![0.0f32; b * m * vocab];
    let mut knew = vec![0.0f32; b * layers * h * m * dqk];
    let mut vnew = vec![0.0f32; b * layers * h * m * dh];
    for (e, r) in outs.into_iter().enumerate() {
        let (lg, kn, vn) = r?;
        let fe = fresh[e] as usize;
        logits[e * m * vocab..e * m * vocab + fe * vocab].copy_from_slice(&lg);
        for lh in 0..layers * h {
            knew[(e * layers * h + lh) * m * dqk..][..fe * dqk]
                .copy_from_slice(&kn[lh * fe * dqk..(lh + 1) * fe * dqk]);
            vnew[(e * layers * h + lh) * m * dh..][..fe * dh]
                .copy_from_slice(&vn[lh * fe * dh..(lh + 1) * fe * dh]);
        }
    }
    Ok(vec![
        Tensor::from_vec(&[b, m, vocab], logits),
        Tensor::from_vec(&[b, layers, h, m, dqk], knew),
        Tensor::from_vec(&[b, layers, h, m, dh], vnew),
    ])
}

/// Block-table view of one sequence's paged K/V cache: per-block raw base
/// pointers of the K and V planes (layout `[planes, block, dqk|dh]` per
/// block, `planes = layers * heads`), built by
/// `exec::kv_pool::PagedSeq::view`. Position `pos` lives in block
/// `pos / block`, row `pos % block`.
///
/// Pointers stay valid for the owning pool's lifetime (blocks are never
/// deallocated). Writing rows requires the exclusive ownership the pool's
/// `prepare_append` establishes; shared prefix blocks are read-only.
pub(crate) struct PagedKv {
    pub k: Vec<*mut f32>,
    pub v: Vec<*mut f32>,
    /// Positions per block.
    pub block: usize,
    /// Planes per block (`layers * heads`).
    pub planes: usize,
}

impl PagedKv {
    /// Positions the block table can hold.
    pub(crate) fn capacity(&self) -> usize {
        self.k.len() * self.block
    }
}

// SAFETY: a `PagedKv` is a bundle of raw plane pointers into pool blocks;
// the aliasing discipline (exclusive writer per unshared block, read-only
// shared blocks, mutex publication) is enforced by the pool — see
// `exec/kv_pool.rs`. Sending the view to an interpreter worker moves only
// the pointers.
unsafe impl Send for PagedKv {}
unsafe impl Sync for PagedKv {}

/// Row `pos` of plane `lh` of a paged cache (`width` = dqk or dh).
///
/// # Safety
/// `pos / block` must be within `planes`, each plane pointer must cover
/// `(lh + 1) * block * width` floats, and no concurrent writer may exist
/// for that block (the pool's ownership rules).
unsafe fn paged_row<'a>(
    planes: &[*mut f32],
    block: usize,
    lh: usize,
    width: usize,
    pos: usize,
) -> &'a [f32] {
    let base = planes[pos / block];
    std::slice::from_raw_parts(base.add((lh * block + pos % block) * width), width)
}

/// Mutable variant of [`paged_row`].
///
/// # Safety
/// As [`paged_row`], plus: the caller must be the block's exclusive owner.
unsafe fn paged_row_mut<'a>(
    planes: &[*mut f32],
    block: usize,
    lh: usize,
    width: usize,
    pos: usize,
) -> &'a mut [f32] {
    let base = planes[pos / block];
    std::slice::from_raw_parts_mut(base.add((lh * block + pos % block) * width), width)
}

/// [`attention_cached`] reading every key/value row — cached and new alike —
/// through a block table. The caller has already appended the `m` new rows
/// at positions `past..past+m` of plane `lh`, so row `s` of the logit loop
/// is one uniform block lookup; the per-row arithmetic (dot order, softmax,
/// accumulation order) is identical to the contiguous path, making the two
/// bitwise-equal for equal inputs.
pub(crate) fn attention_paged(
    q_new: &[f32],
    kv: &PagedKv,
    lh: usize,
    past: usize,
    m: usize,
    dqk: usize,
    dv: usize,
    scale: f32,
) -> Vec<f32> {
    debug_assert_eq!(q_new.len(), m * dqk);
    debug_assert!(past + m <= kv.capacity());
    let mut att = vec![0.0f32; m * dv];
    let mut logits: Vec<f32> = Vec::with_capacity(past + m);
    for j in 0..m {
        let span = past + j + 1; // keys visible to absolute position past + j
        let qj = &q_new[j * dqk..(j + 1) * dqk];
        logits.clear();
        for s in 0..span {
            // SAFETY: s < past + m ≤ capacity; rows ≤ past are committed,
            // rows past..past+m were written by this call's owner.
            let krow = unsafe { paged_row(&kv.k, kv.block, lh, dqk, s) };
            logits.push(dot_f32(qj, krow) * scale);
        }
        softmax_rows(&mut logits, 1, span);
        let out = &mut att[j * dv..(j + 1) * dv];
        for (s, &p) in logits.iter().enumerate() {
            // SAFETY: as above.
            let vrow = unsafe { paged_row(&kv.v, kv.block, lh, dv, s) };
            for (o, &vv) in out.iter_mut().zip(vrow) {
                *o += p * vv;
            }
        }
    }
    att
}

/// [`decode_example`] against a paged cache: the new K/V rows are written
/// into the sequence's blocks **in place** (positions `past..past+m` of
/// every layer/head plane) and attention gathers all rows through the block
/// table — no cache slab enters or leaves the call, so per-step cache
/// traffic is the appended rows only, independent of `n_ctx` capacity.
/// Returns the logits `[m, vocab]`.
pub(crate) fn decode_example_paged(
    cfg: &ModelConfig,
    dqk: usize,
    o: usize,
    p: &ModelParams<'_>,
    ids_new: &[i32],
    past: usize,
    kv: &PagedKv,
) -> Result<Vec<f32>> {
    let (n, d, h, dh, vocab) = (cfg.n_ctx, cfg.d, cfg.heads, cfg.dh(), cfg.vocab);
    let m = ids_new.len();
    if m == 0 {
        bail!("decode: no new tokens");
    }
    if past + m > n {
        bail!("decode: {past} cached + {m} new positions exceed n_ctx {n}");
    }
    if kv.planes != cfg.layers * h || kv.k.len() != kv.v.len() {
        bail!(
            "paged decode: table has {} planes / {} k vs {} v blocks, expected {} planes",
            kv.planes,
            kv.k.len(),
            kv.v.len(),
            cfg.layers * h
        );
    }
    if past + m > kv.capacity() {
        bail!(
            "paged decode: block table covers {} positions, need {}",
            kv.capacity(),
            past + m
        );
    }
    // Dense-head scale even when dqk < dh (§3.4), as in the full forward.
    let scale = 1.0 / (dh as f32).sqrt();

    let (wemb, pos) = match &p.embed {
        EmbedParams::Gpt { wemb, pos } => (*wemb, *pos),
        EmbedParams::Vit { .. } => bail!("decode on vit params"),
    };
    let mut x = vec![0.0f32; m * d];
    for (j, &id) in ids_new.iter().enumerate() {
        if id < 0 || id as usize >= vocab {
            bail!("token id {id} out of vocab range 0..{vocab}");
        }
        let row = &wemb[id as usize * d..(id as usize + 1) * d];
        let ps = &pos[(past + j) * d..(past + j + 1) * d];
        let dst = &mut x[j * d..(j + 1) * d];
        for c in 0..d {
            dst[c] = row[c] + ps[c];
        }
    }

    for (l, bp) in p.blocks.iter().enumerate() {
        let xn = layernorm(&x, m, d, bp.ln1g, bp.ln1b);
        let qf = linear_w(&xn, m, d, &bp.wq, h * dqk, Some(bp.bq));
        let kf = linear_w(&xn, m, d, &bp.wk, h * dqk, Some(bp.bk));
        let vf = linear_w(&xn, m, d, &bp.wv, h * dh, Some(bp.bv));
        let mut merged = vec![0.0f32; m * h * dh];
        for head in 0..h {
            let qh = gather_cols(&qf, m, h * dqk, head * dqk, dqk);
            let kh = gather_cols(&kf, m, h * dqk, head * dqk, dqk);
            let vh = gather_cols(&vf, m, h * dh, head * dh, dh);
            let lh = l * h + head;
            // Append the new rows in place, then attend over everything
            // through the table (the appended rows included).
            for j in 0..m {
                // SAFETY: capacity checked above; the caller guarantees
                // exclusive ownership of the blocks receiving writes.
                unsafe {
                    paged_row_mut(&kv.k, kv.block, lh, dqk, past + j)
                        .copy_from_slice(&kh[j * dqk..(j + 1) * dqk]);
                    paged_row_mut(&kv.v, kv.block, lh, dh, past + j)
                        .copy_from_slice(&vh[j * dh..(j + 1) * dh]);
                }
            }
            let att = attention_paged(&qh, kv, lh, past, m, dqk, dh, scale);
            scatter_cols(&mut merged, &att, m, h * dh, head * dh, dh);
        }
        let attn_out = linear_w(&merged, m, h * dh, &bp.wo, d, Some(bp.bo));
        let y: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();
        let yn = layernorm(&y, m, d, bp.ln2g, bp.ln2b);
        let mut hidden = linear_w(&yn, m, d, &bp.w1, o, Some(bp.b1));
        for v in hidden.iter_mut() {
            *v = gelu(*v);
        }
        let mlp_out = linear_w(&hidden, m, o, &bp.w2, d, Some(bp.b2));
        x = y.iter().zip(&mlp_out).map(|(a, b)| a + b).collect();
    }
    let xn = layernorm(&x, m, d, p.head_ln_g, p.head_ln_b);
    Ok(linear(&xn, m, d, p.head_w, vocab, Some(p.head_b)))
}

/// Paged-cache variant of [`run_decode`]: ids/past/fresh arrive as direct
/// slices and each live example's K/V rides a [`PagedKv`] block-table view
/// instead of slab tensors; `inp` carries only the parameter list. Examples
/// `≥ seqs.len()` are dispatch padding — their logits rows stay zero and no
/// work runs for them, which keeps outputs identical across dispatch
/// policies. Output: logits `[b, m, vocab]` (the new K/V rows were appended
/// in place).
pub(crate) fn run_decode_paged(
    cfg: &'static ModelConfig,
    dqk: usize,
    o: usize,
    b: usize,
    w8: bool,
    ids: &[i32],
    past: &[i32],
    fresh: &[i32],
    seqs: &[PagedKv],
    inp: &mut In<'_, '_>,
) -> Result<Vec<Tensor>> {
    if cfg.kind != ModelKind::Gpt {
        bail!("dec artifact on non-gpt config '{}'", cfg.name);
    }
    let vocab = cfg.vocab;
    if b == 0 || ids.is_empty() || ids.len() % b != 0 {
        bail!("dec ids: {} values do not tile batch {b}", ids.len());
    }
    let m = ids.len() / b;
    if past.len() != b || fresh.len() != b {
        bail!("dec lens: {} past / {} fresh values, expected {b}", past.len(), fresh.len());
    }
    if seqs.len() > b {
        bail!("dec paged: {} block tables for batch {b}", seqs.len());
    }
    let p = ModelParams::read_at_w(cfg, dqk, o, w8, inp)?;
    let outs: Vec<Result<Vec<f32>>> = threads::parallel_map(seqs.len(), |e| {
        let (pe, fe) = (past[e], fresh[e]);
        if pe < 0 || fe < 1 || fe as usize > m {
            bail!("dec lens: example {e} has past {pe} / fresh {fe} for m {m}");
        }
        decode_example_paged(cfg, dqk, o, &p, &ids[e * m..e * m + fe as usize], pe as usize, &seqs[e])
    });
    let mut logits = vec![0.0f32; b * m * vocab];
    for (e, r) in outs.into_iter().enumerate() {
        let lg = r?;
        logits[e * m * vocab..e * m * vocab + lg.len()].copy_from_slice(&lg);
    }
    Ok(vec![Tensor::from_vec(&[b, m, vocab], logits)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layernorm_normalizes_rows() {
        let x = [1.0f32, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0];
        let g = [1.0f32; 4];
        let b = [0.0f32; 4];
        let out = layernorm(&x, 2, 4, &g, &b);
        for r in 0..2 {
            let row = &out[r * 4..(r + 1) * 4];
            let mu: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
            assert!(mu.abs() < 1e-5, "row {r} mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
        // gamma/beta applied after normalization
        let out2 = layernorm(&x, 2, 4, &[2.0; 4], &[0.5; 4]);
        for (a, c) in out.iter().zip(&out2) {
            assert!((a * 2.0 + 0.5 - c).abs() < 1e-5);
        }
    }

    #[test]
    fn gelu_reference_values() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.158_808).abs() < 1e-3);
        // large |x|: identity / zero asymptotes
        assert!((gelu(6.0) - 6.0).abs() < 1e-3);
        assert!(gelu(-6.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3f32;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}: {} vs {fd}", gelu_grad(x));
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = vec![0.1f32, 2.0, -1.0, 3.0, 3.0, 3.0];
        softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!((x[3] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn causal_mask_zeroes_future() {
        let q = vec![1.0f32; 3 * 2];
        let k = vec![1.0f32; 3 * 2];
        let v = vec![1.0f32; 3 * 2];
        let (att, probs) = attention_one(&q, &k, &v, 3, 2, 2, 0.5, true);
        // Row 0 can only attend to itself.
        assert!((probs[0] - 1.0).abs() < 1e-6);
        assert!(probs[1] == 0.0 && probs[2] == 0.0);
        // Uniform inputs: attention output is the value vector.
        for a in att {
            assert!((a - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let src: Vec<f32> = (0..12).map(|v| v as f32).collect(); // [3, 4]
        let blk = gather_cols(&src, 3, 4, 1, 2);
        assert_eq!(blk, vec![1., 2., 5., 6., 9., 10.]);
        let mut dst = vec![0.0f32; 12];
        scatter_cols(&mut dst, &blk, 3, 4, 1, 2);
        assert_eq!(dst[1], 1.0);
        assert_eq!(dst[6], 6.0);
        assert_eq!(dst[0], 0.0);
    }

    #[test]
    fn cross_entropy_uniform() {
        let row = vec![0.0f32; 16];
        assert!((cross_entropy(&row, 3) - (16.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn cached_attention_matches_full_causal() {
        // Pseudo-random but deterministic q/k/v for a 5-position sequence.
        let (n, dqk, dv) = (5usize, 3usize, 2usize);
        let gen = |salt: usize, len: usize| -> Vec<f32> {
            (0..len).map(|i| (((i * 2654435761 + salt * 40503) % 97) as f32 - 48.0) / 31.0).collect()
        };
        let q = gen(1, n * dqk);
        let k = gen(2, n * dqk);
        let v = gen(3, n * dv);
        let (full, _) = attention_one(&q, &k, &v, n, dqk, dv, 0.7, true);
        // Split at every cache point: first `past` positions cached, the
        // rest decoded incrementally — the outputs for the new positions
        // must match the full causal attention rows.
        for past in 0..n {
            let m = n - past;
            let att = attention_cached(
                &q[past * dqk..],
                &k[..past * dqk],
                &k[past * dqk..],
                &v[..past * dv],
                &v[past * dv..],
                past,
                m,
                dqk,
                dv,
                0.7,
            );
            for (a, b) in att.iter().zip(&full[past * dv..]) {
                assert!((a - b).abs() < 1e-6, "past={past}: {a} vs {b}");
            }
        }
    }

    /// Deterministic xorshift-style values in roughly [-1.5, 1.5].
    fn prand(seed: &mut u64) -> f32 {
        *seed ^= *seed << 13;
        *seed ^= *seed >> 7;
        *seed ^= *seed << 17;
        ((*seed % 3001) as f32 - 1500.0) / 1000.0
    }

    #[test]
    fn cached_attention_property_random_splits_and_pruned_shapes() {
        // Satellite coverage for `attention_cached`: across sequence
        // lengths, pruned key widths dqk (≤ dh, the CORP per-head pruning
        // shape) and value widths dv, and *every* past/fresh split, the
        // incremental rows must match the full causal attention.
        let mut seed = 0x00c0_ffee_u64;
        for &(n, dqk, dv) in
            &[(1usize, 1usize, 1usize), (4, 2, 4), (7, 3, 5), (8, 8, 8), (12, 5, 2), (16, 2, 7)]
        {
            let q: Vec<f32> = (0..n * dqk).map(|_| prand(&mut seed)).collect();
            let k: Vec<f32> = (0..n * dqk).map(|_| prand(&mut seed)).collect();
            let v: Vec<f32> = (0..n * dv).map(|_| prand(&mut seed)).collect();
            // Dense-head scale with dh ≥ dqk, as the pruned path uses.
            let scale = 1.0 / (dv.max(dqk) as f32).sqrt();
            let (full, _) = attention_one(&q, &k, &v, n, dqk, dv, scale, true);
            for past in 0..n {
                let m = n - past;
                let att = attention_cached(
                    &q[past * dqk..],
                    &k[..past * dqk],
                    &k[past * dqk..],
                    &v[..past * dv],
                    &v[past * dv..],
                    past,
                    m,
                    dqk,
                    dv,
                    scale,
                );
                for (j, (a, b)) in att.iter().zip(&full[past * dv..]).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "n={n} dqk={dqk} dv={dv} past={past} j={j}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn paged_attention_matches_cached_bitwise() {
        // attention_paged reads rows through a block table the test builds
        // by hand (1 plane, block size 3, so rows straddle blocks); outputs
        // must be bitwise equal to attention_cached on the same rows.
        let (n, dqk, dv, block) = (8usize, 3usize, 2usize, 3usize);
        let mut seed = 0x5eed_u64;
        let q: Vec<f32> = (0..n * dqk).map(|_| prand(&mut seed)).collect();
        let k: Vec<f32> = (0..n * dqk).map(|_| prand(&mut seed)).collect();
        let v: Vec<f32> = (0..n * dv).map(|_| prand(&mut seed)).collect();
        let nb = n.div_ceil(block);
        let mut kblocks: Vec<Vec<f32>> = vec![vec![0.0; block * dqk]; nb];
        let mut vblocks: Vec<Vec<f32>> = vec![vec![0.0; block * dv]; nb];
        for pos in 0..n {
            let (bi, r) = (pos / block, pos % block);
            kblocks[bi][r * dqk..(r + 1) * dqk].copy_from_slice(&k[pos * dqk..(pos + 1) * dqk]);
            vblocks[bi][r * dv..(r + 1) * dv].copy_from_slice(&v[pos * dv..(pos + 1) * dv]);
        }
        let kv = PagedKv {
            k: kblocks.iter_mut().map(|b| b.as_mut_ptr()).collect(),
            v: vblocks.iter_mut().map(|b| b.as_mut_ptr()).collect(),
            block,
            planes: 1,
        };
        for past in 0..n {
            let m = n - past;
            let want = attention_cached(
                &q[past * dqk..],
                &k[..past * dqk],
                &k[past * dqk..],
                &v[..past * dv],
                &v[past * dv..],
                past,
                m,
                dqk,
                dv,
                0.6,
            );
            let got = attention_paged(&q[past * dqk..], &kv, 0, past, m, dqk, dv, 0.6);
            assert_eq!(got, want, "past={past}");
        }
    }
}
