//! Model executor: stitches embed → blocks → head from per-block artifacts.
//!
//! Because weights are graph *arguments*, the same executor runs dense,
//! pruned, and compensated models — it derives the artifact shape key from
//! the actual weight shapes in the store. Capture mode additionally returns
//! each layer's MLP hidden activations and per-head Q/K (the calibration
//! signals of Alg. 1).
//!
//! For serving there is a fused fast path: [`Executor::forward_plan`]
//! resolves every parameter reference once (by-name lookups are hoisted out
//! of the request loop) and returns a batch-polymorphic [`ForwardPlan`]
//! that dispatches the whole network as a single `fwd_*` artifact at the
//! pruned dims read off the stored weight shapes. The plan is bound to a
//! model *variant*, not a batch size: an interior per-batch-size artifact
//! cache lets the native backend run any batch at its true size, while
//! fixed-shape backends (gated PJRT) keep padding to one artifact batch.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use anyhow::{bail, Context, Result};

use crate::model::{ModelConfig, ModelKind, WeightStore};
use crate::runtime::{Input, Runtime};
use crate::tensor::Tensor;

/// Per-layer calibration capture (dense model).
pub struct LayerCapture {
    /// Post-GELU MLP hidden activations [B, n, o].
    pub hidden: Tensor,
    /// Per-head queries [B, h, n, dh] (pre-scale, bias included).
    pub q: Tensor,
    /// Per-head keys [B, h, n, dh].
    pub k: Tensor,
}

pub struct Executor<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: &'static ModelConfig,
}

/// A batch-polymorphic resolved full-forward dispatch: every parameter
/// tensor in canonical `param_spec_at(dqk, o)` order, resolved once per
/// model *variant* by [`Executor::forward_plan`]. Each call then costs one
/// input-list assembly and one runtime dispatch of the fused `fwd_*`
/// artifact at the batch size of the data actually handed in — the fixed
/// artifact-batch binding (and the caller-side padding it forced) is gone.
///
/// Fused artifact names are formatted on first use per batch size and kept
/// in an interior cache behind a [`RwLock`], so the plan stays `Sync` (the
/// serving engine shares one per variant across all worker threads) and a
/// steady-state request loop never re-formats a name.
pub struct ForwardPlan<'rt, 'w> {
    rt: &'rt Runtime,
    pub cfg: &'static ModelConfig,
    /// Retained per-head q/k width derived from the stored `attn.wq` shape.
    pub dqk: usize,
    /// Retained MLP hidden width derived from the stored `mlp.w1` shape.
    pub o: usize,
    params: Vec<&'w Tensor>,
    /// batch size → fused artifact name (interior per-batch-size cache).
    arts: RwLock<HashMap<usize, Arc<str>>>,
}

impl ForwardPlan<'_, '_> {
    /// The fused artifact name this plan dispatches at `batch`, cached so
    /// repeat callers share one allocation per batch size ([`Arc`] handle
    /// identity is observable — tests assert reuse).
    pub fn artifact(&self, batch: usize) -> Arc<str> {
        if let Some(a) = self.arts.read().unwrap().get(&batch) {
            return a.clone();
        }
        let mut cache = self.arts.write().unwrap();
        cache
            .entry(batch)
            .or_insert_with(|| Arc::from(self.cfg.fwd_artifact(self.dqk, self.o, batch)))
            .clone()
    }

    /// Number of batch sizes resolved so far (cache telemetry).
    pub fn cached_batch_sizes(&self) -> usize {
        self.arts.read().unwrap().len()
    }

    fn dispatch(&self, data: Input<'_>, art: &str) -> Result<Tensor> {
        let mut inputs: Vec<Input> = Vec::with_capacity(1 + self.params.len());
        inputs.push(data);
        inputs.extend(self.params.iter().map(|&t| Input::F32(t)));
        let mut out = self.rt.execute(art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Fused vit forward at the batch size of `tokens`
    /// `[batch, patches, patch_dim]` → logits `[batch, classes]`.
    pub fn run_vit(&self, tokens: &Tensor) -> Result<Tensor> {
        if self.cfg.kind != ModelKind::Vit {
            bail!("run_vit on a gpt forward plan");
        }
        let shape = tokens.shape();
        if shape.len() != 3 || shape[1] != self.cfg.patches || shape[2] != self.cfg.patch_dim {
            bail!(
                "run_vit: tokens shape {shape:?}, expected [b, {}, {}]",
                self.cfg.patches,
                self.cfg.patch_dim
            );
        }
        let batch = shape[0];
        if batch == 0 {
            bail!("run_vit: empty batch");
        }
        let art = self.artifact(batch);
        self.dispatch(Input::F32(tokens), &art)
    }

    /// Fused gpt forward: ids `[batch * n_ctx]` → logits
    /// `[batch, n_ctx, vocab]`.
    pub fn run_gpt(&self, ids: &[i32], batch: usize) -> Result<Tensor> {
        if self.cfg.kind != ModelKind::Gpt {
            bail!("run_gpt on a vit forward plan");
        }
        if batch == 0 || ids.len() != batch * self.cfg.n_ctx {
            bail!(
                "run_gpt: {} ids for batch {batch} (expected {})",
                ids.len(),
                batch * self.cfg.n_ctx
            );
        }
        let art = self.artifact(batch);
        self.dispatch(Input::I32(ids, vec![batch, self.cfg.n_ctx]), &art)
    }
}

impl<'rt> Executor<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: &'static ModelConfig) -> Self {
        Self { rt, cfg }
    }

    /// Infer (dqk, o) from the stored block-0 weight shapes.
    pub fn stored_dims(&self, w: &WeightStore) -> Result<(usize, usize)> {
        let wq = w.expect("blocks.0.attn.wq")?;
        let w1 = w.expect("blocks.0.mlp.w1")?;
        Ok((wq.shape()[1] / self.cfg.heads, w1.shape()[1]))
    }

    fn push_params<'a>(
        &self,
        w: &'a WeightStore,
        names: impl Iterator<Item = String>,
        inputs: &mut Vec<Input<'a>>,
    ) -> Result<()> {
        for name in names {
            let t = w.expect(&name)?;
            inputs.push(Input::F32(t));
        }
        Ok(())
    }

    /// Run the embedding graph. vit: `tokens` [B, P, pd]; gpt: `ids` via
    /// `forward_gpt`.
    pub fn embed(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let art = self.cfg.embed_artifact(batch);
        let mut inputs: Vec<Input> = vec![Input::F32(tokens)];
        self.push_params(w, self.cfg.embed_param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    pub fn embed_gpt(&self, w: &WeightStore, ids: &[i32], batch: usize) -> Result<Tensor> {
        if self.cfg.kind != ModelKind::Gpt {
            bail!("embed_gpt on a vit config");
        }
        let art = self.cfg.embed_artifact(batch);
        let shape = vec![batch, self.cfg.n_ctx];
        let mut inputs: Vec<Input> = vec![Input::I32(ids, shape)];
        self.push_params(w, self.cfg.embed_param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Run one block (layer index `l`) on x [B, n, d].
    pub fn block(&self, w: &WeightStore, l: usize, x: &Tensor, batch: usize) -> Result<Tensor> {
        let (dqk, o) = self.stored_dims(w)?;
        let art = self.cfg.block_artifact(dqk, o, batch);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        self.push_params(
            w,
            self.cfg.block_param_spec(dqk, o).into_iter().map(|(n, _)| format!("blocks.{l}.{n}")),
            &mut inputs,
        )?;
        let mut out = self
            .rt
            .execute(&art, &inputs)
            .with_context(|| format!("block layer {l} artifact {art}"))?;
        Ok(out.remove(0))
    }

    /// Run one block through the attention-free (DC-ViT-like) artifact.
    pub fn block_mlponly(&self, w: &WeightStore, l: usize, x: &Tensor, batch: usize) -> Result<Tensor> {
        let w1 = w.expect(&format!("blocks.{l}.mlp.w1"))?;
        let o = w1.shape()[1];
        let art = format!("mlponly_{}_o{o}_b{batch}", self.cfg.name);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        for n in ["ln2.g", "ln2.b", "mlp.w1", "mlp.b1", "mlp.w2", "mlp.b2"] {
            inputs.push(Input::F32(w.expect(&format!("blocks.{l}.{n}"))?));
        }
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Run one block in capture mode (dense shapes only).
    pub fn block_capture(
        &self,
        w: &WeightStore,
        l: usize,
        x: &Tensor,
    ) -> Result<(Tensor, LayerCapture)> {
        let art = self.cfg.blockcap_artifact();
        let (dqk, o) = (self.cfg.dh(), self.cfg.mlp);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        self.push_params(
            w,
            self.cfg.block_param_spec(dqk, o).into_iter().map(|(n, _)| format!("blocks.{l}.{n}")),
            &mut inputs,
        )?;
        let mut out = self.rt.execute(&art, &inputs)?;
        if out.len() != 4 {
            bail!("capture artifact returned {} outputs", out.len());
        }
        let k = out.remove(3);
        let q = out.remove(2);
        let hidden = out.remove(1);
        let y = out.remove(0);
        Ok((y, LayerCapture { hidden, q, k }))
    }

    /// Run the classification / LM head on x [B, n, d].
    pub fn head(&self, w: &WeightStore, x: &Tensor, batch: usize) -> Result<Tensor> {
        let art = self.cfg.head_artifact(batch);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        self.push_params(w, self.cfg.head_param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Final-layernorm features [B, n, d] (dense-task backbone output).
    pub fn features(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let x = self.forward_backbone(w, tokens, batch)?;
        let art = self.cfg.lnf_artifact();
        let inputs: Vec<Input> = vec![
            Input::F32(&x),
            Input::F32(w.expect("head.ln.g")?),
            Input::F32(w.expect("head.ln.b")?),
        ];
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// embed + all blocks (no head).
    pub fn forward_backbone(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let mut x = self.embed(w, tokens, batch)?;
        for l in 0..self.cfg.layers {
            x = self.block(w, l, &x, batch)?;
        }
        Ok(x)
    }

    /// Full forward: vit logits [B, classes].
    pub fn forward_vit(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let x = self.forward_backbone(w, tokens, batch)?;
        self.head(w, &x, batch)
    }

    /// Resolve the batch-polymorphic full-forward fast path for `w`:
    /// derives `(dqk, o)` from the stored weight shapes and resolves every
    /// parameter tensor in canonical order — once per model *variant*, not
    /// per batch size. The returned [`ForwardPlan`] is `Sync` (it borrows
    /// the runtime and the weight store immutably; the artifact-name cache
    /// is behind a lock), so the serving engine shares one per variant
    /// across all worker threads and dispatches any batch at its true size.
    pub fn forward_plan<'w>(&self, w: &'w WeightStore) -> Result<ForwardPlan<'rt, 'w>> {
        let (dqk, o) = self.stored_dims(w)?;
        let spec = self.cfg.param_spec_at(dqk, o);
        let mut params = Vec::with_capacity(spec.len());
        for (name, shape) in &spec {
            let t = w.expect(name)?;
            if t.shape() != shape.as_slice() {
                bail!(
                    "forward_plan: weight '{name}' has shape {:?}, expected {shape:?}",
                    t.shape()
                );
            }
            params.push(t);
        }
        Ok(ForwardPlan {
            rt: self.rt,
            cfg: self.cfg,
            dqk,
            o,
            params,
            arts: RwLock::new(HashMap::new()),
        })
    }

    /// Full forward: gpt logits [B, n, vocab].
    pub fn forward_gpt(&self, w: &WeightStore, ids: &[i32], batch: usize) -> Result<Tensor> {
        let mut x = self.embed_gpt(w, ids, batch)?;
        for l in 0..self.cfg.layers {
            x = self.block(w, l, &x, batch)?;
        }
        self.head(w, &x, batch)
    }

    /// Full dense forward with per-layer capture.
    pub fn forward_capture(
        &self,
        w: &WeightStore,
        tokens: Option<&Tensor>,
        ids: Option<&[i32]>,
    ) -> Result<(Tensor, Vec<LayerCapture>)> {
        let batch = self.cfg.eval_batch();
        let mut x = match self.cfg.kind {
            ModelKind::Vit => self.embed(w, tokens.context("vit capture needs tokens")?, batch)?,
            ModelKind::Gpt => self.embed_gpt(w, ids.context("gpt capture needs ids")?, batch)?,
        };
        let mut caps = Vec::with_capacity(self.cfg.layers);
        for l in 0..self.cfg.layers {
            let (y, cap) = self.block_capture(w, l, &x)?;
            x = y;
            caps.push(cap);
        }
        let logits = self.head(w, &x, batch)?;
        Ok((logits, caps))
    }

    /// Mean cross-entropy via the `evloss` artifact (dense shapes only —
    /// used for GPT perplexity and ViT validation loss).
    pub fn eval_loss(
        &self,
        w: &WeightStore,
        tokens: Option<&Tensor>,
        ids: Option<&[i32]>,
        labels: &[i32],
    ) -> Result<f32> {
        let art = self.cfg.evloss_artifact();
        let batch = self.cfg.eval_batch();
        let mut inputs: Vec<Input> = Vec::new();
        match self.cfg.kind {
            ModelKind::Vit => {
                inputs.push(Input::F32(tokens.context("vit evloss needs tokens")?));
                inputs.push(Input::I32(labels, vec![batch]));
            }
            ModelKind::Gpt => {
                inputs.push(Input::I32(ids.context("gpt evloss needs ids")?, vec![batch, self.cfg.n_ctx]));
                inputs.push(Input::I32(labels, vec![batch, self.cfg.n_ctx]));
            }
        }
        self.push_params(w, self.cfg.param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let out = self.rt.execute(&art, &inputs)?;
        Ok(out[0].data()[0])
    }
}
