//! Model executor: stitches embed → blocks → head from per-block artifacts.
//!
//! Because weights are graph *arguments*, the same executor runs dense,
//! pruned, and compensated models — it derives the artifact shape key from
//! the actual weight shapes in the store. Capture mode additionally returns
//! each layer's MLP hidden activations and per-head Q/K (the calibration
//! signals of Alg. 1).
//!
//! For serving there is a fused fast path: [`Executor::prepare_forward`]
//! resolves every parameter reference once (by-name lookups and artifact
//! name formatting are hoisted out of the request loop) and returns a
//! [`PreparedForward`] that dispatches the whole network as a single
//! `fwd_*` artifact at the pruned dims read off the stored weight shapes.

use anyhow::{bail, Context, Result};

use crate::model::{ModelConfig, ModelKind, WeightStore};
use crate::runtime::{Input, Runtime};
use crate::tensor::Tensor;

/// Per-layer calibration capture (dense model).
pub struct LayerCapture {
    /// Post-GELU MLP hidden activations [B, n, o].
    pub hidden: Tensor,
    /// Per-head queries [B, h, n, dh] (pre-scale, bias included).
    pub q: Tensor,
    /// Per-head keys [B, h, n, dh].
    pub k: Tensor,
}

pub struct Executor<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: &'static ModelConfig,
}

/// A resolved full-forward dispatch: fused `fwd_*` artifact name plus every
/// parameter tensor in canonical `param_spec_at(dqk, o)` order. Built once
/// per (model variant, batch size) by [`Executor::prepare_forward`]; each
/// call then costs one input-list assembly and one runtime dispatch.
pub struct PreparedForward<'rt, 'w> {
    rt: &'rt Runtime,
    pub cfg: &'static ModelConfig,
    /// Fixed batch size the artifact is bound to (callers pad short batches).
    pub batch: usize,
    /// Retained per-head q/k width derived from the stored `attn.wq` shape.
    pub dqk: usize,
    /// Retained MLP hidden width derived from the stored `mlp.w1` shape.
    pub o: usize,
    art: String,
    params: Vec<&'w Tensor>,
}

impl PreparedForward<'_, '_> {
    /// Fused vit forward: tokens `[batch, patches, patch_dim]` → logits
    /// `[batch, classes]`.
    pub fn run_vit(&self, tokens: &Tensor) -> Result<Tensor> {
        if self.cfg.kind != ModelKind::Vit {
            bail!("run_vit on a gpt prepared forward");
        }
        let mut inputs: Vec<Input> = Vec::with_capacity(1 + self.params.len());
        inputs.push(Input::F32(tokens));
        inputs.extend(self.params.iter().map(|&t| Input::F32(t)));
        let mut out = self.rt.execute(&self.art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Fused gpt forward: ids `[batch * n_ctx]` → logits
    /// `[batch, n_ctx, vocab]`.
    pub fn run_gpt(&self, ids: &[i32]) -> Result<Tensor> {
        if self.cfg.kind != ModelKind::Gpt {
            bail!("run_gpt on a vit prepared forward");
        }
        let mut inputs: Vec<Input> = Vec::with_capacity(1 + self.params.len());
        inputs.push(Input::I32(ids, vec![self.batch, self.cfg.n_ctx]));
        inputs.extend(self.params.iter().map(|&t| Input::F32(t)));
        let mut out = self.rt.execute(&self.art, &inputs)?;
        Ok(out.remove(0))
    }

    /// The fused artifact name this handle dispatches.
    pub fn artifact(&self) -> &str {
        &self.art
    }
}

impl<'rt> Executor<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: &'static ModelConfig) -> Self {
        Self { rt, cfg }
    }

    /// Infer (dqk, o) from the stored block-0 weight shapes.
    pub fn stored_dims(&self, w: &WeightStore) -> Result<(usize, usize)> {
        let wq = w.expect("blocks.0.attn.wq")?;
        let w1 = w.expect("blocks.0.mlp.w1")?;
        Ok((wq.shape()[1] / self.cfg.heads, w1.shape()[1]))
    }

    fn push_params<'a>(
        &self,
        w: &'a WeightStore,
        names: impl Iterator<Item = String>,
        inputs: &mut Vec<Input<'a>>,
    ) -> Result<()> {
        for name in names {
            let t = w.expect(&name)?;
            inputs.push(Input::F32(t));
        }
        Ok(())
    }

    /// Run the embedding graph. vit: `tokens` [B, P, pd]; gpt: `ids` via
    /// `forward_gpt`.
    pub fn embed(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let art = self.cfg.embed_artifact(batch);
        let mut inputs: Vec<Input> = vec![Input::F32(tokens)];
        self.push_params(w, self.cfg.embed_param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    pub fn embed_gpt(&self, w: &WeightStore, ids: &[i32], batch: usize) -> Result<Tensor> {
        if self.cfg.kind != ModelKind::Gpt {
            bail!("embed_gpt on a vit config");
        }
        let art = self.cfg.embed_artifact(batch);
        let shape = vec![batch, self.cfg.n_ctx];
        let mut inputs: Vec<Input> = vec![Input::I32(ids, shape)];
        self.push_params(w, self.cfg.embed_param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Run one block (layer index `l`) on x [B, n, d].
    pub fn block(&self, w: &WeightStore, l: usize, x: &Tensor, batch: usize) -> Result<Tensor> {
        let (dqk, o) = self.stored_dims(w)?;
        let art = self.cfg.block_artifact(dqk, o, batch);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        self.push_params(
            w,
            self.cfg.block_param_spec(dqk, o).into_iter().map(|(n, _)| format!("blocks.{l}.{n}")),
            &mut inputs,
        )?;
        let mut out = self
            .rt
            .execute(&art, &inputs)
            .with_context(|| format!("block layer {l} artifact {art}"))?;
        Ok(out.remove(0))
    }

    /// Run one block through the attention-free (DC-ViT-like) artifact.
    pub fn block_mlponly(&self, w: &WeightStore, l: usize, x: &Tensor, batch: usize) -> Result<Tensor> {
        let w1 = w.expect(&format!("blocks.{l}.mlp.w1"))?;
        let o = w1.shape()[1];
        let art = format!("mlponly_{}_o{o}_b{batch}", self.cfg.name);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        for n in ["ln2.g", "ln2.b", "mlp.w1", "mlp.b1", "mlp.w2", "mlp.b2"] {
            inputs.push(Input::F32(w.expect(&format!("blocks.{l}.{n}"))?));
        }
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Run one block in capture mode (dense shapes only).
    pub fn block_capture(
        &self,
        w: &WeightStore,
        l: usize,
        x: &Tensor,
    ) -> Result<(Tensor, LayerCapture)> {
        let art = self.cfg.blockcap_artifact();
        let (dqk, o) = (self.cfg.dh(), self.cfg.mlp);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        self.push_params(
            w,
            self.cfg.block_param_spec(dqk, o).into_iter().map(|(n, _)| format!("blocks.{l}.{n}")),
            &mut inputs,
        )?;
        let mut out = self.rt.execute(&art, &inputs)?;
        if out.len() != 4 {
            bail!("capture artifact returned {} outputs", out.len());
        }
        let k = out.remove(3);
        let q = out.remove(2);
        let hidden = out.remove(1);
        let y = out.remove(0);
        Ok((y, LayerCapture { hidden, q, k }))
    }

    /// Run the classification / LM head on x [B, n, d].
    pub fn head(&self, w: &WeightStore, x: &Tensor, batch: usize) -> Result<Tensor> {
        let art = self.cfg.head_artifact(batch);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        self.push_params(w, self.cfg.head_param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Final-layernorm features [B, n, d] (dense-task backbone output).
    pub fn features(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let x = self.forward_backbone(w, tokens, batch)?;
        let art = self.cfg.lnf_artifact();
        let inputs: Vec<Input> = vec![
            Input::F32(&x),
            Input::F32(w.expect("head.ln.g")?),
            Input::F32(w.expect("head.ln.b")?),
        ];
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// embed + all blocks (no head).
    pub fn forward_backbone(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let mut x = self.embed(w, tokens, batch)?;
        for l in 0..self.cfg.layers {
            x = self.block(w, l, &x, batch)?;
        }
        Ok(x)
    }

    /// Full forward: vit logits [B, classes].
    pub fn forward_vit(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let x = self.forward_backbone(w, tokens, batch)?;
        self.head(w, &x, batch)
    }

    /// Resolve the full-forward fast path for `w` at a fixed batch size:
    /// derives `(dqk, o)` from the stored weight shapes, resolves every
    /// parameter tensor in canonical order, and precomputes the fused
    /// `fwd_*` artifact name. The returned handle is `Sync` (it borrows the
    /// runtime and the weight store immutably), so the serving engine shares
    /// one per model variant across all worker threads.
    pub fn prepare_forward<'w>(
        &self,
        w: &'w WeightStore,
        batch: usize,
    ) -> Result<PreparedForward<'rt, 'w>> {
        let (dqk, o) = self.stored_dims(w)?;
        let spec = self.cfg.param_spec_at(dqk, o);
        let mut params = Vec::with_capacity(spec.len());
        for (name, shape) in &spec {
            let t = w.expect(name)?;
            if t.shape() != shape.as_slice() {
                bail!(
                    "prepare_forward: weight '{name}' has shape {:?}, expected {shape:?}",
                    t.shape()
                );
            }
            params.push(t);
        }
        Ok(PreparedForward {
            rt: self.rt,
            cfg: self.cfg,
            batch,
            dqk,
            o,
            art: self.cfg.fwd_artifact(dqk, o, batch),
            params,
        })
    }

    /// Full forward: gpt logits [B, n, vocab].
    pub fn forward_gpt(&self, w: &WeightStore, ids: &[i32], batch: usize) -> Result<Tensor> {
        let mut x = self.embed_gpt(w, ids, batch)?;
        for l in 0..self.cfg.layers {
            x = self.block(w, l, &x, batch)?;
        }
        self.head(w, &x, batch)
    }

    /// Full dense forward with per-layer capture.
    pub fn forward_capture(
        &self,
        w: &WeightStore,
        tokens: Option<&Tensor>,
        ids: Option<&[i32]>,
    ) -> Result<(Tensor, Vec<LayerCapture>)> {
        let batch = self.cfg.eval_batch();
        let mut x = match self.cfg.kind {
            ModelKind::Vit => self.embed(w, tokens.context("vit capture needs tokens")?, batch)?,
            ModelKind::Gpt => self.embed_gpt(w, ids.context("gpt capture needs ids")?, batch)?,
        };
        let mut caps = Vec::with_capacity(self.cfg.layers);
        for l in 0..self.cfg.layers {
            let (y, cap) = self.block_capture(w, l, &x)?;
            x = y;
            caps.push(cap);
        }
        let logits = self.head(w, &x, batch)?;
        Ok((logits, caps))
    }

    /// Mean cross-entropy via the `evloss` artifact (dense shapes only —
    /// used for GPT perplexity and ViT validation loss).
    pub fn eval_loss(
        &self,
        w: &WeightStore,
        tokens: Option<&Tensor>,
        ids: Option<&[i32]>,
        labels: &[i32],
    ) -> Result<f32> {
        let art = self.cfg.evloss_artifact();
        let batch = self.cfg.eval_batch();
        let mut inputs: Vec<Input> = Vec::new();
        match self.cfg.kind {
            ModelKind::Vit => {
                inputs.push(Input::F32(tokens.context("vit evloss needs tokens")?));
                inputs.push(Input::I32(labels, vec![batch]));
            }
            ModelKind::Gpt => {
                inputs.push(Input::I32(ids.context("gpt evloss needs ids")?, vec![batch, self.cfg.n_ctx]));
                inputs.push(Input::I32(labels, vec![batch, self.cfg.n_ctx]));
            }
        }
        self.push_params(w, self.cfg.param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let out = self.rt.execute(&art, &inputs)?;
        Ok(out[0].data()[0])
    }
}
