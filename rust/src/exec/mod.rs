//! Model executor: stitches embed → blocks → head from per-block artifacts.
//!
//! Because weights are graph *arguments*, the same executor runs dense,
//! pruned, and compensated models — it derives the artifact shape key from
//! the actual weight shapes in the store. Capture mode additionally returns
//! each layer's MLP hidden activations and per-head Q/K (the calibration
//! signals of Alg. 1).
//!
//! For serving there is a fused fast path: [`Executor::forward_plan`]
//! resolves every parameter reference once (by-name lookups are hoisted out
//! of the request loop) and returns a batch-polymorphic [`ForwardPlan`]
//! that dispatches the whole network as a single `fwd_*` artifact at the
//! pruned dims read off the stored weight shapes. The plan is bound to a
//! model *variant*, not a batch size: an interior per-batch-size artifact
//! cache lets the native backend run any batch at its true size, while
//! fixed-shape backends (gated PJRT) keep padding to one artifact batch.
//!
//! Autoregressive generation gets its own resolved fast path:
//! [`Executor::decode_plan`] returns a [`DecodePlan`] that drives the
//! incremental `dec_*` artifact. Per-sequence K/V lives in fixed-size
//! blocks of a shared, refcounted [`kv_pool::KvPool`]: a [`DecodeState`]
//! holds a block *table* rather than an owned full-`n_ctx` slab, the
//! interpreter appends each step's new rows into the blocks in place (zero
//! cache copy per step — traffic scales with tokens fed, not context
//! capacity), identical prompt prefixes share blocks across sequences, and
//! forks copy-on-write at the first divergent block. On runtimes that
//! prefer fixed shapes (gated PJRT, where `dec_*` has no AOT lowering) the
//! plan falls back to full prefill-per-step through the fused `fwd_*`
//! artifact ([`DecodeMode::Prefill`]) — same outputs, more arithmetic.

pub mod kv_pool;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{bail, Context, Result};

pub use kv_pool::{KvPool, KvPoolOpts, KvPoolStats, PagedSeq};

use crate::linalg::QuantMat;
use crate::model::{is_q8_param, LayerDims, ModelConfig, ModelKind, QuantStore, WeightStore};
use crate::runtime::native::forward::PagedKv;
use crate::runtime::{Input, Runtime};
use crate::tensor::Tensor;
use crate::util::lock;

/// First-max argmax over a logits row (shared by serving and generation).
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best as i32
}

/// A resolved parameter reference held by a dispatch plan: an f32 tensor
/// borrowed from a [`WeightStore`], or an int8 matrix borrowed from a
/// [`QuantStore`] (the `quantize` weight transform). Plans map these to
/// runtime [`Input`]s at dispatch; the `_w8` artifact suffix tells the
/// interpreter which parameter slots arrive quantized.
enum ParamRef<'w> {
    F32(&'w Tensor),
    Q8(&'w QuantMat),
}

impl<'w> ParamRef<'w> {
    fn input(&self) -> Input<'w> {
        match self {
            ParamRef::F32(t) => Input::F32(t),
            ParamRef::Q8(qm) => {
                Input::Q8 { data: &qm.data, scales: &qm.scales, din: qm.din, dout: qm.dout }
            }
        }
    }
}

/// Interior batch-size → artifact-name cache shared by the dispatch plans:
/// names are formatted on first use per batch size and returned as shared
/// [`Arc`] handles (identity is observable — tests assert reuse), so plans
/// stay `Sync` and a steady-state request loop never re-formats a name.
struct ArtCache(RwLock<HashMap<usize, Arc<str>>>);

impl ArtCache {
    fn new() -> Self {
        Self(RwLock::new(HashMap::new()))
    }

    fn get(&self, batch: usize, make: impl FnOnce() -> String) -> Arc<str> {
        if let Some(a) = lock::read(&self.0).get(&batch) {
            return a.clone();
        }
        lock::write(&self.0).entry(batch).or_insert_with(|| Arc::from(make())).clone()
    }

    fn len(&self) -> usize {
        lock::read(&self.0).len()
    }
}

/// Per-layer calibration capture (dense model).
pub struct LayerCapture {
    /// Post-GELU MLP hidden activations [B, n, o].
    pub hidden: Tensor,
    /// Per-head queries [B, h, n, dh] (pre-scale, bias included).
    pub q: Tensor,
    /// Per-head keys [B, h, n, dh].
    pub k: Tensor,
}

pub struct Executor<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: &'static ModelConfig,
}

/// A batch-polymorphic resolved full-forward dispatch: every parameter
/// tensor in canonical `param_spec_at(dqk, o)` order, resolved once per
/// model *variant* by [`Executor::forward_plan`]. Each call then costs one
/// input-list assembly and one runtime dispatch of the fused `fwd_*`
/// artifact at the batch size of the data actually handed in — the fixed
/// artifact-batch binding (and the caller-side padding it forced) is gone.
///
/// Fused artifact names are formatted on first use per batch size and kept
/// in an interior cache behind a [`RwLock`], so the plan stays `Sync` (the
/// serving engine shares one per variant across all worker threads) and a
/// steady-state request loop never re-formats a name.
pub struct ForwardPlan<'rt, 'w> {
    rt: &'rt Runtime,
    pub cfg: &'static ModelConfig,
    /// Retained per-head q/k width of block 0 (on uniform stores, of every
    /// block — the usual serving case).
    pub dqk: usize,
    /// Retained MLP hidden width of block 0 (uniform stores: every block).
    pub o: usize,
    /// Per-layer retained dims read off the stored weight shapes. Uniform
    /// stores dispatch the classic `fwd_*_q{dqk}_o{o}` family; stores
    /// written by the global FLOPs allocator dispatch the layered
    /// `fwd_*_qv..._ov...` family.
    dims: LayerDims,
    params: Vec<ParamRef<'w>>,
    /// Serve the int8 weight-quantized (`_w8`) artifact family.
    w8: bool,
    /// batch size → fused artifact name (interior per-batch-size cache).
    arts: ArtCache,
}

impl ForwardPlan<'_, '_> {
    /// The fused artifact name this plan dispatches at `batch`, cached so
    /// repeat callers share one allocation per batch size ([`Arc`] handle
    /// identity is observable — tests assert reuse).
    pub fn artifact(&self, batch: usize) -> Arc<str> {
        self.arts.get(batch, || {
            let mut s = match self.dims.as_uniform() {
                Some((dqk, o)) => self.cfg.fwd_artifact(dqk, o, batch),
                None => self.cfg.fwd_artifact_layered(&self.dims, batch),
            };
            if self.w8 {
                s.push_str("_w8");
            }
            s
        })
    }

    /// Per-layer retained dims this plan was resolved at.
    pub fn layer_dims(&self) -> &LayerDims {
        &self.dims
    }

    /// Does this plan serve int8-quantized block projections?
    pub fn is_quantized(&self) -> bool {
        self.w8
    }

    /// Number of batch sizes resolved so far (cache telemetry).
    pub fn cached_batch_sizes(&self) -> usize {
        self.arts.len()
    }

    fn dispatch(&self, data: Input<'_>, art: &str) -> Result<Tensor> {
        let mut inputs: Vec<Input> = Vec::with_capacity(1 + self.params.len());
        inputs.push(data);
        inputs.extend(self.params.iter().map(|p| p.input()));
        let mut out = self.rt.execute(art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Fused vit forward at the batch size of `tokens`
    /// `[batch, patches, patch_dim]` → logits `[batch, classes]`.
    pub fn run_vit(&self, tokens: &Tensor) -> Result<Tensor> {
        if self.cfg.kind != ModelKind::Vit {
            bail!("run_vit on a gpt forward plan");
        }
        let shape = tokens.shape();
        if shape.len() != 3 || shape[1] != self.cfg.patches || shape[2] != self.cfg.patch_dim {
            bail!(
                "run_vit: tokens shape {shape:?}, expected [b, {}, {}]",
                self.cfg.patches,
                self.cfg.patch_dim
            );
        }
        let batch = shape[0];
        if batch == 0 {
            bail!("run_vit: empty batch");
        }
        let art = self.artifact(batch);
        self.dispatch(Input::F32(tokens), &art)
    }

    /// Fused gpt forward: ids `[batch * n_ctx]` → logits
    /// `[batch, n_ctx, vocab]`.
    pub fn run_gpt(&self, ids: &[i32], batch: usize) -> Result<Tensor> {
        if self.cfg.kind != ModelKind::Gpt {
            bail!("run_gpt on a vit forward plan");
        }
        if batch == 0 || ids.len() != batch * self.cfg.n_ctx {
            bail!(
                "run_gpt: {} ids for batch {batch} (expected {})",
                ids.len(),
                batch * self.cfg.n_ctx
            );
        }
        let art = self.artifact(batch);
        self.dispatch(Input::I32(ids, vec![batch, self.cfg.n_ctx]), &art)
    }
}

/// How a [`DecodePlan`] computes each autoregressive step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeMode {
    /// Incremental attention through the `dec_*` artifact: each step embeds
    /// only the new positions and attends over the per-layer K/V cache —
    /// one position's worth of projection GEMMs per generated token.
    KvCache,
    /// Re-run the full `fwd_*` prefill over the whole (padded) sequence
    /// every step and read the logits at the current position. The only
    /// decode available to fixed-shape runtimes (no `dec_*` AOT lowering),
    /// and the bench baseline the KV cache is measured against.
    Prefill,
}

impl DecodeMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "kv" => DecodeMode::KvCache,
            "prefill" => DecodeMode::Prefill,
            _ => bail!("decode mode must be kv|prefill, got '{s}'"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            DecodeMode::KvCache => "kv",
            DecodeMode::Prefill => "prefill",
        }
    }

    /// Collapse to the mode actually usable on a backend: a runtime that
    /// prefers fixed shapes keeps full prefill-per-step — the incremental
    /// `dec_*` family has no AOT lowering there.
    pub fn resolve(self, fixed_shapes: bool) -> Self {
        if fixed_shapes {
            DecodeMode::Prefill
        } else {
            self
        }
    }
}

/// Per-sequence decode state owned by the caller: the token history plus
/// (in [`DecodeMode::KvCache`]) a paged K/V sequence — a table of
/// fixed-size pool blocks that grows with the tokens actually fed, in
/// place, instead of a full-`n_ctx` slab copied through every dispatch.
/// Blocks covering a shared prompt prefix may be referenced by several
/// states at once (read-only); the first divergent append copies. Dropping
/// the state releases its blocks back to the pool.
pub struct DecodeState {
    ids: Vec<i32>,
    /// `Some` for KV-cache plans; prefill-per-step keeps ids only.
    paged: Option<PagedSeq>,
}

impl DecodeState {
    /// Number of positions decoded so far (prompt + generated).
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Token history (prompt + appended continuations).
    pub fn ids(&self) -> &[i32] {
        &self.ids
    }

    /// Pool blocks this sequence holds (0 for prefill-mode states).
    pub fn kv_blocks(&self) -> usize {
        self.paged.as_ref().map_or(0, |s| s.blocks())
    }

    /// A branch of this sequence sharing every K/V block: both sides keep
    /// decoding independently, and the first append into the shared tail
    /// block copies it (copy-on-write) — the speculative-decode /
    /// best-of-n primitive.
    pub fn fork(&self) -> DecodeState {
        DecodeState { ids: self.ids.clone(), paged: self.paged.as_ref().map(|s| s.fork()) }
    }
}

/// A batch-polymorphic resolved *decode* dispatch (gpt only): parameters
/// resolved once per model variant like [`ForwardPlan`], plus the decode
/// mode. [`DecodePlan::extend_at`] advances a batch of sequences by their
/// new tokens in one fused dispatch — sequences with different cache
/// lengths and different new-token counts batch together (per-sequence
/// `past`/`fresh` lengths ride along; padding rows are masked out), which
/// is what lets the serving engine batch decode steps from different
/// requests. The plan is `Sync`; the per-sequence mutable state lives in
/// caller-owned [`DecodeState`]s.
pub struct DecodePlan<'rt, 'w> {
    rt: &'rt Runtime,
    pub cfg: &'static ModelConfig,
    /// Retained per-head q/k width derived from the stored `attn.wq` shape.
    pub dqk: usize,
    /// Retained MLP hidden width derived from the stored `mlp.w1` shape.
    pub o: usize,
    /// How steps are computed (KV-cache incremental vs prefill-per-step).
    /// Fixed at construction, so one name cache serves the plan.
    pub mode: DecodeMode,
    params: Vec<ParamRef<'w>>,
    /// Serve the int8 weight-quantized (`_w8`) artifact family.
    w8: bool,
    arts: ArtCache,
    /// Paged block allocator behind every KV-cache sequence of this plan
    /// (`None` in prefill mode, which keeps no cache at all).
    pool: Option<Arc<KvPool>>,
    /// KV-cache dispatches so far (telemetry).
    kv_steps: AtomicU64,
    /// Cache-management bytes so far: K+V rows appended into pool blocks.
    /// Paged appends touch only the fresh rows, so this grows with tokens
    /// fed — independent of `n_ctx` capacity (the old slab path copied
    /// full-capacity caches in and out of every dispatch).
    kv_bytes: AtomicU64,
}

impl DecodePlan<'_, '_> {
    /// The artifact name one step dispatches at `batch` under this plan's
    /// mode (`dec_*` for KV-cache, `fwd_*` for prefill-per-step), cached
    /// per batch size like [`ForwardPlan::artifact`].
    pub fn artifact(&self, batch: usize) -> Arc<str> {
        self.arts.get(batch, || {
            let mut s = match self.mode {
                DecodeMode::KvCache => self.cfg.dec_artifact(self.dqk, self.o, batch),
                DecodeMode::Prefill => self.cfg.fwd_artifact(self.dqk, self.o, batch),
            };
            if self.w8 {
                s.push_str("_w8");
            }
            s
        })
    }

    /// Does this plan serve int8-quantized block projections?
    pub fn is_quantized(&self) -> bool {
        self.w8
    }

    /// Pre-format the artifact name at `batch` (engine warmup).
    pub fn warm_names(&self, batch: usize) {
        let _ = self.artifact(batch);
    }

    /// Number of batch sizes resolved so far (cache telemetry).
    pub fn cached_batch_sizes(&self) -> usize {
        self.arts.len()
    }

    /// A fresh empty sequence state for this plan. Blocks are allocated
    /// lazily as tokens arrive; prefill-per-step never touches a cache.
    pub fn begin(&self) -> DecodeState {
        let paged = self.pool.as_ref().map(|p| PagedSeq::new(p.clone()));
        DecodeState { ids: Vec::with_capacity(self.cfg.n_ctx), paged }
    }

    /// Begin a sequence for `prompt`, adopting shared prompt-prefix blocks
    /// registered by earlier sequences (see [`DecodePlan::share_prefix`])
    /// when the pool finds a full-block match. Returns the state plus the
    /// number of adopted positions `skip` — the caller feeds
    /// `prompt[skip..]`, which is never empty (at most `prompt.len() - 1`
    /// positions are adopted, so the first extend still yields the
    /// prompt's next-token logits). Adopted rows were computed by the
    /// registering sequence with per-row arithmetic identical to a fresh
    /// prefill, so downstream logits are unchanged.
    pub fn begin_prompt(&self, prompt: &[i32]) -> Result<(DecodeState, usize)> {
        if prompt.is_empty() {
            bail!("begin_prompt: empty prompt");
        }
        if prompt.len() > self.cfg.n_ctx {
            bail!(
                "begin_prompt: {} prompt positions exceed n_ctx {}",
                prompt.len(),
                self.cfg.n_ctx
            );
        }
        let Some(pool) = &self.pool else {
            return Ok((self.begin(), 0));
        };
        let (seq, skip) = PagedSeq::begin(pool, prompt);
        Ok((DecodeState { ids: prompt[..skip].to_vec(), paged: Some(seq) }, skip))
    }

    /// Publish the first `upto` positions of `st` (full blocks only) in
    /// the pool's prefix registry, so later [`DecodePlan::begin_prompt`]
    /// calls with the same opening adopt the K/V blocks instead of
    /// recomputing the prefill. No-op for prefill-mode plans and pools
    /// with sharing disabled.
    pub fn share_prefix(&self, st: &DecodeState, upto: usize) -> Result<()> {
        if upto > st.len() {
            bail!("share_prefix: {upto} positions of a {}-long sequence", st.len());
        }
        if let Some(seq) = &st.paged {
            seq.register_prefix(&st.ids[..upto]);
        }
        Ok(())
    }

    /// Cache-traffic counters: `(kv dispatches, K/V bytes appended)`.
    pub fn kv_counters(&self) -> (u64, u64) {
        (self.kv_steps.load(Ordering::Relaxed), self.kv_bytes.load(Ordering::Relaxed))
    }

    /// Block-pool telemetry (`None` for prefill-mode plans).
    pub fn pool_stats(&self) -> Option<KvPoolStats> {
        self.pool.as_ref().map(|p| p.stats())
    }

    /// [`DecodePlan::extend_at`] at the batch's true size.
    pub fn extend(
        &self,
        states: &mut [&mut DecodeState],
        new: &[&[i32]],
    ) -> Result<Vec<Vec<f32>>> {
        let b = states.len();
        self.extend_at(states, new, b)
    }

    /// Advance each sequence by its `new` tokens in one fused dispatch at
    /// batch size `dispatch ≥ states.len()` (rows past `states.len()` are
    /// inert padding — the engine's padded dispatch policy), appending the
    /// tokens (and, in KV mode, the new per-layer K/V rows) to each state.
    /// Returns, per sequence, the logits rows at its new positions
    /// (`new[e].len() * vocab` values; the last row is the next-token
    /// distribution). Outputs are per-example and independent of batch
    /// composition, dispatch size, and mode — asserted by
    /// `tests/decode_equality`.
    pub fn extend_at(
        &self,
        states: &mut [&mut DecodeState],
        new: &[&[i32]],
        dispatch: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let n = self.cfg.n_ctx;
        if states.is_empty() || states.len() != new.len() || dispatch < states.len() {
            bail!(
                "extend_at: {} states / {} token slices into dispatch size {dispatch}",
                states.len(),
                new.len()
            );
        }
        for (e, (st, toks)) in states.iter().zip(new).enumerate() {
            if toks.is_empty() {
                bail!("extend_at: sequence {e} has no new tokens");
            }
            if st.len() + toks.len() > n {
                bail!(
                    "extend_at: sequence {e} would grow to {} positions (n_ctx {n})",
                    st.len() + toks.len()
                );
            }
        }
        match self.mode {
            DecodeMode::KvCache => self.extend_kv(states, new, dispatch),
            DecodeMode::Prefill => self.extend_prefill(states, new, dispatch),
        }
    }

    fn extend_kv(
        &self,
        states: &mut [&mut DecodeState],
        new: &[&[i32]],
        dispatch: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let (l, h) = (self.cfg.layers, self.cfg.heads);
        let (dqk, dh, vocab) = (self.dqk, self.cfg.dh(), self.cfg.vocab);
        let b = dispatch;
        let m = new.iter().map(|t| t.len()).max().unwrap();
        let mut ids = vec![0i32; b * m];
        // Padding rows carry inert lengths; the paged interpreter runs no
        // work for examples beyond the live block tables.
        let mut past = vec![0i32; b];
        let mut fresh = vec![1i32; b];
        for (e, (st, toks)) in states.iter_mut().zip(new).enumerate() {
            let Some(seq) = st.paged.as_mut() else {
                bail!(
                    "extend_at: sequence {e} state was not created by a kv-cache plan \
                     (no paged cache; prefill-mode states carry ids only)"
                );
            };
            let dims = seq.pool().dims();
            if dims != (l, h, dqk, dh) {
                bail!(
                    "extend_at: sequence {e} state was not created by a kv-cache plan \
                     of these dims (pool {dims:?}, plan ({l}, {h}, {dqk}, {dh}))"
                );
            }
            // Make the appended positions writable up front: copy-on-write
            // a shared tail block, allocate fresh blocks. On error the
            // sequence keeps its committed length — extra capacity is
            // reclaimed when the state drops.
            seq.prepare_append(toks.len())?;
            ids[e * m..e * m + toks.len()].copy_from_slice(toks);
            past[e] = st.ids.len() as i32;
            fresh[e] = toks.len() as i32;
        }
        let views: Vec<PagedKv> =
            states.iter().map(|st| st.paged.as_ref().unwrap().view()).collect();
        let art = self.artifact(b);
        let params: Vec<Input> = self.params.iter().map(|p| p.input()).collect();
        let logits = self.rt.execute_decode_paged(&art, &ids, &past, &fresh, &views, &params)?;
        // The interpreter wrote the new K/V rows into the blocks in place;
        // commit the lengths and account the appended rows — the only
        // cache traffic this step caused.
        let row_bytes = l * h * (dqk + dh) * std::mem::size_of::<f32>();
        let mut appended = 0usize;
        let mut rows = Vec::with_capacity(states.len());
        for (e, (st, toks)) in states.iter_mut().zip(new).enumerate() {
            let f = toks.len();
            st.ids.extend_from_slice(toks);
            st.paged.as_mut().unwrap().commit(f);
            appended += f;
            rows.push(logits.data()[e * m * vocab..(e * m + f) * vocab].to_vec());
        }
        self.kv_steps.fetch_add(1, Ordering::Relaxed);
        self.kv_bytes.fetch_add((appended * row_bytes) as u64, Ordering::Relaxed);
        Ok(rows)
    }

    fn extend_prefill(
        &self,
        states: &mut [&mut DecodeState],
        new: &[&[i32]],
        dispatch: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let (n, vocab) = (self.cfg.n_ctx, self.cfg.vocab);
        let b = dispatch;
        // Zero-pad every extended sequence back to the fixed artifact
        // width; causal masking keeps the padding out of the live
        // positions' logits. States are only mutated after the dispatch
        // succeeds, mirroring the KV path's error behaviour.
        let mut ids = vec![0i32; b * n];
        for (e, (st, toks)) in states.iter().zip(new).enumerate() {
            ids[e * n..e * n + st.len()].copy_from_slice(&st.ids);
            ids[e * n + st.len()..e * n + st.len() + toks.len()].copy_from_slice(toks);
        }
        let art = self.artifact(b);
        let mut inputs: Vec<Input> = Vec::with_capacity(1 + self.params.len());
        inputs.push(Input::I32(&ids, vec![b, n]));
        inputs.extend(self.params.iter().map(|p| p.input()));
        let mut out = self.rt.execute(&art, &inputs)?;
        let logits = out.remove(0); // [b, n, vocab]
        let mut rows = Vec::with_capacity(states.len());
        for (e, (st, toks)) in states.iter_mut().zip(new).enumerate() {
            let f = toks.len();
            st.ids.extend_from_slice(toks);
            let len = st.len();
            rows.push(logits.data()[(e * n + len - f) * vocab..(e * n + len) * vocab].to_vec());
        }
        Ok(rows)
    }

    /// Greedy generation driver for one sequence: prefill `prompt` in one
    /// step, then `steps − 1` single-token decode steps feeding back each
    /// argmax. Returns the `steps` predicted token ids and the logits row
    /// behind each prediction. The final prediction is never appended, so
    /// `prompt.len() + steps − 1 ≤ n_ctx` must hold. Shared prompt-prefix
    /// blocks are adopted and (on completion) registered when the plan's
    /// pool has sharing enabled.
    pub fn greedy(&self, prompt: &[i32], steps: usize) -> Result<(Vec<i32>, Vec<Vec<f32>>)> {
        self.greedy_chunked(prompt, steps, 0)
    }

    /// [`DecodePlan::greedy`] with the prompt prefill split into chunks of
    /// at most `chunk` tokens (`0` = one-shot). Per-row arithmetic is
    /// independent of how positions are grouped into dispatches, so the
    /// generated tokens are identical; the serving engine uses the same
    /// chunking to keep decode ITL flat while a long prompt prefills.
    pub fn greedy_chunked(
        &self,
        prompt: &[i32],
        steps: usize,
        chunk: usize,
    ) -> Result<(Vec<i32>, Vec<Vec<f32>>)> {
        if prompt.is_empty() || steps == 0 {
            // `steps == 0` must be rejected up front: the capacity guard
            // below computes `steps - 1` in usize.
            bail!(
                "greedy: prompt and steps must be non-empty \
                 ({} prompt tokens, {steps} steps)",
                prompt.len()
            );
        }
        if prompt.len() + steps - 1 > self.cfg.n_ctx {
            bail!(
                "greedy: {} prompt + {steps} generated positions exceed n_ctx {}",
                prompt.len(),
                self.cfg.n_ctx
            );
        }
        let vocab = self.cfg.vocab;
        let (mut st, skip) = self.begin_prompt(prompt)?;
        let mut pending = &prompt[skip..];
        // Feed all but the final prompt chunk; their logits are interior
        // rows the greedy loop never reads.
        while chunk > 0 && pending.len() > chunk {
            let (head, rest) = pending.split_at(chunk);
            self.extend(&mut [&mut st], &[head])?;
            pending = rest;
        }
        let mut toks: Vec<i32> = pending.to_vec();
        let mut preds = Vec::with_capacity(steps);
        let mut rows = Vec::with_capacity(steps);
        for _ in 0..steps {
            let out = self.extend(&mut [&mut st], &[&toks])?;
            let all = out.into_iter().next().expect("extend returned no rows");
            let last = all[all.len() - vocab..].to_vec();
            let p = argmax(&last);
            preds.push(p);
            rows.push(last);
            toks = vec![p];
        }
        // Publish the prompt's full blocks for reuse by later sequences.
        self.share_prefix(&st, prompt.len())?;
        Ok((preds, rows))
    }
}

/// An ordered ladder of prepared plans for the *same* model at different
/// accuracy/latency points, with an atomically switchable active rung.
///
/// CORP's pruned and compensated variants are the same network with
/// arithmetic removed, so a serving member can hold one plan per variant
/// (rung 0 = dense, higher rungs = progressively cheaper degraded plans)
/// and the controller can flip the active rung at batch boundaries
/// without touching the executor or the request stream.
pub struct PlanLadder<T> {
    rungs: Vec<T>,
    active: AtomicUsize,
}

impl<T> PlanLadder<T> {
    /// Build a ladder; rung 0 becomes active. Bails on an empty ladder.
    pub fn new(rungs: Vec<T>) -> Result<Self> {
        if rungs.is_empty() {
            bail!("PlanLadder needs at least one plan rung");
        }
        Ok(PlanLadder { rungs, active: AtomicUsize::new(0) })
    }

    pub fn len(&self) -> usize {
        self.rungs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rungs.is_empty()
    }

    /// Index of the active rung (always in range).
    pub fn active(&self) -> usize {
        self.active.load(Ordering::Acquire).min(self.rungs.len() - 1)
    }

    /// Switch the active rung (clamped into range).
    pub fn set_active(&self, i: usize) {
        self.active.store(i.min(self.rungs.len() - 1), Ordering::Release);
    }

    pub fn get(&self, i: usize) -> Option<&T> {
        self.rungs.get(i)
    }

    /// The active rung's plan.
    pub fn current(&self) -> &T {
        &self.rungs[self.active()]
    }
}

impl<'rt> Executor<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: &'static ModelConfig) -> Self {
        Self { rt, cfg }
    }

    /// Infer (dqk, o) from the stored block-0 weight shapes.
    pub fn stored_dims(&self, w: &WeightStore) -> Result<(usize, usize)> {
        let wq = w.expect("blocks.0.attn.wq")?;
        let w1 = w.expect("blocks.0.mlp.w1")?;
        Ok((wq.shape()[1] / self.cfg.heads, w1.shape()[1]))
    }

    /// Infer per-layer (dqk, o) from *each* stored block's weight shapes —
    /// the source of truth for stores written by the global FLOPs
    /// allocator, where retained widths differ across layers.
    pub fn stored_layer_dims(&self, w: &WeightStore) -> Result<LayerDims> {
        let mut dqk = Vec::with_capacity(self.cfg.layers);
        let mut o = Vec::with_capacity(self.cfg.layers);
        for l in 0..self.cfg.layers {
            let wq = w.expect(&format!("blocks.{l}.attn.wq"))?;
            let w1 = w.expect(&format!("blocks.{l}.mlp.w1"))?;
            dqk.push(wq.shape()[1] / self.cfg.heads);
            o.push(w1.shape()[1]);
        }
        Ok(LayerDims { dqk, o })
    }

    fn push_params<'a>(
        &self,
        w: &'a WeightStore,
        names: impl Iterator<Item = String>,
        inputs: &mut Vec<Input<'a>>,
    ) -> Result<()> {
        for name in names {
            let t = w.expect(&name)?;
            inputs.push(Input::F32(t));
        }
        Ok(())
    }

    /// Run the embedding graph. vit: `tokens` [B, P, pd]; gpt: `ids` via
    /// `forward_gpt`.
    pub fn embed(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let art = self.cfg.embed_artifact(batch);
        let mut inputs: Vec<Input> = vec![Input::F32(tokens)];
        self.push_params(w, self.cfg.embed_param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    pub fn embed_gpt(&self, w: &WeightStore, ids: &[i32], batch: usize) -> Result<Tensor> {
        if self.cfg.kind != ModelKind::Gpt {
            bail!("embed_gpt on a vit config");
        }
        let art = self.cfg.embed_artifact(batch);
        let shape = vec![batch, self.cfg.n_ctx];
        let mut inputs: Vec<Input> = vec![Input::I32(ids, shape)];
        self.push_params(w, self.cfg.embed_param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Run one block (layer index `l`) on x [B, n, d]. Dims come from layer
    /// `l`'s *own* stored weight shapes, so the stitched path serves
    /// non-uniform (globally allocated) stores through the existing
    /// per-shape `block_*` artifacts.
    pub fn block(&self, w: &WeightStore, l: usize, x: &Tensor, batch: usize) -> Result<Tensor> {
        let wq = w.expect(&format!("blocks.{l}.attn.wq"))?;
        let w1 = w.expect(&format!("blocks.{l}.mlp.w1"))?;
        let (dqk, o) = (wq.shape()[1] / self.cfg.heads, w1.shape()[1]);
        let art = self.cfg.block_artifact(dqk, o, batch);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        self.push_params(
            w,
            self.cfg.block_param_spec(dqk, o).into_iter().map(|(n, _)| format!("blocks.{l}.{n}")),
            &mut inputs,
        )?;
        let mut out = self
            .rt
            .execute(&art, &inputs)
            .with_context(|| format!("block layer {l} artifact {art}"))?;
        Ok(out.remove(0))
    }

    /// Run one block through the attention-free (DC-ViT-like) artifact.
    pub fn block_mlponly(&self, w: &WeightStore, l: usize, x: &Tensor, batch: usize) -> Result<Tensor> {
        let w1 = w.expect(&format!("blocks.{l}.mlp.w1"))?;
        let o = w1.shape()[1];
        let art = format!("mlponly_{}_o{o}_b{batch}", self.cfg.name);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        for n in ["ln2.g", "ln2.b", "mlp.w1", "mlp.b1", "mlp.w2", "mlp.b2"] {
            inputs.push(Input::F32(w.expect(&format!("blocks.{l}.{n}"))?));
        }
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Run one block in capture mode (dense shapes only).
    pub fn block_capture(
        &self,
        w: &WeightStore,
        l: usize,
        x: &Tensor,
    ) -> Result<(Tensor, LayerCapture)> {
        let art = self.cfg.blockcap_artifact();
        let (dqk, o) = (self.cfg.dh(), self.cfg.mlp);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        self.push_params(
            w,
            self.cfg.block_param_spec(dqk, o).into_iter().map(|(n, _)| format!("blocks.{l}.{n}")),
            &mut inputs,
        )?;
        let mut out = self.rt.execute(&art, &inputs)?;
        if out.len() != 4 {
            bail!("capture artifact returned {} outputs", out.len());
        }
        let k = out.remove(3);
        let q = out.remove(2);
        let hidden = out.remove(1);
        let y = out.remove(0);
        Ok((y, LayerCapture { hidden, q, k }))
    }

    /// Run the classification / LM head on x [B, n, d].
    pub fn head(&self, w: &WeightStore, x: &Tensor, batch: usize) -> Result<Tensor> {
        let art = self.cfg.head_artifact(batch);
        let mut inputs: Vec<Input> = vec![Input::F32(x)];
        self.push_params(w, self.cfg.head_param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// Final-layernorm features [B, n, d] (dense-task backbone output).
    pub fn features(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let x = self.forward_backbone(w, tokens, batch)?;
        let art = self.cfg.lnf_artifact();
        let inputs: Vec<Input> = vec![
            Input::F32(&x),
            Input::F32(w.expect("head.ln.g")?),
            Input::F32(w.expect("head.ln.b")?),
        ];
        let mut out = self.rt.execute(&art, &inputs)?;
        Ok(out.remove(0))
    }

    /// embed + all blocks (no head).
    pub fn forward_backbone(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let mut x = self.embed(w, tokens, batch)?;
        for l in 0..self.cfg.layers {
            x = self.block(w, l, &x, batch)?;
        }
        Ok(x)
    }

    /// Full forward: vit logits [B, classes].
    pub fn forward_vit(&self, w: &WeightStore, tokens: &Tensor, batch: usize) -> Result<Tensor> {
        let x = self.forward_backbone(w, tokens, batch)?;
        self.head(w, &x, batch)
    }

    /// Resolve the batch-polymorphic full-forward fast path for `w`:
    /// derives `(dqk, o)` from the stored weight shapes and resolves every
    /// parameter tensor in canonical order — once per model *variant*, not
    /// per batch size. The returned [`ForwardPlan`] is `Sync` (it borrows
    /// the runtime and the weight store immutably; the artifact-name cache
    /// is behind a lock), so the serving engine shares one per variant
    /// across all worker threads and dispatches any batch at its true size.
    pub fn forward_plan<'w>(&self, w: &'w WeightStore) -> Result<ForwardPlan<'rt, 'w>> {
        let (dims, params) = self.resolve_params(w)?;
        let (dqk, o) = (dims.dqk[0], dims.o[0]);
        Ok(ForwardPlan {
            rt: self.rt,
            cfg: self.cfg,
            dqk,
            o,
            dims,
            params,
            w8: false,
            arts: ArtCache::new(),
        })
    }

    /// [`Executor::forward_plan`] over an int8 weight-quantized store: the
    /// six per-block GEMM projections dispatch as [`Input::Q8`] and the
    /// plan serves the `_w8` artifact family (native backend only). The
    /// non-quantized remainder resolves from the store's f32 base exactly
    /// like the dense path.
    pub fn forward_plan_q8<'w>(&self, qs: &'w QuantStore) -> Result<ForwardPlan<'rt, 'w>> {
        let (dqk, o, params) = self.resolve_params_q8(qs)?;
        Ok(ForwardPlan {
            rt: self.rt,
            cfg: self.cfg,
            dqk,
            o,
            dims: LayerDims::uniform(self.cfg, dqk, o),
            params,
            w8: true,
            arts: ArtCache::new(),
        })
    }

    /// Resolve per-layer dims and every parameter tensor in canonical
    /// `param_spec_layered` order — the shared front half of the dispatch
    /// plans. At uniform dims the spec (and order) is identical to
    /// `param_spec_at`, so uniform stores behave exactly as before.
    fn resolve_params<'w>(&self, w: &'w WeightStore) -> Result<(LayerDims, Vec<ParamRef<'w>>)> {
        let dims = self.stored_layer_dims(w)?;
        let spec = self.cfg.param_spec_layered(&dims);
        let mut params = Vec::with_capacity(spec.len());
        for (name, shape) in &spec {
            let t = w.expect(name)?;
            if t.shape() != shape.as_slice() {
                bail!(
                    "resolve_params: weight '{name}' has shape {:?}, expected {shape:?}",
                    t.shape()
                );
            }
            params.push(ParamRef::F32(t));
        }
        Ok((dims, params))
    }

    /// Infer (dqk, o) from the quantized block-0 projection shapes.
    pub fn stored_dims_q8(&self, qs: &QuantStore) -> Result<(usize, usize)> {
        let wq = qs
            .shape_of("blocks.0.attn.wq")
            .context("missing quantized weight 'blocks.0.attn.wq'")?;
        let w1 = qs
            .shape_of("blocks.0.mlp.w1")
            .context("missing quantized weight 'blocks.0.mlp.w1'")?;
        Ok((wq[1] / self.cfg.heads, w1[1]))
    }

    /// [`Executor::resolve_params`] over a [`QuantStore`]: the per-block
    /// GEMM projections resolve to int8 matrices, everything else to f32
    /// tensors from the base store, in the same canonical order.
    fn resolve_params_q8<'w>(
        &self,
        qs: &'w QuantStore,
    ) -> Result<(usize, usize, Vec<ParamRef<'w>>)> {
        let mut q_dims = LayerDims { dqk: Vec::new(), o: Vec::new() };
        for l in 0..self.cfg.layers {
            let wq = qs
                .shape_of(&format!("blocks.{l}.attn.wq"))
                .with_context(|| format!("missing quantized weight 'blocks.{l}.attn.wq'"))?;
            let w1 = qs
                .shape_of(&format!("blocks.{l}.mlp.w1"))
                .with_context(|| format!("missing quantized weight 'blocks.{l}.mlp.w1'"))?;
            q_dims.dqk.push(wq[1] / self.cfg.heads);
            q_dims.o.push(w1[1]);
        }
        if q_dims.as_uniform().is_none() {
            bail!(
                "int8 serving requires uniform per-layer dims (the _w8 artifact family \
                 has no layered lowering); store has per-layer dqk {:?} / mlp {:?}",
                q_dims.dqk,
                q_dims.o
            );
        }
        let (dqk, o) = self.stored_dims_q8(qs)?;
        let spec = self.cfg.param_spec_at(dqk, o);
        let mut params = Vec::with_capacity(spec.len());
        for (name, shape) in &spec {
            if is_q8_param(name) {
                let qm = qs.expect_q(name)?;
                if [qm.din, qm.dout] != shape.as_slice() {
                    bail!(
                        "resolve_params_q8: weight '{name}' has shape [{}, {}], expected {shape:?}",
                        qm.din,
                        qm.dout
                    );
                }
                params.push(ParamRef::Q8(qm));
            } else {
                let t = qs.base().expect(name)?;
                if t.shape() != shape.as_slice() {
                    bail!(
                        "resolve_params_q8: weight '{name}' has shape {:?}, expected {shape:?}",
                        t.shape()
                    );
                }
                params.push(ParamRef::F32(t));
            }
        }
        Ok((dqk, o, params))
    }

    /// Resolve the autoregressive-decode fast path for `w` (gpt configs
    /// only), mode auto-selected: [`DecodeMode::KvCache`] unless the
    /// runtime prefers fixed shapes, where only prefill-per-step has an
    /// AOT lowering.
    pub fn decode_plan<'w>(&self, w: &'w WeightStore) -> Result<DecodePlan<'rt, 'w>> {
        self.decode_plan_with(w, DecodeMode::KvCache.resolve(self.rt.prefers_fixed_shapes()))
    }

    /// [`Executor::decode_plan`] at an explicit [`DecodeMode`] (the bench
    /// harness pins both modes to measure the KV-cache speedup), with
    /// default pool knobs.
    pub fn decode_plan_with<'w>(
        &self,
        w: &'w WeightStore,
        mode: DecodeMode,
    ) -> Result<DecodePlan<'rt, 'w>> {
        self.decode_plan_opts(w, mode, KvPoolOpts::default())
    }

    /// [`Executor::decode_plan_with`] with explicit [`KvPoolOpts`] (block
    /// size, pool cap, prefix sharing) — the serving engine and the CLI
    /// size the pool here. The pool is created per plan; sequences of one
    /// plan share blocks, plans do not.
    pub fn decode_plan_opts<'w>(
        &self,
        w: &'w WeightStore,
        mode: DecodeMode,
        pool_opts: KvPoolOpts,
    ) -> Result<DecodePlan<'rt, 'w>> {
        if self.cfg.kind != ModelKind::Gpt {
            bail!("decode_plan on non-gpt model '{}'", self.cfg.name);
        }
        let (dims, params) = self.resolve_params(w)?;
        let Some((dqk, o)) = dims.as_uniform() else {
            bail!(
                "decode plans require uniform per-layer dims (the dec_* artifact family \
                 has no layered lowering); store has per-layer dqk {:?} / mlp {:?}",
                dims.dqk,
                dims.o
            );
        };
        self.build_decode_plan(dqk, o, params, false, mode, pool_opts)
    }

    /// [`Executor::decode_plan_opts`] over an int8 weight-quantized store:
    /// decode steps dispatch the `dec_*_w8` (or `fwd_*_w8` in prefill
    /// mode) artifacts with the block projections as [`Input::Q8`].
    pub fn decode_plan_opts_q8<'w>(
        &self,
        qs: &'w QuantStore,
        mode: DecodeMode,
        pool_opts: KvPoolOpts,
    ) -> Result<DecodePlan<'rt, 'w>> {
        if self.cfg.kind != ModelKind::Gpt {
            bail!("decode_plan on non-gpt model '{}'", self.cfg.name);
        }
        let (dqk, o, params) = self.resolve_params_q8(qs)?;
        self.build_decode_plan(dqk, o, params, true, mode, pool_opts)
    }

    fn build_decode_plan<'w>(
        &self,
        dqk: usize,
        o: usize,
        params: Vec<ParamRef<'w>>,
        w8: bool,
        mode: DecodeMode,
        pool_opts: KvPoolOpts,
    ) -> Result<DecodePlan<'rt, 'w>> {
        let pool = match mode {
            DecodeMode::KvCache => {
                Some(KvPool::new(self.cfg.layers, self.cfg.heads, dqk, self.cfg.dh(), pool_opts))
            }
            DecodeMode::Prefill => None,
        };
        Ok(DecodePlan {
            rt: self.rt,
            cfg: self.cfg,
            dqk,
            o,
            mode,
            params,
            w8,
            arts: ArtCache::new(),
            pool,
            kv_steps: AtomicU64::new(0),
            kv_bytes: AtomicU64::new(0),
        })
    }

    /// Full forward: gpt logits [B, n, vocab].
    pub fn forward_gpt(&self, w: &WeightStore, ids: &[i32], batch: usize) -> Result<Tensor> {
        let mut x = self.embed_gpt(w, ids, batch)?;
        for l in 0..self.cfg.layers {
            x = self.block(w, l, &x, batch)?;
        }
        self.head(w, &x, batch)
    }

    /// Full dense forward with per-layer capture.
    pub fn forward_capture(
        &self,
        w: &WeightStore,
        tokens: Option<&Tensor>,
        ids: Option<&[i32]>,
    ) -> Result<(Tensor, Vec<LayerCapture>)> {
        let batch = self.cfg.eval_batch();
        let mut x = match self.cfg.kind {
            ModelKind::Vit => self.embed(w, tokens.context("vit capture needs tokens")?, batch)?,
            ModelKind::Gpt => self.embed_gpt(w, ids.context("gpt capture needs ids")?, batch)?,
        };
        let mut caps = Vec::with_capacity(self.cfg.layers);
        for l in 0..self.cfg.layers {
            let (y, cap) = self.block_capture(w, l, &x)?;
            x = y;
            caps.push(cap);
        }
        let logits = self.head(w, &x, batch)?;
        Ok((logits, caps))
    }

    /// Mean cross-entropy via the `evloss` artifact (dense shapes only —
    /// used for GPT perplexity and ViT validation loss).
    pub fn eval_loss(
        &self,
        w: &WeightStore,
        tokens: Option<&Tensor>,
        ids: Option<&[i32]>,
        labels: &[i32],
    ) -> Result<f32> {
        let art = self.cfg.evloss_artifact();
        let batch = self.cfg.eval_batch();
        let mut inputs: Vec<Input> = Vec::new();
        match self.cfg.kind {
            ModelKind::Vit => {
                inputs.push(Input::F32(tokens.context("vit evloss needs tokens")?));
                inputs.push(Input::I32(labels, vec![batch]));
            }
            ModelKind::Gpt => {
                inputs.push(Input::I32(ids.context("gpt evloss needs ids")?, vec![batch, self.cfg.n_ctx]));
                inputs.push(Input::I32(labels, vec![batch, self.cfg.n_ctx]));
            }
        }
        self.push_params(w, self.cfg.param_spec().into_iter().map(|(n, _)| n), &mut inputs)?;
        let out = self.rt.execute(&art, &inputs)?;
        Ok(out[0].data()[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-2.0, -1.0]), 1);
    }

    #[test]
    fn decode_mode_parse_and_resolve() {
        assert_eq!(DecodeMode::parse("kv").unwrap(), DecodeMode::KvCache);
        assert_eq!(DecodeMode::parse("prefill").unwrap(), DecodeMode::Prefill);
        assert!(DecodeMode::parse("bogus").is_err());
        for m in [DecodeMode::KvCache, DecodeMode::Prefill] {
            assert_eq!(DecodeMode::parse(m.label()).unwrap(), m);
            // Fixed-shape backends collapse to prefill-per-step.
            assert_eq!(m.resolve(true), DecodeMode::Prefill);
            assert_eq!(m.resolve(false), m);
        }
    }

    #[test]
    fn quantized_plans_use_w8_artifacts() {
        let rt = Runtime::new(std::env::temp_dir().join("corp_exec_no_artifacts")).unwrap();
        let cfg = ModelConfig::by_name("gpt_s").unwrap();
        let exec = Executor::new(&rt, cfg);
        let w = WeightStore::init(cfg, 3);
        let qs = QuantStore::from_store(cfg, &w).unwrap();

        let fp = exec.forward_plan(&w).unwrap();
        let qp = exec.forward_plan_q8(&qs).unwrap();
        assert!(!fp.is_quantized());
        assert!(qp.is_quantized());
        assert_eq!((qp.dqk, qp.o), (fp.dqk, fp.o));
        assert!(!fp.artifact(4).ends_with("_w8"));
        assert_eq!(*qp.artifact(4), format!("{}_w8", fp.artifact(4)));

        let dp = exec
            .decode_plan_opts_q8(&qs, DecodeMode::KvCache, KvPoolOpts::default())
            .unwrap();
        assert!(dp.is_quantized());
        assert!(dp.artifact(2).starts_with("dec_"));
        assert!(dp.artifact(2).ends_with("_w8"));
    }

    #[test]
    fn nonuniform_store_resolves_layered_plan() {
        let rt = Runtime::new(std::env::temp_dir().join("corp_exec_no_artifacts")).unwrap();
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let exec = Executor::new(&rt, cfg);
        let mut w = WeightStore::init(cfg, 7);
        // Shrink layer 2's MLP hidden width to 100 (allocator-style store).
        let d = cfg.d;
        w.insert("blocks.2.mlp.w1", Tensor::zeros(&[d, 100]));
        w.insert("blocks.2.mlp.b1", Tensor::zeros(&[100]));
        w.insert("blocks.2.mlp.w2", Tensor::zeros(&[100, d]));

        let dims = exec.stored_layer_dims(&w).unwrap();
        assert_eq!(dims.o[2], 100);
        assert_eq!(dims.dqk, vec![cfg.dh(); cfg.layers]);
        assert!(dims.as_uniform().is_none());

        let plan = exec.forward_plan(&w).unwrap();
        assert_eq!(plan.layer_dims(), &dims);
        let art = plan.artifact(4);
        assert!(art.starts_with("fwd_vit_t_qv"), "{art}");
        assert!(art.contains("_ov192-192-100-192-192-192_b4"), "{art}");
    }

    #[test]
    fn nonuniform_store_rejects_decode_and_q8() {
        let rt = Runtime::new(std::env::temp_dir().join("corp_exec_no_artifacts")).unwrap();
        let cfg = ModelConfig::by_name("gpt_s").unwrap();
        let exec = Executor::new(&rt, cfg);
        let mut w = WeightStore::init(cfg, 7);
        let d = cfg.d;
        w.insert("blocks.1.mlp.w1", Tensor::zeros(&[d, 64]));
        w.insert("blocks.1.mlp.b1", Tensor::zeros(&[64]));
        w.insert("blocks.1.mlp.w2", Tensor::zeros(&[64, d]));

        let err = exec.decode_plan(&w).unwrap_err().to_string();
        assert!(err.contains("uniform per-layer dims"), "{err}");
        let qs = QuantStore::from_store(cfg, &w).unwrap();
        let err = exec.forward_plan_q8(&qs).unwrap_err().to_string();
        assert!(err.contains("uniform per-layer dims"), "{err}");
    }

    #[test]
    fn decode_plan_rejects_vit() {
        let rt = Runtime::new(std::env::temp_dir().join("corp_exec_no_artifacts")).unwrap();
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let exec = Executor::new(&rt, cfg);
        let w = WeightStore::init(cfg, 1);
        let err = exec.decode_plan(&w).unwrap_err().to_string();
        assert!(err.contains("non-gpt"), "{err}");
    }
}
