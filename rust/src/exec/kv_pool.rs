//! Paged KV-cache block pool for autoregressive decode.
//!
//! The PR-4 decode path gave every sequence a monolithic K/V slab at full
//! `n_ctx` capacity and copied the whole slab into (and back out of) the
//! dispatch buffers on every step — per-step memory traffic scaled with
//! context *capacity*, not with tokens actually generated. This module
//! replaces the slabs with fixed-size **blocks** owned by a shared
//! [`KvPool`]:
//!
//! * A block holds `block` consecutive positions of every layer/head plane,
//!   laid out `[layers, heads, block, dqk|dh]` (K and V planes side by
//!   side). A sequence is a [`PagedSeq`]: a block *table* (pool indices)
//!   plus a committed length.
//! * Block memory is interior-mutable (`UnsafeCell`): the native
//!   interpreter appends a step's new K/V rows in place through raw plane
//!   pointers ([`PagedSeq::view`]) — zero cache copy per decode step.
//! * Blocks are refcounted. Identical prompt prefixes register their full
//!   blocks in a prefix registry (exact token-vector keys — no hash
//!   collisions by construction) so later sequences *adopt* the blocks
//!   instead of recomputing the prefill; [`PagedSeq::fork`] shares every
//!   block, and an append into a shared partial tail block copies it first
//!   (copy-on-write at the first divergent block).
//!
//! # Safety model
//!
//! All bookkeeping (refcounts, free list, registry, telemetry) lives behind
//! a `Mutex`. Block *data* is written only through a `&mut PagedSeq` whose
//! table entries have refcount 1 beyond the writer (enforced by
//! [`PagedSeq::prepare_append`]: shared tails are copied first, fresh
//! blocks are newly allocated) — so every plane write has an exclusive
//! logical owner. Shared (adopted / forked) blocks are read-only. The
//! publication point between a writer registering a prefix and a reader
//! adopting it is the pool mutex, which gives the required happens-before
//! edge. The backing `Vec<BlockMem>` is append-only and each block's planes
//! are boxed slices, so plane pointers stay stable across pool growth and
//! freed blocks are recycled, never deallocated.

use std::cell::UnsafeCell;
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Result};

use crate::runtime::native::forward::PagedKv;
use crate::util::lock;

/// Upper bound on distinct registered prefixes — keeps the registry (and
/// the blocks it pins) from growing without bound on long serving runs.
const MAX_REGISTRY: usize = 512;

/// Construction knobs for a [`KvPool`].
#[derive(Clone, Copy, Debug)]
pub struct KvPoolOpts {
    /// Positions per block.
    pub block: usize,
    /// Pool capacity in blocks (0 = unbounded).
    pub max_blocks: usize,
    /// Enable the prompt-prefix registry (adopt/register are no-ops when
    /// off; copy-on-write for forks still works).
    pub share_prefixes: bool,
}

impl Default for KvPoolOpts {
    fn default() -> Self {
        Self { block: 16, max_blocks: 0, share_prefixes: true }
    }
}

/// One block's storage: K and V planes, `[layers * heads, block, dqk|dh]`.
struct BlockMem {
    k: Box<[UnsafeCell<f32>]>,
    v: Box<[UnsafeCell<f32>]>,
}

impl BlockMem {
    fn kptr(&self) -> *mut f32 {
        self.k.as_ptr() as *mut f32
    }

    fn vptr(&self) -> *mut f32 {
        self.v.as_ptr() as *mut f32
    }
}

struct PoolState {
    /// Per-block refcount (0 = free).
    refs: Vec<u32>,
    /// Recycled block ids (their stale data is never read: a new owner only
    /// reads rows it has committed).
    free: Vec<u32>,
    /// Exact token prefix (block-multiple length) → the blocks covering it.
    /// The registry holds one refcount on each member block.
    registry: HashMap<Vec<i32>, Vec<u32>>,
    /// Blocks currently referenced (telemetry).
    in_use: usize,
    peak_in_use: usize,
    /// Cumulative block acquisitions through `alloc`.
    allocs: u64,
    /// Cumulative blocks adopted from the registry instead of allocated.
    shared_hits: u64,
    /// Cumulative copy-on-write tail-block copies.
    cow_copies: u64,
}

/// Point-in-time pool telemetry (see module docs).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvPoolStats {
    /// Positions per block.
    pub block_positions: usize,
    /// Bytes of K+V data per block.
    pub block_bytes: usize,
    /// Blocks currently referenced by live sequences or the registry.
    pub blocks_in_use: usize,
    /// High-water mark of `blocks_in_use`.
    pub peak_blocks: usize,
    /// Distinct blocks ever backed with memory.
    pub allocated_blocks: usize,
    /// Cumulative block acquisitions (fresh or recycled).
    pub allocs: u64,
    /// Cumulative blocks adopted from the shared-prefix registry.
    pub shared_hits: u64,
    /// Cumulative copy-on-write tail copies.
    pub cow_copies: u64,
    /// Prefix entries currently registered.
    pub registered_prefixes: usize,
    /// Distinct blocks pinned by the prefix registry. These count toward
    /// `blocks_in_use` even with no live sequence holding them — they are a
    /// deliberate cache, not a leak, so the post-run leak check compares
    /// `blocks_in_use` against this.
    pub registered_blocks: usize,
}

impl KvPoolStats {
    /// Bytes currently referenced / high-water bytes.
    pub fn bytes_in_use(&self) -> u64 {
        (self.blocks_in_use * self.block_bytes) as u64
    }

    pub fn peak_bytes(&self) -> u64 {
        (self.peak_blocks * self.block_bytes) as u64
    }
}

/// Shared block allocator for one decode plan (one model variant's dims).
pub struct KvPool {
    layers: usize,
    heads: usize,
    dqk: usize,
    dh: usize,
    block: usize,
    /// Floats per block K plane (`layers * heads * block * dqk`).
    kplane: usize,
    /// Floats per block V plane (`layers * heads * block * dh`).
    vplane: usize,
    max_blocks: usize,
    share_prefixes: bool,
    /// Append-only block storage; index = block id. Planes are boxed, so
    /// their addresses survive `Vec` growth.
    mem: RwLock<Vec<BlockMem>>,
    state: Mutex<PoolState>,
}

// SAFETY: every PoolState mutation happens under `state`; `mem` is guarded
// by its RwLock and only ever appended to. Block plane data is written
// solely through `&mut PagedSeq` on blocks with no other referent (see the
// module-level safety model) and read either by that same owner or — for
// shared prefix blocks — strictly after publication through the mutex.
unsafe impl Send for KvPool {}
unsafe impl Sync for KvPool {}

impl KvPool {
    /// A pool for caches of `layers * heads` planes at per-head widths
    /// `dqk` (K) and `dh` (V).
    pub fn new(layers: usize, heads: usize, dqk: usize, dh: usize, opts: KvPoolOpts) -> Arc<Self> {
        let block = opts.block.max(1);
        Arc::new(Self {
            layers,
            heads,
            dqk,
            dh,
            block,
            kplane: layers * heads * block * dqk,
            vplane: layers * heads * block * dh,
            max_blocks: opts.max_blocks,
            share_prefixes: opts.share_prefixes,
            mem: RwLock::new(Vec::new()),
            state: Mutex::new(PoolState {
                refs: Vec::new(),
                free: Vec::new(),
                registry: HashMap::new(),
                in_use: 0,
                peak_in_use: 0,
                allocs: 0,
                shared_hits: 0,
                cow_copies: 0,
            }),
        })
    }

    /// Positions per block.
    pub fn block_positions(&self) -> usize {
        self.block
    }

    /// Bytes of K+V data per block.
    pub fn block_bytes(&self) -> usize {
        (self.kplane + self.vplane) * std::mem::size_of::<f32>()
    }

    /// The cache dims this pool serves: `(layers, heads, dqk, dh)`.
    pub fn dims(&self) -> (usize, usize, usize, usize) {
        (self.layers, self.heads, self.dqk, self.dh)
    }

    pub fn stats(&self) -> KvPoolStats {
        let st = lock::lock(&self.state);
        let registered: HashSet<u32> =
            st.registry.values().flat_map(|t| t.iter().copied()).collect();
        KvPoolStats {
            block_positions: self.block,
            block_bytes: self.block_bytes(),
            blocks_in_use: st.in_use,
            peak_blocks: st.peak_in_use,
            allocated_blocks: lock::read(&self.mem).len(),
            allocs: st.allocs,
            shared_hits: st.shared_hits,
            cow_copies: st.cow_copies,
            registered_prefixes: st.registry.len(),
            registered_blocks: registered.len(),
        }
    }

    /// Acquire one block (refcount 1), recycling a freed block when one is
    /// available and growing the pool otherwise.
    fn alloc(&self) -> Result<u32> {
        let mut st = lock::lock(&self.state);
        let id = match st.free.pop() {
            Some(id) => id,
            None => {
                let mut mem = lock::write(&self.mem);
                if self.max_blocks > 0 && mem.len() >= self.max_blocks {
                    bail!(
                        "kv pool exhausted: {} blocks in use of max {} (raise the \
                         pool block cap or lower concurrency)",
                        st.in_use,
                        self.max_blocks
                    );
                }
                let id = mem.len() as u32;
                mem.push(BlockMem {
                    k: (0..self.kplane).map(|_| UnsafeCell::new(0.0)).collect(),
                    v: (0..self.vplane).map(|_| UnsafeCell::new(0.0)).collect(),
                });
                st.refs.push(0);
                id
            }
        };
        debug_assert_eq!(st.refs[id as usize], 0);
        st.refs[id as usize] = 1;
        st.allocs += 1;
        st.in_use += 1;
        st.peak_in_use = st.peak_in_use.max(st.in_use);
        Ok(id)
    }

    fn retain(&self, id: u32) {
        let mut st = lock::lock(&self.state);
        st.refs[id as usize] += 1;
    }

    fn release(&self, id: u32) {
        let mut st = lock::lock(&self.state);
        let rc = &mut st.refs[id as usize];
        debug_assert!(*rc > 0, "release of a free block");
        *rc -= 1;
        if *rc == 0 {
            st.free.push(id);
            st.in_use -= 1;
        }
    }

    fn refcount(&self, id: u32) -> u32 {
        lock::lock(&self.state).refs[id as usize]
    }

    /// Raw (K, V) plane base pointers of `id`. Stable for the pool's
    /// lifetime.
    fn planes(&self, id: u32) -> (*mut f32, *mut f32) {
        let mem = lock::read(&self.mem);
        let bm = &mem[id as usize];
        (bm.kptr(), bm.vptr())
    }

    /// Adopt the longest registered prefix of `prompt` covering at most
    /// `max_positions` positions. On a hit, every matched block gains a
    /// refcount for the caller; returns the block table and the matched
    /// position count.
    fn adopt(&self, prompt: &[i32], max_positions: usize) -> Option<(Vec<u32>, usize)> {
        if !self.share_prefixes {
            return None;
        }
        let max_nb = prompt.len().min(max_positions) / self.block;
        if max_nb == 0 {
            return None;
        }
        let mut st = lock::lock(&self.state);
        for nb in (1..=max_nb).rev() {
            if let Some(blocks) = st.registry.get(&prompt[..nb * self.block]) {
                let table = blocks.clone();
                for &id in &table {
                    st.refs[id as usize] += 1;
                }
                st.shared_hits += table.len() as u64;
                return Some((table, nb * self.block));
            }
        }
        None
    }

    /// Register every block-multiple prefix of `prefix` (already computed
    /// into `table`, full blocks only) for adoption by later sequences. The
    /// registry holds one refcount per membership, so published blocks
    /// outlive the sequence that computed them. Best-effort: stops at the
    /// registry cap.
    fn register(&self, prefix: &[i32], table: &[u32]) {
        if !self.share_prefixes {
            return;
        }
        let nb = (prefix.len() / self.block).min(table.len());
        let mut st = lock::lock(&self.state);
        for k in 1..=nb {
            let key = &prefix[..k * self.block];
            if st.registry.contains_key(key) {
                continue;
            }
            if st.registry.len() >= MAX_REGISTRY {
                return;
            }
            for &id in &table[..k] {
                st.refs[id as usize] += 1;
            }
            st.registry.insert(key.to_vec(), table[..k].to_vec());
        }
    }
}

/// One sequence's slice of the pool: a block table plus the committed
/// position count. Dropping the sequence releases its blocks.
pub struct PagedSeq {
    pool: Arc<KvPool>,
    table: Vec<u32>,
    len: usize,
}

impl PagedSeq {
    pub(crate) fn new(pool: Arc<KvPool>) -> Self {
        Self { pool, table: Vec::new(), len: 0 }
    }

    fn adopted(pool: Arc<KvPool>, table: Vec<u32>, len: usize) -> Self {
        debug_assert_eq!(table.len(), len.div_ceil(pool.block));
        Self { pool, table, len }
    }

    /// Committed K/V positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Blocks currently held.
    pub fn blocks(&self) -> usize {
        self.table.len()
    }

    pub(crate) fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Begin a sequence for `prompt`, adopting shared prefix blocks when the
    /// registry has a match. At most `prompt.len() - 1` positions are
    /// adopted so the caller always computes (and gets logits for) the final
    /// prompt position. Returns `(seq, adopted_positions)`.
    pub(crate) fn begin(pool: &Arc<KvPool>, prompt: &[i32]) -> (Self, usize) {
        match pool.adopt(prompt, prompt.len().saturating_sub(1)) {
            Some((table, matched)) => (Self::adopted(pool.clone(), table, matched), matched),
            None => (Self::new(pool.clone()), 0),
        }
    }

    /// Make the next `fresh` positions writable: copy-on-write the partial
    /// tail block if it is shared, then allocate blocks through position
    /// `len + fresh - 1`. After this call every block that will receive
    /// writes is exclusively owned by this sequence.
    pub(crate) fn prepare_append(&mut self, fresh: usize) -> Result<()> {
        debug_assert_eq!(self.table.len(), self.len.div_ceil(self.pool.block));
        let block = self.pool.block;
        if fresh == 0 {
            return Ok(());
        }
        let tail_rows = self.len % block;
        if tail_rows != 0 {
            let tail = *self.table.last().unwrap();
            if self.pool.refcount(tail) > 1 {
                // Copy-on-write: the first divergent block is duplicated;
                // full shared blocks before it stay shared.
                let fresh_id = self.pool.alloc()?;
                let (sk, sv) = self.pool.planes(tail);
                let (dk, dv) = self.pool.planes(fresh_id);
                // SAFETY: source block is live (we hold a reference) and
                // read-only while shared; destination was just allocated
                // with refcount 1, so no other reader or writer exists.
                // Plane buffers are disjoint allocations of the stated
                // lengths.
                unsafe {
                    std::ptr::copy_nonoverlapping(sk, dk, self.pool.kplane);
                    std::ptr::copy_nonoverlapping(sv, dv, self.pool.vplane);
                }
                *self.table.last_mut().unwrap() = fresh_id;
                self.pool.release(tail);
                lock::lock(&self.pool.state).cow_copies += 1;
            }
        }
        let need = (self.len + fresh).div_ceil(block);
        while self.table.len() < need {
            self.table.push(self.pool.alloc()?);
        }
        Ok(())
    }

    /// Mark `fresh` appended positions live (call after the interpreter has
    /// written their rows).
    pub(crate) fn commit(&mut self, fresh: usize) {
        self.len += fresh;
        debug_assert!(self.table.len() >= self.len.div_ceil(self.pool.block));
    }

    /// Raw plane pointers for the native interpreter. The view stays valid
    /// for the pool's lifetime; writing through it requires the exclusive
    /// ownership [`PagedSeq::prepare_append`] establishes.
    pub(crate) fn view(&self) -> PagedKv {
        let mut k = Vec::with_capacity(self.table.len());
        let mut v = Vec::with_capacity(self.table.len());
        for &id in &self.table {
            let (kp, vp) = self.pool.planes(id);
            k.push(kp);
            v.push(vp);
        }
        PagedKv { k, v, block: self.pool.block, planes: self.pool.layers * self.pool.heads }
    }

    /// Publish the first `prefix.len()` positions (full blocks only) for
    /// adoption by later sequences. `prefix` must be this sequence's leading
    /// token ids.
    pub(crate) fn register_prefix(&self, prefix: &[i32]) {
        let upto = prefix.len().min(self.len);
        self.pool.register(&prefix[..upto], &self.table);
    }

    /// A new sequence sharing every block (and the committed length) of
    /// this one. Either side's next append into the shared tail block
    /// triggers copy-on-write.
    pub fn fork(&self) -> Self {
        for &id in &self.table {
            self.pool.retain(id);
        }
        Self { pool: self.pool.clone(), table: self.table.clone(), len: self.len }
    }
}

impl Drop for PagedSeq {
    fn drop(&mut self) {
        for &id in &self.table {
            self.pool.release(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(block: usize, max_blocks: usize) -> Arc<KvPool> {
        KvPool::new(2, 2, 3, 4, KvPoolOpts { block, max_blocks, share_prefixes: true })
    }

    /// Write a recognizable value into row `pos` of plane 0 of a sequence.
    fn write_row0(seq: &PagedSeq, pos: usize, val: f32) {
        let v = seq.view();
        let (bi, r) = (pos / v.block, pos % v.block);
        unsafe {
            *v.k[bi].add(r * 3) = val;
        }
    }

    fn read_row0(seq: &PagedSeq, pos: usize) -> f32 {
        let v = seq.view();
        let (bi, r) = (pos / v.block, pos % v.block);
        unsafe { *v.k[bi].add(r * 3) }
    }

    #[test]
    fn alloc_release_recycles_blocks() {
        let p = pool(4, 0);
        let mut a = PagedSeq::new(p.clone());
        a.prepare_append(9).unwrap(); // 3 blocks
        a.commit(9);
        assert_eq!(a.blocks(), 3);
        assert_eq!(p.stats().blocks_in_use, 3);
        drop(a);
        let s = p.stats();
        assert_eq!(s.blocks_in_use, 0);
        assert_eq!(s.registered_blocks, 0);
        assert_eq!(s.peak_blocks, 3);
        // A new sequence reuses the freed blocks instead of growing.
        let mut b = PagedSeq::new(p.clone());
        b.prepare_append(12).unwrap();
        b.commit(12);
        assert_eq!(p.stats().allocated_blocks, 3);
    }

    #[test]
    fn pool_cap_is_enforced() {
        let p = pool(4, 2);
        let mut a = PagedSeq::new(p.clone());
        a.prepare_append(8).unwrap();
        a.commit(8);
        let mut b = PagedSeq::new(p.clone());
        let err = b.prepare_append(1).unwrap_err().to_string();
        assert!(err.contains("kv pool exhausted"), "{err}");
        drop(a);
        // Capacity returns once the holder drops.
        b.prepare_append(1).unwrap();
    }

    #[test]
    fn fork_copy_on_write_preserves_parent_tail() {
        let p = pool(4, 0);
        let mut a = PagedSeq::new(p.clone());
        a.prepare_append(6).unwrap(); // block 0 full, block 1 partial
        a.commit(6);
        write_row0(&a, 5, 1.5);
        let mut b = a.fork();
        assert_eq!(b.len(), 6);
        // Appending through the fork copies the shared partial tail...
        b.prepare_append(1).unwrap();
        write_row0(&b, 6, 9.0);
        b.commit(1);
        // ...so the parent's tail data survives and both see position 5.
        assert_eq!(read_row0(&a, 5), 1.5);
        assert_eq!(read_row0(&b, 5), 1.5);
        let s = p.stats();
        assert_eq!(s.cow_copies, 1);
        // The parent can still extend its own (now exclusively owned) tail.
        a.prepare_append(1).unwrap();
        write_row0(&a, 6, -3.0);
        a.commit(1);
        assert_eq!(read_row0(&b, 6), 9.0);
        assert_eq!(read_row0(&a, 6), -3.0);
    }

    #[test]
    fn registry_adopts_longest_full_block_prefix() {
        let p = pool(4, 0);
        let prompt: Vec<i32> = (0..10).collect();
        let mut a = PagedSeq::new(p.clone());
        a.prepare_append(10).unwrap();
        a.commit(10);
        write_row0(&a, 0, 7.0);
        a.register_prefix(&prompt); // registers 4- and 8-position prefixes
        assert_eq!(p.stats().registered_prefixes, 2);

        // Same 8-token opening, different continuation: adopt 2 blocks.
        let mut p2: Vec<i32> = (0..9).collect();
        p2[8] = 99;
        let (b, matched) = PagedSeq::begin(&p, &p2);
        assert_eq!(matched, 8);
        assert_eq!(b.blocks(), 2);
        assert_eq!(read_row0(&b, 0), 7.0);
        assert_eq!(p.stats().shared_hits, 2);

        // Only the first block matches → adopt 1.
        let mut p3: Vec<i32> = (0..10).collect();
        p3[5] = 42;
        let (c, matched) = PagedSeq::begin(&p, &p3);
        assert_eq!(matched, 4);
        assert_eq!(c.blocks(), 1);

        // No full-block match (adoption is capped at len - 1).
        let (d, matched) = PagedSeq::begin(&p, &[0, 1, 2, 3]);
        assert_eq!(matched, 0);
        assert_eq!(d.blocks(), 0);
    }

    #[test]
    fn registered_blocks_survive_their_author() {
        let p = pool(4, 0);
        let prompt: Vec<i32> = (50..58).collect();
        let mut a = PagedSeq::new(p.clone());
        a.prepare_append(8).unwrap();
        a.commit(8);
        write_row0(&a, 7, 2.25);
        a.register_prefix(&prompt);
        drop(a);
        // The registry's refcount keeps both blocks alive, and the stats
        // attribute them to the registry — no sequence leaked them.
        let s = p.stats();
        assert_eq!(s.blocks_in_use, 2);
        assert_eq!(s.registered_blocks, 2);
        let mut ext = prompt.clone();
        ext.push(0);
        let (b, matched) = PagedSeq::begin(&p, &ext);
        assert_eq!(matched, 8);
        assert_eq!(read_row0(&b, 7), 2.25);
    }

    #[test]
    fn sharing_disabled_pool_never_adopts() {
        let p = KvPool::new(2, 2, 3, 4, KvPoolOpts { block: 4, max_blocks: 0, share_prefixes: false });
        let prompt: Vec<i32> = (0..8).collect();
        let mut a = PagedSeq::new(p.clone());
        a.prepare_append(8).unwrap();
        a.commit(8);
        a.register_prefix(&prompt);
        let (b, matched) = PagedSeq::begin(&p, &[0, 1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!((matched, b.blocks()), (0, 0));
        assert_eq!(p.stats().registered_prefixes, 0);
    }
}
