//! Global FLOPs-targeted allocation: given one FLOPs budget for the whole
//! model, pick per-layer, per-component retention (MLP hidden widths and
//! per-head QK widths) by marginal score-per-FLOP greedy selection.
//!
//! Replaces the uniform `Sparsity{mlp_s10, attn_s10}` setting with a
//! per-layer [`Allocation`]: layers whose calibration statistics carry more
//! criterion mass keep more units. The cost model is the analytic
//! [`crate::flops`] accounting — each MLP hidden unit costs
//! [`mlp_unit_flops`] and each QK dim (spanning every head of a layer at
//! once, the fused `[d, h·dqk]` layout) costs [`qk_unit_flops`]; both are
//! exact marginals of `flops_layered`, so the achieved budget is measured
//! on the very shapes the pruner then produces.
//!
//! Within one (layer, component) the units are sorted by descending
//! criterion score and the unit cost is constant, so a single global
//! sort-and-sweep over score-per-FLOP densities preserves the within-layer
//! ranking order: a component's `m+1`-th unit is never taken before its
//! `m`-th. CORP compensation then applies unchanged on top of whatever
//! per-layer keep counts come out.

use anyhow::{bail, Result};

use super::{per_head, CalibStats};
use crate::flops::{flops, flops_layered, mlp_unit_flops, qk_unit_flops};
use crate::model::{LayerDims, ModelConfig, Sparsity, WeightStore};
use crate::rank::{nan_last_desc, score_attn_zoo, score_mlp_zoo, Criterion};

/// Per-layer keep counts chosen by the global allocator: `mlp_keep[l]`
/// hidden channels and `qk_keep[l]` per-head QK dims are retained in layer
/// `l`. Every entry is ≥ 1 (a layer is never emptied).
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Allocation {
    pub mlp_keep: Vec<usize>,
    pub qk_keep: Vec<usize>,
}

impl Allocation {
    /// The pruned per-layer dims this allocation produces.
    pub fn layer_dims(&self) -> LayerDims {
        LayerDims { dqk: self.qk_keep.clone(), o: self.mlp_keep.clone() }
    }

    /// Achieved fraction of the dense forward FLOPs, in percent — the
    /// number the ±2% budget acceptance is checked against.
    pub fn achieved_pct(&self, cfg: &ModelConfig) -> f64 {
        let dense = flops(cfg, Sparsity::dense());
        100.0 * flops_layered(cfg, &self.layer_dims()) as f64 / dense as f64
    }
}

/// One marginal retention unit considered by the greedy sweep.
struct Unit {
    layer: usize,
    /// false = MLP hidden channel, true = per-head QK dim.
    qk: bool,
    /// Within-component rank (the floor unit `m = 0` is always kept).
    m: usize,
    /// Criterion score per FLOP (scope-normalized).
    density: f64,
    cost: usize,
}

/// Normalize a scope's unit scores so its finite mass sums to 1 — MLP and
/// attention criteria live on unrelated scales (energy of hidden
/// activations vs logit energy), and the greedy sweep compares their
/// densities directly. Per-*scope* (not per-layer) normalization keeps the
/// inter-layer signal that global allocation exists to exploit.
fn normalize_scope(scores: &mut [Vec<f64>]) {
    let total: f64 = scores
        .iter()
        .flat_map(|v| v.iter())
        .filter(|s| s.is_finite() && **s > 0.0)
        .sum();
    if total > 0.0 {
        for v in scores.iter_mut() {
            for s in v.iter_mut() {
                *s /= total;
            }
        }
    }
}

/// Pick per-layer keep counts so the pruned model's forward FLOPs land at
/// `budget_pct`% of dense (from below; the gap is bounded by one unit
/// cost). `dense` supplies the `mlp.w2` rows the weight-aware criteria
/// score; `stats` is the same one-pass calibration cache the compensator
/// uses — the allocator costs no extra passes.
pub fn allocate_flops(
    cfg: &'static ModelConfig,
    dense: &WeightStore,
    stats: &CalibStats,
    crit: Criterion,
    lambda: f64,
    budget_pct: f64,
) -> Result<Allocation> {
    if !(budget_pct > 0.0 && budget_pct <= 100.0) {
        bail!("flops budget must be in (0, 100] percent, got {budget_pct}");
    }
    if stats.layers.len() != cfg.layers {
        bail!("calibration stats cover {} layers, model has {}", stats.layers.len(), cfg.layers);
    }
    let (h, dh) = (cfg.heads, cfg.dh());

    // Per-layer unit scores, sorted descending (NaN-last) within each
    // component so index m is the m-th most important unit.
    let mut mlp_scores: Vec<Vec<f64>> = Vec::with_capacity(cfg.layers);
    let mut qk_scores: Vec<Vec<f64>> = Vec::with_capacity(cfg.layers);
    for (l, ls) in stats.layers.iter().enumerate() {
        let w2 = dense.expect(&format!("blocks.{l}.mlp.w2"))?;
        let mut ms = score_mlp_zoo(crit, &ls.hidden, &ls.active.active_prob(), w2, lambda);
        ms.sort_by(|a, b| nan_last_desc(*a, *b));
        mlp_scores.push(ms);
        // The fused layout removes a QK dim from every head of the layer at
        // once, so the m-th QK unit's value is the sum over heads of each
        // head's m-th best dim (heads rank independently, exactly as the
        // pruner partitions them).
        let mut per_m = vec![0.0f64; dh];
        for head in 0..h {
            let qh = per_head(&ls.q, head);
            let kh = per_head(&ls.k, head);
            let mut s = score_attn_zoo(crit, &qh, &kh, lambda);
            s.sort_by(|a, b| nan_last_desc(*a, *b));
            for (m, v) in s.iter().enumerate() {
                per_m[m] += v;
            }
        }
        qk_scores.push(per_m);
    }
    normalize_scope(&mut mlp_scores);
    normalize_scope(&mut qk_scores);

    // Floor: one unit of each component per layer; everything above the
    // floor competes globally.
    let mut alloc = Allocation { mlp_keep: vec![1; cfg.layers], qk_keep: vec![1; cfg.layers] };
    let mut spent = flops_layered(cfg, &alloc.layer_dims());
    let dense_total = flops(cfg, Sparsity::dense());
    let target = (budget_pct / 100.0 * dense_total as f64).round() as usize;
    if spent > target {
        bail!(
            "flops budget {budget_pct}% is below the 1-unit-per-layer floor \
             ({spent} of {dense_total} dense flops = {:.1}%)",
            100.0 * spent as f64 / dense_total as f64
        );
    }

    let (mlp_cost, qk_cost) = (mlp_unit_flops(cfg), qk_unit_flops(cfg));
    let mut units: Vec<Unit> = Vec::with_capacity(cfg.layers * (cfg.mlp + dh));
    for l in 0..cfg.layers {
        for m in 1..cfg.mlp {
            units.push(Unit {
                layer: l,
                qk: false,
                m,
                density: mlp_scores[l][m] / mlp_cost as f64,
                cost: mlp_cost,
            });
        }
        for m in 1..dh {
            units.push(Unit {
                layer: l,
                qk: true,
                m,
                density: qk_scores[l][m] / qk_cost as f64,
                cost: qk_cost,
            });
        }
    }
    // Highest density first; ties (and NaN runs) break on (layer, comp, m)
    // so the sweep is deterministic and within-component order is kept even
    // for equal scores.
    units.sort_by(|a, b| {
        nan_last_desc(a.density, b.density)
            .then(a.layer.cmp(&b.layer))
            .then(a.qk.cmp(&b.qk))
            .then(a.m.cmp(&b.m))
    });
    // Greedy sweep. Unit costs are constant within a component, so once a
    // unit is skipped for budget, every later unit of the same cost is
    // skipped too — the kept set is always a per-component prefix.
    for u in &units {
        if spent + u.cost > target {
            continue;
        }
        spent += u.cost;
        if u.qk {
            alloc.qk_keep[u.layer] += 1;
        } else {
            alloc.mlp_keep[u.layer] += 1;
        }
    }
    debug_assert_eq!(spent, flops_layered(cfg, &alloc.layer_dims()));
    Ok(alloc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::prune::LayerStats;
    use crate::stats::{ActiveCounter, MomentAccumulator};
    use crate::tensor::Tensor;
    use crate::util::Pcg64;

    /// Synthetic calibration stats: layer `hot` gets 4× the activation
    /// scale, so score-aware allocation should favor it.
    fn synth_stats(cfg: &'static ModelConfig, hot: usize) -> CalibStats {
        let (h, dh, o) = (cfg.heads, cfg.dh(), cfg.mlp);
        let (samples, n) = (2usize, 4usize);
        let mut rng = Pcg64::new(42);
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let scale = if l == hot { 4.0 } else { 1.0 };
            let rows = 32;
            let mut x = vec![0.0f32; rows * o];
            for v in x.iter_mut() {
                *v = rng.normal_f32(0.0, scale);
            }
            let mut hidden = MomentAccumulator::new(o);
            hidden.add_batch(&x, rows);
            let mut active = ActiveCounter::new(o, 0.05);
            active.add_batch(&x, rows);
            let mut q = vec![0.0f32; samples * h * n * dh];
            let mut k = vec![0.0f32; samples * h * n * dh];
            for v in q.iter_mut().chain(k.iter_mut()) {
                *v = rng.normal_f32(0.0, scale);
            }
            layers.push(LayerStats {
                hidden,
                active,
                q: Tensor::from_vec(&[samples, h, n, dh], q),
                k: Tensor::from_vec(&[samples, h, n, dh], k),
            });
        }
        CalibStats { layers, sections: crate::util::timer::Sections::new() }
    }

    #[test]
    fn allocator_hits_budget_within_two_pct() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let dense = crate::model::WeightStore::init(cfg, 11);
        let stats = synth_stats(cfg, 2);
        for crit in Criterion::zoo() {
            for budget in [40.0, 60.0, 80.0] {
                let a = allocate_flops(cfg, &dense, &stats, crit, 1e-2, budget).unwrap();
                let got = a.achieved_pct(cfg);
                assert!(
                    (got - budget).abs() <= 2.0,
                    "{} @ {budget}%: achieved {got:.2}%",
                    crit.label()
                );
                // Floors and caps.
                assert!(a.mlp_keep.iter().all(|&k| k >= 1 && k <= cfg.mlp));
                assert!(a.qk_keep.iter().all(|&k| k >= 1 && k <= cfg.dh()));
            }
        }
    }

    #[test]
    fn allocator_favors_high_score_layers() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let dense = crate::model::WeightStore::init(cfg, 11);
        let hot = 2usize;
        let stats = synth_stats(cfg, hot);
        let a = allocate_flops(cfg, &dense, &stats, Criterion::Energy, 1e-2, 55.0).unwrap();
        // The hot layer's activation energy dominates, so it keeps at least
        // as many units as every other layer in both components.
        for l in 0..cfg.layers {
            assert!(a.mlp_keep[hot] >= a.mlp_keep[l], "mlp {:?}", a.mlp_keep);
            assert!(a.qk_keep[hot] >= a.qk_keep[l], "qk {:?}", a.qk_keep);
        }
        // And the allocation is genuinely non-uniform.
        assert!(a.layer_dims().as_uniform().is_none(), "{a:?}");
    }

    #[test]
    fn allocator_spends_more_at_higher_budget() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let dense = crate::model::WeightStore::init(cfg, 11);
        let stats = synth_stats(cfg, 0);
        let lo = allocate_flops(cfg, &dense, &stats, Criterion::Variance, 1e-2, 50.0).unwrap();
        let hi = allocate_flops(cfg, &dense, &stats, Criterion::Variance, 1e-2, 75.0).unwrap();
        // Achieved FLOPs track the requested budgets (greedy packs from
        // below, so ordering of the achieved fractions is guaranteed even
        // though individual layer counts may re-mix between budgets).
        assert!(hi.achieved_pct(cfg) > lo.achieved_pct(cfg));
        let total = |a: &Allocation| -> usize {
            a.mlp_keep.iter().sum::<usize>() + a.qk_keep.iter().sum::<usize>()
        };
        assert!(total(&hi) > total(&lo), "hi {hi:?} lo {lo:?}");
    }

    #[test]
    fn allocator_rejects_bad_budgets() {
        let cfg = ModelConfig::by_name("vit_t").unwrap();
        let dense = crate::model::WeightStore::init(cfg, 11);
        let stats = synth_stats(cfg, 0);
        for bad in [0.0, -5.0, 101.0] {
            assert!(allocate_flops(cfg, &dense, &stats, Criterion::Energy, 1e-2, bad).is_err());
        }
        // Below the 1-unit floor: clear error, not a panic.
        let err = allocate_flops(cfg, &dense, &stats, Criterion::Energy, 1e-2, 0.01)
            .unwrap_err()
            .to_string();
        assert!(err.contains("floor"), "{err}");
    }
}
