//! The CORP pipeline (Alg. 1) and the baseline methods.
//!
//! `calibrate` runs the dense model over unlabeled calibration batches and
//! accumulates every statistic all methods need (one pass, cached). `prune`
//! then ranks, compensates, and folds — producing a pruned `WeightStore`
//! whose shapes match the corresponding block artifacts.

pub mod allocate;
pub mod baselines;

use anyhow::Result;

pub use allocate::{allocate_flops, Allocation};

use crate::compensate::compensate_attn_head;
use crate::data::{Split, TextGen, VisionGen};
use crate::exec::{Executor, LayerCapture};
use crate::linalg::Mat;
use crate::model::{keep_count, ModelConfig, ModelKind, Scope, Sparsity, WeightStore};
use crate::rank::{partition_k, score_attn_zoo, score_mlp_zoo, Criterion, MlpCriterion};
use crate::stats::{cov_blocks, ActiveCounter, MomentAccumulator};
use crate::tensor::Tensor;
use crate::util::timer::Sections;

/// Pruning method.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Method {
    /// CORP (Alg. 1): criterion ranking (Alg. 2 for MLP channels, Alg. 4
    /// for q/k dims) + closed-form compensation — the affine MLP solve
    /// B = Σ_PS (Σ_SS + λI)⁻¹ of Alg. 3 / Eq. 9 folded into `mlp.w2`, and
    /// the per-head Kronecker-ridge logit solve of Alg. 5 folded into
    /// `attn.wq` / `attn.wk`.
    Corp,
    /// Same ranking, no compensation (the "w/o comp" curves).
    Naive,
    /// GRAIL-like: uncentered Gram-ridge output reconstruction, MLP only
    /// scope applies to w2; attention pruned naively.
    Grail,
    /// VBP-like: variance ranking + bias-only compensation, no B matrix.
    Vbp,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Corp => "CORP",
            Method::Naive => "naive",
            Method::Grail => "GRAIL-like",
            Method::Vbp => "VBP-like",
        }
    }
}

/// Pipeline options.
#[derive(Clone, Debug)]
pub struct PruneOpts {
    /// Uniform per-layer sparsity — the retention default when no global
    /// allocation is set.
    pub sparsity: Sparsity,
    pub method: Method,
    /// Ranking criterion from the zoo (`rank::Criterion`); applies to both
    /// scopes. The paper's default wraps the combined MLP signal.
    pub criterion: Criterion,
    /// Per-layer keep counts from the global FLOPs allocator. When set it
    /// overrides `sparsity` everywhere retention counts are derived
    /// (ranking, compensation, artifact shapes).
    pub alloc: Option<Allocation>,
    /// Ridge strength λ shared by the Eq. 9 affine solve and the Alg. 5
    /// Kronecker system (normalized by the mean Gram diagonal, see
    /// `linalg::ridge::ridge_right`).
    pub lambda: f64,
    /// Number of calibration batches (batch size = cfg.eval_batch()).
    pub calib_batches: usize,
    /// Sample cap for the attention Kronecker accumulation.
    pub attn_max_samples: usize,
    /// Threshold for the active-probability statistic.
    pub active_eps: f32,
    /// Compute per-layer rho²/J* diagnostics (costly eigen solves; §Perf L3-2).
    pub diagnostics: bool,
    pub seed: u64,
}

impl Default for PruneOpts {
    fn default() -> Self {
        Self {
            sparsity: Sparsity::of(Scope::Both, 5),
            method: Method::Corp,
            criterion: Criterion::Mlp(MlpCriterion::Combined),
            alloc: None,
            lambda: 1e-2,
            calib_batches: 16,
            attn_max_samples: 128,
            active_eps: 0.05,
            diagnostics: false,
            seed: 1234,
        }
    }
}

impl PruneOpts {
    /// MLP hidden channels layer `l` keeps: the allocator's per-layer count
    /// when a global allocation is set, the uniform `keep_count` otherwise.
    pub fn mlp_keep(&self, cfg: &ModelConfig, l: usize) -> usize {
        match &self.alloc {
            Some(a) => a.mlp_keep[l],
            None => keep_count(cfg.mlp, self.sparsity.mlp_s10),
        }
    }

    /// Per-head QK dims layer `l` keeps (see [`PruneOpts::mlp_keep`]).
    pub fn attn_keep(&self, cfg: &ModelConfig, l: usize) -> usize {
        match &self.alloc {
            Some(a) => a.qk_keep[l],
            None => keep_count(cfg.dh(), self.sparsity.attn_s10),
        }
    }
}

/// Per-layer calibration statistics.
pub struct LayerStats {
    /// Hidden-activation moments over [B·n, o].
    pub hidden: MomentAccumulator,
    pub active: ActiveCounter,
    /// Captured per-head queries/keys, concatenated over batches:
    /// [samples, heads, n, dh].
    pub q: Tensor,
    pub k: Tensor,
}

/// Full calibration result (Alg. 1's cache).
pub struct CalibStats {
    pub layers: Vec<LayerStats>,
    /// Wall-time charged per pipeline section (Table 6 analogue).
    pub sections: Sections,
}

/// Run the dense model on calibration data and accumulate statistics.
///
/// Streaming: each captured batch is folded into the per-layer Gram/active
/// accumulators as soon as the forward pass returns — hidden activations are
/// never materialized beyond the current batch. Layers are independent, so
/// the per-batch fold fans the layer updates out over the worker pool (each
/// layer's accumulator is owned by exactly one worker, so statistics do not
/// depend on the worker count). Only the Q/K slabs needed for the attention
/// compensator are retained, capped at `opts.attn_max_samples` samples.
pub fn calibrate(exec: &Executor<'_>, w: &WeightStore, opts: &PruneOpts) -> Result<CalibStats> {
    let cfg = exec.cfg;
    let b = cfg.eval_batch();
    let mut sections = Sections::new();
    let mut hidden_acc: Vec<MomentAccumulator> =
        (0..cfg.layers).map(|_| MomentAccumulator::new(cfg.mlp)).collect();
    let mut active_acc: Vec<ActiveCounter> =
        (0..cfg.layers).map(|_| ActiveCounter::new(cfg.mlp, opts.active_eps)).collect();
    let mut qs: Vec<Vec<Tensor>> = vec![Vec::new(); cfg.layers];
    let mut ks: Vec<Vec<Tensor>> = vec![Vec::new(); cfg.layers];
    let vision = VisionGen::new(crate::data::DATA_SEED);
    let text = TextGen::new(crate::data::DATA_SEED);

    let mut attn_kept_samples = 0usize;
    for batch in 0..opts.calib_batches {
        // Calibration is *unlabeled*: only inputs are used.
        let (tokens, ids) = match cfg.kind {
            ModelKind::Vit => (Some(vision.batch(Split::Calib, batch as u64, b).0), None),
            ModelKind::Gpt => (None, Some(text.batch(Split::Calib, batch as u64, b, cfg.n_ctx).0)),
        };
        let caps = sections.time("calibration", || {
            exec.forward_capture(w, tokens.as_ref(), ids.as_deref())
        })?;
        let keep_qk = attn_kept_samples < opts.attn_max_samples;
        let rows = b * cfg.n_ctx;
        let mut captures = caps.1;
        sections.time("calibration", || {
            let items: Vec<(&mut MomentAccumulator, &mut ActiveCounter, &LayerCapture)> =
                hidden_acc
                    .iter_mut()
                    .zip(active_acc.iter_mut())
                    .zip(captures.iter())
                    .map(|((h, a), cap)| (h, a, cap))
                    .collect();
            crate::util::threads::parallel_items(items, |(hidden, active, cap)| {
                hidden.add_batch(cap.hidden.data(), rows);
                active.add_batch(cap.hidden.data(), rows);
            });
        });
        if keep_qk {
            for (l, cap) in captures.drain(..).enumerate() {
                qs[l].push(cap.q);
                ks[l].push(cap.k);
            }
            attn_kept_samples += b;
        }
    }

    // Concatenate Q/K batches per layer.
    let layers = hidden_acc
        .into_iter()
        .zip(active_acc)
        .zip(qs.into_iter().zip(ks))
        .map(|((hidden, active), (qv, kv))| LayerStats {
            hidden,
            active,
            q: concat_leading(&qv),
            k: concat_leading(&kv),
        })
        .collect();
    Ok(CalibStats { layers, sections })
}

fn concat_leading(parts: &[Tensor]) -> Tensor {
    assert!(!parts.is_empty());
    let mut shape = parts[0].shape().to_vec();
    let inner: usize = shape[1..].iter().product();
    let total: usize = parts.iter().map(|t| t.shape()[0]).sum();
    let mut data = Vec::with_capacity(total * inner);
    for p in parts {
        data.extend_from_slice(p.data());
    }
    shape[0] = total;
    Tensor::from_vec(&shape, data)
}

/// Reshape the captured [samples, heads, n, dh] slab into per-head
/// [samples, n, dh] views (copied; sizes are small).
pub fn per_head(t: &Tensor, head: usize) -> Tensor {
    let s = t.shape();
    let (b, h, n, dh) = (s[0], s[1], s[2], s[3]);
    let mut out = Vec::with_capacity(b * n * dh);
    for i in 0..b {
        let base = ((i * h) + head) * n * dh;
        out.extend_from_slice(&t.data()[base..base + n * dh]);
    }
    Tensor::from_vec(&[b, n, dh], out)
}

/// Outcome of a pruning run.
pub struct PruneResult {
    pub weights: WeightStore,
    /// Mean per-layer MLP ρ² (variance explained) — diagnostic.
    pub mean_mlp_rho2: f64,
    /// Mean per-head attention ρ².
    pub mean_attn_rho2: f64,
    pub sections: Sections,
}

/// Rank + compensate + fold (Alg. 1 after calibration).
pub fn prune(
    exec: &Executor<'_>,
    dense: &WeightStore,
    stats: &CalibStats,
    opts: &PruneOpts,
) -> Result<PruneResult> {
    match opts.method {
        Method::Corp => prune_corp(exec, dense, stats, opts, true),
        Method::Naive => prune_corp(exec, dense, stats, opts, false),
        Method::Grail => baselines::prune_grail(exec, dense, stats, opts),
        Method::Vbp => baselines::prune_vbp(exec, dense, stats, opts),
    }
}

/// Convenience: calibrate + prune.
pub fn run_pipeline(
    exec: &Executor<'_>,
    dense: &WeightStore,
    opts: &PruneOpts,
) -> Result<PruneResult> {
    let stats = calibrate(exec, dense, opts)?;
    let mut result = prune(exec, dense, &stats, opts)?;
    result.sections.merge(&stats.sections);
    Ok(result)
}

/// One unit of independent pruning work: a layer's MLP scope, or a single
/// attention head. The flat task list is fanned out over the worker pool —
/// every solve (ridge, Kronecker, SVD) touches only its own layer/head
/// statistics and dense weights, so tasks are embarrassingly parallel.
enum Job {
    Mlp { l: usize },
    Head { l: usize, head: usize },
}

/// Result of one `Job`, applied serially to the output store afterwards.
enum JobOut {
    Mlp {
        l: usize,
        w1: Tensor,
        b1: Tensor,
        w2: Tensor,
        /// `None` on the naive path (dense b2 is kept).
        b2: Option<Tensor>,
        rho2: Option<f64>,
    },
    Head {
        l: usize,
        head: usize,
        wq: Mat,
        bq: Vec<f64>,
        wk: Mat,
        bk: Vec<f64>,
        rho2: Option<f64>,
    },
}

fn prune_corp(
    exec: &Executor<'_>,
    dense: &WeightStore,
    stats: &CalibStats,
    opts: &PruneOpts,
    compensate: bool,
) -> Result<PruneResult> {
    let cfg = exec.cfg;
    let mut out = dense.clone();
    let mut sections = Sections::new();
    let dh = cfg.dh();
    let h = cfg.heads;

    // A layer contributes a job only when it actually sheds units — under a
    // global allocation layers may differ (some staying dense).
    let mut jobs: Vec<Job> = Vec::new();
    for l in 0..cfg.layers {
        if opts.mlp_keep(cfg, l) < cfg.mlp {
            jobs.push(Job::Mlp { l });
        }
    }
    for l in 0..cfg.layers {
        if opts.attn_keep(cfg, l) < dh {
            for head in 0..h {
                jobs.push(Job::Head { l, head });
            }
        }
    }

    // Rank + solve every independent unit in parallel. Section seconds are
    // summed across workers (CPU seconds, comparable to the serial seed
    // breakdown); `prune_wall` records the wall time of the region.
    let wall = crate::util::Stopwatch::start();
    let outs: Vec<Result<(JobOut, f64, f64)>> =
        crate::util::threads::parallel_map(jobs.len(), |ji| match jobs[ji] {
            Job::Mlp { l } => {
                let ls = &stats.layers[l];
                let w1 = dense.expect(&format!("blocks.{l}.mlp.w1"))?;
                let b1 = dense.expect(&format!("blocks.{l}.mlp.b1"))?;
                let w2 = dense.expect(&format!("blocks.{l}.mlp.w2"))?;
                let b2 = dense.expect(&format!("blocks.{l}.mlp.b2"))?;
                let rank_t = crate::util::Stopwatch::start();
                let scores = score_mlp_zoo(
                    opts.criterion,
                    &ls.hidden,
                    &ls.active.active_prob(),
                    w2,
                    opts.lambda,
                );
                let (kept, pruned) = partition_k(&scores, opts.mlp_keep(cfg, l));
                let rank_s = rank_t.secs();
                // First layer: always a column gather.
                let w1g = w1.gather_cols(&kept);
                let b1g = b1.gather_cols(&kept);
                let comp_t = crate::util::Stopwatch::start();
                let jo = if compensate {
                    let cov = ls.hidden.covariance();
                    let mean = ls.hidden.mean();
                    let blocks = cov_blocks(&cov, &mean, &kept, &pruned);
                    let comp = crate::compensate::mlp::compensate_mlp_opts(
                        w2, b2, &kept, &pruned, &blocks, opts.lambda, opts.diagnostics,
                    );
                    JobOut::Mlp {
                        l,
                        w1: w1g,
                        b1: b1g,
                        w2: comp.w2_hat,
                        b2: Some(comp.b2_hat),
                        rho2: Some(comp.rho2),
                    }
                } else {
                    JobOut::Mlp { l, w1: w1g, b1: b1g, w2: w2.gather_rows(&kept), b2: None, rho2: None }
                };
                Ok((jo, rank_s, comp_t.secs()))
            }
            Job::Head { l, head } => {
                let ls = &stats.layers[l];
                let wq = dense.expect(&format!("blocks.{l}.attn.wq"))?;
                let bq = dense.expect(&format!("blocks.{l}.attn.bq"))?;
                let wk = dense.expect(&format!("blocks.{l}.attn.wk"))?;
                let bk = dense.expect(&format!("blocks.{l}.attn.bk"))?;
                let qh = per_head(&ls.q, head);
                let kh = per_head(&ls.k, head);
                let dqk = opts.attn_keep(cfg, l);
                let rank_t = crate::util::Stopwatch::start();
                let scores = score_attn_zoo(opts.criterion, &qh, &kh, opts.lambda);
                let (kept, pruned) = partition_k(&scores, dqk);
                let rank_s = rank_t.secs();
                let comp_t = crate::util::Stopwatch::start();
                let jo = if compensate {
                    // Dense per-head projection blocks [d, dh].
                    let wq_head = head_block(wq, head, dh);
                    let wk_head = head_block(wk, head, dh);
                    let bq_head: Vec<f64> =
                        (0..dh).map(|j| bq.data()[head * dh + j] as f64).collect();
                    let bk_head: Vec<f64> =
                        (0..dh).map(|j| bk.data()[head * dh + j] as f64).collect();
                    let comp = compensate_attn_head(
                        &qh,
                        &kh,
                        &kept,
                        &pruned,
                        &wq_head,
                        &bq_head,
                        &wk_head,
                        &bk_head,
                        opts.lambda,
                        opts.attn_max_samples,
                    );
                    JobOut::Head {
                        l,
                        head,
                        wq: comp.wq,
                        bq: comp.bq,
                        wk: comp.wk,
                        bk: comp.bk,
                        rho2: Some(comp.rho2),
                    }
                } else {
                    // Naive: gather kept columns of the per-head blocks.
                    let mut nwq = Mat::zeros(cfg.d, dqk);
                    let mut nwk = Mat::zeros(cfg.d, dqk);
                    let mut nbq = vec![0.0f64; dqk];
                    let mut nbk = vec![0.0f64; dqk];
                    for (j, &c) in kept.iter().enumerate() {
                        for r in 0..cfg.d {
                            nwq.set(r, j, wq.at2(r, head * dh + c) as f64);
                            nwk.set(r, j, wk.at2(r, head * dh + c) as f64);
                        }
                        nbq[j] = bq.data()[head * dh + c] as f64;
                        nbk[j] = bk.data()[head * dh + c] as f64;
                    }
                    JobOut::Head { l, head, wq: nwq, bq: nbq, wk: nwk, bk: nbk, rho2: None }
                };
                Ok((jo, rank_s, comp_t.secs()))
            }
        });
    sections.add("prune_wall", wall.secs());

    // Apply results serially (deterministic order), assembling the fused
    // per-layer attention projections from the per-head blocks.
    let mut rho_mlp = Vec::new();
    let mut rho_attn = Vec::new();
    let mut attn_new: Vec<Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>> =
        (0..cfg.layers).map(|_| None).collect();
    for res in outs {
        let (jo, rank_s, comp_s) = res?;
        sections.add("ranking", rank_s);
        sections.add("compensation", comp_s);
        match jo {
            JobOut::Mlp { l, w1, b1, w2, b2, rho2 } => {
                out.insert(format!("blocks.{l}.mlp.w1"), w1);
                out.insert(format!("blocks.{l}.mlp.b1"), b1);
                out.insert(format!("blocks.{l}.mlp.w2"), w2);
                if let Some(b2) = b2 {
                    out.insert(format!("blocks.{l}.mlp.b2"), b2);
                }
                if let Some(r) = rho2 {
                    rho_mlp.push(r);
                }
            }
            JobOut::Head { l, head, wq, bq, wk, bk, rho2 } => {
                let dqk = opts.attn_keep(cfg, l);
                let slot = attn_new[l].get_or_insert_with(|| {
                    (
                        vec![0.0f32; cfg.d * h * dqk],
                        vec![0.0f32; h * dqk],
                        vec![0.0f32; cfg.d * h * dqk],
                        vec![0.0f32; h * dqk],
                    )
                });
                write_head_block(&mut slot.0, &wq, head, dqk, h);
                write_head_block(&mut slot.2, &wk, head, dqk, h);
                for j in 0..dqk {
                    slot.1[head * dqk + j] = bq[j] as f32;
                    slot.3[head * dqk + j] = bk[j] as f32;
                }
                if let Some(r) = rho2 {
                    rho_attn.push(r);
                }
            }
        }
    }
    for (l, slot) in attn_new.into_iter().enumerate() {
        if let Some((nwq, nbq, nwk, nbk)) = slot {
            let dqk = opts.attn_keep(cfg, l);
            out.insert(format!("blocks.{l}.attn.wq"), Tensor::from_vec(&[cfg.d, h * dqk], nwq));
            out.insert(format!("blocks.{l}.attn.bq"), Tensor::from_vec(&[h * dqk], nbq));
            out.insert(format!("blocks.{l}.attn.wk"), Tensor::from_vec(&[cfg.d, h * dqk], nwk));
            out.insert(format!("blocks.{l}.attn.bk"), Tensor::from_vec(&[h * dqk], nbk));
        }
    }

    let mean = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    Ok(PruneResult {
        weights: out,
        mean_mlp_rho2: mean(&rho_mlp),
        mean_attn_rho2: mean(&rho_attn),
        sections,
    })
}

/// Extract head `head`'s [d, dh] block from a fused projection [d, h*dh].
pub(crate) fn head_block(w: &Tensor, head: usize, dh: usize) -> Mat {
    let d = w.shape()[0];
    let hdh = w.shape()[1];
    let mut out = Mat::zeros(d, dh);
    for r in 0..d {
        for j in 0..dh {
            out.set(r, j, w.data()[r * hdh + head * dh + j] as f64);
        }
    }
    out
}

/// Write a [d, dqk] per-head block into the fused layout [d, h*dqk].
pub(crate) fn write_head_block(dst: &mut [f32], block: &Mat, head: usize, dqk: usize, h: usize) {
    let d = block.r;
    assert_eq!(block.c, dqk);
    for r in 0..d {
        for j in 0..dqk {
            dst[r * h * dqk + head * dqk + j] = block.at(r, j) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_block_roundtrip() {
        let (d, h, dh) = (3, 2, 2);
        let w = Tensor::from_vec(&[d, h * dh], (0..12).map(|v| v as f32).collect());
        let b0 = head_block(&w, 0, dh);
        let b1 = head_block(&w, 1, dh);
        assert_eq!(b0.at(0, 0), 0.0);
        assert_eq!(b0.at(0, 1), 1.0);
        assert_eq!(b1.at(0, 0), 2.0);
        assert_eq!(b1.at(2, 1), 11.0);
        // Round-trip through write_head_block.
        let mut dst = vec![0.0f32; d * h * dh];
        write_head_block(&mut dst, &b0, 0, dh, h);
        write_head_block(&mut dst, &b1, 1, dh, h);
        assert_eq!(dst, w.data());
    }

    #[test]
    fn per_head_extracts() {
        // [b=1, h=2, n=2, dh=2]
        let t = Tensor::from_vec(&[1, 2, 2, 2], (0..8).map(|v| v as f32).collect());
        let h0 = per_head(&t, 0);
        let h1 = per_head(&t, 1);
        assert_eq!(h0.shape(), &[1, 2, 2]);
        assert_eq!(h0.data(), &[0., 1., 2., 3.]);
        assert_eq!(h1.data(), &[4., 5., 6., 7.]);
    }

    #[test]
    fn concat_leading_stacks() {
        let a = Tensor::from_vec(&[1, 2], vec![1., 2.]);
        let b = Tensor::from_vec(&[2, 2], vec![3., 4., 5., 6.]);
        let c = concat_leading(&[a, b]);
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn default_opts_sane() {
        let o = PruneOpts::default();
        assert_eq!(o.method, Method::Corp);
        assert_eq!(o.criterion, Criterion::Mlp(MlpCriterion::Combined));
        assert!(o.alloc.is_none());
        assert!(o.lambda > 0.0);
    }

    #[test]
    fn keep_helpers_prefer_allocation() {
        let cfg = crate::model::ModelConfig::by_name("vit_t").unwrap();
        let mut o = PruneOpts::default();
        assert_eq!(o.mlp_keep(cfg, 0), keep_count(cfg.mlp, 5));
        assert_eq!(o.attn_keep(cfg, 0), keep_count(cfg.dh(), 5));
        o.alloc = Some(Allocation {
            mlp_keep: (0..cfg.layers).map(|l| cfg.mlp - l).collect(),
            qk_keep: vec![3; cfg.layers],
        });
        assert_eq!(o.mlp_keep(cfg, 2), cfg.mlp - 2);
        assert_eq!(o.attn_keep(cfg, 1), 3);
    }
}
