//! The CORP pipeline (Alg. 1) and the baseline methods.
//!
//! `calibrate` runs the dense model over unlabeled calibration batches and
//! accumulates every statistic all methods need (one pass, cached). `prune`
//! then ranks, compensates, and folds — producing a pruned `WeightStore`
//! whose shapes match the corresponding block artifacts.

pub mod baselines;

use anyhow::Result;

use crate::compensate::compensate_attn_head;
use crate::data::{Split, TextGen, VisionGen};
use crate::exec::Executor;
use crate::linalg::Mat;
use crate::model::{ModelKind, Scope, Sparsity, WeightStore};
use crate::rank::{partition, score_attn_logit_energy, score_mlp, MlpCriterion};
use crate::stats::{cov_blocks, ActiveCounter, MomentAccumulator};
use crate::tensor::Tensor;
use crate::util::timer::Sections;

/// Pruning method.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Method {
    /// CORP: ranking + closed-form affine / logit compensation.
    Corp,
    /// Same ranking, no compensation (the "w/o comp" curves).
    Naive,
    /// GRAIL-like: uncentered Gram-ridge output reconstruction, MLP only
    /// scope applies to w2; attention pruned naively.
    Grail,
    /// VBP-like: variance ranking + bias-only compensation, no B matrix.
    Vbp,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Corp => "CORP",
            Method::Naive => "naive",
            Method::Grail => "GRAIL-like",
            Method::Vbp => "VBP-like",
        }
    }
}

/// Pipeline options.
#[derive(Clone, Debug)]
pub struct PruneOpts {
    pub sparsity: Sparsity,
    pub method: Method,
    pub criterion: MlpCriterion,
    pub lambda: f64,
    /// Number of calibration batches (batch size = cfg.eval_batch()).
    pub calib_batches: usize,
    /// Sample cap for the attention Kronecker accumulation.
    pub attn_max_samples: usize,
    /// Threshold for the active-probability statistic.
    pub active_eps: f32,
    /// Compute per-layer rho²/J* diagnostics (costly eigen solves; §Perf L3-2).
    pub diagnostics: bool,
    pub seed: u64,
}

impl Default for PruneOpts {
    fn default() -> Self {
        Self {
            sparsity: Sparsity::of(Scope::Both, 5),
            method: Method::Corp,
            criterion: MlpCriterion::Combined,
            lambda: 1e-2,
            calib_batches: 16,
            attn_max_samples: 128,
            active_eps: 0.05,
            diagnostics: false,
            seed: 1234,
        }
    }
}

/// Per-layer calibration statistics.
pub struct LayerStats {
    /// Hidden-activation moments over [B·n, o].
    pub hidden: MomentAccumulator,
    pub active: ActiveCounter,
    /// Captured per-head queries/keys, concatenated over batches:
    /// [samples, heads, n, dh].
    pub q: Tensor,
    pub k: Tensor,
}

/// Full calibration result (Alg. 1's cache).
pub struct CalibStats {
    pub layers: Vec<LayerStats>,
    /// Wall-time charged per pipeline section (Table 6 analogue).
    pub sections: Sections,
}

/// Run the dense model on calibration data and accumulate statistics.
pub fn calibrate(exec: &Executor<'_>, w: &WeightStore, opts: &PruneOpts) -> Result<CalibStats> {
    let cfg = exec.cfg;
    let b = cfg.eval_batch();
    let mut sections = Sections::new();
    let mut hidden_acc: Vec<MomentAccumulator> =
        (0..cfg.layers).map(|_| MomentAccumulator::new(cfg.mlp)).collect();
    let mut active_acc: Vec<ActiveCounter> =
        (0..cfg.layers).map(|_| ActiveCounter::new(cfg.mlp, opts.active_eps)).collect();
    let mut qs: Vec<Vec<Tensor>> = vec![Vec::new(); cfg.layers];
    let mut ks: Vec<Vec<Tensor>> = vec![Vec::new(); cfg.layers];
    let vision = VisionGen::new(crate::data::DATA_SEED);
    let text = TextGen::new(crate::data::DATA_SEED);

    let mut attn_kept_samples = 0usize;
    for batch in 0..opts.calib_batches {
        // Calibration is *unlabeled*: only inputs are used.
        let (tokens, ids) = match cfg.kind {
            ModelKind::Vit => (Some(vision.batch(Split::Calib, batch as u64, b).0), None),
            ModelKind::Gpt => (None, Some(text.batch(Split::Calib, batch as u64, b, cfg.n_ctx).0)),
        };
        let caps = sections.time("calibration", || {
            exec.forward_capture(w, tokens.as_ref(), ids.as_deref())
        })?;
        let keep_qk = attn_kept_samples < opts.attn_max_samples;
        for (l, cap) in caps.1.into_iter().enumerate() {
            let rows = b * cfg.n_ctx;
            sections.time("calibration", || {
                hidden_acc[l].add_batch(cap.hidden.data(), rows);
                active_acc[l].add_batch(cap.hidden.data(), rows);
            });
            if keep_qk {
                qs[l].push(cap.q);
                ks[l].push(cap.k);
            }
        }
        if keep_qk {
            attn_kept_samples += b;
        }
    }

    // Concatenate Q/K batches per layer.
    let layers = hidden_acc
        .into_iter()
        .zip(active_acc)
        .zip(qs.into_iter().zip(ks))
        .map(|((hidden, active), (qv, kv))| LayerStats {
            hidden,
            active,
            q: concat_leading(&qv),
            k: concat_leading(&kv),
        })
        .collect();
    Ok(CalibStats { layers, sections })
}

fn concat_leading(parts: &[Tensor]) -> Tensor {
    assert!(!parts.is_empty());
    let mut shape = parts[0].shape().to_vec();
    let inner: usize = shape[1..].iter().product();
    let total: usize = parts.iter().map(|t| t.shape()[0]).sum();
    let mut data = Vec::with_capacity(total * inner);
    for p in parts {
        data.extend_from_slice(p.data());
    }
    shape[0] = total;
    Tensor::from_vec(&shape, data)
}

/// Reshape the captured [samples, heads, n, dh] slab into per-head
/// [samples, n, dh] views (copied; sizes are small).
pub fn per_head(t: &Tensor, head: usize) -> Tensor {
    let s = t.shape();
    let (b, h, n, dh) = (s[0], s[1], s[2], s[3]);
    let mut out = Vec::with_capacity(b * n * dh);
    for i in 0..b {
        let base = ((i * h) + head) * n * dh;
        out.extend_from_slice(&t.data()[base..base + n * dh]);
    }
    Tensor::from_vec(&[b, n, dh], out)
}

/// Outcome of a pruning run.
pub struct PruneResult {
    pub weights: WeightStore,
    /// Mean per-layer MLP ρ² (variance explained) — diagnostic.
    pub mean_mlp_rho2: f64,
    /// Mean per-head attention ρ².
    pub mean_attn_rho2: f64,
    pub sections: Sections,
}

/// Rank + compensate + fold (Alg. 1 after calibration).
pub fn prune(
    exec: &Executor<'_>,
    dense: &WeightStore,
    stats: &CalibStats,
    opts: &PruneOpts,
) -> Result<PruneResult> {
    match opts.method {
        Method::Corp => prune_corp(exec, dense, stats, opts, true),
        Method::Naive => prune_corp(exec, dense, stats, opts, false),
        Method::Grail => baselines::prune_grail(exec, dense, stats, opts),
        Method::Vbp => baselines::prune_vbp(exec, dense, stats, opts),
    }
}

/// Convenience: calibrate + prune.
pub fn run_pipeline(
    exec: &Executor<'_>,
    dense: &WeightStore,
    opts: &PruneOpts,
) -> Result<PruneResult> {
    let stats = calibrate(exec, dense, opts)?;
    let mut result = prune(exec, dense, &stats, opts)?;
    result.sections.merge(&stats.sections);
    Ok(result)
}

fn prune_corp(
    exec: &Executor<'_>,
    dense: &WeightStore,
    stats: &CalibStats,
    opts: &PruneOpts,
    compensate: bool,
) -> Result<PruneResult> {
    let cfg = exec.cfg;
    let mut out = dense.clone();
    let mut sections = Sections::new();
    let mut rho_mlp = Vec::new();
    let mut rho_attn = Vec::new();

    for l in 0..cfg.layers {
        let ls = &stats.layers[l];
        // ---------------- MLP scope ----------------
        if opts.sparsity.mlp_s10 > 0 {
            let w1 = dense.expect(&format!("blocks.{l}.mlp.w1"))?;
            let b1 = dense.expect(&format!("blocks.{l}.mlp.b1"))?;
            let w2 = dense.expect(&format!("blocks.{l}.mlp.w2"))?;
            let b2 = dense.expect(&format!("blocks.{l}.mlp.b2"))?;
            let (kept, pruned) = sections.time("ranking", || {
                let scores = score_mlp(opts.criterion, &ls.hidden.energy(), &ls.active.active_prob(), w2);
                partition(&scores, opts.sparsity.mlp_s10)
            });
            // First layer: always a column gather.
            out.insert(format!("blocks.{l}.mlp.w1"), w1.gather_cols(&kept));
            out.insert(format!("blocks.{l}.mlp.b1"), b1.gather_cols(&kept));
            if compensate {
                let (w2_hat, b2_hat, rho2) = sections.time("compensation", || {
                    let cov = ls.hidden.covariance();
                    let mean = ls.hidden.mean();
                    let blocks = cov_blocks(&cov, &mean, &kept, &pruned);
                    let comp = crate::compensate::mlp::compensate_mlp_opts(
                        w2, b2, &kept, &pruned, &blocks, opts.lambda, opts.diagnostics,
                    );
                    (comp.w2_hat, comp.b2_hat, comp.rho2)
                });
                out.insert(format!("blocks.{l}.mlp.w2"), w2_hat);
                out.insert(format!("blocks.{l}.mlp.b2"), b2_hat);
                rho_mlp.push(rho2);
            } else {
                out.insert(format!("blocks.{l}.mlp.w2"), w2.gather_rows(&kept));
            }
        }
        // ---------------- Attention scope ----------------
        if opts.sparsity.attn_s10 > 0 {
            let dh = cfg.dh();
            let h = cfg.heads;
            let wq = dense.expect(&format!("blocks.{l}.attn.wq"))?;
            let bq = dense.expect(&format!("blocks.{l}.attn.bq"))?;
            let wk = dense.expect(&format!("blocks.{l}.attn.wk"))?;
            let bk = dense.expect(&format!("blocks.{l}.attn.bk"))?;
            let dqk = crate::model::keep_count(dh, opts.sparsity.attn_s10);
            let mut new_wq = vec![0.0f32; cfg.d * h * dqk];
            let mut new_bq = vec![0.0f32; h * dqk];
            let mut new_wk = vec![0.0f32; cfg.d * h * dqk];
            let mut new_bk = vec![0.0f32; h * dqk];
            for head in 0..h {
                let qh = per_head(&ls.q, head);
                let kh = per_head(&ls.k, head);
                let (kept, pruned) = sections.time("ranking", || {
                    let scores = score_attn_logit_energy(&qh, &kh);
                    partition(&scores, opts.sparsity.attn_s10)
                });
                // Dense per-head projection blocks [d, dh].
                let wq_head = head_block(wq, head, dh);
                let wk_head = head_block(wk, head, dh);
                let bq_head: Vec<f64> =
                    (0..dh).map(|j| bq.data()[head * dh + j] as f64).collect();
                let bk_head: Vec<f64> =
                    (0..dh).map(|j| bk.data()[head * dh + j] as f64).collect();
                if compensate {
                    let comp = sections.time("compensation", || {
                        compensate_attn_head(
                            &qh,
                            &kh,
                            &kept,
                            &pruned,
                            &wq_head,
                            &bq_head,
                            &wk_head,
                            &bk_head,
                            opts.lambda,
                            opts.attn_max_samples,
                        )
                    });
                    write_head_block(&mut new_wq, &comp.wq, head, dqk, h);
                    write_head_block(&mut new_wk, &comp.wk, head, dqk, h);
                    for j in 0..dqk {
                        new_bq[head * dqk + j] = comp.bq[j] as f32;
                        new_bk[head * dqk + j] = comp.bk[j] as f32;
                    }
                    rho_attn.push(comp.rho2);
                } else {
                    // Naive: gather kept columns.
                    for (j, &c) in kept.iter().enumerate() {
                        for r in 0..cfg.d {
                            new_wq[r * h * dqk + head * dqk + j] = wq.at2(r, head * dh + c);
                            new_wk[r * h * dqk + head * dqk + j] = wk.at2(r, head * dh + c);
                        }
                        new_bq[head * dqk + j] = bq.data()[head * dh + c];
                        new_bk[head * dqk + j] = bk.data()[head * dh + c];
                    }
                }
            }
            out.insert(format!("blocks.{l}.attn.wq"), Tensor::from_vec(&[cfg.d, h * dqk], new_wq));
            out.insert(format!("blocks.{l}.attn.bq"), Tensor::from_vec(&[h * dqk], new_bq));
            out.insert(format!("blocks.{l}.attn.wk"), Tensor::from_vec(&[cfg.d, h * dqk], new_wk));
            out.insert(format!("blocks.{l}.attn.bk"), Tensor::from_vec(&[h * dqk], new_bk));
        }
    }

    let mean = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    Ok(PruneResult {
        weights: out,
        mean_mlp_rho2: mean(&rho_mlp),
        mean_attn_rho2: mean(&rho_attn),
        sections,
    })
}

/// Extract head `head`'s [d, dh] block from a fused projection [d, h*dh].
pub(crate) fn head_block(w: &Tensor, head: usize, dh: usize) -> Mat {
    let d = w.shape()[0];
    let hdh = w.shape()[1];
    let mut out = Mat::zeros(d, dh);
    for r in 0..d {
        for j in 0..dh {
            out.set(r, j, w.data()[r * hdh + head * dh + j] as f64);
        }
    }
    out
}

/// Write a [d, dqk] per-head block into the fused layout [d, h*dqk].
pub(crate) fn write_head_block(dst: &mut [f32], block: &Mat, head: usize, dqk: usize, h: usize) {
    let d = block.r;
    assert_eq!(block.c, dqk);
    for r in 0..d {
        for j in 0..dqk {
            dst[r * h * dqk + head * dqk + j] = block.at(r, j) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_block_roundtrip() {
        let (d, h, dh) = (3, 2, 2);
        let w = Tensor::from_vec(&[d, h * dh], (0..12).map(|v| v as f32).collect());
        let b0 = head_block(&w, 0, dh);
        let b1 = head_block(&w, 1, dh);
        assert_eq!(b0.at(0, 0), 0.0);
        assert_eq!(b0.at(0, 1), 1.0);
        assert_eq!(b1.at(0, 0), 2.0);
        assert_eq!(b1.at(2, 1), 11.0);
        // Round-trip through write_head_block.
        let mut dst = vec![0.0f32; d * h * dh];
        write_head_block(&mut dst, &b0, 0, dh, h);
        write_head_block(&mut dst, &b1, 1, dh, h);
        assert_eq!(dst, w.data());
    }

    #[test]
    fn per_head_extracts() {
        // [b=1, h=2, n=2, dh=2]
        let t = Tensor::from_vec(&[1, 2, 2, 2], (0..8).map(|v| v as f32).collect());
        let h0 = per_head(&t, 0);
        let h1 = per_head(&t, 1);
        assert_eq!(h0.shape(), &[1, 2, 2]);
        assert_eq!(h0.data(), &[0., 1., 2., 3.]);
        assert_eq!(h1.data(), &[4., 5., 6., 7.]);
    }

    #[test]
    fn concat_leading_stacks() {
        let a = Tensor::from_vec(&[1, 2], vec![1., 2.]);
        let b = Tensor::from_vec(&[2, 2], vec![3., 4., 5., 6.]);
        let c = concat_leading(&[a, b]);
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn default_opts_sane() {
        let o = PruneOpts::default();
        assert_eq!(o.method, Method::Corp);
        assert_eq!(o.criterion, MlpCriterion::Combined);
        assert!(o.lambda > 0.0);
    }
}
