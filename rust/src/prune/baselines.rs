//! Baseline pruning methods (mechanism re-implementations; DESIGN.md §5).
//!
//! * GRAIL-like — post-hoc *uncentered* Gram-ridge reconstruction of the
//!   module output through the second linear layer only; no bias/mean
//!   modeling, no Q/K logit compensation (attention pruned naively).
//! * VBP-like — activation-variance ranking, bias-only compensation
//!   (b̂ = b + W_P μ_P), no B matrix; MLP scope only.
//! * SNOWS-like — 2:4 semi-structured magnitude masking of W₂ rows with
//!   per-output closed-form least-squares recovery on calibration Gram
//!   statistics (keeps feature dims; no structural shrinkage).
//! * DC-ViT-like — removes whole attention modules (by attention-output
//!   energy) and prunes MLP channels, recovering with closed-form
//!   feature-mimic ridge per modified block (substitute for DC-ViT's SGD
//!   feature mimicking).

use anyhow::Result;

use super::{CalibStats, PruneOpts, PruneResult};
use crate::exec::Executor;
use crate::linalg::chol::Cholesky;
use crate::linalg::Mat;
use crate::model::WeightStore;
use crate::rank::{nan_last_desc, partition_k};

use crate::tensor::Tensor;
use crate::util::timer::Sections;

/// GRAIL-like: for each MLP block, prune hidden channels (same combined
/// ranking as CORP for comparability) and refit W₂ rows by uncentered ridge
/// so that X_S Ŵ ≈ X W₂ on calibration data:
///   Ŵ = (E[x_S x_Sᵀ] + λI)⁻¹ E[x_S xᵀ] W₂.
/// Attention scope is pruned naively (GRAIL has no logit compensator).
pub fn prune_grail(
    exec: &Executor<'_>,
    dense: &WeightStore,
    stats: &CalibStats,
    opts: &PruneOpts,
) -> Result<PruneResult> {
    // Start from the naive-pruned model (both scopes), then overwrite the
    // MLP second layers with the Gram-ridge reconstruction.
    let naive_opts = PruneOpts { method: super::Method::Naive, ..opts.clone() };
    let mut result = super::prune_corp(exec, dense, stats, &naive_opts, false)?;
    let cfg = exec.cfg;
    let mut sections = Sections::new();

    {
        for l in 0..cfg.layers {
            let keep = opts.mlp_keep(cfg, l);
            if keep >= cfg.mlp {
                continue;
            }
            let ls = &stats.layers[l];
            let w2 = dense.expect(&format!("blocks.{l}.mlp.w2"))?;
            let (kept, _pruned) = {
                // Same zoo ranking as the naive pass above, so the refit
                // targets exactly the surviving channels.
                let scores = crate::rank::score_mlp_zoo(
                    opts.criterion,
                    &ls.hidden,
                    &ls.active.active_prob(),
                    w2,
                    opts.lambda,
                );
                partition_k(&scores, keep)
            };
            let w2_hat = sections.time("compensation", || {
                let second = ls.hidden.second_moment(); // E[x xᵀ], uncentered
                let all: Vec<usize> = (0..cfg.mlp).collect();
                let ss = second.submatrix(&kept, &kept);
                let sa = second.submatrix(&kept, &all);
                // W₂ as Mat [o, d].
                let w2m = Mat::from_f32(cfg.mlp, cfg.d, w2.data());
                let rhs = sa.mul(&w2m); // [|S|, d]
                let scale = (ss.trace() / ss.r.max(1) as f64).max(1e-12);
                let (f, _) = Cholesky::new_with_jitter(&ss.add_diag(opts.lambda * scale));
                let sol = f.solve_mat(&rhs); // [|S|, d]
                Tensor::from_vec(&[kept.len(), cfg.d], sol.to_f32())
            });
            result.weights.insert(format!("blocks.{l}.mlp.w2"), w2_hat);
            // b2 left unchanged (GRAIL models no bias shift).
        }
    }
    result.sections.merge(&sections);
    Ok(result)
}

/// VBP-like: variance ranking + bias-only compensation on the MLP scope;
/// attention pruned naively at the requested attention sparsity.
pub fn prune_vbp(
    exec: &Executor<'_>,
    dense: &WeightStore,
    stats: &CalibStats,
    opts: &PruneOpts,
) -> Result<PruneResult> {
    let cfg = exec.cfg;
    // Attention scope: reuse the naive path (VBP does not prune QK dims; we
    // still honor the requested scope for matched-FLOPs comparisons).
    let naive_opts = PruneOpts { method: super::Method::Naive, ..opts.clone() };
    let mut result = super::prune_corp(exec, dense, stats, &naive_opts, false)?;
    let mut sections = Sections::new();

    {
        for l in 0..cfg.layers {
            let keep = opts.mlp_keep(cfg, l);
            if keep >= cfg.mlp {
                continue;
            }
            let ls = &stats.layers[l];
            let w1 = dense.expect(&format!("blocks.{l}.mlp.w1"))?;
            let b1 = dense.expect(&format!("blocks.{l}.mlp.b1"))?;
            let w2 = dense.expect(&format!("blocks.{l}.mlp.w2"))?;
            let b2 = dense.expect(&format!("blocks.{l}.mlp.b2"))?;
            let (kept, pruned) = sections.time("ranking", || {
                // Variance ranking, clamped at the accumulator boundary
                // (`MomentAccumulator::variance` owns the ≥ 0 contract).
                partition_k(&ls.hidden.variance(), keep)
            });
            result.weights.insert(format!("blocks.{l}.mlp.w1"), w1.gather_cols(&kept));
            result.weights.insert(format!("blocks.{l}.mlp.b1"), b1.gather_cols(&kept));
            result.weights.insert(format!("blocks.{l}.mlp.w2"), w2.gather_rows(&kept));
            // Bias compensation: b̂ = b + Σ_{i∈P} μ_i · W₂[i, :].
            let (b2_hat,) = sections.time("compensation", || {
                let mean = ls.hidden.mean();
                let mut b = b2.data().to_vec();
                for &i in &pruned {
                    let row = w2.row(i);
                    for (bj, &wij) in b.iter_mut().zip(row) {
                        *bj += (mean[i] as f32) * wij;
                    }
                }
                (Tensor::from_vec(&[cfg.d], b),)
            });
            result.weights.insert(format!("blocks.{l}.mlp.b2"), b2_hat.clone());
        }
    }
    result.sections.merge(&sections);
    Ok(result)
}

/// SNOWS-like 2:4 semi-structured pruning of W₂ with closed-form row
/// recovery. Keeps all feature dimensions (no structural speedup) — used
/// only for the Table 4a analogue. Returns dense-shaped weights.
///
/// For each output column c of the layer y = xᵀW₂ (+b): mask the smallest
/// 2 of every 4 consecutive input weights (by |w|·√E[x²], the activation-
/// aware magnitude), then refit the surviving support to minimize
/// E‖xᵀw_orig − x_Sᵀw_new‖² = min over w_new, solved from the hidden Gram.
pub fn prune_snows24(
    exec: &Executor<'_>,
    dense: &WeightStore,
    stats: &CalibStats,
    opts: &PruneOpts,
    scope_mlp: bool,
) -> Result<PruneResult> {
    let cfg = exec.cfg;
    let mut out = dense.clone();
    let mut sections = Sections::new();

    for l in 0..cfg.layers {
        let ls = &stats.layers[l];
        if scope_mlp {
            let w2 = dense.expect(&format!("blocks.{l}.mlp.w2"))?;
            let energy = ls.hidden.energy();
            let second = ls.hidden.second_moment();
            let new_w2 = sections.time("compensation", || {
                snows_mask_and_recover(w2, &energy, &second, opts.lambda)
            });
            out.insert(format!("blocks.{l}.mlp.w2"), new_w2);
        } else {
            // Attention scope: 2:4 on wq/wk input dims, recovered against the
            // layer-input Gram. We approximate the input second moment with
            // the identity-scaled Gram of Q/K activations' pre-projection
            // statistics being unavailable; magnitude-only masking + no
            // recovery is the honest fallback and matches SNOWS' 2:4 scope
            // on Q/K projections.
            for name in ["attn.wq", "attn.wk"] {
                let w = dense.expect(&format!("blocks.{l}.{name}"))?;
                let masked = sections.time("compensation", || mask24_only(w));
                out.insert(format!("blocks.{l}.{name}"), masked);
            }
        }
    }
    Ok(PruneResult { weights: out, mean_mlp_rho2: 0.0, mean_attn_rho2: 0.0, sections })
}

/// 2:4 masking + per-output least-squares recovery for W₂ [o, d].
fn snows_mask_and_recover(w2: &Tensor, energy: &[f64], second: &Mat, lambda: f64) -> Tensor {
    let (o, d) = (w2.shape()[0], w2.shape()[1]);
    let mut out = vec![0.0f32; o * d];
    let scale = (second.trace() / o.max(1) as f64).max(1e-12);
    for c in 0..d {
        // Column c of the output: weights w2[:, c] over hidden inputs.
        let col: Vec<f64> = (0..o).map(|i| w2.at2(i, c) as f64).collect();
        // Activation-aware 2:4 masking along the input axis.
        let mut support: Vec<usize> = Vec::with_capacity(o / 2);
        for g in (0..o).step_by(4) {
            let end = (g + 4).min(o);
            let mut idx: Vec<usize> = (g..end).collect();
            idx.sort_by(|&a, &b| {
                let sa = col[a].abs() * energy[a].sqrt();
                let sb = col[b].abs() * energy[b].sqrt();
                nan_last_desc(sa, sb)
            });
            let keep = idx.len().div_ceil(2);
            let mut kept: Vec<usize> = idx[..keep].to_vec();
            kept.sort_unstable();
            support.extend(kept);
        }
        // Recover: w_new = (Σ_SS + λI)⁻¹ Σ_S,: w_orig.
        let ss = second.submatrix(&support, &support);
        let all: Vec<usize> = (0..o).collect();
        let sa = second.submatrix(&support, &all);
        let mut rhs = vec![0.0f64; support.len()];
        for (i, _) in support.iter().enumerate() {
            rhs[i] = (0..o).map(|j| sa.at(i, j) * col[j]).sum();
        }
        let (f, _) = Cholesky::new_with_jitter(&ss.add_diag(lambda * scale));
        let sol = f.solve_vec(&rhs);
        for (i, &s) in support.iter().enumerate() {
            out[s * d + c] = sol[i] as f32;
        }
    }
    Tensor::from_vec(&[o, d], out)
}

/// Plain magnitude 2:4 masking along the input (row) axis.
fn mask24_only(w: &Tensor) -> Tensor {
    let (r, c) = (w.shape()[0], w.shape()[1]);
    let mut out = w.data().to_vec();
    for j in 0..c {
        for g in (0..r).step_by(4) {
            let end = (g + 4).min(r);
            let mut idx: Vec<usize> = (g..end).collect();
            idx.sort_by(|&a, &b| {
                nan_last_desc(w.at2(a, j).abs() as f64, w.at2(b, j).abs() as f64)
            });
            let keep = idx.len().div_ceil(2);
            for &i in &idx[keep..] {
                out[i * c + j] = 0.0;
            }
        }
    }
    Tensor::from_vec(&[r, c], out)
}

/// DC-ViT-like: remove attention modules from the `remove` lowest-importance
/// blocks (importance = calibration attention-logit energy), prune MLP
/// channels everywhere at `opts.sparsity.mlp_s10`, and feature-mimic each
/// modified block's MLP against the dense block outputs by closed-form
/// ridge. Returns weights *plus* the list of attention-free layers (the
/// executor must use the `mlponly_*` artifacts for those layers).
pub fn prune_dcvit(
    exec: &Executor<'_>,
    dense: &WeightStore,
    stats: &CalibStats,
    opts: &PruneOpts,
    remove_attn_layers: usize,
) -> Result<(PruneResult, Vec<usize>)> {
    let cfg = exec.cfg;
    // Rank blocks by total attention logit energy; remove the weakest.
    let mut energies: Vec<(usize, f64)> = (0..cfg.layers)
        .map(|l| {
            let ls = &stats.layers[l];
            let mut e = 0.0;
            for head in 0..cfg.heads {
                let qh = super::per_head(&ls.q, head);
                let kh = super::per_head(&ls.k, head);
                e += crate::rank::score_attn_logit_energy(&qh, &kh).iter().sum::<f64>();
            }
            (l, e)
        })
        .collect();
    // Ascending energy; `total_cmp` keeps degenerate (NaN) layers last so
    // they are never selected for attention removal.
    energies.sort_by(|a, b| a.1.total_cmp(&b.1));
    let removed: Vec<usize> = energies.iter().take(remove_attn_layers).map(|&(l, _)| l).collect();

    // MLP pruning with CORP-style compensation (DC-ViT recovers with feature
    // mimicking; the closed-form affine recovery is our gradient-free
    // substitute — documented in DESIGN.md).
    let corp_opts = PruneOpts {
        method: super::Method::Corp,
        sparsity: crate::model::Sparsity { mlp_s10: opts.sparsity.mlp_s10, attn_s10: 0 },
        // DC-ViT removes whole attention modules instead of QK dims: keep
        // any global allocation's MLP counts but leave attention dense.
        alloc: opts.alloc.clone().map(|mut a| {
            a.qk_keep = vec![exec.cfg.dh(); exec.cfg.layers];
            a
        }),
        ..opts.clone()
    };
    let result = super::prune_corp(exec, dense, stats, &corp_opts, true)?;
    Ok((result, removed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::gen;
    use crate::util::Pcg64;

    #[test]
    fn mask24_keeps_half_per_group() {
        let mut rng = Pcg64::new(1);
        let w = Tensor::from_vec(&[8, 3], gen::matrix(&mut rng, 8, 3, 1.0));
        let m = mask24_only(&w);
        for j in 0..3 {
            for g in (0..8).step_by(4) {
                let nz = (g..g + 4).filter(|&i| m.at2(i, j) != 0.0).count();
                assert_eq!(nz, 2, "col {j} group {g}");
            }
        }
        // Survivors are the 2 largest-magnitude entries of each group.
        for j in 0..3 {
            for g in (0..8).step_by(4) {
                let mut mags: Vec<(f32, usize)> =
                    (g..g + 4).map(|i| (w.at2(i, j).abs(), i)).collect();
                mags.sort_by(|a, b| b.0.total_cmp(&a.0));
                for &(_, i) in &mags[..2] {
                    assert_ne!(m.at2(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn snows_recovery_beats_plain_masking() {
        // Correlated hidden activations: recovery must reduce output error
        // versus masking alone.
        let mut rng = Pcg64::new(4);
        let (o, d, rows) = (16, 4, 300);
        // x = z B + noise, z low-dim -> correlated channels.
        let basis = gen::matrix(&mut rng, 3, o, 1.0);
        let mut x = vec![0.0f32; rows * o];
        for r in 0..rows {
            let z: Vec<f32> = (0..3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            for c in 0..o {
                let mut v = 0.0;
                for k in 0..3 {
                    v += z[k] * basis[k * o + c];
                }
                x[r * o + c] = v + rng.normal_f32(0.0, 0.05);
            }
        }
        let mut acc = crate::stats::MomentAccumulator::new(o);
        acc.add_batch(&x, rows);
        let w2 = Tensor::from_vec(&[o, d], gen::matrix(&mut rng, o, d, 1.0));
        let energy = acc.energy();
        let second = acc.second_moment();
        let recovered = snows_mask_and_recover(&w2, &energy, &second, 1e-6);
        let masked = {
            // activation-aware mask only (same support, no refit):
            let mut m = recovered.clone();
            // rebuild support from recovered (non-zeros), then copy orig vals
            for i in 0..o {
                for j in 0..d {
                    if m.at2(i, j) != 0.0 {
                        m.data_mut()[i * d + j] = w2.at2(i, j);
                    }
                }
            }
            m
        };
        let err = |wn: &Tensor| -> f64 {
            let mut e = 0.0;
            for r in 0..rows {
                let xr = &x[r * o..(r + 1) * o];
                for j in 0..d {
                    let full: f64 = (0..o).map(|i| (xr[i] * w2.at2(i, j)) as f64).sum();
                    let got: f64 = (0..o).map(|i| (xr[i] * wn.at2(i, j)) as f64).sum();
                    e += (full - got) * (full - got);
                }
            }
            e
        };
        let e_rec = err(&recovered);
        let e_mask = err(&masked);
        assert!(e_rec < e_mask * 0.9, "recovered {e_rec} vs masked {e_mask}");
    }
}
