//! Deterministic discrete-event simulation of the serving engine.
//!
//! The threaded engine (`serve/engine.rs`) cannot give bit-reproducible
//! controller trajectories: condvar wakeups and OS scheduling order are
//! outside any seed's control. This module replays the *same* queueing
//! semantics — bounded FIFO, single-unit batch formation with a deadline,
//! dispatch-policy shapes, continuation re-enqueue, shed-on-full-queue,
//! controller ticks — as a single-real-thread event loop on a
//! [`VirtualClock`], with `opts.workers` modeled as simulated servers and
//! per-batch service times drawn from a [`SimCost`] model instead of the
//! wall clock. Every source of ordering is a seeded RNG or a deterministic
//! tie-break (lowest event time, then insertion order; lowest server index
//! first), so a run is a pure function of its inputs: the same seed gives
//! the same trajectory at any worker count, and tests can assert exact
//! transition sequences.
//!
//! Batches still execute the *real* workload step (real plans, real
//! predictions) — only *time* is synthetic. The controller's cost
//! estimator observes the simulated service times, so its decisions track
//! the cost model exactly as they would track measured wall time in
//! production.
//!
//! The chaos layer (`EngineOpts::chaos`) is threaded through here too:
//! injected kills take a simulated server dark for the supervisor backoff
//! and route its batch through the retry path, dispatch faults and
//! deadline expiries resolve before the step runs, and delays stretch the
//! drawn service time — all keyed on schedule-independent identities
//! (request id, per-server dispatch ordinal), so a fault trajectory is as
//! bit-reproducible as a fault-free one.

#![cfg(not(pjrt_backend))]

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, VecDeque};

use anyhow::{bail, Result};

use crate::serve::clock::{Clock, VirtualClock};
use crate::serve::controller::{Action, Controller, CostEstimator, MemberCfg, Obs, Transition};
use crate::serve::engine::{
    arrival_order, arrival_times, finalize_stats, EngineOpts, EngineStats, ErasedMember,
    FaultState, FaultTally, Queued, RequestRecord, Unit, RESPAWN_BACKOFF_S, RESPAWN_BUDGET,
};
use crate::serve::workload::{DispatchPolicy, StepOutcome};
use crate::util::Pcg64;

/// Per-member service-time model: `tables[variant][dispatch - 1]` is the
/// batch execution time in seconds for a dispatch of that size on that
/// plan rung, optionally perturbed by a seeded multiplicative jitter in
/// `[1 - jitter, 1 + jitter]`.
#[derive(Debug, Clone)]
pub struct SimCost {
    tables: Vec<Vec<f64>>,
    jitter: f64,
}

impl SimCost {
    /// Affine cost `scale * (base_s + per_row_s * dispatch)` per rung —
    /// one `scales` entry per variant (empty = single dense rung at 1.0).
    /// A degraded rung's scale < 1 models CORP's cheaper pruned GEMMs.
    pub fn affine(max_batch: usize, base_s: f64, per_row_s: f64, scales: &[f64]) -> Self {
        let scales: &[f64] = if scales.is_empty() { &[1.0] } else { scales };
        let tables = scales
            .iter()
            .map(|&sc| (1..=max_batch.max(1)).map(|b| sc * (base_s + per_row_s * b as f64)).collect())
            .collect();
        SimCost { tables, jitter: 0.0 }
    }

    /// Measured per-rung cost tables (`tables[variant][dispatch - 1]`,
    /// seconds) — e.g. timed on the real executor by the bench harness.
    pub fn measured(tables: Vec<Vec<f64>>) -> Result<Self> {
        if tables.is_empty() || tables.iter().any(|t| t.is_empty()) {
            bail!("SimCost::measured: every variant needs a non-empty cost table");
        }
        Ok(SimCost { tables, jitter: 0.0 })
    }

    /// Multiplicative service-time jitter amplitude (0 = deterministic
    /// costs; the jitter *stream* is seeded either way).
    pub fn with_jitter(mut self, jitter: f64) -> Self {
        self.jitter = jitter.clamp(0.0, 0.99);
        self
    }

    /// Service time for one batch: `u` is a uniform draw in `[0, 1)`.
    fn cost(&self, variant: usize, dispatch: usize, u: f64) -> f64 {
        let t = &self.tables[variant.min(self.tables.len() - 1)];
        let c = t[dispatch.clamp(1, t.len()) - 1];
        (c * (1.0 + self.jitter * (2.0 * u - 1.0))).max(0.0)
    }
}

#[derive(Clone, Copy, Debug)]
enum EvKind {
    /// The k-th offered arrival (index into the interleaved order).
    Arrival(usize),
    /// A waiting server's batch-formation deadline; stale if the server's
    /// generation moved on.
    Deadline { server: usize, gen: u64 },
    /// A busy server finishes its batch.
    Done { server: usize },
    /// Controller tick.
    Tick,
    /// A killed server comes back after its supervisor backoff.
    Respawn { server: usize },
    /// A retried request's backoff (`not_before`) expires; the event
    /// carries nothing — it exists to re-run the schedule pass.
    Wake,
}

struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    // Reversed so the std max-heap pops the earliest event; ties break by
    // insertion order for full determinism.
    fn cmp(&self, other: &Self) -> CmpOrdering {
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

enum ServerState {
    Idle,
    /// Holding a partial batch open for more same-unit arrivals.
    Waiting { unit: usize, batch: Vec<Queued>, gen: u64 },
    /// Executing; outcomes were computed at dispatch time.
    Busy { batch: Vec<Queued>, outs: Vec<StepOutcome>, exec_ms: f64, variant: usize },
}

struct Sim<'u, 's> {
    units: &'u [Unit<'s>],
    costs: &'u [SimCost],
    opts: &'u EngineOpts,
    clock: VirtualClock,
    b_art: usize,
    seq: u64,
    gen: u64,
    heap: BinaryHeap<Ev>,
    queue: VecDeque<Queued>,
    servers: Vec<ServerState>,
    shed: Vec<usize>,
    records: Vec<Vec<RequestRecord>>,
    batch_log: Vec<(usize, usize, usize, f64, usize)>,
    /// Windowed per-member completion latencies, drained every tick.
    lat: Vec<Vec<f64>>,
    est: CostEstimator,
    controller: Option<Controller>,
    wait_s: f64,
    thresh: f64,
    jitter_rng: Pcg64,
    order: Vec<(usize, usize)>,
    arrivals: Vec<f64>,
    fired: usize,
    tick_arr_mark: usize,
    closed: bool,
    /// The same one-shot chaos plan the threaded engine consumes; keys
    /// are schedule-independent (request id / server dispatch ordinal),
    /// so the replayed trajectory is identical.
    faults: Option<FaultState>,
    tally: Vec<FaultTally>,
    /// Per-server: alive flag, remaining respawn budget, next backoff,
    /// and the server's own dispatch ordinal (the `kill=W@B` key).
    alive: Vec<bool>,
    budget: Vec<usize>,
    backoff: Vec<f64>,
    dispatch_ord: Vec<usize>,
    respawns: usize,
    /// Cumulative fault events (timeouts + retries + failures), windowed
    /// per controller tick into `Obs::fault_rate`.
    fault_events: usize,
    tick_fault_mark: usize,
}

impl Sim<'_, '_> {
    fn push_ev(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Ev { t, seq: self.seq, kind });
    }

    /// Move every queued same-unit request into server `s`'s open batch.
    /// Requests whose retry backoff (`not_before`) has not expired are
    /// left in place, as in the threaded workers.
    fn top_up(&mut self, s: usize) {
        let now = self.clock.now();
        if let ServerState::Waiting { unit, batch, .. } = &mut self.servers[s] {
            let unit = *unit;
            let mut i = 0;
            while batch.len() < self.b_art && i < self.queue.len() {
                if self.queue[i].unit == unit && self.queue[i].not_before <= now {
                    batch.push(self.queue.remove(i).expect("indexed item"));
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Route a timed-out / faulted / kill-recovered request: re-enqueue
    /// with its original arrival while retry budget remains, else a
    /// counted failure whose engine-side KV state is reclaimed. Mirrors
    /// the threaded engine's `retry_or_fail` exactly.
    fn retry_or_fail(&mut self, mut q: Queued, timed_out: bool) {
        let now = self.clock.now();
        if timed_out {
            self.tally[q.unit].timeouts += 1;
        }
        self.fault_events += 1;
        if q.tries < self.opts.max_retries {
            q.tries += 1;
            self.tally[q.unit].retries += 1;
            q.not_before = if self.opts.retry_backoff > 0.0 {
                now + self.opts.retry_backoff * (1u64 << (q.tries - 1).min(16)) as f64
            } else {
                0.0
            };
            if q.not_before > now {
                self.push_ev(q.not_before, EvKind::Wake);
            }
            self.queue.push_back(q);
        } else {
            self.tally[q.unit].failures += 1;
            self.tally[q.unit].reclaimed_blocks += (self.units[q.unit].reclaim)(&[q.id]);
        }
    }

    /// Dispatch server `s`'s held batch: compute real outcomes now, draw
    /// the simulated service time, and schedule its completion.
    fn start_exec(&mut self, s: usize) -> Result<()> {
        let (unit, mut batch) =
            match std::mem::replace(&mut self.servers[s], ServerState::Idle) {
                ServerState::Waiting { unit, batch, .. } => (unit, batch),
                other => {
                    self.servers[s] = other;
                    return Ok(());
                }
            };
        // Deadlines and injected dispatch faults resolve before the step
        // runs — same ordering as the threaded workers, so a retried
        // request reproduces its fault-free prediction bit-for-bit.
        if self.opts.request_timeout > 0.0 || self.faults.is_some() {
            let now = self.clock.now();
            let timeout_s = self.opts.request_timeout;
            for q in std::mem::take(&mut batch) {
                if timeout_s > 0.0 && now > q.arrival + (q.tries + 1) as f64 * timeout_s {
                    self.retry_or_fail(q, true);
                } else if self
                    .faults
                    .as_ref()
                    .map_or(false, |f| f.take_fail(q.id, q.steps))
                {
                    self.retry_or_fail(q, false);
                } else {
                    batch.push(q);
                }
            }
            if batch.is_empty() {
                return Ok(());
            }
        }
        // Injected kill, keyed on this server's own dispatch ordinal: the
        // batch never executes; its requests take the retry path, the
        // server goes dark and comes back after the supervisor backoff.
        let my_ord = self.dispatch_ord[s];
        self.dispatch_ord[s] += 1;
        if self.faults.as_ref().map_or(false, |f| f.take_kill(s, my_ord)) {
            if self.budget[s] == 0 {
                bail!("serve worker {s}: panic respawn budget exhausted");
            }
            self.budget[s] -= 1;
            self.respawns += 1;
            for q in batch {
                self.retry_or_fail(q, false);
            }
            self.alive[s] = false;
            let back = self.backoff[s];
            self.backoff[s] = (back * 2.0).min(0.05);
            let t = self.clock.now() + back;
            self.push_ev(t, EvKind::Respawn { server: s });
            return Ok(());
        }
        let take = batch.len();
        let dispatch = if self.controller.is_some()
            && self.units[unit].policy == DispatchPolicy::Auto
        {
            if (take as f64) < self.thresh * self.b_art as f64 {
                take
            } else {
                self.b_art
            }
        } else {
            self.units[unit].policy.dispatch_size(take, self.b_art)
        };
        let variant = self.units[unit].plans.active();
        let now = self.clock.now();
        for q in batch.iter_mut() {
            if q.first_deq.is_none() {
                q.first_deq = Some(now);
            }
        }
        let ids: Vec<usize> = batch.iter().map(|q| q.id).collect();
        let outs = (self.units[unit].step)(&ids, dispatch)?;
        if outs.len() != batch.len() {
            bail!(
                "workload '{}' returned {} outcomes for a batch of {}",
                self.units[unit].label,
                outs.len(),
                batch.len()
            );
        }
        let u = self.jitter_rng.uniform();
        let cost = self.costs[unit.min(self.costs.len() - 1)].cost(variant, dispatch, u);
        let mut service = cost.max(self.opts.exec_floor);
        if let Some(f) = self.faults.as_ref() {
            // Injected service-time stretch: timing only; the engine's
            // measured exec time includes it, so the estimator sees it
            // here too.
            service += batch.iter().filter_map(|q| f.take_delay(q.id)).sum::<f64>();
        }
        self.est.observe(dispatch, service);
        let exec_ms = service * 1e3;
        self.batch_log.push((unit, take, dispatch, exec_ms, variant));
        self.servers[s] = ServerState::Busy { batch, outs, exec_ms, variant };
        self.push_ev(now + service, EvKind::Done { server: s });
        Ok(())
    }

    /// Assign queued work to servers: waiting servers top up (they hold
    /// the oldest heads), idle servers pick up new heads, and anything
    /// full — or anything at all, once the arrival schedule is exhausted —
    /// dispatches. Lowest server index first, for determinism.
    fn schedule_pass(&mut self) -> Result<()> {
        for s in 0..self.servers.len() {
            if matches!(self.servers[s], ServerState::Waiting { .. }) {
                self.top_up(s);
                let full = matches!(
                    &self.servers[s],
                    ServerState::Waiting { batch, .. } if batch.len() >= self.b_art
                );
                if full || self.closed {
                    self.start_exec(s)?;
                }
            }
        }
        for s in 0..self.servers.len() {
            while self.alive[s] && matches!(self.servers[s], ServerState::Idle) {
                // Head = oldest queued request whose retry backoff has
                // expired (the threaded workers scan the same way).
                let now = self.clock.now();
                let Some(at) = self.queue.iter().position(|q| q.not_before <= now) else {
                    break;
                };
                let head = self.queue.remove(at).expect("indexed item");
                let unit = head.unit;
                self.gen += 1;
                let gen = self.gen;
                self.servers[s] = ServerState::Waiting { unit, batch: vec![head], gen };
                self.top_up(s);
                let full = matches!(
                    &self.servers[s],
                    ServerState::Waiting { batch, .. } if batch.len() >= self.b_art
                );
                if full || self.closed || self.wait_s <= 0.0 {
                    self.start_exec(s)?;
                } else {
                    self.push_ev(self.clock.now() + self.wait_s, EvKind::Deadline { server: s, gen });
                    break;
                }
            }
        }
        Ok(())
    }

    fn on_arrival(&mut self, k: usize) {
        let (unit, id) = self.order[k];
        self.fired += 1;
        if self.fired == self.order.len() {
            // Mirror of the threaded generator setting `closed`: waiting
            // servers stop holding batches open once no more arrivals can
            // come.
            self.closed = true;
        }
        if self.queue.len() >= self.opts.queue_cap {
            self.shed[unit] += 1;
        } else {
            self.queue.push_back(Queued {
                unit,
                id,
                arrival: self.arrivals[k],
                steps: 0,
                first_deq: None,
                first_done: None,
                tries: 0,
                not_before: 0.0,
            });
        }
    }

    fn on_done(&mut self, s: usize) {
        let (batch, outs, exec_ms, variant) =
            match std::mem::replace(&mut self.servers[s], ServerState::Idle) {
                ServerState::Busy { batch, outs, exec_ms, variant } => {
                    (batch, outs, exec_ms, variant)
                }
                other => {
                    self.servers[s] = other;
                    return;
                }
            };
        let t_done = self.clock.now();
        for (mut q, out) in batch.into_iter().zip(outs) {
            q.steps += 1;
            if q.first_done.is_none() {
                q.first_done = Some(t_done);
            }
            match out {
                StepOutcome::Done(o) => {
                    let first = q.first_done.expect("set above");
                    let first_ms = (first - q.arrival).max(0.0) * 1e3;
                    let total_ms = (t_done - q.arrival).max(0.0) * 1e3;
                    self.lat[q.unit].push(total_ms);
                    self.records[q.unit].push(RequestRecord {
                        id: q.id,
                        queue_ms: (q.first_deq.expect("set above") - q.arrival).max(0.0) * 1e3,
                        exec_ms,
                        total_ms,
                        steps: q.steps,
                        first_ms,
                        itl_ms: if q.steps > 1 {
                            (total_ms - first_ms) / (q.steps - 1) as f64
                        } else {
                            0.0
                        },
                        pred: o.pred,
                        tokens: o.tokens,
                        variant,
                    });
                }
                // Continuations bypass the queue bound, as in the engine.
                StepOutcome::Continue => self.queue.push_back(q),
            }
        }
    }

    fn on_tick(&mut self) {
        let Some(controller) = self.controller.as_mut() else { return };
        let copts = self.opts.controller.as_ref().expect("controller implies opts");
        let t = self.clock.now();
        let queue_frac = self.queue.len() as f64 / self.opts.queue_cap.max(1) as f64;
        let arrival_rate =
            (self.fired - self.tick_arr_mark) as f64 / copts.tick_s.max(1e-4);
        self.tick_arr_mark = self.fired;
        let fault_rate =
            (self.fault_events - self.tick_fault_mark) as f64 / copts.tick_s.max(1e-4);
        self.tick_fault_mark = self.fault_events;
        let p99: Vec<Option<f64>> = self
            .lat
            .iter_mut()
            .map(|w| {
                if w.is_empty() {
                    None
                } else {
                    w.sort_by(|a, b| a.total_cmp(b));
                    let p = crate::util::bench::percentile(w, 0.99);
                    w.clear();
                    Some(p)
                }
            })
            .collect();
        let actions = controller
            .tick(&Obs { t, queue_frac, arrival_rate, fault_rate, p99_ms: &p99 }, &self.est);
        for a in actions {
            match a {
                Action::MaxWait(w) => self.wait_s = w.max(0.0),
                Action::FillThreshold(th) => self.thresh = th,
                Action::Variant { member, variant } => {
                    self.units[member].plans.set_active(variant)
                }
            }
        }
        self.push_ev(t + copts.tick_s.max(1e-4), EvKind::Tick);
    }

    fn finished(&self) -> bool {
        self.fired == self.order.len()
            && self.queue.is_empty()
            && self.servers.iter().all(|s| matches!(s, ServerState::Idle))
    }

    fn run(mut self) -> Result<Vec<EngineStats>> {
        for (k, &at) in self.arrivals.clone().iter().enumerate() {
            self.push_ev(at, EvKind::Arrival(k));
        }
        if let Some(copts) = self.opts.controller.as_ref() {
            self.push_ev(copts.tick_s.max(1e-4), EvKind::Tick);
        }
        while let Some(ev) = self.heap.pop() {
            self.clock.set(ev.t);
            match ev.kind {
                EvKind::Arrival(k) => self.on_arrival(k),
                EvKind::Deadline { server, gen } => {
                    let live = matches!(
                        &self.servers[server],
                        ServerState::Waiting { gen: g, .. } if *g == gen
                    );
                    if live {
                        self.start_exec(server)?;
                    }
                }
                EvKind::Done { server } => self.on_done(server),
                EvKind::Tick => self.on_tick(),
                EvKind::Respawn { server } => self.alive[server] = true,
                EvKind::Wake => {}
            }
            self.schedule_pass()?;
            if self.finished() {
                break;
            }
        }
        // Anything still queued at teardown (every server dead, or the
        // run poisoned) is a counted failure whose KV state is reclaimed
        // — the engine's teardown drain, so the leak check holds on
        // every exit path.
        for q in std::mem::take(&mut self.queue) {
            self.tally[q.unit].failures += 1;
            self.tally[q.unit].reclaimed_blocks += (self.units[q.unit].reclaim)(&[q.id]);
        }
        let total_s = self.clock.now();
        let transitions: Vec<Transition> = self
            .controller
            .as_ref()
            .map(|c| c.transitions().to_vec())
            .unwrap_or_default();
        let slo_default = self
            .opts
            .controller
            .as_ref()
            .map(|c| c.slo_p99_ms)
            .unwrap_or(self.opts.slo_p99_ms);
        Ok(finalize_stats(
            self.units,
            std::mem::take(&mut self.records),
            std::mem::take(&mut self.shed),
            &self.batch_log,
            &transitions,
            total_s,
            slo_default,
            &self.tally,
            self.respawns,
        ))
    }
}

/// Run a fleet through the discrete-event simulator: same members, same
/// options, same real per-batch model execution as [`super::run_fleet`],
/// but service *times* come from `costs` (one [`SimCost`] per member; the
/// last one covers any excess members) and all time is virtual — the
/// result is bit-reproducible for a given `(members, costs, opts)` at any
/// `opts.workers`. KV telemetry still reflects the real plans' pools.
pub fn run_fleet_sim(
    members: Vec<ErasedMember<'_>>,
    costs: &[SimCost],
    opts: &EngineOpts,
) -> Result<Vec<EngineStats>> {
    if members.is_empty() {
        bail!("run_fleet_sim: the fleet needs at least one member");
    }
    if members.iter().any(|m| m.requests == 0) {
        bail!("run_fleet_sim: every member needs at least one request");
    }
    if costs.is_empty() {
        bail!("run_fleet_sim: needs at least one SimCost model");
    }
    let total: usize = members.iter().map(|m| m.requests).sum();
    EngineOpts { requests: total, ..opts.clone() }.validate()?;
    let mut units = Vec::with_capacity(members.len());
    for m in members {
        units.push((m.mk)(opts)?);
    }

    let order = arrival_order(&units);
    let arrivals = arrival_times(order.len(), opts.rate, opts.spike, opts.seed);
    let n_units = units.len();
    let controller = opts.controller.as_ref().map(|copts| {
        let member_cfgs: Vec<MemberCfg> = units
            .iter()
            .map(|u| MemberCfg {
                slo_p99_ms: if u.slo_p99_ms > 0.0 { u.slo_p99_ms } else { copts.slo_p99_ms },
                variants: u.plans.variants(),
            })
            .collect();
        Controller::new(copts.clone(), opts.max_wait.max(0.0), opts.max_batch, &member_cfgs)
    });
    let sim = Sim {
        units: &units,
        costs,
        opts,
        clock: VirtualClock::new(),
        b_art: opts.max_batch,
        seq: 0,
        gen: 0,
        heap: BinaryHeap::new(),
        queue: VecDeque::new(),
        servers: (0..opts.workers).map(|_| ServerState::Idle).collect(),
        shed: vec![0; n_units],
        records: vec![Vec::new(); n_units],
        batch_log: Vec::new(),
        lat: vec![Vec::new(); n_units],
        est: CostEstimator::new(opts.max_batch),
        controller,
        wait_s: opts.max_wait.max(0.0),
        thresh: DispatchPolicy::AUTO_FILL_THRESHOLD,
        jitter_rng: Pcg64::new(opts.seed ^ 0x6a69_7474_6572), // "jitter"
        order,
        arrivals,
        fired: 0,
        tick_arr_mark: 0,
        closed: false,
        faults: opts.chaos.clone().filter(|p| !p.is_empty()).map(FaultState::new),
        tally: vec![FaultTally::default(); n_units],
        alive: vec![true; opts.workers],
        budget: vec![RESPAWN_BUDGET; opts.workers],
        backoff: vec![RESPAWN_BACKOFF_S; opts.workers],
        dispatch_ord: vec![0; opts.workers],
        respawns: 0,
        fault_events: 0,
        tick_fault_mark: 0,
    };
    sim.run()
}
