//! Serving layer (L3): closed-loop measurement + the concurrent engine.
//!
//! Two entry points back the paper's efficiency claims (Tables 5/10):
//!
//! * [`measure`] — closed-loop micro-measurement: batch-1 requests issued
//!   back-to-back for p50/p95 latency, then saturated batches for
//!   images/sec. Both run through one batch-polymorphic
//!   [`crate::exec::ForwardPlan`] (parameters resolved once per variant),
//!   so dense, pruned, and compensated variants are timed on the GEMM
//!   shapes they actually keep.
//! * [`engine`] — the concurrent batched serving engine, generic over a
//!   [`Workload`]: an open-loop Poisson arrival process feeds a bounded
//!   queue drained by a pool of worker threads, each forming batches up to
//!   `max_batch` under a batching deadline and dispatching them padded or
//!   at their exact size per the [`DispatchPolicy`], with per-request
//!   queueing/execution/token accounting and load shedding when the queue
//!   is full. Multi-step requests (autoregressive generation via
//!   [`GenWorkload`] + the KV-cached [`crate::exec::DecodePlan`]) are
//!   re-enqueued between steps so decode steps from different sequences
//!   batch together, and [`engine::run_fleet`] serves N workloads —
//!   possibly over different models — through one queue. See
//!   [`engine::run_engine`].
//!
//! Riding on the engine:
//!
//! * [`controller`] — the SLO-aware feedback controller: an online
//!   per-batch-size cost-curve estimator (replacing the static auto-fill
//!   threshold), adaptive batch-formation deadlines, and — CORP's knob —
//!   hysteretic dense → pruned+compensated variant degradation under
//!   sustained queue pressure, with recovery when load clears.
//! * [`clock`] — the [`Clock`](clock::Clock) abstraction all engine time
//!   flows through: wall clock in production, virtual clock in tests.
//! * [`sim`] — a single-thread discrete-event replay of the engine's
//!   queueing semantics on the virtual clock, for bit-reproducible
//!   controller trajectories (`run_fleet_sim`).
//!
//! The engine is fault-tolerant: worker panics are caught and supervised
//! (bounded respawns, exponential backoff), requests carry deadlines and
//! a retry budget ([`EngineOpts`]), aborted generations return their
//! paged KV blocks, and a deterministic chaos layer ([`FaultPlan`],
//! `corp serve --chaos`) injects kills/faults/delays identically into the
//! live engine and the simulator. See `engine`'s module docs for the
//! failure model.
//!
//! The engine shares one `Runtime` across workers — the native backend is
//! pure Rust and thread-safe. The gated PJRT path stays on the closed-loop
//! `measure` (its executables are not shared across threads), on padded
//! fixed-shape dispatch (its artifacts are lowered at one batch size), and
//! on prefill-per-step decode (no `dec_*` AOT lowering).

pub mod clock;
pub mod controller;
pub mod engine;
pub mod sim;
pub mod workload;

pub use controller::{Action, Controller, ControllerOpts, CostEstimator, MemberCfg, Obs, Transition};
pub use engine::{
    run_engine, run_engine_q8, run_fleet, EngineOpts, EngineStats, ErasedMember, FaultPlan,
    FleetMember, RequestRecord, StoreRef,
};
#[cfg(not(pjrt_backend))]
pub use sim::{run_fleet_sim, SimCost};
pub use workload::{
    default_min_prompt, DispatchPolicy, GenRequest, GenWorkload, GptWorkload, PlanPair, Plans,
    RequestOutput, StepOutcome, TextRequest, VisionWorkload, Workload,
};

use anyhow::Result;

use crate::data::{Split, VisionGen};
use crate::exec::Executor;
use crate::model::WeightStore;
use crate::tensor::Tensor;
use crate::util::bench::stats_from;
use std::time::Instant;

/// Latency / throughput measurement for one model variant.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// p50 single-request latency, ms (batch 1).
    pub p50_ms: f64,
    /// p95 single-request latency, ms.
    pub p95_ms: f64,
    /// Saturated throughput, images/sec (batch = eval batch).
    pub throughput_fps: f64,
}

/// Closed-loop latency at batch 1 + saturated throughput at the eval batch.
///
/// Uses one fused [`crate::exec::ForwardPlan`] for both sections — except
/// on a runtime that prefers fixed shapes (a `--cfg pjrt_backend` build
/// with a loaded manifest), where the layered `embed_*/block_*/head_*`
/// artifacts are kept so the reported numbers measure the PJRT executables
/// (the fused family has no AOT lowering and would silently fall back to
/// the native interpreter).
pub fn measure(
    exec: &Executor<'_>,
    w: &WeightStore,
    gen: &VisionGen,
    lat_iters: usize,
    tp_iters: usize,
) -> Result<ServeStats> {
    let plan = if exec.rt.prefers_fixed_shapes() { None } else { Some(exec.forward_plan(w)?) };
    let step = |t: &Tensor, b: usize| -> Result<Tensor> {
        match &plan {
            Some(p) => p.run_vit(t),
            None => exec.forward_vit(w, t, b),
        }
    };

    // ---- batch-1 latency ----
    let (tokens1, _) = gen.batch(Split::Eval, 0, 1);
    step(&tokens1, 1)?; // warmup (compiles executables on the PJRT path)
    let mut lat = Vec::with_capacity(lat_iters);
    for i in 0..lat_iters {
        let (t, _) = gen.batch(Split::Eval, i as u64, 1);
        let t0 = Instant::now();
        step(&t, 1)?;
        lat.push(t0.elapsed().as_secs_f64());
    }
    let s = stats_from("latency", &lat);

    // ---- saturated throughput ----
    let b = exec.cfg.eval_batch();
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    step(&tokens, b)?; // warmup
    let t0 = Instant::now();
    for i in 0..tp_iters {
        let (t, _) = gen.batch(Split::Eval, i as u64, b);
        step(&t, b)?;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    Ok(ServeStats {
        p50_ms: s.p50_s * 1e3,
        p95_ms: s.p95_s * 1e3,
        throughput_fps: (tp_iters * b) as f64 / elapsed,
    })
}

#[cfg(test)]
mod tests {
    // Engine behaviour is covered by `tests/serve_engine.rs` (determinism
    // across worker counts and dispatch policies, bounded-queue shedding,
    // padding vs exact-size correctness, GptWorkload determinism);
    // `measure` by `tests/pipeline_e2e.rs`; the dispatch policy and
    // workload units by `serve::workload::tests`.
}
