//! Batched inference engine (the L3 serving coordinator).
//!
//! Two measurement modes back Tables 5/10:
//! * closed-loop latency: batch-1 requests issued back-to-back, p50/p95;
//! * saturated throughput: batch-16 back-to-back, images/sec.
//!
//! Plus a dynamic batcher for the `serve_pruned` example: an open-loop
//! arrival process feeds a queue; the engine drains up to `max_batch`
//! requests per step (padding the final partial batch), recording
//! per-request queueing + execution latency. PJRT executables are not
//! thread-safe to share here (the client is single-process CPU), so the
//! engine is an event loop rather than a worker pool — the batching policy
//! is the part the paper's efficiency tables exercise.

use anyhow::Result;

use crate::data::{Split, VisionGen};
use crate::exec::Executor;
use crate::model::WeightStore;
use crate::util::bench::{percentile, stats_from};
use crate::util::Pcg64;
use std::time::Instant;

/// Latency / throughput measurement for one model variant.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// p50 single-request latency, ms (batch 1).
    pub p50_ms: f64,
    /// p95 single-request latency, ms.
    pub p95_ms: f64,
    /// Saturated throughput, images/sec (batch = eval batch).
    pub throughput_fps: f64,
}

/// Closed-loop latency at batch 1 + saturated throughput at `tp_batch`.
pub fn measure(
    exec: &Executor<'_>,
    w: &WeightStore,
    gen: &VisionGen,
    lat_iters: usize,
    tp_iters: usize,
) -> Result<ServeStats> {
    // ---- batch-1 latency ----
    let (tokens1, _) = gen.batch(Split::Eval, 0, 1);
    // Warmup (compiles executables).
    exec.forward_vit(w, &tokens1, 1)?;
    let mut lat = Vec::with_capacity(lat_iters);
    for i in 0..lat_iters {
        let (t, _) = gen.batch(Split::Eval, i as u64, 1);
        let t0 = Instant::now();
        exec.forward_vit(w, &t, 1)?;
        lat.push(t0.elapsed().as_secs_f64());
    }
    let s = stats_from("latency", &lat);

    // ---- saturated throughput ----
    let b = exec.cfg.eval_batch();
    let (tokens, _) = gen.batch(Split::Eval, 0, b);
    exec.forward_vit(w, &tokens, b)?; // warmup
    let t0 = Instant::now();
    for i in 0..tp_iters {
        let (t, _) = gen.batch(Split::Eval, i as u64, b);
        exec.forward_vit(w, &t, b)?;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    Ok(ServeStats {
        p50_ms: s.p50_s * 1e3,
        p95_ms: s.p95_s * 1e3,
        throughput_fps: (tp_iters * b) as f64 / elapsed,
    })
}

/// A request in the dynamic batcher.
struct Request {
    arrival: f64,
    image_index: u64,
}

/// Result of a dynamic-batching run.
#[derive(Debug, Clone)]
pub struct BatcherStats {
    pub served: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub mean_batch: f64,
    pub throughput_fps: f64,
}

/// Dynamic batcher options.
#[derive(Clone, Debug)]
pub struct BatcherOpts {
    /// Open-loop arrival rate, requests/sec.
    pub rate: f64,
    /// Total requests to serve.
    pub requests: usize,
    /// Maximum batch (bounded by the artifact batch size).
    pub max_batch: usize,
    /// Max time to wait for a fuller batch, seconds.
    pub max_wait: f64,
    pub seed: u64,
}

impl Default for BatcherOpts {
    fn default() -> Self {
        Self { rate: 200.0, requests: 256, max_batch: 16, max_wait: 0.02, seed: 7 }
    }
}

/// Run the dynamic batcher: Poisson arrivals, greedy batch assembly with a
/// wait bound, per-request latency measured arrival → completion.
pub fn run_batcher(
    exec: &Executor<'_>,
    w: &WeightStore,
    gen: &VisionGen,
    opts: &BatcherOpts,
) -> Result<BatcherStats> {
    let b_art = exec.cfg.eval_batch();
    let max_batch = opts.max_batch.min(b_art);
    // Pre-generate Poisson arrival times.
    let mut rng = Pcg64::new(opts.seed);
    let mut arrivals = Vec::with_capacity(opts.requests);
    let mut t = 0.0f64;
    for i in 0..opts.requests {
        t += -rng.uniform().max(1e-12).ln() / opts.rate;
        arrivals.push(Request { arrival: t, image_index: i as u64 });
    }
    // Warmup.
    let (warm, _) = gen.batch(Split::Eval, 0, b_art);
    exec.forward_vit(w, &warm, b_art)?;

    let wall0 = Instant::now();
    let mut latencies = Vec::with_capacity(opts.requests);
    let mut batch_sizes = Vec::new();
    let mut next = 0usize;
    while next < arrivals.len() {
        let now = wall0.elapsed().as_secs_f64();
        // Wait for the first request if the queue is empty.
        if arrivals[next].arrival > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                (arrivals[next].arrival - now).min(0.01),
            ));
            continue;
        }
        // Assemble a batch: everything that has arrived, up to max_batch;
        // if below max_batch, wait up to max_wait for more.
        let deadline = arrivals[next].arrival + opts.max_wait;
        loop {
            let now = wall0.elapsed().as_secs_f64();
            let ready = arrivals[next..]
                .iter()
                .take_while(|r| r.arrival <= now)
                .count();
            if ready >= max_batch || now >= deadline || next + ready >= arrivals.len() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        let now = wall0.elapsed().as_secs_f64();
        let ready = arrivals[next..].iter().take_while(|r| r.arrival <= now).count();
        let take = ready.min(max_batch).max(1);
        let batch = &arrivals[next..next + take];
        // Build the input batch (pad to the artifact batch size).
        let (mut tokens, _) = gen.batch(Split::Eval, batch[0].image_index, b_art);
        if take < b_art {
            // Padding: reuse the generated batch as-is; only `take` results
            // are returned to callers.
            let _ = &mut tokens;
        }
        exec.forward_vit(w, &tokens, b_art)?;
        let done = wall0.elapsed().as_secs_f64();
        for r in batch {
            latencies.push(done - r.arrival);
        }
        batch_sizes.push(take);
        next += take;
    }
    let total = wall0.elapsed().as_secs_f64();
    let mut sorted = latencies.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(BatcherStats {
        served: latencies.len(),
        p50_ms: percentile(&sorted, 0.5) * 1e3,
        p95_ms: percentile(&sorted, 0.95) * 1e3,
        mean_batch: batch_sizes.iter().sum::<usize>() as f64 / batch_sizes.len().max(1) as f64,
        throughput_fps: latencies.len() as f64 / total,
    })
}

#[cfg(test)]
mod tests {
    // Engine behaviour is covered by integration tests (needs artifacts);
    // the arrival process is deterministic via the seeded RNG.
}
