//! SLO-aware feedback controller for the serving engine (ROADMAP item 1).
//!
//! Three cooperating pieces, all pure state machines so they are trivially
//! testable off the wall clock:
//!
//! * [`CostEstimator`] — an online per-dispatch-size exec-cost curve
//!   (EWMA per size, read through a running-max so the learned curve is
//!   monotone in batch size by construction). It replaces the fixed
//!   `DispatchPolicy::AUTO_FILL_THRESHOLD` once enough samples exist: the
//!   exact-vs-padded choice compares the *learned* cost of dispatching at
//!   the formed size against dispatching at the padded artifact size.
//! * [`Controller`] — per control tick, observes queue depth, arrival
//!   rate, per-member p99 latency, and the request fault rate
//!   (timeouts + retries + failures per second, see [`Obs`]) and emits
//!   [`Action`]s:
//!   a new batch-formation `max_wait`, a new auto-dispatch fill
//!   threshold, and — the CORP-specific knob — *variant switches*. Under
//!   sustained pressure a member degrades from the dense plan rung to the
//!   pruned+compensated rung (same `Executor`, same weights family,
//!   different prepared plan); when load clears it recovers. Hysteresis
//!   (consecutive-tick counters plus a minimum dwell time) keeps it from
//!   flapping.
//! * [`Transition`] — the audit trail of variant switches, surfaced in
//!   `EngineStats` so tests can assert the degrade→recover sequence
//!   exactly.

/// Online per-dispatch-size execution-cost estimator.
///
/// `observe(dispatch, secs)` folds a measured batch execution time into an
/// EWMA bucket for that dispatch size. `cost(b)` reads the curve through a
/// running max over all observed sizes `<= b`, which (a) makes the
/// returned curve monotone non-decreasing in batch size regardless of
/// sample noise, and (b) lets unobserved sizes borrow the nearest smaller
/// observation as a lower bound.
#[derive(Debug, Clone)]
pub struct CostEstimator {
    ewma: Vec<f64>,
    seen: Vec<u64>,
    alpha: f64,
}

impl CostEstimator {
    /// Estimator for dispatch sizes `1..=max_batch`.
    pub fn new(max_batch: usize) -> Self {
        CostEstimator {
            ewma: vec![0.0; max_batch + 1],
            seen: vec![0; max_batch + 1],
            alpha: 0.2,
        }
    }

    /// Fold one measured execution (`secs` for a batch dispatched at
    /// `dispatch` rows) into the curve.
    pub fn observe(&mut self, dispatch: usize, secs: f64) {
        if dispatch == 0 || !secs.is_finite() || secs < 0.0 || self.ewma.len() < 2 {
            return;
        }
        let d = dispatch.min(self.ewma.len() - 1);
        if self.seen[d] == 0 {
            self.ewma[d] = secs;
        } else {
            self.ewma[d] += self.alpha * (secs - self.ewma[d]);
        }
        self.seen[d] += 1;
    }

    /// Number of samples folded in for dispatch size `b`.
    pub fn samples(&self, b: usize) -> u64 {
        if b < self.seen.len() { self.seen[b] } else { 0 }
    }

    /// Learned cost of dispatching `b` rows: running max of the EWMA over
    /// observed sizes `<= b` (monotone by construction). `None` until at
    /// least one size `<= b` has been observed.
    pub fn cost(&self, b: usize) -> Option<f64> {
        let hi = b.min(self.ewma.len() - 1);
        let mut best: Option<f64> = None;
        for d in 1..=hi {
            if self.seen[d] > 0 {
                best = Some(match best {
                    Some(c) => c.max(self.ewma[d]),
                    None => self.ewma[d],
                });
            }
        }
        best
    }

    /// Learned exact-vs-padded decision for a formed batch of `take` rows
    /// against a padded artifact of `max_batch` rows: dispatch exact when
    /// the learned cost at `take` undercuts the learned cost at
    /// `max_batch`. Falls back to the static
    /// [`crate::serve::DispatchPolicy::AUTO_FILL_THRESHOLD`] rule until
    /// both sizes have data.
    pub fn dispatch_size(&self, take: usize, max_batch: usize) -> usize {
        if take >= max_batch {
            return max_batch;
        }
        match (self.cost_at(take), self.cost_at(max_batch)) {
            (Some(ct), Some(cm)) => {
                if ct < cm {
                    take
                } else {
                    max_batch
                }
            }
            _ => {
                let fill = take as f64 / max_batch as f64;
                if fill >= crate::serve::DispatchPolicy::AUTO_FILL_THRESHOLD {
                    max_batch
                } else {
                    take
                }
            }
        }
    }

    /// Smallest fill fraction `take / max_batch` at which the learned
    /// decision pads up to the full artifact (i.e. the data-driven
    /// replacement for `AUTO_FILL_THRESHOLD`). Falls back to the static
    /// 0.5 until the padded size itself has samples.
    pub fn fill_threshold(&self, max_batch: usize) -> f64 {
        if max_batch == 0 || self.cost_at(max_batch).is_none() {
            return crate::serve::DispatchPolicy::AUTO_FILL_THRESHOLD;
        }
        for take in 1..=max_batch {
            if self.dispatch_size(take, max_batch) == max_batch {
                return take as f64 / max_batch as f64;
            }
        }
        1.0
    }

    /// Cost at exactly-observed prefix <= b, but requiring size `b`'s own
    /// bucket to have data so the decision reflects a measured point, not
    /// only a lower bound borrowed from smaller sizes.
    fn cost_at(&self, b: usize) -> Option<f64> {
        let d = b.min(self.seen.len().saturating_sub(1));
        if d == 0 || self.seen[d] == 0 {
            None
        } else {
            self.cost(d)
        }
    }
}

/// Controller tuning knobs. Defaults are production-ish; tests tighten
/// the tick and hysteresis windows.
#[derive(Debug, Clone)]
pub struct ControllerOpts {
    /// Control-tick period in seconds.
    pub tick_s: f64,
    /// Fleet-default p99 latency budget in milliseconds (0 disables the
    /// latency breach signal; queue pressure still drives degradation).
    /// A member's own `slo_p99_ms` overrides this.
    pub slo_p99_ms: f64,
    /// Enable variant degradation (the dense→pruned+compensated switch).
    pub degrade: bool,
    /// Consecutive breached ticks before degrading one rung.
    pub degrade_after: u32,
    /// Consecutive clear ticks before recovering one rung.
    pub recover_after: u32,
    /// Minimum ticks between any two variant switches of one member.
    pub min_dwell_ticks: u32,
    /// Queue fill fraction at or above which the tick counts as breached.
    pub queue_hi: f64,
    /// Queue fill fraction at or below which the tick may count as clear.
    pub queue_lo: f64,
    /// Floor for the adapted batch-formation `max_wait` (seconds).
    pub wait_lo: f64,
    /// Request faults per second (timeouts + retries + failures) at or
    /// above which a tick counts as breached, alongside queue and latency
    /// pressure. 0 disables the fault signal.
    pub fault_hi: f64,
}

impl Default for ControllerOpts {
    fn default() -> Self {
        ControllerOpts {
            tick_s: 0.02,
            slo_p99_ms: 0.0,
            degrade: false,
            degrade_after: 2,
            recover_after: 4,
            min_dwell_ticks: 4,
            queue_hi: 0.5,
            queue_lo: 0.125,
            wait_lo: 0.0005,
            fault_hi: 0.0,
        }
    }
}

/// One recorded variant switch: member `member` moved `from -> to` at
/// controller time `t` (seconds on the engine clock).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transition {
    pub t: f64,
    pub member: usize,
    pub from: usize,
    pub to: usize,
}

/// Per-member static configuration handed to [`Controller::new`].
#[derive(Debug, Clone, Copy)]
pub struct MemberCfg {
    /// p99 budget in ms; 0 defers to `ControllerOpts::slo_p99_ms`.
    pub slo_p99_ms: f64,
    /// Number of plan rungs available (1 = no degradation possible).
    pub variants: usize,
}

/// One control tick's inputs.
#[derive(Debug, Clone, Copy)]
pub struct Obs<'a> {
    /// Engine-clock time of the tick (seconds).
    pub t: f64,
    /// Queue depth as a fraction of `queue_cap` at tick time.
    pub queue_frac: f64,
    /// Arrivals per second observed over the last tick window.
    pub arrival_rate: f64,
    /// Request faults (timeouts + retries + terminal failures) per second
    /// over the last tick window.
    pub fault_rate: f64,
    /// Windowed p99 latency per member (ms); `None` when the member
    /// completed nothing in the window.
    pub p99_ms: &'a [Option<f64>],
}

/// Control outputs, applied by the engine after each tick.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// New batch-formation deadline (seconds).
    MaxWait(f64),
    /// New auto-dispatch fill threshold in `[0, 1]`.
    FillThreshold(f64),
    /// Switch `member` to plan rung `variant`.
    Variant { member: usize, variant: usize },
}

struct MemberState {
    cfg: MemberCfg,
    variant: usize,
    breach_ticks: u32,
    clear_ticks: u32,
    last_switch: Option<u64>,
}

/// The feedback controller: holds per-member hysteresis state and the
/// transition log. Pure — call [`Controller::tick`] with an [`Obs`] and a
/// [`CostEstimator`], apply the returned [`Action`]s.
pub struct Controller {
    opts: ControllerOpts,
    base_wait: f64,
    max_batch: usize,
    members: Vec<MemberState>,
    ticks: u64,
    transitions: Vec<Transition>,
}

impl Controller {
    pub fn new(opts: ControllerOpts, base_wait: f64, max_batch: usize, members: &[MemberCfg]) -> Self {
        Controller {
            opts,
            base_wait: base_wait.max(0.0),
            max_batch: max_batch.max(1),
            members: members
                .iter()
                .map(|&cfg| MemberState {
                    cfg,
                    variant: 0,
                    breach_ticks: 0,
                    clear_ticks: 0,
                    last_switch: None,
                })
                .collect(),
            ticks: 0,
            transitions: Vec::new(),
        }
    }

    /// Current plan rung for `member` (0 = dense).
    pub fn variant(&self, member: usize) -> usize {
        self.members.get(member).map_or(0, |m| m.variant)
    }

    /// All variant switches so far, in order.
    pub fn transitions(&self) -> &[Transition] {
        &self.transitions
    }

    /// Run one control tick.
    pub fn tick(&mut self, obs: &Obs, est: &CostEstimator) -> Vec<Action> {
        self.ticks += 1;
        let tick = self.ticks;
        let mut out = Vec::new();

        // Dispatch threshold: hand the engine the learned fill threshold
        // (falls back to the static 0.5 until the curve has data).
        out.push(Action::FillThreshold(est.fill_threshold(self.max_batch)));

        // Batch-formation deadline: under queue pressure, stop holding
        // batches open (the queue itself guarantees full batches); under
        // light load, wait roughly long enough for max_batch arrivals but
        // never beyond the configured base.
        let wait = if obs.queue_frac >= self.opts.queue_hi {
            self.opts.wait_lo
        } else if obs.arrival_rate > 0.0 {
            (self.max_batch as f64 / obs.arrival_rate).clamp(self.opts.wait_lo, self.base_wait)
        } else {
            self.base_wait
        };
        out.push(Action::MaxWait(wait));

        if !self.opts.degrade {
            return out;
        }
        for (i, m) in self.members.iter_mut().enumerate() {
            if m.cfg.variants < 2 {
                continue;
            }
            let slo = if m.cfg.slo_p99_ms > 0.0 { m.cfg.slo_p99_ms } else { self.opts.slo_p99_ms };
            let p99 = obs.p99_ms.get(i).copied().flatten();
            let lat_breach = slo > 0.0 && p99.map_or(false, |p| p > slo);
            let fault_breach =
                self.opts.fault_hi > 0.0 && obs.fault_rate >= self.opts.fault_hi;
            let breach = obs.queue_frac >= self.opts.queue_hi || lat_breach || fault_breach;
            let clear = obs.queue_frac <= self.opts.queue_lo
                && (slo <= 0.0 || p99.map_or(true, |p| p < 0.5 * slo))
                && (self.opts.fault_hi <= 0.0 || obs.fault_rate < 0.5 * self.opts.fault_hi);

            if breach {
                m.breach_ticks += 1;
                m.clear_ticks = 0;
            } else if clear {
                m.clear_ticks += 1;
                m.breach_ticks = 0;
            } else {
                m.breach_ticks = 0;
                m.clear_ticks = 0;
            }

            let dwell_ok = m
                .last_switch
                .map_or(true, |s| tick - s >= self.opts.min_dwell_ticks as u64);
            if breach
                && m.breach_ticks >= self.opts.degrade_after
                && m.variant + 1 < m.cfg.variants
                && dwell_ok
            {
                let from = m.variant;
                m.variant += 1;
                m.breach_ticks = 0;
                m.last_switch = Some(tick);
                self.transitions.push(Transition { t: obs.t, member: i, from, to: m.variant });
                out.push(Action::Variant { member: i, variant: m.variant });
            } else if clear && m.clear_ticks >= self.opts.recover_after && m.variant > 0 && dwell_ok {
                let from = m.variant;
                m.variant -= 1;
                m.clear_ticks = 0;
                m.last_switch = Some(tick);
                self.transitions.push(Transition { t: obs.t, member: i, from, to: m.variant });
                out.push(Action::Variant { member: i, variant: m.variant });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(t: f64, qf: f64, p99: Option<f64>) -> (f64, f64, Vec<Option<f64>>) {
        (t, qf, vec![p99])
    }

    #[test]
    fn estimator_monotone_and_converges() {
        let mut est = CostEstimator::new(8);
        // Noisy samples of a true increasing curve cost(b) = 1 + b.
        let mut rng = crate::util::rng::Pcg64::new(11);
        for _ in 0..200 {
            for b in 1..=8usize {
                let noise = 0.1 * (rng.uniform() - 0.5);
                est.observe(b, (1.0 + b as f64) * (1.0 + noise));
            }
        }
        let mut prev = 0.0;
        for b in 1..=8 {
            let c = est.cost(b).expect("observed");
            assert!(c >= prev, "cost curve not monotone at b={b}: {c} < {prev}");
            prev = c;
        }
        // True curve: cost(4) < cost(8) => exact wins at take=4.
        assert_eq!(est.dispatch_size(4, 8), 4);
        assert_eq!(est.dispatch_size(8, 8), 8);
    }

    #[test]
    fn estimator_falls_back_to_static_threshold() {
        let est = CostEstimator::new(16);
        // No data: static 0.5 rule (mirrors DispatchPolicy::Auto).
        assert_eq!(est.dispatch_size(7, 16), 7);
        assert_eq!(est.dispatch_size(8, 16), 16);
        assert_eq!(est.fill_threshold(16), crate::serve::DispatchPolicy::AUTO_FILL_THRESHOLD);
    }

    #[test]
    fn flat_cost_curve_pads_up() {
        // A flat curve (padding is free) should drive the threshold to
        // pad from the smallest sizes.
        let mut est = CostEstimator::new(8);
        for _ in 0..50 {
            for b in 1..=8usize {
                est.observe(b, 0.005);
            }
        }
        assert_eq!(est.dispatch_size(2, 8), 8);
        assert!(est.fill_threshold(8) <= 1.0 / 8.0 + 1e-9);
    }

    #[test]
    fn controller_degrades_and_recovers_with_dwell() {
        let opts = ControllerOpts {
            degrade: true,
            degrade_after: 2,
            recover_after: 2,
            min_dwell_ticks: 3,
            ..Default::default()
        };
        let mut c = Controller::new(
            opts,
            0.01,
            8,
            &[MemberCfg { slo_p99_ms: 100.0, variants: 2 }],
        );
        let est = CostEstimator::new(8);
        let mut t = 0.0;
        // Sustained pressure: degrade after 2 breached ticks.
        for _ in 0..2 {
            t += 0.02;
            let (tt, qf, p99) = obs(t, 0.9, Some(250.0));
            c.tick(
                &Obs { t: tt, queue_frac: qf, arrival_rate: 500.0, fault_rate: 0.0, p99_ms: &p99 },
                &est,
            );
        }
        assert_eq!(c.variant(0), 1);
        // Clear ticks: recovery blocked by dwell until 3 ticks passed.
        for _ in 0..4 {
            t += 0.02;
            let (tt, qf, p99) = obs(t, 0.0, Some(5.0));
            c.tick(
                &Obs { t: tt, queue_frac: qf, arrival_rate: 10.0, fault_rate: 0.0, p99_ms: &p99 },
                &est,
            );
        }
        assert_eq!(c.variant(0), 0);
        let seq: Vec<(usize, usize)> = c.transitions().iter().map(|tr| (tr.from, tr.to)).collect();
        assert_eq!(seq, vec![(0, 1), (1, 0)]);
    }

    #[test]
    fn controller_never_flaps_within_dwell_window() {
        let opts = ControllerOpts {
            degrade: true,
            degrade_after: 1,
            recover_after: 1,
            min_dwell_ticks: 4,
            ..Default::default()
        };
        let mut c = Controller::new(
            opts,
            0.01,
            8,
            &[MemberCfg { slo_p99_ms: 50.0, variants: 3 }],
        );
        let est = CostEstimator::new(8);
        // Adversarial alternating observations for many ticks.
        let mut switch_ticks: Vec<u64> = Vec::new();
        for k in 0..64u64 {
            let hot = k % 2 == 0;
            let p99 = vec![Some(if hot { 500.0 } else { 1.0 })];
            let before = c.transitions().len();
            c.tick(
                &Obs {
                    t: k as f64 * 0.02,
                    queue_frac: if hot { 1.0 } else { 0.0 },
                    arrival_rate: 100.0,
                    fault_rate: 0.0,
                    p99_ms: &p99,
                },
                &est,
            );
            if c.transitions().len() > before {
                switch_ticks.push(k);
            }
        }
        for w in switch_ticks.windows(2) {
            assert!(
                w[1] - w[0] >= 4,
                "variant flapped within the dwell window: switches at ticks {:?}",
                switch_ticks
            );
        }
    }

    #[test]
    fn max_wait_adapts_to_pressure() {
        let opts = ControllerOpts::default();
        let wait_lo = opts.wait_lo;
        let mut c = Controller::new(opts, 0.01, 8, &[]);
        let est = CostEstimator::new(8);
        let acts = c.tick(
            &Obs { t: 0.0, queue_frac: 0.9, arrival_rate: 1000.0, fault_rate: 0.0, p99_ms: &[] },
            &est,
        );
        assert!(acts.contains(&Action::MaxWait(wait_lo)), "pressure should floor max_wait");
        let acts = c.tick(
            &Obs { t: 0.1, queue_frac: 0.0, arrival_rate: 0.0, fault_rate: 0.0, p99_ms: &[] },
            &est,
        );
        assert!(acts.contains(&Action::MaxWait(0.01)), "idle should restore base wait");
    }

    #[test]
    fn sustained_faults_degrade_even_with_empty_queue() {
        let opts = ControllerOpts {
            degrade: true,
            degrade_after: 2,
            recover_after: 2,
            min_dwell_ticks: 1,
            fault_hi: 5.0,
            ..Default::default()
        };
        let mut c = Controller::new(
            opts,
            0.01,
            8,
            &[MemberCfg { slo_p99_ms: 0.0, variants: 2 }],
        );
        let est = CostEstimator::new(8);
        // Queue and latency are healthy, but requests keep faulting.
        for k in 0..2 {
            c.tick(
                &Obs {
                    t: k as f64 * 0.02,
                    queue_frac: 0.0,
                    arrival_rate: 100.0,
                    fault_rate: 20.0,
                    p99_ms: &[Some(1.0)],
                },
                &est,
            );
        }
        assert_eq!(c.variant(0), 1, "fault pressure alone should degrade");
        // A fault rate below half the threshold counts as clear again.
        for k in 2..4 {
            c.tick(
                &Obs {
                    t: k as f64 * 0.02,
                    queue_frac: 0.0,
                    arrival_rate: 100.0,
                    fault_rate: 1.0,
                    p99_ms: &[Some(1.0)],
                },
                &est,
            );
        }
        assert_eq!(c.variant(0), 0, "calm faults should recover");
    }
}
