//! Serving workloads and the batch dispatch policy.
//!
//! The engine (`serve::engine`) is generic over a [`Workload`]: the
//! workload owns request synthesis (what arrives), batch input assembly
//! (how queued requests become one fused dispatch), and per-request output
//! accounting (what each request is charged and what it predicted). The
//! queueing/batching core is written once; [`VisionWorkload`] (one image
//! per request, Table-5-style classification serving) and [`GptWorkload`]
//! (prompt-length request model with per-token accounting, the paper's OPT
//! deployment analogue) are the two scenarios.
//!
//! [`DispatchPolicy`] decides the *shape* each formed batch dispatches at:
//! padded to the fixed artifact batch (shape reuse — what a compiled
//! fixed-shape backend wants), exact at the true batch size (the native
//! backend does proportionally less arithmetic), or `auto`, which picks
//! exact-size dispatch below a fill-ratio threshold and padded shape reuse
//! above it.

use anyhow::{bail, Result};

use crate::data::{Split, TextGen, VisionGen};
use crate::exec::ForwardPlan;
use crate::model::{ModelConfig, ModelKind};
use crate::tensor::Tensor;

/// First-max argmax over a logits row.
pub(crate) fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best as i32
}

/// How a formed batch of `take ≤ max_batch` requests is dispatched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Always pad to the fixed artifact batch (`max_batch`). One shape for
    /// the whole run — what an AOT fixed-shape backend reuses — at the cost
    /// of full-batch arithmetic on partial batches.
    Padded,
    /// Always dispatch at the true batch size. Partial batches do
    /// proportionally less work (the native backend interprets any size),
    /// at the cost of one artifact shape per distinct size.
    Exact,
    /// Exact below [`DispatchPolicy::AUTO_FILL_THRESHOLD`] fill ratio,
    /// padded at or above it: nearly-full batches keep the reusable fixed
    /// shape (padding waste is small), sparse batches skip the padding
    /// arithmetic (where the waste dominates).
    Auto,
}

impl DispatchPolicy {
    /// Fill ratio (`take / max_batch`) at which `auto` switches from
    /// exact-size dispatch to padded shape reuse.
    pub const AUTO_FILL_THRESHOLD: f64 = 0.5;

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "padded" => DispatchPolicy::Padded,
            "exact" => DispatchPolicy::Exact,
            "auto" => DispatchPolicy::Auto,
            _ => bail!("dispatch must be padded|exact|auto, got '{s}'"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            DispatchPolicy::Padded => "padded",
            DispatchPolicy::Exact => "exact",
            DispatchPolicy::Auto => "auto",
        }
    }

    /// Collapse to the policy actually usable on a backend: a runtime that
    /// prefers fixed shapes (gated PJRT with a manifest) keeps the padded
    /// path — exact-size artifacts have no AOT lowering there and would
    /// silently fall back to the interpreter.
    pub fn resolve(self, fixed_shapes: bool) -> Self {
        if fixed_shapes {
            DispatchPolicy::Padded
        } else {
            self
        }
    }

    /// The batch size a formed batch of `take` requests dispatches at.
    pub fn dispatch_size(&self, take: usize, max_batch: usize) -> usize {
        debug_assert!(take >= 1 && take <= max_batch);
        match self {
            DispatchPolicy::Padded => max_batch,
            DispatchPolicy::Exact => take,
            DispatchPolicy::Auto => {
                if (take as f64) < Self::AUTO_FILL_THRESHOLD * max_batch as f64 {
                    take
                } else {
                    max_batch
                }
            }
        }
    }
}

/// Per-request output accounting, produced by [`Workload::run_batch`].
#[derive(Debug, Clone, Copy)]
pub struct RequestOutput {
    /// Argmax prediction — vision: the logits row's class; text: the vocab
    /// argmax at the prompt's final position (the next-token prediction).
    pub pred: i32,
    /// Tokens this request is accounted (vision: 1 image; text: the prompt
    /// length), so throughput can be reported per token, not per request.
    pub tokens: usize,
}

/// A serving scenario: request synthesis, batch input assembly, and
/// per-request output accounting. Implementations must be `Sync` — the
/// engine shares one workload across its generator and worker threads.
pub trait Workload: Sync {
    /// One request's input payload, synthesized off the clock.
    type Req: Send + Sync;

    /// The model this workload drives (the engine cross-checks it against
    /// the executor's).
    fn cfg(&self) -> &'static ModelConfig;

    /// Axis label for benches and logs (`"vision"` / `"text"`).
    fn label(&self) -> &'static str {
        self.cfg().kind.workload_label()
    }

    /// Synthesize request `id`'s payload (request id == eval-stream index,
    /// so results are reproducible and comparable across runs).
    fn synth(&self, id: usize) -> Self::Req;

    /// Assemble `reqs` into one fused dispatch at batch size
    /// `dispatch ≥ reqs.len()` (rows past `reqs.len()` are zero padding,
    /// whose outputs are dropped) and return one [`RequestOutput`] per
    /// request, in order. Per-example math makes the outputs independent of
    /// `dispatch`, batch composition, and worker count — asserted by tests.
    fn run_batch(
        &self,
        plan: &ForwardPlan<'_, '_>,
        reqs: &[&Self::Req],
        dispatch: usize,
    ) -> Result<Vec<RequestOutput>>;
}

/// Image-classification serving: one eval-stream image per request.
pub struct VisionWorkload {
    cfg: &'static ModelConfig,
    gen: VisionGen,
}

impl VisionWorkload {
    pub fn new(cfg: &'static ModelConfig, seed: u64) -> Result<Self> {
        if cfg.kind != ModelKind::Vit {
            bail!("VisionWorkload on model '{}' (kind {:?})", cfg.name, cfg.kind);
        }
        Ok(Self { cfg, gen: VisionGen::new(seed) })
    }
}

impl Workload for VisionWorkload {
    /// One image's patch tokens, flat `[patches * patch_dim]`.
    type Req = Vec<f32>;

    fn cfg(&self) -> &'static ModelConfig {
        self.cfg
    }

    fn synth(&self, id: usize) -> Vec<f32> {
        self.gen.batch(Split::Eval, id as u64, 1).0.into_vec()
    }

    fn run_batch(
        &self,
        plan: &ForwardPlan<'_, '_>,
        reqs: &[&Vec<f32>],
        dispatch: usize,
    ) -> Result<Vec<RequestOutput>> {
        let per = self.cfg.patches * self.cfg.patch_dim;
        if reqs.is_empty() || dispatch < reqs.len() {
            bail!("run_batch: {} requests into dispatch size {dispatch}", reqs.len());
        }
        let mut buf = vec![0.0f32; dispatch * per];
        for (i, r) in reqs.iter().enumerate() {
            if r.len() != per {
                bail!("run_batch: request {i} carries {} values, expected {per}", r.len());
            }
            buf[i * per..(i + 1) * per].copy_from_slice(r);
        }
        let tokens = Tensor::from_vec(&[dispatch, self.cfg.patches, self.cfg.patch_dim], buf);
        let logits = plan.run_vit(&tokens)?;
        let c = self.cfg.classes;
        Ok((0..reqs.len())
            .map(|i| RequestOutput { pred: argmax(&logits.data()[i * c..(i + 1) * c]), tokens: 1 })
            .collect())
    }
}

/// LM serving with a prompt-length request model: request `id` is an
/// eval-stream prompt of deterministic length in `[min_prompt, n_ctx]`
/// ([`TextGen::prompt`]); accounting is per token, and the prediction is
/// the next-token argmax at the prompt's final position.
pub struct GptWorkload {
    cfg: &'static ModelConfig,
    gen: TextGen,
    min_prompt: usize,
}

impl GptWorkload {
    pub fn new(cfg: &'static ModelConfig, seed: u64) -> Result<Self> {
        if cfg.kind != ModelKind::Gpt {
            bail!("GptWorkload on model '{}' (kind {:?})", cfg.name, cfg.kind);
        }
        // Default arrival mix: prompts of 1/8th context up to full context
        // (floored at 4 tokens so tiny configs still vary).
        let min_prompt = if cfg.n_ctx < 4 { cfg.n_ctx } else { (cfg.n_ctx / 8).max(4) };
        Ok(Self { cfg, gen: TextGen::new(seed), min_prompt })
    }

    /// Override the minimum prompt length of the arrival mix.
    pub fn with_min_prompt(mut self, min_prompt: usize) -> Self {
        assert!(min_prompt >= 1 && min_prompt <= self.cfg.n_ctx);
        self.min_prompt = min_prompt;
        self
    }
}

/// One LM request: fixed-width ids (prompt + zero padding) and the true
/// prompt length the request is accounted at.
pub struct TextRequest {
    /// `[n_ctx]` ids; positions `>= prompt_len` are padding the causal mask
    /// keeps out of the prompt's logits.
    pub ids: Vec<i32>,
    pub prompt_len: usize,
}

impl Workload for GptWorkload {
    type Req = TextRequest;

    fn cfg(&self) -> &'static ModelConfig {
        self.cfg
    }

    fn synth(&self, id: usize) -> TextRequest {
        let (ids, prompt_len) = self.gen.prompt(id as u64, self.cfg.n_ctx, self.min_prompt);
        TextRequest { ids, prompt_len }
    }

    fn run_batch(
        &self,
        plan: &ForwardPlan<'_, '_>,
        reqs: &[&TextRequest],
        dispatch: usize,
    ) -> Result<Vec<RequestOutput>> {
        let n = self.cfg.n_ctx;
        if reqs.is_empty() || dispatch < reqs.len() {
            bail!("run_batch: {} requests into dispatch size {dispatch}", reqs.len());
        }
        let mut ids = vec![0i32; dispatch * n];
        for (i, r) in reqs.iter().enumerate() {
            if r.ids.len() != n || r.prompt_len < 1 || r.prompt_len > n {
                bail!(
                    "run_batch: request {i} carries {} ids with prompt_len {} (n_ctx {n})",
                    r.ids.len(),
                    r.prompt_len
                );
            }
            ids[i * n..(i + 1) * n].copy_from_slice(&r.ids);
        }
        let logits = plan.run_gpt(&ids, dispatch)?; // [dispatch, n, vocab]
        let v = self.cfg.vocab;
        Ok(reqs
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let at = (i * n + r.prompt_len - 1) * v;
                RequestOutput { pred: argmax(&logits.data()[at..at + v]), tokens: r.prompt_len }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-2.0, -1.0]), 1);
    }

    #[test]
    fn dispatch_policy_sizes() {
        assert_eq!(DispatchPolicy::Padded.dispatch_size(3, 16), 16);
        assert_eq!(DispatchPolicy::Exact.dispatch_size(3, 16), 3);
        // auto: below half fill → exact, at/above → padded.
        assert_eq!(DispatchPolicy::Auto.dispatch_size(7, 16), 7);
        assert_eq!(DispatchPolicy::Auto.dispatch_size(8, 16), 16);
        assert_eq!(DispatchPolicy::Auto.dispatch_size(16, 16), 16);
    }

    #[test]
    fn dispatch_policy_parse_and_resolve() {
        assert_eq!(DispatchPolicy::parse("padded").unwrap(), DispatchPolicy::Padded);
        assert_eq!(DispatchPolicy::parse("exact").unwrap(), DispatchPolicy::Exact);
        assert_eq!(DispatchPolicy::parse("auto").unwrap(), DispatchPolicy::Auto);
        assert!(DispatchPolicy::parse("bogus").is_err());
        for p in [DispatchPolicy::Padded, DispatchPolicy::Exact, DispatchPolicy::Auto] {
            assert_eq!(DispatchPolicy::parse(p.label()).unwrap(), p);
            // Fixed-shape backends collapse everything to padded.
            assert_eq!(p.resolve(true), DispatchPolicy::Padded);
            assert_eq!(p.resolve(false), p);
        }
    }

    #[test]
    fn workload_kind_mismatch_rejected() {
        let vit = ModelConfig::by_name("vit_t").unwrap();
        let gpt = ModelConfig::by_name("gpt_s").unwrap();
        assert!(VisionWorkload::new(gpt, 0).is_err());
        assert!(GptWorkload::new(vit, 0).is_err());
        assert_eq!(VisionWorkload::new(vit, 0).unwrap().label(), "vision");
        assert_eq!(GptWorkload::new(gpt, 0).unwrap().label(), "text");
    }

    #[test]
    fn gpt_workload_synth_prompt_lengths() {
        let gpt = ModelConfig::by_name("gpt_s").unwrap();
        let wl = GptWorkload::new(gpt, 17).unwrap().with_min_prompt(6);
        for id in 0..8 {
            let r = wl.synth(id);
            assert_eq!(r.ids.len(), gpt.n_ctx);
            assert!((6..=gpt.n_ctx).contains(&r.prompt_len));
            assert!(r.ids[r.prompt_len..].iter().all(|&v| v == 0));
        }
    }
}
