//! Serving workloads and the batch dispatch policy.
//!
//! The engine (`serve::engine`) is generic over a [`Workload`]: the
//! workload owns request synthesis (what arrives), batch input assembly
//! (how queued requests become one fused dispatch), and per-request output
//! accounting (what each request is charged and what it predicted). The
//! queueing/batching core is written once; [`VisionWorkload`] (one image
//! per request, Table-5-style classification serving), [`GptWorkload`]
//! (prompt-length request model with per-token accounting, the paper's OPT
//! deployment analogue), and [`GenWorkload`] (autoregressive generation on
//! the KV-cached decode path) are the scenarios.
//!
//! [`DispatchPolicy`] decides the *shape* each formed batch dispatches at:
//! padded to the fixed artifact batch (shape reuse — what a compiled
//! fixed-shape backend wants), exact at the true batch size (the native
//! backend does proportionally less arithmetic), or `auto`, which picks
//! exact-size dispatch below a fill-ratio threshold and padded shape reuse
//! above it.
//!
//! The engine drives every scenario through one method,
//! [`Workload::run_step`]: a step either finishes a request
//! ([`StepOutcome::Done`]) or asks the engine to re-enqueue it
//! ([`StepOutcome::Continue`]), so decode steps from *different* sequences
//! batch together in later engine batches. Single-shot workloads finish
//! every request in its first step; [`GenWorkload`] is the multi-step
//! generation scenario.

use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use std::collections::BTreeMap;

use crate::data::{Split, TextGen, VisionGen};
use crate::exec::{argmax, DecodeMode, DecodePlan, DecodeState, ForwardPlan, PlanLadder};
use crate::model::{ModelConfig, ModelKind};
use crate::tensor::Tensor;
use crate::util::{lock, Pcg64};

/// How a formed batch of `take ≤ max_batch` requests is dispatched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Always pad to the fixed artifact batch (`max_batch`). One shape for
    /// the whole run — what an AOT fixed-shape backend reuses — at the cost
    /// of full-batch arithmetic on partial batches.
    Padded,
    /// Always dispatch at the true batch size. Partial batches do
    /// proportionally less work (the native backend interprets any size),
    /// at the cost of one artifact shape per distinct size.
    Exact,
    /// Exact below [`DispatchPolicy::AUTO_FILL_THRESHOLD`] fill ratio,
    /// padded at or above it: nearly-full batches keep the reusable fixed
    /// shape (padding waste is small), sparse batches skip the padding
    /// arithmetic (where the waste dominates).
    Auto,
}

impl DispatchPolicy {
    /// Fill ratio (`take / max_batch`) at which `auto` switches from
    /// exact-size dispatch to padded shape reuse.
    pub const AUTO_FILL_THRESHOLD: f64 = 0.5;

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "padded" => DispatchPolicy::Padded,
            "exact" => DispatchPolicy::Exact,
            "auto" => DispatchPolicy::Auto,
            _ => bail!("dispatch must be padded|exact|auto, got '{s}'"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            DispatchPolicy::Padded => "padded",
            DispatchPolicy::Exact => "exact",
            DispatchPolicy::Auto => "auto",
        }
    }

    /// Collapse to the policy actually usable on a backend: a runtime that
    /// prefers fixed shapes (gated PJRT with a manifest) keeps the padded
    /// path — exact-size artifacts have no AOT lowering there and would
    /// silently fall back to the interpreter.
    pub fn resolve(self, fixed_shapes: bool) -> Self {
        if fixed_shapes {
            DispatchPolicy::Padded
        } else {
            self
        }
    }

    /// The batch size a formed batch of `take` requests dispatches at.
    pub fn dispatch_size(&self, take: usize, max_batch: usize) -> usize {
        debug_assert!(take >= 1 && take <= max_batch);
        match self {
            DispatchPolicy::Padded => max_batch,
            DispatchPolicy::Exact => take,
            DispatchPolicy::Auto => {
                if (take as f64) < Self::AUTO_FILL_THRESHOLD * max_batch as f64 {
                    take
                } else {
                    max_batch
                }
            }
        }
    }
}

/// Per-request output accounting, carried by [`StepOutcome::Done`].
#[derive(Debug, Clone, Copy)]
pub struct RequestOutput {
    /// Argmax prediction — vision: the logits row's class; text: the vocab
    /// argmax at the prompt's final position (the next-token prediction);
    /// generation: the final generated token.
    pub pred: i32,
    /// Tokens this request is accounted (vision: 1 image; text: the prompt
    /// length; generation: prompt + generated), so throughput can be
    /// reported per token, not per request.
    pub tokens: usize,
}

/// Outcome of one engine step for one request.
#[derive(Debug, Clone, Copy)]
pub enum StepOutcome {
    /// The request finished this step; record its output.
    Done(RequestOutput),
    /// The request has more steps (e.g. decode tokens left); the engine
    /// re-enqueues it so its next step batches with other requests.
    Continue,
}

/// One variant's resolved dispatch plans. Exactly the plan the workload
/// declared is built: the batch-polymorphic full forward for single-shot
/// workloads, the incremental decode plan for workloads with a
/// [`Workload::decode`] mode — the other stays `None` (resolving both
/// would shape-check every parameter tensor twice and warm artifact names
/// that are never dispatched).
pub struct PlanPair<'rt, 'w> {
    pub fwd: Option<ForwardPlan<'rt, 'w>>,
    pub dec: Option<DecodePlan<'rt, 'w>>,
}

/// The plans the engine hands every [`Workload::run_step`]: a
/// [`PlanLadder`] of [`PlanPair`] rungs — rung 0 is the primary (dense)
/// variant, higher rungs are the degraded (pruned+compensated) variants
/// the controller switches to under load. Runs without `--degrade` carry a
/// single rung, so `fwd()` / `dec()` behave exactly as before.
pub struct Plans<'rt, 'w> {
    ladder: PlanLadder<PlanPair<'rt, 'w>>,
}

impl<'rt, 'w> Plans<'rt, 'w> {
    /// A one-rung ladder (the no-controller, no-degrade common case).
    pub fn single(fwd: Option<ForwardPlan<'rt, 'w>>, dec: Option<DecodePlan<'rt, 'w>>) -> Self {
        Plans {
            ladder: PlanLadder::new(vec![PlanPair { fwd, dec }])
                .expect("one rung is never empty"),
        }
    }

    /// A multi-rung ladder; rung 0 (the dense plan) starts active.
    pub fn ladder(pairs: Vec<PlanPair<'rt, 'w>>) -> Result<Self> {
        Ok(Plans { ladder: PlanLadder::new(pairs)? })
    }

    /// Number of plan rungs (variants) available.
    pub fn variants(&self) -> usize {
        self.ladder.len()
    }

    /// Index of the active rung (0 = dense).
    pub fn active(&self) -> usize {
        self.ladder.active()
    }

    /// Switch the active rung (clamped; called by the controller at batch
    /// boundaries only — in-flight sequences stay pinned to their rung).
    pub fn set_active(&self, i: usize) {
        self.ladder.set_active(i)
    }

    /// Rung `i`'s plan pair (clamped into range).
    pub fn pair(&self, i: usize) -> &PlanPair<'rt, 'w> {
        self.ladder.get(i.min(self.ladder.len() - 1)).expect("clamped index in range")
    }

    /// The active rung's full-forward plan, or a clear error for an engine
    /// mismatch.
    pub fn fwd(&self) -> Result<&ForwardPlan<'rt, 'w>> {
        self.fwd_at(self.active())
    }

    /// The active rung's decode plan, or a clear error for a
    /// workload/engine mismatch.
    pub fn dec(&self) -> Result<&DecodePlan<'rt, 'w>> {
        self.dec_at(self.active())
    }

    /// Rung `i`'s full-forward plan.
    pub fn fwd_at(&self, i: usize) -> Result<&ForwardPlan<'rt, 'w>> {
        self.pair(i).fwd.as_ref().context("workload needs a forward plan but the engine built none")
    }

    /// Rung `i`'s decode plan.
    pub fn dec_at(&self, i: usize) -> Result<&DecodePlan<'rt, 'w>> {
        self.pair(i).dec.as_ref().context("workload needs a decode plan but the engine built none")
    }
}

/// A serving scenario: request synthesis, batch input assembly, and
/// per-request output accounting. Implementations must be `Sync` — the
/// engine shares one workload across its generator and worker threads.
pub trait Workload: Sync {
    /// One request's input payload, synthesized off the clock.
    type Req: Send + Sync;

    /// The model this workload drives (the engine cross-checks it against
    /// the executor's).
    fn cfg(&self) -> &'static ModelConfig;

    /// Axis label for benches and logs (`"vision"` / `"text"`).
    fn label(&self) -> &'static str {
        self.cfg().kind.workload_label()
    }

    /// Synthesize request `id`'s payload (request id == eval-stream index,
    /// so results are reproducible and comparable across runs).
    fn synth(&self, id: usize) -> Self::Req;

    /// The decode mode this workload drives, or `None` for single-shot
    /// workloads (the engine then skips building a [`DecodePlan`]). The
    /// engine resolves the mode against the runtime's shape preference.
    fn decode(&self) -> Option<DecodeMode> {
        None
    }

    /// One engine step over a formed batch: assemble `reqs` into one fused
    /// dispatch at batch size `dispatch ≥ reqs.len()` (rows past
    /// `reqs.len()` are inert padding) and return one [`StepOutcome`] per
    /// request, in order — [`StepOutcome::Done`] to record the request,
    /// [`StepOutcome::Continue`] to have the engine re-enqueue it for a
    /// later step. Per-example math makes the outcomes independent of
    /// `dispatch`, batch composition, and worker count — asserted by tests.
    fn run_step(
        &self,
        plans: &Plans<'_, '_>,
        reqs: &[&Self::Req],
        dispatch: usize,
    ) -> Result<Vec<StepOutcome>>;

    /// Release any engine-side state a request still holds when the engine
    /// aborts it (retry budget exhausted, injected fault, or a run torn
    /// down with the request still queued). Returns the number of KV pool
    /// blocks returned to the free list. Single-shot workloads hold no
    /// such state — the default is a no-op.
    fn reclaim(&self, _req: &Self::Req) -> usize {
        0
    }
}

/// Wrap a single-shot batch's outputs: every request finishes in one step.
fn all_done(outs: Vec<RequestOutput>) -> Vec<StepOutcome> {
    outs.into_iter().map(StepOutcome::Done).collect()
}

/// Default minimum prompt length of the text serving mixes (shared by
/// [`GptWorkload`], [`GenWorkload`], and `corp generate`): an eighth of the
/// context floored at 4 tokens, so tiny configs still vary.
pub fn default_min_prompt(cfg: &ModelConfig) -> usize {
    if cfg.n_ctx < 4 {
        cfg.n_ctx
    } else {
        (cfg.n_ctx / 8).max(4)
    }
}

/// Image-classification serving: one eval-stream image per request.
pub struct VisionWorkload {
    cfg: &'static ModelConfig,
    gen: VisionGen,
}

impl VisionWorkload {
    pub fn new(cfg: &'static ModelConfig, seed: u64) -> Result<Self> {
        if cfg.kind != ModelKind::Vit {
            bail!("VisionWorkload on model '{}' (kind {:?})", cfg.name, cfg.kind);
        }
        Ok(Self { cfg, gen: VisionGen::new(seed) })
    }

    /// One fused classification dispatch (rows past `reqs.len()` are zero
    /// padding whose outputs are dropped): one [`RequestOutput`] per
    /// request, in order.
    fn run_batch(
        &self,
        plan: &ForwardPlan<'_, '_>,
        reqs: &[&Vec<f32>],
        dispatch: usize,
    ) -> Result<Vec<RequestOutput>> {
        let per = self.cfg.patches * self.cfg.patch_dim;
        if reqs.is_empty() || dispatch < reqs.len() {
            bail!("run_batch: {} requests into dispatch size {dispatch}", reqs.len());
        }
        let mut buf = vec![0.0f32; dispatch * per];
        for (i, r) in reqs.iter().enumerate() {
            if r.len() != per {
                bail!("run_batch: request {i} carries {} values, expected {per}", r.len());
            }
            buf[i * per..(i + 1) * per].copy_from_slice(r);
        }
        let tokens = Tensor::from_vec(&[dispatch, self.cfg.patches, self.cfg.patch_dim], buf);
        let logits = plan.run_vit(&tokens)?;
        let c = self.cfg.classes;
        Ok((0..reqs.len())
            .map(|i| RequestOutput { pred: argmax(&logits.data()[i * c..(i + 1) * c]), tokens: 1 })
            .collect())
    }
}

impl Workload for VisionWorkload {
    /// One image's patch tokens, flat `[patches * patch_dim]`.
    type Req = Vec<f32>;

    fn cfg(&self) -> &'static ModelConfig {
        self.cfg
    }

    fn synth(&self, id: usize) -> Vec<f32> {
        self.gen.batch(Split::Eval, id as u64, 1).0.into_vec()
    }

    fn run_step(
        &self,
        plans: &Plans<'_, '_>,
        reqs: &[&Vec<f32>],
        dispatch: usize,
    ) -> Result<Vec<StepOutcome>> {
        Ok(all_done(self.run_batch(plans.fwd()?, reqs, dispatch)?))
    }
}

/// LM serving with a prompt-length request model: request `id` is an
/// eval-stream prompt of deterministic length in `[min_prompt, n_ctx]`
/// ([`TextGen::prompt`]); accounting is per token, and the prediction is
/// the next-token argmax at the prompt's final position.
pub struct GptWorkload {
    cfg: &'static ModelConfig,
    gen: TextGen,
    min_prompt: usize,
}

impl GptWorkload {
    pub fn new(cfg: &'static ModelConfig, seed: u64) -> Result<Self> {
        if cfg.kind != ModelKind::Gpt {
            bail!("GptWorkload on model '{}' (kind {:?})", cfg.name, cfg.kind);
        }
        Ok(Self { cfg, gen: TextGen::new(seed), min_prompt: default_min_prompt(cfg) })
    }

    /// Override the minimum prompt length of the arrival mix.
    pub fn with_min_prompt(mut self, min_prompt: usize) -> Self {
        assert!(min_prompt >= 1 && min_prompt <= self.cfg.n_ctx);
        self.min_prompt = min_prompt;
        self
    }

    /// One fused prompt-scoring dispatch (rows past `reqs.len()` are zero
    /// padding the causal mask keeps inert): one [`RequestOutput`] per
    /// request, in order.
    fn run_batch(
        &self,
        plan: &ForwardPlan<'_, '_>,
        reqs: &[&TextRequest],
        dispatch: usize,
    ) -> Result<Vec<RequestOutput>> {
        let n = self.cfg.n_ctx;
        if reqs.is_empty() || dispatch < reqs.len() {
            bail!("run_batch: {} requests into dispatch size {dispatch}", reqs.len());
        }
        let mut ids = vec![0i32; dispatch * n];
        for (i, r) in reqs.iter().enumerate() {
            if r.ids.len() != n || r.prompt_len < 1 || r.prompt_len > n {
                bail!(
                    "run_batch: request {i} carries {} ids with prompt_len {} (n_ctx {n})",
                    r.ids.len(),
                    r.prompt_len
                );
            }
            ids[i * n..(i + 1) * n].copy_from_slice(&r.ids);
        }
        let logits = plan.run_gpt(&ids, dispatch)?; // [dispatch, n, vocab]
        let v = self.cfg.vocab;
        Ok(reqs
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let at = (i * n + r.prompt_len - 1) * v;
                RequestOutput { pred: argmax(&logits.data()[at..at + v]), tokens: r.prompt_len }
            })
            .collect())
    }
}

/// One LM request: fixed-width ids (prompt + zero padding) and the true
/// prompt length the request is accounted at.
pub struct TextRequest {
    /// `[n_ctx]` ids; positions `>= prompt_len` are padding the causal mask
    /// keeps out of the prompt's logits.
    pub ids: Vec<i32>,
    pub prompt_len: usize,
}

impl Workload for GptWorkload {
    type Req = TextRequest;

    fn cfg(&self) -> &'static ModelConfig {
        self.cfg
    }

    fn synth(&self, id: usize) -> TextRequest {
        let (ids, prompt_len) = self.gen.prompt(id as u64, self.cfg.n_ctx, self.min_prompt);
        TextRequest { ids, prompt_len }
    }

    fn run_step(
        &self,
        plans: &Plans<'_, '_>,
        reqs: &[&TextRequest],
        dispatch: usize,
    ) -> Result<Vec<StepOutcome>> {
        Ok(all_done(self.run_batch(plans.fwd()?, reqs, dispatch)?))
    }
}

/// Autoregressive generation serving: request `id` is an eval-stream prompt
/// plus a deterministic per-id target length; every engine step advances
/// the sequence by one fused [`DecodePlan::extend_at`] dispatch (prefill
/// steps feed prompt tokens, later steps decode the fed-back greedy argmax
/// token), and unfinished requests return [`StepOutcome::Continue`] so
/// their next step batches with *other* sequences — the
/// continuation-re-enqueue batching model. Accounting is per token
/// (prompt + generated); the prediction is the final generated token.
///
/// Two knobs exercise the paged KV cache:
///
/// * [`GenWorkload::with_prefill_chunk`] caps the prompt tokens fed per
///   step, so a long prefill is spread over several `Continue` steps that
///   interleave with *other* sequences' single-token decode steps in later
///   engine batches — decode inter-token latency stays flat while a long
///   prompt is in flight, instead of stalling behind one huge dispatch.
/// * [`GenWorkload::with_shared_prefix`] stamps a deterministic common
///   opening onto every synthesized prompt; on prompt completion the
///   opening's K/V blocks are registered in the pool's prefix registry,
///   and later requests with the same opening adopt those blocks instead
///   of recomputing the prefill (per-row arithmetic is identical either
///   way, so predictions don't change).
pub struct GenWorkload {
    cfg: &'static ModelConfig,
    gen: TextGen,
    seed: u64,
    min_prompt: usize,
    max_new: usize,
    mode: DecodeMode,
    /// Max prompt tokens fed per engine step (`0` = whole prompt at once).
    prefill_chunk: usize,
    /// Common-opening length stamped onto every prompt (`0` = natural
    /// eval-stream prompts, which share no openings).
    shared_prefix: usize,
}

/// One generation request: the true (unpadded) prompt, the target number
/// of generated tokens, and the interior per-sequence decode state the
/// steps advance. A request is in at most one in-flight batch at a time
/// (the engine re-enqueues it only after its step completes), so the lock
/// is uncontended.
pub struct GenRequest {
    /// Prompt ids, length `prompt_len` (no padding).
    pub prompt: Vec<i32>,
    pub prompt_len: usize,
    /// Greedy tokens to generate (≥ 1); the request finishes after this
    /// many predictions.
    pub target_new: usize,
    state: Mutex<GenState>,
}

struct GenState {
    /// `Some` while the sequence is live; dropped on completion so the
    /// request's KV pool blocks go back to the free list immediately.
    dec: Option<DecodeState>,
    /// Prompt positions in the cache so far (adopted + fed); the prompt is
    /// fully prefilled once this reaches `prompt.len()`.
    fed: usize,
    /// Last predicted token — the next step's input.
    next: i32,
    /// Predictions made so far.
    produced: usize,
    /// Plan rung the sequence was begun on. KV pool dims differ across
    /// rungs (pruned dqk ≠ dense dqk), so a live sequence is pinned to the
    /// rung that created its [`DecodeState`] even if the controller
    /// switches the active rung mid-flight; new sequences pick up the
    /// switch on their first step.
    variant: usize,
}

impl GenWorkload {
    pub fn new(cfg: &'static ModelConfig, seed: u64) -> Result<Self> {
        if cfg.kind != ModelKind::Gpt {
            bail!("GenWorkload on model '{}' (kind {:?})", cfg.name, cfg.kind);
        }
        // Same default arrival mix as GptWorkload; generation targets are
        // short continuations by default.
        Ok(Self {
            cfg,
            gen: TextGen::new(seed),
            seed,
            min_prompt: default_min_prompt(cfg),
            max_new: 8,
            mode: DecodeMode::KvCache,
            prefill_chunk: 0,
            shared_prefix: 0,
        })
    }

    /// Override the maximum generated-token target of the request mix.
    pub fn with_max_new(mut self, max_new: usize) -> Self {
        assert!(max_new >= 1 && max_new <= self.cfg.n_ctx);
        self.max_new = max_new;
        self
    }

    /// Override the minimum prompt length of the arrival mix.
    pub fn with_min_prompt(mut self, min_prompt: usize) -> Self {
        assert!(min_prompt >= 1 && min_prompt <= self.cfg.n_ctx);
        self.min_prompt = min_prompt;
        self
    }

    /// Pin the decode mode (the bench harness sweeps kv vs prefill).
    pub fn with_decode(mut self, mode: DecodeMode) -> Self {
        self.mode = mode;
        self
    }

    /// Cap the prompt tokens fed per engine step (`0` = one-shot prefill).
    /// Splitting positions across dispatches doesn't change any per-row
    /// arithmetic, so predictions are unchanged.
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        self.prefill_chunk = chunk;
        self
    }

    /// Stamp a deterministic `len`-token common opening onto every
    /// synthesized prompt, so the pool's prefix registry gets real hits
    /// (natural eval-stream prompts share no openings).
    pub fn with_shared_prefix(mut self, len: usize) -> Self {
        assert!(len <= self.cfg.n_ctx);
        self.shared_prefix = len;
        self
    }
}

impl Workload for GenWorkload {
    type Req = GenRequest;

    fn cfg(&self) -> &'static ModelConfig {
        self.cfg
    }

    fn label(&self) -> &'static str {
        "gen"
    }

    fn decode(&self) -> Option<DecodeMode> {
        Some(self.mode)
    }

    fn synth(&self, id: usize) -> GenRequest {
        let (ids, plen0) = self.gen.prompt(id as u64, self.cfg.n_ctx, self.min_prompt);
        let mut rng = Pcg64::new(
            self.seed ^ (id as u64).wrapping_mul(0x9e3779b97f4a7c15) ^ 0x67656e, // "gen"
        );
        let target = 1 + rng.below(self.max_new);
        // The final prediction is never appended, so prompt + target − 1
        // positions must fit in the context; clamp the prompt, not the
        // target, so the generation mix stays intact.
        let plen = plen0.min(self.cfg.n_ctx + 1 - target).max(1);
        let mut prompt = ids[..plen].to_vec();
        if self.shared_prefix > 0 {
            // Same opening for every id (seed-derived, not id-derived), so
            // the pool's prefix registry gets genuine cross-request hits.
            let mut op = Pcg64::new(self.seed ^ 0x707265666978); // "prefix"
            for slot in prompt.iter_mut().take(self.shared_prefix) {
                *slot = op.below(self.cfg.vocab) as i32;
            }
        }
        GenRequest {
            prompt,
            prompt_len: plen,
            target_new: target,
            state: Mutex::new(GenState { dec: None, fed: 0, next: 0, produced: 0, variant: 0 }),
        }
    }

    fn run_step(
        &self,
        plans: &Plans<'_, '_>,
        reqs: &[&GenRequest],
        dispatch: usize,
    ) -> Result<Vec<StepOutcome>> {
        if reqs.is_empty() || dispatch < reqs.len() {
            bail!("run_step: {} requests into dispatch size {dispatch}", reqs.len());
        }
        let mut guards: Vec<_> = reqs.iter().map(|r| lock::lock(&r.state)).collect();
        // Prefill steps feed (a chunk of) the prompt; decode steps feed the
        // fed-back argmax token. Both kinds batch together in one dispatch
        // (per-sequence lengths ride along), which is exactly how a long
        // chunked prefill interleaves with other sequences' decode steps.
        let active = plans.active();
        let mut toks: Vec<Vec<i32>> = Vec::with_capacity(reqs.len());
        let mut prefilled = Vec::with_capacity(reqs.len());
        for (r, g) in reqs.iter().zip(guards.iter_mut()) {
            if g.dec.is_none() {
                // Pin the sequence to the rung active at its first step:
                // KV pool dims differ across rungs, so the whole sequence
                // runs the plan that created its state.
                g.variant = active;
                // Adopt registered shared-prefix blocks where available;
                // `fed` counts the adopted positions as already cached.
                let (st, skip) = plans.dec_at(g.variant)?.begin_prompt(&r.prompt)?;
                g.dec = Some(st);
                g.fed = skip;
            }
            let plen = r.prompt.len();
            if g.fed < plen {
                let feed = match self.prefill_chunk {
                    0 => plen - g.fed,
                    c => c.min(plen - g.fed),
                };
                toks.push(r.prompt[g.fed..g.fed + feed].to_vec());
                g.fed += feed;
                prefilled.push(true);
            } else {
                toks.push(vec![g.next]);
                prefilled.push(false);
            }
        }
        // Group rows by pinned rung. Single-rung batches (every batch when
        // the controller is off, and most batches when it is on — switches
        // happen at batch boundaries) keep the engine's dispatch size;
        // mixed batches straddling a switch dispatch each rung's group at
        // its own exact size.
        let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, g) in guards.iter().enumerate() {
            groups.entry(g.variant).or_default().push(i);
        }
        let mut rows: Vec<Vec<f32>> = vec![Vec::new(); reqs.len()];
        for (&v, idxs) in &groups {
            let dec = plans.dec_at(v)?;
            let disp = if groups.len() == 1 { dispatch } else { idxs.len() };
            let mut states: Vec<&mut DecodeState> = Vec::with_capacity(idxs.len());
            let mut want = idxs.iter().peekable();
            for (i, g) in guards.iter_mut().enumerate() {
                if want.peek() == Some(&&i) {
                    want.next();
                    states.push(g.dec.as_mut().expect("state initialized above"));
                }
            }
            let new: Vec<&[i32]> = idxs.iter().map(|&i| toks[i].as_slice()).collect();
            let out = dec.extend_at(&mut states, &new, disp)?;
            drop(states);
            for (&i, row) in idxs.iter().zip(out) {
                rows[i] = row;
            }
        }
        let vocab = self.cfg.vocab;
        let mut outs = Vec::with_capacity(reqs.len());
        for (((r, g), row), pre) in reqs.iter().zip(guards.iter_mut()).zip(rows).zip(prefilled) {
            let plen = r.prompt.len();
            if pre && g.fed == plen && self.shared_prefix > 0 {
                // Prompt complete: publish the stamped opening's blocks for
                // adoption by later requests (registering once is enough —
                // repeat registrations of the same opening are no-ops).
                plans
                    .dec_at(g.variant)?
                    .share_prefix(g.dec.as_ref().expect("state live"), self.shared_prefix.min(plen))?;
            }
            if pre && g.fed < plen {
                // Interior prefill chunk: its logits are prompt-interior
                // rows nothing consumes; keep feeding next step.
                outs.push(StepOutcome::Continue);
                continue;
            }
            let pred = argmax(&row[row.len() - vocab..]);
            g.produced += 1;
            if g.produced >= r.target_new {
                // Drop the sequence state now, not at request teardown, so
                // its non-shared pool blocks are immediately reusable.
                g.dec = None;
                outs.push(StepOutcome::Done(RequestOutput {
                    pred,
                    tokens: r.prompt_len + r.target_new,
                }));
            } else {
                g.next = pred;
                outs.push(StepOutcome::Continue);
            }
        }
        Ok(outs)
    }

    /// Abort a generation mid-flight: drop its decode state so any paged
    /// KV blocks it still holds go back to the pool immediately. Returns
    /// the block count released (shared/registered blocks stay pinned by
    /// their other referents).
    fn reclaim(&self, req: &GenRequest) -> usize {
        let mut g = lock::lock(&req.state);
        g.dec.take().map_or(0, |d| d.kv_blocks())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_policy_sizes() {
        assert_eq!(DispatchPolicy::Padded.dispatch_size(3, 16), 16);
        assert_eq!(DispatchPolicy::Exact.dispatch_size(3, 16), 3);
        // auto: below half fill → exact, at/above → padded.
        assert_eq!(DispatchPolicy::Auto.dispatch_size(7, 16), 7);
        assert_eq!(DispatchPolicy::Auto.dispatch_size(8, 16), 16);
        assert_eq!(DispatchPolicy::Auto.dispatch_size(16, 16), 16);
    }

    #[test]
    fn dispatch_policy_parse_and_resolve() {
        assert_eq!(DispatchPolicy::parse("padded").unwrap(), DispatchPolicy::Padded);
        assert_eq!(DispatchPolicy::parse("exact").unwrap(), DispatchPolicy::Exact);
        assert_eq!(DispatchPolicy::parse("auto").unwrap(), DispatchPolicy::Auto);
        assert!(DispatchPolicy::parse("bogus").is_err());
        for p in [DispatchPolicy::Padded, DispatchPolicy::Exact, DispatchPolicy::Auto] {
            assert_eq!(DispatchPolicy::parse(p.label()).unwrap(), p);
            // Fixed-shape backends collapse everything to padded.
            assert_eq!(p.resolve(true), DispatchPolicy::Padded);
            assert_eq!(p.resolve(false), p);
        }
    }

    #[test]
    fn workload_kind_mismatch_rejected() {
        let vit = ModelConfig::by_name("vit_t").unwrap();
        let gpt = ModelConfig::by_name("gpt_s").unwrap();
        assert!(VisionWorkload::new(gpt, 0).is_err());
        assert!(GptWorkload::new(vit, 0).is_err());
        assert_eq!(VisionWorkload::new(vit, 0).unwrap().label(), "vision");
        assert_eq!(GptWorkload::new(gpt, 0).unwrap().label(), "text");
    }

    #[test]
    fn gpt_workload_synth_prompt_lengths() {
        let gpt = ModelConfig::by_name("gpt_s").unwrap();
        let wl = GptWorkload::new(gpt, 17).unwrap().with_min_prompt(6);
        for id in 0..8 {
            let r = wl.synth(id);
            assert_eq!(r.ids.len(), gpt.n_ctx);
            assert!((6..=gpt.n_ctx).contains(&r.prompt_len));
            assert!(r.ids[r.prompt_len..].iter().all(|&v| v == 0));
        }
    }

    #[test]
    fn gen_workload_synth_respects_context_budget() {
        let gpt = ModelConfig::by_name("gpt_s").unwrap();
        let vit = ModelConfig::by_name("vit_t").unwrap();
        assert!(GenWorkload::new(vit, 0).is_err());
        let wl = GenWorkload::new(gpt, 17).unwrap().with_max_new(6);
        assert_eq!(wl.label(), "gen");
        assert_eq!(wl.decode(), Some(DecodeMode::KvCache));
        assert_eq!(
            wl.with_decode(DecodeMode::Prefill).decode(),
            Some(DecodeMode::Prefill)
        );
        let wl = GenWorkload::new(gpt, 17).unwrap().with_max_new(6);
        let mut targets = Vec::new();
        for id in 0..16 {
            let r = wl.synth(id);
            assert_eq!(r.prompt.len(), r.prompt_len);
            assert!(r.prompt_len >= 1);
            assert!((1..=6).contains(&r.target_new));
            // The final prediction is never appended, so prompt + target − 1
            // positions must fit.
            assert!(r.prompt_len + r.target_new - 1 <= gpt.n_ctx);
            // Deterministic per id.
            let r2 = wl.synth(id);
            assert_eq!(r.prompt, r2.prompt);
            assert_eq!(r.target_new, r2.target_new);
            targets.push(r.target_new);
        }
        // The generation mix is not degenerate.
        assert!(targets.iter().any(|&t| t != targets[0]));
    }

    #[test]
    fn gen_workload_shared_prefix_stamps_common_opening() {
        let gpt = ModelConfig::by_name("gpt_s").unwrap();
        let wl = GenWorkload::new(gpt, 17).unwrap().with_shared_prefix(8).with_prefill_chunk(4);
        let a = wl.synth(0);
        let b = wl.synth(1);
        // Every prompt opens with the same seed-derived stamp, in-vocab.
        let s = 8.min(a.prompt_len).min(b.prompt_len);
        assert!(s >= 1);
        assert_eq!(a.prompt[..s], b.prompt[..s]);
        let v = gpt.vocab as i32;
        assert!(a.prompt[..8.min(a.prompt_len)].iter().all(|&t| (0..v).contains(&t)));
        // Unstamped synthesis is untouched by the new knobs' defaults.
        let base = GenWorkload::new(gpt, 17).unwrap();
        let c = base.synth(0);
        assert_eq!(c.prompt[s..], a.prompt[s..]);
        assert_eq!(c.target_new, a.target_new);
    }
}
