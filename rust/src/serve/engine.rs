//! Concurrent batched serving engine.
//!
//! Queueing model (open loop): a generator thread replays a seeded Poisson
//! arrival process into a *bounded* FIFO queue; arrivals that find the queue
//! full are shed and counted (backpressure instead of unbounded buildup).
//! `workers` executor threads drain the queue: each pops a request, then
//! keeps the batch open up to `max_wait` seconds waiting for the queue to
//! yield up to `max_batch` requests, pads the (possibly partial) batch to
//! the fixed artifact batch, and dispatches one fused forward
//! ([`crate::exec::PreparedForward`]) shared by every worker.
//!
//! Accounting is per request: queueing delay (intended arrival → dequeue),
//! execution time (its batch's forward), and total latency. Predictions are
//! returned per request so tests can assert that batching, padding, and the
//! worker count never change *what* is computed — rows of a padded batch
//! are processed per example, so a request's logits are identical to a
//! batch-1 forward of the same image.
//!
//! Worker threads call [`threads::serialize_nested_regions`] on entry:
//! the per-example fan-out inside the native backend runs serial on them,
//! so total parallelism equals the engine's worker count and the host is
//! never oversubscribed by nested pools.

use anyhow::{bail, Result};

use crate::data::VisionGen;
use crate::exec::Executor;
use crate::model::WeightStore;

// Internals of the real (non-PJRT) engine; the `--cfg pjrt_backend` build
// compiles a stub `run_engine` instead (see below), because sharing one
// `Runtime` across worker threads requires the backend to be `Sync` and
// the vendored `xla` client/executable types are not known to be.
#[cfg(not(pjrt_backend))]
use {
    crate::data::Split,
    crate::model::ModelKind,
    crate::tensor::Tensor,
    crate::util::bench::percentile,
    crate::util::{threads, Pcg64},
    std::collections::VecDeque,
    std::sync::{Condvar, Mutex},
    std::time::{Duration, Instant},
};

/// Serving-engine options.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// Executor threads draining the queue.
    pub workers: usize,
    /// Open-loop arrival rate, requests/sec. Non-finite or ≤ 0 means
    /// "saturated": every request is due at t = 0.
    pub rate: f64,
    /// Total requests offered to the engine.
    pub requests: usize,
    /// Maximum requests per batch; also the fixed artifact batch size that
    /// partial batches are padded to.
    pub max_batch: usize,
    /// Batching deadline: how long a worker holds a non-full batch open
    /// waiting for more arrivals, seconds.
    pub max_wait: f64,
    /// Queue bound; arrivals beyond it are shed (counted, not served).
    pub queue_cap: usize,
    /// Minimum per-batch execution time, seconds (0 = off). A load-shaping
    /// knob for backpressure tests and experiments: the worker sleeps out
    /// the remainder after the real forward.
    pub exec_floor: f64,
    /// Seed for the Poisson arrival process.
    pub seed: u64,
}

impl Default for EngineOpts {
    fn default() -> Self {
        Self {
            workers: 2,
            rate: 200.0,
            requests: 256,
            max_batch: 16,
            max_wait: 0.01,
            queue_cap: 1024,
            exec_floor: 0.0,
            seed: 7,
        }
    }
}

/// Per-request accounting (one row per *served* request).
#[derive(Debug, Clone)]
pub struct RequestRecord {
    /// Request id; doubles as the eval-stream image index.
    pub id: usize,
    /// Intended arrival → dequeue into a batch, ms.
    pub queue_ms: f64,
    /// Execution time of the batch this request rode in, ms.
    pub exec_ms: f64,
    /// Intended arrival → completion, ms.
    pub total_ms: f64,
    /// Argmax class of this request's logits row.
    pub pred: i32,
}

/// Aggregate result of one engine run.
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub served: usize,
    /// Requests shed at the full queue.
    pub shed: usize,
    /// Batches executed.
    pub batches: usize,
    pub mean_batch: f64,
    /// p50 / p95 of total per-request latency, ms.
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// p50 queueing delay, ms.
    pub queue_p50_ms: f64,
    /// Mean per-batch execution time, ms.
    pub exec_mean_ms: f64,
    /// Served requests per second of wall time.
    pub throughput_fps: f64,
    /// Per-request records, sorted by id.
    pub records: Vec<RequestRecord>,
}

/// A request sitting in the engine queue.
#[cfg(not(pjrt_backend))]
struct Queued {
    id: usize,
    arrival: Instant,
}

/// Queue state shared between the generator and the workers.
#[cfg(not(pjrt_backend))]
struct Shared {
    queue: VecDeque<Queued>,
    closed: bool,
    shed: usize,
}

#[cfg(not(pjrt_backend))]
fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best as i32
}

/// Run the engine: offered load is `opts.requests` eval-stream images (image
/// index = request id) at `opts.rate` req/s; returns per-request accounting
/// plus aggregates. The weight store may be dense, pruned, or compensated —
/// the fused fast path dispatches at whatever shapes it finds.
#[cfg(not(pjrt_backend))]
pub fn run_engine(
    exec: &Executor<'_>,
    w: &WeightStore,
    gen: &VisionGen,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    let cfg = exec.cfg;
    if cfg.kind != ModelKind::Vit {
        bail!("the serving engine drives vision workloads; got model '{}'", cfg.name);
    }
    if opts.requests == 0 {
        bail!("run_engine: requests must be > 0");
    }
    let b_art = opts.max_batch.max(1);
    let workers = opts.workers.max(1);
    let prepared = exec.prepare_forward(w, b_art)?;
    let per = cfg.patches * cfg.patch_dim;

    // Pre-generate every request's image so data synthesis never pollutes
    // the timed region (request id == eval-stream image index).
    let token_rows: Vec<Vec<f32>> = threads::parallel_map(opts.requests, |i| {
        gen.batch(Split::Eval, i as u64, 1).0.into_vec()
    });

    // Warmup dispatch (first-touch allocations, PJRT compilation when gated
    // in) before the clock starts.
    {
        let mut warm = vec![0.0f32; b_art * per];
        for (i, row) in token_rows.iter().take(b_art).enumerate() {
            warm[i * per..(i + 1) * per].copy_from_slice(row);
        }
        prepared.run_vit(&Tensor::from_vec(&[b_art, cfg.patches, cfg.patch_dim], warm))?;
    }

    // Seeded Poisson arrival offsets (seconds from engine start).
    let rate = if opts.rate.is_finite() && opts.rate > 0.0 { opts.rate } else { f64::INFINITY };
    let mut rng = Pcg64::new(opts.seed);
    let mut arrivals = Vec::with_capacity(opts.requests);
    let mut t = 0.0f64;
    for _ in 0..opts.requests {
        t += -rng.uniform().max(1e-12).ln() / rate;
        arrivals.push(t);
    }

    let shared = Mutex::new(Shared { queue: VecDeque::new(), closed: false, shed: 0 });
    let cv = Condvar::new();
    let results: Mutex<Vec<RequestRecord>> = Mutex::new(Vec::with_capacity(opts.requests));
    // Per executed batch: (requests carried, execution ms).
    let batches: Mutex<Vec<(usize, f64)>> = Mutex::new(Vec::new());
    let wait_dur = Duration::from_secs_f64(opts.max_wait.max(0.0));
    let wall0 = Instant::now();

    std::thread::scope(|s| -> Result<()> {
        // ---- open-loop generator ----
        s.spawn(|| {
            for (id, &at) in arrivals.iter().enumerate() {
                loop {
                    let now = wall0.elapsed().as_secs_f64();
                    if now >= at {
                        break;
                    }
                    std::thread::sleep(Duration::from_secs_f64((at - now).min(0.005)));
                }
                let mut g = shared.lock().unwrap();
                if g.queue.len() >= opts.queue_cap {
                    g.shed += 1;
                } else {
                    g.queue.push_back(Queued {
                        id,
                        arrival: wall0 + Duration::from_secs_f64(at),
                    });
                    cv.notify_one();
                }
            }
            shared.lock().unwrap().closed = true;
            cv.notify_all();
        });

        // ---- worker pool ----
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| -> Result<()> {
                    threads::serialize_nested_regions();
                    loop {
                        let mut batch: Vec<Queued> = Vec::with_capacity(b_art);
                        {
                            let mut g = shared.lock().unwrap();
                            // Block for the batch head (or a clean shutdown).
                            loop {
                                if let Some(q) = g.queue.pop_front() {
                                    batch.push(q);
                                    break;
                                }
                                if g.closed {
                                    return Ok(());
                                }
                                g = cv.wait(g).unwrap();
                            }
                            // Hold the batch open until full, closed, or the
                            // batching deadline expires.
                            let deadline = Instant::now() + wait_dur;
                            while batch.len() < b_art {
                                while batch.len() < b_art {
                                    match g.queue.pop_front() {
                                        Some(q) => batch.push(q),
                                        None => break,
                                    }
                                }
                                if batch.len() >= b_art || g.closed {
                                    break;
                                }
                                let now = Instant::now();
                                if now >= deadline {
                                    break;
                                }
                                let (g2, _) = cv.wait_timeout(g, deadline - now).unwrap();
                                g = g2;
                            }
                            // Hand leftover work to an idle worker: our
                            // wait_timeout may have consumed its wakeup.
                            if !g.queue.is_empty() {
                                cv.notify_one();
                            }
                        }
                        let take = batch.len();
                        let t_deq = Instant::now();
                        // Pad the partial batch to the fixed artifact batch;
                        // pad rows are zeros and their outputs are dropped.
                        let mut buf = vec![0.0f32; b_art * per];
                        for (i, q) in batch.iter().enumerate() {
                            buf[i * per..(i + 1) * per].copy_from_slice(&token_rows[q.id]);
                        }
                        let tokens =
                            Tensor::from_vec(&[b_art, cfg.patches, cfg.patch_dim], buf);
                        let logits = prepared.run_vit(&tokens)?;
                        if opts.exec_floor > 0.0 {
                            let spent = t_deq.elapsed().as_secs_f64();
                            if spent < opts.exec_floor {
                                std::thread::sleep(Duration::from_secs_f64(
                                    opts.exec_floor - spent,
                                ));
                            }
                        }
                        let t_done = Instant::now();
                        let exec_ms =
                            t_done.saturating_duration_since(t_deq).as_secs_f64() * 1e3;
                        let mut recs = results.lock().unwrap();
                        for (i, q) in batch.iter().enumerate() {
                            let row = &logits.data()[i * cfg.classes..(i + 1) * cfg.classes];
                            recs.push(RequestRecord {
                                id: q.id,
                                queue_ms: t_deq.saturating_duration_since(q.arrival).as_secs_f64()
                                    * 1e3,
                                exec_ms,
                                total_ms: t_done
                                    .saturating_duration_since(q.arrival)
                                    .as_secs_f64()
                                    * 1e3,
                                pred: argmax(row),
                            });
                        }
                        drop(recs);
                        batches.lock().unwrap().push((take, exec_ms));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("serve worker panicked")?;
        }
        Ok(())
    })?;

    let total_s = wall0.elapsed().as_secs_f64();
    let shed = shared.lock().unwrap().shed;
    let mut records = results.into_inner().unwrap();
    records.sort_by_key(|r| r.id);
    let batch_log = batches.into_inner().unwrap();

    let mut totals: Vec<f64> = records.iter().map(|r| r.total_ms).collect();
    totals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut queues: Vec<f64> = records.iter().map(|r| r.queue_ms).collect();
    queues.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n_batches = batch_log.len();
    Ok(EngineStats {
        served: records.len(),
        shed,
        batches: n_batches,
        mean_batch: if n_batches == 0 {
            0.0
        } else {
            batch_log.iter().map(|&(take, _)| take).sum::<usize>() as f64 / n_batches as f64
        },
        p50_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.50) },
        p95_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.95) },
        queue_p50_ms: if queues.is_empty() { 0.0 } else { percentile(&queues, 0.50) },
        exec_mean_ms: if n_batches == 0 {
            0.0
        } else {
            batch_log.iter().map(|&(_, ms)| ms).sum::<f64>() / n_batches as f64
        },
        throughput_fps: records.len() as f64 / total_s.max(1e-12),
        records,
    })
}

/// Deliberate compile-out for the `--cfg pjrt_backend` build: the engine
/// shares one `Runtime` across scoped worker threads, which requires the
/// backend to be `Sync`; the vendored PJRT client/executable types are not
/// known to satisfy that, so instead of a crate-wide build break the
/// gated build gets a stub that fails fast. Closed-loop [`super::measure`]
/// remains the serving measurement on that path.
#[cfg(pjrt_backend)]
pub fn run_engine(
    _exec: &Executor<'_>,
    _w: &WeightStore,
    _gen: &VisionGen,
    _opts: &EngineOpts,
) -> Result<EngineStats> {
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads); use serve::measure"
    )
}

#[cfg(all(test, not(pjrt_backend)))]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-2.0, -1.0]), 1);
    }

    #[test]
    fn default_opts_sane() {
        let o = EngineOpts::default();
        assert!(o.workers >= 1 && o.max_batch >= 1);
        assert!(o.queue_cap >= o.max_batch);
        assert!(o.max_wait >= 0.0 && o.exec_floor == 0.0);
    }
}
