//! Concurrent batched serving engine, generic over a [`Workload`].
//!
//! Queueing model (open loop): a generator thread replays a seeded Poisson
//! arrival process into a *bounded* FIFO queue; arrivals that find the queue
//! full are shed and counted (backpressure instead of unbounded buildup).
//! `workers` executor threads drain the queue: each pops a request, then
//! keeps the batch open up to `max_wait` seconds waiting for the queue to
//! yield up to `max_batch` requests *of the same fleet unit*, picks a
//! dispatch size for the (possibly partial) batch per the configured
//! [`DispatchPolicy`] — padded to the fixed artifact batch or exact at the
//! true size — and hands it to the workload, which assembles inputs and runs
//! one fused dispatch through the [`Plans`] shared by every worker.
//!
//! The engine core knows nothing about images, prompts, or decode steps:
//! request synthesis, batch input assembly, and per-request output
//! accounting live behind the [`Workload`] trait. Multi-step workloads
//! ([`super::GenWorkload`]) return [`StepOutcome::Continue`] from a step;
//! the engine then *re-enqueues* the request (keeping its original arrival
//! for latency accounting, bypassing the queue bound so an admitted request
//! is never shed mid-generation), so decode steps from different sequences
//! batch together — the continuation-re-enqueue batching model.
//!
//! [`run_fleet`] runs *N* workloads — possibly over different models —
//! through one queue and one worker pool (a mixed vision + text +
//! generation fleet). Requests are interleaved round-robin across the
//! members; workers form single-unit batches (a batch never mixes models),
//! and per-member stats come back separately. [`run_engine`] is the
//! single-member instance of the same core. Members are type-erased via
//! [`FleetMember::erased`], so a fleet is just a `Vec<ErasedMember>`.
//!
//! All time flows through the [`Clock`] trait (`serve/clock.rs`): arrival
//! generation, batching deadlines, execution timestamps, and the
//! controller's tick cadence. Production uses the wall clock; the
//! discrete-event simulator (`serve/sim.rs`) replays the same queueing
//! semantics on a virtual clock for bit-reproducible controller tests.
//!
//! With [`EngineOpts::controller`] set, a control thread wakes every tick,
//! observes queue depth / arrival rate / per-member windowed p99, and
//! adapts `max_wait`, the auto-dispatch fill threshold (from the online
//! [`CostEstimator`]), and — with `degrade` — the active plan rung of each
//! member ([`Plans::set_active`]): dense under normal load, the
//! pruned+compensated fallback under sustained pressure, and — when an
//! int8 rung is configured ([`FleetMember::with_quant_fallback`]) — the
//! weight-quantized variant as the cheapest last resort (see
//! `serve/controller.rs` for the hysteresis state machine).
//!
//! Accounting is per request: queueing delay (intended arrival → first
//! dequeue), execution time of the final step's batch, total latency,
//! time-to-first-step and mean inter-step time (for generation:
//! time-to-first-token and inter-token latency), plus the workload's
//! [`super::RequestOutput`] (prediction + token charge). Predictions are
//! returned per request so tests can assert that batching, padding vs
//! exact-size dispatch, worker count, and batch composition never change
//! *what* is computed.
//!
//! Worker threads call [`threads::serialize_nested_regions`] on entry:
//! the per-example fan-out inside the native backend runs serial on them,
//! so total parallelism equals the engine's worker count and the host is
//! never oversubscribed by nested pools.

use anyhow::{bail, Result};

use crate::exec::Executor;
use crate::model::{QuantStore, WeightStore};
use crate::serve::controller::{ControllerOpts, Transition};
use crate::serve::workload::{DispatchPolicy, Workload};

// Internals of the real (non-PJRT) engine; the `--cfg pjrt_backend` build
// compiles a stub `run_engine` instead (see below), because sharing one
// `Runtime` across worker threads requires the backend to be `Sync` and
// the vendored `xla` client/executable types are not known to be.
#[cfg(not(pjrt_backend))]
use {
    crate::exec::{KvPoolOpts, KvPoolStats},
    crate::serve::clock::{Clock, WallClock},
    crate::serve::controller::{Action, Controller, CostEstimator, MemberCfg, Obs},
    crate::serve::workload::{PlanPair, Plans, StepOutcome},
    crate::util::bench::percentile,
    crate::util::{threads, Pcg64},
    std::collections::VecDeque,
    std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering},
    std::sync::{Arc, Condvar, Mutex},
    std::time::Duration,
};

/// Serving-engine options.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// Executor threads draining the queue.
    pub workers: usize,
    /// Open-loop arrival rate, requests/sec. Non-finite or ≤ 0 means
    /// "saturated": every request is due at t = 0.
    pub rate: f64,
    /// Total requests offered to the engine ([`run_fleet`] uses the
    /// per-member counts instead).
    pub requests: usize,
    /// Maximum requests per batch; also the fixed artifact batch size that
    /// the padded dispatch path pads partial batches to.
    pub max_batch: usize,
    /// Batching deadline: how long a worker holds a non-full batch open
    /// waiting for more arrivals, seconds. With a controller this is the
    /// *base* wait the controller adapts below.
    pub max_wait: f64,
    /// Queue bound; *arrivals* beyond it are shed (counted, not served).
    /// Re-enqueued continuations of admitted requests are exempt.
    pub queue_cap: usize,
    /// Minimum per-batch execution time, seconds (0 = off). A load-shaping
    /// knob for backpressure tests and experiments: the worker sleeps out
    /// the remainder after the real forward.
    pub exec_floor: f64,
    /// Seed for the Poisson arrival process.
    pub seed: u64,
    /// Batch dispatch-shape policy (padded / exact / auto). Collapses to
    /// `Padded` on runtimes that prefer fixed shapes (gated PJRT).
    pub dispatch: DispatchPolicy,
    /// KV pool: positions per block (`0` = pool default). Decode workloads
    /// only; single-shot workloads never build a pool.
    pub kv_block: usize,
    /// KV pool capacity in blocks (`0` = unbounded). A run that outgrows
    /// the cap fails fast with a clear error instead of thrashing.
    pub kv_blocks: usize,
    /// Arrival-rate multiplier applied to the middle third of the offered
    /// schedule (`1` = flat). The load-spike scenario the controller is
    /// tested against.
    pub spike: f64,
    /// Default per-member p99 latency budget, ms (`0` = no SLO). A
    /// [`FleetMember::with_slo_p99_ms`] override wins per member.
    pub slo_p99_ms: f64,
    /// Feedback-controller configuration (`None` = static knobs, the
    /// pre-controller behavior).
    pub controller: Option<ControllerOpts>,
}

impl Default for EngineOpts {
    fn default() -> Self {
        Self {
            workers: 2,
            rate: 200.0,
            requests: 256,
            max_batch: 16,
            max_wait: 0.01,
            queue_cap: 1024,
            exec_floor: 0.0,
            seed: 7,
            dispatch: DispatchPolicy::Auto,
            kv_block: 0,
            kv_blocks: 0,
            spike: 1.0,
            slo_p99_ms: 0.0,
            controller: None,
        }
    }
}

impl EngineOpts {
    /// Reject degenerate configurations with clear errors instead of
    /// silently shedding everything (`queue_cap == 0`), spinning on empty
    /// batches (`max_batch == 0`), deadlocking (`workers == 0`), or
    /// panicking later on a non-finite `--exec-floor`.
    pub(crate) fn validate(&self) -> Result<()> {
        if self.requests == 0 {
            bail!("run_engine: requests must be > 0");
        }
        if self.max_batch == 0 {
            bail!("run_engine: max_batch must be > 0 (got 0 — no batch could ever form)");
        }
        if self.queue_cap == 0 {
            bail!("run_engine: queue_cap must be > 0 (got 0 — every arrival would be shed)");
        }
        if self.workers == 0 {
            bail!("run_engine: workers must be > 0 (got 0 — nothing would drain the queue)");
        }
        if !self.exec_floor.is_finite() || self.exec_floor < 0.0 {
            bail!(
                "run_engine: --exec-floor must be a finite number of seconds >= 0 (got {})",
                self.exec_floor
            );
        }
        if !self.spike.is_finite() || self.spike <= 0.0 {
            bail!("run_engine: --spike must be a finite rate multiplier > 0 (got {})", self.spike);
        }
        Ok(())
    }
}

#[cfg(not(pjrt_backend))]
impl EngineOpts {
    /// Pool knobs for a decode unit's plan (prefix sharing always on; the
    /// workload decides whether prompts actually share openings).
    fn kv_pool_opts(&self) -> KvPoolOpts {
        let mut o = KvPoolOpts::default();
        if self.kv_block > 0 {
            o.block = self.kv_block;
        }
        o.max_blocks = self.kv_blocks;
        o
    }
}

/// Per-request accounting (one row per *served* request).
#[derive(Debug, Clone)]
pub struct RequestRecord {
    /// Request id; doubles as the eval-stream index the workload
    /// synthesized the payload from. Ids are per fleet member.
    pub id: usize,
    /// Intended arrival → first dequeue into a batch, ms.
    pub queue_ms: f64,
    /// Execution time of the batch carrying this request's *final* step, ms.
    pub exec_ms: f64,
    /// Intended arrival → completion of the final step, ms.
    pub total_ms: f64,
    /// Engine steps (batches) this request rode in: 1 for single-shot
    /// workloads; prefill + decode continuations for generation.
    pub steps: usize,
    /// Intended arrival → end of the first step, ms (time-to-first-token
    /// for generation; == `total_ms` when `steps == 1`).
    pub first_ms: f64,
    /// Mean inter-step time, ms — `(total − first) / (steps − 1)`; 0 when
    /// `steps == 1`. For generation this is the mean inter-token time.
    pub itl_ms: f64,
    /// Workload prediction (vision: class; text: next-token id; generation:
    /// final generated token).
    pub pred: i32,
    /// Tokens charged to this request (vision: 1; text: prompt length;
    /// generation: prompt + generated).
    pub tokens: usize,
    /// Plan rung active when the request's *final* step dispatched (0 =
    /// dense). For pinned generation sequences this is the engine-level
    /// rung at that moment, which can lag the sequence's own pinned rung
    /// by one switch — an accounting approximation, not an execution one.
    pub variant: usize,
}

/// Aggregate result of one engine run (per fleet member).
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub served: usize,
    /// Requests shed at the full queue.
    pub shed: usize,
    /// Batches executed.
    pub batches: usize,
    /// Mean requests carried per executed batch.
    pub mean_batch: f64,
    /// Mean batch size actually *dispatched* (= artifact batch under the
    /// padded policy; = mean_batch under exact; in between under auto).
    pub mean_dispatch: f64,
    /// Mean engine steps per served request (1.0 for single-shot
    /// workloads; prefill + decode steps for generation).
    pub steps_mean: f64,
    /// p50 / p95 / p99 of total per-request latency, ms.
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// The member's effective p99 budget, ms (0 = none configured).
    pub slo_p99_ms: f64,
    /// p50 queueing delay, ms.
    pub queue_p50_ms: f64,
    /// p50 time to the end of a request's first step, ms (TTFT for
    /// generation workloads).
    pub first_p50_ms: f64,
    /// Mean inter-step (inter-token) time over multi-step requests, ms.
    pub itl_mean_ms: f64,
    /// Mean per-batch execution time, ms.
    pub exec_mean_ms: f64,
    /// Served requests per second of wall time.
    pub throughput_fps: f64,
    /// Served tokens per second of wall time (== throughput_fps for the
    /// vision workload, where every request is one image).
    pub throughput_tps: f64,
    /// Mean K/V bytes appended to the paged cache per KV-cache dispatch
    /// (0 for single-shot workloads and prefill-mode decode). Appends touch
    /// only the fresh rows, so this scales with tokens fed per step —
    /// independent of `n_ctx` capacity.
    pub kv_bytes_per_step: f64,
    /// High-water bytes of live KV pool blocks over the run (summed across
    /// plan rungs — each rung owns its own pool).
    pub kv_peak_bytes: u64,
    /// Pool blocks still held at the end of the run (registered shared
    /// prefixes; completed sequences release theirs as they finish).
    pub kv_blocks_in_use: usize,
    /// Cumulative KV block allocations (fresh or recycled).
    pub kv_allocs: u64,
    /// Blocks adopted from the shared-prefix registry instead of allocated
    /// and recomputed.
    pub kv_shared_hits: u64,
    /// Copy-on-write block copies (a shared tail diverged).
    pub kv_cow_copies: u64,
    /// Served requests whose final step dispatched on each plan rung
    /// (index 0 = dense). Length = the member's rung count.
    pub served_by_variant: Vec<usize>,
    /// Seconds each plan rung was the member's active rung, from the
    /// controller's transition log (everything in rung 0 without one).
    pub time_in_variant_s: Vec<f64>,
    /// This member's variant switches, in order (empty without `degrade`).
    pub transitions: Vec<Transition>,
    /// Per-request records, sorted by id.
    pub records: Vec<RequestRecord>,
}

/// A borrowed weight store of either precision, so plan ladders can mix
/// f32 rungs with int8 weight-quantized rungs (the cheapest degrade
/// target). Plan resolution picks the matching [`Executor`] builder per
/// rung: [`Executor::forward_plan`]/[`Executor::decode_plan_opts`] for
/// f32, the `_q8` twins for int8.
#[derive(Clone, Copy)]
pub enum StoreRef<'w> {
    F32(&'w WeightStore),
    Q8(&'w QuantStore),
}

/// One model + workload bound into a fleet run (see [`run_fleet`]).
pub struct FleetMember<'x, 'rt, 'w, W: Workload> {
    pub exec: &'x Executor<'rt>,
    pub weights: &'w WeightStore,
    pub workload: &'x W,
    /// Requests offered for this member ([`EngineOpts::requests`] is
    /// ignored by [`run_fleet`]).
    pub requests: usize,
    /// Per-member p99 budget, ms (`0` defers to the fleet default).
    pub slo_p99_ms: f64,
    /// Degraded-variant weight stores, cheapest last: rung 1.. of the
    /// member's plan ladder (rung 0 is `weights`). Same model config,
    /// different folded weights — pruned+compensated f32 via
    /// [`Self::with_fallback`], or int8 weight-quantized via
    /// [`Self::with_quant_fallback`].
    pub fallbacks: Vec<StoreRef<'w>>,
}

impl<'x, 'rt, 'w, W: Workload> FleetMember<'x, 'rt, 'w, W> {
    pub fn new(
        exec: &'x Executor<'rt>,
        weights: &'w WeightStore,
        workload: &'x W,
        requests: usize,
    ) -> Self {
        FleetMember { exec, weights, workload, requests, slo_p99_ms: 0.0, fallbacks: Vec::new() }
    }

    /// Set this member's p99 latency budget (ms).
    pub fn with_slo_p99_ms(mut self, slo_p99_ms: f64) -> Self {
        self.slo_p99_ms = slo_p99_ms;
        self
    }

    /// Append a degraded-variant weight store (the controller's next rung).
    pub fn with_fallback(mut self, weights: &'w WeightStore) -> Self {
        self.fallbacks.push(StoreRef::F32(weights));
        self
    }

    /// Append an int8 weight-quantized rung (typically the cheapest,
    /// appended last so the controller degrades to it only under the most
    /// sustained pressure).
    pub fn with_quant_fallback(mut self, quant: &'w QuantStore) -> Self {
        self.fallbacks.push(StoreRef::Q8(quant));
        self
    }

    /// Type-erase the member so fleets of mixed workload types fit one
    /// `Vec` (see [`run_fleet`]). Plan building is deferred into the
    /// erased closure so it happens inside the fleet run, with the fleet's
    /// resolved options.
    pub fn erased<'e>(self) -> ErasedMember<'e>
    where
        'x: 'e,
        'rt: 'e,
        'w: 'e,
    {
        #[cfg(not(pjrt_backend))]
        {
            let FleetMember { exec, weights, workload, requests, slo_p99_ms, fallbacks } = self;
            ErasedMember {
                requests,
                mk: Box::new(move |opts: &EngineOpts| {
                    let policy = opts.dispatch.resolve(exec.rt.prefers_fixed_shapes());
                    let mut stores: Vec<StoreRef<'e>> = Vec::with_capacity(1 + fallbacks.len());
                    stores.push(StoreRef::F32(weights));
                    for &f in fallbacks.iter() {
                        stores.push(f);
                    }
                    make_unit(
                        exec,
                        &stores,
                        workload,
                        requests,
                        opts.max_batch,
                        policy,
                        opts.kv_pool_opts(),
                        slo_p99_ms,
                    )
                }),
            }
        }
        #[cfg(pjrt_backend)]
        {
            ErasedMember { requests: self.requests, _marker: std::marker::PhantomData }
        }
    }
}

/// A type-erased fleet member: request count plus a deferred unit builder.
/// Built via [`FleetMember::erased`].
pub struct ErasedMember<'e> {
    pub(crate) requests: usize,
    #[cfg(not(pjrt_backend))]
    #[allow(clippy::type_complexity)]
    pub(crate) mk: Box<dyn FnOnce(&EngineOpts) -> Result<Unit<'e>> + 'e>,
    #[cfg(pjrt_backend)]
    pub(crate) _marker: std::marker::PhantomData<&'e ()>,
}

/// A request (or a re-enqueued continuation) sitting in the engine queue.
/// Timestamps are engine-clock seconds (see [`Clock`]).
#[cfg(not(pjrt_backend))]
pub(crate) struct Queued {
    pub(crate) unit: usize,
    pub(crate) id: usize,
    pub(crate) arrival: f64,
    /// Steps completed so far.
    pub(crate) steps: usize,
    pub(crate) first_deq: Option<f64>,
    pub(crate) first_done: Option<f64>,
}

/// Queue state shared between the generator and the workers.
#[cfg(not(pjrt_backend))]
struct Shared {
    queue: VecDeque<Queued>,
    closed: bool,
    /// Shed arrivals, per fleet unit.
    shed: Vec<usize>,
}

/// Aggregated KV-cache telemetry for one unit, summed over its plan rungs
/// (each rung owns its own pool; peaks are summed as an upper bound on
/// simultaneous residency).
#[cfg(not(pjrt_backend))]
#[derive(Default, Clone, Copy)]
pub(crate) struct KvAgg {
    pub(crate) steps: u64,
    pub(crate) bytes: u64,
    pub(crate) peak_bytes: u64,
    pub(crate) blocks_in_use: usize,
    pub(crate) allocs: u64,
    pub(crate) shared_hits: u64,
    pub(crate) cow_copies: u64,
}

/// A type-erased fleet unit: the workload, its resolved plan ladder, and
/// its pre-synthesized payloads, closed over a step function so units with
/// different `Workload::Req` types share one queue and one worker pool.
#[cfg(not(pjrt_backend))]
pub(crate) struct Unit<'s> {
    pub(crate) label: &'static str,
    pub(crate) requests: usize,
    pub(crate) policy: DispatchPolicy,
    /// This member's p99 budget (ms; 0 = defer to the fleet default).
    pub(crate) slo_p99_ms: f64,
    /// The plan ladder every step dispatches through; the controller flips
    /// the active rung between batches.
    pub(crate) plans: Arc<Plans<'s, 's>>,
    #[allow(clippy::type_complexity)]
    pub(crate) step: Box<dyn Fn(&[usize], usize) -> Result<Vec<StepOutcome>> + Sync + 's>,
    /// KV-cache telemetry snapshot; `None` for units without decode plans.
    #[allow(clippy::type_complexity)]
    pub(crate) kv: Box<dyn Fn() -> Option<KvAgg> + Sync + 's>,
}

/// Build one unit: resolve one plan rung per weight store (rung 0 = the
/// primary, usually dense, store), pre-synthesize every payload (request
/// id == eval-stream index, so data synthesis never pollutes the timed
/// region), and warm every rung's dispatch path before the clock starts.
#[cfg(not(pjrt_backend))]
#[allow(clippy::too_many_arguments)]
pub(crate) fn make_unit<'s, W: Workload>(
    exec: &Executor<'s>,
    stores: &[StoreRef<'s>],
    workload: &'s W,
    requests: usize,
    max_batch: usize,
    policy: DispatchPolicy,
    kv_opts: KvPoolOpts,
    slo_p99_ms: f64,
) -> Result<Unit<'s>> {
    let cfg = exec.cfg;
    if workload.cfg() != cfg {
        bail!(
            "workload '{}' drives model '{}', executor is bound to '{}'",
            workload.label(),
            workload.cfg().name,
            cfg.name
        );
    }
    if stores.is_empty() {
        bail!("make_unit: a member needs at least one weight store");
    }
    // Resolve exactly the plan the workload dispatches through: decode
    // workloads never touch the full-forward plan (the decode plan owns its
    // own prefill fallback), and resolving both would shape-check every
    // parameter twice and warm names that are never dispatched. One rung
    // per store; plans are shared (`Arc`) between the step closure, the
    // telemetry closure, and the engine (for controller rung switches).
    let mut pairs: Vec<PlanPair<'s, 's>> = Vec::with_capacity(stores.len());
    for &store in stores {
        pairs.push(match (workload.decode(), store) {
            (Some(mode), StoreRef::F32(w)) => PlanPair {
                fwd: None,
                dec: Some(exec.decode_plan_opts(
                    w,
                    mode.resolve(exec.rt.prefers_fixed_shapes()),
                    kv_opts,
                )?),
            },
            (Some(mode), StoreRef::Q8(qs)) => PlanPair {
                fwd: None,
                dec: Some(exec.decode_plan_opts_q8(
                    qs,
                    mode.resolve(exec.rt.prefers_fixed_shapes()),
                    kv_opts,
                )?),
            },
            (None, StoreRef::F32(w)) => PlanPair { fwd: Some(exec.forward_plan(w)?), dec: None },
            (None, StoreRef::Q8(qs)) => {
                PlanPair { fwd: Some(exec.forward_plan_q8(qs)?), dec: None }
            }
        });
    }
    let plans = Arc::new(Plans::ladder(pairs)?);
    let payloads: Vec<W::Req> = threads::parallel_map(requests, |i| workload.synth(i));

    // Warmup before the clock starts, once per rung: run the full artifact
    // batch AND batch size 1 (first-touch allocation, PJRT compilation when
    // gated in), and under exact/auto dispatch pre-populate the rung's
    // artifact-name caches for every size a batch could dispatch at — so
    // no batch pays first-use name formatting inside its timed region, and
    // a controller rung switch never pays cold-plan costs mid-run. Warm
    // payloads are synthesized *past* the request id range (fresh per
    // rung): multi-step workloads carry per-request state, and warmup must
    // never pre-advance a real request.
    for v in 0..plans.variants() {
        plans.set_active(v);
        let warm: Vec<W::Req> = (0..max_batch + 1).map(|i| workload.synth(requests + i)).collect();
        let refs: Vec<&W::Req> = warm.iter().take(max_batch).collect();
        workload.run_step(&plans, &refs, max_batch)?;
        let pair = plans.pair(v);
        if policy != DispatchPolicy::Padded {
            workload.run_step(&plans, &[&warm[max_batch]], 1)?;
            for b in 1..=max_batch {
                if let Some(f) = &pair.fwd {
                    f.artifact(b);
                }
                if let Some(d) = &pair.dec {
                    d.warm_names(b);
                }
            }
        } else if let Some(d) = &pair.dec {
            d.warm_names(max_batch);
        }
    }
    plans.set_active(0);

    // Baseline counters after warmup, per rung, so per-step means cover
    // only the measured run (pool-level stats like peak blocks keep warmup
    // — the registry it warmed stays live).
    let kv0: Vec<(u64, u64)> = (0..plans.variants())
        .map(|v| plans.pair(v).dec.as_ref().map(|d| d.kv_counters()).unwrap_or((0, 0)))
        .collect();
    let step_plans = plans.clone();
    let kv_plans = plans.clone();
    Ok(Unit {
        label: workload.label(),
        requests,
        policy,
        slo_p99_ms,
        plans,
        step: Box::new(move |ids: &[usize], dispatch: usize| {
            let reqs: Vec<&W::Req> = ids.iter().map(|&i| &payloads[i]).collect();
            workload.run_step(&step_plans, &reqs, dispatch)
        }),
        kv: Box::new(move || {
            let mut agg = KvAgg::default();
            let mut any = false;
            for v in 0..kv_plans.variants() {
                if let Some(d) = kv_plans.pair(v).dec.as_ref() {
                    any = true;
                    let (s, b) = d.kv_counters();
                    agg.steps += s - kv0[v].0;
                    agg.bytes += b - kv0[v].1;
                    let p = d.pool_stats().unwrap_or_default();
                    agg.peak_bytes += p.peak_bytes();
                    agg.blocks_in_use += p.blocks_in_use;
                    agg.allocs += p.allocs;
                    agg.shared_hits += p.shared_hits;
                    agg.cow_copies += p.cow_copies;
                }
            }
            any.then_some(agg)
        }),
    })
}

/// Run the engine: offered load is `opts.requests` workload-synthesized
/// requests (request id == eval-stream index) at `opts.rate` req/s; returns
/// per-request accounting plus aggregates. The weight store may be dense,
/// pruned, or compensated — the batch-polymorphic plans dispatch at
/// whatever shapes they find, and the workload decides what a request *is*
/// (including multi-step generation via re-enqueued continuations).
#[cfg(not(pjrt_backend))]
pub fn run_engine<W: Workload>(
    exec: &Executor<'_>,
    w: &WeightStore,
    workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    opts.validate()?;
    run_engine_on(exec, StoreRef::F32(w), workload, opts)
}

/// [`run_engine`] over an int8 weight-quantized store: every weight GEMM
/// dispatches through the quantized `_w8` plan rung. Predictions track the
/// f32 run to quantization tolerance (pinned by `tests/quant_equality`);
/// batching, shedding, and accounting semantics are identical.
#[cfg(not(pjrt_backend))]
pub fn run_engine_q8<W: Workload>(
    exec: &Executor<'_>,
    qs: &QuantStore,
    workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    opts.validate()?;
    run_engine_on(exec, StoreRef::Q8(qs), workload, opts)
}

#[cfg(not(pjrt_backend))]
fn run_engine_on<W: Workload>(
    exec: &Executor<'_>,
    store: StoreRef<'_>,
    workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    let policy = opts.dispatch.resolve(exec.rt.prefers_fixed_shapes());
    let unit = make_unit(
        exec,
        &[store],
        workload,
        opts.requests,
        opts.max_batch,
        policy,
        opts.kv_pool_opts(),
        opts.slo_p99_ms,
    )?;
    let mut stats = run_units(vec![unit], opts)?;
    Ok(stats.remove(0))
}

/// Run N workloads — possibly over different models — through one queue
/// and one worker pool: a mixed fleet. Member arrivals interleave
/// round-robin (m0.0, m1.0, …, m0.1, m1.1, …) on one seeded Poisson
/// schedule; workers form single-unit batches, so a dispatch never mixes
/// models. Returns per-member stats in argument order. Per-example math
/// makes each member's outputs identical to a single-workload
/// [`run_engine`] run with the same seeds — asserted by
/// `tests/serve_engine`.
#[cfg(not(pjrt_backend))]
pub fn run_fleet(members: Vec<ErasedMember<'_>>, opts: &EngineOpts) -> Result<Vec<EngineStats>> {
    if members.is_empty() {
        bail!("run_fleet: the fleet needs at least one member");
    }
    if members.iter().any(|m| m.requests == 0) {
        bail!("run_fleet: every member needs at least one request");
    }
    let total: usize = members.iter().map(|m| m.requests).sum();
    EngineOpts { requests: total, ..opts.clone() }.validate()?;
    let mut units = Vec::with_capacity(members.len());
    for m in members {
        units.push((m.mk)(opts)?);
    }
    run_units(units, opts)
}

/// Seeded arrival schedule shared by the threaded engine and the
/// simulator: Poisson offsets (seconds from engine start) at `rate`, with
/// the middle third of the schedule offered at `rate * spike`.
#[cfg(not(pjrt_backend))]
pub(crate) fn arrival_times(total: usize, rate: f64, spike: f64, seed: u64) -> Vec<f64> {
    let rate = if rate.is_finite() && rate > 0.0 { rate } else { f64::INFINITY };
    let spike = if spike.is_finite() && spike > 0.0 { spike } else { 1.0 };
    let (lo, hi) = (total / 3, total - total / 3);
    let mut rng = Pcg64::new(seed);
    let mut out = Vec::with_capacity(total);
    let mut t = 0.0f64;
    for i in 0..total {
        let r = if i >= lo && i < hi { rate * spike } else { rate };
        t += -rng.uniform().max(1e-12).ln() / r;
        out.push(t);
    }
    out
}

/// Deterministic round-robin interleave of unit arrivals: (unit, id) pairs
/// in offered order, independent of timing.
#[cfg(not(pjrt_backend))]
pub(crate) fn arrival_order(units: &[Unit<'_>]) -> Vec<(usize, usize)> {
    let total: usize = units.iter().map(|u| u.requests).sum();
    let mut order: Vec<(usize, usize)> = Vec::with_capacity(total);
    let mut issued = vec![0usize; units.len()];
    while order.len() < total {
        for (u, unit) in units.iter().enumerate() {
            if issued[u] < unit.requests {
                order.push((u, issued[u]));
                issued[u] += 1;
            }
        }
    }
    order
}

/// Controller state shared between the worker pool and the control thread.
#[cfg(not(pjrt_backend))]
struct Ctl {
    /// Adapted batch-formation deadline, seconds (f64 bits).
    max_wait_bits: AtomicU64,
    /// Adapted auto-dispatch fill threshold in `[0, 1]` (f64 bits).
    thresh_bits: AtomicU64,
    /// Online per-dispatch-size cost curve, fed by the workers.
    est: Mutex<CostEstimator>,
    /// Windowed per-member completion latencies (ms), drained every tick.
    lat: Mutex<Vec<Vec<f64>>>,
    /// Cumulative offered arrivals (shed ones included).
    arrivals: AtomicUsize,
    done: AtomicBool,
}

/// The shared queueing/batching core: one generator, one bounded queue,
/// one worker pool over any number of type-erased units, plus (when
/// configured) one control thread — all timed by `clock`.
#[cfg(not(pjrt_backend))]
fn run_units(units: Vec<Unit<'_>>, opts: &EngineOpts) -> Result<Vec<EngineStats>> {
    run_units_on(units, opts, &WallClock::new())
}

#[cfg(not(pjrt_backend))]
fn run_units_on(
    units: Vec<Unit<'_>>,
    opts: &EngineOpts,
    clock: &dyn Clock,
) -> Result<Vec<EngineStats>> {
    let b_art = opts.max_batch;
    let workers = opts.workers;
    let base_wait = opts.max_wait.max(0.0);

    let order = arrival_order(&units);
    let arrivals = arrival_times(order.len(), opts.rate, opts.spike, opts.seed);

    let shared =
        Mutex::new(Shared { queue: VecDeque::new(), closed: false, shed: vec![0; units.len()] });
    let cv = Condvar::new();
    let results: Mutex<Vec<Vec<RequestRecord>>> = Mutex::new(vec![Vec::new(); units.len()]);
    // Per executed batch: (unit, requests carried, dispatch size, exec ms,
    // active plan rung).
    let batches: Mutex<Vec<(usize, usize, usize, f64, usize)>> = Mutex::new(Vec::new());
    let ctl = opts.controller.as_ref().map(|_| Ctl {
        max_wait_bits: AtomicU64::new(base_wait.to_bits()),
        thresh_bits: AtomicU64::new(DispatchPolicy::AUTO_FILL_THRESHOLD.to_bits()),
        est: Mutex::new(CostEstimator::new(b_art)),
        lat: Mutex::new(vec![Vec::new(); units.len()]),
        arrivals: AtomicUsize::new(0),
        done: AtomicBool::new(false),
    });

    let transitions = std::thread::scope(|s| -> Result<Vec<Transition>> {
        // ---- open-loop generator ----
        s.spawn(|| {
            'replay: for (&(unit, id), &at) in order.iter().zip(&arrivals) {
                loop {
                    // A failed worker poisons the run by setting `closed`;
                    // stop replaying the schedule so the error surfaces
                    // promptly instead of after the full arrival tail.
                    if shared.lock().unwrap().closed {
                        break 'replay;
                    }
                    let now = clock.now();
                    if now >= at {
                        break;
                    }
                    clock.sleep((at - now).min(0.005));
                }
                if let Some(c) = &ctl {
                    c.arrivals.fetch_add(1, Ordering::AcqRel);
                }
                let mut g = shared.lock().unwrap();
                if g.closed {
                    break 'replay;
                }
                if g.queue.len() >= opts.queue_cap {
                    g.shed[unit] += 1;
                } else {
                    g.queue.push_back(Queued {
                        unit,
                        id,
                        arrival: at,
                        steps: 0,
                        first_deq: None,
                        first_done: None,
                    });
                    cv.notify_one();
                }
            }
            shared.lock().unwrap().closed = true;
            cv.notify_all();
        });

        // ---- control thread ----
        let ctl_handle = ctl.as_ref().map(|c| {
            let copts = opts.controller.clone().expect("ctl implies controller opts");
            let members: Vec<MemberCfg> = units
                .iter()
                .map(|u| MemberCfg {
                    slo_p99_ms: if u.slo_p99_ms > 0.0 { u.slo_p99_ms } else { copts.slo_p99_ms },
                    variants: u.plans.variants(),
                })
                .collect();
            let units = &units;
            let shared = &shared;
            s.spawn(move || -> Vec<Transition> {
                let mut controller = Controller::new(copts.clone(), base_wait, b_art, &members);
                let mut prev_arrivals = 0usize;
                loop {
                    clock.sleep(copts.tick_s.max(1e-4));
                    if c.done.load(Ordering::Acquire) {
                        break;
                    }
                    let t = clock.now();
                    let queue_frac = shared.lock().unwrap().queue.len() as f64
                        / opts.queue_cap.max(1) as f64;
                    let arr = c.arrivals.load(Ordering::Acquire);
                    let arrival_rate =
                        (arr - prev_arrivals) as f64 / copts.tick_s.max(1e-4);
                    prev_arrivals = arr;
                    let p99: Vec<Option<f64>> = {
                        let mut lat = c.lat.lock().unwrap();
                        lat.iter_mut()
                            .map(|w| {
                                if w.is_empty() {
                                    None
                                } else {
                                    w.sort_by(|a, b| a.total_cmp(b));
                                    let p = percentile(w, 0.99);
                                    w.clear();
                                    Some(p)
                                }
                            })
                            .collect()
                    };
                    let est = c.est.lock().unwrap().clone();
                    let actions = controller.tick(
                        &Obs { t, queue_frac, arrival_rate, p99_ms: &p99 },
                        &est,
                    );
                    for a in actions {
                        match a {
                            Action::MaxWait(w) => {
                                c.max_wait_bits.store(w.to_bits(), Ordering::Release)
                            }
                            Action::FillThreshold(th) => {
                                c.thresh_bits.store(th.to_bits(), Ordering::Release)
                            }
                            Action::Variant { member, variant } => {
                                units[member].plans.set_active(variant)
                            }
                        }
                    }
                }
                controller.transitions().to_vec()
            })
        });

        // ---- worker pool ----
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| -> Result<()> {
                    threads::serialize_nested_regions();
                    loop {
                        let mut batch: Vec<Queued> = Vec::with_capacity(b_art);
                        {
                            let mut g = shared.lock().unwrap();
                            // Block for the batch head (or a clean shutdown).
                            loop {
                                if let Some(q) = g.queue.pop_front() {
                                    batch.push(q);
                                    break;
                                }
                                if g.closed {
                                    return Ok(());
                                }
                                g = cv.wait(g).unwrap();
                            }
                            // Hold the batch open until full, closed, or the
                            // batching deadline expires — draining only
                            // requests of the head's unit (a batch never
                            // mixes models). The deadline comes from the
                            // controller when one is running.
                            let unit = batch[0].unit;
                            let wait_s = match &ctl {
                                Some(c) => {
                                    f64::from_bits(c.max_wait_bits.load(Ordering::Acquire))
                                }
                                None => base_wait,
                            };
                            let deadline = clock.now() + wait_s.max(0.0);
                            loop {
                                let mut i = 0;
                                while batch.len() < b_art && i < g.queue.len() {
                                    if g.queue[i].unit == unit {
                                        batch.push(g.queue.remove(i).expect("indexed item"));
                                    } else {
                                        i += 1;
                                    }
                                }
                                if batch.len() >= b_art || g.closed {
                                    break;
                                }
                                let now = clock.now();
                                if now >= deadline {
                                    break;
                                }
                                let (g2, _) = cv
                                    .wait_timeout(
                                        g,
                                        Duration::from_secs_f64((deadline - now).max(0.0)),
                                    )
                                    .unwrap();
                                g = g2;
                            }
                            // Hand leftover work to an idle worker: our
                            // wait_timeout may have consumed its wakeup.
                            if !g.queue.is_empty() {
                                cv.notify_one();
                            }
                        }
                        let unit = batch[0].unit;
                        let take = batch.len();
                        // Dispatch shape: the learned cost curve replaces the
                        // static fill threshold under `auto` once a
                        // controller is running.
                        let dispatch = match &ctl {
                            Some(c) if units[unit].policy == DispatchPolicy::Auto => {
                                let th = f64::from_bits(c.thresh_bits.load(Ordering::Acquire));
                                if (take as f64) < th * b_art as f64 {
                                    take
                                } else {
                                    b_art
                                }
                            }
                            _ => units[unit].policy.dispatch_size(take, b_art),
                        };
                        let variant = units[unit].plans.active();
                        let t_deq = clock.now();
                        for q in batch.iter_mut() {
                            if q.first_deq.is_none() {
                                q.first_deq = Some(t_deq);
                            }
                        }
                        let ids: Vec<usize> = batch.iter().map(|q| q.id).collect();
                        // On any workload failure, poison the run (`closed`
                        // stops the generator's replay and drains the other
                        // workers) so the error surfaces promptly instead
                        // of after the full arrival schedule.
                        let poison = || {
                            shared.lock().unwrap().closed = true;
                            cv.notify_all();
                        };
                        let outs: Vec<StepOutcome> = match (units[unit].step)(&ids, dispatch) {
                            Ok(outs) => outs,
                            Err(e) => {
                                poison();
                                return Err(e);
                            }
                        };
                        if outs.len() != batch.len() {
                            // Fail fast on a broken Workload impl rather
                            // than silently dropping records (served + shed
                            // == requests must hold per unit).
                            poison();
                            bail!(
                                "workload '{}' returned {} outcomes for a batch of {}",
                                units[unit].label,
                                outs.len(),
                                batch.len()
                            );
                        }
                        if opts.exec_floor > 0.0 {
                            let spent = clock.now() - t_deq;
                            if spent < opts.exec_floor {
                                clock.sleep(opts.exec_floor - spent);
                            }
                        }
                        let t_done = clock.now();
                        let exec_s = (t_done - t_deq).max(0.0);
                        let exec_ms = exec_s * 1e3;
                        if let Some(c) = &ctl {
                            c.est.lock().unwrap().observe(dispatch, exec_s);
                        }
                        let mut requeue: Vec<Queued> = Vec::new();
                        {
                            let mut recs = results.lock().unwrap();
                            for (mut q, out) in batch.into_iter().zip(outs) {
                                q.steps += 1;
                                if q.first_done.is_none() {
                                    q.first_done = Some(t_done);
                                }
                                match out {
                                    StepOutcome::Done(o) => {
                                        let first = q.first_done.expect("set above");
                                        let first_ms = (first - q.arrival).max(0.0) * 1e3;
                                        let total_ms = (t_done - q.arrival).max(0.0) * 1e3;
                                        if let Some(c) = &ctl {
                                            c.lat.lock().unwrap()[q.unit].push(total_ms);
                                        }
                                        recs[q.unit].push(RequestRecord {
                                            id: q.id,
                                            queue_ms: (q.first_deq.expect("set above")
                                                - q.arrival)
                                                .max(0.0)
                                                * 1e3,
                                            exec_ms,
                                            total_ms,
                                            steps: q.steps,
                                            first_ms,
                                            itl_ms: if q.steps > 1 {
                                                (total_ms - first_ms) / (q.steps - 1) as f64
                                            } else {
                                                0.0
                                            },
                                            pred: o.pred,
                                            tokens: o.tokens,
                                            variant,
                                        });
                                    }
                                    StepOutcome::Continue => requeue.push(q),
                                }
                            }
                        }
                        batches.lock().unwrap().push((unit, take, dispatch, exec_ms, variant));
                        if !requeue.is_empty() {
                            // Continuations of admitted requests bypass the
                            // queue bound: shedding one mid-generation would
                            // strand its state and break served + shed
                            // accounting.
                            let mut g = shared.lock().unwrap();
                            for q in requeue {
                                g.queue.push_back(q);
                            }
                            cv.notify_one();
                        }
                    }
                })
            })
            .collect();
        // Join workers first, then release the control thread — even when
        // a worker failed, so the scope never deadlocks on the ticker.
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            if let Err(e) = h.join().expect("serve worker panicked") {
                worker_err.get_or_insert(e);
            }
        }
        if let Some(c) = &ctl {
            c.done.store(true, Ordering::Release);
        }
        let transitions = match ctl_handle {
            Some(h) => h.join().expect("serve controller panicked"),
            None => Vec::new(),
        };
        match worker_err {
            Some(e) => Err(e),
            None => Ok(transitions),
        }
    })?;

    let total_s = clock.now();
    let shed = std::mem::take(&mut shared.lock().unwrap().shed);
    let per_unit = results.into_inner().unwrap();
    let batch_log = batches.into_inner().unwrap();
    let slo_default = opts.controller.as_ref().map(|c| c.slo_p99_ms).unwrap_or(opts.slo_p99_ms);
    Ok(finalize_stats(&units, per_unit, shed, &batch_log, &transitions, total_s, slo_default))
}

/// Aggregate per-unit records + the batch log into [`EngineStats`] — the
/// one accounting path shared by the threaded engine and the simulator.
#[cfg(not(pjrt_backend))]
pub(crate) fn finalize_stats(
    units: &[Unit<'_>],
    per_unit: Vec<Vec<RequestRecord>>,
    shed: Vec<usize>,
    batch_log: &[(usize, usize, usize, f64, usize)],
    transitions: &[Transition],
    total_s: f64,
    slo_default: f64,
) -> Vec<EngineStats> {
    let mut out = Vec::with_capacity(units.len());
    for (u, mut records) in per_unit.into_iter().enumerate() {
        records.sort_by_key(|r| r.id);
        let mut totals: Vec<f64> = records.iter().map(|r| r.total_ms).collect();
        totals.sort_by(|a, b| a.total_cmp(b));
        let mut queues: Vec<f64> = records.iter().map(|r| r.queue_ms).collect();
        queues.sort_by(|a, b| a.total_cmp(b));
        let mut firsts: Vec<f64> = records.iter().map(|r| r.first_ms).collect();
        firsts.sort_by(|a, b| a.total_cmp(b));
        let multi: Vec<&RequestRecord> = records.iter().filter(|r| r.steps > 1).collect();
        let ub: Vec<&(usize, usize, usize, f64, usize)> =
            batch_log.iter().filter(|&&(bu, ..)| bu == u).collect();
        let n_batches = ub.len();
        let tokens: usize = records.iter().map(|r| r.tokens).sum();
        let kv = (units[u].kv)().unwrap_or_default();
        let variants = units[u].plans.variants();
        let mut served_by_variant = vec![0usize; variants];
        for r in &records {
            served_by_variant[r.variant.min(variants - 1)] += 1;
        }
        let my_transitions: Vec<Transition> =
            transitions.iter().filter(|t| t.member == u).copied().collect();
        let mut time_in_variant_s = vec![0.0f64; variants];
        {
            let (mut cur, mut t0) = (0usize, 0.0f64);
            for tr in &my_transitions {
                let t = tr.t.clamp(0.0, total_s);
                time_in_variant_s[cur.min(variants - 1)] += (t - t0).max(0.0);
                cur = tr.to;
                t0 = t;
            }
            time_in_variant_s[cur.min(variants - 1)] += (total_s - t0).max(0.0);
        }
        out.push(EngineStats {
            served: records.len(),
            shed: shed[u],
            batches: n_batches,
            mean_batch: if n_batches == 0 {
                0.0
            } else {
                ub.iter().map(|&&(_, take, ..)| take).sum::<usize>() as f64 / n_batches as f64
            },
            mean_dispatch: if n_batches == 0 {
                0.0
            } else {
                ub.iter().map(|&&(_, _, d, ..)| d).sum::<usize>() as f64 / n_batches as f64
            },
            steps_mean: if records.is_empty() {
                0.0
            } else {
                records.iter().map(|r| r.steps).sum::<usize>() as f64 / records.len() as f64
            },
            p50_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.50) },
            p95_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.95) },
            p99_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.99) },
            slo_p99_ms: if units[u].slo_p99_ms > 0.0 { units[u].slo_p99_ms } else { slo_default },
            queue_p50_ms: if queues.is_empty() { 0.0 } else { percentile(&queues, 0.50) },
            first_p50_ms: if firsts.is_empty() { 0.0 } else { percentile(&firsts, 0.50) },
            itl_mean_ms: if multi.is_empty() {
                0.0
            } else {
                multi.iter().map(|r| r.itl_ms).sum::<f64>() / multi.len() as f64
            },
            exec_mean_ms: if n_batches == 0 {
                0.0
            } else {
                ub.iter().map(|&&(_, _, _, ms, _)| ms).sum::<f64>() / n_batches as f64
            },
            throughput_fps: records.len() as f64 / total_s.max(1e-12),
            throughput_tps: tokens as f64 / total_s.max(1e-12),
            kv_bytes_per_step: if kv.steps == 0 { 0.0 } else { kv.bytes as f64 / kv.steps as f64 },
            kv_peak_bytes: kv.peak_bytes,
            kv_blocks_in_use: kv.blocks_in_use,
            kv_allocs: kv.allocs,
            kv_shared_hits: kv.shared_hits,
            kv_cow_copies: kv.cow_copies,
            served_by_variant,
            time_in_variant_s,
            transitions: my_transitions,
            records,
        });
    }
    out
}

/// Deliberate compile-out for the `--cfg pjrt_backend` build: the engine
/// shares one `Runtime` across scoped worker threads, which requires the
/// backend to be `Sync`; the vendored PJRT client/executable types are not
/// known to satisfy that, so instead of a crate-wide build break the
/// gated build gets a stub that fails fast. Closed-loop [`super::measure`]
/// remains the serving measurement on that path (and keeps the padded
/// fixed-shape dispatch — see [`DispatchPolicy::resolve`]).
#[cfg(pjrt_backend)]
pub fn run_engine<W: Workload>(
    _exec: &Executor<'_>,
    _w: &WeightStore,
    _workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    opts.validate()?;
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads); use serve::measure"
    )
}

/// Stub mirror of [`run_engine_q8`] for the gated build; int8 weights are
/// additionally a native-interpreter feature, so there is nothing for PJRT
/// to dispatch even single-threaded.
#[cfg(pjrt_backend)]
pub fn run_engine_q8<W: Workload>(
    _exec: &Executor<'_>,
    _qs: &QuantStore,
    _workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    opts.validate()?;
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads, and int8 weights are \
         native-only); use serve::measure"
    )
}

/// Stub mirror of the fleet entry point for the gated build (see
/// [`run_engine`] above). Configuration errors still surface as errors —
/// never as panics — so a user-settable knob like `--exec-floor` fails the
/// same way on both builds.
#[cfg(pjrt_backend)]
pub fn run_fleet(members: Vec<ErasedMember<'_>>, opts: &EngineOpts) -> Result<Vec<EngineStats>> {
    if members.is_empty() {
        bail!("run_fleet: the fleet needs at least one member");
    }
    if members.iter().any(|m| m.requests == 0) {
        bail!("run_fleet: every member needs at least one request");
    }
    let total: usize = members.iter().map(|m| m.requests).sum();
    EngineOpts { requests: total, ..opts.clone() }.validate()?;
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads); use serve::measure"
    )
}

#[cfg(all(test, not(pjrt_backend)))]
mod tests {
    use super::*;

    #[test]
    fn default_opts_sane() {
        let o = EngineOpts::default();
        assert!(o.workers >= 1 && o.max_batch >= 1);
        assert!(o.queue_cap >= o.max_batch);
        assert!(o.max_wait >= 0.0 && o.exec_floor == 0.0);
        assert_eq!(o.dispatch, DispatchPolicy::Auto);
        assert_eq!(o.spike, 1.0);
        assert!(o.controller.is_none());
        assert!(o.validate().is_ok());
    }

    #[test]
    fn degenerate_opts_rejected() {
        for (opts, needle) in [
            (EngineOpts { requests: 0, ..Default::default() }, "requests"),
            (EngineOpts { max_batch: 0, ..Default::default() }, "max_batch"),
            (EngineOpts { queue_cap: 0, ..Default::default() }, "queue_cap"),
            (EngineOpts { workers: 0, ..Default::default() }, "workers"),
            // Regression: a bad --exec-floor used to *panic* in an assert;
            // it must be a plain error naming the flag.
            (EngineOpts { exec_floor: -1.0, ..Default::default() }, "--exec-floor"),
            (EngineOpts { exec_floor: f64::NAN, ..Default::default() }, "--exec-floor"),
            (EngineOpts { spike: 0.0, ..Default::default() }, "--spike"),
            (EngineOpts { spike: f64::INFINITY, ..Default::default() }, "--spike"),
        ] {
            let err = opts.validate().unwrap_err().to_string();
            assert!(err.contains(needle), "{err}");
        }
    }

    #[test]
    fn arrival_times_spike_compresses_middle_third() {
        let flat = arrival_times(90, 100.0, 1.0, 42);
        let spiked = arrival_times(90, 100.0, 3.0, 42);
        assert_eq!(flat.len(), 90);
        // Same RNG stream: the first third is identical, the spiked middle
        // third accumulates 3x slower, and every sequence is increasing.
        for i in 0..30 {
            assert!((flat[i] - spiked[i]).abs() < 1e-12);
        }
        let flat_mid = flat[59] - flat[30];
        let spiked_mid = spiked[59] - spiked[30];
        assert!((spiked_mid - flat_mid / 3.0).abs() < 1e-9, "{spiked_mid} vs {flat_mid}");
        for w in spiked.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // Saturated rate still yields an all-zero schedule.
        assert!(arrival_times(8, 0.0, 3.0, 1).iter().all(|&t| t == 0.0));
    }
}
