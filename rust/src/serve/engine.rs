//! Concurrent batched serving engine, generic over a [`Workload`].
//!
//! Queueing model (open loop): a generator thread replays a seeded Poisson
//! arrival process into a *bounded* FIFO queue; arrivals that find the queue
//! full are shed and counted (backpressure instead of unbounded buildup).
//! `workers` executor threads drain the queue: each pops a request, then
//! keeps the batch open up to `max_wait` seconds waiting for the queue to
//! yield up to `max_batch` requests *of the same fleet unit*, picks a
//! dispatch size for the (possibly partial) batch per the configured
//! [`DispatchPolicy`] — padded to the fixed artifact batch or exact at the
//! true size — and hands it to the workload, which assembles inputs and runs
//! one fused dispatch through the [`Plans`] shared by every worker.
//!
//! The engine core knows nothing about images, prompts, or decode steps:
//! request synthesis, batch input assembly, and per-request output
//! accounting live behind the [`Workload`] trait. Multi-step workloads
//! ([`super::GenWorkload`]) return [`StepOutcome::Continue`] from a step;
//! the engine then *re-enqueues* the request (keeping its original arrival
//! for latency accounting, bypassing the queue bound so an admitted request
//! is never shed mid-generation), so decode steps from different sequences
//! batch together — the continuation-re-enqueue batching model.
//!
//! [`run_fleet`] runs *N* workloads — possibly over different models —
//! through one queue and one worker pool (a mixed vision + text +
//! generation fleet). Requests are interleaved round-robin across the
//! members; workers form single-unit batches (a batch never mixes models),
//! and per-member stats come back separately. [`run_engine`] is the
//! single-member instance of the same core. Members are type-erased via
//! [`FleetMember::erased`], so a fleet is just a `Vec<ErasedMember>`.
//!
//! All time flows through the [`Clock`] trait (`serve/clock.rs`): arrival
//! generation, batching deadlines, execution timestamps, and the
//! controller's tick cadence. Production uses the wall clock; the
//! discrete-event simulator (`serve/sim.rs`) replays the same queueing
//! semantics on a virtual clock for bit-reproducible controller tests.
//!
//! With [`EngineOpts::controller`] set, a control thread wakes every tick,
//! observes queue depth / arrival rate / per-member windowed p99, and
//! adapts `max_wait`, the auto-dispatch fill threshold (from the online
//! [`CostEstimator`]), and — with `degrade` — the active plan rung of each
//! member ([`Plans::set_active`]): dense under normal load, the
//! pruned+compensated fallback under sustained pressure, and — when an
//! int8 rung is configured ([`FleetMember::with_quant_fallback`]) — the
//! weight-quantized variant as the cheapest last resort (see
//! `serve/controller.rs` for the hysteresis state machine).
//!
//! Accounting is per request: queueing delay (intended arrival → first
//! dequeue), execution time of the final step's batch, total latency,
//! time-to-first-step and mean inter-step time (for generation:
//! time-to-first-token and inter-token latency), plus the workload's
//! [`super::RequestOutput`] (prediction + token charge). Predictions are
//! returned per request so tests can assert that batching, padding vs
//! exact-size dispatch, worker count, and batch composition never change
//! *what* is computed.
//!
//! Worker threads call [`threads::serialize_nested_regions`] on entry:
//! the per-example fan-out inside the native backend runs serial on them,
//! so total parallelism equals the engine's worker count and the host is
//! never oversubscribed by nested pools.
//!
//! Fault tolerance: every worker runs a *supervised* loop — a panic inside
//! a batch cycle (a workload bug, or an injected chaos kill) is caught
//! with `catch_unwind`, the in-flight batch is recovered and routed
//! through the retry policy, and the worker respawns in place under a
//! bounded budget with exponential backoff; only an exhausted budget fails
//! the run, and then with a typed error, never a process abort. Requests
//! carry a deadline ([`EngineOpts::request_timeout`], checked at dispatch
//! time so state never half-advances) and a retry budget
//! ([`EngineOpts::max_retries`]); past the budget they are counted in
//! [`EngineStats::failures`] and their engine-side state — a generation's
//! paged KV blocks — is reclaimed via [`Workload::reclaim`]
//! ([`EngineStats::kv_reclaimed_blocks`]). The deterministic [`FaultPlan`]
//! injects worker kills, per-request dispatch failures, and batch delays,
//! keyed on schedule-independent identities (request id + step, worker
//! index + its own batch ordinal) so the discrete-event simulator replays
//! the same fault trajectory bit-for-bit (`tests/serve_faults`).

use anyhow::{anyhow, bail, Result};

use crate::exec::Executor;
use crate::model::{QuantStore, WeightStore};
use crate::serve::controller::{ControllerOpts, Transition};
use crate::serve::workload::{DispatchPolicy, Workload};

// Internals of the real (non-PJRT) engine; the `--cfg pjrt_backend` build
// compiles a stub `run_engine` instead (see below), because sharing one
// `Runtime` across worker threads requires the backend to be `Sync` and
// the vendored `xla` client/executable types are not known to be.
#[cfg(not(pjrt_backend))]
use {
    crate::exec::{KvPoolOpts, KvPoolStats},
    crate::serve::clock::{Clock, WallClock},
    crate::serve::controller::{Action, Controller, CostEstimator, MemberCfg, Obs},
    crate::serve::workload::{PlanPair, Plans, StepOutcome},
    crate::util::bench::percentile,
    crate::util::{lock, threads, Pcg64},
    std::collections::VecDeque,
    std::panic::{catch_unwind, AssertUnwindSafe},
    std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering},
    std::sync::{Arc, Condvar, Mutex},
    std::time::Duration,
};

/// Deterministic fault-injection plan (the chaos layer).
///
/// Faults key on *schedule-independent* identities — a request id plus its
/// step index, or a worker index plus that worker's own batch ordinal —
/// never on wall time or global dispatch order, so one plan produces the
/// same set of faulted requests in the threaded engine at any worker
/// count, and a bit-identical trajectory in the discrete-event simulator.
/// Every entry fires at most once (a retried request is not re-faulted by
/// the same entry).
///
/// Spec grammar for [`FaultPlan::parse`] (comma-separated entries):
///
/// * `kill=W@B` — worker `W` panics at the start of its `B`-th dispatched
///   batch (both 0-based); the supervisor absorbs the panic, retries the
///   batch, and respawns the worker.
/// * `fail=ID[@STEP]` — request `ID`'s dispatch at step `STEP` (default 0)
///   reports a fault before the step runs; the request retries or, past
///   its budget, fails.
/// * `delay=ID:MS` — the batch carrying request `ID` runs `MS` ms long
///   (timing-only; predictions are unaffected).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// `(worker, batch_ordinal)`: panic that worker at the start of that
    /// (0-based, per-worker) batch.
    pub kills: Vec<(usize, usize)>,
    /// `(request_id, step)`: fault that request's dispatch at that step.
    pub fails: Vec<(usize, usize)>,
    /// `(request_id, extra_seconds)`: stretch the batch carrying that
    /// request by the given service-time delay.
    pub delays: Vec<(usize, f64)>,
}

fn chaos_idx(s: &str, entry: &str) -> Result<usize> {
    match s.trim().parse::<usize>() {
        Ok(v) => Ok(v),
        Err(_) => bail!("--chaos entry '{entry}': '{s}' is not a non-negative integer"),
    }
}

impl FaultPlan {
    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty() && self.fails.is_empty() && self.delays.is_empty()
    }

    /// Parse a `--chaos` spec, e.g. `kill=0@1,fail=3,fail=5@2,delay=7:20`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let Some((kind, val)) = entry.split_once('=') else {
                bail!(
                    "--chaos entry '{entry}': expected kind=value \
                     (kill=W@B, fail=ID[@STEP], delay=ID:MS)"
                );
            };
            match kind.trim() {
                "kill" => {
                    let Some((w, b)) = val.split_once('@') else {
                        bail!("--chaos kill '{val}': expected W@B (worker@batch-ordinal)");
                    };
                    plan.kills.push((chaos_idx(w, entry)?, chaos_idx(b, entry)?));
                }
                "fail" => {
                    plan.fails.push(match val.split_once('@') {
                        Some((id, step)) => (chaos_idx(id, entry)?, chaos_idx(step, entry)?),
                        None => (chaos_idx(val, entry)?, 0),
                    });
                }
                "delay" => {
                    let Some((id, ms)) = val.split_once(':') else {
                        bail!("--chaos delay '{val}': expected ID:MS");
                    };
                    let ms: f64 = match ms.trim().parse() {
                        Ok(v) => v,
                        Err(_) => bail!("--chaos entry '{entry}': '{ms}' is not a number"),
                    };
                    if !ms.is_finite() || ms < 0.0 {
                        bail!("--chaos delay '{entry}': delay must be a finite ms >= 0");
                    }
                    plan.delays.push((chaos_idx(id, entry)?, ms / 1e3));
                }
                other => {
                    bail!("--chaos entry '{entry}': unknown fault kind '{other}' (kill/fail/delay)")
                }
            }
        }
        Ok(plan)
    }
}

/// One-shot fired-tracking over a [`FaultPlan`]: each entry is claimed
/// atomically, so exactly one dispatch observes it — in the threaded
/// engine *and* (trivially) in the single-threaded simulator, which reuses
/// this type so both replay identical trajectories.
#[cfg(not(pjrt_backend))]
pub(crate) struct FaultState {
    plan: FaultPlan,
    kill_fired: Vec<AtomicBool>,
    fail_fired: Vec<AtomicBool>,
    delay_fired: Vec<AtomicBool>,
}

#[cfg(not(pjrt_backend))]
impl FaultState {
    pub(crate) fn new(plan: FaultPlan) -> Self {
        let flags = |n: usize| (0..n).map(|_| AtomicBool::new(false)).collect();
        FaultState {
            kill_fired: flags(plan.kills.len()),
            fail_fired: flags(plan.fails.len()),
            delay_fired: flags(plan.delays.len()),
            plan,
        }
    }

    /// Claim a kill of `worker` at its `ord`-th dispatched batch.
    pub(crate) fn take_kill(&self, worker: usize, ord: usize) -> bool {
        self.plan.kills.iter().enumerate().any(|(i, &(w, b))| {
            w == worker && b == ord && !self.kill_fired[i].swap(true, Ordering::AcqRel)
        })
    }

    /// Claim a dispatch fault for request `id` at step `step`.
    pub(crate) fn take_fail(&self, id: usize, step: usize) -> bool {
        self.plan.fails.iter().enumerate().any(|(i, &(rid, s))| {
            rid == id && s == step && !self.fail_fired[i].swap(true, Ordering::AcqRel)
        })
    }

    /// Claim the service-time delay attached to request `id`, seconds.
    pub(crate) fn take_delay(&self, id: usize) -> Option<f64> {
        for (i, &(rid, s)) in self.plan.delays.iter().enumerate() {
            if rid == id && !self.delay_fired[i].swap(true, Ordering::AcqRel) {
                return Some(s);
            }
        }
        None
    }
}

/// Serving-engine options.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// Executor threads draining the queue.
    pub workers: usize,
    /// Open-loop arrival rate, requests/sec. Non-finite or ≤ 0 means
    /// "saturated": every request is due at t = 0.
    pub rate: f64,
    /// Total requests offered to the engine ([`run_fleet`] uses the
    /// per-member counts instead).
    pub requests: usize,
    /// Maximum requests per batch; also the fixed artifact batch size that
    /// the padded dispatch path pads partial batches to.
    pub max_batch: usize,
    /// Batching deadline: how long a worker holds a non-full batch open
    /// waiting for more arrivals, seconds. With a controller this is the
    /// *base* wait the controller adapts below.
    pub max_wait: f64,
    /// Queue bound; *arrivals* beyond it are shed (counted, not served).
    /// Re-enqueued continuations of admitted requests are exempt.
    pub queue_cap: usize,
    /// Minimum per-batch execution time, seconds (0 = off). A load-shaping
    /// knob for backpressure tests and experiments: the worker sleeps out
    /// the remainder after the real forward.
    pub exec_floor: f64,
    /// Seed for the Poisson arrival process.
    pub seed: u64,
    /// Batch dispatch-shape policy (padded / exact / auto). Collapses to
    /// `Padded` on runtimes that prefer fixed shapes (gated PJRT).
    pub dispatch: DispatchPolicy,
    /// KV pool: positions per block (`0` = pool default). Decode workloads
    /// only; single-shot workloads never build a pool.
    pub kv_block: usize,
    /// KV pool capacity in blocks (`0` = unbounded). A run that outgrows
    /// the cap fails fast with a clear error instead of thrashing.
    pub kv_blocks: usize,
    /// Arrival-rate multiplier applied to the middle third of the offered
    /// schedule (`1` = flat). The load-spike scenario the controller is
    /// tested against.
    pub spike: f64,
    /// Default per-member p99 latency budget, ms (`0` = no SLO). A
    /// [`FleetMember::with_slo_p99_ms`] override wins per member.
    pub slo_p99_ms: f64,
    /// Per-request deadline, seconds from the intended arrival (`0` = no
    /// deadline). Checked at dispatch time; each retry extends the
    /// deadline by one more budget (attempt `k` expires at
    /// `arrival + (k+1) * request_timeout`), so a retried request gets a
    /// fresh attempt instead of expiring the instant it re-enqueues.
    pub request_timeout: f64,
    /// Retry budget for timed-out / faulted requests; past it they are
    /// counted in [`EngineStats::failures`] and their KV state reclaimed.
    pub max_retries: usize,
    /// Base backoff before a retried request is eligible to dispatch
    /// again, seconds (doubles per attempt; `0` = immediately eligible).
    pub retry_backoff: f64,
    /// Deterministic fault injection (`None` = no chaos).
    pub chaos: Option<FaultPlan>,
    /// Feedback-controller configuration (`None` = static knobs, the
    /// pre-controller behavior).
    pub controller: Option<ControllerOpts>,
}

impl Default for EngineOpts {
    fn default() -> Self {
        Self {
            workers: 2,
            rate: 200.0,
            requests: 256,
            max_batch: 16,
            max_wait: 0.01,
            queue_cap: 1024,
            exec_floor: 0.0,
            seed: 7,
            dispatch: DispatchPolicy::Auto,
            kv_block: 0,
            kv_blocks: 0,
            spike: 1.0,
            slo_p99_ms: 0.0,
            request_timeout: 0.0,
            max_retries: 0,
            retry_backoff: 0.0,
            chaos: None,
            controller: None,
        }
    }
}

impl EngineOpts {
    /// Reject degenerate configurations with clear errors instead of
    /// silently shedding everything (`queue_cap == 0`), spinning on empty
    /// batches (`max_batch == 0`), deadlocking (`workers == 0`), or
    /// panicking later on a non-finite `--exec-floor`.
    pub(crate) fn validate(&self) -> Result<()> {
        if self.requests == 0 {
            bail!("run_engine: requests must be > 0");
        }
        if self.max_batch == 0 {
            bail!("run_engine: max_batch must be > 0 (got 0 — no batch could ever form)");
        }
        if self.queue_cap == 0 {
            bail!("run_engine: queue_cap must be > 0 (got 0 — every arrival would be shed)");
        }
        if self.workers == 0 {
            bail!("run_engine: workers must be > 0 (got 0 — nothing would drain the queue)");
        }
        if !self.exec_floor.is_finite() || self.exec_floor < 0.0 {
            bail!(
                "run_engine: --exec-floor must be a finite number of seconds >= 0 (got {})",
                self.exec_floor
            );
        }
        if !self.spike.is_finite() || self.spike <= 0.0 {
            bail!("run_engine: --spike must be a finite rate multiplier > 0 (got {})", self.spike);
        }
        if !self.request_timeout.is_finite() || self.request_timeout < 0.0 {
            bail!(
                "run_engine: --request-timeout-ms must be a finite deadline >= 0 (got {} s)",
                self.request_timeout
            );
        }
        if !self.retry_backoff.is_finite() || self.retry_backoff < 0.0 {
            bail!(
                "run_engine: --retry-backoff-ms must be a finite backoff >= 0 (got {} s)",
                self.retry_backoff
            );
        }
        Ok(())
    }
}

#[cfg(not(pjrt_backend))]
impl EngineOpts {
    /// Pool knobs for a decode unit's plan (prefix sharing always on; the
    /// workload decides whether prompts actually share openings).
    fn kv_pool_opts(&self) -> KvPoolOpts {
        let mut o = KvPoolOpts::default();
        if self.kv_block > 0 {
            o.block = self.kv_block;
        }
        o.max_blocks = self.kv_blocks;
        o
    }
}

/// Per-request accounting (one row per *served* request).
#[derive(Debug, Clone)]
pub struct RequestRecord {
    /// Request id; doubles as the eval-stream index the workload
    /// synthesized the payload from. Ids are per fleet member.
    pub id: usize,
    /// Intended arrival → first dequeue into a batch, ms.
    pub queue_ms: f64,
    /// Execution time of the batch carrying this request's *final* step, ms.
    pub exec_ms: f64,
    /// Intended arrival → completion of the final step, ms.
    pub total_ms: f64,
    /// Engine steps (batches) this request rode in: 1 for single-shot
    /// workloads; prefill + decode continuations for generation.
    pub steps: usize,
    /// Intended arrival → end of the first step, ms (time-to-first-token
    /// for generation; == `total_ms` when `steps == 1`).
    pub first_ms: f64,
    /// Mean inter-step time, ms — `(total − first) / (steps − 1)`; 0 when
    /// `steps == 1`. For generation this is the mean inter-token time.
    pub itl_ms: f64,
    /// Workload prediction (vision: class; text: next-token id; generation:
    /// final generated token).
    pub pred: i32,
    /// Tokens charged to this request (vision: 1; text: prompt length;
    /// generation: prompt + generated).
    pub tokens: usize,
    /// Plan rung active when the request's *final* step dispatched (0 =
    /// dense). For pinned generation sequences this is the engine-level
    /// rung at that moment, which can lag the sequence's own pinned rung
    /// by one switch — an accounting approximation, not an execution one.
    pub variant: usize,
}

/// Aggregate result of one engine run (per fleet member).
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub served: usize,
    /// Requests shed at the full queue.
    pub shed: usize,
    /// Batches executed.
    pub batches: usize,
    /// Mean requests carried per executed batch.
    pub mean_batch: f64,
    /// Mean batch size actually *dispatched* (= artifact batch under the
    /// padded policy; = mean_batch under exact; in between under auto).
    pub mean_dispatch: f64,
    /// Mean engine steps per served request (1.0 for single-shot
    /// workloads; prefill + decode steps for generation).
    pub steps_mean: f64,
    /// p50 / p95 / p99 of total per-request latency, ms.
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// The member's effective p99 budget, ms (0 = none configured).
    pub slo_p99_ms: f64,
    /// p50 queueing delay, ms.
    pub queue_p50_ms: f64,
    /// p50 time to the end of a request's first step, ms (TTFT for
    /// generation workloads).
    pub first_p50_ms: f64,
    /// Mean inter-step (inter-token) time over multi-step requests, ms.
    pub itl_mean_ms: f64,
    /// Mean per-batch execution time, ms.
    pub exec_mean_ms: f64,
    /// Served requests per second of wall time.
    pub throughput_fps: f64,
    /// Served tokens per second of wall time (== throughput_fps for the
    /// vision workload, where every request is one image).
    pub throughput_tps: f64,
    /// Mean K/V bytes appended to the paged cache per KV-cache dispatch
    /// (0 for single-shot workloads and prefill-mode decode). Appends touch
    /// only the fresh rows, so this scales with tokens fed per step —
    /// independent of `n_ctx` capacity.
    pub kv_bytes_per_step: f64,
    /// High-water bytes of live KV pool blocks over the run (summed across
    /// plan rungs — each rung owns its own pool).
    pub kv_peak_bytes: u64,
    /// Pool blocks still held at the end of the run (registered shared
    /// prefixes; completed sequences release theirs as they finish).
    pub kv_blocks_in_use: usize,
    /// Cumulative KV block allocations (fresh or recycled).
    pub kv_allocs: u64,
    /// Blocks adopted from the shared-prefix registry instead of allocated
    /// and recomputed.
    pub kv_shared_hits: u64,
    /// Copy-on-write block copies (a shared tail diverged).
    pub kv_cow_copies: u64,
    /// Pool blocks pinned by the shared-prefix registry at the end of the
    /// run (a deliberate cache, not a leak: the leak check is
    /// `kv_blocks_in_use == kv_registered_blocks`).
    pub kv_registered_blocks: usize,
    /// Requests that exhausted their retry budget (never served, excluded
    /// from every latency percentile). Per member,
    /// `served + shed + failures` accounts for every offered request.
    pub failures: usize,
    /// Re-enqueue events: timed-out, fault-injected, and panic-recovered
    /// requests sent back to the queue with their original arrival.
    pub retries: usize,
    /// Deadline expirations observed at dispatch time.
    pub timeouts: usize,
    /// Worker panics absorbed by the supervisor (an engine-wide count,
    /// reported on every member; the simulator counts absorbed server
    /// kills the same way).
    pub worker_respawns: usize,
    /// Paged-KV blocks released by reclaiming failed / aborted
    /// generations (timeout past the retry budget, injected fault, or a
    /// run torn down with continuations still queued).
    pub kv_reclaimed_blocks: usize,
    /// Served requests whose final step dispatched on each plan rung
    /// (index 0 = dense). Length = the member's rung count.
    pub served_by_variant: Vec<usize>,
    /// Seconds each plan rung was the member's active rung, from the
    /// controller's transition log (everything in rung 0 without one).
    pub time_in_variant_s: Vec<f64>,
    /// This member's variant switches, in order (empty without `degrade`).
    pub transitions: Vec<Transition>,
    /// Per-request records, sorted by id.
    pub records: Vec<RequestRecord>,
}

/// A borrowed weight store of either precision, so plan ladders can mix
/// f32 rungs with int8 weight-quantized rungs (the cheapest degrade
/// target). Plan resolution picks the matching [`Executor`] builder per
/// rung: [`Executor::forward_plan`]/[`Executor::decode_plan_opts`] for
/// f32, the `_q8` twins for int8.
#[derive(Clone, Copy)]
pub enum StoreRef<'w> {
    F32(&'w WeightStore),
    Q8(&'w QuantStore),
}

/// One model + workload bound into a fleet run (see [`run_fleet`]).
pub struct FleetMember<'x, 'rt, 'w, W: Workload> {
    pub exec: &'x Executor<'rt>,
    pub weights: &'w WeightStore,
    pub workload: &'x W,
    /// Requests offered for this member ([`EngineOpts::requests`] is
    /// ignored by [`run_fleet`]).
    pub requests: usize,
    /// Per-member p99 budget, ms (`0` defers to the fleet default).
    pub slo_p99_ms: f64,
    /// Degraded-variant weight stores, cheapest last: rung 1.. of the
    /// member's plan ladder (rung 0 is `weights`). Same model config,
    /// different folded weights — pruned+compensated f32 via
    /// [`Self::with_fallback`], or int8 weight-quantized via
    /// [`Self::with_quant_fallback`].
    pub fallbacks: Vec<StoreRef<'w>>,
}

impl<'x, 'rt, 'w, W: Workload> FleetMember<'x, 'rt, 'w, W> {
    pub fn new(
        exec: &'x Executor<'rt>,
        weights: &'w WeightStore,
        workload: &'x W,
        requests: usize,
    ) -> Self {
        FleetMember { exec, weights, workload, requests, slo_p99_ms: 0.0, fallbacks: Vec::new() }
    }

    /// Set this member's p99 latency budget (ms).
    pub fn with_slo_p99_ms(mut self, slo_p99_ms: f64) -> Self {
        self.slo_p99_ms = slo_p99_ms;
        self
    }

    /// Append a degraded-variant weight store (the controller's next rung).
    pub fn with_fallback(mut self, weights: &'w WeightStore) -> Self {
        self.fallbacks.push(StoreRef::F32(weights));
        self
    }

    /// Append an int8 weight-quantized rung (typically the cheapest,
    /// appended last so the controller degrades to it only under the most
    /// sustained pressure).
    pub fn with_quant_fallback(mut self, quant: &'w QuantStore) -> Self {
        self.fallbacks.push(StoreRef::Q8(quant));
        self
    }

    /// Type-erase the member so fleets of mixed workload types fit one
    /// `Vec` (see [`run_fleet`]). Plan building is deferred into the
    /// erased closure so it happens inside the fleet run, with the fleet's
    /// resolved options.
    pub fn erased<'e>(self) -> ErasedMember<'e>
    where
        'x: 'e,
        'rt: 'e,
        'w: 'e,
    {
        #[cfg(not(pjrt_backend))]
        {
            let FleetMember { exec, weights, workload, requests, slo_p99_ms, fallbacks } = self;
            ErasedMember {
                requests,
                mk: Box::new(move |opts: &EngineOpts| {
                    let policy = opts.dispatch.resolve(exec.rt.prefers_fixed_shapes());
                    let mut stores: Vec<StoreRef<'e>> = Vec::with_capacity(1 + fallbacks.len());
                    stores.push(StoreRef::F32(weights));
                    for &f in fallbacks.iter() {
                        stores.push(f);
                    }
                    make_unit(
                        exec,
                        &stores,
                        workload,
                        requests,
                        opts.max_batch,
                        policy,
                        opts.kv_pool_opts(),
                        slo_p99_ms,
                    )
                }),
            }
        }
        #[cfg(pjrt_backend)]
        {
            ErasedMember { requests: self.requests, _marker: std::marker::PhantomData }
        }
    }
}

/// A type-erased fleet member: request count plus a deferred unit builder.
/// Built via [`FleetMember::erased`].
pub struct ErasedMember<'e> {
    pub(crate) requests: usize,
    #[cfg(not(pjrt_backend))]
    #[allow(clippy::type_complexity)]
    pub(crate) mk: Box<dyn FnOnce(&EngineOpts) -> Result<Unit<'e>> + 'e>,
    #[cfg(pjrt_backend)]
    pub(crate) _marker: std::marker::PhantomData<&'e ()>,
}

/// A request (or a re-enqueued continuation) sitting in the engine queue.
/// Timestamps are engine-clock seconds (see [`Clock`]).
#[cfg(not(pjrt_backend))]
#[derive(Clone)]
pub(crate) struct Queued {
    pub(crate) unit: usize,
    pub(crate) id: usize,
    pub(crate) arrival: f64,
    /// Steps completed so far.
    pub(crate) steps: usize,
    pub(crate) first_deq: Option<f64>,
    pub(crate) first_done: Option<f64>,
    /// Retry attempts consumed (timeouts, injected faults, recovered
    /// panics). The deadline stretches with each attempt.
    pub(crate) tries: usize,
    /// Earliest engine-clock time this entry may dispatch again (retry
    /// backoff; `0` = immediately eligible).
    pub(crate) not_before: f64,
}

/// Per-unit fault accounting, merged into [`EngineStats`] by
/// [`finalize_stats`] — shared by the threaded engine and the simulator.
#[cfg(not(pjrt_backend))]
#[derive(Default, Clone, Copy)]
pub(crate) struct FaultTally {
    pub(crate) failures: usize,
    pub(crate) retries: usize,
    pub(crate) timeouts: usize,
    pub(crate) reclaimed_blocks: usize,
}

/// Queue state shared between the generator and the workers.
#[cfg(not(pjrt_backend))]
struct Shared {
    queue: VecDeque<Queued>,
    closed: bool,
    /// Shed arrivals, per fleet unit.
    shed: Vec<usize>,
}

/// Aggregated KV-cache telemetry for one unit, summed over its plan rungs
/// (each rung owns its own pool; peaks are summed as an upper bound on
/// simultaneous residency).
#[cfg(not(pjrt_backend))]
#[derive(Default, Clone, Copy)]
pub(crate) struct KvAgg {
    pub(crate) steps: u64,
    pub(crate) bytes: u64,
    pub(crate) peak_bytes: u64,
    pub(crate) blocks_in_use: usize,
    pub(crate) allocs: u64,
    pub(crate) shared_hits: u64,
    pub(crate) cow_copies: u64,
    pub(crate) registered_blocks: usize,
}

/// A type-erased fleet unit: the workload, its resolved plan ladder, and
/// its pre-synthesized payloads, closed over a step function so units with
/// different `Workload::Req` types share one queue and one worker pool.
#[cfg(not(pjrt_backend))]
pub(crate) struct Unit<'s> {
    pub(crate) label: &'static str,
    pub(crate) requests: usize,
    pub(crate) policy: DispatchPolicy,
    /// This member's p99 budget (ms; 0 = defer to the fleet default).
    pub(crate) slo_p99_ms: f64,
    /// The plan ladder every step dispatches through; the controller flips
    /// the active rung between batches.
    pub(crate) plans: Arc<Plans<'s, 's>>,
    #[allow(clippy::type_complexity)]
    pub(crate) step: Box<dyn Fn(&[usize], usize) -> Result<Vec<StepOutcome>> + Sync + 's>,
    /// KV-cache telemetry snapshot; `None` for units without decode plans.
    #[allow(clippy::type_complexity)]
    pub(crate) kv: Box<dyn Fn() -> Option<KvAgg> + Sync + 's>,
    /// Release the engine-side state (paged KV blocks) of aborted
    /// requests; returns the number of pool blocks returned.
    #[allow(clippy::type_complexity)]
    pub(crate) reclaim: Box<dyn Fn(&[usize]) -> usize + Sync + 's>,
}

/// Build one unit: resolve one plan rung per weight store (rung 0 = the
/// primary, usually dense, store), pre-synthesize every payload (request
/// id == eval-stream index, so data synthesis never pollutes the timed
/// region), and warm every rung's dispatch path before the clock starts.
#[cfg(not(pjrt_backend))]
#[allow(clippy::too_many_arguments)]
pub(crate) fn make_unit<'s, W: Workload>(
    exec: &Executor<'s>,
    stores: &[StoreRef<'s>],
    workload: &'s W,
    requests: usize,
    max_batch: usize,
    policy: DispatchPolicy,
    kv_opts: KvPoolOpts,
    slo_p99_ms: f64,
) -> Result<Unit<'s>> {
    let cfg = exec.cfg;
    if workload.cfg() != cfg {
        bail!(
            "workload '{}' drives model '{}', executor is bound to '{}'",
            workload.label(),
            workload.cfg().name,
            cfg.name
        );
    }
    if stores.is_empty() {
        bail!("make_unit: a member needs at least one weight store");
    }
    // Resolve exactly the plan the workload dispatches through: decode
    // workloads never touch the full-forward plan (the decode plan owns its
    // own prefill fallback), and resolving both would shape-check every
    // parameter twice and warm names that are never dispatched. One rung
    // per store; plans are shared (`Arc`) between the step closure, the
    // telemetry closure, and the engine (for controller rung switches).
    let mut pairs: Vec<PlanPair<'s, 's>> = Vec::with_capacity(stores.len());
    for &store in stores {
        pairs.push(match (workload.decode(), store) {
            (Some(mode), StoreRef::F32(w)) => PlanPair {
                fwd: None,
                dec: Some(exec.decode_plan_opts(
                    w,
                    mode.resolve(exec.rt.prefers_fixed_shapes()),
                    kv_opts,
                )?),
            },
            (Some(mode), StoreRef::Q8(qs)) => PlanPair {
                fwd: None,
                dec: Some(exec.decode_plan_opts_q8(
                    qs,
                    mode.resolve(exec.rt.prefers_fixed_shapes()),
                    kv_opts,
                )?),
            },
            (None, StoreRef::F32(w)) => PlanPair { fwd: Some(exec.forward_plan(w)?), dec: None },
            (None, StoreRef::Q8(qs)) => {
                PlanPair { fwd: Some(exec.forward_plan_q8(qs)?), dec: None }
            }
        });
    }
    let plans = Arc::new(Plans::ladder(pairs)?);
    // Shared between the step and reclaim closures: the engine retries or
    // fails requests by id, and reclamation needs the same payload slots.
    let payloads: Arc<Vec<W::Req>> = Arc::new(threads::parallel_map(requests, |i| workload.synth(i)));

    // Warmup before the clock starts, once per rung: run the full artifact
    // batch AND batch size 1 (first-touch allocation, PJRT compilation when
    // gated in), and under exact/auto dispatch pre-populate the rung's
    // artifact-name caches for every size a batch could dispatch at — so
    // no batch pays first-use name formatting inside its timed region, and
    // a controller rung switch never pays cold-plan costs mid-run. Warm
    // payloads are synthesized *past* the request id range (fresh per
    // rung): multi-step workloads carry per-request state, and warmup must
    // never pre-advance a real request.
    for v in 0..plans.variants() {
        plans.set_active(v);
        let warm: Vec<W::Req> = (0..max_batch + 1).map(|i| workload.synth(requests + i)).collect();
        let refs: Vec<&W::Req> = warm.iter().take(max_batch).collect();
        workload.run_step(&plans, &refs, max_batch)?;
        let pair = plans.pair(v);
        if policy != DispatchPolicy::Padded {
            workload.run_step(&plans, &[&warm[max_batch]], 1)?;
            for b in 1..=max_batch {
                if let Some(f) = &pair.fwd {
                    f.artifact(b);
                }
                if let Some(d) = &pair.dec {
                    d.warm_names(b);
                }
            }
        } else if let Some(d) = &pair.dec {
            d.warm_names(max_batch);
        }
    }
    plans.set_active(0);

    // Baseline counters after warmup, per rung, so per-step means cover
    // only the measured run (pool-level stats like peak blocks keep warmup
    // — the registry it warmed stays live).
    let kv0: Vec<(u64, u64)> = (0..plans.variants())
        .map(|v| plans.pair(v).dec.as_ref().map(|d| d.kv_counters()).unwrap_or((0, 0)))
        .collect();
    let step_plans = plans.clone();
    let kv_plans = plans.clone();
    let step_payloads = payloads.clone();
    Ok(Unit {
        label: workload.label(),
        requests,
        policy,
        slo_p99_ms,
        plans,
        step: Box::new(move |ids: &[usize], dispatch: usize| {
            let reqs: Vec<&W::Req> = ids.iter().map(|&i| &step_payloads[i]).collect();
            workload.run_step(&step_plans, &reqs, dispatch)
        }),
        kv: Box::new(move || {
            let mut agg = KvAgg::default();
            let mut any = false;
            for v in 0..kv_plans.variants() {
                if let Some(d) = kv_plans.pair(v).dec.as_ref() {
                    any = true;
                    let (s, b) = d.kv_counters();
                    agg.steps += s - kv0[v].0;
                    agg.bytes += b - kv0[v].1;
                    let p = d.pool_stats().unwrap_or_default();
                    agg.peak_bytes += p.peak_bytes();
                    agg.blocks_in_use += p.blocks_in_use;
                    agg.allocs += p.allocs;
                    agg.shared_hits += p.shared_hits;
                    agg.cow_copies += p.cow_copies;
                    agg.registered_blocks += p.registered_blocks;
                }
            }
            any.then_some(agg)
        }),
        reclaim: Box::new(move |ids: &[usize]| {
            ids.iter().map(|&i| workload.reclaim(&payloads[i])).sum()
        }),
    })
}

/// Run the engine: offered load is `opts.requests` workload-synthesized
/// requests (request id == eval-stream index) at `opts.rate` req/s; returns
/// per-request accounting plus aggregates. The weight store may be dense,
/// pruned, or compensated — the batch-polymorphic plans dispatch at
/// whatever shapes they find, and the workload decides what a request *is*
/// (including multi-step generation via re-enqueued continuations).
#[cfg(not(pjrt_backend))]
pub fn run_engine<W: Workload>(
    exec: &Executor<'_>,
    w: &WeightStore,
    workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    opts.validate()?;
    run_engine_on(exec, StoreRef::F32(w), workload, opts)
}

/// [`run_engine`] over an int8 weight-quantized store: every weight GEMM
/// dispatches through the quantized `_w8` plan rung. Predictions track the
/// f32 run to quantization tolerance (pinned by `tests/quant_equality`);
/// batching, shedding, and accounting semantics are identical.
#[cfg(not(pjrt_backend))]
pub fn run_engine_q8<W: Workload>(
    exec: &Executor<'_>,
    qs: &QuantStore,
    workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    opts.validate()?;
    run_engine_on(exec, StoreRef::Q8(qs), workload, opts)
}

#[cfg(not(pjrt_backend))]
fn run_engine_on<W: Workload>(
    exec: &Executor<'_>,
    store: StoreRef<'_>,
    workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    let policy = opts.dispatch.resolve(exec.rt.prefers_fixed_shapes());
    let unit = make_unit(
        exec,
        &[store],
        workload,
        opts.requests,
        opts.max_batch,
        policy,
        opts.kv_pool_opts(),
        opts.slo_p99_ms,
    )?;
    let mut stats = run_units(vec![unit], opts)?;
    Ok(stats.remove(0))
}

/// Run N workloads — possibly over different models — through one queue
/// and one worker pool: a mixed fleet. Member arrivals interleave
/// round-robin (m0.0, m1.0, …, m0.1, m1.1, …) on one seeded Poisson
/// schedule; workers form single-unit batches, so a dispatch never mixes
/// models. Returns per-member stats in argument order. Per-example math
/// makes each member's outputs identical to a single-workload
/// [`run_engine`] run with the same seeds — asserted by
/// `tests/serve_engine`.
#[cfg(not(pjrt_backend))]
pub fn run_fleet(members: Vec<ErasedMember<'_>>, opts: &EngineOpts) -> Result<Vec<EngineStats>> {
    if members.is_empty() {
        bail!("run_fleet: the fleet needs at least one member");
    }
    if members.iter().any(|m| m.requests == 0) {
        bail!("run_fleet: every member needs at least one request");
    }
    let total: usize = members.iter().map(|m| m.requests).sum();
    EngineOpts { requests: total, ..opts.clone() }.validate()?;
    let mut units = Vec::with_capacity(members.len());
    for m in members {
        units.push((m.mk)(opts)?);
    }
    run_units(units, opts)
}

/// Seeded arrival schedule shared by the threaded engine and the
/// simulator: Poisson offsets (seconds from engine start) at `rate`, with
/// the middle third of the schedule offered at `rate * spike`.
#[cfg(not(pjrt_backend))]
pub(crate) fn arrival_times(total: usize, rate: f64, spike: f64, seed: u64) -> Vec<f64> {
    let rate = if rate.is_finite() && rate > 0.0 { rate } else { f64::INFINITY };
    let spike = if spike.is_finite() && spike > 0.0 { spike } else { 1.0 };
    let (lo, hi) = (total / 3, total - total / 3);
    let mut rng = Pcg64::new(seed);
    let mut out = Vec::with_capacity(total);
    let mut t = 0.0f64;
    for i in 0..total {
        let r = if i >= lo && i < hi { rate * spike } else { rate };
        t += -rng.uniform().max(1e-12).ln() / r;
        out.push(t);
    }
    out
}

/// Deterministic round-robin interleave of unit arrivals: (unit, id) pairs
/// in offered order, independent of timing.
#[cfg(not(pjrt_backend))]
pub(crate) fn arrival_order(units: &[Unit<'_>]) -> Vec<(usize, usize)> {
    let total: usize = units.iter().map(|u| u.requests).sum();
    let mut order: Vec<(usize, usize)> = Vec::with_capacity(total);
    let mut issued = vec![0usize; units.len()];
    while order.len() < total {
        for (u, unit) in units.iter().enumerate() {
            if issued[u] < unit.requests {
                order.push((u, issued[u]));
                issued[u] += 1;
            }
        }
    }
    order
}

/// Controller state shared between the worker pool and the control thread.
#[cfg(not(pjrt_backend))]
struct Ctl {
    /// Adapted batch-formation deadline, seconds (f64 bits).
    max_wait_bits: AtomicU64,
    /// Adapted auto-dispatch fill threshold in `[0, 1]` (f64 bits).
    thresh_bits: AtomicU64,
    /// Online per-dispatch-size cost curve, fed by the workers.
    est: Mutex<CostEstimator>,
    /// Windowed per-member completion latencies (ms), drained every tick.
    lat: Mutex<Vec<Vec<f64>>>,
    /// Cumulative offered arrivals (shed ones included).
    arrivals: AtomicUsize,
    /// Cumulative fault events (timeouts + injected faults + recovered
    /// panics) — the controller's degrade-pressure signal.
    faults: AtomicUsize,
    done: AtomicBool,
}

/// Worker panics absorbed per worker before the run fails with a typed
/// error (never a process abort). Shared with the simulator so both
/// supervision loops agree.
#[cfg(not(pjrt_backend))]
pub(crate) const RESPAWN_BUDGET: usize = 8;

/// Initial supervisor backoff after an absorbed panic, seconds; doubles
/// per respawn, capped at 50 ms.
#[cfg(not(pjrt_backend))]
pub(crate) const RESPAWN_BACKOFF_S: f64 = 0.001;

/// The shared queueing/batching core: one generator, one bounded queue,
/// one worker pool over any number of type-erased units, plus (when
/// configured) one control thread — all timed by `clock`.
#[cfg(not(pjrt_backend))]
fn run_units(units: Vec<Unit<'_>>, opts: &EngineOpts) -> Result<Vec<EngineStats>> {
    run_units_on(units, opts, &WallClock::new())
}

#[cfg(not(pjrt_backend))]
fn run_units_on(
    units: Vec<Unit<'_>>,
    opts: &EngineOpts,
    clock: &dyn Clock,
) -> Result<Vec<EngineStats>> {
    let b_art = opts.max_batch;
    let workers = opts.workers;
    let base_wait = opts.max_wait.max(0.0);
    let timeout_s = opts.request_timeout;
    let max_retries = opts.max_retries;

    let order = arrival_order(&units);
    let arrivals = arrival_times(order.len(), opts.rate, opts.spike, opts.seed);

    let shared =
        Mutex::new(Shared { queue: VecDeque::new(), closed: false, shed: vec![0; units.len()] });
    let cv = Condvar::new();
    let results: Mutex<Vec<Vec<RequestRecord>>> = Mutex::new(vec![Vec::new(); units.len()]);
    // Per executed batch: (unit, requests carried, dispatch size, exec ms,
    // active plan rung).
    let batches: Mutex<Vec<(usize, usize, usize, f64, usize)>> = Mutex::new(Vec::new());
    let faults = opts.chaos.clone().filter(|p| !p.is_empty()).map(FaultState::new);
    let tally: Mutex<Vec<FaultTally>> = Mutex::new(vec![FaultTally::default(); units.len()]);
    let respawns = AtomicUsize::new(0);
    // Per-worker in-flight batch, registered before anything fallible in
    // the batch cycle so the supervisor can recover it after a panic.
    let inflight: Vec<Mutex<Option<Vec<Queued>>>> =
        (0..workers).map(|_| Mutex::new(None)).collect();
    let ctl = opts.controller.as_ref().map(|_| Ctl {
        max_wait_bits: AtomicU64::new(base_wait.to_bits()),
        thresh_bits: AtomicU64::new(DispatchPolicy::AUTO_FILL_THRESHOLD.to_bits()),
        est: Mutex::new(CostEstimator::new(b_art)),
        lat: Mutex::new(vec![Vec::new(); units.len()]),
        arrivals: AtomicUsize::new(0),
        faults: AtomicUsize::new(0),
        done: AtomicBool::new(false),
    });

    // Route a timed-out / faulted / panic-recovered request: back into the
    // queue with its ORIGINAL arrival (latency accounting keeps the full
    // story) while retry budget remains; past the budget, a counted
    // failure whose engine-side KV state is reclaimed on the spot.
    let retry_or_fail = |mut q: Queued, timed_out: bool, now: f64| {
        let mut t = lock::lock(&tally);
        if timed_out {
            t[q.unit].timeouts += 1;
        }
        if let Some(c) = &ctl {
            c.faults.fetch_add(1, Ordering::AcqRel);
        }
        if q.tries < max_retries {
            q.tries += 1;
            t[q.unit].retries += 1;
            drop(t);
            q.not_before = if opts.retry_backoff > 0.0 {
                now + opts.retry_backoff * (1u64 << (q.tries - 1).min(16)) as f64
            } else {
                0.0
            };
            let mut g = lock::lock(&shared);
            g.queue.push_back(q);
            cv.notify_one();
        } else {
            t[q.unit].failures += 1;
            t[q.unit].reclaimed_blocks += (units[q.unit].reclaim)(&[q.id]);
        }
    };

    let transitions = std::thread::scope(|s| -> Result<Vec<Transition>> {
        // ---- open-loop generator ----
        s.spawn(|| {
            'replay: for (&(unit, id), &at) in order.iter().zip(&arrivals) {
                loop {
                    // A failed worker poisons the run by setting `closed`;
                    // stop replaying the schedule so the error surfaces
                    // promptly instead of after the full arrival tail.
                    if lock::lock(&shared).closed {
                        break 'replay;
                    }
                    let now = clock.now();
                    if now >= at {
                        break;
                    }
                    clock.sleep((at - now).min(0.005));
                }
                if let Some(c) = &ctl {
                    c.arrivals.fetch_add(1, Ordering::AcqRel);
                }
                let mut g = lock::lock(&shared);
                if g.closed {
                    break 'replay;
                }
                if g.queue.len() >= opts.queue_cap {
                    g.shed[unit] += 1;
                } else {
                    g.queue.push_back(Queued {
                        unit,
                        id,
                        arrival: at,
                        steps: 0,
                        first_deq: None,
                        first_done: None,
                        tries: 0,
                        not_before: 0.0,
                    });
                    cv.notify_one();
                }
            }
            lock::lock(&shared).closed = true;
            cv.notify_all();
        });

        // ---- control thread ----
        let ctl_handle = ctl.as_ref().map(|c| {
            let copts = opts.controller.clone().expect("ctl implies controller opts");
            let members: Vec<MemberCfg> = units
                .iter()
                .map(|u| MemberCfg {
                    slo_p99_ms: if u.slo_p99_ms > 0.0 { u.slo_p99_ms } else { copts.slo_p99_ms },
                    variants: u.plans.variants(),
                })
                .collect();
            let units = &units;
            let shared = &shared;
            s.spawn(move || -> Vec<Transition> {
                let mut controller = Controller::new(copts.clone(), base_wait, b_art, &members);
                let mut prev_arrivals = 0usize;
                let mut prev_faults = 0usize;
                loop {
                    clock.sleep(copts.tick_s.max(1e-4));
                    if c.done.load(Ordering::Acquire) {
                        break;
                    }
                    let t = clock.now();
                    let queue_frac =
                        lock::lock(shared).queue.len() as f64 / opts.queue_cap.max(1) as f64;
                    let arr = c.arrivals.load(Ordering::Acquire);
                    let arrival_rate =
                        (arr - prev_arrivals) as f64 / copts.tick_s.max(1e-4);
                    prev_arrivals = arr;
                    let flt = c.faults.load(Ordering::Acquire);
                    let fault_rate = (flt - prev_faults) as f64 / copts.tick_s.max(1e-4);
                    prev_faults = flt;
                    let p99: Vec<Option<f64>> = {
                        let mut lat = lock::lock(&c.lat);
                        lat.iter_mut()
                            .map(|w| {
                                if w.is_empty() {
                                    None
                                } else {
                                    w.sort_by(|a, b| a.total_cmp(b));
                                    let p = percentile(w, 0.99);
                                    w.clear();
                                    Some(p)
                                }
                            })
                            .collect()
                    };
                    let est = lock::lock(&c.est).clone();
                    let actions = controller.tick(
                        &Obs { t, queue_frac, arrival_rate, fault_rate, p99_ms: &p99 },
                        &est,
                    );
                    for a in actions {
                        match a {
                            Action::MaxWait(w) => {
                                c.max_wait_bits.store(w.to_bits(), Ordering::Release)
                            }
                            Action::FillThreshold(th) => {
                                c.thresh_bits.store(th.to_bits(), Ordering::Release)
                            }
                            Action::Variant { member, variant } => {
                                units[member].plans.set_active(variant)
                            }
                        }
                    }
                }
                controller.transitions().to_vec()
            })
        });

        // ---- worker pool ----
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let units = &units;
                let shared = &shared;
                let cv = &cv;
                let ctl = &ctl;
                let results = &results;
                let batches = &batches;
                let faults = &faults;
                let inflight = &inflight;
                let respawns = &respawns;
                let retry_or_fail = &retry_or_fail;
                s.spawn(move || -> Result<()> {
                    threads::serialize_nested_regions();
                    // Supervised loop: a panic inside a batch cycle (a
                    // workload bug, or an injected chaos kill) is caught,
                    // the in-flight batch recovered for retry, and the
                    // worker respawned in place under a bounded budget
                    // with exponential backoff. Only an exhausted budget
                    // fails the run — with a typed error, not an abort.
                    let mut budget = RESPAWN_BUDGET;
                    let mut backoff = RESPAWN_BACKOFF_S;
                    let ord = AtomicUsize::new(0);
                    loop {
                        let ran = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
                            loop {
                                let mut batch: Vec<Queued> = Vec::with_capacity(b_art);
                                {
                                    let mut g = lock::lock(shared);
                                    // Block for the batch head (or a clean
                                    // shutdown). Backoff-deferred retries are
                                    // skipped until they come eligible.
                                    loop {
                                        let now = clock.now();
                                        if let Some(i) =
                                            g.queue.iter().position(|q| q.not_before <= now)
                                        {
                                            batch.push(g.queue.remove(i).expect("indexed item"));
                                            break;
                                        }
                                        if g.closed && g.queue.is_empty() {
                                            return Ok(());
                                        }
                                        g = if g.queue.is_empty() {
                                            lock::wait(cv, g)
                                        } else {
                                            lock::wait_timeout(cv, g, Duration::from_millis(1))
                                        };
                                    }
                                    // Hold the batch open until full, closed, or
                                    // the batching deadline expires — draining
                                    // only requests of the head's unit (a batch
                                    // never mixes models). The deadline comes
                                    // from the controller when one is running.
                                    let unit = batch[0].unit;
                                    let wait_s = match ctl {
                                        Some(c) => {
                                            f64::from_bits(c.max_wait_bits.load(Ordering::Acquire))
                                        }
                                        None => base_wait,
                                    };
                                    let deadline = clock.now() + wait_s.max(0.0);
                                    loop {
                                        let now = clock.now();
                                        let mut i = 0;
                                        while batch.len() < b_art && i < g.queue.len() {
                                            if g.queue[i].unit == unit
                                                && g.queue[i].not_before <= now
                                            {
                                                batch.push(
                                                    g.queue.remove(i).expect("indexed item"),
                                                );
                                            } else {
                                                i += 1;
                                            }
                                        }
                                        if batch.len() >= b_art || g.closed {
                                            break;
                                        }
                                        if now >= deadline {
                                            break;
                                        }
                                        g = lock::wait_timeout(
                                            cv,
                                            g,
                                            Duration::from_secs_f64((deadline - now).max(0.0)),
                                        );
                                    }
                                    // Hand leftover work to an idle worker: our
                                    // wait_timeout may have consumed its wakeup.
                                    if !g.queue.is_empty() {
                                        cv.notify_one();
                                    }
                                }
                                // Deadlines and injected dispatch faults resolve
                                // *before* the step runs, so a rejected
                                // request's state never half-advances and a
                                // retried one reproduces its fault-free
                                // prediction bit-for-bit.
                                if timeout_s > 0.0 || faults.is_some() {
                                    let now = clock.now();
                                    let kept: Vec<Queued> = batch
                                        .drain(..)
                                        .filter_map(|q| {
                                            if timeout_s > 0.0
                                                && now
                                                    > q.arrival
                                                        + (q.tries + 1) as f64 * timeout_s
                                            {
                                                retry_or_fail(q, true, now);
                                                None
                                            } else if faults
                                                .as_ref()
                                                .map_or(false, |f| f.take_fail(q.id, q.steps))
                                            {
                                                retry_or_fail(q, false, now);
                                                None
                                            } else {
                                                Some(q)
                                            }
                                        })
                                        .collect();
                                    batch = kept;
                                    if batch.is_empty() {
                                        continue;
                                    }
                                }
                                // Register the in-flight batch, then fire any
                                // injected kill keyed on this worker's own
                                // batch ordinal — the supervisor recovers the
                                // registered batch for retry.
                                *lock::lock(&inflight[w]) = Some(batch.clone());
                                let my_ord = ord.fetch_add(1, Ordering::AcqRel);
                                if let Some(f) = faults {
                                    if f.take_kill(w, my_ord) {
                                        panic!(
                                            "chaos: injected kill of worker {w} at batch {my_ord}"
                                        );
                                    }
                                }
                                let unit = batch[0].unit;
                                let take = batch.len();
                                // Dispatch shape: the learned cost curve
                                // replaces the static fill threshold under
                                // `auto` once a controller is running.
                                let dispatch = match ctl {
                                    Some(c) if units[unit].policy == DispatchPolicy::Auto => {
                                        let th =
                                            f64::from_bits(c.thresh_bits.load(Ordering::Acquire));
                                        if (take as f64) < th * b_art as f64 {
                                            take
                                        } else {
                                            b_art
                                        }
                                    }
                                    _ => units[unit].policy.dispatch_size(take, b_art),
                                };
                                let variant = units[unit].plans.active();
                                let t_deq = clock.now();
                                for q in batch.iter_mut() {
                                    if q.first_deq.is_none() {
                                        q.first_deq = Some(t_deq);
                                    }
                                }
                                let ids: Vec<usize> = batch.iter().map(|q| q.id).collect();
                                // On a *typed* workload failure, poison the run
                                // (`closed` stops the generator's replay and
                                // drains the other workers) so the error
                                // surfaces promptly instead of after the full
                                // arrival schedule. Panics take the supervised
                                // retry path instead.
                                let poison = || {
                                    lock::lock(shared).closed = true;
                                    cv.notify_all();
                                };
                                let outs: Vec<StepOutcome> =
                                    match (units[unit].step)(&ids, dispatch) {
                                        Ok(outs) => outs,
                                        Err(e) => {
                                            poison();
                                            return Err(e);
                                        }
                                    };
                                if outs.len() != batch.len() {
                                    // Fail fast on a broken Workload impl
                                    // rather than silently dropping records
                                    // (served + shed + failures == requests
                                    // must hold per unit).
                                    poison();
                                    bail!(
                                        "workload '{}' returned {} outcomes for a batch of {}",
                                        units[unit].label,
                                        outs.len(),
                                        batch.len()
                                    );
                                }
                                if opts.exec_floor > 0.0 {
                                    let spent = clock.now() - t_deq;
                                    if spent < opts.exec_floor {
                                        clock.sleep(opts.exec_floor - spent);
                                    }
                                }
                                if let Some(f) = faults {
                                    // Injected service-time stretch: timing
                                    // only, predictions unaffected.
                                    let extra: f64 =
                                        batch.iter().filter_map(|q| f.take_delay(q.id)).sum();
                                    if extra > 0.0 {
                                        clock.sleep(extra);
                                    }
                                }
                                let t_done = clock.now();
                                let exec_s = (t_done - t_deq).max(0.0);
                                let exec_ms = exec_s * 1e3;
                                if let Some(c) = ctl {
                                    lock::lock(&c.est).observe(dispatch, exec_s);
                                }
                                let mut requeue: Vec<Queued> = Vec::new();
                                {
                                    let mut recs = lock::lock(results);
                                    for (mut q, out) in batch.into_iter().zip(outs) {
                                        q.steps += 1;
                                        if q.first_done.is_none() {
                                            q.first_done = Some(t_done);
                                        }
                                        match out {
                                            StepOutcome::Done(o) => {
                                                let first = q.first_done.expect("set above");
                                                let first_ms =
                                                    (first - q.arrival).max(0.0) * 1e3;
                                                let total_ms =
                                                    (t_done - q.arrival).max(0.0) * 1e3;
                                                if let Some(c) = ctl {
                                                    lock::lock(&c.lat)[q.unit].push(total_ms);
                                                }
                                                recs[q.unit].push(RequestRecord {
                                                    id: q.id,
                                                    queue_ms: (q.first_deq.expect("set above")
                                                        - q.arrival)
                                                        .max(0.0)
                                                        * 1e3,
                                                    exec_ms,
                                                    total_ms,
                                                    steps: q.steps,
                                                    first_ms,
                                                    itl_ms: if q.steps > 1 {
                                                        (total_ms - first_ms)
                                                            / (q.steps - 1) as f64
                                                    } else {
                                                        0.0
                                                    },
                                                    pred: o.pred,
                                                    tokens: o.tokens,
                                                    variant,
                                                });
                                            }
                                            StepOutcome::Continue => requeue.push(q),
                                        }
                                    }
                                }
                                lock::lock(batches).push((unit, take, dispatch, exec_ms, variant));
                                // The batch is fully accounted — nothing left
                                // for the supervisor to recover.
                                *lock::lock(&inflight[w]) = None;
                                if !requeue.is_empty() {
                                    // Continuations of admitted requests bypass
                                    // the queue bound: shedding one
                                    // mid-generation would strand its state and
                                    // break served + shed + failures
                                    // accounting.
                                    let mut g = lock::lock(shared);
                                    for q in requeue {
                                        g.queue.push_back(q);
                                    }
                                    cv.notify_one();
                                }
                            }
                        }));
                        match ran {
                            Ok(done) => return done,
                            Err(_) => {
                                let now = clock.now();
                                if let Some(b) = lock::lock(&inflight[w]).take() {
                                    for q in b {
                                        retry_or_fail(q, false, now);
                                    }
                                }
                                if budget == 0 {
                                    lock::lock(shared).closed = true;
                                    cv.notify_all();
                                    bail!("serve worker {w}: panic respawn budget exhausted");
                                }
                                budget -= 1;
                                respawns.fetch_add(1, Ordering::AcqRel);
                                clock.sleep(backoff);
                                backoff = (backoff * 2.0).min(0.05);
                            }
                        }
                    }
                })
            })
            .collect();
        // Join workers first, then release the control thread — even when
        // a worker failed, so the scope never deadlocks on the ticker. A
        // join-level panic can only come from outside the supervised
        // region; it surfaces as a typed error, never a process abort.
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    worker_err.get_or_insert(e);
                }
                Err(_) => {
                    worker_err
                        .get_or_insert(anyhow!("serve worker panicked outside supervision"));
                }
            }
        }
        if let Some(c) = &ctl {
            c.done.store(true, Ordering::Release);
        }
        let transitions = match ctl_handle {
            Some(h) => match h.join() {
                Ok(t) => t,
                Err(_) => {
                    worker_err.get_or_insert(anyhow!("serve controller panicked"));
                    Vec::new()
                }
            },
            None => Vec::new(),
        };
        match worker_err {
            Some(e) => Err(e),
            None => Ok(transitions),
        }
    })?;

    let total_s = clock.now();
    // Teardown reclamation: anything still queued (continuations of a
    // poisoned run) is failed and its KV state released, so the pool's
    // post-run leak check holds on every exit path.
    let leftovers: Vec<Queued> = {
        let mut g = lock::lock(&shared);
        g.queue.drain(..).collect()
    };
    for q in leftovers {
        let mut t = lock::lock(&tally);
        t[q.unit].failures += 1;
        t[q.unit].reclaimed_blocks += (units[q.unit].reclaim)(&[q.id]);
    }
    let shed = std::mem::take(&mut lock::lock(&shared).shed);
    let per_unit = lock::into_inner(results);
    let batch_log = lock::into_inner(batches);
    let fault_tally = lock::lock(&tally).clone();
    let slo_default = opts.controller.as_ref().map(|c| c.slo_p99_ms).unwrap_or(opts.slo_p99_ms);
    Ok(finalize_stats(
        &units,
        per_unit,
        shed,
        &batch_log,
        &transitions,
        total_s,
        slo_default,
        &fault_tally,
        respawns.load(Ordering::Acquire),
    ))
}

/// Aggregate per-unit records + the batch log into [`EngineStats`] — the
/// one accounting path shared by the threaded engine and the simulator.
#[cfg(not(pjrt_backend))]
#[allow(clippy::too_many_arguments)]
pub(crate) fn finalize_stats(
    units: &[Unit<'_>],
    per_unit: Vec<Vec<RequestRecord>>,
    shed: Vec<usize>,
    batch_log: &[(usize, usize, usize, f64, usize)],
    transitions: &[Transition],
    total_s: f64,
    slo_default: f64,
    faults: &[FaultTally],
    respawns: usize,
) -> Vec<EngineStats> {
    let mut out = Vec::with_capacity(units.len());
    for (u, mut records) in per_unit.into_iter().enumerate() {
        records.sort_by_key(|r| r.id);
        let mut totals: Vec<f64> = records.iter().map(|r| r.total_ms).collect();
        totals.sort_by(|a, b| a.total_cmp(b));
        let mut queues: Vec<f64> = records.iter().map(|r| r.queue_ms).collect();
        queues.sort_by(|a, b| a.total_cmp(b));
        let mut firsts: Vec<f64> = records.iter().map(|r| r.first_ms).collect();
        firsts.sort_by(|a, b| a.total_cmp(b));
        let multi: Vec<&RequestRecord> = records.iter().filter(|r| r.steps > 1).collect();
        let ub: Vec<&(usize, usize, usize, f64, usize)> =
            batch_log.iter().filter(|&&(bu, ..)| bu == u).collect();
        let n_batches = ub.len();
        let tokens: usize = records.iter().map(|r| r.tokens).sum();
        let kv = (units[u].kv)().unwrap_or_default();
        let variants = units[u].plans.variants();
        let mut served_by_variant = vec![0usize; variants];
        for r in &records {
            served_by_variant[r.variant.min(variants - 1)] += 1;
        }
        let my_transitions: Vec<Transition> =
            transitions.iter().filter(|t| t.member == u).copied().collect();
        let mut time_in_variant_s = vec![0.0f64; variants];
        {
            let (mut cur, mut t0) = (0usize, 0.0f64);
            for tr in &my_transitions {
                let t = tr.t.clamp(0.0, total_s);
                time_in_variant_s[cur.min(variants - 1)] += (t - t0).max(0.0);
                cur = tr.to;
                t0 = t;
            }
            time_in_variant_s[cur.min(variants - 1)] += (total_s - t0).max(0.0);
        }
        out.push(EngineStats {
            served: records.len(),
            shed: shed[u],
            batches: n_batches,
            mean_batch: if n_batches == 0 {
                0.0
            } else {
                ub.iter().map(|&&(_, take, ..)| take).sum::<usize>() as f64 / n_batches as f64
            },
            mean_dispatch: if n_batches == 0 {
                0.0
            } else {
                ub.iter().map(|&&(_, _, d, ..)| d).sum::<usize>() as f64 / n_batches as f64
            },
            steps_mean: if records.is_empty() {
                0.0
            } else {
                records.iter().map(|r| r.steps).sum::<usize>() as f64 / records.len() as f64
            },
            p50_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.50) },
            p95_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.95) },
            p99_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.99) },
            slo_p99_ms: if units[u].slo_p99_ms > 0.0 { units[u].slo_p99_ms } else { slo_default },
            queue_p50_ms: if queues.is_empty() { 0.0 } else { percentile(&queues, 0.50) },
            first_p50_ms: if firsts.is_empty() { 0.0 } else { percentile(&firsts, 0.50) },
            itl_mean_ms: if multi.is_empty() {
                0.0
            } else {
                multi.iter().map(|r| r.itl_ms).sum::<f64>() / multi.len() as f64
            },
            exec_mean_ms: if n_batches == 0 {
                0.0
            } else {
                ub.iter().map(|&&(_, _, _, ms, _)| ms).sum::<f64>() / n_batches as f64
            },
            throughput_fps: records.len() as f64 / total_s.max(1e-12),
            throughput_tps: tokens as f64 / total_s.max(1e-12),
            kv_bytes_per_step: if kv.steps == 0 { 0.0 } else { kv.bytes as f64 / kv.steps as f64 },
            kv_peak_bytes: kv.peak_bytes,
            kv_blocks_in_use: kv.blocks_in_use,
            kv_allocs: kv.allocs,
            kv_shared_hits: kv.shared_hits,
            kv_cow_copies: kv.cow_copies,
            kv_registered_blocks: kv.registered_blocks,
            failures: faults.get(u).map_or(0, |f| f.failures),
            retries: faults.get(u).map_or(0, |f| f.retries),
            timeouts: faults.get(u).map_or(0, |f| f.timeouts),
            worker_respawns: respawns,
            kv_reclaimed_blocks: faults.get(u).map_or(0, |f| f.reclaimed_blocks),
            served_by_variant,
            time_in_variant_s,
            transitions: my_transitions,
            records,
        });
    }
    out
}

/// Deliberate compile-out for the `--cfg pjrt_backend` build: the engine
/// shares one `Runtime` across scoped worker threads, which requires the
/// backend to be `Sync`; the vendored PJRT client/executable types are not
/// known to satisfy that, so instead of a crate-wide build break the
/// gated build gets a stub that fails fast. Closed-loop [`super::measure`]
/// remains the serving measurement on that path (and keeps the padded
/// fixed-shape dispatch — see [`DispatchPolicy::resolve`]).
#[cfg(pjrt_backend)]
pub fn run_engine<W: Workload>(
    _exec: &Executor<'_>,
    _w: &WeightStore,
    _workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    opts.validate()?;
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads); use serve::measure"
    )
}

/// Stub mirror of [`run_engine_q8`] for the gated build; int8 weights are
/// additionally a native-interpreter feature, so there is nothing for PJRT
/// to dispatch even single-threaded.
#[cfg(pjrt_backend)]
pub fn run_engine_q8<W: Workload>(
    _exec: &Executor<'_>,
    _qs: &QuantStore,
    _workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    opts.validate()?;
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads, and int8 weights are \
         native-only); use serve::measure"
    )
}

/// Stub mirror of the fleet entry point for the gated build (see
/// [`run_engine`] above). Configuration errors still surface as errors —
/// never as panics — so a user-settable knob like `--exec-floor` fails the
/// same way on both builds.
#[cfg(pjrt_backend)]
pub fn run_fleet(members: Vec<ErasedMember<'_>>, opts: &EngineOpts) -> Result<Vec<EngineStats>> {
    if members.is_empty() {
        bail!("run_fleet: the fleet needs at least one member");
    }
    if members.iter().any(|m| m.requests == 0) {
        bail!("run_fleet: every member needs at least one request");
    }
    let total: usize = members.iter().map(|m| m.requests).sum();
    EngineOpts { requests: total, ..opts.clone() }.validate()?;
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads); use serve::measure"
    )
}

#[cfg(all(test, not(pjrt_backend)))]
mod tests {
    use super::*;

    #[test]
    fn default_opts_sane() {
        let o = EngineOpts::default();
        assert!(o.workers >= 1 && o.max_batch >= 1);
        assert!(o.queue_cap >= o.max_batch);
        assert!(o.max_wait >= 0.0 && o.exec_floor == 0.0);
        assert_eq!(o.dispatch, DispatchPolicy::Auto);
        assert_eq!(o.spike, 1.0);
        assert!(o.controller.is_none());
        assert!(o.validate().is_ok());
    }

    #[test]
    fn degenerate_opts_rejected() {
        for (opts, needle) in [
            (EngineOpts { requests: 0, ..Default::default() }, "requests"),
            (EngineOpts { max_batch: 0, ..Default::default() }, "max_batch"),
            (EngineOpts { queue_cap: 0, ..Default::default() }, "queue_cap"),
            (EngineOpts { workers: 0, ..Default::default() }, "workers"),
            // Regression: a bad --exec-floor used to *panic* in an assert;
            // it must be a plain error naming the flag.
            (EngineOpts { exec_floor: -1.0, ..Default::default() }, "--exec-floor"),
            (EngineOpts { exec_floor: f64::NAN, ..Default::default() }, "--exec-floor"),
            (EngineOpts { spike: 0.0, ..Default::default() }, "--spike"),
            (EngineOpts { spike: f64::INFINITY, ..Default::default() }, "--spike"),
            (
                EngineOpts { request_timeout: -1.0, ..Default::default() },
                "--request-timeout-ms",
            ),
            (
                EngineOpts { request_timeout: f64::NAN, ..Default::default() },
                "--request-timeout-ms",
            ),
            (EngineOpts { retry_backoff: -0.5, ..Default::default() }, "--retry-backoff-ms"),
            (
                EngineOpts { retry_backoff: f64::INFINITY, ..Default::default() },
                "--retry-backoff-ms",
            ),
        ] {
            let err = opts.validate().unwrap_err().to_string();
            assert!(err.contains(needle), "{err}");
        }
    }

    #[test]
    fn fault_plan_parses_all_kinds() {
        let p = FaultPlan::parse("kill=1@3, fail=7, fail=5@2, delay=9:250").unwrap();
        assert_eq!(p.kills, vec![(1, 3)]);
        assert_eq!(p.fails, vec![(7, 0), (5, 2)]);
        assert_eq!(p.delays.len(), 1);
        assert_eq!(p.delays[0].0, 9);
        assert!((p.delays[0].1 - 0.25).abs() < 1e-12);
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ,  ").unwrap().is_empty());
    }

    #[test]
    fn fault_plan_rejects_malformed_specs() {
        for (spec, needle) in [
            ("kill", "kind=value"),
            ("boom=3", "unknown fault kind"),
            ("kill=2", "W@B"),
            ("kill=x@1", "not a non-negative integer"),
            ("fail=-3", "not a non-negative integer"),
            ("delay=3", "ID:MS"),
            ("delay=3:abc", "not a number"),
            ("delay=3:-5", ">= 0"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err().to_string();
            assert!(err.contains(needle), "{spec}: {err}");
        }
    }

    #[test]
    fn fault_state_entries_fire_once() {
        let fs = FaultState::new(FaultPlan::parse("kill=0@1,fail=4@1,delay=2:10").unwrap());
        assert!(!fs.take_kill(0, 0));
        assert!(!fs.take_kill(1, 1));
        assert!(fs.take_kill(0, 1));
        assert!(!fs.take_kill(0, 1), "kill entries are one-shot");
        assert!(!fs.take_fail(4, 0));
        assert!(fs.take_fail(4, 1));
        assert!(!fs.take_fail(4, 1), "fail entries are one-shot");
        assert!(fs.take_delay(2).is_some());
        assert!(fs.take_delay(2).is_none(), "delay entries are one-shot");
    }

    #[test]
    fn arrival_times_spike_compresses_middle_third() {
        let flat = arrival_times(90, 100.0, 1.0, 42);
        let spiked = arrival_times(90, 100.0, 3.0, 42);
        assert_eq!(flat.len(), 90);
        // Same RNG stream: the first third is identical, the spiked middle
        // third accumulates 3x slower, and every sequence is increasing.
        for i in 0..30 {
            assert!((flat[i] - spiked[i]).abs() < 1e-12);
        }
        let flat_mid = flat[59] - flat[30];
        let spiked_mid = spiked[59] - spiked[30];
        assert!((spiked_mid - flat_mid / 3.0).abs() < 1e-9, "{spiked_mid} vs {flat_mid}");
        for w in spiked.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // Saturated rate still yields an all-zero schedule.
        assert!(arrival_times(8, 0.0, 3.0, 1).iter().all(|&t| t == 0.0));
    }
}
