//! Concurrent batched serving engine, generic over a [`Workload`].
//!
//! Queueing model (open loop): a generator thread replays a seeded Poisson
//! arrival process into a *bounded* FIFO queue; arrivals that find the queue
//! full are shed and counted (backpressure instead of unbounded buildup).
//! `workers` executor threads drain the queue: each pops a request, then
//! keeps the batch open up to `max_wait` seconds waiting for the queue to
//! yield up to `max_batch` requests, picks a dispatch size for the (possibly
//! partial) batch per the configured [`DispatchPolicy`] — padded to the
//! fixed artifact batch or exact at the true size — and hands it to the
//! workload, which assembles inputs and runs one fused dispatch through a
//! [`crate::exec::ForwardPlan`] shared by every worker.
//!
//! The engine core knows nothing about images or prompts: request
//! synthesis, batch input assembly, and per-request output accounting live
//! behind the [`Workload`] trait ([`super::VisionWorkload`] /
//! [`super::GptWorkload`]) — one queueing/batching core, two scenarios.
//!
//! Accounting is per request: queueing delay (intended arrival → dequeue),
//! execution time (its batch's forward), total latency, and the workload's
//! [`RequestOutput`] (prediction + token charge). Predictions are returned
//! per request so tests can assert that batching, padding vs exact-size
//! dispatch, and the worker count never change *what* is computed — rows
//! are processed per example, so a request's logits are identical to a
//! batch-1 forward of the same payload.
//!
//! Worker threads call [`threads::serialize_nested_regions`] on entry:
//! the per-example fan-out inside the native backend runs serial on them,
//! so total parallelism equals the engine's worker count and the host is
//! never oversubscribed by nested pools.

use anyhow::{bail, Result};

use crate::exec::Executor;
use crate::model::WeightStore;
use crate::serve::workload::{DispatchPolicy, Workload};

// Internals of the real (non-PJRT) engine; the `--cfg pjrt_backend` build
// compiles a stub `run_engine` instead (see below), because sharing one
// `Runtime` across worker threads requires the backend to be `Sync` and
// the vendored `xla` client/executable types are not known to be.
#[cfg(not(pjrt_backend))]
use {
    crate::serve::workload::RequestOutput,
    crate::util::bench::percentile,
    crate::util::{threads, Pcg64},
    std::collections::VecDeque,
    std::sync::{Condvar, Mutex},
    std::time::{Duration, Instant},
};

/// Serving-engine options.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// Executor threads draining the queue.
    pub workers: usize,
    /// Open-loop arrival rate, requests/sec. Non-finite or ≤ 0 means
    /// "saturated": every request is due at t = 0.
    pub rate: f64,
    /// Total requests offered to the engine.
    pub requests: usize,
    /// Maximum requests per batch; also the fixed artifact batch size that
    /// the padded dispatch path pads partial batches to.
    pub max_batch: usize,
    /// Batching deadline: how long a worker holds a non-full batch open
    /// waiting for more arrivals, seconds.
    pub max_wait: f64,
    /// Queue bound; arrivals beyond it are shed (counted, not served).
    pub queue_cap: usize,
    /// Minimum per-batch execution time, seconds (0 = off). A load-shaping
    /// knob for backpressure tests and experiments: the worker sleeps out
    /// the remainder after the real forward.
    pub exec_floor: f64,
    /// Seed for the Poisson arrival process.
    pub seed: u64,
    /// Batch dispatch-shape policy (padded / exact / auto). Collapses to
    /// `Padded` on runtimes that prefer fixed shapes (gated PJRT).
    pub dispatch: DispatchPolicy,
}

impl Default for EngineOpts {
    fn default() -> Self {
        Self {
            workers: 2,
            rate: 200.0,
            requests: 256,
            max_batch: 16,
            max_wait: 0.01,
            queue_cap: 1024,
            exec_floor: 0.0,
            seed: 7,
            dispatch: DispatchPolicy::Auto,
        }
    }
}

impl EngineOpts {
    /// Reject degenerate configurations with clear errors instead of
    /// silently shedding everything (`queue_cap == 0`), spinning on empty
    /// batches (`max_batch == 0`), or deadlocking (`workers == 0`).
    fn validate(&self) -> Result<()> {
        if self.requests == 0 {
            bail!("run_engine: requests must be > 0");
        }
        if self.max_batch == 0 {
            bail!("run_engine: max_batch must be > 0 (got 0 — no batch could ever form)");
        }
        if self.queue_cap == 0 {
            bail!("run_engine: queue_cap must be > 0 (got 0 — every arrival would be shed)");
        }
        if self.workers == 0 {
            bail!("run_engine: workers must be > 0 (got 0 — nothing would drain the queue)");
        }
        Ok(())
    }
}

/// Per-request accounting (one row per *served* request).
#[derive(Debug, Clone)]
pub struct RequestRecord {
    /// Request id; doubles as the eval-stream index the workload
    /// synthesized the payload from.
    pub id: usize,
    /// Intended arrival → dequeue into a batch, ms.
    pub queue_ms: f64,
    /// Execution time of the batch this request rode in, ms.
    pub exec_ms: f64,
    /// Intended arrival → completion, ms.
    pub total_ms: f64,
    /// Workload prediction (vision: class; text: next-token id).
    pub pred: i32,
    /// Tokens charged to this request (vision: 1; text: prompt length).
    pub tokens: usize,
}

/// Aggregate result of one engine run.
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub served: usize,
    /// Requests shed at the full queue.
    pub shed: usize,
    /// Batches executed.
    pub batches: usize,
    /// Mean requests carried per executed batch.
    pub mean_batch: f64,
    /// Mean batch size actually *dispatched* (= artifact batch under the
    /// padded policy; = mean_batch under exact; in between under auto).
    pub mean_dispatch: f64,
    /// p50 / p95 of total per-request latency, ms.
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// p50 queueing delay, ms.
    pub queue_p50_ms: f64,
    /// Mean per-batch execution time, ms.
    pub exec_mean_ms: f64,
    /// Served requests per second of wall time.
    pub throughput_fps: f64,
    /// Served tokens per second of wall time (== throughput_fps for the
    /// vision workload, where every request is one image).
    pub throughput_tps: f64,
    /// Per-request records, sorted by id.
    pub records: Vec<RequestRecord>,
}

/// A request sitting in the engine queue.
#[cfg(not(pjrt_backend))]
struct Queued {
    id: usize,
    arrival: Instant,
}

/// Queue state shared between the generator and the workers.
#[cfg(not(pjrt_backend))]
struct Shared {
    queue: VecDeque<Queued>,
    closed: bool,
    shed: usize,
}

/// Run the engine: offered load is `opts.requests` workload-synthesized
/// requests (request id == eval-stream index) at `opts.rate` req/s; returns
/// per-request accounting plus aggregates. The weight store may be dense,
/// pruned, or compensated — the batch-polymorphic plan dispatches at
/// whatever shapes it finds, and the workload decides what a request *is*.
#[cfg(not(pjrt_backend))]
pub fn run_engine<W: Workload>(
    exec: &Executor<'_>,
    w: &WeightStore,
    workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    let cfg = exec.cfg;
    if workload.cfg() != cfg {
        bail!(
            "workload '{}' drives model '{}', executor is bound to '{}'",
            workload.label(),
            workload.cfg().name,
            cfg.name
        );
    }
    opts.validate()?;
    let b_art = opts.max_batch;
    let workers = opts.workers;
    let policy = opts.dispatch.resolve(exec.rt.prefers_fixed_shapes());
    let plan = exec.forward_plan(w)?;

    // Pre-synthesize every request's payload so data synthesis never
    // pollutes the timed region (request id == eval-stream index).
    let payloads: Vec<W::Req> = threads::parallel_map(opts.requests, |i| workload.synth(i));

    // Warmup before the clock starts: run the full artifact batch AND batch
    // size 1 (first-touch allocation, PJRT compilation when gated in), and
    // under exact/auto dispatch pre-populate the plan's artifact-name cache
    // for every size a batch could dispatch at — so no batch pays first-use
    // name formatting inside its timed region.
    {
        let warm: Vec<&W::Req> = payloads.iter().take(b_art).collect();
        workload.run_batch(&plan, &warm, b_art)?;
        if policy != DispatchPolicy::Padded {
            workload.run_batch(&plan, &warm[..1], 1)?;
            for b in 1..=b_art {
                plan.artifact(b);
            }
        }
    }

    // Seeded Poisson arrival offsets (seconds from engine start).
    let rate = if opts.rate.is_finite() && opts.rate > 0.0 { opts.rate } else { f64::INFINITY };
    let mut rng = Pcg64::new(opts.seed);
    let mut arrivals = Vec::with_capacity(opts.requests);
    let mut t = 0.0f64;
    for _ in 0..opts.requests {
        t += -rng.uniform().max(1e-12).ln() / rate;
        arrivals.push(t);
    }

    let shared = Mutex::new(Shared { queue: VecDeque::new(), closed: false, shed: 0 });
    let cv = Condvar::new();
    let results: Mutex<Vec<RequestRecord>> = Mutex::new(Vec::with_capacity(opts.requests));
    // Per executed batch: (requests carried, dispatch size, execution ms).
    let batches: Mutex<Vec<(usize, usize, f64)>> = Mutex::new(Vec::new());
    let wait_dur = Duration::from_secs_f64(opts.max_wait.max(0.0));
    let wall0 = Instant::now();

    std::thread::scope(|s| -> Result<()> {
        // ---- open-loop generator ----
        s.spawn(|| {
            'replay: for (id, &at) in arrivals.iter().enumerate() {
                loop {
                    // A failed worker poisons the run by setting `closed`;
                    // stop replaying the schedule so the error surfaces
                    // promptly instead of after the full arrival tail.
                    if shared.lock().unwrap().closed {
                        break 'replay;
                    }
                    let now = wall0.elapsed().as_secs_f64();
                    if now >= at {
                        break;
                    }
                    std::thread::sleep(Duration::from_secs_f64((at - now).min(0.005)));
                }
                let mut g = shared.lock().unwrap();
                if g.closed {
                    break 'replay;
                }
                if g.queue.len() >= opts.queue_cap {
                    g.shed += 1;
                } else {
                    g.queue.push_back(Queued {
                        id,
                        arrival: wall0 + Duration::from_secs_f64(at),
                    });
                    cv.notify_one();
                }
            }
            shared.lock().unwrap().closed = true;
            cv.notify_all();
        });

        // ---- worker pool ----
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| -> Result<()> {
                    threads::serialize_nested_regions();
                    loop {
                        let mut batch: Vec<Queued> = Vec::with_capacity(b_art);
                        {
                            let mut g = shared.lock().unwrap();
                            // Block for the batch head (or a clean shutdown).
                            loop {
                                if let Some(q) = g.queue.pop_front() {
                                    batch.push(q);
                                    break;
                                }
                                if g.closed {
                                    return Ok(());
                                }
                                g = cv.wait(g).unwrap();
                            }
                            // Hold the batch open until full, closed, or the
                            // batching deadline expires.
                            let deadline = Instant::now() + wait_dur;
                            while batch.len() < b_art {
                                while batch.len() < b_art {
                                    match g.queue.pop_front() {
                                        Some(q) => batch.push(q),
                                        None => break,
                                    }
                                }
                                if batch.len() >= b_art || g.closed {
                                    break;
                                }
                                let now = Instant::now();
                                if now >= deadline {
                                    break;
                                }
                                let (g2, _) = cv.wait_timeout(g, deadline - now).unwrap();
                                g = g2;
                            }
                            // Hand leftover work to an idle worker: our
                            // wait_timeout may have consumed its wakeup.
                            if !g.queue.is_empty() {
                                cv.notify_one();
                            }
                        }
                        let take = batch.len();
                        let dispatch = policy.dispatch_size(take, b_art);
                        let t_deq = Instant::now();
                        let inputs: Vec<&W::Req> =
                            batch.iter().map(|q| &payloads[q.id]).collect();
                        // On any workload failure, poison the run (`closed`
                        // stops the generator's replay and drains the other
                        // workers) so the error surfaces promptly instead
                        // of after the full arrival schedule.
                        let poison = || {
                            shared.lock().unwrap().closed = true;
                            cv.notify_all();
                        };
                        let outs: Vec<RequestOutput> =
                            match workload.run_batch(&plan, &inputs, dispatch) {
                                Ok(outs) => outs,
                                Err(e) => {
                                    poison();
                                    return Err(e);
                                }
                            };
                        if outs.len() != batch.len() {
                            // Fail fast on a broken Workload impl rather
                            // than silently dropping records in the zip
                            // below (served + shed == requests must hold).
                            poison();
                            bail!(
                                "workload '{}' returned {} outputs for a batch of {}",
                                workload.label(),
                                outs.len(),
                                batch.len()
                            );
                        }
                        if opts.exec_floor > 0.0 {
                            let spent = t_deq.elapsed().as_secs_f64();
                            if spent < opts.exec_floor {
                                std::thread::sleep(Duration::from_secs_f64(
                                    opts.exec_floor - spent,
                                ));
                            }
                        }
                        let t_done = Instant::now();
                        let exec_ms =
                            t_done.saturating_duration_since(t_deq).as_secs_f64() * 1e3;
                        let mut recs = results.lock().unwrap();
                        for (q, out) in batch.iter().zip(&outs) {
                            recs.push(RequestRecord {
                                id: q.id,
                                queue_ms: t_deq.saturating_duration_since(q.arrival).as_secs_f64()
                                    * 1e3,
                                exec_ms,
                                total_ms: t_done
                                    .saturating_duration_since(q.arrival)
                                    .as_secs_f64()
                                    * 1e3,
                                pred: out.pred,
                                tokens: out.tokens,
                            });
                        }
                        drop(recs);
                        batches.lock().unwrap().push((take, dispatch, exec_ms));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("serve worker panicked")?;
        }
        Ok(())
    })?;

    let total_s = wall0.elapsed().as_secs_f64();
    let shed = shared.lock().unwrap().shed;
    let mut records = results.into_inner().unwrap();
    records.sort_by_key(|r| r.id);
    let batch_log = batches.into_inner().unwrap();

    let mut totals: Vec<f64> = records.iter().map(|r| r.total_ms).collect();
    totals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut queues: Vec<f64> = records.iter().map(|r| r.queue_ms).collect();
    queues.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n_batches = batch_log.len();
    let tokens: usize = records.iter().map(|r| r.tokens).sum();
    Ok(EngineStats {
        served: records.len(),
        shed,
        batches: n_batches,
        mean_batch: if n_batches == 0 {
            0.0
        } else {
            batch_log.iter().map(|&(take, _, _)| take).sum::<usize>() as f64 / n_batches as f64
        },
        mean_dispatch: if n_batches == 0 {
            0.0
        } else {
            batch_log.iter().map(|&(_, d, _)| d).sum::<usize>() as f64 / n_batches as f64
        },
        p50_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.50) },
        p95_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.95) },
        queue_p50_ms: if queues.is_empty() { 0.0 } else { percentile(&queues, 0.50) },
        exec_mean_ms: if n_batches == 0 {
            0.0
        } else {
            batch_log.iter().map(|&(_, _, ms)| ms).sum::<f64>() / n_batches as f64
        },
        throughput_fps: records.len() as f64 / total_s.max(1e-12),
        throughput_tps: tokens as f64 / total_s.max(1e-12),
        records,
    })
}

/// Deliberate compile-out for the `--cfg pjrt_backend` build: the engine
/// shares one `Runtime` across scoped worker threads, which requires the
/// backend to be `Sync`; the vendored PJRT client/executable types are not
/// known to satisfy that, so instead of a crate-wide build break the
/// gated build gets a stub that fails fast. Closed-loop [`super::measure`]
/// remains the serving measurement on that path (and keeps the padded
/// fixed-shape dispatch — see [`DispatchPolicy::resolve`]).
#[cfg(pjrt_backend)]
pub fn run_engine<W: Workload>(
    _exec: &Executor<'_>,
    _w: &WeightStore,
    _workload: &W,
    _opts: &EngineOpts,
) -> Result<EngineStats> {
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads); use serve::measure"
    )
}

#[cfg(all(test, not(pjrt_backend)))]
mod tests {
    use super::*;

    #[test]
    fn default_opts_sane() {
        let o = EngineOpts::default();
        assert!(o.workers >= 1 && o.max_batch >= 1);
        assert!(o.queue_cap >= o.max_batch);
        assert!(o.max_wait >= 0.0 && o.exec_floor == 0.0);
        assert_eq!(o.dispatch, DispatchPolicy::Auto);
        assert!(o.validate().is_ok());
    }

    #[test]
    fn degenerate_opts_rejected() {
        for (opts, needle) in [
            (EngineOpts { requests: 0, ..Default::default() }, "requests"),
            (EngineOpts { max_batch: 0, ..Default::default() }, "max_batch"),
            (EngineOpts { queue_cap: 0, ..Default::default() }, "queue_cap"),
            (EngineOpts { workers: 0, ..Default::default() }, "workers"),
        ] {
            let err = opts.validate().unwrap_err().to_string();
            assert!(err.contains(needle), "{err}");
        }
    }
}
