//! Concurrent batched serving engine, generic over a [`Workload`].
//!
//! Queueing model (open loop): a generator thread replays a seeded Poisson
//! arrival process into a *bounded* FIFO queue; arrivals that find the queue
//! full are shed and counted (backpressure instead of unbounded buildup).
//! `workers` executor threads drain the queue: each pops a request, then
//! keeps the batch open up to `max_wait` seconds waiting for the queue to
//! yield up to `max_batch` requests *of the same fleet unit*, picks a
//! dispatch size for the (possibly partial) batch per the configured
//! [`DispatchPolicy`] — padded to the fixed artifact batch or exact at the
//! true size — and hands it to the workload, which assembles inputs and runs
//! one fused dispatch through the [`Plans`] shared by every worker.
//!
//! The engine core knows nothing about images, prompts, or decode steps:
//! request synthesis, batch input assembly, and per-request output
//! accounting live behind the [`Workload`] trait. Multi-step workloads
//! ([`super::GenWorkload`]) return [`StepOutcome::Continue`] from a step;
//! the engine then *re-enqueues* the request (keeping its original arrival
//! for latency accounting, bypassing the queue bound so an admitted request
//! is never shed mid-generation), so decode steps from different sequences
//! batch together — the continuation-re-enqueue batching model.
//!
//! [`run_fleet`] runs *two* workloads — possibly over different models —
//! through one queue and one worker pool (a mixed vision + generation
//! fleet). Requests are interleaved round-robin across the members of the
//! fleet; workers form single-unit batches (a batch never mixes models),
//! and per-member stats come back separately. [`run_engine`] is the
//! single-member instance of the same core.
//!
//! Accounting is per request: queueing delay (intended arrival → first
//! dequeue), execution time of the final step's batch, total latency,
//! time-to-first-step and mean inter-step time (for generation:
//! time-to-first-token and inter-token latency), plus the workload's
//! [`super::RequestOutput`] (prediction + token charge). Predictions are
//! returned
//! per request so tests can assert that batching, padding vs exact-size
//! dispatch, worker count, and batch composition never change *what* is
//! computed.
//!
//! Worker threads call [`threads::serialize_nested_regions`] on entry:
//! the per-example fan-out inside the native backend runs serial on them,
//! so total parallelism equals the engine's worker count and the host is
//! never oversubscribed by nested pools.

use anyhow::{bail, Result};

use crate::exec::Executor;
use crate::model::WeightStore;
use crate::serve::workload::{DispatchPolicy, Workload};

// Internals of the real (non-PJRT) engine; the `--cfg pjrt_backend` build
// compiles a stub `run_engine` instead (see below), because sharing one
// `Runtime` across worker threads requires the backend to be `Sync` and
// the vendored `xla` client/executable types are not known to be.
#[cfg(not(pjrt_backend))]
use {
    crate::exec::{KvPoolOpts, KvPoolStats},
    crate::serve::workload::{Plans, StepOutcome},
    crate::util::bench::percentile,
    crate::util::{threads, Pcg64},
    std::collections::VecDeque,
    std::sync::{Arc, Condvar, Mutex},
    std::time::{Duration, Instant},
};

/// Serving-engine options.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// Executor threads draining the queue.
    pub workers: usize,
    /// Open-loop arrival rate, requests/sec. Non-finite or ≤ 0 means
    /// "saturated": every request is due at t = 0.
    pub rate: f64,
    /// Total requests offered to the engine ([`run_fleet`] uses the
    /// per-member counts instead).
    pub requests: usize,
    /// Maximum requests per batch; also the fixed artifact batch size that
    /// the padded dispatch path pads partial batches to.
    pub max_batch: usize,
    /// Batching deadline: how long a worker holds a non-full batch open
    /// waiting for more arrivals, seconds.
    pub max_wait: f64,
    /// Queue bound; *arrivals* beyond it are shed (counted, not served).
    /// Re-enqueued continuations of admitted requests are exempt.
    pub queue_cap: usize,
    /// Minimum per-batch execution time, seconds (0 = off). A load-shaping
    /// knob for backpressure tests and experiments: the worker sleeps out
    /// the remainder after the real forward.
    pub exec_floor: f64,
    /// Seed for the Poisson arrival process.
    pub seed: u64,
    /// Batch dispatch-shape policy (padded / exact / auto). Collapses to
    /// `Padded` on runtimes that prefer fixed shapes (gated PJRT).
    pub dispatch: DispatchPolicy,
    /// KV pool: positions per block (`0` = pool default). Decode workloads
    /// only; single-shot workloads never build a pool.
    pub kv_block: usize,
    /// KV pool capacity in blocks (`0` = unbounded). A run that outgrows
    /// the cap fails fast with a clear error instead of thrashing.
    pub kv_blocks: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        Self {
            workers: 2,
            rate: 200.0,
            requests: 256,
            max_batch: 16,
            max_wait: 0.01,
            queue_cap: 1024,
            exec_floor: 0.0,
            seed: 7,
            dispatch: DispatchPolicy::Auto,
            kv_block: 0,
            kv_blocks: 0,
        }
    }
}

impl EngineOpts {
    /// Reject degenerate configurations with clear errors instead of
    /// silently shedding everything (`queue_cap == 0`), spinning on empty
    /// batches (`max_batch == 0`), or deadlocking (`workers == 0`).
    fn validate(&self) -> Result<()> {
        if self.requests == 0 {
            bail!("run_engine: requests must be > 0");
        }
        if self.max_batch == 0 {
            bail!("run_engine: max_batch must be > 0 (got 0 — no batch could ever form)");
        }
        if self.queue_cap == 0 {
            bail!("run_engine: queue_cap must be > 0 (got 0 — every arrival would be shed)");
        }
        if self.workers == 0 {
            bail!("run_engine: workers must be > 0 (got 0 — nothing would drain the queue)");
        }
        Ok(())
    }
}

#[cfg(not(pjrt_backend))]
impl EngineOpts {
    /// Pool knobs for a decode unit's plan (prefix sharing always on; the
    /// workload decides whether prompts actually share openings).
    fn kv_pool_opts(&self) -> KvPoolOpts {
        let mut o = KvPoolOpts::default();
        if self.kv_block > 0 {
            o.block = self.kv_block;
        }
        o.max_blocks = self.kv_blocks;
        o
    }
}

/// Per-request accounting (one row per *served* request).
#[derive(Debug, Clone)]
pub struct RequestRecord {
    /// Request id; doubles as the eval-stream index the workload
    /// synthesized the payload from. Ids are per fleet member.
    pub id: usize,
    /// Intended arrival → first dequeue into a batch, ms.
    pub queue_ms: f64,
    /// Execution time of the batch carrying this request's *final* step, ms.
    pub exec_ms: f64,
    /// Intended arrival → completion of the final step, ms.
    pub total_ms: f64,
    /// Engine steps (batches) this request rode in: 1 for single-shot
    /// workloads; prefill + decode continuations for generation.
    pub steps: usize,
    /// Intended arrival → end of the first step, ms (time-to-first-token
    /// for generation; == `total_ms` when `steps == 1`).
    pub first_ms: f64,
    /// Mean inter-step time, ms — `(total − first) / (steps − 1)`; 0 when
    /// `steps == 1`. For generation this is the mean inter-token time.
    pub itl_ms: f64,
    /// Workload prediction (vision: class; text: next-token id; generation:
    /// final generated token).
    pub pred: i32,
    /// Tokens charged to this request (vision: 1; text: prompt length;
    /// generation: prompt + generated).
    pub tokens: usize,
}

/// Aggregate result of one engine run (per fleet member).
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub served: usize,
    /// Requests shed at the full queue.
    pub shed: usize,
    /// Batches executed.
    pub batches: usize,
    /// Mean requests carried per executed batch.
    pub mean_batch: f64,
    /// Mean batch size actually *dispatched* (= artifact batch under the
    /// padded policy; = mean_batch under exact; in between under auto).
    pub mean_dispatch: f64,
    /// Mean engine steps per served request (1.0 for single-shot
    /// workloads; prefill + decode steps for generation).
    pub steps_mean: f64,
    /// p50 / p95 of total per-request latency, ms.
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// p50 queueing delay, ms.
    pub queue_p50_ms: f64,
    /// p50 time to the end of a request's first step, ms (TTFT for
    /// generation workloads).
    pub first_p50_ms: f64,
    /// Mean inter-step (inter-token) time over multi-step requests, ms.
    pub itl_mean_ms: f64,
    /// Mean per-batch execution time, ms.
    pub exec_mean_ms: f64,
    /// Served requests per second of wall time.
    pub throughput_fps: f64,
    /// Served tokens per second of wall time (== throughput_fps for the
    /// vision workload, where every request is one image).
    pub throughput_tps: f64,
    /// Mean K/V bytes appended to the paged cache per KV-cache dispatch
    /// (0 for single-shot workloads and prefill-mode decode). Appends touch
    /// only the fresh rows, so this scales with tokens fed per step —
    /// independent of `n_ctx` capacity.
    pub kv_bytes_per_step: f64,
    /// High-water bytes of live KV pool blocks over the run.
    pub kv_peak_bytes: u64,
    /// Pool blocks still held at the end of the run (registered shared
    /// prefixes; completed sequences release theirs as they finish).
    pub kv_blocks_in_use: usize,
    /// Cumulative KV block allocations (fresh or recycled).
    pub kv_allocs: u64,
    /// Blocks adopted from the shared-prefix registry instead of allocated
    /// and recomputed.
    pub kv_shared_hits: u64,
    /// Copy-on-write block copies (a shared tail diverged).
    pub kv_cow_copies: u64,
    /// Per-request records, sorted by id.
    pub records: Vec<RequestRecord>,
}

/// One model + workload bound into a fleet run (see [`run_fleet`]).
pub struct FleetMember<'x, 'rt, 'w, W: Workload> {
    pub exec: &'x Executor<'rt>,
    pub weights: &'w WeightStore,
    pub workload: &'x W,
    /// Requests offered for this member ([`EngineOpts::requests`] is
    /// ignored by [`run_fleet`]).
    pub requests: usize,
}

/// A request (or a re-enqueued continuation) sitting in the engine queue.
#[cfg(not(pjrt_backend))]
struct Queued {
    unit: usize,
    id: usize,
    arrival: Instant,
    /// Steps completed so far.
    steps: usize,
    first_deq: Option<Instant>,
    first_done: Option<Instant>,
}

/// Queue state shared between the generator and the workers.
#[cfg(not(pjrt_backend))]
struct Shared {
    queue: VecDeque<Queued>,
    closed: bool,
    /// Shed arrivals, per fleet unit.
    shed: Vec<usize>,
}

/// A type-erased fleet unit: the workload, its resolved plans, and its
/// pre-synthesized payloads, closed over a step function so units with
/// different `Workload::Req` types share one queue and one worker pool.
#[cfg(not(pjrt_backend))]
struct Unit<'s> {
    label: &'static str,
    requests: usize,
    policy: DispatchPolicy,
    #[allow(clippy::type_complexity)]
    step: Box<dyn Fn(&[usize], usize) -> Result<Vec<StepOutcome>> + Sync + 's>,
    /// KV-cache telemetry snapshot: `(dispatches, appended bytes, pool)`;
    /// `None` for units without a decode plan.
    #[allow(clippy::type_complexity)]
    kv: Box<dyn Fn() -> Option<(u64, u64, KvPoolStats)> + Sync + 's>,
}

/// Build one unit: resolve the plans, pre-synthesize every payload (request
/// id == eval-stream index, so data synthesis never pollutes the timed
/// region), and warm the dispatch path before the clock starts.
#[cfg(not(pjrt_backend))]
fn make_unit<'s, W: Workload>(
    exec: &Executor<'s>,
    w: &'s WeightStore,
    workload: &'s W,
    requests: usize,
    max_batch: usize,
    policy: DispatchPolicy,
    kv_opts: KvPoolOpts,
) -> Result<Unit<'s>> {
    let cfg = exec.cfg;
    if workload.cfg() != cfg {
        bail!(
            "workload '{}' drives model '{}', executor is bound to '{}'",
            workload.label(),
            workload.cfg().name,
            cfg.name
        );
    }
    // Resolve exactly the plan the workload dispatches through: decode
    // workloads never touch the full-forward plan (the decode plan owns its
    // own prefill fallback), and resolving both would shape-check every
    // parameter twice and warm names that are never dispatched. Plans are
    // shared (`Arc`) between the step closure and the telemetry closure.
    let plans = Arc::new(match workload.decode() {
        Some(mode) => Plans {
            fwd: None,
            dec: Some(exec.decode_plan_opts(
                w,
                mode.resolve(exec.rt.prefers_fixed_shapes()),
                kv_opts,
            )?),
        },
        None => Plans { fwd: Some(exec.forward_plan(w)?), dec: None },
    });
    let payloads: Vec<W::Req> = threads::parallel_map(requests, |i| workload.synth(i));

    // Warmup before the clock starts: run the full artifact batch AND batch
    // size 1 (first-touch allocation, PJRT compilation when gated in), and
    // under exact/auto dispatch pre-populate the plans' artifact-name
    // caches for every size a batch could dispatch at — so no batch pays
    // first-use name formatting inside its timed region. Warm payloads are
    // synthesized *past* the request id range: multi-step workloads carry
    // per-request state, and warmup must never pre-advance a real request.
    {
        let warm: Vec<W::Req> = (0..max_batch + 1).map(|i| workload.synth(requests + i)).collect();
        let refs: Vec<&W::Req> = warm.iter().take(max_batch).collect();
        workload.run_step(&plans, &refs, max_batch)?;
        if policy != DispatchPolicy::Padded {
            workload.run_step(&plans, &[&warm[max_batch]], 1)?;
            for b in 1..=max_batch {
                if let Some(f) = &plans.fwd {
                    f.artifact(b);
                }
                if let Some(d) = &plans.dec {
                    d.warm_names(b);
                }
            }
        } else if let Some(d) = &plans.dec {
            d.warm_names(max_batch);
        }
    }

    // Baseline counters after warmup, so per-step means cover only the
    // measured run (pool-level stats like peak blocks keep warmup — the
    // registry it warmed stays live).
    let (kv_s0, kv_b0) = plans.dec.as_ref().map(|d| d.kv_counters()).unwrap_or((0, 0));
    let kv_plans = plans.clone();
    Ok(Unit {
        label: workload.label(),
        requests,
        policy,
        step: Box::new(move |ids: &[usize], dispatch: usize| {
            let reqs: Vec<&W::Req> = ids.iter().map(|&i| &payloads[i]).collect();
            workload.run_step(&plans, &reqs, dispatch)
        }),
        kv: Box::new(move || {
            kv_plans.dec.as_ref().map(|d| {
                let (s, b) = d.kv_counters();
                (s - kv_s0, b - kv_b0, d.pool_stats().unwrap_or_default())
            })
        }),
    })
}

/// Run the engine: offered load is `opts.requests` workload-synthesized
/// requests (request id == eval-stream index) at `opts.rate` req/s; returns
/// per-request accounting plus aggregates. The weight store may be dense,
/// pruned, or compensated — the batch-polymorphic plans dispatch at
/// whatever shapes they find, and the workload decides what a request *is*
/// (including multi-step generation via re-enqueued continuations).
#[cfg(not(pjrt_backend))]
pub fn run_engine<W: Workload>(
    exec: &Executor<'_>,
    w: &WeightStore,
    workload: &W,
    opts: &EngineOpts,
) -> Result<EngineStats> {
    opts.validate()?;
    let policy = opts.dispatch.resolve(exec.rt.prefers_fixed_shapes());
    let unit =
        make_unit(exec, w, workload, opts.requests, opts.max_batch, policy, opts.kv_pool_opts())?;
    let mut stats = run_units(vec![unit], opts)?;
    Ok(stats.remove(0))
}

/// Run two workloads — possibly over different models — through one queue
/// and one worker pool: a mixed fleet. Member arrivals interleave
/// round-robin (a.0, b.0, a.1, b.1, …) on one seeded Poisson schedule;
/// workers form single-unit batches, so a dispatch never mixes models.
/// Returns per-member stats in argument order. Per-example math makes each
/// member's outputs identical to a single-workload [`run_engine`] run with
/// the same seeds — asserted by `tests/serve_engine`.
#[cfg(not(pjrt_backend))]
pub fn run_fleet<A: Workload, B: Workload>(
    a: FleetMember<'_, '_, '_, A>,
    b: FleetMember<'_, '_, '_, B>,
    opts: &EngineOpts,
) -> Result<[EngineStats; 2]> {
    EngineOpts { requests: a.requests + b.requests, ..opts.clone() }.validate()?;
    if a.requests == 0 || b.requests == 0 {
        bail!("run_fleet: every member needs at least one request");
    }
    let pa = opts.dispatch.resolve(a.exec.rt.prefers_fixed_shapes());
    let pb = opts.dispatch.resolve(b.exec.rt.prefers_fixed_shapes());
    let kv = opts.kv_pool_opts();
    let ua = make_unit(a.exec, a.weights, a.workload, a.requests, opts.max_batch, pa, kv)?;
    let ub = make_unit(b.exec, b.weights, b.workload, b.requests, opts.max_batch, pb, kv)?;
    let mut stats = run_units(vec![ua, ub], opts)?;
    let sb = stats.remove(1);
    let sa = stats.remove(0);
    Ok([sa, sb])
}

/// The shared queueing/batching core: one generator, one bounded queue, one
/// worker pool over any number of type-erased units.
#[cfg(not(pjrt_backend))]
fn run_units(units: Vec<Unit<'_>>, opts: &EngineOpts) -> Result<Vec<EngineStats>> {
    let b_art = opts.max_batch;
    let workers = opts.workers;
    let total: usize = units.iter().map(|u| u.requests).sum();

    // Deterministic round-robin interleave of unit arrivals: (unit, id)
    // pairs in offered order, independent of timing.
    let mut order: Vec<(usize, usize)> = Vec::with_capacity(total);
    {
        let mut issued = vec![0usize; units.len()];
        while order.len() < total {
            for (u, unit) in units.iter().enumerate() {
                if issued[u] < unit.requests {
                    order.push((u, issued[u]));
                    issued[u] += 1;
                }
            }
        }
    }

    // Seeded Poisson arrival offsets (seconds from engine start).
    let rate = if opts.rate.is_finite() && opts.rate > 0.0 { opts.rate } else { f64::INFINITY };
    let mut rng = Pcg64::new(opts.seed);
    let mut arrivals = Vec::with_capacity(total);
    let mut t = 0.0f64;
    for _ in 0..total {
        t += -rng.uniform().max(1e-12).ln() / rate;
        arrivals.push(t);
    }

    let shared =
        Mutex::new(Shared { queue: VecDeque::new(), closed: false, shed: vec![0; units.len()] });
    let cv = Condvar::new();
    let results: Mutex<Vec<Vec<RequestRecord>>> = Mutex::new(vec![Vec::new(); units.len()]);
    // Per executed batch: (unit, requests carried, dispatch size, exec ms).
    let batches: Mutex<Vec<(usize, usize, usize, f64)>> = Mutex::new(Vec::new());
    let wait_dur = Duration::from_secs_f64(opts.max_wait.max(0.0));
    let wall0 = Instant::now();

    std::thread::scope(|s| -> Result<()> {
        // ---- open-loop generator ----
        s.spawn(|| {
            'replay: for (&(unit, id), &at) in order.iter().zip(&arrivals) {
                loop {
                    // A failed worker poisons the run by setting `closed`;
                    // stop replaying the schedule so the error surfaces
                    // promptly instead of after the full arrival tail.
                    if shared.lock().unwrap().closed {
                        break 'replay;
                    }
                    let now = wall0.elapsed().as_secs_f64();
                    if now >= at {
                        break;
                    }
                    std::thread::sleep(Duration::from_secs_f64((at - now).min(0.005)));
                }
                let mut g = shared.lock().unwrap();
                if g.closed {
                    break 'replay;
                }
                if g.queue.len() >= opts.queue_cap {
                    g.shed[unit] += 1;
                } else {
                    g.queue.push_back(Queued {
                        unit,
                        id,
                        arrival: wall0 + Duration::from_secs_f64(at),
                        steps: 0,
                        first_deq: None,
                        first_done: None,
                    });
                    cv.notify_one();
                }
            }
            shared.lock().unwrap().closed = true;
            cv.notify_all();
        });

        // ---- worker pool ----
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| -> Result<()> {
                    threads::serialize_nested_regions();
                    loop {
                        let mut batch: Vec<Queued> = Vec::with_capacity(b_art);
                        {
                            let mut g = shared.lock().unwrap();
                            // Block for the batch head (or a clean shutdown).
                            loop {
                                if let Some(q) = g.queue.pop_front() {
                                    batch.push(q);
                                    break;
                                }
                                if g.closed {
                                    return Ok(());
                                }
                                g = cv.wait(g).unwrap();
                            }
                            // Hold the batch open until full, closed, or the
                            // batching deadline expires — draining only
                            // requests of the head's unit (a batch never
                            // mixes models).
                            let unit = batch[0].unit;
                            let deadline = Instant::now() + wait_dur;
                            loop {
                                let mut i = 0;
                                while batch.len() < b_art && i < g.queue.len() {
                                    if g.queue[i].unit == unit {
                                        batch.push(g.queue.remove(i).expect("indexed item"));
                                    } else {
                                        i += 1;
                                    }
                                }
                                if batch.len() >= b_art || g.closed {
                                    break;
                                }
                                let now = Instant::now();
                                if now >= deadline {
                                    break;
                                }
                                let (g2, _) = cv.wait_timeout(g, deadline - now).unwrap();
                                g = g2;
                            }
                            // Hand leftover work to an idle worker: our
                            // wait_timeout may have consumed its wakeup.
                            if !g.queue.is_empty() {
                                cv.notify_one();
                            }
                        }
                        let unit = batch[0].unit;
                        let take = batch.len();
                        let dispatch = units[unit].policy.dispatch_size(take, b_art);
                        let t_deq = Instant::now();
                        for q in batch.iter_mut() {
                            if q.first_deq.is_none() {
                                q.first_deq = Some(t_deq);
                            }
                        }
                        let ids: Vec<usize> = batch.iter().map(|q| q.id).collect();
                        // On any workload failure, poison the run (`closed`
                        // stops the generator's replay and drains the other
                        // workers) so the error surfaces promptly instead
                        // of after the full arrival schedule.
                        let poison = || {
                            shared.lock().unwrap().closed = true;
                            cv.notify_all();
                        };
                        let outs: Vec<StepOutcome> = match (units[unit].step)(&ids, dispatch) {
                            Ok(outs) => outs,
                            Err(e) => {
                                poison();
                                return Err(e);
                            }
                        };
                        if outs.len() != batch.len() {
                            // Fail fast on a broken Workload impl rather
                            // than silently dropping records (served + shed
                            // == requests must hold per unit).
                            poison();
                            bail!(
                                "workload '{}' returned {} outcomes for a batch of {}",
                                units[unit].label,
                                outs.len(),
                                batch.len()
                            );
                        }
                        if opts.exec_floor > 0.0 {
                            let spent = t_deq.elapsed().as_secs_f64();
                            if spent < opts.exec_floor {
                                std::thread::sleep(Duration::from_secs_f64(
                                    opts.exec_floor - spent,
                                ));
                            }
                        }
                        let t_done = Instant::now();
                        let exec_ms =
                            t_done.saturating_duration_since(t_deq).as_secs_f64() * 1e3;
                        let mut requeue: Vec<Queued> = Vec::new();
                        {
                            let mut recs = results.lock().unwrap();
                            for (mut q, out) in batch.into_iter().zip(outs) {
                                q.steps += 1;
                                if q.first_done.is_none() {
                                    q.first_done = Some(t_done);
                                }
                                match out {
                                    StepOutcome::Done(o) => {
                                        let first = q.first_done.expect("set above");
                                        let first_ms = first
                                            .saturating_duration_since(q.arrival)
                                            .as_secs_f64()
                                            * 1e3;
                                        let total_ms = t_done
                                            .saturating_duration_since(q.arrival)
                                            .as_secs_f64()
                                            * 1e3;
                                        recs[q.unit].push(RequestRecord {
                                            id: q.id,
                                            queue_ms: q
                                                .first_deq
                                                .expect("set above")
                                                .saturating_duration_since(q.arrival)
                                                .as_secs_f64()
                                                * 1e3,
                                            exec_ms,
                                            total_ms,
                                            steps: q.steps,
                                            first_ms,
                                            itl_ms: if q.steps > 1 {
                                                (total_ms - first_ms) / (q.steps - 1) as f64
                                            } else {
                                                0.0
                                            },
                                            pred: o.pred,
                                            tokens: o.tokens,
                                        });
                                    }
                                    StepOutcome::Continue => requeue.push(q),
                                }
                            }
                        }
                        batches.lock().unwrap().push((unit, take, dispatch, exec_ms));
                        if !requeue.is_empty() {
                            // Continuations of admitted requests bypass the
                            // queue bound: shedding one mid-generation would
                            // strand its state and break served + shed
                            // accounting.
                            let mut g = shared.lock().unwrap();
                            for q in requeue {
                                g.queue.push_back(q);
                            }
                            cv.notify_one();
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("serve worker panicked")?;
        }
        Ok(())
    })?;

    let total_s = wall0.elapsed().as_secs_f64();
    let shed = std::mem::take(&mut shared.lock().unwrap().shed);
    let per_unit = results.into_inner().unwrap();
    let batch_log = batches.into_inner().unwrap();

    let mut out = Vec::with_capacity(units.len());
    for (u, mut records) in per_unit.into_iter().enumerate() {
        records.sort_by_key(|r| r.id);
        let mut totals: Vec<f64> = records.iter().map(|r| r.total_ms).collect();
        totals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut queues: Vec<f64> = records.iter().map(|r| r.queue_ms).collect();
        queues.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut firsts: Vec<f64> = records.iter().map(|r| r.first_ms).collect();
        firsts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let multi: Vec<&RequestRecord> = records.iter().filter(|r| r.steps > 1).collect();
        let ub: Vec<&(usize, usize, usize, f64)> =
            batch_log.iter().filter(|&&(bu, _, _, _)| bu == u).collect();
        let n_batches = ub.len();
        let tokens: usize = records.iter().map(|r| r.tokens).sum();
        let (kv_steps, kv_bytes, kv_pool) =
            (units[u].kv)().unwrap_or((0, 0, KvPoolStats::default()));
        out.push(EngineStats {
            served: records.len(),
            shed: shed[u],
            batches: n_batches,
            mean_batch: if n_batches == 0 {
                0.0
            } else {
                ub.iter().map(|&&(_, take, _, _)| take).sum::<usize>() as f64 / n_batches as f64
            },
            mean_dispatch: if n_batches == 0 {
                0.0
            } else {
                ub.iter().map(|&&(_, _, d, _)| d).sum::<usize>() as f64 / n_batches as f64
            },
            steps_mean: if records.is_empty() {
                0.0
            } else {
                records.iter().map(|r| r.steps).sum::<usize>() as f64 / records.len() as f64
            },
            p50_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.50) },
            p95_ms: if totals.is_empty() { 0.0 } else { percentile(&totals, 0.95) },
            queue_p50_ms: if queues.is_empty() { 0.0 } else { percentile(&queues, 0.50) },
            first_p50_ms: if firsts.is_empty() { 0.0 } else { percentile(&firsts, 0.50) },
            itl_mean_ms: if multi.is_empty() {
                0.0
            } else {
                multi.iter().map(|r| r.itl_ms).sum::<f64>() / multi.len() as f64
            },
            exec_mean_ms: if n_batches == 0 {
                0.0
            } else {
                ub.iter().map(|&&(_, _, _, ms)| ms).sum::<f64>() / n_batches as f64
            },
            throughput_fps: records.len() as f64 / total_s.max(1e-12),
            throughput_tps: tokens as f64 / total_s.max(1e-12),
            kv_bytes_per_step: if kv_steps == 0 { 0.0 } else { kv_bytes as f64 / kv_steps as f64 },
            kv_peak_bytes: kv_pool.peak_bytes(),
            kv_blocks_in_use: kv_pool.blocks_in_use,
            kv_allocs: kv_pool.allocs,
            kv_shared_hits: kv_pool.shared_hits,
            kv_cow_copies: kv_pool.cow_copies,
            records,
        });
    }
    Ok(out)
}

/// Deliberate compile-out for the `--cfg pjrt_backend` build: the engine
/// shares one `Runtime` across scoped worker threads, which requires the
/// backend to be `Sync`; the vendored PJRT client/executable types are not
/// known to satisfy that, so instead of a crate-wide build break the
/// gated build gets a stub that fails fast. Closed-loop [`super::measure`]
/// remains the serving measurement on that path (and keeps the padded
/// fixed-shape dispatch — see [`DispatchPolicy::resolve`]).
#[cfg(pjrt_backend)]
pub fn run_engine<W: Workload>(
    _exec: &Executor<'_>,
    _w: &WeightStore,
    _workload: &W,
    _opts: &EngineOpts,
) -> Result<EngineStats> {
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads); use serve::measure"
    )
}

/// Stub mirror of the fleet entry point for the gated build (see
/// [`run_engine`] above).
#[cfg(pjrt_backend)]
pub fn run_fleet<A: Workload, B: Workload>(
    _a: FleetMember<'_, '_, '_, A>,
    _b: FleetMember<'_, '_, '_, B>,
    _opts: &EngineOpts,
) -> Result<[EngineStats; 2]> {
    bail!(
        "the concurrent serving engine is unavailable in the pjrt_backend build \
         (PJRT executables are not shared across threads); use serve::measure"
    )
}

#[cfg(all(test, not(pjrt_backend)))]
mod tests {
    use super::*;

    #[test]
    fn default_opts_sane() {
        let o = EngineOpts::default();
        assert!(o.workers >= 1 && o.max_batch >= 1);
        assert!(o.queue_cap >= o.max_batch);
        assert!(o.max_wait >= 0.0 && o.exec_floor == 0.0);
        assert_eq!(o.dispatch, DispatchPolicy::Auto);
        assert!(o.validate().is_ok());
    }

    #[test]
    fn degenerate_opts_rejected() {
        for (opts, needle) in [
            (EngineOpts { requests: 0, ..Default::default() }, "requests"),
            (EngineOpts { max_batch: 0, ..Default::default() }, "max_batch"),
            (EngineOpts { queue_cap: 0, ..Default::default() }, "queue_cap"),
            (EngineOpts { workers: 0, ..Default::default() }, "workers"),
        ] {
            let err = opts.validate().unwrap_err().to_string();
            assert!(err.contains(needle), "{err}");
        }
    }
}
