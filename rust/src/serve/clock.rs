//! Clock abstraction for the serving engine.
//!
//! The engine's arrival generation, batch-formation deadlines, and the
//! controller's tick cadence all consume time through the [`Clock`] trait
//! instead of touching `std::time` directly. Production uses [`WallClock`]
//! (monotonic `Instant` under the hood); tests and the discrete-event
//! simulator ([`crate::serve::sim`]) use [`VirtualClock`], whose `sleep`
//! *advances* simulated time instead of blocking, so controller
//! trajectories are bit-reproducible under `cargo test` — no wall-clock
//! jitter ever enters the arithmetic. Determinism of a run then rests
//! entirely on the seeded RNGs feeding arrivals and service-time jitter.
//!
//! Time is represented as `f64` seconds since the clock's origin (engine
//! start). Sub-microsecond precision is irrelevant at serving timescales
//! and `f64` keeps deadline math trivial and portable across both impls.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Source of time for the serving engine: `now` in seconds since the
/// clock's origin, and `sleep` for a non-negative duration in seconds.
pub trait Clock: Sync {
    /// Seconds elapsed since the clock's origin.
    fn now(&self) -> f64;
    /// Block (wall clock) or advance (virtual clock) for `secs` seconds.
    /// Negative or non-finite values are treated as zero.
    fn sleep(&self, secs: f64);
}

/// Production clock: monotonic wall time relative to construction.
pub struct WallClock {
    origin: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { origin: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    fn sleep(&self, secs: f64) {
        if secs.is_finite() && secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }
}

/// Deterministic clock: time only moves when something advances it.
///
/// `sleep` advances the clock by the requested amount, which is exactly
/// the semantics a single-threaded discrete-event loop wants. The current
/// time is stored as `f64` bits in an `AtomicU64` so the clock is `Sync`
/// without a lock (writers in the simulator are single-threaded; readers
/// may be anywhere).
pub struct VirtualClock {
    now_bits: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now_bits: AtomicU64::new(0f64.to_bits()) }
    }

    /// Advance simulated time by `secs` (no-op for non-positive values).
    pub fn advance(&self, secs: f64) {
        if secs.is_finite() && secs > 0.0 {
            self.set(self.now() + secs);
        }
    }

    /// Jump simulated time to `t` seconds. Time never moves backwards:
    /// a target earlier than `now` leaves the clock untouched.
    pub fn set(&self, t: f64) {
        if t.is_finite() && t > self.now() {
            self.now_bits.store(t.to_bits(), Ordering::Release);
        }
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        f64::from_bits(self.now_bits.load(Ordering::Acquire))
    }

    fn sleep(&self, secs: f64) {
        self.advance(secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_monotone() {
        let c = WallClock::new();
        let a = c.now();
        c.sleep(0.001);
        let b = c.now();
        assert!(b >= a, "wall clock went backwards: {a} -> {b}");
        c.sleep(-1.0); // must not panic
        c.sleep(f64::NAN);
    }

    #[test]
    fn virtual_clock_sleep_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.sleep(0.5);
        assert_eq!(c.now(), 0.5);
        c.advance(0.25);
        assert_eq!(c.now(), 0.75);
        c.sleep(-3.0);
        c.advance(f64::NAN);
        assert_eq!(c.now(), 0.75);
    }

    #[test]
    fn virtual_clock_set_never_rewinds() {
        let c = VirtualClock::new();
        c.set(2.0);
        assert_eq!(c.now(), 2.0);
        c.set(1.0);
        assert_eq!(c.now(), 2.0);
        c.set(3.5);
        assert_eq!(c.now(), 3.5);
    }
}
