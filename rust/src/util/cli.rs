//! Tiny declarative CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments, and
//! generated `--help` text. Used by `src/main.rs` and the examples.

use std::collections::BTreeMap;

/// Parsed arguments: flags, key-value options, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid { key: String, value: String, why: String },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} expects a value"),
            CliError::Invalid { key, value, why } => {
                write!(f, "invalid value for --{key}: {value} ({why})")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Option specification used for validation + help.
pub struct Spec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub specs: Vec<Spec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: &'static str) -> Self {
        self.specs.push(Spec { name, takes_value: true, help, default: Some(default) });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, takes_value: false, help, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for spec in &self.specs {
            let v = if spec.takes_value { " <value>" } else { "" };
            let d = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{v}\t{}{d}\n", spec.name, spec.help));
        }
        s
    }

    /// Parse a raw argv slice (without the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut out = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                out.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    out.opts.insert(key, v);
                } else {
                    out.flags.push(key);
                }
            } else {
                out.pos.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str) -> String {
        self.get(key).unwrap_or("").to_string()
    }

    pub fn usize(&self, key: &str) -> Result<usize, CliError> {
        let v = self.str(key);
        v.parse().map_err(|e| CliError::Invalid { key: key.into(), value: v, why: format!("{e}") })
    }

    pub fn f64(&self, key: &str) -> Result<f64, CliError> {
        let v = self.str(key);
        v.parse().map_err(|e| CliError::Invalid { key: key.into(), value: v, why: format!("{e}") })
    }

    pub fn f32(&self, key: &str) -> Result<f32, CliError> {
        Ok(self.f64(key)? as f32)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("prune", "run CORP")
            .opt("model", "model size", "base")
            .opt("sparsity", "target sparsity", "0.5")
            .flag("no-comp", "disable compensation")
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.str("model"), "base");
        assert_eq!(a.f64("sparsity").unwrap(), 0.5);
        assert!(!a.has_flag("no-comp"));
    }

    #[test]
    fn parse_separate_and_inline_values() {
        let a = cmd().parse(&sv(&["--model", "huge", "--sparsity=0.7", "--no-comp", "pos1"])).unwrap();
        assert_eq!(a.str("model"), "huge");
        assert_eq!(a.f64("sparsity").unwrap(), 0.7);
        assert!(a.has_flag("no-comp"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(cmd().parse(&sv(&["--bogus"])), Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(cmd().parse(&sv(&["--model"])), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn invalid_number_rejected() {
        let a = cmd().parse(&sv(&["--sparsity", "abc"])).unwrap();
        assert!(matches!(a.f64("sparsity"), Err(CliError::Invalid { .. })));
    }

    #[test]
    fn usage_mentions_options() {
        let u = cmd().usage();
        assert!(u.contains("--model"));
        assert!(u.contains("--no-comp"));
    }
}
