//! Mini benchmark harness (criterion is not available offline).
//!
//! Provides warmup + timed iterations with mean / p50 / p95 statistics, and a
//! CSV emitter so every paper table/figure bench under `rust/benches/` can
//! both print paper-shaped rows and persist machine-readable results.

use std::time::Instant;

/// Result of a timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

/// Time `f` with `warmup` unrecorded runs then `iters` recorded runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    stats_from(name, &samples)
}

/// Compute stats from raw per-iteration samples (used when the caller does
/// its own timing, e.g. latency-per-request inside the serve engine).
pub fn stats_from(name: &str, samples: &[f64]) -> BenchStats {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    BenchStats {
        name: name.to_string(),
        iters: sorted.len(),
        mean_s: mean,
        p50_s: percentile(&sorted, 0.50),
        p95_s: percentile(&sorted, 0.95),
        min_s: sorted[0],
    }
}

/// Percentile of an ascending-sorted slice (nearest-rank with interpolation).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// CSV writer for bench results: one header + rows, written under `results/`.
pub struct CsvWriter {
    path: std::path::PathBuf,
    lines: Vec<String>,
}

impl CsvWriter {
    pub fn new(name: &str, header: &str) -> Self {
        let dir = std::path::Path::new("results");
        let _ = std::fs::create_dir_all(dir);
        Self { path: dir.join(format!("{name}.csv")), lines: vec![header.to_string()] }
    }

    pub fn row(&mut self, cols: &[String]) {
        self.lines.push(cols.join(","));
    }

    pub fn flush(&self) -> std::io::Result<()> {
        std::fs::write(&self.path, self.lines.join("\n") + "\n")
    }
}

/// Benchmark mode read from `CORP_BENCH_MODE`: scales workloads.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BenchMode {
    /// CI smoke: tiny sizes, single points.
    Smoke,
    /// Default: small models, reduced sweeps — minutes, not hours.
    Fast,
    /// Full reproduction sweep.
    Full,
}

pub fn bench_mode() -> BenchMode {
    match std::env::var("CORP_BENCH_MODE").as_deref() {
        Ok("smoke") => BenchMode::Smoke,
        Ok("full") => BenchMode::Full,
        _ => BenchMode::Fast,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_iters() {
        let s = bench("noop", 1, 10, || 1 + 1);
        assert_eq!(s.iters, 10);
        assert!(s.mean_s >= 0.0);
        assert!(s.p50_s <= s.p95_s);
        assert!(s.min_s <= s.mean_s * 1.0001);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&v, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&v, 1.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&v, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn csv_writer_writes() {
        let mut w = CsvWriter::new("_test_bench_csv", "a,b");
        w.row(&["1".into(), "2".into()]);
        w.flush().unwrap();
        let content = std::fs::read_to_string("results/_test_bench_csv.csv").unwrap();
        assert!(content.starts_with("a,b\n1,2"));
        let _ = std::fs::remove_file("results/_test_bench_csv.csv");
    }

    #[test]
    fn throughput_inverse_of_mean() {
        let s = BenchStats { name: "x".into(), iters: 1, mean_s: 0.5, p50_s: 0.5, p95_s: 0.5, min_s: 0.5 };
        assert!((s.throughput(16.0) - 32.0).abs() < 1e-9);
    }
}
