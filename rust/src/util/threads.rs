//! Scoped worker pool for the numeric hot paths (no external deps).
//!
//! Built on `std::thread::scope`: callers hand over either an index range
//! ([`parallel_map`]), a mutable buffer split into row blocks
//! ([`parallel_chunks_mut`]), or a list of owned work items
//! ([`parallel_items`]). Workers are spawned per call — at the granularity
//! the pipeline uses (row panels of a GEMM, per-layer compensation solves)
//! spawn cost is noise next to the work, and scoped threads keep every
//! borrow safe without `Arc`.
//!
//! Worker count: `CORP_THREADS` env var, else `available_parallelism()`.
//! [`with_threads`] scopes an override (used by the thread-invariance tests
//! and the bench harness sweep). Nested parallel regions run serial: a
//! worker thread sees [`threads`]` == 1`, so a parallel `Mat::mul` inside a
//! parallel per-layer compensation task never oversubscribes the host.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

static OVERRIDE: AtomicUsize = AtomicUsize::new(0); // 0 = no override
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());
static DEFAULT: OnceLock<usize> = OnceLock::new();

thread_local! {
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

fn default_threads() -> usize {
    *DEFAULT.get_or_init(|| {
        if let Ok(v) = std::env::var("CORP_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Effective worker count for a parallel region started on this thread.
/// Returns 1 inside a pool worker (nested regions run serial).
pub fn threads() -> usize {
    if IN_POOL.with(|f| f.get()) {
        return 1;
    }
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => default_threads(),
        n => n,
    }
}

/// Run `f` with the worker count pinned to `n`. Overrides are process-global,
/// so concurrent `with_threads` calls (e.g. the test harness) serialize on an
/// internal lock; the override is restored even if `f` panics. The lock is
/// not reentrant — do not nest `with_threads` calls.
pub fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let _guard = OVERRIDE_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.store(self.0, Ordering::SeqCst);
        }
    }
    let prev = OVERRIDE.swap(n.max(1), Ordering::SeqCst);
    let _restore = Restore(prev);
    f()
}

fn mark_in_pool() {
    serialize_nested_regions();
}

/// Mark the calling thread as a pool worker for the rest of its lifetime:
/// every parallel region started on it runs serial ([`threads`] returns 1).
///
/// The pool's own workers are marked automatically; this hook exists for
/// long-lived threads spawned *outside* the pool that still execute
/// pool-using code — the serving engine's batch executors call it so that a
/// per-request forward pass does not fan out a nested pool per worker and
/// oversubscribe the host (total parallelism stays at the engine's worker
/// count).
pub fn serialize_nested_regions() {
    IN_POOL.with(|f| f.set(true));
}

/// Map `f` over `0..n` on the pool; results are returned in index order.
/// Work is distributed dynamically (atomic cursor), so uneven task costs
/// balance across workers.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let w = threads().min(n);
    if w <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..w)
            .map(|_| {
                s.spawn(|| {
                    mark_in_pool();
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("pool worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|o| o.expect("pool produced no result for an index")).collect()
}

/// Split `data` into consecutive chunks of `chunk` elements (last may be
/// short) and run `f(chunk_index, chunk)` on the pool. Chunks are assigned
/// round-robin, so for equal-cost chunks the partition is deterministic in
/// the chunk count — and because each chunk is processed start-to-finish by
/// exactly one worker, results are bitwise independent of the worker count.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "parallel_chunks_mut: chunk must be > 0");
    let n_chunks = data.len().div_ceil(chunk);
    let w = threads().min(n_chunks);
    if w <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, &mut [T])>> = Vec::with_capacity(w);
    buckets.resize_with(w, Vec::new);
    for (i, c) in data.chunks_mut(chunk).enumerate() {
        buckets[i % w].push((i, c));
    }
    std::thread::scope(|s| {
        for bucket in buckets {
            let fr = &f;
            s.spawn(move || {
                mark_in_pool();
                for (i, c) in bucket {
                    fr(i, c);
                }
            });
        }
    });
}

/// Consume a list of owned work items on the pool (round-robin assignment).
/// Used where each item carries its own `&mut` state, e.g. per-layer
/// calibration accumulators.
pub fn parallel_items<T, F>(items: Vec<T>, f: F)
where
    T: Send,
    F: Fn(T) + Sync,
{
    let w = threads().min(items.len());
    if w <= 1 {
        for it in items {
            f(it);
        }
        return;
    }
    let mut buckets: Vec<Vec<T>> = Vec::with_capacity(w);
    buckets.resize_with(w, Vec::new);
    for (i, it) in items.into_iter().enumerate() {
        buckets[i % w].push(it);
    }
    std::thread::scope(|s| {
        for bucket in buckets {
            let fr = &f;
            s.spawn(move || {
                mark_in_pool();
                for it in bucket {
                    fr(it);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_at_least_one() {
        assert!(threads() >= 1);
    }

    #[test]
    fn with_threads_overrides() {
        // (The ambient count outside the lock is observable by concurrent
        // tests, so only the value *inside* the override is asserted.)
        with_threads(5, || assert_eq!(threads(), 5));
        with_threads(3, || assert_eq!(threads(), 3));
    }

    #[test]
    fn parallel_map_matches_serial() {
        let serial: Vec<usize> = (0..257).map(|i| i * i).collect();
        for w in [1, 2, 5] {
            let par = with_threads(w, || parallel_map(257, |i| i * i));
            assert_eq!(par, serial, "w={w}");
        }
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map(0, |i| i).is_empty());
        assert_eq!(parallel_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn chunks_cover_disjointly() {
        let mut data = vec![0u32; 103];
        with_threads(4, || {
            parallel_chunks_mut(&mut data, 10, |i, c| {
                for v in c.iter_mut() {
                    *v += 1 + i as u32;
                }
            });
        });
        // Every element written exactly once with its chunk's value.
        for (j, &v) in data.iter().enumerate() {
            assert_eq!(v, 1 + (j / 10) as u32, "j={j}");
        }
    }

    #[test]
    fn items_all_consumed() {
        use std::sync::atomic::AtomicUsize;
        let total = AtomicUsize::new(0);
        let items: Vec<usize> = (1..=20).collect();
        with_threads(3, || {
            parallel_items(items, |v| {
                total.fetch_add(v, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 210);
    }

    #[test]
    fn nested_regions_run_serial() {
        let inner_counts = with_threads(2, || parallel_map(2, |_| threads()));
        // Inside a pool worker the effective width is 1.
        // (When the outer region ran serial — single-core host — the inner
        // count equals the override instead.)
        for c in inner_counts {
            assert!(c == 1 || c == 2);
        }
    }
}
