//! Mini property-testing harness (proptest is not available offline).
//!
//! `run_prop(name, cases, |rng| ...)` executes a closure over `cases`
//! independently-seeded random inputs; on failure it reports the failing
//! case's seed so the case can be replayed deterministically with
//! `CORP_PROP_SEED`.

use crate::util::rng::Pcg64;

/// Number of cases, overridable with `CORP_PROP_CASES`.
pub fn default_cases(fallback: usize) -> usize {
    std::env::var("CORP_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(fallback)
}

/// Run a property over `cases` random seeds. The closure gets a fresh RNG per
/// case and should panic (assert) on violation.
pub fn run_prop(name: &str, cases: usize, mut f: impl FnMut(&mut Pcg64)) {
    // Replay mode: run exactly one seed.
    if let Ok(seed) = std::env::var("CORP_PROP_SEED") {
        let seed: u64 = seed.parse().expect("CORP_PROP_SEED must be u64");
        let mut rng = Pcg64::new(seed);
        f(&mut rng);
        return;
    }
    // Deterministic per-property base seed derived from the name.
    let base: u64 = name.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
    for case in 0..cases {
        let seed = base.wrapping_add((case as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let mut rng = Pcg64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case}; replay with CORP_PROP_SEED={seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Helpers for generating structured random inputs.
pub mod gen {
    use crate::util::rng::Pcg64;

    /// Random dimension in [lo, hi].
    pub fn dim(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// Random matrix (row-major) with entries N(0, scale).
    pub fn matrix(rng: &mut Pcg64, r: usize, c: usize, scale: f32) -> Vec<f32> {
        (0..r * c).map(|_| rng.normal_f32(0.0, scale)).collect()
    }

    /// Random symmetric positive-definite matrix A = GᵀG + εI.
    pub fn spd(rng: &mut Pcg64, n: usize, eps: f32) -> Vec<f32> {
        let g = matrix(rng, n, n, 1.0);
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[k * n + i] * g[k * n + j];
                }
                a[i * n + j] = s / n as f32 + if i == j { eps } else { 0.0 };
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn props_run_all_cases() {
        let mut count = 0;
        run_prop("counting", 17, |_rng| {
            count += 1;
        });
        assert_eq!(count, 17);
    }

    #[test]
    fn seeds_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        run_prop("determinism", 5, |rng| first.push(rng.next_u64()));
        let mut second: Vec<u64> = Vec::new();
        run_prop("determinism", 5, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }

    #[test]
    fn spd_is_symmetric_positive() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(1);
        let n = 8;
        let a = gen::spd(&mut rng, n, 0.1);
        for i in 0..n {
            for j in 0..n {
                assert!((a[i * n + j] - a[j * n + i]).abs() < 1e-6);
            }
            assert!(a[i * n + i] > 0.0);
        }
    }
}
