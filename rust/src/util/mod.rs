//! Small self-contained substrates: RNG, JSON, logging, timing, CLI parsing,
//! a mini property-testing harness, a bench harness, and the scoped worker
//! pool behind the parallel linalg kernels.
//!
//! The build environment is offline, so everything that would normally come
//! from serde_json / clap / criterion / proptest / rand / rayon is
//! implemented here (and unit-tested like any other module); `anyhow` is a
//! vendored shim under `vendor/anyhow`.

pub mod rng;
pub mod json;
pub mod log;
pub mod timer;
pub mod cli;
pub mod lock;
pub mod prop;
pub mod bench;
pub mod threads;

pub use rng::Pcg64;
pub use timer::Stopwatch;
