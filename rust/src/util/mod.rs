//! Small self-contained substrates: RNG, JSON, logging, timing, CLI parsing,
//! a mini property-testing harness, and a bench harness.
//!
//! The build environment ships only the `xla` crate's dependency closure, so
//! everything that would normally come from serde_json / clap / criterion /
//! proptest / rand is implemented here (and unit-tested like any other
//! module).

pub mod rng;
pub mod json;
pub mod log;
pub mod timer;
pub mod cli;
pub mod prop;
pub mod bench;

pub use rng::Pcg64;
pub use timer::Stopwatch;
