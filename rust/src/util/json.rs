//! Minimal JSON parser + writer.
//!
//! Used for the artifact manifest written by `python/compile/aot.py` and for
//! results/ CSV-adjacent metadata. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (not produced by our tooling).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (valid UTF-8 by construction).
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| self.err("invalid utf-8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"block_96","shapes":[[2,17,96],[96,384]],"ok":true,"lam":0.001}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
