//! Wall-clock timing helpers: a stopwatch and a named-section accumulator used
//! for the Table-6 runtime breakdown (calibration / ranking / compensation).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn restart(&mut self) -> f64 {
        let s = self.secs();
        self.0 = Instant::now();
        s
    }
}

/// Accumulates wall time by section name. The CORP pipeline charges every
/// phase here so the Table 6 analogue ("calibration dominates") is measured,
/// not asserted.
#[derive(Default, Debug, Clone)]
pub struct Sections {
    totals: BTreeMap<String, f64>,
}

impl Sections {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and charge it to `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed().as_secs_f64());
        out
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        *self.totals.entry(name.to_string()).or_insert(0.0) += secs;
    }

    pub fn get(&self, name: &str) -> f64 {
        self.totals.get(name).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.totals.values().sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.totals.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn merge(&mut self, other: &Sections) {
        for (k, v) in other.iter() {
            self.add(k, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_accumulate() {
        let mut s = Sections::new();
        s.add("cal", 1.0);
        s.add("cal", 2.0);
        s.add("rank", 0.5);
        assert_eq!(s.get("cal"), 3.0);
        assert_eq!(s.get("rank"), 0.5);
        assert_eq!(s.get("absent"), 0.0);
        assert!((s.total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn time_charges_section() {
        let mut s = Sections::new();
        let v = s.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(s.get("work") >= 0.004);
    }

    #[test]
    fn merge_sums() {
        let mut a = Sections::new();
        a.add("x", 1.0);
        let mut b = Sections::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert_eq!(a.get("x"), 3.0);
        assert_eq!(a.get("y"), 3.0);
    }
}
