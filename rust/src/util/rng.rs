//! PCG64 (DXSM) pseudo-random generator plus the sampling helpers the rest of
//! the crate needs (uniform, normal, truncated normal, permutation, choice).
//!
//! Deterministic seeding is load-bearing: datasets, weight init and property
//! tests are all reproducible from a `u64` seed.

/// Permuted congruential generator, 128-bit state (PCG64-DXSM variant).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed. Two generators with different
    /// seeds produce independent-looking streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into state+increment.
        let mut s = seed;
        let mut next = || {
            s = s.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Self { state, inc };
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream (for per-worker / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Self {
        let a = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Self::new(a)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping; bias is negligible for the
        // n << 2^64 values used here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Truncated normal in [-2σ, 2σ] (the usual ViT init).
    pub fn trunc_normal_f32(&mut self, std: f32) -> f32 {
        loop {
            let v = self.normal() as f32;
            if v.abs() <= 2.0 {
                return v * std;
            }
        }
    }

    /// Fill a slice with N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Fisher–Yates permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            p.swap(i, j);
        }
        p
    }

    /// Sample `k` distinct indices from 0..n (k <= n).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p = self.permutation(n);
        p.truncate(k);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg64::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg64::new(3);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_distinct() {
        let mut r = Pcg64::new(4);
        let c = r.choose(100, 30);
        assert_eq!(c.len(), 30);
        let mut s = c.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg64::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg64::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
