//! Poison-recovering lock helpers.
//!
//! `std`'s `Mutex`/`RwLock` poison their guard when a holder panics; the
//! idiomatic `.lock().unwrap()` then *cascades* that panic into every other
//! thread touching the lock — one worker's bug tears down the whole serving
//! fleet. The fault-tolerant engine treats a panic as a per-request failure
//! (see `serve/engine.rs`), so the shared state must stay usable after one.
//!
//! Every structure guarded by these helpers is written transactionally —
//! state is mutated after the fallible work, or is a plain counter/queue
//! whose partial update is harmless — so recovering the guard with
//! [`std::sync::PoisonError::into_inner`] is sound: the worst case is one
//! request's bookkeeping missing, which the failure accounting records
//! anyway. A grep gate in `scripts/check.sh` keeps bare `.lock().unwrap()`
//! out of `serve/` and `exec/` so new call sites go through here.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Acquire a read guard, recovering from writer poisoning.
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Acquire a write guard, recovering from poisoning.
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Consume a mutex, recovering its value even if poisoned.
pub fn into_inner<T>(m: Mutex<T>) -> T {
    m.into_inner().unwrap_or_else(|e| e.into_inner())
}

/// [`Condvar::wait`] that recovers a poisoned guard instead of panicking.
pub fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

/// [`Condvar::wait_timeout`] with the same poison recovery; the timeout
/// flag is dropped (callers re-check their own deadline anyway).
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> MutexGuard<'a, T> {
    cv.wait_timeout(g, dur).map(|(g, _)| g).unwrap_or_else(|e| e.into_inner().0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_after_holder_panic() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7);
        *lock(&m) = 9;
        assert_eq!(into_inner(Arc::try_unwrap(m).unwrap()), 9);
    }

    #[test]
    fn rwlock_recovers_after_writer_panic() {
        let l = Arc::new(RwLock::new(vec![1, 2]));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert_eq!(read(&l).len(), 2);
        write(&l).push(3);
        assert_eq!(read(&l).len(), 3);
    }

    #[test]
    fn condvar_wrappers_pass_through() {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        let g = lock(&m);
        let g = wait_timeout(&cv, g, Duration::from_millis(1));
        assert!(!*g);
    }
}
