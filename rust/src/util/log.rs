//! Leveled stderr logging with a `CORP_LOG` environment override.
//!
//! Levels: error < warn < info < debug < trace. Default level is `info`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let parsed = match std::env::var("CORP_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Programmatic override (used by tests and the CLI's `-q`/`-v` flags).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warnlog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! debuglog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
