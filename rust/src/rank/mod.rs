//! Ranking criteria (Alg. 2 / Alg. 4 and the App. E ablation).
//!
//! MLP hidden channels are scored with simple data-driven signals; attention
//! head dimensions with expected logit energy. Per the paper's thesis, the
//! ranking is deliberately simple — compensation does the heavy lifting.

use std::cmp::Ordering;

use crate::linalg::{Cholesky, Mat};
use crate::model::keep_count;
use crate::stats::MomentAccumulator;
use crate::tensor::Tensor;

/// MLP channel ranking criterion.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MlpCriterion {
    /// Activation energy E[x_i²].
    ActEnergy,
    /// Output-weight column norm ‖W₂[i, :]‖₂.
    Magnitude,
    /// Combined (Wanda-like): E_i · ‖W₂[i, :]‖₂ — the paper's default.
    Combined,
    /// Active probability P(|x| > ε) (App. E ablation).
    ActiveProb,
}

impl MlpCriterion {
    pub fn label(&self) -> &'static str {
        match self {
            MlpCriterion::ActEnergy => "act",
            MlpCriterion::Magnitude => "mag",
            MlpCriterion::Combined => "combined",
            MlpCriterion::ActiveProb => "active",
        }
    }

    pub fn all() -> [MlpCriterion; 4] {
        [MlpCriterion::ActEnergy, MlpCriterion::Magnitude, MlpCriterion::Combined, MlpCriterion::ActiveProb]
    }
}

/// Pruning criterion for the full zoo: the paper's simple MLP signals plus
/// the calibration-statistics-only criteria from related one-shot work.
/// Every member is computable from the one-pass calibration statistics the
/// compensation path already streams — no gradients, no extra passes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Criterion {
    /// The existing per-scope signals (`score_mlp` for channels,
    /// logit energy for head dims) — the paper's defaults.
    Mlp(MlpCriterion),
    /// Variance-based (VBP/Berisha-style): rank by activation variance
    /// (MLP channels) / var(q)·var(k) (head dims).
    Variance,
    /// OBS/CAP-style correlation-aware saliency from the calibration Gram:
    /// ‖W₂[i,:]‖² / [(Σ + λ·scale·I)⁻¹]_ii for MLP channels, and the
    /// analogous inverse-Gram diagonal for head dims.
    Obs,
    /// Attention-logit-energy signal applied to both scopes (for MLP
    /// channels this degrades to plain activation energy).
    Energy,
}

impl Criterion {
    pub fn label(&self) -> &'static str {
        match self {
            Criterion::Mlp(c) => c.label(),
            Criterion::Variance => "variance",
            Criterion::Obs => "obs",
            Criterion::Energy => "energy",
        }
    }

    /// The criterion zoo swept by the bench table: one representative of the
    /// paper's combined default plus each alternative scoring family.
    pub fn zoo() -> [Criterion; 4] {
        [Criterion::Mlp(MlpCriterion::Combined), Criterion::Variance, Criterion::Obs, Criterion::Energy]
    }
}

/// Diagonal of `(m + λ·scale·I)⁻¹` via Cholesky (scale = mean diagonal, so
/// λ is unitless like the compensation ridge). Shared by the OBS-style
/// scores for both scopes.
fn ridge_inverse_diag(m: &Mat, lambda: f64) -> Vec<f64> {
    let d = m.r;
    let scale = (m.trace() / d.max(1) as f64).max(1e-12);
    let reg = m.add_diag(lambda * scale);
    let (f, _) = Cholesky::new_with_jitter(&reg);
    let inv = f.solve_mat(&Mat::eye(d));
    (0..d).map(|i| inv.at(i, i)).collect()
}

/// Score MLP hidden channels under any zoo criterion, straight from the
/// layer's calibration accumulator. `Mlp(_)` delegates to [`score_mlp`];
/// the alternatives use the variance / inverse-Gram views of the same
/// one-pass statistics.
pub fn score_mlp_zoo(
    crit: Criterion,
    acc: &MomentAccumulator,
    active_prob: &[f64],
    w2: &Tensor,
    lambda: f64,
) -> Vec<f64> {
    match crit {
        Criterion::Mlp(c) => score_mlp(c, &acc.energy(), active_prob, w2),
        Criterion::Energy => acc.energy(),
        Criterion::Variance => acc.variance(),
        Criterion::Obs => {
            // OBS saliency for removing channel i of the output projection:
            // ‖W₂[i,:]‖² / [H⁻¹]_ii with H = E[xxᵀ] + λ·scale·I (the layer's
            // local Hessian under squared reconstruction error).
            let o = acc.d;
            assert_eq!(w2.shape()[0], o);
            let inv_diag = ridge_inverse_diag(&acc.second_moment(), lambda);
            (0..o)
                .map(|i| {
                    let wn: f64 = w2.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum();
                    wn / inv_diag[i].max(1e-300)
                })
                .collect()
        }
    }
}

/// Per-dimension variance over all `[B·n]` rows of a per-head `[B, n, dh]`
/// slab (clamped at 0, matching the accumulator contract).
fn slab_variance(t: &Tensor) -> Vec<f64> {
    let shape = t.shape();
    let (b, n, dh) = (shape[0], shape[1], shape[2]);
    let rows = (b * n) as f64;
    let mut sum = vec![0.0f64; dh];
    let mut sq = vec![0.0f64; dh];
    for r in 0..b * n {
        for j in 0..dh {
            let v = t.data()[r * dh + j] as f64;
            sum[j] += v;
            sq[j] += v * v;
        }
    }
    (0..dh)
        .map(|j| {
            let m = sum[j] / rows;
            (sq[j] / rows - m * m).max(0.0)
        })
        .collect()
}

/// `[dh, dh]` uncentered Gram over all rows of a per-head `[B, n, dh]` slab.
fn slab_gram(t: &Tensor) -> Mat {
    let shape = t.shape();
    let (b, n, dh) = (shape[0], shape[1], shape[2]);
    let mut g = Mat::zeros(dh, dh);
    for r in 0..b * n {
        let row = &t.data()[r * dh..(r + 1) * dh];
        for i in 0..dh {
            let vi = row[i] as f64;
            for j in i..dh {
                g.a[i * dh + j] += vi * row[j] as f64;
            }
        }
    }
    for i in 0..dh {
        for j in 0..i {
            g.a[i * dh + j] = g.a[j * dh + i];
        }
    }
    let rows = (b * n).max(1) as f64;
    for v in g.a.iter_mut() {
        *v /= rows;
    }
    g
}

/// Score one head's QK dimensions under any zoo criterion. `q`, `k`:
/// `[B, n, dh]` captured calibration slabs for that head.
/// `Mlp(_)` and `Energy` use the paper's logit-energy signal (Alg. 4);
/// `Variance` ranks by var(q_j)·var(k_j); `Obs` by the inverse-Gram
/// saliency 1 / ([(G_q+λI)⁻¹]_jj · [(G_k+λI)⁻¹]_jj).
pub fn score_attn_zoo(crit: Criterion, q: &Tensor, k: &Tensor, lambda: f64) -> Vec<f64> {
    match crit {
        Criterion::Mlp(_) | Criterion::Energy => score_attn_logit_energy(q, k),
        Criterion::Variance => {
            let vq = slab_variance(q);
            let vk = slab_variance(k);
            vq.iter().zip(&vk).map(|(&a, &b)| a * b).collect()
        }
        Criterion::Obs => {
            let iq = ridge_inverse_diag(&slab_gram(q), lambda);
            let ik = ridge_inverse_diag(&slab_gram(k), lambda);
            iq.iter().zip(&ik).map(|(&a, &b)| 1.0 / (a * b).max(1e-300)).collect()
        }
    }
}

/// Score MLP hidden channels.
///
/// `energy` = E[x_i²] per channel; `active_prob` = P(|x_i| > ε);
/// `w2` = second linear layer [o, d] (rows are the pruned-away columns W_P
/// of the paper's output-projection view).
pub fn score_mlp(
    crit: MlpCriterion,
    energy: &[f64],
    active_prob: &[f64],
    w2: &Tensor,
) -> Vec<f64> {
    let o = energy.len();
    assert_eq!(w2.shape()[0], o);
    let col_norm = |i: usize| -> f64 {
        w2.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    };
    match crit {
        MlpCriterion::ActEnergy => energy.to_vec(),
        MlpCriterion::Magnitude => (0..o).map(col_norm).collect(),
        MlpCriterion::Combined => (0..o).map(|i| energy[i] * col_norm(i)).collect(),
        MlpCriterion::ActiveProb => active_prob.to_vec(),
    }
}

/// Attention logit-energy scores s_j = E[‖q_j‖² ‖k_j‖²] per head dimension
/// (Alg. 4). `q`, `k`: [B, n, dh] for one head; expectation over samples b,
/// with per-sample column norms over tokens.
pub fn score_attn_logit_energy(q: &Tensor, k: &Tensor) -> Vec<f64> {
    let shape = q.shape();
    assert_eq!(shape.len(), 3);
    let (b, n, dh) = (shape[0], shape[1], shape[2]);
    assert_eq!(k.shape(), shape);
    let mut scores = vec![0.0f64; dh];
    for s in 0..b {
        for j in 0..dh {
            let mut qn = 0.0f64;
            let mut kn = 0.0f64;
            for t in 0..n {
                let qv = q.data()[(s * n + t) * dh + j] as f64;
                let kv = k.data()[(s * n + t) * dh + j] as f64;
                qn += qv * qv;
                kn += kv * kv;
            }
            scores[j] += qn * kn;
        }
    }
    for v in scores.iter_mut() {
        *v /= b as f64;
    }
    scores
}

/// Descending comparator with NaN pinned last. `total_cmp` alone is NaN-safe
/// but orders +NaN *above* +∞ — a descending `total_cmp` sort would rank a
/// NaN score as the most important channel. Degenerate calibration stats
/// must instead prune NaN-scored channels first, so NaN sorts after every
/// finite (and infinite) value regardless of sign bit.
pub fn nan_last_desc(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => b.total_cmp(&a),
    }
}

/// Partition 0..dim into (kept, pruned) keeping the `k` highest-scoring
/// indices. Kept/pruned lists are sorted ascending so that gathers are
/// deterministic. NaN scores deterministically land in the pruned set
/// (see [`nan_last_desc`]); ties break on index.
pub fn partition_k(scores: &[f64], k: usize) -> (Vec<usize>, Vec<usize>) {
    let dim = scores.len();
    let k = k.min(dim);
    let mut idx: Vec<usize> = (0..dim).collect();
    idx.sort_by(|&a, &b| nan_last_desc(scores[a], scores[b]).then(a.cmp(&b)));
    let mut kept: Vec<usize> = idx[..k].to_vec();
    let mut pruned: Vec<usize> = idx[k..].to_vec();
    kept.sort_unstable();
    pruned.sort_unstable();
    (kept, pruned)
}

/// [`partition_k`] at the uniform-sparsity keep count `keep_count(dim, s10)`.
pub fn partition(scores: &[f64], s10: u8) -> (Vec<usize>, Vec<usize>) {
    partition_k(scores, keep_count(scores.len(), s10))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn partition_keeps_top_scores() {
        let scores = vec![0.1, 5.0, 0.2, 4.0, 0.05, 3.0];
        let (kept, pruned) = partition(&scores, 5); // keep 3 of 6
        assert_eq!(kept, vec![1, 3, 5]);
        assert_eq!(pruned, vec![0, 2, 4]);
    }

    #[test]
    fn partition_dense_keeps_all() {
        let scores = vec![1.0, 2.0, 3.0];
        let (kept, pruned) = partition(&scores, 0);
        assert_eq!(kept, vec![0, 1, 2]);
        assert!(pruned.is_empty());
    }

    #[test]
    fn partition_sizes_prop() {
        run_prop("rank.partition sizes", 20, |rng| {
            let dim = 1 + rng.below(64);
            let s10 = rng.below(8) as u8;
            let scores: Vec<f64> = (0..dim).map(|_| rng.uniform()).collect();
            let (kept, pruned) = partition(&scores, s10);
            assert_eq!(kept.len(), keep_count(dim, s10));
            assert_eq!(kept.len() + pruned.len(), dim);
            // Disjoint + sorted.
            let mut all: Vec<usize> = kept.iter().chain(&pruned).copied().collect();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), dim);
            // Min kept score >= max pruned score.
            if !pruned.is_empty() && !kept.is_empty() {
                let min_kept = kept.iter().map(|&i| scores[i]).fold(f64::MAX, f64::min);
                let max_pruned = pruned.iter().map(|&i| scores[i]).fold(f64::MIN, f64::max);
                assert!(min_kept >= max_pruned);
            }
        });
    }

    #[test]
    fn mlp_criteria_shapes_and_monotonicity() {
        let energy = vec![1.0, 4.0, 0.25];
        let active = vec![0.9, 0.5, 0.1];
        let w2 = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 2.0, 3.0, 4.0]);
        let act = score_mlp(MlpCriterion::ActEnergy, &energy, &active, &w2);
        assert_eq!(act, energy);
        let mag = score_mlp(MlpCriterion::Magnitude, &energy, &active, &w2);
        assert!((mag[0] - 1.0).abs() < 1e-9);
        assert!((mag[1] - 2.0).abs() < 1e-9);
        assert!((mag[2] - 5.0).abs() < 1e-9);
        let comb = score_mlp(MlpCriterion::Combined, &energy, &active, &w2);
        assert!((comb[2] - 0.25 * 5.0).abs() < 1e-9);
        let ap = score_mlp(MlpCriterion::ActiveProb, &energy, &active, &w2);
        assert_eq!(ap, active);
    }

    #[test]
    fn logit_energy_identifies_hot_dimension() {
        // dim 1 carries 10x the q/k magnitude -> highest score.
        let b = 3;
        let n = 5;
        let dh = 4;
        let mut rng = crate::util::Pcg64::new(2);
        let mut q = vec![0.0f32; b * n * dh];
        let mut k = vec![0.0f32; b * n * dh];
        for i in 0..b * n {
            for j in 0..dh {
                let scale = if j == 1 { 10.0 } else { 1.0 };
                q[i * dh + j] = rng.normal_f32(0.0, scale);
                k[i * dh + j] = rng.normal_f32(0.0, scale);
            }
        }
        let qs = Tensor::from_vec(&[b, n, dh], q);
        let ks = Tensor::from_vec(&[b, n, dh], k);
        let scores = score_attn_logit_energy(&qs, &ks);
        let best = scores.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        assert_eq!(best, 1);
    }

    #[test]
    fn partition_nan_and_zero_variance_regression() {
        // A calibration distribution with a constant (zero-variance) channel
        // scores 0.0 and a degenerate channel scores NaN: ranking must not
        // panic, and the NaN channel must deterministically be pruned first.
        let scores = vec![0.7, f64::NAN, 0.0, 3.0, f64::NAN, 1.2];
        let (kept, pruned) = partition(&scores, 5); // keep 3 of 6
        assert_eq!(kept, vec![0, 3, 5]);
        assert_eq!(pruned, vec![1, 2, 4]);
        // NaN sorts below everything, including -inf and the zero-variance 0.0.
        let (kept2, pruned2) = partition_k(&[f64::NAN, f64::NEG_INFINITY, 0.0], 2);
        assert_eq!(kept2, vec![1, 2]);
        assert_eq!(pruned2, vec![0]);
        // All-NaN stays deterministic: index order.
        let (kept3, _) = partition_k(&[f64::NAN, f64::NAN, f64::NAN], 2);
        assert_eq!(kept3, vec![0, 1]);
    }

    #[test]
    fn zoo_scores_from_degenerate_stats_rank_without_panic() {
        // Constant-zero channel + constant non-zero channel + varying channel:
        // every zoo criterion must produce finite, non-negative scores that
        // feed partition without panicking.
        let o = 3;
        let rows = 32;
        let mut x = vec![0.0f32; rows * o];
        for r in 0..rows {
            x[r * o] = 0.0;
            x[r * o + 1] = 0.5;
            x[r * o + 2] = if r % 2 == 0 { 2.0 } else { -2.0 };
        }
        let mut acc = MomentAccumulator::new(o);
        acc.add_batch(&x, rows);
        let active = vec![0.0, 1.0, 1.0];
        let w2 = Tensor::from_vec(&[o, 2], vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        for crit in Criterion::zoo() {
            let scores = score_mlp_zoo(crit, &acc, &active, &w2, 1e-2);
            assert_eq!(scores.len(), o);
            assert!(
                scores.iter().all(|s| s.is_finite() && *s >= 0.0),
                "{}: {scores:?}",
                crit.label()
            );
            let (kept, pruned) = partition_k(&scores, 2);
            assert_eq!(kept.len(), 2);
            assert_eq!(pruned.len(), 1);
            // The varying high-energy channel always survives.
            assert!(kept.contains(&2), "{}: kept {kept:?}", crit.label());
        }
    }

    #[test]
    fn attn_zoo_scores_identify_hot_dimension() {
        let (b, n, dh) = (3, 5, 4);
        let mut rng = crate::util::Pcg64::new(5);
        let mut q = vec![0.0f32; b * n * dh];
        let mut k = vec![0.0f32; b * n * dh];
        for i in 0..b * n {
            for j in 0..dh {
                let scale = if j == 2 { 8.0 } else { 1.0 };
                q[i * dh + j] = rng.normal_f32(0.0, scale);
                k[i * dh + j] = rng.normal_f32(0.0, scale);
            }
        }
        let qs = Tensor::from_vec(&[b, n, dh], q);
        let ks = Tensor::from_vec(&[b, n, dh], k);
        for crit in Criterion::zoo() {
            let scores = score_attn_zoo(crit, &qs, &ks, 1e-2);
            assert_eq!(scores.len(), dh);
            assert!(scores.iter().all(|s| s.is_finite()), "{}: {scores:?}", crit.label());
            let best = scores.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            assert_eq!(best, 2, "{}: {scores:?}", crit.label());
        }
    }
}
