//! Ranking criteria (Alg. 2 / Alg. 4 and the App. E ablation).
//!
//! MLP hidden channels are scored with simple data-driven signals; attention
//! head dimensions with expected logit energy. Per the paper's thesis, the
//! ranking is deliberately simple — compensation does the heavy lifting.

use crate::model::keep_count;
use crate::tensor::Tensor;

/// MLP channel ranking criterion.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MlpCriterion {
    /// Activation energy E[x_i²].
    ActEnergy,
    /// Output-weight column norm ‖W₂[i, :]‖₂.
    Magnitude,
    /// Combined (Wanda-like): E_i · ‖W₂[i, :]‖₂ — the paper's default.
    Combined,
    /// Active probability P(|x| > ε) (App. E ablation).
    ActiveProb,
}

impl MlpCriterion {
    pub fn label(&self) -> &'static str {
        match self {
            MlpCriterion::ActEnergy => "act",
            MlpCriterion::Magnitude => "mag",
            MlpCriterion::Combined => "combined",
            MlpCriterion::ActiveProb => "active",
        }
    }

    pub fn all() -> [MlpCriterion; 4] {
        [MlpCriterion::ActEnergy, MlpCriterion::Magnitude, MlpCriterion::Combined, MlpCriterion::ActiveProb]
    }
}

/// Score MLP hidden channels.
///
/// `energy` = E[x_i²] per channel; `active_prob` = P(|x_i| > ε);
/// `w2` = second linear layer [o, d] (rows are the pruned-away columns W_P
/// of the paper's output-projection view).
pub fn score_mlp(
    crit: MlpCriterion,
    energy: &[f64],
    active_prob: &[f64],
    w2: &Tensor,
) -> Vec<f64> {
    let o = energy.len();
    assert_eq!(w2.shape()[0], o);
    let col_norm = |i: usize| -> f64 {
        w2.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    };
    match crit {
        MlpCriterion::ActEnergy => energy.to_vec(),
        MlpCriterion::Magnitude => (0..o).map(col_norm).collect(),
        MlpCriterion::Combined => (0..o).map(|i| energy[i] * col_norm(i)).collect(),
        MlpCriterion::ActiveProb => active_prob.to_vec(),
    }
}

/// Attention logit-energy scores s_j = E[‖q_j‖² ‖k_j‖²] per head dimension
/// (Alg. 4). `q`, `k`: [B, n, dh] for one head; expectation over samples b,
/// with per-sample column norms over tokens.
pub fn score_attn_logit_energy(q: &Tensor, k: &Tensor) -> Vec<f64> {
    let shape = q.shape();
    assert_eq!(shape.len(), 3);
    let (b, n, dh) = (shape[0], shape[1], shape[2]);
    assert_eq!(k.shape(), shape);
    let mut scores = vec![0.0f64; dh];
    for s in 0..b {
        for j in 0..dh {
            let mut qn = 0.0f64;
            let mut kn = 0.0f64;
            for t in 0..n {
                let qv = q.data()[(s * n + t) * dh + j] as f64;
                let kv = k.data()[(s * n + t) * dh + j] as f64;
                qn += qv * qv;
                kn += kv * kv;
            }
            scores[j] += qn * kn;
        }
    }
    for v in scores.iter_mut() {
        *v /= b as f64;
    }
    scores
}

/// Partition 0..dim into (kept, pruned) keeping the `keep_count(dim, s10)`
/// highest-scoring indices. Kept/pruned lists are sorted ascending so that
/// gathers are deterministic.
pub fn partition(scores: &[f64], s10: u8) -> (Vec<usize>, Vec<usize>) {
    let dim = scores.len();
    let k = keep_count(dim, s10);
    let mut idx: Vec<usize> = (0..dim).collect();
    // Sort by score descending, tie-break on index for determinism.
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
    let mut kept: Vec<usize> = idx[..k].to_vec();
    let mut pruned: Vec<usize> = idx[k..].to_vec();
    kept.sort_unstable();
    pruned.sort_unstable();
    (kept, pruned)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn partition_keeps_top_scores() {
        let scores = vec![0.1, 5.0, 0.2, 4.0, 0.05, 3.0];
        let (kept, pruned) = partition(&scores, 5); // keep 3 of 6
        assert_eq!(kept, vec![1, 3, 5]);
        assert_eq!(pruned, vec![0, 2, 4]);
    }

    #[test]
    fn partition_dense_keeps_all() {
        let scores = vec![1.0, 2.0, 3.0];
        let (kept, pruned) = partition(&scores, 0);
        assert_eq!(kept, vec![0, 1, 2]);
        assert!(pruned.is_empty());
    }

    #[test]
    fn partition_sizes_prop() {
        run_prop("rank.partition sizes", 20, |rng| {
            let dim = 1 + rng.below(64);
            let s10 = rng.below(8) as u8;
            let scores: Vec<f64> = (0..dim).map(|_| rng.uniform()).collect();
            let (kept, pruned) = partition(&scores, s10);
            assert_eq!(kept.len(), keep_count(dim, s10));
            assert_eq!(kept.len() + pruned.len(), dim);
            // Disjoint + sorted.
            let mut all: Vec<usize> = kept.iter().chain(&pruned).copied().collect();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), dim);
            // Min kept score >= max pruned score.
            if !pruned.is_empty() && !kept.is_empty() {
                let min_kept = kept.iter().map(|&i| scores[i]).fold(f64::MAX, f64::min);
                let max_pruned = pruned.iter().map(|&i| scores[i]).fold(f64::MIN, f64::max);
                assert!(min_kept >= max_pruned);
            }
        });
    }

    #[test]
    fn mlp_criteria_shapes_and_monotonicity() {
        let energy = vec![1.0, 4.0, 0.25];
        let active = vec![0.9, 0.5, 0.1];
        let w2 = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 2.0, 3.0, 4.0]);
        let act = score_mlp(MlpCriterion::ActEnergy, &energy, &active, &w2);
        assert_eq!(act, energy);
        let mag = score_mlp(MlpCriterion::Magnitude, &energy, &active, &w2);
        assert!((mag[0] - 1.0).abs() < 1e-9);
        assert!((mag[1] - 2.0).abs() < 1e-9);
        assert!((mag[2] - 5.0).abs() < 1e-9);
        let comb = score_mlp(MlpCriterion::Combined, &energy, &active, &w2);
        assert!((comb[2] - 0.25 * 5.0).abs() < 1e-9);
        let ap = score_mlp(MlpCriterion::ActiveProb, &energy, &active, &w2);
        assert_eq!(ap, active);
    }

    #[test]
    fn logit_energy_identifies_hot_dimension() {
        // dim 1 carries 10x the q/k magnitude -> highest score.
        let b = 3;
        let n = 5;
        let dh = 4;
        let mut rng = crate::util::Pcg64::new(2);
        let mut q = vec![0.0f32; b * n * dh];
        let mut k = vec![0.0f32; b * n * dh];
        for i in 0..b * n {
            for j in 0..dh {
                let scale = if j == 1 { 10.0 } else { 1.0 };
                q[i * dh + j] = rng.normal_f32(0.0, scale);
                k[i * dh + j] = rng.normal_f32(0.0, scale);
            }
        }
        let qs = Tensor::from_vec(&[b, n, dh], q);
        let ks = Tensor::from_vec(&[b, n, dh], k);
        let scores = score_attn_logit_energy(&qs, &ks);
        let best = scores.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(best, 1);
    }
}
