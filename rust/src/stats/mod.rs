//! Calibration statistics.
//!
//! Streaming accumulators for activation means and second moments (the Σ
//! blocks of Eq. 10), plus the redundancy diagnostics of Table 9 /
//! Appendix A: effective rank, k95 energy concentration, and activation
//! sparsity.

use crate::linalg::gemm::syrk_upper_f32;
use crate::linalg::{sym_eig, Mat};

/// Streaming accumulator of per-channel mean and the full second-moment Gram
/// E[x xᵀ] over calibration activations. Feed row-major [rows, d] batches;
/// finalize into mean vector + covariance matrix.
pub struct MomentAccumulator {
    pub d: usize,
    count: usize,
    sum: Vec<f64>,
    /// Accumulated raw Gram XᵀX in f32 (hot path), promoted to f64 blocks at
    /// finalize time. For the channel counts used here (≤ ~1.5k) and batch
    /// counts (≤ ~1e5 rows) the f32 accumulation error is ~1e-3 relative,
    /// which the ridge λ dominates; `syrk` keeps this path fast.
    gram: Vec<f32>,
}

impl MomentAccumulator {
    pub fn new(d: usize) -> Self {
        Self { d, count: 0, sum: vec![0.0; d], gram: vec![0.0; d * d] }
    }

    /// Add a [rows, d] batch of activations. Batches are folded in as they
    /// stream off the calibration forward pass — nothing beyond the running
    /// Gram/sum is materialized. The Gram update is the packed parallel
    /// SYRK, the dominant cost of calibration statistics.
    pub fn add_batch(&mut self, x: &[f32], rows: usize) {
        assert_eq!(x.len(), rows * self.d);
        for r in 0..rows {
            let row = &x[r * self.d..(r + 1) * self.d];
            for (s, &v) in self.sum.iter_mut().zip(row) {
                *s += v as f64;
            }
        }
        syrk_upper_f32(x, &mut self.gram, rows, self.d);
        self.count += rows;
    }

    /// Fold another accumulator (over disjoint rows) into this one.
    ///
    /// Not on the default calibration path — there each layer's accumulator
    /// is owned by exactly one worker, which keeps statistics independent of
    /// the worker count. This is the reduction hook for sharded calibration
    /// (partial Grams computed per data shard, merged once at the end).
    pub fn merge(&mut self, other: &MomentAccumulator) {
        assert_eq!(self.d, other.d);
        self.count += other.count;
        for (s, o) in self.sum.iter_mut().zip(&other.sum) {
            *s += o;
        }
        for (g, o) in self.gram.iter_mut().zip(&other.gram) {
            *g += o;
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Per-channel mean μ.
    pub fn mean(&self) -> Vec<f64> {
        assert!(self.count > 0);
        self.sum.iter().map(|s| s / self.count as f64).collect()
    }

    /// Per-channel second moment E[x_i²] (the activation-energy ranking
    /// signal of Alg. 2).
    ///
    /// Clamped at 0: the Gram diagonal is mathematically non-negative, but
    /// the f32 SYRK accumulation can drift a hair below zero for channels
    /// that are (near-)constant zero. Downstream score derivations take
    /// `sqrt(energy)` and feed sort comparators, so the clamp lives here at
    /// the accumulator boundary rather than at every call site.
    pub fn energy(&self) -> Vec<f64> {
        assert!(self.count > 0);
        (0..self.d)
            .map(|i| (self.gram[i * self.d + i] as f64 / self.count as f64).max(0.0))
            .collect()
    }

    /// Per-channel variance E[x_i²] − μ_i², clamped at 0.
    ///
    /// The clamp is part of the accumulator contract (same reasoning as
    /// [`MomentAccumulator::energy`]): for a constant channel the two terms
    /// cancel only up to floating-point error, and a tiny negative variance
    /// turns into NaN under `sqrt` in variance-based rankings.
    pub fn variance(&self) -> Vec<f64> {
        assert!(self.count > 0);
        let mu = self.mean();
        self.energy().iter().zip(&mu).map(|(&e, &m)| (e - m * m).max(0.0)).collect()
    }

    /// Full covariance Σ = E[xxᵀ] − μμᵀ as an f64 matrix.
    pub fn covariance(&self) -> Mat {
        assert!(self.count > 0);
        let n = self.count as f64;
        let mu = self.mean();
        let d = self.d;
        let mut out = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                out.a[i * d + j] = self.gram[i * d + j] as f64 / n - mu[i] * mu[j];
            }
        }
        out.symmetrize();
        out
    }

    /// Raw (uncentered) second-moment matrix E[xxᵀ].
    pub fn second_moment(&self) -> Mat {
        assert!(self.count > 0);
        let n = self.count as f64;
        let d = self.d;
        let mut out = Mat::zeros(d, d);
        for i in 0..d * d {
            out.a[i] = self.gram[i] as f64 / n;
        }
        out.symmetrize();
        out
    }
}

/// Streaming count of |x| > eps per channel — the "active probability"
/// ranking signal (App. E) and the activation-sparsity column of Table 9.
pub struct ActiveCounter {
    pub d: usize,
    count: usize,
    active: Vec<u64>,
    eps: f32,
}

impl ActiveCounter {
    pub fn new(d: usize, eps: f32) -> Self {
        Self { d, count: 0, active: vec![0; d], eps }
    }

    pub fn add_batch(&mut self, x: &[f32], rows: usize) {
        assert_eq!(x.len(), rows * self.d);
        for r in 0..rows {
            let row = &x[r * self.d..(r + 1) * self.d];
            for (c, &v) in self.active.iter_mut().zip(row) {
                *c += (v.abs() > self.eps) as u64;
            }
        }
        self.count += rows;
    }

    /// Fold another counter (over disjoint rows) into this one.
    pub fn merge(&mut self, other: &ActiveCounter) {
        assert_eq!(self.d, other.d);
        self.count += other.count;
        for (c, o) in self.active.iter_mut().zip(&other.active) {
            *c += o;
        }
    }

    /// Per-channel P(|x| > eps).
    pub fn active_prob(&self) -> Vec<f64> {
        assert!(self.count > 0);
        self.active.iter().map(|&a| a as f64 / self.count as f64).collect()
    }

    /// Mean fraction of *inactive* entries — the layer's activation sparsity.
    pub fn sparsity(&self) -> f64 {
        let p = self.active_prob();
        1.0 - p.iter().sum::<f64>() / p.len() as f64
    }
}

/// Redundancy diagnostics over an activation covariance (Table 9).
#[derive(Debug, Clone)]
pub struct Redundancy {
    /// Effective rank: exp(entropy of the normalized eigenvalue spectrum).
    pub effective_rank: f64,
    /// Channels needed to explain 95% of activation variance.
    pub k95: usize,
    /// effective_rank / dim.
    pub rank_ratio: f64,
    /// k95 / dim.
    pub k95_ratio: f64,
}

/// Compute redundancy stats from a covariance matrix.
pub fn redundancy(cov: &Mat) -> Redundancy {
    let (vals, _) = sym_eig(cov);
    let pos: Vec<f64> = vals.iter().map(|&v| v.max(0.0)).collect();
    let total: f64 = pos.iter().sum();
    let d = cov.r;
    if total <= 0.0 {
        return Redundancy { effective_rank: 0.0, k95: 0, rank_ratio: 0.0, k95_ratio: 0.0 };
    }
    // Effective rank = exp(−Σ p ln p) over p = λ/Σλ.
    let mut ent = 0.0;
    for &v in &pos {
        let p = v / total;
        if p > 1e-300 {
            ent -= p * p.ln();
        }
    }
    let eff = ent.exp();
    // k95 over the sorted (descending) spectrum.
    let mut cum = 0.0;
    let mut k95 = d;
    for (i, &v) in pos.iter().enumerate() {
        cum += v;
        if cum >= 0.95 * total {
            k95 = i + 1;
            break;
        }
    }
    Redundancy {
        effective_rank: eff,
        k95,
        rank_ratio: eff / d as f64,
        k95_ratio: k95 as f64 / d as f64,
    }
}

/// Extract the Σ_SS / Σ_PS / Σ_PP blocks (Eq. 10) of a covariance matrix for
/// a kept/pruned index partition.
pub struct CovBlocks {
    pub ss: Mat,
    pub ps: Mat,
    pub pp: Mat,
    pub mu_s: Vec<f64>,
    pub mu_p: Vec<f64>,
}

pub fn cov_blocks(cov: &Mat, mean: &[f64], kept: &[usize], pruned: &[usize]) -> CovBlocks {
    CovBlocks {
        ss: cov.submatrix(kept, kept),
        ps: cov.submatrix(pruned, kept),
        pp: cov.submatrix(pruned, pruned),
        mu_s: kept.iter().map(|&i| mean[i]).collect(),
        mu_p: pruned.iter().map(|&i| mean[i]).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, run_prop};
    use crate::util::Pcg64;

    #[test]
    fn moments_match_direct_computation() {
        run_prop("stats.moments = direct", 10, |rng| {
            let d = gen::dim(rng, 1, 6);
            let rows = 50;
            let x = gen::matrix(rng, rows, d, 1.0);
            let mut acc = MomentAccumulator::new(d);
            // Feed in two chunks to exercise streaming.
            acc.add_batch(&x[..(rows / 2) * d], rows / 2);
            acc.add_batch(&x[(rows / 2) * d..], rows - rows / 2);
            let mean = acc.mean();
            for j in 0..d {
                let direct: f64 = (0..rows).map(|i| x[i * d + j] as f64).sum::<f64>() / rows as f64;
                assert!((mean[j] - direct).abs() < 1e-4);
            }
            let cov = acc.covariance();
            for a in 0..d {
                for b in 0..d {
                    let direct: f64 = (0..rows)
                        .map(|i| (x[i * d + a] as f64 - mean[a]) * (x[i * d + b] as f64 - mean[b]))
                        .sum::<f64>()
                        / rows as f64;
                    assert!((cov.at(a, b) - direct).abs() < 1e-3, "({a},{b})");
                }
            }
        });
    }

    #[test]
    fn merge_equals_single_accumulator() {
        let mut rng = Pcg64::new(21);
        let d = 9;
        let x1 = gen::matrix(&mut rng, 40, d, 1.0);
        let x2 = gen::matrix(&mut rng, 25, d, 1.0);
        let mut whole = MomentAccumulator::new(d);
        whole.add_batch(&x1, 40);
        whole.add_batch(&x2, 25);
        let mut a = MomentAccumulator::new(d);
        a.add_batch(&x1, 40);
        let mut b = MomentAccumulator::new(d);
        b.add_batch(&x2, 25);
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        let (ma, mw) = (a.mean(), whole.mean());
        for (x, y) in ma.iter().zip(&mw) {
            assert!((x - y).abs() < 1e-9);
        }
        assert!(a.covariance().max_abs_diff(&whole.covariance()) < 1e-5);

        let mut ca = ActiveCounter::new(d, 0.5);
        ca.add_batch(&x1, 40);
        let mut cb = ActiveCounter::new(d, 0.5);
        cb.add_batch(&x2, 25);
        ca.merge(&cb);
        let mut cw = ActiveCounter::new(d, 0.5);
        cw.add_batch(&x1, 40);
        cw.add_batch(&x2, 25);
        for (x, y) in ca.active_prob().iter().zip(&cw.active_prob()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn energy_is_second_moment() {
        let mut acc = MomentAccumulator::new(2);
        acc.add_batch(&[1.0, 2.0, 3.0, 4.0], 2);
        let e = acc.energy();
        assert!((e[0] - 5.0).abs() < 1e-6); // (1+9)/2
        assert!((e[1] - 10.0).abs() < 1e-6); // (4+16)/2
    }

    #[test]
    fn accumulator_contract_energy_and_variance_nonnegative() {
        // Constant channel (variance exactly 0 up to fp error) next to a
        // varying one: energy/variance must come back finite and >= 0, and
        // the constant channel's variance must be clamped to exactly 0.
        let mut acc = MomentAccumulator::new(3);
        let rows = 64;
        let mut x = vec![0.0f32; rows * 3];
        for r in 0..rows {
            x[r * 3] = 0.3; // constant
            x[r * 3 + 1] = if r % 2 == 0 { 1.0 } else { -1.0 };
            x[r * 3 + 2] = 0.0; // constant zero
        }
        acc.add_batch(&x, rows);
        let e = acc.energy();
        let v = acc.variance();
        for (i, (&ei, &vi)) in e.iter().zip(&v).enumerate() {
            assert!(ei.is_finite() && ei >= 0.0, "energy[{i}] = {ei}");
            assert!(vi.is_finite() && vi >= 0.0, "variance[{i}] = {vi}");
            // sqrt must be safe on the contract outputs.
            assert!(ei.sqrt().is_finite() && vi.sqrt().is_finite());
        }
        assert_eq!(v[0], 0.0, "constant channel variance not clamped: {}", v[0]);
        assert_eq!(v[2], 0.0);
        assert!((v[1] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn variance_matches_covariance_diagonal() {
        let mut rng = Pcg64::new(77);
        let d = 7;
        let x = gen::matrix(&mut rng, 120, d, 1.5);
        let mut acc = MomentAccumulator::new(d);
        acc.add_batch(&x, 120);
        let v = acc.variance();
        let cov = acc.covariance();
        for i in 0..d {
            assert!((v[i] - cov.at(i, i).max(0.0)).abs() < 1e-6, "channel {i}");
        }
    }

    #[test]
    fn active_counter() {
        let mut c = ActiveCounter::new(2, 0.5);
        c.add_batch(&[1.0, 0.1, 0.0, 2.0, 0.9, 0.2], 3);
        let p = c.active_prob();
        assert!((p[0] - 2.0 / 3.0).abs() < 1e-9);
        assert!((p[1] - 1.0 / 3.0).abs() < 1e-9);
        assert!((c.sparsity() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn redundancy_isotropic_full_rank() {
        let cov = Mat::eye(10);
        let r = redundancy(&cov);
        assert!((r.effective_rank - 10.0).abs() < 1e-6);
        assert_eq!(r.k95, 10);
    }

    #[test]
    fn redundancy_rank_one() {
        let mut cov = Mat::zeros(8, 8);
        cov.set(0, 0, 5.0);
        let r = redundancy(&cov);
        assert!((r.effective_rank - 1.0).abs() < 1e-9);
        assert_eq!(r.k95, 1);
        assert!(r.rank_ratio < 0.2);
    }

    #[test]
    fn low_rank_data_has_low_effective_rank() {
        // Generate d=12 activations that live in a 3-dim subspace + noise.
        let mut rng = Pcg64::new(3);
        let d = 12;
        let rows = 400;
        let basis = gen::matrix(&mut rng, 3, d, 1.0);
        let mut x = vec![0.0f32; rows * d];
        for r in 0..rows {
            let z: Vec<f32> = (0..3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            for j in 0..d {
                let mut v = 0.0;
                for k in 0..3 {
                    v += z[k] * basis[k * d + j];
                }
                x[r * d + j] = v + rng.normal_f32(0.0, 0.01);
            }
        }
        let mut acc = MomentAccumulator::new(d);
        acc.add_batch(&x, rows);
        let r = redundancy(&acc.covariance());
        assert!(r.effective_rank < 4.0, "eff rank {}", r.effective_rank);
        assert!(r.k95 <= 4);
    }

    #[test]
    fn cov_blocks_partition() {
        let mut acc = MomentAccumulator::new(4);
        let mut rng = Pcg64::new(9);
        let x = gen::matrix(&mut rng, 100, 4, 1.0);
        acc.add_batch(&x, 100);
        let cov = acc.covariance();
        let mean = acc.mean();
        let blocks = cov_blocks(&cov, &mean, &[0, 2], &[1, 3]);
        assert_eq!((blocks.ss.r, blocks.ss.c), (2, 2));
        assert_eq!((blocks.ps.r, blocks.ps.c), (2, 2));
        assert!((blocks.ss.at(0, 1) - cov.at(0, 2)).abs() < 1e-12);
        assert!((blocks.ps.at(1, 0) - cov.at(3, 0)).abs() < 1e-12);
        assert!((blocks.mu_p[0] - mean[1]).abs() < 1e-12);
    }
}
