//! High-level coordination: checkpoint + pruned-model caching and the
//! end-to-end experiment driver used by the CLI, examples, and benches.
//!
//! The coordinator owns a `Runtime`, hands out `Executor`s, memoizes the
//! trained dense checkpoints (`train::ensure_checkpoint`) and calibration
//! statistics (one calibration pass per model serves every sparsity /
//! method / criterion combination — this is what makes the sweep benches
//! tractable), and records the Table-6 runtime breakdown.

use std::collections::HashMap;

use anyhow::Result;

use crate::data::VisionGen;
use crate::exec::Executor;
use crate::model::{ModelConfig, ModelKind, Sparsity, WeightStore};
use crate::prune::{calibrate, prune, CalibStats, Method, PruneOpts, PruneResult};
use crate::runtime::Runtime;
use crate::train::{ensure_checkpoint, TrainOpts};
use crate::util::timer::Sections;

/// Scale knob for experiments (maps from CORP_BENCH_MODE).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scale {
    /// Training steps for dense checkpoints.
    pub train_steps: usize,
    /// Calibration batches (x eval_batch = images).
    pub calib_batches: usize,
    /// Eval batches for accuracy numbers.
    pub eval_batches: usize,
    /// Latency / throughput iterations.
    pub serve_iters: usize,
}

impl Scale {
    pub fn from_env() -> Self {
        match crate::util::bench::bench_mode() {
            crate::util::bench::BenchMode::Smoke => {
                Self { train_steps: 40, calib_batches: 4, eval_batches: 4, serve_iters: 5 }
            }
            crate::util::bench::BenchMode::Fast => {
                Self { train_steps: 250, calib_batches: 8, eval_batches: 8, serve_iters: 10 }
            }
            crate::util::bench::BenchMode::Full => {
                Self { train_steps: 600, calib_batches: 32, eval_batches: 48, serve_iters: 50 }
            }
        }
    }
}

/// The coordinator: runtime + caches.
pub struct Coordinator {
    pub rt: Runtime,
    pub scale: Scale,
    dense_cache: HashMap<&'static str, WeightStore>,
    calib_cache: HashMap<String, CalibStats>,
}

impl Coordinator {
    pub fn new() -> Result<Self> {
        Ok(Self {
            rt: Runtime::from_default_dir()?,
            scale: Scale::from_env(),
            dense_cache: HashMap::new(),
            calib_cache: HashMap::new(),
        })
    }

    pub fn executor(&self, cfg: &'static ModelConfig) -> Executor<'_> {
        Executor::new(&self.rt, cfg)
    }

    /// Trained dense weights (cached in memory + on disk).
    pub fn dense(&mut self, cfg: &'static ModelConfig) -> Result<&WeightStore> {
        if !self.dense_cache.contains_key(cfg.name) {
            let opts = self.train_opts(cfg);
            let w = ensure_checkpoint(&self.rt, cfg, &opts)?;
            self.dense_cache.insert(cfg.name, w);
        }
        Ok(&self.dense_cache[cfg.name])
    }

    pub fn train_opts(&self, cfg: &ModelConfig) -> TrainOpts {
        // Smaller ViTs need *more* steps: escaping the sign-flip plateau is
        // slower at low capacity (measured: vit_t ~700, vit_b ~300). The
        // mode scales these base counts.
        let base = match cfg.name {
            "vit_t" => 700,
            "vit_s" => 450,
            "vit_b" => 300,
            "gpt_s" => 400,
            _ => 260, // vit_l / vit_h: larger models escape the plateau sooner
        };
        let steps = match crate::util::bench::bench_mode() {
            crate::util::bench::BenchMode::Smoke => (base / 6).max(30),
            crate::util::bench::BenchMode::Fast => base,
            crate::util::bench::BenchMode::Full => base * 2,
        };
        let _ = ModelKind::Vit; // kind currently does not change the recipe
        TrainOpts { steps, ..TrainOpts::default() }
    }

    /// Calibration statistics for a model (cached; keyed by calib size).
    pub fn calib(
        &mut self,
        cfg: &'static ModelConfig,
        opts: &PruneOpts,
    ) -> Result<&CalibStats> {
        let key = format!("{}@{}", cfg.name, opts.calib_batches);
        if !self.calib_cache.contains_key(&key) {
            let dense = self.dense(cfg)?.clone();
            let exec = Executor::new(&self.rt, cfg);
            let stats = calibrate(&exec, &dense, opts)?;
            self.calib_cache.insert(key.clone(), stats);
        }
        Ok(&self.calib_cache[&key])
    }

    /// Direct access to a cached calibration (key = "{model}@{batches}").
    /// Panics if `calib` was not called first for that key.
    pub fn calib_stats(&self, key: &str) -> &CalibStats {
        &self.calib_cache[key]
    }

    /// Run one (method, sparsity, criterion) pruning job from cached
    /// calibration; returns the pruned weights + merged section timings.
    pub fn prune_job(
        &mut self,
        cfg: &'static ModelConfig,
        opts: &PruneOpts,
    ) -> Result<PruneResult> {
        let dense = self.dense(cfg)?.clone();
        // Make sure calibration is cached, then borrow it.
        self.calib(cfg, opts)?;
        let key = format!("{}@{}", cfg.name, opts.calib_batches);
        let stats = &self.calib_cache[&key];
        let exec = Executor::new(&self.rt, cfg);
        let mut result = prune(&exec, &dense, stats, opts)?;
        result.sections.merge(&stats.sections);
        Ok(result)
    }

    /// Accuracy of a weight store (dense or pruned) on the eval split.
    ///
    /// The task identity is always `DATA_SEED` (the generator seed defines
    /// the classes themselves); `seed` selects which disjoint window of the
    /// eval stream is scored, so different evaluation seeds see different
    /// examples while every variant scored under one seed is comparable.
    /// The seed was previously accepted and silently ignored.
    pub fn top1(&self, cfg: &'static ModelConfig, w: &WeightStore, seed: u64) -> Result<f64> {
        let exec = Executor::new(&self.rt, cfg);
        let gen = VisionGen::new(crate::data::DATA_SEED);
        let start = crate::eval::eval_window(seed);
        crate::eval::top1_from(&exec, w, &gen, self.scale.eval_batches, start)
    }

    /// Full experiment row: prune at `sparsity` with `method` and report
    /// (top1, params, flops, sections).
    pub fn accuracy_at(
        &mut self,
        cfg: &'static ModelConfig,
        sparsity: Sparsity,
        method: Method,
        opts_base: &PruneOpts,
    ) -> Result<(f64, usize, usize, Sections)> {
        let opts = PruneOpts { sparsity, method, ..opts_base.clone() };
        let result = if sparsity.is_dense() {
            PruneResult {
                weights: self.dense(cfg)?.clone(),
                mean_mlp_rho2: 0.0,
                mean_attn_rho2: 0.0,
                sections: Sections::new(),
            }
        } else {
            self.prune_job(cfg, &opts)?
        };
        let top1 = self.top1(cfg, &result.weights, opts.seed)?;
        let p = crate::flops::params(cfg, sparsity);
        let f = crate::flops::flops(cfg, sparsity);
        Ok((top1, p, f, result.sections))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_modes_ordered() {
        // smoke < fast < full in every knob.
        let smoke = Scale { train_steps: 40, calib_batches: 4, eval_batches: 4, serve_iters: 5 };
        let fast = Scale { train_steps: 250, calib_batches: 16, eval_batches: 16, serve_iters: 20 };
        assert!(smoke.train_steps < fast.train_steps);
        assert!(smoke.calib_batches < fast.calib_batches);
    }
}
