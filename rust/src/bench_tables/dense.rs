//! Table 8: dense-prediction transfer (DINOv2 substitute).
//!
//! Protocol (matching the paper): fit depth + segmentation heads closed-form
//! on *dense* backbone features, freeze them, prune the backbone only at 50%
//! joint sparsity, and compare downstream metrics.

use anyhow::Result;

use super::vit_sizes;
use crate::coordinator::Coordinator;
use crate::data::dense_task::{argmax_rows, depth_metrics, mean_iou, one_hot, LinearHead};
use crate::data::vision::{CLASSES, PATCHES};
use crate::data::{Split, VisionGen};
use crate::exec::Executor;
use crate::linalg::Mat;
use crate::model::{ModelConfig, Scope, Sparsity, WeightStore};
use crate::prune::{Method, PruneOpts};
use crate::util::bench::CsvWriter;

/// Extract per-patch features [B*PATCHES, d] (CLS token dropped) and the
/// aligned dense targets over `n_batches` of a split.
fn patch_features(
    exec: &Executor<'_>,
    w: &WeightStore,
    gen: &VisionGen,
    split: Split,
    n_batches: usize,
) -> Result<(Mat, Vec<f32>, Vec<i32>)> {
    let cfg = exec.cfg;
    let b = cfg.eval_batch();
    let d = cfg.d;
    let mut feats: Vec<f64> = Vec::new();
    let mut depth = Vec::new();
    let mut seg = Vec::new();
    for i in 0..n_batches {
        let (tokens, targets) = gen.batch_dense(split, i as u64, b);
        let x = exec.features(w, &tokens, b)?; // [b, n_ctx, d]
        for s in 0..b {
            for p in 0..PATCHES {
                // token index p+1 (skip CLS)
                let base = (s * cfg.n_ctx + p + 1) * d;
                feats.extend(x.data()[base..base + d].iter().map(|&v| v as f64));
            }
        }
        depth.extend_from_slice(&targets.depth);
        seg.extend_from_slice(&targets.seg);
    }
    let rows = depth.len();
    Ok((Mat::from_rows(rows, d, feats), depth, seg))
}

/// Table 8 generator.
pub fn table8(coord: &mut Coordinator) -> Result<()> {
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let gen = VisionGen::new(crate::data::DATA_SEED);
    let fit_batches = coord.scale.eval_batches.max(8);
    let eval_batches = coord.scale.eval_batches;
    let mut csv = CsvWriter::new(
        "table8",
        "model,variant,params_m,rmse,delta1,miou",
    );
    println!("Table 8 — dense-prediction transfer, backbone pruned 50% joint");
    println!("{:7} {:7} | {:>9} | {:>7} {:>7} {:>7}", "model", "variant", "params M", "RMSE", "δ1", "mIoU");

    for cfg in vit_sizes() {
        let dense_w = coord.dense(cfg)?.clone();
        let pruned = {
            let o = PruneOpts {
                sparsity: Sparsity::of(Scope::Both, 5),
                method: Method::Corp,
                ..opts.clone()
            };
            coord.prune_job(cfg, &o)?.weights
        };
        let exec = Executor::new(&coord.rt, cfg);

        // Fit heads on dense train-split features (closed form).
        let (ftr, dtr, str_) = patch_features(&exec, &dense_w, &gen, Split::Train, fit_batches)?;
        let depth_head = LinearHead::fit(&ftr, &Mat::from_rows(dtr.len(), 1, dtr.iter().map(|&v| v as f64).collect()), 1e-2);
        let seg_head = LinearHead::fit(&ftr, &one_hot(&str_, CLASSES), 1e-2);

        // Evaluate a backbone variant with the frozen heads.
        let eval_variant = |w: &WeightStore| -> Result<(f64, f64, f64)> {
            let (fe, de, se) = patch_features(&exec, w, &gen, Split::Eval, eval_batches)?;
            let dp = depth_head.apply(&fe);
            let pred: Vec<f64> = (0..dp.r).map(|i| dp.at(i, 0)).collect();
            let (rmse, d1) = depth_metrics(&pred, &de);
            let sp = argmax_rows(&seg_head.apply(&fe));
            let miou = mean_iou(&sp, &se, CLASSES);
            Ok((rmse, d1, miou))
        };

        let (rmse_d, d1_d, miou_d) = eval_variant(&dense_w)?;
        let (rmse_p, d1_p, miou_p) = eval_variant(&pruned)?;

        let pd = crate::flops::params(cfg, Sparsity::dense()) as f64 / 1e6;
        let pp = crate::flops::params(cfg, Sparsity::of(Scope::Both, 5)) as f64 / 1e6;
        println!("{:7} {:7} | {:9.3} | {:7.4} {:7.4} {:7.4}", cfg.name, "dense", pd, rmse_d, d1_d, miou_d);
        println!("{:7} {:7} | {:9.3} | {:7.4} {:7.4} {:7.4}", cfg.name, "pruned", pp, rmse_p, d1_p, miou_p);
        csv.row(&[cfg.name.into(), "dense".into(), format!("{pd:.3}"), format!("{rmse_d:.4}"), format!("{d1_d:.4}"), format!("{miou_d:.4}")]);
        csv.row(&[cfg.name.into(), "pruned".into(), format!("{pp:.3}"), format!("{rmse_p:.4}"), format!("{d1_p:.4}"), format!("{miou_p:.4}")]);
    }
    csv.flush()?;
    Ok(())
}

#[allow(unused)]
fn _silence(_: &ModelConfig) {}
