//! `corp bench prune` — the criterion-zoo accuracy harness behind
//! `BENCH_prune.json`.
//!
//! Sweeps the ranking-criterion zoo (`rank::Criterion::zoo()`) against the
//! mode's sparsity grid, scoring each cell both compensated (CORP) and
//! uncompensated (naive) so the table shows what closed-form compensation
//! buys on top of every criterion. A second sweep exercises the global
//! FLOPs-targeted allocator at mode-scaled budgets, recording the achieved
//! FLOPs fraction (measured by `flops_layered` on the allocator's per-layer
//! keep counts — the ±2% acceptance gate) next to the resulting top-1.
//! Results print as a table and are optionally emitted as machine-readable
//! JSON (schema `corp-bench-prune/v1`) so the numbers are tracked
//! PR-over-PR.
//!
//! Like `bench linalg`/`bench serve`: a failed cell aborts the sweep with
//! the cell's coordinates in the error, and any pre-existing `--out` file
//! is removed up front so a crashed sweep can never leave a stale JSON
//! that looks like fresh results.

use anyhow::{Context, Result};

use super::{large_model, num, obj, sparsity_grid};
use crate::coordinator::Coordinator;
use crate::model::{Scope, Sparsity};
use crate::prune::{allocate_flops, Method, PruneOpts};
use crate::rank::Criterion;
use crate::util::bench::{bench_mode, BenchMode};
use crate::util::json::Json;

/// One (criterion, sparsity) cell: compensated vs uncompensated top-1 at
/// the same kept set, plus the analytic cost of the uniform shape.
struct GridRow {
    criterion: &'static str,
    s10: u8,
    corp_top1: f64,
    naive_top1: f64,
    flops: usize,
    flops_reduction_pct: f64,
}

impl GridRow {
    fn comp_delta(&self) -> f64 {
        self.corp_top1 - self.naive_top1
    }

    fn print(&self) {
        println!(
            "{:9} s={:.1} | corp {:6.2}% | naive {:6.2}% | Δcomp {:+6.2}pp | flops -{:.1}%",
            self.criterion,
            self.s10 as f64 / 10.0,
            self.corp_top1,
            self.naive_top1,
            self.comp_delta(),
            self.flops_reduction_pct
        );
    }

    fn json(&self) -> Json {
        obj(vec![
            ("criterion", Json::Str(self.criterion.to_string())),
            ("sparsity", num(self.s10 as f64 / 10.0)),
            ("corp_top1", num(self.corp_top1)),
            ("naive_top1", num(self.naive_top1)),
            ("compensation_delta_pp", num(self.comp_delta())),
            ("flops", num(self.flops as f64)),
            ("flops_reduction_pct", num(self.flops_reduction_pct)),
        ])
    }
}

/// One allocator cell: criterion × budget → per-layer keep counts,
/// achieved FLOPs fraction, and the compensated top-1 on those shapes.
struct AllocRow {
    criterion: &'static str,
    budget_pct: f64,
    achieved_pct: f64,
    top1: f64,
    mlp_keep: Vec<usize>,
    qk_keep: Vec<usize>,
}

impl AllocRow {
    fn print(&self) {
        println!(
            "{:9} budget {:5.1}% | achieved {:5.1}% | top-1 {:6.2}% | mlp {:?} qk {:?}",
            self.criterion, self.budget_pct, self.achieved_pct, self.top1, self.mlp_keep, self.qk_keep
        );
    }

    fn json(&self) -> Json {
        obj(vec![
            ("criterion", Json::Str(self.criterion.to_string())),
            ("budget_pct", num(self.budget_pct)),
            ("achieved_pct", num(self.achieved_pct)),
            ("top1", num(self.top1)),
            ("mlp_keep", Json::Arr(self.mlp_keep.iter().map(|&k| num(k as f64)).collect())),
            ("qk_keep", Json::Arr(self.qk_keep.iter().map(|&k| num(k as f64)).collect())),
        ])
    }
}

/// FLOPs budgets (% of dense) the allocator sweep targets, by mode.
fn mode_budgets() -> Vec<f64> {
    match bench_mode() {
        BenchMode::Smoke => vec![60.0],
        BenchMode::Fast => vec![50.0, 70.0],
        BenchMode::Full => vec![40.0, 60.0, 80.0],
    }
}

/// Run the pruning benchmark suite; when `json_out` is set, write
/// `BENCH_prune.json`-style output there (schema `corp-bench-prune/v1`).
pub fn bench_prune(json_out: Option<&str>) -> Result<()> {
    // Fail loudly, never stale-ly (same contract as the other benches).
    if let Some(path) = json_out {
        let _ = std::fs::remove_file(path);
    }
    let cfg = large_model();
    let mut coord = Coordinator::new()?;
    let base = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let dense = coord.dense(cfg)?.clone();
    let dense_top1 = coord.top1(cfg, &dense, base.seed)?;
    println!(
        "prune bench — mode {:?}, model {}, dense top-1 {dense_top1:.2}%",
        bench_mode(),
        cfg.name
    );

    // ---- criterion × sparsity × compensation grid ----
    let mut rows: Vec<GridRow> = Vec::new();
    for crit in Criterion::zoo() {
        for s10 in sparsity_grid().into_iter().filter(|&s| s > 0) {
            let sp = Sparsity::of(Scope::Both, s10);
            let mut top = [0.0f64; 2];
            for (i, method) in [Method::Corp, Method::Naive].into_iter().enumerate() {
                let opts =
                    PruneOpts { sparsity: sp, method, criterion: crit, ..base.clone() };
                let r = coord.prune_job(cfg, &opts).with_context(|| {
                    format!(
                        "prune bench cell failed: criterion {} s10 {s10} method {}",
                        crit.label(),
                        method.label()
                    )
                })?;
                top[i] = coord.top1(cfg, &r.weights, opts.seed)?;
            }
            let f = crate::flops::flops(cfg, sp);
            let fd = crate::flops::flops(cfg, Sparsity::dense());
            let row = GridRow {
                criterion: crit.label(),
                s10,
                corp_top1: top[0],
                naive_top1: top[1],
                flops: f,
                flops_reduction_pct: crate::flops::reduction_pct(fd, f),
            };
            row.print();
            rows.push(row);
        }
    }

    // ---- global FLOPs-targeted allocation ----
    let mut alloc_rows: Vec<AllocRow> = Vec::new();
    coord.calib(cfg, &base)?;
    let calib_key = format!("{}@{}", cfg.name, base.calib_batches);
    for crit in Criterion::zoo() {
        for budget in mode_budgets() {
            let alloc = {
                let stats = coord.calib_stats(&calib_key);
                allocate_flops(cfg, &dense, stats, crit, base.lambda, budget)
            }
            .with_context(|| {
                format!(
                    "prune bench cell failed: allocation criterion {} budget {budget}%",
                    crit.label()
                )
            })?;
            let opts =
                PruneOpts { criterion: crit, alloc: Some(alloc.clone()), ..base.clone() };
            let r = coord.prune_job(cfg, &opts).with_context(|| {
                format!(
                    "prune bench cell failed: allocated prune criterion {} budget {budget}%",
                    crit.label()
                )
            })?;
            let row = AllocRow {
                criterion: crit.label(),
                budget_pct: budget,
                achieved_pct: alloc.achieved_pct(cfg),
                top1: coord.top1(cfg, &r.weights, opts.seed)?,
                mlp_keep: alloc.mlp_keep,
                qk_keep: alloc.qk_keep,
            };
            row.print();
            alloc_rows.push(row);
        }
    }

    if let Some(path) = json_out {
        let root = obj(vec![
            ("schema", Json::Str("corp-bench-prune/v1".into())),
            (
                "mode",
                Json::Str(
                    match bench_mode() {
                        BenchMode::Smoke => "smoke",
                        BenchMode::Fast => "fast",
                        BenchMode::Full => "full",
                    }
                    .into(),
                ),
            ),
            ("model", Json::Str(cfg.name.to_string())),
            ("calib_batches", num(base.calib_batches as f64)),
            ("dense_top1", num(dense_top1)),
            ("grid", Json::Arr(rows.iter().map(|r| r.json()).collect())),
            ("allocation", Json::Arr(alloc_rows.iter().map(|r| r.json()).collect())),
        ]);
        std::fs::write(path, root.to_string() + "\n")
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_budgets_sane() {
        let b = mode_budgets();
        assert!(!b.is_empty());
        assert!(b.iter().all(|&p| p > 0.0 && p <= 100.0));
    }

    #[test]
    fn grid_row_json_round_trips() {
        let row = GridRow {
            criterion: "energy",
            s10: 5,
            corp_top1: 61.5,
            naive_top1: 58.0,
            flops: 1_000_000,
            flops_reduction_pct: 40.0,
        };
        let parsed = Json::parse(&row.json().to_string()).unwrap();
        assert_eq!(parsed.get("criterion").as_str(), Some("energy"));
        assert_eq!(parsed.get("sparsity").as_f64(), Some(0.5));
        assert_eq!(parsed.get("compensation_delta_pp").as_f64(), Some(3.5));
    }

    #[test]
    fn alloc_row_json_round_trips() {
        let row = AllocRow {
            criterion: "obs",
            budget_pct: 60.0,
            achieved_pct: 59.1,
            top1: 60.2,
            mlp_keep: vec![3, 2],
            qk_keep: vec![4, 4],
        };
        let parsed = Json::parse(&row.json().to_string()).unwrap();
        assert_eq!(parsed.get("budget_pct").as_f64(), Some(60.0));
        assert_eq!(parsed.get("achieved_pct").as_f64(), Some(59.1));
    }
}
