//! Generators for every table and figure in the paper's evaluation section
//! (see DESIGN.md §6 for the experiment index). Each generator prints
//! paper-shaped rows and writes a CSV under `results/`; the thin wrappers in
//! `rust/benches/` call straight into these.

pub mod tables;
pub mod nlp;
pub mod dense;
pub mod linalg;
pub mod prune;
pub mod serve;

use std::collections::BTreeMap;

use crate::model::config::FAMILY;
use crate::model::{ModelConfig, ModelKind};
use crate::util::bench::{bench_mode, BenchMode};
use crate::util::json::Json;

/// JSON number shorthand shared by the harness emitters (`linalg`, `serve`).
pub(crate) fn num(v: f64) -> Json {
    Json::Num(v)
}

/// JSON object from (key, value) pairs, shared by the harness emitters.
pub(crate) fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Which ViT sizes a bench sweeps, by mode.
pub fn vit_sizes() -> Vec<&'static ModelConfig> {
    let all: Vec<&'static ModelConfig> =
        FAMILY.iter().filter(|c| c.kind == ModelKind::Vit).collect();
    match bench_mode() {
        BenchMode::Smoke => all[..1].to_vec(),
        BenchMode::Fast => all[..3].to_vec(),
        BenchMode::Full => all,
    }
}

/// Sparsity grid (s10 values) for sweep figures, by mode.
pub fn sparsity_grid() -> Vec<u8> {
    match bench_mode() {
        BenchMode::Smoke => vec![0, 5],
        BenchMode::Fast => vec![0, 4, 5, 7],
        BenchMode::Full => vec![0, 1, 2, 3, 4, 5, 6, 7],
    }
}

/// The "large" model for single-model tables (4a, fig2), by mode.
pub fn large_model() -> &'static ModelConfig {
    match bench_mode() {
        BenchMode::Smoke => ModelConfig::by_name("vit_t").unwrap(),
        BenchMode::Fast => ModelConfig::by_name("vit_b").unwrap(),
        BenchMode::Full => ModelConfig::by_name("vit_l").unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_nonempty() {
        assert!(!vit_sizes().is_empty());
        let g = sparsity_grid();
        assert!(g.contains(&0));
        assert!(g.iter().all(|&s| s <= 7));
    }
}
