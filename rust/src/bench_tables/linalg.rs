//! `corp bench linalg` — the perf-trajectory harness behind
//! `BENCH_linalg.json`.
//!
//! Benchmarks every micro-kernel along the full dispatch ladder — the
//! runtime-selected SIMD tile (AVX2 where detected), the portable packed
//! tile (`CORP_SIMD=off` forced around the timed region), and the seed's
//! scalar baselines (preserved in `linalg::gemm::reference`) — plus the
//! int8 weight-quantized GEMM against its f32 counterpart at
//! pipeline-realistic activation×weight shapes, the SYRK worker-count
//! sweep, and the end-to-end calibrate+prune pipeline on the native
//! backend, all scaled by `CORP_BENCH_MODE`. Results print as a table and
//! are optionally emitted as machine-readable JSON (schema
//! `corp-bench-linalg/v2`) so the numbers are tracked PR-over-PR.
//!
//! Like `bench serve`: a failed cell aborts the sweep with the cell's
//! coordinates in the error (non-zero exit through the CLI), and any
//! pre-existing `--out` file is removed up front — a crashed sweep can
//! never leave a stale JSON that looks like fresh results.

use anyhow::{Context, Result};

use super::{num, obj};
use crate::exec::Executor;
use crate::linalg::gemm::{matmul_f32, reference, simd_label, syrk_upper_f32};
use crate::linalg::{matmul_q8, quantize, Cholesky, Mat};
use crate::model::{ModelConfig, Scope, Sparsity, WeightStore};
use crate::prune::{calibrate, prune, Method, PruneOpts};
use crate::runtime::Runtime;
use crate::util::bench::{bench, bench_mode, BenchMode};
use crate::util::json::Json;
use crate::util::prop::gen;
use crate::util::threads;
use crate::util::{Pcg64, Stopwatch};

/// One kernel's row: the runtime-dispatched path (AVX2 where the host has
/// it), the portable packed tile, and the seed scalar baseline on the
/// same inputs.
struct KernelResult {
    name: String,
    dims: String,
    flops: f64,
    simd_s: f64,
    packed_s: f64,
    seed_s: f64,
}

impl KernelResult {
    fn speedup_vs_seed(&self) -> f64 {
        self.seed_s / self.simd_s.max(1e-12)
    }

    fn speedup_vs_packed(&self) -> f64 {
        self.packed_s / self.simd_s.max(1e-12)
    }

    fn gflops(&self, secs: f64) -> f64 {
        self.flops / secs.max(1e-12) / 1e9
    }

    fn print(&self) {
        println!(
            "{:12} {:>14} | {:8} {:8.3} ms ({:6.2} GF/s) | packed {:8.3} ms ({:6.2} GF/s) | \
             seed {:8.3} ms | {:4.2}x packed {:5.2}x seed",
            self.name,
            self.dims,
            simd_label(),
            self.simd_s * 1e3,
            self.gflops(self.simd_s),
            self.packed_s * 1e3,
            self.gflops(self.packed_s),
            self.seed_s * 1e3,
            self.speedup_vs_packed(),
            self.speedup_vs_seed()
        );
    }

    fn json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("dims", Json::Str(self.dims.clone())),
            ("dispatch", Json::Str(simd_label().to_string())),
            ("flops", num(self.flops)),
            ("simd_s", num(self.simd_s)),
            ("simd_gflops", num(self.gflops(self.simd_s))),
            ("packed_s", num(self.packed_s)),
            ("packed_gflops", num(self.gflops(self.packed_s))),
            ("seed_s", num(self.seed_s)),
            ("seed_gflops", num(self.gflops(self.seed_s))),
            ("speedup_simd_vs_packed", num(self.speedup_vs_packed())),
            ("speedup_vs_seed", num(self.speedup_vs_seed())),
        ])
    }
}

/// Run `f` with `CORP_SIMD=off` forced, restoring the caller's env after —
/// how the packed column is timed on hosts where dispatch picks AVX2.
fn with_simd_off<R>(f: impl FnOnce() -> R) -> R {
    let prev = std::env::var_os("CORP_SIMD");
    std::env::set_var("CORP_SIMD", "off");
    let out = f();
    match prev {
        Some(v) => std::env::set_var("CORP_SIMD", v),
        None => std::env::remove_var("CORP_SIMD"),
    }
    out
}

/// Sizes per mode: (gemm n, syrk (rows, channels), cholesky n, iters).
fn mode_sizes() -> (usize, (usize, usize), usize, usize) {
    match bench_mode() {
        BenchMode::Smoke => (128, (512, 256), 160, 3),
        BenchMode::Fast => (256, (2048, 768), 640, 5),
        BenchMode::Full => (512, (4096, 1280), 1024, 7),
    }
}

/// Int8 GEMM cell shape per mode: (rows, din, dout) — an activation panel
/// against one weight matrix, the serving fast path's shape (rows = batch
/// × tokens; din/dout = layer widths).
fn mode_q8() -> (usize, usize, usize) {
    match bench_mode() {
        BenchMode::Smoke => (256, 256, 256),
        BenchMode::Fast => (1024, 512, 512),
        BenchMode::Full => (2048, 768, 768),
    }
}

/// E2E pipeline scale per mode: (model, calib batches).
fn mode_e2e() -> (&'static str, usize) {
    match bench_mode() {
        BenchMode::Smoke => ("vit_t", 2),
        BenchMode::Fast => ("vit_t", 8),
        BenchMode::Full => ("vit_b", 16),
    }
}

/// Run the linalg benchmark suite; when `json_out` is set, write
/// `BENCH_linalg.json`-style output there (schema `corp-bench-linalg/v2`).
pub fn bench_linalg(json_out: Option<&str>) -> Result<()> {
    // Fail loudly, never stale-ly (same contract as `bench serve`): a
    // pre-existing output file must not survive a crashed sweep.
    if let Some(path) = json_out {
        let _ = std::fs::remove_file(path);
    }
    let (gemm_n, (syrk_rows, syrk_n), chol_n, iters) = mode_sizes();
    let mut rng = Pcg64::new(1);
    let mut kernels: Vec<KernelResult> = Vec::new();

    // ---- GEMM ----
    {
        let n = gemm_n;
        let a = gen::matrix(&mut rng, n, n, 1.0);
        let b = gen::matrix(&mut rng, n, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let s_simd = bench("gemm_simd", 2, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            matmul_f32(&a, &b, &mut c, n, n, n);
        });
        let s_packed = with_simd_off(|| {
            bench("gemm_packed", 1, iters, || {
                c.iter_mut().for_each(|v| *v = 0.0);
                matmul_f32(&a, &b, &mut c, n, n, n);
            })
        });
        let s_seed = bench("gemm_seed", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            reference::matmul_f32_seed(&a, &b, &mut c, n, n, n);
        });
        kernels.push(KernelResult {
            name: "gemm".into(),
            dims: format!("{n}x{n}x{n}"),
            flops: 2.0 * (n * n * n) as f64,
            simd_s: s_simd.mean_s,
            packed_s: s_packed.mean_s,
            seed_s: s_seed.mean_s,
        });
    }

    // ---- SYRK (the Gram-accumulation hot path) ----
    {
        let (rows, n) = (syrk_rows, syrk_n);
        let x = gen::matrix(&mut rng, rows, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let s_simd = bench("syrk_simd", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            syrk_upper_f32(&x, &mut c, rows, n);
        });
        let s_packed = with_simd_off(|| {
            bench("syrk_packed", 1, iters, || {
                c.iter_mut().for_each(|v| *v = 0.0);
                syrk_upper_f32(&x, &mut c, rows, n);
            })
        });
        let s_seed = bench("syrk_seed", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            reference::syrk_upper_f32_seed(&x, &mut c, rows, n);
        });
        kernels.push(KernelResult {
            name: "syrk".into(),
            dims: format!("{rows}x{n}"),
            flops: (rows * n * n) as f64, // ~half of full gemm
            simd_s: s_simd.mean_s,
            packed_s: s_packed.mean_s,
            seed_s: s_seed.mean_s,
        });
    }

    // ---- TN-GEMM (CᵀC shape used by the attention accumulators) ----
    {
        let (rows, n) = (syrk_rows / 2, syrk_n / 2);
        let a = gen::matrix(&mut rng, rows, n, 1.0);
        let b = gen::matrix(&mut rng, rows, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let s_simd = bench("tn_simd", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            crate::linalg::gemm::matmul_tn_f32(&a, &b, &mut c, rows, n, n);
        });
        let s_packed = with_simd_off(|| {
            bench("tn_packed", 1, iters, || {
                c.iter_mut().for_each(|v| *v = 0.0);
                crate::linalg::gemm::matmul_tn_f32(&a, &b, &mut c, rows, n, n);
            })
        });
        let s_seed = bench("tn_seed", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            reference::matmul_tn_f32_seed(&a, &b, &mut c, rows, n, n);
        });
        kernels.push(KernelResult {
            name: "gemm_tn".into(),
            dims: format!("{rows}x{n}x{n}"),
            flops: 2.0 * (rows * n * n) as f64,
            simd_s: s_simd.mean_s,
            packed_s: s_packed.mean_s,
            seed_s: s_seed.mean_s,
        });
    }

    println!(
        "linalg microbench — mode {:?}, dispatch {}, {} worker(s)",
        bench_mode(),
        simd_label(),
        threads::threads()
    );
    for k in &kernels {
        k.print();
    }

    // ---- int8 weight-quantized GEMM vs f32 (the serving fast path) ----
    let q8 = {
        let (rows, din, dout) = mode_q8();
        let x = gen::matrix(&mut rng, rows, din, 1.0);
        let w = gen::matrix(&mut rng, din, dout, 0.1);
        let qm = quantize(&w, din, dout);
        let mut out_f = vec![0.0f32; rows * dout];
        let mut out_q = vec![0.0f32; rows * dout];
        let s_f32 = bench("gemm_f32", 1, iters, || {
            out_f.iter_mut().for_each(|v| *v = 0.0);
            matmul_f32(&x, &w, &mut out_f, rows, din, dout);
        });
        let s_q8 = bench("gemm_q8", 1, iters, || {
            out_q.iter_mut().for_each(|v| *v = 0.0);
            matmul_q8(&x, &qm, &mut out_q, rows);
        });
        // Per-cell sanity at full grid coordinates: the int8 path must
        // track f32 within quantization tolerance, or the row is noise.
        let scale = out_f.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
        let maxd = out_f
            .iter()
            .zip(&out_q)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        if maxd > 0.05 * scale {
            anyhow::bail!(
                "linalg bench cell failed: gemm_q8 {rows}x{din}x{dout} drifted {maxd:.3e} \
                 from f32 (max |out| {scale:.3e})"
            );
        }
        let flops = 2.0 * (rows * din * dout) as f64;
        let gf_q8 = flops / s_q8.mean_s.max(1e-12) / 1e9;
        let gf_f32 = flops / s_f32.mean_s.max(1e-12) / 1e9;
        println!(
            "{:12} {:>14} | int8 {:8.3} ms ({gf_q8:6.2} GF/s) | f32 {:8.3} ms ({gf_f32:6.2} GF/s) \
             | {:4.2}x | max |Δ| {maxd:.2e}",
            "gemm_q8",
            format!("{rows}x{din}x{dout}"),
            s_q8.mean_s * 1e3,
            s_f32.mean_s * 1e3,
            s_f32.mean_s / s_q8.mean_s.max(1e-12)
        );
        obj(vec![
            ("name", Json::Str("gemm_q8".into())),
            ("dims", Json::Str(format!("{rows}x{din}x{dout}"))),
            ("dispatch", Json::Str(simd_label().to_string())),
            ("flops", num(flops)),
            ("q8_s", num(s_q8.mean_s)),
            ("q8_gflops", num(gf_q8)),
            ("f32_s", num(s_f32.mean_s)),
            ("f32_gflops", num(gf_f32)),
            ("speedup_q8_vs_f32", num(s_f32.mean_s / s_q8.mean_s.max(1e-12))),
            ("q8_bytes", num(qm.bytes() as f64)),
            ("f32_bytes", num((w.len() * 4) as f64)),
            ("max_abs_err", num(maxd as f64)),
        ])
    };

    // ---- Cholesky + parallel multi-RHS solve (no seed counterpart delta;
    // reported for the trajectory) ----
    let chol = {
        let n = chol_n;
        let a = Mat::from_f32(n, n, &gen::spd(&mut rng, n, 0.5));
        let s_fac = bench("cholesky", 1, iters.min(3), || Cholesky::new(&a).unwrap());
        let f = Cholesky::new(&a)
            .with_context(|| format!("linalg bench cell failed: cholesky {n}x{n}"))?;
        let rhs = Mat::from_f32(n, 64, &gen::matrix(&mut rng, n, 64, 1.0));
        let s_solve = bench("chol_solve64", 1, iters.min(3), || f.solve_mat(&rhs));
        println!(
            "{:12} {:>14} | factor {:9.3} ms | 64-rhs solve {:9.3} ms",
            "cholesky",
            format!("{n}x{n}"),
            s_fac.mean_s * 1e3,
            s_solve.mean_s * 1e3
        );
        obj(vec![
            ("n", num(n as f64)),
            ("factor_s", num(s_fac.mean_s)),
            ("solve64_s", num(s_solve.mean_s)),
        ])
    };

    // ---- SYRK thread sweep ----
    let mut sweep = Vec::new();
    {
        let (rows, n) = (syrk_rows, syrk_n);
        let x = gen::matrix(&mut rng, rows, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let avail = threads::threads();
        let mut counts = vec![1usize, 2, 4, avail];
        counts.retain(|&w| w <= avail.max(1));
        counts.sort_unstable();
        counts.dedup();
        for w in counts {
            let s = threads::with_threads(w, || {
                bench(&format!("syrk_w{w}"), 1, iters.min(3), || {
                    c.iter_mut().for_each(|v| *v = 0.0);
                    syrk_upper_f32(&x, &mut c, rows, n);
                })
            });
            let gf = (rows * n * n) as f64 / s.mean_s.max(1e-12) / 1e9;
            println!("{:12} {:>14} | {w} worker(s): {:9.3} ms ({gf:6.2} GF/s)", "syrk_sweep", format!("{rows}x{n}"), s.mean_s * 1e3);
            sweep.push(obj(vec![
                ("threads", num(w as f64)),
                ("syrk_s", num(s.mean_s)),
                ("gflops", num(gf)),
            ]));
        }
    }

    // ---- End-to-end calibrate + prune on the native backend ----
    let (model, calib_batches) = mode_e2e();
    let e2e = {
        let cfg = ModelConfig::by_name(model).context("e2e model")?;
        let rt = Runtime::from_default_dir()?;
        let exec = Executor::new(&rt, cfg);
        let dense = WeightStore::init(cfg, 1);
        let opts = PruneOpts {
            sparsity: Sparsity::of(Scope::Both, 5),
            method: Method::Corp,
            calib_batches,
            ..PruneOpts::default()
        };
        let sw = Stopwatch::start();
        let stats = calibrate(&exec, &dense, &opts).with_context(|| {
            format!("linalg bench cell failed: e2e calibrate model {model} calib {calib_batches}")
        })?;
        let calib_s = sw.secs();
        let sw2 = Stopwatch::start();
        let result = prune(&exec, &dense, &stats, &opts).with_context(|| {
            format!("linalg bench cell failed: e2e prune model {model} calib {calib_batches}")
        })?;
        let prune_s = sw2.secs();
        println!(
            "e2e {model} (calib {calib_batches} batches): calibrate {calib_s:.3}s  prune {prune_s:.3}s  (sections: rank {:.3}s comp {:.3}s)",
            result.sections.get("ranking"),
            result.sections.get("compensation"),
        );
        obj(vec![
            ("model", Json::Str(model.to_string())),
            ("calib_batches", num(calib_batches as f64)),
            ("calibrate_s", num(calib_s)),
            ("prune_s", num(prune_s)),
            ("total_s", num(calib_s + prune_s)),
            ("ranking_cpu_s", num(result.sections.get("ranking"))),
            ("compensation_cpu_s", num(result.sections.get("compensation"))),
        ])
    };

    if let Some(path) = json_out {
        let root = obj(vec![
            ("schema", Json::Str("corp-bench-linalg/v2".into())),
            (
                "mode",
                Json::Str(
                    match bench_mode() {
                        BenchMode::Smoke => "smoke",
                        BenchMode::Fast => "fast",
                        BenchMode::Full => "full",
                    }
                    .into(),
                ),
            ),
            ("dispatch", Json::Str(simd_label().to_string())),
            ("threads", num(threads::threads() as f64)),
            ("kernels", Json::Arr(kernels.iter().map(|k| k.json()).collect())),
            ("quantized", q8),
            ("cholesky", chol),
            ("thread_sweep", Json::Arr(sweep)),
            ("e2e", e2e),
        ]);
        std::fs::write(path, root.to_string() + "\n")
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_tables_cover_all_modes() {
        // Pure functions of the mode env; just exercise the mapping tables.
        let (g, (sr, sn), c, it) = mode_sizes();
        assert!(g >= 64 && sr > sn / 8 && c >= 64 && it >= 1);
        let (rows, din, dout) = mode_q8();
        assert!(rows >= 64 && din >= 64 && dout >= 64);
        let (m, cb) = mode_e2e();
        assert!(ModelConfig::by_name(m).is_some());
        assert!(cb >= 1);
    }

    #[test]
    fn kernel_result_math() {
        let k = KernelResult {
            name: "x".into(),
            dims: "1".into(),
            flops: 2e9,
            simd_s: 0.5,
            packed_s: 1.0,
            seed_s: 2.0,
        };
        assert!((k.speedup_vs_seed() - 4.0).abs() < 1e-12);
        assert!((k.speedup_vs_packed() - 2.0).abs() < 1e-12);
        assert!((k.gflops(0.5) - 4.0).abs() < 1e-12);
        // json round-trips through the serializer
        let j = k.json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("speedup_vs_seed").as_f64(), Some(4.0));
        assert_eq!(parsed.get("speedup_simd_vs_packed").as_f64(), Some(2.0));
    }

    #[test]
    fn with_simd_off_passes_closure_result_through() {
        // Env *values* are not asserted here: gemm's own env-override test
        // may flip CORP_SIMD concurrently (dispatch is result-invariant,
        // so that race is benign for every numeric test — but not for a
        // string equality on the var itself).
        assert_eq!(with_simd_off(|| 42), 42);
    }
}
