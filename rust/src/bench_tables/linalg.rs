//! `corp bench linalg` — the perf-trajectory harness behind
//! `BENCH_linalg.json`.
//!
//! Benchmarks the packed parallel kernels against the seed's scalar
//! baselines (preserved in `linalg::gemm::reference`), sweeps the SYRK
//! worker count, and times the end-to-end calibrate+prune pipeline on the
//! native backend, all scaled by `CORP_BENCH_MODE`. Results print as a
//! table and are optionally emitted as machine-readable JSON so the numbers
//! are tracked PR-over-PR.

use anyhow::{Context, Result};

use super::{num, obj};
use crate::exec::Executor;
use crate::linalg::gemm::{matmul_f32, reference, syrk_upper_f32};
use crate::linalg::{Cholesky, Mat};
use crate::model::{ModelConfig, Scope, Sparsity, WeightStore};
use crate::prune::{calibrate, prune, Method, PruneOpts};
use crate::runtime::Runtime;
use crate::util::bench::{bench, bench_mode, BenchMode};
use crate::util::json::Json;
use crate::util::prop::gen;
use crate::util::threads;
use crate::util::{Pcg64, Stopwatch};

struct KernelResult {
    name: String,
    dims: String,
    flops: f64,
    new_s: f64,
    seed_s: f64,
}

impl KernelResult {
    fn speedup(&self) -> f64 {
        self.seed_s / self.new_s.max(1e-12)
    }

    fn gflops(&self, secs: f64) -> f64 {
        self.flops / secs.max(1e-12) / 1e9
    }

    fn print(&self) {
        println!(
            "{:24} {:>14} | packed {:9.3} ms ({:6.2} GF/s) | seed {:9.3} ms ({:6.2} GF/s) | {:5.2}x",
            self.name,
            self.dims,
            self.new_s * 1e3,
            self.gflops(self.new_s),
            self.seed_s * 1e3,
            self.gflops(self.seed_s),
            self.speedup()
        );
    }

    fn json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("dims", Json::Str(self.dims.clone())),
            ("flops", num(self.flops)),
            ("packed_s", num(self.new_s)),
            ("packed_gflops", num(self.gflops(self.new_s))),
            ("seed_s", num(self.seed_s)),
            ("seed_gflops", num(self.gflops(self.seed_s))),
            ("speedup_vs_seed", num(self.speedup())),
        ])
    }
}

/// Sizes per mode: (gemm n, syrk (rows, channels), cholesky n, iters).
fn mode_sizes() -> (usize, (usize, usize), usize, usize) {
    match bench_mode() {
        BenchMode::Smoke => (128, (512, 256), 160, 3),
        BenchMode::Fast => (256, (2048, 768), 640, 5),
        BenchMode::Full => (512, (4096, 1280), 1024, 7),
    }
}

/// E2E pipeline scale per mode: (model, calib batches).
fn mode_e2e() -> (&'static str, usize) {
    match bench_mode() {
        BenchMode::Smoke => ("vit_t", 2),
        BenchMode::Fast => ("vit_t", 8),
        BenchMode::Full => ("vit_b", 16),
    }
}

/// Run the linalg benchmark suite; when `json_out` is set, write
/// `BENCH_linalg.json`-style output there.
pub fn bench_linalg(json_out: Option<&str>) -> Result<()> {
    let (gemm_n, (syrk_rows, syrk_n), chol_n, iters) = mode_sizes();
    let mut rng = Pcg64::new(1);
    let mut kernels: Vec<KernelResult> = Vec::new();

    // ---- GEMM ----
    {
        let n = gemm_n;
        let a = gen::matrix(&mut rng, n, n, 1.0);
        let b = gen::matrix(&mut rng, n, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let s_new = bench("gemm_packed", 2, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            matmul_f32(&a, &b, &mut c, n, n, n);
        });
        let s_seed = bench("gemm_seed", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            reference::matmul_f32_seed(&a, &b, &mut c, n, n, n);
        });
        kernels.push(KernelResult {
            name: "gemm".into(),
            dims: format!("{n}x{n}x{n}"),
            flops: 2.0 * (n * n * n) as f64,
            new_s: s_new.mean_s,
            seed_s: s_seed.mean_s,
        });
    }

    // ---- SYRK (the Gram-accumulation hot path) ----
    {
        let (rows, n) = (syrk_rows, syrk_n);
        let x = gen::matrix(&mut rng, rows, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let s_new = bench("syrk_packed", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            syrk_upper_f32(&x, &mut c, rows, n);
        });
        let s_seed = bench("syrk_seed", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            reference::syrk_upper_f32_seed(&x, &mut c, rows, n);
        });
        kernels.push(KernelResult {
            name: "syrk".into(),
            dims: format!("{rows}x{n}"),
            flops: (rows * n * n) as f64, // ~half of full gemm
            new_s: s_new.mean_s,
            seed_s: s_seed.mean_s,
        });
    }

    // ---- TN-GEMM (CᵀC shape used by the attention accumulators) ----
    {
        let (rows, n) = (syrk_rows / 2, syrk_n / 2);
        let a = gen::matrix(&mut rng, rows, n, 1.0);
        let b = gen::matrix(&mut rng, rows, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let s_new = bench("tn_packed", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            crate::linalg::gemm::matmul_tn_f32(&a, &b, &mut c, rows, n, n);
        });
        let s_seed = bench("tn_seed", 1, iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            reference::matmul_tn_f32_seed(&a, &b, &mut c, rows, n, n);
        });
        kernels.push(KernelResult {
            name: "gemm_tn".into(),
            dims: format!("{rows}x{n}x{n}"),
            flops: 2.0 * (rows * n * n) as f64,
            new_s: s_new.mean_s,
            seed_s: s_seed.mean_s,
        });
    }

    println!(
        "linalg microbench — mode {:?}, {} worker(s)",
        bench_mode(),
        threads::threads()
    );
    for k in &kernels {
        k.print();
    }

    // ---- Cholesky + parallel multi-RHS solve (no seed counterpart delta;
    // reported for the trajectory) ----
    let chol = {
        let n = chol_n;
        let a = Mat::from_f32(n, n, &gen::spd(&mut rng, n, 0.5));
        let s_fac = bench("cholesky", 1, iters.min(3), || Cholesky::new(&a).unwrap());
        let f = Cholesky::new(&a).unwrap();
        let rhs = Mat::from_f32(n, 64, &gen::matrix(&mut rng, n, 64, 1.0));
        let s_solve = bench("chol_solve64", 1, iters.min(3), || f.solve_mat(&rhs));
        println!(
            "{:24} {:>14} | factor {:9.3} ms | 64-rhs solve {:9.3} ms",
            "cholesky",
            format!("{n}x{n}"),
            s_fac.mean_s * 1e3,
            s_solve.mean_s * 1e3
        );
        obj(vec![
            ("n", num(n as f64)),
            ("factor_s", num(s_fac.mean_s)),
            ("solve64_s", num(s_solve.mean_s)),
        ])
    };

    // ---- SYRK thread sweep ----
    let mut sweep = Vec::new();
    {
        let (rows, n) = (syrk_rows, syrk_n);
        let x = gen::matrix(&mut rng, rows, n, 1.0);
        let mut c = vec![0.0f32; n * n];
        let avail = threads::threads();
        let mut counts = vec![1usize, 2, 4, avail];
        counts.retain(|&w| w <= avail.max(1));
        counts.sort_unstable();
        counts.dedup();
        for w in counts {
            let s = threads::with_threads(w, || {
                bench(&format!("syrk_w{w}"), 1, iters.min(3), || {
                    c.iter_mut().for_each(|v| *v = 0.0);
                    syrk_upper_f32(&x, &mut c, rows, n);
                })
            });
            let gf = (rows * n * n) as f64 / s.mean_s.max(1e-12) / 1e9;
            println!("{:24} {:>14} | {w} worker(s): {:9.3} ms ({gf:6.2} GF/s)", "syrk_sweep", format!("{rows}x{n}"), s.mean_s * 1e3);
            sweep.push(obj(vec![
                ("threads", num(w as f64)),
                ("syrk_s", num(s.mean_s)),
                ("gflops", num(gf)),
            ]));
        }
    }

    // ---- End-to-end calibrate + prune on the native backend ----
    let (model, calib_batches) = mode_e2e();
    let e2e = {
        let cfg = ModelConfig::by_name(model).context("e2e model")?;
        let rt = Runtime::from_default_dir()?;
        let exec = Executor::new(&rt, cfg);
        let dense = WeightStore::init(cfg, 1);
        let opts = PruneOpts {
            sparsity: Sparsity::of(Scope::Both, 5),
            method: Method::Corp,
            calib_batches,
            ..PruneOpts::default()
        };
        let sw = Stopwatch::start();
        let stats = calibrate(&exec, &dense, &opts)?;
        let calib_s = sw.secs();
        let sw2 = Stopwatch::start();
        let result = prune(&exec, &dense, &stats, &opts)?;
        let prune_s = sw2.secs();
        println!(
            "e2e {model} (calib {calib_batches} batches): calibrate {calib_s:.3}s  prune {prune_s:.3}s  (sections: rank {:.3}s comp {:.3}s)",
            result.sections.get("ranking"),
            result.sections.get("compensation"),
        );
        obj(vec![
            ("model", Json::Str(model.to_string())),
            ("calib_batches", num(calib_batches as f64)),
            ("calibrate_s", num(calib_s)),
            ("prune_s", num(prune_s)),
            ("total_s", num(calib_s + prune_s)),
            ("ranking_cpu_s", num(result.sections.get("ranking"))),
            ("compensation_cpu_s", num(result.sections.get("compensation"))),
        ])
    };

    if let Some(path) = json_out {
        let root = obj(vec![
            ("schema", Json::Str("corp-bench-linalg/v1".into())),
            (
                "mode",
                Json::Str(
                    match bench_mode() {
                        BenchMode::Smoke => "smoke",
                        BenchMode::Fast => "fast",
                        BenchMode::Full => "full",
                    }
                    .into(),
                ),
            ),
            ("threads", num(threads::threads() as f64)),
            ("kernels", Json::Arr(kernels.iter().map(|k| k.json()).collect())),
            ("cholesky", chol),
            ("thread_sweep", Json::Arr(sweep)),
            ("e2e", e2e),
        ]);
        std::fs::write(path, root.to_string() + "\n")
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_tables_cover_all_modes() {
        // Pure functions of the mode env; just exercise the mapping tables.
        let (g, (sr, sn), c, it) = mode_sizes();
        assert!(g >= 64 && sr > sn / 8 && c >= 64 && it >= 1);
        let (m, cb) = mode_e2e();
        assert!(ModelConfig::by_name(m).is_some());
        assert!(cb >= 1);
    }

    #[test]
    fn kernel_result_math() {
        let k = KernelResult {
            name: "x".into(),
            dims: "1".into(),
            flops: 2e9,
            new_s: 0.5,
            seed_s: 2.0,
        };
        assert!((k.speedup() - 4.0).abs() < 1e-12);
        assert!((k.gflops(0.5) - 4.0).abs() < 1e-12);
        // json round-trips through the serializer
        let j = k.json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("speedup_vs_seed").as_f64(), Some(4.0));
    }
}
