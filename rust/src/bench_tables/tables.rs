//! Vision tables & figures: Table 2/3/4a/4b/5/6/9/10, Figures 2/3/4/5.

use anyhow::Result;

use super::{large_model, sparsity_grid, vit_sizes};
use crate::coordinator::Coordinator;
use crate::data::VisionGen;
use crate::exec::Executor;
use crate::flops::{flops, params, reduction_pct};
use crate::model::{ModelConfig, Scope, Sparsity};
use crate::prune::{baselines, Method, PruneOpts};
use crate::rank::MlpCriterion;
use crate::util::bench::CsvWriter;

/// Evaluation seed for every table row. Must match the `PruneOpts` seed the
/// `accuracy_at` rows evaluate under: `Coordinator::top1` scores the eval
/// window selected by the seed, so dense baselines and pruned variants have
/// to share one seed or the printed deltas pick up eval-sampling noise.
const EVAL_SEED: u64 = 1234;

/// Compile-time companion to [`EVAL_SEED`]: keep it locked to the default
/// `PruneOpts::seed` used by all `accuracy_at` rows.
#[cfg(test)]
mod eval_seed_guard {
    #[test]
    fn eval_seed_matches_default_prune_seed() {
        assert_eq!(super::EVAL_SEED, crate::prune::PruneOpts::default().seed);
    }
}

/// Table 2: Top-1 / FLOPs / params for every size × {MLP, Attn, Both} @50%.
pub fn table2(coord: &mut Coordinator) -> Result<()> {
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let mut csv = CsvWriter::new("table2", "model,scope,top1,flops_m,flops_red,params_m,params_red");
    println!("Table 2 — 50% structured sparsity (CORP)");
    println!("{:7} {:5} | {:>6} | {:>9} {:>7} | {:>9} {:>7}", "model", "scope", "top1", "GFLOPs", "red%", "params M", "red%");
    for cfg in vit_sizes() {
        let dense_w = coord.dense(cfg)?.clone();
        let dense_acc = coord.top1(cfg, &dense_w, EVAL_SEED)?;
        let fd = flops(cfg, Sparsity::dense());
        let pd = params(cfg, Sparsity::dense());
        println!(
            "{:7} {:5} | {:6.2} | {:9.1} {:>7} | {:9.3} {:>7}",
            cfg.name, "dense", dense_acc, fd as f64 / 1e6, "-", pd as f64 / 1e6, "-"
        );
        csv.row(&[cfg.name.into(), "dense".into(), format!("{dense_acc:.2}"),
            format!("{:.3}", fd as f64 / 1e6), "0".into(), format!("{:.3}", pd as f64 / 1e6), "0".into()]);
        for scope in [Scope::Mlp, Scope::Attn, Scope::Both] {
            let sp = Sparsity::of(scope, 5);
            let (acc, p, f, _) = coord.accuracy_at(cfg, sp, Method::Corp, &opts)?;
            println!(
                "{:7} {:5} | {:6.2} | {:9.1} {:6.1}% | {:9.3} {:6.1}%",
                cfg.name, scope.label(), acc,
                f as f64 / 1e6, reduction_pct(fd, f),
                p as f64 / 1e6, reduction_pct(pd, p)
            );
            csv.row(&[cfg.name.into(), scope.label().into(), format!("{acc:.2}"),
                format!("{:.3}", f as f64 / 1e6), format!("{:.2}", reduction_pct(fd, f)),
                format!("{:.3}", p as f64 / 1e6), format!("{:.2}", reduction_pct(pd, p))]);
        }
    }
    csv.flush()?;
    Ok(())
}

/// Table 3: calibration-size sweep at 50% joint sparsity.
pub fn table3(coord: &mut Coordinator) -> Result<()> {
    let grid: &[usize] = match crate::util::bench::bench_mode() {
        crate::util::bench::BenchMode::Smoke => &[2, 4],
        crate::util::bench::BenchMode::Fast => &[2, 4, 8],
        crate::util::bench::BenchMode::Full => &[2, 4, 8, 16, 32],
    };
    let mut csv = CsvWriter::new("table3", "model,calib_images,top1");
    println!("Table 3 — calibration-size sensitivity (50% joint, CORP)");
    print!("{:>8}", "calib");
    let sizes = vit_sizes();
    for cfg in &sizes {
        print!(" {:>8}", cfg.name);
    }
    println!();
    for &batches in grid {
        print!("{:>8}", batches * 16);
        for cfg in &sizes {
            let opts = PruneOpts { calib_batches: batches, ..PruneOpts::default() };
            let (acc, _, _, _) =
                coord.accuracy_at(cfg, Sparsity::of(Scope::Both, 5), Method::Corp, &opts)?;
            print!(" {:8.2}", acc);
            csv.row(&[cfg.name.into(), (batches * 16).to_string(), format!("{acc:.2}")]);
        }
        println!();
    }
    csv.flush()?;
    Ok(())
}

/// Table 4a: CORP vs GRAIL-like vs SNOWS-like on the large model, MLP/Attn.
pub fn table4a(coord: &mut Coordinator) -> Result<()> {
    let cfg = large_model();
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let dense_w = coord.dense(cfg)?.clone();
    let dense_acc = coord.top1(cfg, &dense_w, EVAL_SEED)?;
    let mut csv = CsvWriter::new("table4a", "method,scope,top1,delta");
    println!("Table 4a — {} (dense {dense_acc:.2}%)", cfg.name);
    println!("{:11} {:5} | {:>6} {:>7}", "method", "scope", "top1", "delta");

    let row = |m: &str, s: &str, acc: f64, csv: &mut CsvWriter| {
        println!("{m:11} {s:5} | {acc:6.2} {:7.2}", acc - dense_acc);
        csv.row(&[m.into(), s.into(), format!("{acc:.2}"), format!("{:.2}", acc - dense_acc)]);
    };

    for (scope, label) in [(Scope::Attn, "attn"), (Scope::Mlp, "mlp")] {
        // SNOWS-like 2:4 with recovery (dense shapes).
        {
            coord.calib(cfg, &opts)?;
            let dense = coord.dense(cfg)?.clone();
            let key = format!("{}@{}", cfg.name, opts.calib_batches);
            let stats = coord.calib_stats(&key);
            let exec = Executor::new(&coord.rt, cfg);
            let res = baselines::prune_snows24(&exec, &dense, stats, &opts, scope == Scope::Mlp)?;
            let acc = coord.top1(cfg, &res.weights, EVAL_SEED)?;
            row("SNOWS-2:4", label, acc, &mut csv);
        }
        // GRAIL-like at 50%.
        let (acc, _, _, _) = coord.accuracy_at(cfg, Sparsity::of(scope, 5), Method::Grail, &opts)?;
        row("GRAIL-like", label, acc, &mut csv);
        // CORP at 50%.
        let (acc, _, _, _) = coord.accuracy_at(cfg, Sparsity::of(scope, 5), Method::Corp, &opts)?;
        row("CORP", label, acc, &mut csv);
    }
    csv.flush()?;
    Ok(())
}

/// Table 4b: CORP vs DC-ViT-like at matched FLOPs reduction (vit_b).
pub fn table4b(coord: &mut Coordinator) -> Result<()> {
    let cfg = ModelConfig::by_name("vit_b").unwrap();
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let dense_w = coord.dense(cfg)?.clone();
    let dense_acc = coord.top1(cfg, &dense_w, EVAL_SEED)?;
    let fd = flops(cfg, Sparsity::dense());
    let mut csv = CsvWriter::new("table4b", "method,flops_red,top1,delta");
    println!("Table 4b — matched FLOPs reduction on {} (dense {dense_acc:.2}%)", cfg.name);

    // DC-ViT-like: (removed attention layers, mlp sparsity) pairs.
    let dc_settings: &[(usize, u8)] = &[(2, 1), (3, 2), (4, 4)];
    // CORP joint sparsities with roughly matching FLOPs cuts.
    let corp_settings: &[u8] = &[1, 2, 4];

    for (&(removed, mlp_s10), &corp_s10) in dc_settings.iter().zip(corp_settings) {
        // --- DC-ViT-like ---
        coord.calib(cfg, &opts)?;
        let dense = coord.dense(cfg)?.clone();
        let key = format!("{}@{}", cfg.name, opts.calib_batches);
        let stats = coord.calib_stats(&key);
        let exec = Executor::new(&coord.rt, cfg);
        let dc_opts = PruneOpts {
            sparsity: Sparsity { mlp_s10, attn_s10: 0 },
            ..opts.clone()
        };
        let (res, skipped) = baselines::prune_dcvit(&exec, &dense, stats, &dc_opts, removed)?;
        let acc = eval_mlponly(coord, cfg, &res.weights, &skipped)?;
        let f_dc = flops_dcvit(cfg, mlp_s10, &skipped);
        println!(
            "DC-ViT-like  flops -{:5.1}% | top1 {acc:6.2} Δ{:6.2}  (attn removed from {} blocks)",
            reduction_pct(fd, f_dc), acc - dense_acc, skipped.len()
        );
        csv.row(&["dcvit".into(), format!("{:.2}", reduction_pct(fd, f_dc)), format!("{acc:.2}"), format!("{:.2}", acc - dense_acc)]);
        // --- CORP ---
        let sp = Sparsity::of(Scope::Both, corp_s10);
        let (acc, _, f, _) = coord.accuracy_at(cfg, sp, Method::Corp, &opts)?;
        println!(
            "CORP         flops -{:5.1}% | top1 {acc:6.2} Δ{:6.2}",
            reduction_pct(fd, f), acc - dense_acc
        );
        csv.row(&["corp".into(), format!("{:.2}", reduction_pct(fd, f)), format!("{acc:.2}"), format!("{:.2}", acc - dense_acc)]);
    }
    csv.flush()?;
    Ok(())
}

/// FLOPs of a DC-ViT-like configuration: MLP pruned everywhere, attention
/// removed from `skipped` blocks.
pub fn flops_dcvit(cfg: &ModelConfig, mlp_s10: u8, skipped: &[usize]) -> usize {
    let base = flops(cfg, Sparsity { mlp_s10, attn_s10: 0 });
    // Attention cost per block (dense dqk).
    let n = cfg.n_ctx;
    let (d, h, dh) = (cfg.d, cfg.heads, cfg.dh());
    let attn = 2 * n * d * (h * dh) * 3 + 2 * n * n * (h * dh) * 2 + 2 * n * (h * dh) * d;
    base - attn * skipped.len()
}

/// Evaluate a model whose `skipped` layers use the attention-free artifact.
/// Scores the same [`EVAL_SEED`] eval window as `Coordinator::top1`, so the
/// DC-ViT rows stay comparable with the `accuracy_at` CORP rows.
fn eval_mlponly(
    coord: &Coordinator,
    cfg: &'static ModelConfig,
    w: &crate::model::WeightStore,
    skipped: &[usize],
) -> Result<f64> {
    let exec = Executor::new(&coord.rt, cfg);
    let gen = VisionGen::new(crate::data::DATA_SEED);
    let b = cfg.eval_batch();
    let start = crate::eval::eval_window(EVAL_SEED);
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..coord.scale.eval_batches {
        let (tokens, labels) = gen.batch(crate::data::Split::Eval, start + i as u64, b);
        let mut x = exec.embed(w, &tokens, b)?;
        for l in 0..cfg.layers {
            if skipped.contains(&l) {
                x = exec.block_mlponly(w, l, &x, b)?;
            } else {
                x = exec.block(w, l, &x, b)?;
            }
        }
        let logits = exec.head(w, &x, b)?;
        let c = cfg.classes;
        for (j, &label) in labels.iter().enumerate() {
            let rowv = &logits.data()[j * c..(j + 1) * c];
            let best = (0..c).max_by(|&a, &bb| rowv[a].total_cmp(&rowv[bb])).unwrap();
            if best == label as usize {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(100.0 * correct as f64 / total as f64)
}

/// Tables 5 & 10: accuracy + efficiency across sparsity levels (joint scope).
/// Table 5 is the largest model's slice of Table 10.
pub fn table10(coord: &mut Coordinator) -> Result<()> {
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let mut csv = CsvWriter::new(
        "table10",
        "model,sparsity,top1,params_m,flops_m,p50_ms,fps,params_red,flops_red,tp_speedup",
    );
    println!("Table 5/10 — accuracy & efficiency across sparsity (joint, CORP)");
    println!(
        "{:7} {:>4} | {:>6} {:>9} {:>9} {:>8} {:>7} | {:>6} {:>6} {:>5}",
        "model", "s", "top1", "params M", "GFLOPs", "p50 ms", "fps", "par↓%", "fl↓%", "TP×"
    );
    for cfg in vit_sizes() {
        let fd = flops(cfg, Sparsity::dense());
        let pd = params(cfg, Sparsity::dense());
        let mut fps_dense = 0.0;
        for &s in &sparsity_grid() {
            let sp = Sparsity::of(Scope::Both, s);
            let weights = if s == 0 {
                coord.dense(cfg)?.clone()
            } else {
                let o = PruneOpts { sparsity: sp, ..opts.clone() };
                coord.prune_job(cfg, &o)?.weights
            };
            let acc = coord.top1(cfg, &weights, EVAL_SEED)?;
            let exec = Executor::new(&coord.rt, cfg);
            let gen = VisionGen::new(crate::data::DATA_SEED);
            let stats = crate::serve::measure(&exec, &weights, &gen, coord.scale.serve_iters, coord.scale.serve_iters)?;
            if s == 0 {
                fps_dense = stats.throughput_fps;
            }
            let p = params(cfg, sp);
            let f = flops(cfg, sp);
            let speedup = if fps_dense > 0.0 { stats.throughput_fps / fps_dense } else { 1.0 };
            println!(
                "{:7} {:>4.1} | {:6.2} {:9.3} {:9.1} {:8.2} {:7.0} | {:6.1} {:6.1} {:5.2}",
                cfg.name, s as f64 / 10.0, acc,
                p as f64 / 1e6, f as f64 / 1e6,
                stats.p50_ms, stats.throughput_fps,
                reduction_pct(pd, p), reduction_pct(fd, f), speedup
            );
            csv.row(&[cfg.name.into(), format!("{:.1}", s as f64 / 10.0), format!("{acc:.2}"),
                format!("{:.3}", p as f64 / 1e6), format!("{:.3}", f as f64 / 1e6),
                format!("{:.3}", stats.p50_ms), format!("{:.1}", stats.throughput_fps),
                format!("{:.2}", reduction_pct(pd, p)), format!("{:.2}", reduction_pct(fd, f)),
                format!("{:.3}", speedup)]);
        }
    }
    csv.flush()?;
    Ok(())
}

/// Table 6: pipeline runtime breakdown per model size.
pub fn table6(coord: &mut Coordinator) -> Result<()> {
    let mut csv = CsvWriter::new("table6", "model,params_m,calibration_s,ranking_s,compensation_s,total_s");
    println!("Table 6 — pipeline runtime breakdown (50% joint)");
    println!("{:7} {:>9} | {:>8} {:>7} {:>7} {:>8}", "model", "params M", "calib s", "rank s", "comp s", "total s");
    for cfg in vit_sizes() {
        // Fresh calibration per model (do not reuse the cache — we time it).
        let opts = PruneOpts {
            calib_batches: coord.scale.calib_batches,
            sparsity: Sparsity::of(Scope::Both, 5),
            ..PruneOpts::default()
        };
        let dense = coord.dense(cfg)?.clone();
        let exec = Executor::new(&coord.rt, cfg);
        let result = crate::prune::run_pipeline(&exec, &dense, &opts)?;
        let s = &result.sections;
        let (cal, rank, comp) = (s.get("calibration"), s.get("ranking"), s.get("compensation"));
        println!(
            "{:7} {:9.3} | {:8.2} {:7.3} {:7.2} {:8.2}",
            cfg.name, params(cfg, Sparsity::dense()) as f64 / 1e6, cal, rank, comp, cal + rank + comp
        );
        csv.row(&[cfg.name.into(), format!("{:.3}", params(cfg, Sparsity::dense()) as f64 / 1e6),
            format!("{cal:.3}"), format!("{rank:.4}"), format!("{comp:.3}"), format!("{:.3}", cal + rank + comp)]);
    }
    csv.flush()?;
    Ok(())
}

/// Table 9: MLP redundancy statistics per block (vit_b analogue of DeiT-B).
pub fn table9(coord: &mut Coordinator) -> Result<()> {
    let cfg = match crate::util::bench::bench_mode() {
        crate::util::bench::BenchMode::Smoke => ModelConfig::by_name("vit_t").unwrap(),
        _ => ModelConfig::by_name("vit_b").unwrap(),
    };
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    coord.dense(cfg)?;
    coord.calib(cfg, &opts)?;
    let key = format!("{}@{}", cfg.name, opts.calib_batches);
    let stats = coord.calib_stats(&key);
    let mut csv = CsvWriter::new("table9", "layer,dim,eff_rank,rank_ratio,k95,k95_ratio,act_sparsity");
    println!("Table 9 — MLP activation redundancy ({})", cfg.name);
    println!("{:>5} {:>5} {:>9} {:>6} {:>5} {:>6} {:>9}", "layer", "dim", "eff.rank", "ratio", "k95", "ratio", "sparsity");
    for (l, ls) in stats.layers.iter().enumerate() {
        let red = crate::stats::redundancy(&ls.hidden.covariance());
        let sp = ls.active.sparsity();
        println!(
            "{l:>5} {:>5} {:>9.1} {:>6.3} {:>5} {:>6.3} {:>9.2}",
            cfg.mlp, red.effective_rank, red.rank_ratio, red.k95, red.k95_ratio, sp
        );
        csv.row(&[l.to_string(), cfg.mlp.to_string(), format!("{:.1}", red.effective_rank),
            format!("{:.3}", red.rank_ratio), red.k95.to_string(), format!("{:.3}", red.k95_ratio),
            format!("{sp:.3}")]);
    }
    csv.flush()?;
    Ok(())
}

/// Figure 2: accuracy vs sparsity with/without compensation, 3 scopes.
pub fn fig2(coord: &mut Coordinator) -> Result<()> {
    let cfg = large_model();
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let mut csv = CsvWriter::new("fig2", "model,scope,sparsity,method,top1");
    println!("Figure 2 — accuracy vs sparsity, comp vs no-comp ({})", cfg.name);
    for scope in [Scope::Mlp, Scope::Attn, Scope::Both] {
        for method in [Method::Corp, Method::Naive] {
            print!("{:5} {:6}:", scope.label(), method.label());
            for &s in &sparsity_grid() {
                let (acc, _, _, _) =
                    coord.accuracy_at(cfg, Sparsity::of(scope, s), method, &opts)?;
                print!(" {:.0}%@{:.1}", acc, s as f64 / 10.0);
                csv.row(&[cfg.name.into(), scope.label().into(), format!("{:.1}", s as f64 / 10.0),
                    method.label().into(), format!("{acc:.2}")]);
            }
            println!();
        }
    }
    csv.flush()?;
    Ok(())
}

/// Figure 3: CORP vs VBP-like vs GRAIL-like, MLP-only, per size.
pub fn fig3(coord: &mut Coordinator) -> Result<()> {
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let mut csv = CsvWriter::new("fig3", "model,method,sparsity,top1");
    println!("Figure 3 — MLP-only pruning: CORP vs VBP-like vs GRAIL-like");
    for cfg in vit_sizes() {
        for method in [Method::Corp, Method::Grail, Method::Vbp] {
            print!("{:7} {:10}:", cfg.name, method.label());
            for &s in &sparsity_grid() {
                if s == 0 {
                    continue;
                }
                let (acc, _, _, _) =
                    coord.accuracy_at(cfg, Sparsity::of(Scope::Mlp, s), method, &opts)?;
                print!(" {:.1}@{:.1}", acc, s as f64 / 10.0);
                csv.row(&[cfg.name.into(), method.label().into(), format!("{:.1}", s as f64 / 10.0), format!("{acc:.2}")]);
            }
            println!();
        }
    }
    csv.flush()?;
    Ok(())
}

/// Figure 4: matched-FLOPs comparison — CORP prunes both scopes, baselines
/// MLP-only; accuracy at each *FLOPs reduction* level.
pub fn fig4(coord: &mut Coordinator) -> Result<()> {
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let mut csv = CsvWriter::new("fig4", "model,method,flops_red,top1");
    println!("Figure 4 — accuracy at matched FLOPs reduction");
    for cfg in vit_sizes() {
        let fd = flops(cfg, Sparsity::dense());
        // CORP joint at grid sparsities; baselines MLP-only at the sparsity
        // that produces the closest FLOPs cut.
        for &s in &sparsity_grid() {
            if s == 0 {
                continue;
            }
            let sp_joint = Sparsity::of(Scope::Both, s);
            let target_red = reduction_pct(fd, flops(cfg, sp_joint));
            let (acc_corp, _, _, _) = coord.accuracy_at(cfg, sp_joint, Method::Corp, &opts)?;
            // Find MLP-only sparsity matching target_red (may cap at 0.7).
            let mut best = (7u8, f64::MAX);
            for cand in 1..=7u8 {
                let red = reduction_pct(fd, flops(cfg, Sparsity::of(Scope::Mlp, cand)));
                let gap = (red - target_red).abs();
                if gap < best.1 {
                    best = (cand, gap);
                }
            }
            let sp_mlp = Sparsity::of(Scope::Mlp, best.0);
            let (acc_grail, _, _, _) = coord.accuracy_at(cfg, sp_mlp, Method::Grail, &opts)?;
            let (acc_vbp, _, _, _) = coord.accuracy_at(cfg, sp_mlp, Method::Vbp, &opts)?;
            println!(
                "{:7} flops -{target_red:5.1}% | CORP(joint) {acc_corp:6.2} GRAIL(mlp@{:.1}) {acc_grail:6.2} VBP(mlp@{:.1}) {acc_vbp:6.2}",
                cfg.name, best.0 as f64 / 10.0, best.0 as f64 / 10.0
            );
            for (m, a) in [("corp", acc_corp), ("grail", acc_grail), ("vbp", acc_vbp)] {
                csv.row(&[cfg.name.into(), m.into(), format!("{target_red:.2}"), format!("{a:.2}")]);
            }
        }
    }
    csv.flush()?;
    Ok(())
}

/// Figure 5: ranking-criterion ablation × compensation at 50% joint.
pub fn fig5(coord: &mut Coordinator) -> Result<()> {
    let cfg = large_model();
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let mut csv = CsvWriter::new("fig5", "model,criterion,method,top1");
    println!("Figure 5 — ranking ablation × compensation ({}, 50% joint)", cfg.name);
    println!("{:9} | {:>8} {:>8}", "criterion", "comp", "no-comp");
    for crit in MlpCriterion::all() {
        let mut accs = Vec::new();
        for method in [Method::Corp, Method::Naive] {
            let o = PruneOpts { criterion: crate::rank::Criterion::Mlp(crit), ..opts.clone() };
            let (acc, _, _, _) =
                coord.accuracy_at(cfg, Sparsity::of(Scope::Both, 5), method, &o)?;
            csv.row(&[cfg.name.into(), crit.label().into(), method.label().into(), format!("{acc:.2}")]);
            accs.push(acc);
        }
        println!("{:9} | {:8.2} {:8.2}", crit.label(), accs[0], accs[1]);
    }
    csv.flush()?;
    Ok(())
}
