//! Table 7: language-model pruning (OPT-1.3B → char-GPT substitute).

use anyhow::Result;

use crate::coordinator::Coordinator;
use crate::data::TextGen;
use crate::exec::Executor;
use crate::flops::{flops, params, reduction_pct};
use crate::model::{ModelConfig, Scope, Sparsity};
use crate::prune::PruneOpts;
use crate::util::bench::CsvWriter;

/// Table 7: perplexity + FLOPs/params at 30% sparsity for MLP / Attn / Both.
/// Calibration uses the Calib split; evaluation the Eval split — the same
/// calibration–evaluation mismatch the paper probes with C4 → WikiText-2.
pub fn table7(coord: &mut Coordinator) -> Result<()> {
    let cfg = ModelConfig::by_name("gpt_s").unwrap();
    let opts = PruneOpts { calib_batches: coord.scale.calib_batches, ..PruneOpts::default() };
    let dense = coord.dense(cfg)?.clone();
    // Prune all three scopes up front (prune_job needs &mut coord).
    let mut pruned = Vec::new();
    for scope in [Scope::Mlp, Scope::Attn, Scope::Both] {
        let o = PruneOpts { sparsity: Sparsity::of(scope, 3), ..opts.clone() };
        pruned.push(coord.prune_job(cfg, &o)?.weights);
    }
    let exec = Executor::new(&coord.rt, cfg);
    let gen = TextGen::new(crate::data::DATA_SEED);
    let n_eval = coord.scale.eval_batches;
    let fd = flops(cfg, Sparsity::dense());
    let pd = params(cfg, Sparsity::dense());
    let mut csv = CsvWriter::new("table7", "target,ppl,flops_m,flops_red,params_m,params_red");
    println!("Table 7 — char-GPT (OPT substitute) at 30% sparsity");
    println!("{:9} | {:>7} | {:>9} {:>6} | {:>9} {:>6}", "target", "ppl", "MFLOPs", "red%", "params M", "red%");

    let base_ppl = crate::eval::ppl_stitched(&exec, &dense, &gen, n_eval)?;
    println!("{:9} | {:7.3} | {:9.1} {:>6} | {:9.3} {:>6}", "baseline", base_ppl, fd as f64 / 1e6, "-", pd as f64 / 1e6, "-");
    csv.row(&["baseline".into(), format!("{base_ppl:.4}"), format!("{:.3}", fd as f64 / 1e6), "0".into(),
        format!("{:.3}", pd as f64 / 1e6), "0".into()]);

    for ((scope, label), weights) in
        [(Scope::Mlp, "mlp"), (Scope::Attn, "attn"), (Scope::Both, "both")].into_iter().zip(&pruned)
    {
        let sp = Sparsity::of(scope, 3);
        let ppl = crate::eval::ppl_stitched(&exec, weights, &gen, n_eval)?;
        let f = flops(cfg, sp);
        let p = params(cfg, sp);
        println!(
            "{label:9} | {ppl:7.3} | {:9.1} {:5.1}% | {:9.3} {:5.1}%",
            f as f64 / 1e6, reduction_pct(fd, f),
            p as f64 / 1e6, reduction_pct(pd, p)
        );
        csv.row(&[label.into(), format!("{ppl:.4}"), format!("{:.3}", f as f64 / 1e6),
            format!("{:.2}", reduction_pct(fd, f)), format!("{:.3}", p as f64 / 1e6),
            format!("{:.2}", reduction_pct(pd, p))]);
    }
    println!("(source entropy floor: ppl ≈ {:.2})", TextGen::entropy_floor().exp());
    csv.flush()?;
    Ok(())
}
