//! `corp bench serve` — the serving-engine harness behind `BENCH_serve.json`.
//!
//! Drives the concurrent engine (`serve::run_engine`) over a grid of
//! workload (vision / text / gen) × model variant (dense / pruned /
//! compensated / compensated+int8 at 50% joint sparsity) × worker count ×
//! arrival rate × dispatch policy (padded / exact) — and, for the
//! generation workload, a decode axis (KV-cache vs prefill-per-step, with
//! a paged-KV cell that turns on chunked prefill + a shared prompt
//! opening) — reporting per-cell p50/p95/p99 latency, queueing delay,
//! mean formed and dispatched batch sizes, steps per request, TTFT/ITL,
//! and requests+tokens/sec (schema `corp-bench-serve/v7`). The
//! "saturated" rate offers the whole
//! request set at t = 0 with an ample queue, so the throughput column is
//! the engine's capacity — this is where the pruned fast path has to beat
//! dense, since its GEMMs run at the retained widths, and where KV-cache
//! decode has to beat prefill-per-step at identical outputs (per-token
//! work is one position's GEMMs instead of the full context's). The low
//! rates are where the dispatch axis matters: batches are mostly partial
//! there, so exact-size dispatch skips the padding arithmetic and should
//! cut tail latency versus padded on the same variant.
//!
//! KV-cache cells additionally report the paged pool's telemetry:
//! `kv_bytes_per_step` is the bytes of K/V *appended* per decode dispatch
//! (paging makes this a function of batch and head widths only — it must
//! not scale with `n_ctx`), `kv_peak_bytes` is the pool's high-water mark,
//! and `kv_shared_ratio` is the fraction of block acquisitions served by
//! adopting a published prefix block instead of allocating. The chunked +
//! shared-prefix cell doubles as the prefill-interference probe: its
//! `itl_mean_ms` shows decode cadence while long prefills are split into
//! bounded chunks and interleaved into the same batches.
//!
//! v6 adds the int8 row axis (`variant = "compensated_int8"`,
//! `quantized = true` on every grid row): the pruned+compensated store
//! weight-quantized to int8 with the dequant correction folded from the
//! same calibration pass, dispatched through `serve::run_engine_q8` and
//! the `_w8` plan rung — the row where int8 throughput has to beat f32 at
//! matching predictions (pinned by `tests/quant_equality`).
//!
//! v7 adds the chaos cell (`cell = "chaos"`): the same fleet served
//! through the simulator with a deterministic fault plan injected —
//! worker kills, dispatch faults, and a service-time delay — under
//! per-request deadlines and a retry budget, controller off and then on
//! (with the fault-rate degrade signal armed). The row reports goodput
//! (non-failed fraction of offered requests), p99, and the full fault
//! accounting (`failures`/`retries`/`timeouts`/`worker_respawns`), using
//! a deterministic affine cost model so the trajectory is bit-stable
//! run-to-run.
//!
//! v5 adds the load-spike cell (`cell = "load_spike"`): the fleet served
//! through the deterministic discrete-event simulator under a 3× arrival
//! spike over the middle third of the schedule, with the SLO feedback
//! controller off and then on (`--degrade`), service times drawn from
//! per-batch-size cost tables *measured on the real executor* — so the row
//! pairs the tail-latency/shedding win against its accuracy proxy (the
//! fraction of requests served by the degraded pruned+compensated rung).
//!
//! A failed cell aborts the sweep with the cell's coordinates in the error
//! (non-zero exit through the CLI), and any pre-existing `--out` file is
//! removed up front — a crashed sweep can never leave a stale JSON that
//! looks like fresh results.

use anyhow::{bail, Context, Result};

use super::{num, obj};
use crate::exec::{DecodeMode, Executor};
use crate::model::{ModelConfig, ModelKind, Scope, Sparsity, WeightStore};
use crate::prune::{calibrate, prune, Method, PruneOpts};
use crate::runtime::Runtime;
use crate::serve::{
    run_engine, run_engine_q8, DispatchPolicy, EngineOpts, GenWorkload, GptWorkload, StoreRef,
    VisionWorkload, Workload,
};
use crate::util::bench::{bench_mode, BenchMode};
use crate::util::json::Json;
use crate::util::threads;

/// Arrival rate treated as "everything is due immediately".
const SATURATED_RATE: f64 = 1e9;

/// The dispatch axis every cell is swept over (`auto` interpolates between
/// these two and is covered by tests, not the bench grid).
const DISPATCHES: [DispatchPolicy; 2] = [DispatchPolicy::Padded, DispatchPolicy::Exact];

/// One workload's slice of the bench grid.
struct WorkloadGrid {
    model: &'static str,
    /// `true` serves the multi-step generation workload (gpt models only);
    /// its cells additionally sweep the decode axis (kv vs prefill).
    gen: bool,
    requests: usize,
    workers: Vec<usize>,
    rates: Vec<f64>,
    max_batch: usize,
    calib_batches: usize,
}

/// Per-mode grids: one vision + one text + one generation entry each, so
/// every `BENCH_serve.json` carries all three workload axes (the gen entry
/// fans into kv, kv + chunked/shared-prefix, and prefill decode cells).
fn mode_grids() -> Vec<WorkloadGrid> {
    match bench_mode() {
        BenchMode::Smoke => vec![
            WorkloadGrid {
                model: "vit_t",
                gen: false,
                requests: 96,
                workers: vec![1, 2],
                rates: vec![SATURATED_RATE, 150.0],
                max_batch: 8,
                calib_batches: 2,
            },
            WorkloadGrid {
                model: "gpt_s",
                gen: false,
                requests: 32,
                workers: vec![1],
                rates: vec![SATURATED_RATE, 60.0],
                max_batch: 4,
                calib_batches: 2,
            },
            WorkloadGrid {
                model: "gpt_s",
                gen: true,
                requests: 16,
                workers: vec![1],
                rates: vec![SATURATED_RATE],
                max_batch: 4,
                calib_batches: 2,
            },
        ],
        BenchMode::Fast => vec![
            WorkloadGrid {
                model: "vit_t",
                gen: false,
                requests: 256,
                workers: vec![1, 2],
                rates: vec![SATURATED_RATE, 300.0, 120.0],
                max_batch: 16,
                calib_batches: 4,
            },
            WorkloadGrid {
                model: "gpt_s",
                gen: false,
                requests: 64,
                workers: vec![1, 2],
                rates: vec![SATURATED_RATE, 60.0],
                max_batch: 8,
                calib_batches: 4,
            },
            WorkloadGrid {
                model: "gpt_s",
                gen: true,
                requests: 32,
                workers: vec![1, 2],
                rates: vec![SATURATED_RATE],
                max_batch: 4,
                calib_batches: 4,
            },
        ],
        BenchMode::Full => vec![
            WorkloadGrid {
                model: "vit_b",
                gen: false,
                requests: 512,
                workers: vec![1, 2, 4],
                rates: vec![SATURATED_RATE, 400.0, 150.0],
                max_batch: 16,
                calib_batches: 8,
            },
            WorkloadGrid {
                model: "gpt_s",
                gen: false,
                requests: 128,
                workers: vec![1, 2],
                rates: vec![SATURATED_RATE, 80.0],
                max_batch: 8,
                calib_batches: 8,
            },
            WorkloadGrid {
                model: "gpt_s",
                gen: true,
                requests: 64,
                workers: vec![1, 2],
                rates: vec![SATURATED_RATE, 40.0],
                max_batch: 8,
                calib_batches: 8,
            },
        ],
    }
}

/// Sweep one workload's grid cells and append a JSON row per cell.
fn grid_runs<W: Workload>(
    exec: &Executor<'_>,
    variants: &[(&str, StoreRef<'_>)],
    workload: &W,
    g: &WorkloadGrid,
    // `(prefill_chunk, shared_prefix)` for generation cells (0 = off);
    // `None` for single-shot workloads, which have no prefill axis.
    kv_cell: Option<(usize, usize)>,
    runs: &mut Vec<Json>,
) -> Result<()> {
    let decode = workload.decode().map(|m| m.label());
    for &(label, store) in variants {
        for &nw in &g.workers {
            for &rate in &g.rates {
                for dispatch in DISPATCHES {
                    let eopts = EngineOpts {
                        workers: nw,
                        rate,
                        requests: g.requests,
                        max_batch: g.max_batch,
                        max_wait: 0.005,
                        // Capacity grid: queue everything, shed nothing.
                        queue_cap: g.requests,
                        dispatch,
                        ..Default::default()
                    };
                    let rate_label = if rate >= SATURATED_RATE {
                        "saturated".to_string()
                    } else {
                        format!("{rate:.0}/s")
                    };
                    // A failing cell aborts the whole sweep with its
                    // coordinates — never a silently partial grid.
                    let s = match store {
                        StoreRef::F32(w) => run_engine(exec, w, workload, &eopts),
                        StoreRef::Q8(qs) => run_engine_q8(exec, qs, workload, &eopts),
                    }
                    .with_context(|| {
                        format!(
                            "serve bench cell failed: workload {}{} model {} variant {label} \
                             workers {nw} rate {rate_label} dispatch {}",
                            workload.label(),
                            decode.map(|d| format!("/{d}")).unwrap_or_default(),
                            g.model,
                            dispatch.label()
                        )
                    })?;
                    println!(
                        "{:6}{} {label:12} w={nw} rate {rate_label:>9} {:6}: p50 {:8.2}ms \
                         p95 {:8.2}ms | queue p50 {:8.2}ms | batch {:4.1} → {:4.1} | \
                         {:6.0} req/s {:7.0} tok/s",
                        workload.label(),
                        decode.map(|d| format!("/{d:7}")).unwrap_or_else(|| " ".repeat(8)),
                        dispatch.label(),
                        s.p50_ms,
                        s.p95_ms,
                        s.queue_p50_ms,
                        s.mean_batch,
                        s.mean_dispatch,
                        s.throughput_fps,
                        s.throughput_tps
                    );
                    let mut row = vec![
                        ("workload", Json::Str(workload.label().to_string())),
                        ("model", Json::Str(g.model.to_string())),
                        ("variant", Json::Str(label.to_string())),
                        ("workers", num(nw as f64)),
                        ("rate_rps", num(rate)),
                        ("saturated", Json::Bool(rate >= SATURATED_RATE)),
                        ("dispatch", Json::Str(dispatch.label().to_string())),
                        ("quantized", Json::Bool(matches!(store, StoreRef::Q8(_)))),
                        ("requests", num(g.requests as f64)),
                        ("max_batch", num(g.max_batch as f64)),
                        ("served", num(s.served as f64)),
                        ("shed", num(s.shed as f64)),
                        ("batches", num(s.batches as f64)),
                        ("mean_batch", num(s.mean_batch)),
                        ("mean_dispatch", num(s.mean_dispatch)),
                        ("mean_steps", num(s.steps_mean)),
                        ("p50_ms", num(s.p50_ms)),
                        ("p95_ms", num(s.p95_ms)),
                        ("p99_ms", num(s.p99_ms)),
                        ("queue_p50_ms", num(s.queue_p50_ms)),
                        ("ttft_p50_ms", num(s.first_p50_ms)),
                        ("itl_mean_ms", num(s.itl_mean_ms)),
                        ("exec_mean_ms", num(s.exec_mean_ms)),
                        ("requests_per_sec", num(s.throughput_fps)),
                        ("tokens_per_sec", num(s.throughput_tps)),
                    ];
                    // The decode axis only exists for generation cells;
                    // those also carry the paged-KV columns (all-zero on
                    // prefill-per-step cells, which hold no cache).
                    if let Some(d) = decode {
                        row.push(("decode", Json::Str(d.to_string())));
                        let (chunk, shared) = kv_cell.unwrap_or((0, 0));
                        row.push(("prefill_chunk", num(chunk as f64)));
                        row.push(("shared_prefix", num(shared as f64)));
                        row.push(("kv_bytes_per_step", num(s.kv_bytes_per_step)));
                        row.push(("kv_peak_bytes", num(s.kv_peak_bytes as f64)));
                        let grabs = s.kv_allocs + s.kv_shared_hits;
                        row.push((
                            "kv_shared_ratio",
                            num(if grabs == 0 {
                                0.0
                            } else {
                                s.kv_shared_hits as f64 / grabs as f64
                            }),
                        ));
                    }
                    // Keep the v1 column name on the vision axis so the
                    // BENCH trajectory stays comparable across schemas.
                    if workload.cfg().kind == ModelKind::Vit {
                        row.push(("images_per_sec", num(s.throughput_fps)));
                    }
                    runs.push(obj(row));
                }
            }
        }
    }
    Ok(())
}

/// The v5 load-spike cell: one fleet member with a dense primary rung and
/// a CORP-compensated fallback rung, served through the deterministic
/// simulator (`serve::run_fleet_sim`) under a 3× arrival spike over the
/// middle third — controller off, then on with variant degradation.
/// Service times come from per-dispatch-size cost tables measured on the
/// real executor, so the p99/shed/degraded-fraction trade-off in the row
/// reflects this machine's actual dense-vs-compensated cost gap.
#[cfg(not(pjrt_backend))]
fn spike_cells(rt: &Runtime, runs: &mut Vec<Json>) -> Result<()> {
    use crate::serve::{run_fleet_sim, ControllerOpts, FleetMember, SimCost};

    let (model, requests, max_batch, workers, reps) = match bench_mode() {
        BenchMode::Smoke => ("vit_t", 96usize, 8usize, 2usize, 2usize),
        BenchMode::Fast => ("vit_t", 192, 8, 2, 3),
        BenchMode::Full => ("vit_b", 256, 8, 2, 3),
    };
    let cfg = ModelConfig::by_name(model).context("spike cell model")?;
    let exec = Executor::new(rt, cfg);
    let dense = WeightStore::init(cfg, 1);
    let popts =
        PruneOpts { sparsity: Sparsity::of(Scope::Both, 5), calib_batches: 2, ..PruneOpts::default() };
    let stats = calibrate(&exec, &dense, &popts)?;
    let comp = prune(&exec, &dense, &stats, &PruneOpts { method: Method::Corp, ..popts })?;

    // Measure the per-rung cost tables (min of `reps` timed passes per
    // dispatch size) — the simulator's service-time model.
    let gen = crate::data::VisionGen::new(crate::data::DATA_SEED);
    let mut tables = Vec::with_capacity(2);
    for w in [&dense, &comp.weights] {
        let plan = exec.forward_plan(w)?;
        let mut table = Vec::with_capacity(max_batch);
        for b in 1..=max_batch {
            let (t, _) = gen.batch(crate::data::Split::Eval, b as u64, b);
            plan.run_vit(&t)?; // warmup
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let t0 = std::time::Instant::now();
                plan.run_vit(&t)?;
                best = best.min(t0.elapsed().as_secs_f64());
            }
            table.push(best);
        }
        tables.push(table);
    }
    let cost_dense_full = tables[0][max_batch - 1].max(1e-9);
    let cost = SimCost::measured(tables)?;

    // Base rate at half the dense fleet capacity: the 3× spike then offers
    // 1.5× dense capacity through the middle third, so the engine must
    // shed — unless the controller degrades to the cheaper rung.
    let rate = 0.5 * (workers * max_batch) as f64 / cost_dense_full;
    let spike = 3.0;
    let slo_p99_ms = 10.0 * cost_dense_full * 1e3;
    let wl = VisionWorkload::new(cfg, crate::data::DATA_SEED)?;
    for controller_on in [false, true] {
        let eopts = EngineOpts {
            workers,
            rate,
            requests,
            max_batch,
            max_wait: 0.004,
            queue_cap: 32,
            dispatch: DispatchPolicy::Auto,
            spike,
            slo_p99_ms,
            controller: controller_on.then(|| ControllerOpts {
                tick_s: 0.01,
                slo_p99_ms,
                degrade: true,
                recover_after: 3,
                ..Default::default()
            }),
            ..Default::default()
        };
        let member = FleetMember::new(&exec, &dense, &wl, requests).with_fallback(&comp.weights);
        let s = run_fleet_sim(vec![member.erased()], std::slice::from_ref(&cost), &eopts)
            .context("serve bench cell failed: load_spike")?
            .remove(0);
        let time_dense_s = s.time_in_variant_s.first().copied().unwrap_or(0.0);
        let time_degraded_s: f64 = s.time_in_variant_s.iter().skip(1).sum();
        let degraded: usize = s.served_by_variant.iter().skip(1).sum();
        let degraded_frac = if s.served == 0 { 0.0 } else { degraded as f64 / s.served as f64 };
        println!(
            "spike  {model:12} controller={controller_on:5} w={workers} rate {rate:7.0}/s ×{spike:.0}: \
             p99 {:8.2}ms (SLO {slo_p99_ms:.1}ms) | served {:3} shed {:3} | \
             degraded {:4.0}% | {} transition(s)",
            s.p99_ms,
            s.served,
            s.shed,
            degraded_frac * 100.0,
            s.transitions.len()
        );
        runs.push(obj(vec![
            ("cell", Json::Str("load_spike".into())),
            ("workload", Json::Str("vision".into())),
            ("model", Json::Str(model.to_string())),
            ("controller", Json::Bool(controller_on)),
            ("degrade", Json::Bool(controller_on)),
            ("workers", num(workers as f64)),
            ("rate_rps", num(rate)),
            ("spike", num(spike)),
            ("requests", num(requests as f64)),
            ("max_batch", num(max_batch as f64)),
            ("slo_p99_ms", num(slo_p99_ms)),
            ("p50_ms", num(s.p50_ms)),
            ("p95_ms", num(s.p95_ms)),
            ("p99_ms", num(s.p99_ms)),
            ("served", num(s.served as f64)),
            ("shed", num(s.shed as f64)),
            ("time_dense_s", num(time_dense_s)),
            ("time_degraded_s", num(time_degraded_s)),
            ("degraded_frac", num(degraded_frac)),
            ("transitions", num(s.transitions.len() as f64)),
        ]));
    }
    Ok(())
}

/// The v7 chaos cell: the fleet served through the deterministic
/// simulator with an injected fault plan — two worker kills, two dispatch
/// faults, one service-time delay — under per-request deadlines and a
/// retry budget, controller off and then on (fault-rate degrade signal
/// armed). Costs are a fixed affine model, so the whole trajectory
/// (goodput, p99, fault tallies) is bit-stable run-to-run and across
/// machines.
#[cfg(not(pjrt_backend))]
fn chaos_cells(rt: &Runtime, runs: &mut Vec<Json>) -> Result<()> {
    use crate::serve::{run_fleet_sim, ControllerOpts, FaultPlan, FleetMember, SimCost};

    let (model, requests) = match bench_mode() {
        BenchMode::Smoke => ("vit_t", 96usize),
        BenchMode::Fast => ("vit_t", 192),
        BenchMode::Full => ("vit_b", 256),
    };
    let (workers, max_batch) = (2usize, 8usize);
    let cfg = ModelConfig::by_name(model).context("chaos cell model")?;
    let exec = Executor::new(rt, cfg);
    let dense = WeightStore::init(cfg, 1);
    let popts =
        PruneOpts { sparsity: Sparsity::of(Scope::Both, 5), calib_batches: 2, ..PruneOpts::default() };
    let stats = calibrate(&exec, &dense, &popts)?;
    let comp = prune(&exec, &dense, &stats, &PruneOpts { method: Method::Corp, ..popts })?;

    // Deterministic affine costs (degraded rung at 40%): full-batch cost
    // 8 ms → fleet capacity 2·8/0.008 = 2000 req/s; offer 60% of it.
    let (base_s, per_row_s) = (0.004, 0.0005);
    let cost = SimCost::affine(max_batch, base_s, per_row_s, &[1.0, 0.4]);
    let cost_full = base_s + per_row_s * max_batch as f64;
    let rate = 0.6 * (workers * max_batch) as f64 / cost_full;
    let slo_p99_ms = 10.0 * cost_full * 1e3;
    let chaos = FaultPlan::parse("kill=0@1,kill=1@4,fail=3,fail=7@0,delay=5:30")?;
    let wl = VisionWorkload::new(cfg, crate::data::DATA_SEED)?;
    for controller_on in [false, true] {
        let eopts = EngineOpts {
            workers,
            rate,
            requests,
            max_batch,
            max_wait: 0.004,
            queue_cap: 64,
            dispatch: DispatchPolicy::Auto,
            slo_p99_ms,
            request_timeout: 20.0 * cost_full,
            max_retries: 2,
            retry_backoff: 0.001,
            chaos: Some(chaos.clone()),
            controller: controller_on.then(|| ControllerOpts {
                tick_s: 0.01,
                slo_p99_ms,
                degrade: true,
                recover_after: 3,
                fault_hi: 50.0,
                ..Default::default()
            }),
            ..Default::default()
        };
        let member = FleetMember::new(&exec, &dense, &wl, requests).with_fallback(&comp.weights);
        let s = run_fleet_sim(vec![member.erased()], std::slice::from_ref(&cost), &eopts)
            .context("serve bench cell failed: chaos")?
            .remove(0);
        let goodput = s.served as f64 / requests.max(1) as f64;
        println!(
            "chaos  {model:12} controller={controller_on:5} w={workers} rate {rate:7.0}/s: \
             p99 {:8.2}ms | served {:3} shed {:3} failed {:2} | {} retries {} timeouts \
             {} respawn(s) | goodput {:5.1}%",
            s.p99_ms,
            s.served,
            s.shed,
            s.failures,
            s.retries,
            s.timeouts,
            s.worker_respawns,
            goodput * 100.0
        );
        runs.push(obj(vec![
            ("cell", Json::Str("chaos".into())),
            ("workload", Json::Str("vision".into())),
            ("model", Json::Str(model.to_string())),
            ("controller", Json::Bool(controller_on)),
            ("workers", num(workers as f64)),
            ("rate_rps", num(rate)),
            ("requests", num(requests as f64)),
            ("max_batch", num(max_batch as f64)),
            ("slo_p99_ms", num(slo_p99_ms)),
            ("request_timeout_ms", num(eopts.request_timeout * 1e3)),
            ("retries_budget", num(eopts.max_retries as f64)),
            ("p50_ms", num(s.p50_ms)),
            ("p99_ms", num(s.p99_ms)),
            ("served", num(s.served as f64)),
            ("shed", num(s.shed as f64)),
            ("failures", num(s.failures as f64)),
            ("retries", num(s.retries as f64)),
            ("timeouts", num(s.timeouts as f64)),
            ("worker_respawns", num(s.worker_respawns as f64)),
            ("kv_reclaimed_blocks", num(s.kv_reclaimed_blocks as f64)),
            ("goodput_frac", num(goodput)),
        ]));
    }
    Ok(())
}

/// The gated PJRT build has no threaded engine or simulator — the
/// load-spike and chaos cells are no-ops there; the grid rows still carry
/// the v7 schema.
#[cfg(pjrt_backend)]
fn spike_cells(_rt: &Runtime, _runs: &mut Vec<Json>) -> Result<()> {
    Ok(())
}

#[cfg(pjrt_backend)]
fn chaos_cells(_rt: &Runtime, _runs: &mut Vec<Json>) -> Result<()> {
    Ok(())
}

/// Run the serving benchmark grid; when `json_out` is set, write
/// `BENCH_serve.json`-style output there (schema `corp-bench-serve/v7`).
pub fn bench_serve(json_out: Option<&str>) -> Result<()> {
    let rt = Runtime::from_default_dir()?;
    // Fail loudly, never stale-ly: if a cell errors mid-sweep the run
    // aborts (non-zero exit through the CLI), and a pre-existing output
    // file must not survive to masquerade as this run's results.
    if let Some(path) = json_out {
        let _ = std::fs::remove_file(path);
    }
    let mut runs = Vec::new();
    for g in mode_grids() {
        let cfg = ModelConfig::by_name(g.model).context("bench serve model")?;
        let exec = Executor::new(&rt, cfg);

        // Accuracy is irrelevant to throughput shape, so the dense variant
        // is a deterministic init; one calibration pass serves both pruned
        // variants.
        let dense = WeightStore::init(cfg, 1);
        let popts = PruneOpts {
            sparsity: Sparsity::of(Scope::Both, 5),
            calib_batches: g.calib_batches,
            ..PruneOpts::default()
        };
        let stats = calibrate(&exec, &dense, &popts)?;
        let pruned =
            prune(&exec, &dense, &stats, &PruneOpts { method: Method::Naive, ..popts.clone() })?;
        let comp =
            prune(&exec, &dense, &stats, &PruneOpts { method: Method::Corp, ..popts.clone() })?;
        // The int8 variant: the compensated store quantized with the
        // dequant correction fitted on the same calibration moments.
        let kept = crate::compensate::mlp_kept_indices(cfg, &dense, &stats, &popts)?;
        let (quant, _) = crate::compensate::quantize_weights_corrected(
            cfg,
            &comp.weights,
            &stats,
            &kept,
            popts.lambda,
        )?;
        let variants: [(&str, StoreRef); 4] = [
            ("dense", StoreRef::F32(&dense)),
            ("pruned", StoreRef::F32(&pruned.weights)),
            ("compensated", StoreRef::F32(&comp.weights)),
            ("compensated_int8", StoreRef::Q8(&quant)),
        ];

        println!(
            "serve bench — mode {:?}, {} workload, model {}, {} requests, max batch {}, \
             50% joint sparsity, {} pool worker(s) available",
            bench_mode(),
            if g.gen { "gen" } else { cfg.kind.workload_label() },
            g.model,
            g.requests,
            g.max_batch,
            threads::threads()
        );
        match (cfg.kind, g.gen) {
            (ModelKind::Vit, false) => {
                let wl = VisionWorkload::new(cfg, crate::data::DATA_SEED)?;
                grid_runs(&exec, &variants, &wl, &g, None, &mut runs)?;
            }
            (ModelKind::Gpt, false) => {
                let wl = GptWorkload::new(cfg, crate::data::DATA_SEED)?;
                grid_runs(&exec, &variants, &wl, &g, None, &mut runs)?;
            }
            (ModelKind::Gpt, true) => {
                // The decode axis: same request mix, same outputs. Plain
                // KV-cache, then the paged-KV stress cell (prefills split
                // into 8-token chunks, one block-width of shared opening so
                // prefix adoption fires), then full prefill-per-step.
                let shared = 16.min(cfg.n_ctx);
                let cells =
                    [(DecodeMode::KvCache, 0, 0), (DecodeMode::KvCache, 8, shared), (DecodeMode::Prefill, 0, 0)];
                for (mode, chunk, shared) in cells {
                    let wl = GenWorkload::new(cfg, crate::data::DATA_SEED)?
                        .with_decode(mode)
                        .with_prefill_chunk(chunk)
                        .with_shared_prefix(shared);
                    grid_runs(&exec, &variants, &wl, &g, Some((chunk, shared)), &mut runs)?;
                }
            }
            (ModelKind::Vit, true) => bail!("gen grid on vision model '{}'", g.model),
        }
    }
    spike_cells(&rt, &mut runs)?;
    chaos_cells(&rt, &mut runs)?;

    if let Some(path) = json_out {
        let root = obj(vec![
            ("schema", Json::Str("corp-bench-serve/v7".into())),
            (
                "mode",
                Json::Str(
                    match bench_mode() {
                        BenchMode::Smoke => "smoke",
                        BenchMode::Fast => "fast",
                        BenchMode::Full => "full",
                    }
                    .into(),
                ),
            ),
            ("threads", num(threads::threads() as f64)),
            ("scope", Json::Str("both".into())),
            ("sparsity", num(0.5)),
            ("runs", Json::Arr(runs)),
        ]);
        std::fs::write(path, root.to_string() + "\n")
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_grids_cover_acceptance_shape() {
        // Every mode carries all three workload axes: vision, single-shot
        // text (each with a saturated and, for the dispatch-policy
        // comparison, at least one finite rate), and a generation grid
        // (gpt-only — it becomes kv, kv+chunked/shared, and prefill decode
        // cells); grids stay within the engine's bounds.
        let grids = mode_grids();
        let kinds: Vec<ModelKind> =
            grids.iter().map(|g| ModelConfig::by_name(g.model).unwrap().kind).collect();
        assert!(kinds.contains(&ModelKind::Vit) && kinds.contains(&ModelKind::Gpt));
        assert!(grids.iter().any(|g| g.gen));
        for g in &grids {
            assert!(!g.workers.is_empty());
            assert!(g.rates.iter().any(|&r| r >= SATURATED_RATE));
            if g.gen {
                // The decode axis only fits gpt models.
                assert_eq!(ModelConfig::by_name(g.model).unwrap().kind, ModelKind::Gpt);
            } else {
                assert!(g.rates.iter().any(|&r| r < SATURATED_RATE));
            }
            assert!(g.requests >= g.max_batch && g.max_batch >= 1 && g.calib_batches >= 1);
        }
        assert_eq!(DISPATCHES, [DispatchPolicy::Padded, DispatchPolicy::Exact]);
    }
}
