//! `corp bench serve` — the serving-engine harness behind `BENCH_serve.json`.
//!
//! Drives the concurrent engine (`serve::run_engine`) over a grid of
//! model variant (dense / pruned / compensated at 50% joint sparsity) ×
//! worker count × arrival rate, and reports per-cell p50/p95 latency,
//! queueing delay, mean batch size, and images/sec. The "saturated" rate
//! offers the whole request set at t = 0 with an ample queue, so the
//! images/sec column is the engine's capacity — this is where the pruned
//! fast path has to beat dense, since its GEMMs run at the retained widths.

use anyhow::{Context, Result};

use super::{num, obj};
use crate::data::VisionGen;
use crate::exec::Executor;
use crate::model::{ModelConfig, Scope, Sparsity, WeightStore};
use crate::prune::{calibrate, prune, Method, PruneOpts};
use crate::runtime::Runtime;
use crate::serve::{run_engine, EngineOpts};
use crate::util::bench::{bench_mode, BenchMode};
use crate::util::json::Json;
use crate::util::threads;

/// Arrival rate treated as "everything is due immediately".
const SATURATED_RATE: f64 = 1e9;

/// Grid per mode: (model, requests, worker counts, rates, max_batch,
/// calibration batches for the pruned variants).
fn mode_grid() -> (&'static str, usize, Vec<usize>, Vec<f64>, usize, usize) {
    match bench_mode() {
        BenchMode::Smoke => ("vit_t", 96, vec![1, 2], vec![SATURATED_RATE], 8, 2),
        BenchMode::Fast => ("vit_t", 256, vec![1, 2], vec![SATURATED_RATE, 300.0], 16, 4),
        BenchMode::Full => ("vit_b", 512, vec![1, 2, 4], vec![SATURATED_RATE, 400.0], 16, 8),
    }
}

/// Run the serving benchmark grid; when `json_out` is set, write
/// `BENCH_serve.json`-style output there.
pub fn bench_serve(json_out: Option<&str>) -> Result<()> {
    let (model, requests, worker_counts, rates, max_batch, calib_batches) = mode_grid();
    let cfg = ModelConfig::by_name(model).context("bench serve model")?;
    let rt = Runtime::from_default_dir()?;
    let exec = Executor::new(&rt, cfg);

    // Accuracy is irrelevant to throughput shape, so the dense variant is a
    // deterministic init; one calibration pass serves both pruned variants.
    let dense = WeightStore::init(cfg, 1);
    let popts = PruneOpts {
        sparsity: Sparsity::of(Scope::Both, 5),
        calib_batches,
        ..PruneOpts::default()
    };
    let stats = calibrate(&exec, &dense, &popts)?;
    let pruned = prune(&exec, &dense, &stats, &PruneOpts { method: Method::Naive, ..popts.clone() })?;
    let comp = prune(&exec, &dense, &stats, &PruneOpts { method: Method::Corp, ..popts.clone() })?;
    let variants: [(&str, &WeightStore); 3] =
        [("dense", &dense), ("pruned", &pruned.weights), ("compensated", &comp.weights)];

    println!(
        "serve bench — mode {:?}, model {model}, {requests} requests, max batch {max_batch}, \
         50% joint sparsity, {} pool worker(s) available",
        bench_mode(),
        threads::threads()
    );
    let gen = VisionGen::new(crate::data::DATA_SEED);
    let mut runs = Vec::new();
    for &(label, w) in &variants {
        for &nw in &worker_counts {
            for &rate in &rates {
                let eopts = EngineOpts {
                    workers: nw,
                    rate,
                    requests,
                    max_batch,
                    max_wait: 0.005,
                    // Capacity grid: queue everything, shed nothing.
                    queue_cap: requests,
                    ..Default::default()
                };
                let s = run_engine(&exec, w, &gen, &eopts)?;
                let rate_label = if rate >= SATURATED_RATE {
                    "saturated".to_string()
                } else {
                    format!("{rate:.0}/s")
                };
                println!(
                    "{label:12} w={nw} rate {rate_label:>9}: p50 {:9.2}ms p95 {:9.2}ms | \
                     queue p50 {:9.2}ms | batch {:4.1} | {:7.0} img/s",
                    s.p50_ms, s.p95_ms, s.queue_p50_ms, s.mean_batch, s.throughput_fps
                );
                runs.push(obj(vec![
                    ("variant", Json::Str(label.to_string())),
                    ("workers", num(nw as f64)),
                    ("rate_rps", num(rate)),
                    ("saturated", Json::Bool(rate >= SATURATED_RATE)),
                    ("served", num(s.served as f64)),
                    ("shed", num(s.shed as f64)),
                    ("batches", num(s.batches as f64)),
                    ("p50_ms", num(s.p50_ms)),
                    ("p95_ms", num(s.p95_ms)),
                    ("queue_p50_ms", num(s.queue_p50_ms)),
                    ("exec_mean_ms", num(s.exec_mean_ms)),
                    ("mean_batch", num(s.mean_batch)),
                    ("images_per_sec", num(s.throughput_fps)),
                ]));
            }
        }
    }

    if let Some(path) = json_out {
        let root = obj(vec![
            ("schema", Json::Str("corp-bench-serve/v1".into())),
            (
                "mode",
                Json::Str(
                    match bench_mode() {
                        BenchMode::Smoke => "smoke",
                        BenchMode::Fast => "fast",
                        BenchMode::Full => "full",
                    }
                    .into(),
                ),
            ),
            ("threads", num(threads::threads() as f64)),
            ("model", Json::Str(model.to_string())),
            ("scope", Json::Str("both".into())),
            ("sparsity", num(0.5)),
            ("requests", num(requests as f64)),
            ("max_batch", num(max_batch as f64)),
            ("runs", Json::Arr(runs)),
        ]);
        std::fs::write(path, root.to_string() + "\n")
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_grid_covers_acceptance_shape() {
        // ≥ 2 worker counts in every mode, so the JSON always satisfies the
        // "per worker count" axis; grids stay within the engine's bounds.
        let (m, req, workers, rates, mb, cb) = mode_grid();
        assert!(ModelConfig::by_name(m).is_some());
        assert!(workers.len() >= 2);
        assert!(!rates.is_empty());
        assert!(req >= mb && mb >= 1 && cb >= 1);
    }
}
