//! `corp` CLI — train / prune / eval / serve / tables from the terminal.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match corp::run_cli(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}
