//! Synthetic datasets (ImageNet / C4-WikiText / NYUv2+ADE20k substitutes —
//! see DESIGN.md §Substitutions).
//!
//! All generators are deterministic from a seed, stream batches on demand
//! (nothing is materialized beyond the batch), and expose disjoint train /
//! calibration / eval splits via independent seed domains.

pub mod vision;
pub mod text;
pub mod dense_task;

pub use text::TextGen;
pub use vision::VisionGen;

/// Canonical dataset seed. The generator seed defines the *task* (class
/// prototypes, Markov transition structure); train / calibration / eval
/// draw disjoint example streams from the same task via [`Split`]. Every
/// component must build generators from this seed or models will be
/// evaluated on a different task than they were trained on.
pub const DATA_SEED: u64 = 17;

/// Split tag — maps to an independent RNG stream so splits never overlap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Calib,
    Eval,
}

impl Split {
    pub(crate) fn salt(self) -> u64 {
        match self {
            Split::Train => 0x7261696e,
            Split::Calib => 0x63616c69,
            Split::Eval => 0x6576616c,
        }
    }
}
