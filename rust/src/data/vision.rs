//! Synthetic vision classification dataset (ImageNet substitute).
//!
//! Each example is a 16×16×3 image presented as 16 patch tokens of dim 48
//! (the layout the `embed_*` artifacts expect). An example of class c is
//!
//!   x = s · α · prototype_c + Σ_k z_k · basis_k + ε,   s ∈ {−1, +1}
//!
//! * `prototype_c` — fixed class texture (class-discriminative signal);
//! * the random **sign s** (flipped with probability `FLIP_P`) injects a
//!   non-linearly-separable component — the model must learn partially
//!   orientation-invariant features (full 50/50 flipping creates an
//!   XOR-like plateau that small ViTs take thousands of steps to escape;
//!   25% keeps the nonlinearity while training in a few hundred steps);
//! * `basis_k` — a shared low-rank nuisance subspace with decaying power;
//!   this induces the correlated, low-effective-rank activations that CORP
//!   exploits (the Table 9 analogue is *measured* on the trained model);
//! * ε — isotropic pixel noise.
//!
//! Latents (class, z, s) also generate the dense-prediction targets used by
//! the DINOv2-substitute experiment (per-patch depth / segmentation).

use super::Split;
use crate::tensor::Tensor;
use crate::util::Pcg64;

pub const PATCHES: usize = 16;
pub const PATCH_DIM: usize = 48;
pub const DIM: usize = PATCHES * PATCH_DIM;
pub const CLASSES: usize = 16;
pub const NUISANCE_RANK: usize = 6;
/// Probability of the sign flip.
pub const FLIP_P: f64 = 0.25;
/// Nuisance subspace amplitude.
pub const NUISANCE_SCALE: f32 = 0.8;

/// Deterministic synthetic vision data generator.
pub struct VisionGen {
    seed: u64,
    prototypes: Vec<Vec<f32>>, // [classes][DIM]
    bases: Vec<Vec<f32>>,      // [rank][DIM]
    noise: f32,
}

/// One dense-prediction target pair.
pub struct DenseTargets {
    /// Per-patch depth in (0, 1): [B * PATCHES].
    pub depth: Vec<f32>,
    /// Per-patch segmentation label in 0..CLASSES: [B * PATCHES].
    pub seg: Vec<i32>,
}

impl VisionGen {
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64::new(seed ^ 0x76697369);
        let mut prototypes = Vec::with_capacity(CLASSES);
        for _ in 0..CLASSES {
            let mut p = vec![0.0f32; DIM];
            rng.fill_normal(&mut p, 1.0);
            prototypes.push(p);
        }
        let mut bases = Vec::with_capacity(NUISANCE_RANK);
        for _ in 0..NUISANCE_RANK {
            let mut b = vec![0.0f32; DIM];
            rng.fill_normal(&mut b, 1.0);
            bases.push(b);
        }
        Self { seed, prototypes, bases, noise: 0.2 }
    }

    fn batch_rng(&self, split: Split, index: u64) -> Pcg64 {
        Pcg64::new(self.seed ^ split.salt().wrapping_mul(0x9e3779b97f4a7c15) ^ index.wrapping_mul(0x2545f4914f6cdd1d))
    }

    /// Generate batch `index` of `b` examples: tokens `[b, PATCHES, PATCH_DIM]`
    /// and labels `[b]`.
    pub fn batch(&self, split: Split, index: u64, b: usize) -> (Tensor, Vec<i32>) {
        let (tokens, labels, _, _, _) = self.batch_with_latents(split, index, b);
        (tokens, labels)
    }

    /// Batch plus the latents (class, sign, z) used by dense targets.
    #[allow(clippy::type_complexity)]
    pub fn batch_with_latents(
        &self,
        split: Split,
        index: u64,
        b: usize,
    ) -> (Tensor, Vec<i32>, Vec<f32>, Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = self.batch_rng(split, index);
        let mut data = vec![0.0f32; b * DIM];
        let mut labels = Vec::with_capacity(b);
        let mut signs = Vec::with_capacity(b);
        let mut zs = Vec::with_capacity(b);
        let mut alphas = Vec::with_capacity(b);
        for i in 0..b {
            let c = rng.below(CLASSES);
            let s = if rng.uniform() < FLIP_P { -1.0f32 } else { 1.0 };
            let alpha = rng.uniform_in(0.7, 1.3);
            let z: Vec<f32> = (0..NUISANCE_RANK)
                .map(|k| rng.normal_f32(0.0, 1.0) * NUISANCE_SCALE * (0.9f32).powi(k as i32))
                .collect();
            let out = &mut data[i * DIM..(i + 1) * DIM];
            let proto = &self.prototypes[c];
            for j in 0..DIM {
                let mut v = s * alpha * proto[j];
                for (k, base) in self.bases.iter().enumerate() {
                    v += z[k] * base[j];
                }
                out[j] = v + rng.normal_f32(0.0, self.noise);
            }
            labels.push(c as i32);
            signs.push(s);
            zs.push(z);
            alphas.push(alpha);
        }
        (Tensor::from_vec(&[b, PATCHES, PATCH_DIM], data), labels, signs, zs, alphas)
    }

    /// Dense-prediction targets derived from the same latents: depth is a
    /// smooth function of the class texture energy per patch; segmentation
    /// marks the class on high-energy patches and background elsewhere.
    pub fn batch_dense(&self, split: Split, index: u64, b: usize) -> (Tensor, DenseTargets) {
        let (tokens, labels, signs, zs, _alphas) = self.batch_with_latents(split, index, b);
        let mut depth = Vec::with_capacity(b * PATCHES);
        let mut seg = Vec::with_capacity(b * PATCHES);
        for i in 0..b {
            let c = labels[i] as usize;
            let proto = &self.prototypes[c];
            for p in 0..PATCHES {
                let patch = &proto[p * PATCH_DIM..(p + 1) * PATCH_DIM];
                let energy: f32 = patch.iter().map(|v| v * v).sum::<f32>() / PATCH_DIM as f32;
                let nuisance: f32 = zs[i][0] * 0.1;
                // depth in (0,1): logistic of class-texture energy + nuisance
                let raw = (energy - 1.0) * 2.0 + nuisance + signs[i] * 0.05;
                depth.push(1.0 / (1.0 + (-raw).exp()));
                seg.push(if energy > 1.0 { c as i32 } else { (CLASSES - 1) as i32 });
            }
        }
        (tokens, DenseTargets { depth, seg })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let g = VisionGen::new(42);
        let (t1, l1) = g.batch(Split::Train, 3, 4);
        let (t2, l2) = g.batch(Split::Train, 3, 4);
        assert_eq!(t1.data(), t2.data());
        assert_eq!(l1, l2);
    }

    #[test]
    fn batches_differ_by_index_and_split() {
        let g = VisionGen::new(42);
        let (t1, _) = g.batch(Split::Train, 0, 4);
        let (t2, _) = g.batch(Split::Train, 1, 4);
        let (t3, _) = g.batch(Split::Eval, 0, 4);
        assert_ne!(t1.data(), t2.data());
        assert_ne!(t1.data(), t3.data());
    }

    #[test]
    fn shapes_and_label_range() {
        let g = VisionGen::new(1);
        let (t, l) = g.batch(Split::Calib, 0, 8);
        assert_eq!(t.shape(), &[8, PATCHES, PATCH_DIM]);
        assert!(l.iter().all(|&c| (0..CLASSES as i32).contains(&c)));
    }

    #[test]
    fn class_signal_present() {
        // Mean |corr| with own prototype (mod sign) must exceed cross-class.
        let g = VisionGen::new(7);
        let (t, l) = g.batch(Split::Train, 0, 64);
        let mut own = 0.0f64;
        let mut cross = 0.0f64;
        let mut n_own = 0;
        let mut n_cross = 0;
        for i in 0..64 {
            let x = &t.data()[i * DIM..(i + 1) * DIM];
            for c in 0..CLASSES {
                let dot: f32 = x.iter().zip(&g.prototypes[c]).map(|(a, b)| a * b).sum();
                let v = (dot.abs() / DIM as f32) as f64;
                if c == l[i] as usize {
                    own += v;
                    n_own += 1;
                } else {
                    cross += v;
                    n_cross += 1;
                }
            }
        }
        assert!(own / n_own as f64 > 2.0 * cross / n_cross as f64);
    }

    #[test]
    fn dense_targets_shapes() {
        let g = VisionGen::new(3);
        let (t, d) = g.batch_dense(Split::Eval, 0, 5);
        assert_eq!(t.shape(), &[5, PATCHES, PATCH_DIM]);
        assert_eq!(d.depth.len(), 5 * PATCHES);
        assert_eq!(d.seg.len(), 5 * PATCHES);
        assert!(d.depth.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(d.seg.iter().all(|&v| (0..CLASSES as i32).contains(&v)));
    }

    #[test]
    fn sign_flip_rate_matches_flip_p() {
        let g = VisionGen::new(11);
        let (_, _, signs, _, _) = g.batch_with_latents(Split::Train, 0, 512);
        let neg = signs.iter().filter(|&&s| s < 0.0).count() as f64 / 512.0;
        assert!((neg - FLIP_P).abs() < 0.08, "neg={neg}");
    }
}
