//! Dense-prediction task heads (DINOv2-substitute, Table 8 analogue).
//!
//! Given frozen backbone patch features, fit two closed-form heads:
//! * depth: per-patch ridge regression feature → scalar;
//! * segmentation: per-patch one-vs-rest ridge scores, argmax label.
//!
//! Heads are fitted once on the *dense* backbone and kept frozen while the
//! backbone is pruned — exactly the paper's protocol (prune backbone only,
//! keep task heads unchanged).

use crate::linalg::ridge::ridge_fit_affine;
use crate::linalg::Mat;

/// A fitted linear head: y = x·W + b.
pub struct LinearHead {
    pub w: Mat,          // [d, k]
    pub b: Vec<f64>,     // [k]
}

impl LinearHead {
    /// Fit with ridge on features [n, d] and targets [n, k].
    pub fn fit(features: &Mat, targets: &Mat, lambda: f64) -> Self {
        let (w, b) = ridge_fit_affine(features, targets, lambda);
        Self { w, b }
    }

    /// Apply to features [n, d] -> [n, k].
    pub fn apply(&self, features: &Mat) -> Mat {
        let mut out = features.mul(&self.w);
        for i in 0..out.r {
            for j in 0..out.c {
                out.a[i * out.c + j] += self.b[j];
            }
        }
        out
    }
}

/// One-hot encode labels `[n]` -> `[n, k]`.
pub fn one_hot(labels: &[i32], k: usize) -> Mat {
    let mut out = Mat::zeros(labels.len(), k);
    for (i, &l) in labels.iter().enumerate() {
        out.set(i, l as usize, 1.0);
    }
    out
}

/// Depth metrics: RMSE and δ1 = fraction with max(pred/gt, gt/pred) < 1.25.
pub fn depth_metrics(pred: &[f64], gt: &[f32]) -> (f64, f64) {
    assert_eq!(pred.len(), gt.len());
    let n = pred.len() as f64;
    let mut se = 0.0;
    let mut d1 = 0usize;
    for (&p, &g) in pred.iter().zip(gt) {
        let g = g as f64;
        let p = p.clamp(1e-6, 1.0);
        let g2 = g.max(1e-6);
        se += (p - g) * (p - g);
        let ratio = (p / g2).max(g2 / p);
        if ratio < 1.25 {
            d1 += 1;
        }
    }
    ((se / n).sqrt(), d1 as f64 / n)
}

/// Mean IoU over classes for predicted/gt label maps.
pub fn mean_iou(pred: &[i32], gt: &[i32], k: usize) -> f64 {
    assert_eq!(pred.len(), gt.len());
    let mut inter = vec![0usize; k];
    let mut uni = vec![0usize; k];
    for (&p, &g) in pred.iter().zip(gt) {
        if p == g {
            inter[g as usize] += 1;
            uni[g as usize] += 1;
        } else {
            uni[p as usize] += 1;
            uni[g as usize] += 1;
        }
    }
    let mut sum = 0.0;
    let mut count = 0;
    for c in 0..k {
        if uni[c] > 0 {
            sum += inter[c] as f64 / uni[c] as f64;
            count += 1;
        }
    }
    if count == 0 {
        0.0
    } else {
        sum / count as f64
    }
}

/// Argmax rows of a score matrix.
pub fn argmax_rows(scores: &Mat) -> Vec<i32> {
    (0..scores.r)
        .map(|i| {
            let row = scores.row(i);
            let mut best = 0usize;
            for j in 1..row.len() {
                if row[j] > row[best] {
                    best = j;
                }
            }
            best as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::gen;
    use crate::util::Pcg64;

    #[test]
    fn head_fits_linear_targets() {
        let mut rng = Pcg64::new(1);
        let x = Mat::from_f32(60, 5, &gen::matrix(&mut rng, 60, 5, 1.0));
        let w = Mat::from_f32(5, 2, &gen::matrix(&mut rng, 5, 2, 1.0));
        let y = x.mul(&w);
        let head = LinearHead::fit(&x, &y, 1e-8);
        let pred = head.apply(&x);
        assert!(pred.max_abs_diff(&y) < 1e-4);
    }

    #[test]
    fn one_hot_rows() {
        let m = one_hot(&[0, 2, 1], 3);
        assert_eq!(m.row(0), &[1.0, 0.0, 0.0]);
        assert_eq!(m.row(1), &[0.0, 0.0, 1.0]);
        assert_eq!(m.row(2), &[0.0, 1.0, 0.0]);
    }

    #[test]
    fn depth_metrics_perfect() {
        let gt = vec![0.2f32, 0.5, 0.9];
        let pred = vec![0.2f64, 0.5, 0.9];
        let (rmse, d1) = depth_metrics(&pred, &gt);
        assert!(rmse < 1e-6); // f32→f64 widening leaves ~1e-8 residue
        assert_eq!(d1, 1.0);
    }

    #[test]
    fn depth_metrics_detects_error() {
        let gt = vec![0.5f32; 10];
        let pred = vec![0.9f64; 10];
        let (rmse, d1) = depth_metrics(&pred, &gt);
        assert!((rmse - 0.4).abs() < 1e-9);
        assert_eq!(d1, 0.0); // 0.9/0.5 = 1.8 > 1.25
    }

    #[test]
    fn miou_perfect_and_disjoint() {
        assert_eq!(mean_iou(&[0, 1, 1], &[0, 1, 1], 2), 1.0);
        let m = mean_iou(&[0, 0], &[1, 1], 2);
        assert_eq!(m, 0.0);
    }

    #[test]
    fn argmax_basic() {
        let m = Mat::from_rows(2, 3, vec![0.1, 0.9, 0.2, 5.0, -1.0, 2.0]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
    }
}
