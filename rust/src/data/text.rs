//! Synthetic character-level corpus (C4 / WikiText-2 substitute).
//!
//! An order-2 Markov source over `VOCAB` symbols: each context (a, b) allows
//! only K successor symbols with a skewed distribution, so the corpus has
//! learnable structure and a well-defined entropy floor. Calibration and
//! evaluation draw from *different splits* (different seed domains), giving
//! the calibration–evaluation mismatch the paper's OPT experiment probes.

use super::Split;
use crate::util::Pcg64;

pub const VOCAB: usize = 96;
const SUCCESSORS: usize = 4;
/// Skewed successor distribution (sums to 1).
const PROBS: [f64; SUCCESSORS] = [0.6, 0.2, 0.15, 0.05];

/// Deterministic Markov text generator.
pub struct TextGen {
    seed: u64,
}

impl TextGen {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// The K allowed successors of context (a, b) — a pure function of the
    /// generator seed, shared by all splits (same language, different text).
    fn successors(&self, a: i32, b: i32) -> [i32; SUCCESSORS] {
        let mut h = Pcg64::new(
            self.seed ^ (a as u64).wrapping_mul(0x9e3779b97f4a7c15) ^ (b as u64).wrapping_mul(0xc2b2ae3d27d4eb4f),
        );
        let mut out = [0i32; SUCCESSORS];
        for slot in out.iter_mut() {
            *slot = h.below(VOCAB) as i32;
        }
        out
    }

    fn sample_next(&self, a: i32, b: i32, rng: &mut Pcg64) -> i32 {
        let succ = self.successors(a, b);
        let u = rng.uniform();
        let mut cum = 0.0;
        for (i, &p) in PROBS.iter().enumerate() {
            cum += p;
            if u < cum {
                return succ[i];
            }
        }
        succ[SUCCESSORS - 1]
    }

    /// Generate batch `index`: inputs ids `[b, n_ctx]` and next-token targets
    /// `[b, n_ctx]` (`targets[t] = ids[t+1]`).
    pub fn batch(&self, split: Split, index: u64, b: usize, n_ctx: usize) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Pcg64::new(
            self.seed
                ^ split.salt().wrapping_mul(0x9e3779b97f4a7c15)
                ^ index.wrapping_mul(0x2545f4914f6cdd1d)
                ^ 0x74657874,
        );
        let mut ids = Vec::with_capacity(b * n_ctx);
        let mut targets = Vec::with_capacity(b * n_ctx);
        for _ in 0..b {
            // Burn in the chain from a random context.
            let mut a = rng.below(VOCAB) as i32;
            let mut c = rng.below(VOCAB) as i32;
            for _ in 0..8 {
                let n = self.sample_next(a, c, &mut rng);
                a = c;
                c = n;
            }
            let mut seq = Vec::with_capacity(n_ctx + 1);
            seq.push(c);
            for _ in 0..n_ctx {
                let n = self.sample_next(a, c, &mut rng);
                a = c;
                c = n;
                seq.push(c);
            }
            ids.extend_from_slice(&seq[..n_ctx]);
            targets.extend_from_slice(&seq[1..=n_ctx]);
        }
        (ids, targets)
    }

    /// Serving request model: synthesize prompt `id` for an LM request —
    /// eval-split ids truncated to a deterministic prompt length drawn
    /// uniformly from `[min_len, n_ctx]` (a pure function of the generator
    /// seed and `id`), then zero-padded back to `n_ctx` so the fixed-width
    /// `fwd_*` artifacts accept it. Causal masking makes positions
    /// `< prompt_len` independent of the padding, so per-request outputs
    /// are identical however the request is batched. Returns
    /// `(ids [n_ctx], prompt_len)`.
    pub fn prompt(&self, id: u64, n_ctx: usize, min_len: usize) -> (Vec<i32>, usize) {
        assert!(min_len >= 1 && min_len <= n_ctx);
        let (mut ids, _) = self.batch(Split::Eval, id, 1, n_ctx);
        let mut rng = Pcg64::new(
            self.seed ^ id.wrapping_mul(0x9e3779b97f4a7c15) ^ 0x70726f6d70740a, // "prompt"
        );
        let len = min_len + rng.below(n_ctx - min_len + 1);
        for slot in ids.iter_mut().skip(len) {
            *slot = 0;
        }
        (ids, len)
    }

    /// The source's conditional entropy (nats/token) — the perplexity floor
    /// exp(H) ≈ 2.89 that a perfect model approaches (slightly lower when
    /// successor collisions merge probability mass).
    pub fn entropy_floor() -> f64 {
        -PROBS.iter().map(|p| p * p.ln()).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let g = TextGen::new(5);
        let (a1, t1) = g.batch(Split::Train, 2, 3, 32);
        let (a2, t2) = g.batch(Split::Train, 2, 3, 32);
        assert_eq!(a1, a2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn targets_shifted_by_one() {
        let g = TextGen::new(5);
        let n = 16;
        let (ids, targets) = g.batch(Split::Eval, 0, 2, n);
        // Inside each row, ids[t+1] == targets[t].
        for row in 0..2 {
            for t in 0..n - 1 {
                assert_eq!(ids[row * n + t + 1], targets[row * n + t]);
            }
        }
    }

    #[test]
    fn vocab_range() {
        let g = TextGen::new(1);
        let (ids, targets) = g.batch(Split::Calib, 7, 4, 64);
        for &v in ids.iter().chain(&targets) {
            assert!((0..VOCAB as i32).contains(&v));
        }
    }

    #[test]
    fn transitions_respect_markov_support() {
        let g = TextGen::new(9);
        let n = 64;
        let (ids, targets) = g.batch(Split::Train, 0, 2, n);
        for row in 0..2 {
            for t in 1..n {
                let a = ids[row * n + t - 1];
                let b = ids[row * n + t];
                let next = targets[row * n + t];
                assert!(g.successors(a, b).contains(&next), "t={t}");
            }
        }
    }

    #[test]
    fn splits_produce_different_text() {
        let g = TextGen::new(5);
        let (a, _) = g.batch(Split::Calib, 0, 2, 32);
        let (b, _) = g.batch(Split::Eval, 0, 2, 32);
        assert_ne!(a, b);
    }

    #[test]
    fn prompt_request_model() {
        let g = TextGen::new(5);
        let n = 32;
        let (ids1, l1) = g.prompt(3, n, 4);
        let (ids2, l2) = g.prompt(3, n, 4);
        // Deterministic per id; lengths stay inside [min_len, n_ctx].
        assert_eq!(ids1, ids2);
        assert_eq!(l1, l2);
        assert!((4..=n).contains(&l1));
        assert_eq!(ids1.len(), n);
        // The prefix is the eval stream; the tail is zero padding.
        let (full, _) = g.batch(Split::Eval, 3, 1, n);
        assert_eq!(&ids1[..l1], &full[..l1]);
        assert!(ids1[l1..].iter().all(|&v| v == 0));
        // Lengths vary across ids (the arrival mix is not degenerate).
        let lens: Vec<usize> = (0..16).map(|i| g.prompt(i, n, 4).1).collect();
        assert!(lens.iter().any(|&l| l != lens[0]));
    }

    #[test]
    fn prompt_min_len_equals_n_ctx() {
        // Degenerate arrival mix: min_len == n_ctx pins every prompt at the
        // full context with no padding (`below(1)` must return 0, not
        // panic) — the edge the generation workload's clamping leans on.
        let g = TextGen::new(5);
        let n = 16;
        for id in 0..8 {
            let (ids, len) = g.prompt(id, n, n);
            assert_eq!(len, n);
            assert_eq!(ids.len(), n);
            let (full, _) = g.batch(Split::Eval, id, 1, n);
            assert_eq!(ids, full, "id {id}: full-context prompt must be unpadded eval text");
        }
        // And the other boundary: min_len == 1 still yields lengths ≥ 1.
        for id in 0..8 {
            let (_, len) = g.prompt(id, n, 1);
            assert!((1..=n).contains(&len));
        }
    }

    #[test]
    fn entropy_floor_value() {
        let h = TextGen::entropy_floor();
        assert!((h - 1.063).abs() < 0.02, "{h}"); // -Σ p ln p for the PROBS
    }
}
